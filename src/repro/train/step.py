"""Loss + train_step builders (sharding-aware, remat/microbatch-ready).

``build_train_step`` returns a pure function
``(state, batch) -> (state, metrics)`` suitable for ``jax.jit`` with
explicit in/out shardings; the builder also returns those shardings
(derived from the logical-axis trees + rule set).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..models import ShardCtx, apply_train, init_model, model_axes
from ..optim import OptConfig, adamw_update, init_opt_state


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  vocab_size: int) -> jnp.ndarray:
    """Stable CE over (possibly vocab-sharded) logits.  Mean over tokens.

    Written max/exp/sum-style so GSPMD keeps the vocab axis sharded and only
    psums the (B, S) statistics.
    """
    logits = logits.astype(jnp.float32)
    m = jnp.max(logits, axis=-1, keepdims=True)
    lse = jnp.log(jnp.sum(jnp.exp(logits - m), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def fused_lm_loss(x: jnp.ndarray, w: jnp.ndarray, labels: jnp.ndarray,
                  vocab_size: int, chunk: int = 8192) -> jnp.ndarray:
    """Vocab-chunked softmax-xent: never materializes (B, S, V) logits.

    Scans over vocab chunks of the LM head, keeping only running
    (max, sumexp, gold) statistics of shape (B, S) — the classic fused-loss
    optimization (beyond-paper; EXPERIMENTS.md §Perf).  The scan body is
    rematerialized in the backward pass, trading ~2× head FLOPs for
    O(B·S·V) → O(B·S·chunk) loss memory traffic.
    """
    b, s, d = x.shape
    v = w.shape[1]
    chunk = min(chunk, v)
    assert v % chunk == 0, (v, chunk)
    n_chunks = v // chunk
    xf = x.reshape(b * s, d)
    lab = labels.reshape(b * s)

    @jax.checkpoint
    def body(carry, i):
        m, se, gold = carry
        wc = jax.lax.dynamic_slice_in_dim(w, i * chunk, chunk, 1)
        lg = (xf @ wc).astype(jnp.float32)  # (BS, chunk)
        # mask padded vocab tail
        ids = i * chunk + jnp.arange(chunk)
        lg = jnp.where(ids[None, :] < vocab_size, lg, -1e30)
        m_new = jnp.maximum(m, jnp.max(lg, axis=-1))
        se = se * jnp.exp(m - m_new) + jnp.sum(jnp.exp(lg - m_new[:, None]),
                                               axis=-1)
        in_chunk = (lab >= i * chunk) & (lab < (i + 1) * chunk)
        g = jnp.take_along_axis(
            lg, jnp.clip(lab - i * chunk, 0, chunk - 1)[:, None], axis=1)[:, 0]
        gold = jnp.where(in_chunk, g, gold)
        return (m_new, se, gold), None

    init = (jnp.full((b * s,), -1e30, jnp.float32),
            jnp.zeros((b * s,), jnp.float32),
            jnp.zeros((b * s,), jnp.float32))
    (m, se, gold), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    return jnp.mean(jnp.log(se) + m - gold)


def loss_fn(params, batch, cfg, ctx, fused: bool = False,
            loss_chunk: int = 8192):
    if fused:
        from ..models.transformer import apply_backbone
        x, aux = apply_backbone(params, batch, cfg, ctx)
        w = params["lm_head"] if "lm_head" in params else params["embed"].T
        ce = fused_lm_loss(x, w, batch["labels"], cfg.vocab_size, loss_chunk)
    else:
        logits, aux = apply_train(params, batch, cfg, ctx)
        ce = cross_entropy(logits, batch["labels"], cfg.vocab_size)
    return ce + aux, {"ce": ce, "aux": aux}


def build_train_step(cfg, ctx: ShardCtx, opt_cfg: OptConfig,
                     microbatch: int = 1, fused_loss: bool = False,
                     loss_chunk: int = 8192):
    """Returns train_step(state, batch) -> (state, metrics).

    ``microbatch > 1`` accumulates gradients over sequential micro-batches
    (splits the leading batch dim) — the standard activation-memory lever.
    ``fused_loss`` uses the vocab-chunked softmax-xent (§Perf lever).
    """
    _loss = functools.partial(loss_fn, fused=fused_loss,
                              loss_chunk=loss_chunk)

    def train_step(state, batch):
        if microbatch == 1:
            (loss, parts), grads = jax.value_and_grad(
                _loss, has_aux=True)(state["params"], batch, cfg, ctx)
        else:
            def mb_slice(i, t):
                mb = t.shape[0] // microbatch
                return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, 0)

            def acc_step(carry, i):
                gsum, lsum = carry
                mb_batch = jax.tree.map(
                    functools.partial(mb_slice, i),
                    {k: v for k, v in batch.items() if k != "positions"})
                if "positions" in batch:  # (3, B, S): slice dim 1
                    mbp = jax.lax.dynamic_slice_in_dim(
                        batch["positions"],
                        i * (batch["positions"].shape[1] // microbatch),
                        batch["positions"].shape[1] // microbatch, 1)
                    mb_batch["positions"] = mbp
                (l, _), g = jax.value_and_grad(_loss, has_aux=True)(
                    state["params"], mb_batch, cfg, ctx)
                gsum = jax.tree.map(jnp.add, gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              state["params"])
            (gsum, lsum), _ = jax.lax.scan(
                acc_step, (g0, jnp.zeros((), jnp.float32)),
                jnp.arange(microbatch))
            grads = jax.tree.map(lambda g: g / microbatch, gsum)
            loss = lsum / microbatch
            parts = {"ce": loss, "aux": jnp.zeros((), jnp.float32)}

        new_params, new_opt, om = adamw_update(
            state["params"], grads, state["opt"], opt_cfg)
        metrics = {"loss": loss, **parts, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def build_dxt_fit_step(opt_cfg: OptConfig, skip_nonfinite: bool = True,
                       **engine_kwargs):
    """Fitting step for the engine-backed DXT layer (``core.layers``).

    Returns ``fit_step(state, batch) -> (state, metrics)`` minimizing the
    MSE between the layer's transform of ``batch["x"]`` (B, N1, N2, N3)
    and ``batch["y"]``.  The forward runs the planned engine and the
    backward runs *through* it too — ``jax.value_and_grad`` hits the
    engine's custom VJP, so the input cotangent is the adjoint-planned
    GEMT and the factor gradients are SR-GEMM rank-k updates
    (docs/engine.md, "Differentiation"); ``repro.engine.grad_stats()``
    counts the lowered backward kernels.  ``engine_kwargs`` (``fuse=``,
    ``autotune=``, ``mesh=``, …) pass through to the engine.

    ``skip_nonfinite`` (default on — docs/numerics.md) guards the update:
    when the loss or any gradient leaf is NaN/Inf, the step returns the
    *old* state unchanged instead of poisoning the optimizer, reports
    ``metrics["skipped_nonfinite"] = 1.0``, and (when running eagerly)
    counts ``train.nonfinite_skipped``.  The guard is a ``where``-select,
    so the step stays jittable and shape-stable.
    """
    from ..core.layers import apply_dxt3d_layer
    from ..obs import metrics as _metrics

    def loss_fn(params, batch):
        pred = apply_dxt3d_layer(params, batch["x"], **engine_kwargs)
        # |·|² keeps the loss real for complex kinds (DFT factors train
        # too); identical to the squared error on real transforms.
        return jnp.mean(jnp.abs(pred - batch["y"]) ** 2)

    def fit_step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_params, new_opt, om = adamw_update(state["params"], grads,
                                               state["opt"], opt_cfg)
        metrics = {"loss": loss, **om}
        if skip_nonfinite:
            finite = jnp.isfinite(loss)
            for g in jax.tree.leaves(grads):
                finite &= jnp.isfinite(g).all()
            keep = lambda new, old: jax.tree.map(  # noqa: E731
                lambda n, o: jnp.where(finite, n, o), new, old)
            new_params = keep(new_params, state["params"])
            new_opt = keep(new_opt, state["opt"])
            metrics["skipped_nonfinite"] = 1.0 - finite.astype(jnp.float32)
            if not isinstance(finite, jax.core.Tracer) and not bool(finite):
                _metrics.inc("train.nonfinite_skipped")
        return {"params": new_params, "opt": new_opt}, metrics

    return fit_step


def init_dxt_fit_state(dims, opt_cfg: OptConfig, ranks=None,
                       kind: str = "dct", key=None,
                       init_scale: float = 0.0) -> dict:
    """Train state for ``build_dxt_fit_step``: DXT-initialized factors +
    AdamW state (m/v inherit the factor shapes)."""
    from ..core.layers import init_dxt3d_layer

    params = init_dxt3d_layer(dims, ranks, kind=kind, key=key,
                              init_scale=init_scale)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def init_train_state(key, cfg, opt_cfg: OptConfig) -> dict:
    params = init_model(key, cfg)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def train_state_axes(cfg) -> dict:
    """Logical axes for the full train state (opt m/v inherit param axes)."""
    pa = model_axes(cfg)
    return {"params": pa, "opt": {"m": pa, "v": pa, "step": ()}}
