"""Training-step substrate (loss, state init, sharded train step).

Not a paper subsystem — production scaffolding for the north-star training
path (``docs/architecture.md``, "Production substrate").
"""
from .step import (build_dxt_fit_step, build_train_step, cross_entropy,
                   init_dxt_fit_state, init_train_state, loss_fn,
                   train_state_axes)
