from .step import (build_train_step, cross_entropy, init_train_state,
                   loss_fn, train_state_axes)
