"""Identity-keyed memoization for (immutable) device arrays.

jax arrays are unhashable, so plain ``lru_cache``/dict keys don't work; and
keying on content means hashing the whole array on every lookup — exactly
the cost the memo is supposed to avoid.  :class:`ArrayMemo` keys on
``id(array)`` and guards against id reuse by holding a weak reference to the
keyed object (entries self-evict when the array is collected).  Objects that
don't support weak references (e.g. raw ``np.ndarray``) are computed but not
cached — correct, just not memoized.
"""
from __future__ import annotations

import weakref
from typing import Any, Callable, Hashable

__all__ = ["ArrayMemo"]


class ArrayMemo:
    """``(array identity, extra key) -> value`` cache with weakref eviction."""

    def __init__(self):
        self._entries: dict[tuple, tuple[weakref.ref, Any]] = {}

    def get_or_compute(self, array, extra: Hashable,
                       compute: Callable[[], Any]) -> Any:
        key = (id(array), extra)
        hit = self._entries.get(key)
        if hit is not None and hit[0]() is array:
            return hit[1]
        value = compute()
        try:
            ref = weakref.ref(array,
                              lambda _r, k=key: self._entries.pop(k, None))
        except TypeError:
            return value  # not weakref-able: skip caching
        self._entries[key] = (ref, value)
        return value

    def __len__(self) -> int:
        return len(self._entries)
