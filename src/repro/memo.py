"""Identity-keyed memoization for (immutable) device arrays.

jax arrays are unhashable, so plain ``lru_cache``/dict keys don't work; and
keying on content means hashing the whole array on every lookup — exactly
the cost the memo is supposed to avoid.  :class:`ArrayMemo` keys on
``id(array)`` and guards against id reuse by holding a weak reference to the
keyed object (entries self-evict when the array is collected).  Objects that
don't support weak references (e.g. raw ``np.ndarray``) are computed but not
cached — correct, just not memoized.

A ``maxsize`` bound makes the memo an LRU cache: long-running serve
sessions stream distinct coefficient matrices through ``esop_plan_cached``
and friends, and without a bound the host-side schedules (plus the strong
references some values hold on derived arrays) grow without limit.  Hits
refresh recency; inserting past the bound evicts the least-recently-used
entry.  ``stats`` counts hits/misses/evictions so the engine's ``info``
dict can prove cache behaviour in production.
"""
from __future__ import annotations

import weakref
from collections import OrderedDict
from typing import Any, Callable, Hashable

__all__ = ["ArrayMemo"]


class ArrayMemo:
    """``(array identity, extra key) -> value`` LRU cache with weakref
    eviction and hit/miss/evict accounting.

    ``maxsize=None`` (default) keeps the pre-bound behaviour: unbounded,
    entries only leave when their keyed array is garbage-collected.

    ``on_event`` optionally receives each accounting event name
    (``"hits"``/``"misses"``/``"evictions"``, matching the ``stats`` keys)
    as it happens — the hook the observability layer uses to mirror memo
    behaviour into the current metrics registry without this module
    importing it.
    """

    def __init__(self, maxsize: int | None = None,
                 on_event: Callable[[str], None] | None = None):
        if maxsize is not None and maxsize < 1:
            raise ValueError(f"maxsize must be >= 1 or None, got {maxsize}")
        self._entries: "OrderedDict[tuple, tuple[weakref.ref, Any]]" = (
            OrderedDict())
        self.maxsize = maxsize
        self.on_event = on_event
        self.stats = {"hits": 0, "misses": 0, "evictions": 0}

    def _record(self, event: str) -> None:
        self.stats[event] += 1
        if self.on_event is not None:
            self.on_event(event)

    def get_or_compute(self, array, extra: Hashable,
                       compute: Callable[[], Any]) -> Any:
        key = (id(array), extra)
        hit = self._entries.get(key)
        if hit is not None and hit[0]() is array:
            self._record("hits")
            self._entries.move_to_end(key)  # refresh LRU recency
            return hit[1]
        self._record("misses")
        value = compute()
        try:
            ref = weakref.ref(array,
                              lambda _r, k=key: self._entries.pop(k, None))
        except TypeError:
            return value  # not weakref-able: skip caching
        self._entries[key] = (ref, value)
        self._entries.move_to_end(key)
        self._evict_over_bound()
        return value

    def set_maxsize(self, maxsize: int | None) -> None:
        """Re-bound the memo; shrinking evicts LRU entries immediately."""
        if maxsize is not None and maxsize < 1:
            raise ValueError(f"maxsize must be >= 1 or None, got {maxsize}")
        self.maxsize = maxsize
        self._evict_over_bound()

    def _evict_over_bound(self) -> None:
        if self.maxsize is None:
            return
        while len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)  # least recently used
            self._record("evictions")

    def clear(self) -> None:
        self._entries.clear()

    def __len__(self) -> int:
        return len(self._entries)
