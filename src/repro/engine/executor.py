"""Plan caching + execution — the engine's public entry points.

``gemt3_planned`` is the drop-in, data-driven counterpart of
``core.gemt.gemt3``: it builds (or fetches from the in-process plan cache) a
:class:`~repro.engine.plan.GemtPlan`, optionally autotunes per-stage block
sizes against the persisted JSON cache, and executes the three lowered
stages through the Pallas kernel dispatch.  Batched inputs (a leading batch
axis) run each stage as a single fused GEMM.

With ``mesh=``/``axes=`` the same entry point runs the TriADA distributed
schedule (paper §4–§5): the planned per-shard stages execute inside a
``shard_map`` body — Pallas/interpret kernels on the local shards, one
``psum_scatter`` per sharded-mode stage — and ``info`` splits the byte
accounting into per-shard local HBM traffic and modeled collective ICI
bytes.  See ``docs/distributed.md``.

``differentiable=True`` makes the execution boundary a ``jax.custom_vjp``
whose backward pass re-enters the engine (docs/engine.md,
"Differentiation"): the X-cotangent runs as the *adjoint plan* — another
planned GEMT over the transposed coefficients, derived from (and cached
off) the forward plan — and the three coefficient cotangents as
mode-unfolded rank-k SR-GEMM updates.  ``info`` gains ``grad_*`` fields
and ``grad_stats()`` counts the executed backward dispatch.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..kernels import ops
from ..kernels.ops import _memo_sink
from ..memo import ArrayMemo
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .autotune import (AutotuneCache, autotune_fused, autotune_fused3,
                       autotune_gemm, make_key)
from .lower import (lower_chain_pair, lower_chain_triple, lower_coeff_grad,
                    lower_coeff_grad_batch, lower_fused_pair,
                    lower_fused_triple, lower_sharded_stage, lower_stage)
from .plan import (DEFAULT_ESOP_THRESHOLD, DEFAULT_VMEM_BUDGET,
                   AdjointChainPlan, GemtPlan, _is_traced, build_plan,
                   derive_adjoint_plan, normalize_axes, plan_adjoint_chain,
                   plan_hbm_bytes, refresh_fused_pair, refresh_fused_triple)

__all__ = [
    "plan_gemt3",
    "execute",
    "execute_with_info",
    "execute_sharded_with_info",
    "gemt3_planned",
    "clear_plan_cache",
    "invalidate_plans",
    "plan_cache_info",
    "grad_stats",
    "reset_grad_stats",
]

_PLAN_CACHE: dict[tuple, GemtPlan] = {}
_ADJ_PLAN_CACHE: dict[tuple, GemtPlan] = {}  # forward plan key -> adjoint
_CHAIN_PLAN_CACHE: dict[tuple, AdjointChainPlan] = {}  # backward walk fusion
_TUNED_PLAN_CACHE: dict[tuple, GemtPlan] = {}  # post-autotune variants
_SHARDED_FN_CACHE: dict[tuple, tuple] = {}  # plan+cs -> (jitted shard_map, infos)
# per-array-identity digests: plan-cache hits stay cheap
_FP_MEMO = ArrayMemo(on_event=_memo_sink("memo.fingerprint."))

# Host-side proof that backward passes actually lower through the engine —
# incremented while the VJP body runs in Python, never from plan metadata.
# "kernel" counts SR-GEMM / block-ESOP / fused launches, "einsum" the
# planned fallback stages; the coeff_* split covers the three coefficient
# cotangents' rank-k updates.  The counters live in the *current* metrics
# registry under the ``grad.`` namespace (``obs.session()`` scoping
# applies); ``grad_stats``/``reset_grad_stats`` are kept as thin shims.
_GRAD_KEYS = (
    "backward_calls",
    "kernel_stages",
    "einsum_stages",
    "coeff_kernel",
    "coeff_einsum",
    "fused_launches",
)


def grad_stats() -> dict:
    """Engine-wide backward-pass dispatch counters (``grad.*`` namespace).

    Counted when the VJP's Python body runs: once per eager backward
    call, but only once per *compilation* under ``jax.jit`` (cached
    executions never re-enter Python).  The counters prove what the
    backward lowers to — kernel vs einsum dispatch — not how many jitted
    steps executed; count steps at the training loop if needed.

    Shim over the current :class:`repro.obs.MetricsRegistry` — prefer
    ``obs.get_registry().snapshot()`` for new code.
    """
    reg = _metrics.get_registry()
    return {k: reg.value("grad." + k) for k in _GRAD_KEYS}


def reset_grad_stats() -> None:
    """Zero the ``grad.*`` counters in the current registry (shim —
    prefer ``obs.get_registry().reset("grad.")``)."""
    _metrics.get_registry().reset("grad.")


def _fingerprint(c: jnp.ndarray) -> str:
    """Digest of a coefficient matrix's shape/dtype/zero structure.

    Memoized on array identity so a hot loop reusing the same coefficient
    arrays doesn't pay a device sync + full-matrix hash per call.  Tracers
    (an outer jit is planning through us) digest to a shape/dtype tag —
    consistent with the planner, whose traced plans are dense-only and
    depend on nothing else.
    """
    if isinstance(c, jax.core.Tracer):
        return f"traced:{tuple(c.shape)}:{jnp.dtype(c.dtype).name}"

    def compute():
        cn = np.asarray(c)
        h = hashlib.sha1(f"{cn.shape}|{cn.dtype}".encode())
        h.update(np.packbits(cn != 0).tobytes())
        return h.hexdigest()[:16]

    return _FP_MEMO.get_or_compute(c, "fp", compute)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _ADJ_PLAN_CACHE.clear()
    _CHAIN_PLAN_CACHE.clear()
    _TUNED_PLAN_CACHE.clear()
    _SHARDED_FN_CACHE.clear()


def _mesh_desc(mesh, axes=None, batch_axis=None):
    """Hashable mesh description used in plan-cache keys (shape + axis
    assignment; device identity is not part of the key)."""
    if mesh is None:
        return None
    return (tuple(mesh.shape.items()), normalize_axes(axes), batch_axis)


def invalidate_plans(predicate=None, *, mesh=None) -> int:
    """Selectively drop cached plans; returns how many primary entries fell.

    ``predicate(key, plan)`` picks ``_PLAN_CACHE`` entries (the cache key's
    last element is the ``_mesh_desc`` — ``None`` for single-device plans);
    ``mesh=`` is the common case and matches every plan built for a mesh of
    that shape.  Derived state — adjoint plans, autotuned variants, and the
    jitted ``shard_map`` programs whose closures capture the old mesh's
    devices — is dropped alongside its forward plan, so a re-meshed session
    (``docs/serving.md``) replans from scratch instead of dispatching onto
    dead devices.  With no arguments everything goes (a counted
    :func:`clear_plan_cache`).  Counted in ``plan.invalidations``.
    """
    if predicate is None and mesh is None:
        n = len(_PLAN_CACHE)
        clear_plan_cache()
        _metrics.inc("plan.invalidations", n)
        return n
    if predicate is None:
        shape = tuple(mesh.shape.items())

        def predicate(key, plan):
            return key[-1] is not None and key[-1][0] == shape

    dropped: set[str] = set()
    n = 0
    for key, plan in list(_PLAN_CACHE.items()):
        if predicate(key, plan):
            del _PLAN_CACHE[key]
            dropped.add(plan.key)
            n += 1
    if dropped:
        for key, adj in list(_ADJ_PLAN_CACHE.items()):
            if key[0] in dropped:
                del _ADJ_PLAN_CACHE[key]
                dropped.add(adj.key)  # sharded VJP fns key off the adjoint
        for key in list(_CHAIN_PLAN_CACHE):
            if key[0] in dropped or key[1] in dropped:
                del _CHAIN_PLAN_CACHE[key]
        vjp_prefixes = ("vjp_prefix", "vjp_chain", "vjp_rec_chain",
                        "vjp_adj_chain", "vjp_adj_tail", "vjp_coeff_batch",
                        "vjp_coeff", "vjp_fused_walk")
        for cache in (_TUNED_PLAN_CACHE, _SHARDED_FN_CACHE):
            for key in list(cache):
                pk = key[1] if key[0] in vjp_prefixes else key[0]
                if pk in dropped:
                    del cache[key]
    _metrics.inc("plan.invalidations", n)
    return n


def plan_cache_info() -> dict:
    return {"entries": len(_PLAN_CACHE), "adjoint": len(_ADJ_PLAN_CACHE),
            "chain": len(_CHAIN_PLAN_CACHE),
            "tuned": len(_TUNED_PLAN_CACHE),
            "sharded_fns": len(_SHARDED_FN_CACHE)}


def default_mode_axes(mesh, batch_axis=None) -> tuple:
    """Default per-mode axis assignment: mesh axes in order, modes beyond
    the mesh rank unsharded — e.g. a ``("data", "model")`` mesh shards
    modes 1–2 and keeps mode 3 local (the paper's single-pod placement).
    Axes claimed by ``batch_axis`` are excluded (an axis can shard only
    one dim of the stationary tensor)."""
    taken = (set() if batch_axis is None else
             set(batch_axis if isinstance(batch_axis, tuple)
                 else (batch_axis,)))
    names = tuple(a for a in mesh.axis_names if a not in taken)
    return (names + (None, None, None))[:3]


def plan_gemt3(
    x_shape: tuple[int, ...],
    x_dtype,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    order: tuple[int, int, int] | None = None,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | str | None = None,  # see FUSE_MODES
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    backend: str | None = None,  # pin every stage ("einsum"); None = auto
    accum: str | None = None,  # accumulation mode; see engine.numerics
    error_budget: float | None = None,  # max a-priori relative error bound
    mesh=None,
    axes=None,
    batch_axis=None,
) -> GemtPlan:
    """Build (or fetch) the plan for this problem; memoized in-process."""
    key = (
        tuple(x_shape), jnp.dtype(x_dtype).name,
        tuple(order) if order is not None else None,
        esop_threshold, block_sizes, fuse, vmem_budget, backend,
        accum, error_budget,
        _fingerprint(c1), _fingerprint(c2), _fingerprint(c3),
        _mesh_desc(mesh, axes, batch_axis),
    )
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("plan", {"shape": tuple(x_shape), "fuse": fuse,
                                      "vmem_budget": vmem_budget})
        with sp:
            plan = build_plan(x_shape, x_dtype, c1, c2, c3, order=order,
                              esop_threshold=esop_threshold,
                              block_sizes=block_sizes, fuse=fuse,
                              vmem_budget=vmem_budget, backend=backend,
                              accum=accum, error_budget=error_budget,
                              mesh=mesh, axes=axes,
                              batch_axis=batch_axis)
        _PLAN_CACHE[key] = plan
        _metrics.inc("plan.builds")
        fusion_events = [e for e in plan.events
                         if e.get("kind") != "numerics_degradation"]
        if fusion_events:
            _metrics.inc("plan.fusion_degradations", len(fusion_events))
        numerics_events = [e for e in plan.events
                           if e.get("kind") == "numerics_degradation"]
        if numerics_events:
            _metrics.inc("plan.numerics_degradations", len(numerics_events))
    else:
        _metrics.inc("plan.cache_hits")
    return plan


def _autotuned_plan(
    plan: GemtPlan,
    cs: dict[int, jnp.ndarray],
    batch: int,
    cache: AutotuneCache,
    use_pallas: bool | None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    x_dtype=jnp.float32,
) -> GemtPlan:
    """Replace each kernel stage's (and the fused pair's/triple's) tiles
    with tuned ones.

    Adjoint plans (``key`` suffix ``|adjoint`` from ``derive_adjoint_plan``)
    tune under their own autotune role: forward-tuned tiles must never
    replay for the backward's transposed problems (see ``make_key``).
    """
    adjoint = plan.key.endswith("|adjoint")
    fused_idx = (set() if plan.fused is None
                 else {plan.fused.first, plan.fused.first + 1})
    if plan.fused3 is not None:
        fused_idx = {0, 1, 2}  # the megakernel covers the whole schedule
    stages = []
    for i, st in enumerate(plan.stages):
        if st.backend == "einsum" or i in fused_idx:
            # fused stages never run their staged tiles — don't probe them
            stages.append(st)
            continue
        rows = st.rows * max(batch, 1)
        c = cs[st.mode]
        sig = _fingerprint(c)
        key = make_key(rows, st.k, st.n, c.dtype, st.backend, sig,
                       adjoint=adjoint, accum=st.accum)
        hit = cache.get(key)
        knobs_live = use_pallas is True or ops.on_tpu()
        # Warm-cache fast path (no probe allocation) — unless the entry is
        # an untuned off-TPU default and the knobs are live here.
        if hit is not None and (hit.get("tuned", True) or not knobs_live):
            bm, bn, bk = int(hit["bm"]), int(hit["bn"]), int(hit["bk"])
        else:
            probe = jnp.ones((rows, st.n), dtype=c.dtype)
            # Sharded-mode stages contract an N_s/P row slice of C; probe
            # with a representative slice so shapes match the local GEMM.
            c_arg = c if int(c.shape[0]) == st.n else c[: st.n]
            bm, bn, bk = autotune_gemm(probe, c_arg, st.backend, sig=sig,
                                       cache=cache, use_pallas=use_pallas,
                                       adjoint=adjoint, accum=st.accum)
        stages.append(dataclasses.replace(st, bm=bm, bn=bn, bk=bk))

    fused = plan.fused
    fused3 = plan.fused3
    isz = jnp.dtype(x_dtype).itemsize
    if fused3 is not None:
        ca, cb, cc = cs[fused3.mode_a], cs[fused3.mode_b], cs[fused3.mode_c]
        bu, bka, bnb, bnc = autotune_fused3(
            ca, cb, cc, rows=fused3.rows * max(batch, 1), dtype=x_dtype,
            start=(fused3.bu, fused3.bka, fused3.bnb, fused3.bnc),
            bna=fused3.bna, kbp=fused3.kbp, kcp=fused3.kcp,
            sig=":".join(_fingerprint(c) for c in (ca, cb, cc)), cache=cache,
            use_pallas=use_pallas, vmem_budget=vmem_budget, adjoint=adjoint,
            accum=fused3.accum)
        if (bu, bka, bnb, bnc) != (fused3.bu, fused3.bka, fused3.bnb,
                                   fused3.bnc):
            fused3 = refresh_fused_triple(
                dataclasses.replace(fused3, bu=bu, bka=bka, bnb=bnb,
                                    bnc=bnc),
                ca, cb, cc, batch, isz)
    if fused is not None:
        ca, cb = cs[fused.mode_a], cs[fused.mode_b]
        bu, bka, bnb = autotune_fused(
            ca, cb, rows=fused.rows * max(batch, 1), dtype=x_dtype,
            start=(fused.bu, fused.bka, fused.bnb),
            bna=fused.bna, kbp=fused.kbp,
            sig=f"{_fingerprint(ca)}:{_fingerprint(cb)}", cache=cache,
            use_pallas=use_pallas, vmem_budget=vmem_budget, adjoint=adjoint,
            accum=fused.accum)
        if (bu, bka, bnb) != (fused.bu, fused.bka, fused.bnb):
            fused = refresh_fused_pair(
                dataclasses.replace(fused, bu=bu, bka=bka, bnb=bnb),
                ca, cb, batch, isz)
    # Tuning moved tiles, so the byte model must be re-evaluated on what
    # will actually run — stale numbers describe a configuration that never
    # executes (the revisit factors depend on bm/bn and the fused tiles).
    # x's itemsize keeps the units identical to build_plan's model.
    stages_t = tuple(stages)
    return dataclasses.replace(
        plan, stages=stages_t, fused=fused, fused3=fused3,
        hbm_bytes_staged=plan_hbm_bytes(stages_t, None, batch, isz),
        hbm_bytes_moved=plan_hbm_bytes(stages_t, fused, batch, isz,
                                       fused3=fused3))


def execute_with_info(
    plan: GemtPlan,
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    out: jnp.ndarray | None = None,
    *,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Run a plan; returns ``(y, info)`` with per-stage dispatch accounting.

    When the plan carries a fused pair, those two stages run as one fused
    kernel launch (``info["fused"]`` reports its modes, VMEM footprint and
    the modeled pair-traffic saving); the surrounding stages run staged.
    ``info["hbm_bytes_moved"]`` / ``"hbm_bytes_staged"`` expose the modeled
    traffic of the executed vs. the all-staged schedule.
    """
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span("execute", {"order": plan.order,
                                     "backends": plan.backends,
                                     "macs": plan.macs,
                                     "hbm_bytes_moved": plan.hbm_bytes_moved,
                                     "shape": tuple(x.shape),
                                     "key": plan.key})
    with sp:
        cs = {1: c1, 2: c2, 3: c3}
        y = x
        stage_infos = []
        i = 0
        while i < len(plan.stages):
            if plan.fused3 is not None and i == 0:
                ft = plan.fused3
                y, finfo = lower_fused_triple(y, cs[ft.mode_a], cs[ft.mode_b],
                                              cs[ft.mode_c], ft,
                                              use_pallas=use_pallas)
                stage_infos.append(finfo)
                i += 3
                continue
            if plan.fused is not None and i == plan.fused.first:
                fp = plan.fused
                y, finfo = lower_fused_pair(y, cs[fp.mode_a], cs[fp.mode_b],
                                            fp, use_pallas=use_pallas)
                stage_infos.append(finfo)
                i += 2
                continue
            st = plan.stages[i]
            y, sinfo = lower_stage(y, cs[st.mode], st, use_pallas=use_pallas)
            stage_infos.append(sinfo)
            i += 1
        if out is not None:
            y = out + y
        info = _assemble_info(plan, stage_infos)
    _record_execution(info)
    return y, info


def _record_execution(info: dict) -> None:
    """Mirror one execution's ``info`` accounting into the current
    metrics registry (``engine.*`` namespace) — the counter totals stay
    in exact parity with summing the per-call ``info`` fields."""
    reg = _metrics.get_registry()
    reg.inc("engine.executions")
    reg.inc("engine.macs", info["macs"])
    reg.inc("engine.hbm_bytes_moved", info["hbm_bytes_moved"])
    reg.inc("engine.hbm_bytes_staged", info["hbm_bytes_staged"])
    reg.inc("engine.collective_bytes", info["collective_bytes"])
    for si in info["stages"]:
        backend = si.get("backend")
        if backend == "fused":
            reg.inc("engine.fused3_launches"
                    if len(si.get("modes", ())) == 3
                    else "engine.fused_launches")
        reg.inc(f"engine.stage.{backend}")


def _assemble_info(plan: GemtPlan, stage_infos: list[dict]) -> dict:
    """Shared info-dict builder for the local and sharded executors.

    Byte accounting is three-way: ``hbm_bytes_moved`` /
    ``hbm_bytes_staged`` are the modeled (per-shard, under a mesh) HBM
    traffic of the executed vs. all-staged schedule, ``hbm_bytes_local``
    aliases the executed number explicitly, and ``collective_bytes`` is
    the modeled per-device psum_scatter ICI traffic (0 on a single
    device).
    """
    fused_info = next((i for i in stage_infos if i.get("backend") == "fused"),
                      None)
    # Aggregate fetch savings over *staged* stages only: the fused pair's
    # counts live in a product space (C_a blocks × C_b slabs) whose units
    # don't sum with per-stage grids — its own savings are under
    # info["fused"]["fetch_savings"].
    staged_infos = [i for i in stage_infos if i.get("backend") != "fused"]
    dense = sum(i.get("blocks_dense", 0) for i in staged_infos)
    live = sum(i.get("blocks_live", 0) for i in staged_infos)
    return {
        "order": plan.order,
        "backends": plan.backends,  # the per-stage (staged-fallback) plan
        # what actually ran: the fused pair collapses to one entry
        "backends_executed": tuple(
            ("fused" + str(i["modes"]) if i.get("backend") == "fused"
             else i["backend"]) for i in stage_infos),
        "macs": plan.macs,
        "macs_effective": plan.macs_effective,
        "stages": stage_infos,
        "fused": fused_info,
        "axes": plan.axes,
        "shards": plan.shards,
        "batch_axis": plan.batch_axis,
        "hbm_bytes_staged": plan.hbm_bytes_staged,
        "hbm_bytes_moved": plan.hbm_bytes_moved,
        "hbm_bytes_local": plan.hbm_bytes_moved,
        "collective_bytes": plan.collective_bytes,
        "fetch_savings": ((1.0 - live / dense) if dense
                          else (fused_info or {}).get("fetch_savings", 0.0)),
        # Bounded ESOP-schedule memo accounting (LRU; see kernels.ops) —
        # serve telemetry uses this to prove the host-side cache behaves.
        "esop_memo": ops.esop_memo_stats(),
        # Planner events (fusion degradations) replayed from the plan —
        # present on cache hits too, so serving sees why a tier demoted.
        "events": list(plan.events),
        # Guarded-numerics accounting: the resolved accumulation mode, the
        # a-priori staged rounding bound it was held to, and any budget
        # escalations/demotions (docs/numerics.md).
        "numerics": {
            "accum": plan.accum,
            "error_bound": plan.error_bound,
            "error_budget": plan.error_budget,
            "events": [e for e in plan.events
                       if e.get("kind") == "numerics_degradation"],
        },
    }


def _sharded_callable(plan: GemtPlan, mesh, use_pallas,
                      cs: dict[int, jnp.ndarray], batched: bool):
    """Build the jitted ``shard_map`` program executing ``plan`` on ``mesh``.

    ESOP / fused-pair prefetch schedules are precomputed host-side from the
    concrete coefficient matrices *before* entering the body — inside it
    the replicated operands are tracers (traced plans carry no such stages,
    so they precompute nothing).  Returns ``(fn, stage_infos)`` where
    ``stage_infos`` is populated at trace time (all entries are static
    host-side accounting, identical for every call of this program).
    """
    fp = plan.fused
    ft = plan.fused3
    fused_idx = set() if fp is None else {fp.first, fp.first + 1}
    if ft is not None:
        fused_idx = {0, 1, 2}
    esop_plans = {}
    for i, st in enumerate(plan.stages):
        if st.backend == "esop" and i not in fused_idx:
            esop_plans[st.mode] = ops.esop_plan_cached(cs[st.mode], st.bk,
                                                       st.bn)
    fused_plans = None
    if fp is not None:
        fused_plans = (ops.esop_plan_cached(cs[fp.mode_a], fp.bna, fp.bka),
                       ops.esop_plan_cached(cs[fp.mode_b], fp.bnb, fp.kbp))
    fused3_plans = None
    if ft is not None:
        fused3_plans = (ops.esop_plan_cached(cs[ft.mode_a], ft.bna, ft.bka),
                        ops.esop_plan_cached(cs[ft.mode_b], ft.bnb, ft.kbp),
                        ops.esop_plan_cached(cs[ft.mode_c], ft.bnc, ft.kcp))

    spec = (P(plan.batch_axis, *plan.axes) if batched else P(*plan.axes))
    stage_infos: list[dict] = []

    def body(x_l, c1_l, c2_l, c3_l):
        del stage_infos[:]  # body re-traces refill, they never duplicate
        cs_l = {1: c1_l, 2: c2_l, 3: c3_l}
        y = x_l
        i = 0
        while i < len(plan.stages):
            if ft is not None and i == 0:
                y, finfo = lower_fused_triple(y, cs_l[ft.mode_a],
                                              cs_l[ft.mode_b],
                                              cs_l[ft.mode_c], ft,
                                              use_pallas=use_pallas,
                                              plans=fused3_plans)
                stage_infos.append(finfo)
                i += 3
                continue
            if fp is not None and i == fp.first:
                y, finfo = lower_fused_pair(y, cs_l[fp.mode_a],
                                            cs_l[fp.mode_b], fp,
                                            use_pallas=use_pallas,
                                            plans=fused_plans)
                stage_infos.append(finfo)
                i += 2
                continue
            st = plan.stages[i]
            if st.axis is None:
                y, sinfo = lower_stage(y, cs_l[st.mode], st,
                                       use_pallas=use_pallas,
                                       esop_plan=esop_plans.get(st.mode))
            else:
                y, sinfo = lower_sharded_stage(y, cs_l[st.mode], st, mesh,
                                               use_pallas=use_pallas)
            stage_infos.append(sinfo)
            i += 1
        return y

    fn = shard_map(body, mesh=mesh, in_specs=(spec, P(), P(), P()),
                   out_specs=spec, check_vma=False)
    return jax.jit(fn), stage_infos


def execute_sharded_with_info(
    plan: GemtPlan,
    mesh,
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    out: jnp.ndarray | None = None,
    *,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Run a mesh plan through the TriADA ``shard_map`` schedule.

    The jitted program is cached per (plan, coefficient content,
    ``use_pallas``), so serving hot loops pay neither the shard_map
    retrace nor the ESOP schedule recompute.  ``info`` matches the
    single-device executor's, with ``collective_bytes`` > 0 for sharded
    stages and all HBM numbers per-shard.
    """
    if plan.axes == (None, None, None) and plan.batch_axis is None:
        # Nothing is sharded: the shard_map program would just replicate
        # the whole computation on every device — run the local executor.
        return execute_with_info(plan, x, c1, c2, c3, out,
                                 use_pallas=use_pallas)
    # The autotuner replaces tiles without touching plan.key, so the tile
    # state must be part of the program key — a tuned plan may not reuse
    # the untuned plan's compiled stages (and vice versa).
    tiles = tuple((s.bm, s.bn, s.bk) for s in plan.stages)
    ftiles = (None if plan.fused is None else
              (plan.fused.bu, plan.fused.bka, plan.fused.bnb))
    f3tiles = (None if plan.fused3 is None else
               (plan.fused3.bu, plan.fused3.bka, plan.fused3.bnb,
                plan.fused3.bnc))
    key = (plan.key, tiles, ftiles, f3tiles, use_pallas, x.ndim,
           _fingerprint(c1), _fingerprint(c2), _fingerprint(c3))
    hit = _SHARDED_FN_CACHE.get(key)
    if hit is None:
        fn, stage_infos = _sharded_callable(
            plan, mesh, use_pallas, {1: c1, 2: c2, 3: c3},
            batched=x.ndim == 4)
        hit = [fn, stage_infos, None]  # assembled info filled post-trace
        _SHARDED_FN_CACHE[key] = hit
    fn, stage_infos, info = hit
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span("execute.sharded",
                         {"order": plan.order, "backends": plan.backends,
                          "axes": tuple(str(a) for a in plan.axes),
                          "macs": plan.macs,
                          "collective_bytes": plan.collective_bytes,
                          "shape": tuple(x.shape), "key": plan.key})
    with sp:
        y = fn(x, c1, c2, c3)
        if out is not None:
            y = out + y
    if info is None:
        # stage_infos is static trace-time accounting, identical for every
        # call of this program — assemble once, not per request (the
        # serving hot loop measured the per-call dict building).
        info = _assemble_info(plan, list(stage_infos))
        hit[2] = info
    info = dict(info)
    info["esop_memo"] = ops.esop_memo_stats()  # live, not cache-frozen
    _record_execution(info)
    return y, info


def execute(plan, x, c1, c2, c3, out=None, *, use_pallas=None):
    """Run a plan, result only."""
    y, _ = execute_with_info(plan, x, c1, c2, c3, out, use_pallas=use_pallas)
    return y


# --------------------------------------------------------------------------
# Differentiation: the engine's custom VJP (the backward pass re-enters the
# engine as another planned trilinear transform — see docs/engine.md,
# "Differentiation").
# --------------------------------------------------------------------------


def _transposed(c: jnp.ndarray) -> jnp.ndarray:
    return ops.transposed_cached(c)


def _match_cotangent(t: jnp.ndarray, like: jnp.ndarray) -> jnp.ndarray:
    """Cast a cotangent to its primal's dtype (custom_vjp requires it).

    A real primal feeding a complex computation (DFT stages promote) gets
    the real part — the transpose of the real→complex embedding, matching
    jax's ``convert_element_type`` transpose rule.
    """
    if t.dtype == like.dtype:  # hot path: no-op cast still dispatches
        return t
    if (jnp.issubdtype(t.dtype, jnp.complexfloating)
            and not jnp.issubdtype(like.dtype, jnp.complexfloating)):
        t = jnp.real(t)
    return t.astype(like.dtype)


def _rebatched_plan(plan: GemtPlan, batch: int, isz: int) -> GemtPlan:
    """Re-evaluate a plan's byte model for a different batch size.

    Stage schedules are batch-independent (``StagePlan.rows`` excludes the
    batch axis; the executors fold the actual batch in at dispatch), so a
    plan built for one batch size executes correctly for any other — only
    the modeled ``hbm_bytes_*`` totals scale with the batch.  The serving
    layer's batched-entry reuse (``DxtServeSession.warmup``) plans once
    per *bucket* and rescales here, so coalesced launches of varying size
    never rebuild a plan.
    """
    return dataclasses.replace(
        plan,
        hbm_bytes_staged=plan_hbm_bytes(plan.stages, None, batch, isz),
        hbm_bytes_moved=plan_hbm_bytes(plan.stages, plan.fused, batch, isz,
                                       fused3=plan.fused3))


def _tuned_plan(plan: GemtPlan, cs: dict[int, jnp.ndarray], batch: int,
                autotune_cache, use_pallas, vmem_budget: int,
                x_dtype) -> GemtPlan:
    """Memoized autotuned variant of ``plan`` (forward and adjoint share
    this path, so adjoint shapes hit the same JSON cache)."""
    cache = (autotune_cache if isinstance(autotune_cache, AutotuneCache)
             else AutotuneCache(autotune_cache))
    # Memoize the tuned variant: a warm hot loop must not pay the cache
    # probes + fused-mask refresh (a device pad + host sync) per call.
    # plan.key only digests the zero *structure*, so the content
    # fingerprints are added — different coefficient matrices of identical
    # sparsity must still tune under their own sigs.
    tkey = (plan.key, cache.path, batch, use_pallas,
            _fingerprint(cs[1]), _fingerprint(cs[2]), _fingerprint(cs[3]))
    tuned = _TUNED_PLAN_CACHE.get(tkey)
    if tuned is None:
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("autotune.plan",
                             {"key": plan.key, "batch": batch})
        with sp:
            tuned = _autotuned_plan(plan, cs, batch, cache, use_pallas,
                                    vmem_budget=vmem_budget, x_dtype=x_dtype)
        _TUNED_PLAN_CACHE[tkey] = tuned
        _metrics.inc("plan.tuned_builds")
    return tuned


def _adjoint_plan(plan: GemtPlan, g_shape, g_dtype,
                  cts: dict[int, jnp.ndarray], *, esop_threshold, block_sizes,
                  fuse, vmem_budget, mesh) -> GemtPlan:
    """Derive (or fetch) the adjoint plan keyed off the forward plan."""
    key = (plan.key, tuple(g_shape), jnp.dtype(g_dtype).name, esop_threshold,
           block_sizes, fuse, vmem_budget,
           _fingerprint(cts[1]), _fingerprint(cts[2]), _fingerprint(cts[3]))
    adj = _ADJ_PLAN_CACHE.get(key)
    if adj is None:
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("plan.adjoint",
                             {"key": plan.key, "shape": tuple(g_shape)})
        with sp:
            adj = derive_adjoint_plan(plan, g_shape, g_dtype, cts[1], cts[2],
                                      cts[3], esop_threshold=esop_threshold,
                                      block_sizes=block_sizes, fuse=fuse,
                                      vmem_budget=vmem_budget, mesh=mesh)
        _ADJ_PLAN_CACHE[key] = adj
        _metrics.inc("plan.adjoint_builds")
    return adj


def _chain_plan(plan: GemtPlan, adj: GemtPlan, g_shape, g_dtype, fuse,
                vmem_budget) -> AdjointChainPlan:
    """Derive (or fetch) the backward walk's fusion schedule.

    Shared by the backward executor and the forward-time ``grad_*``
    accounting, so both see the *same* decision.  Keyed off the **untuned**
    adjoint plan — the chain tiles come from the chain's own VMEM ladder,
    not the per-stage autotuner, and the byte-model comparison must not
    flip between the info prediction and the execution.
    """
    key = (plan.key, adj.key, tuple(g_shape), jnp.dtype(g_dtype).name,
           fuse, vmem_budget)
    chain = _CHAIN_PLAN_CACHE.get(key)
    if chain is None:
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("plan.adjoint_chain",
                             {"key": plan.key, "shape": tuple(g_shape)})
        with sp:
            chain = plan_adjoint_chain(plan, adj, g_shape, g_dtype,
                                       fuse=fuse, vmem_budget=vmem_budget)
        _CHAIN_PLAN_CACHE[key] = chain
        _metrics.inc("plan.adjoint_chain_builds")
        if chain.events:
            _metrics.inc("plan.adjoint_fusion_degradations",
                         len(chain.events))
    return chain


def _kernels_live(use_pallas, *arrays) -> bool:
    """Would the chain ops dispatch the Pallas path for these operands?"""
    return ((use_pallas is True or (use_pallas is None and ops.on_tpu()))
            and not any(jnp.issubdtype(a.dtype, jnp.complexfloating)
                        for a in arrays))


def _execute_vjp_composed(plan: GemtPlan, adj: GemtPlan,
                          chain: AdjointChainPlan, x, cs: dict, cts: dict,
                          g, use_pallas) -> tuple:
    """The fused walk as ONE cached jit — the span-free hot path.

    Same engine-lowered pieces as :func:`_execute_vjp` (on TPU every
    ``pallas_call`` inside the program is still its own kernel launch,
    so ``grad_launches`` accounting is identical), but a single dispatch
    drops the per-piece Python cost and lets XLA share subexpressions
    across the recompute / adjoint / coefficient programs.  Runs with
    tracing enabled take the multi-dispatch walk instead so each span
    times a real launch — a span inside a jitted body would only fire
    at trace time.

    The ``stage_infos`` are static per (plan, chain): the staged-stage
    entries come from ``lower_stage`` at trace time, captured into a
    cell cached next to the compiled walk.
    """
    wkey = ("vjp_fused_walk", plan.key, adj.key, chain.depth,
            chain.rec_fused, use_pallas, x.ndim,
            _fingerprint(cs[1]), _fingerprint(cs[2]), _fingerprint(cs[3]))
    hit = _SHARDED_FN_CACHE.get(wkey)
    if hit is None:
        m0, m1, m2 = chain.modes
        rec_plan = adj_plan = None
        if chain.rec_fused:
            ma, mb = chain.rec_modes
            if _kernels_live(use_pallas, cs[ma], cs[mb]):
                rt = chain.rec_tiles
                rec_plan = ops.esop_plan_cached(cs[ma], rt[3], rt[1])
        if chain.depth == 3:
            if _kernels_live(use_pallas, cts[m0], cts[m1], cts[m2]):
                t3 = chain.tiles
                adj_plan = ops.esop_plan_cached(cts[m0], t3[4], t3[1])
        elif _kernels_live(use_pallas, cts[m0], cts[m1]):
            t2 = chain.tiles
            adj_plan = ops.esop_plan_cached(cts[m0], t2[3], t2[1])
        infos_cell: list = []

        def walk_body(x_, g_, c1_, c2_, c3_, t1_, t2_, t3_):
            csd = {1: c1_, 2: c2_, 3: c3_}
            ctd = {1: t1_, 2: t2_, 3: t3_}
            infos = []
            if chain.rec_fused:
                y2, y1 = lower_chain_pair(
                    x_, csd[chain.rec_modes[0]], csd[chain.rec_modes[1]],
                    chain.rec_modes[0], chain.rec_modes[1], chain.rec_tiles,
                    use_pallas=use_pallas, plan_a=rec_plan)
                infos.append({"kind": "grad_recompute", "backend": "fused",
                              "modes": chain.rec_modes,
                              "vmem_bytes": chain.rec_vmem_bytes})
                ys = [x_, y1, y2]
            else:
                ys, y = [x_], x_
                for st in plan.stages[:-1]:
                    y, si = lower_stage(y, csd[st.mode], st,
                                        use_pallas=use_pallas)
                    infos.append(dict(si, kind="grad_recompute"))
                    ys.append(y)
            if chain.depth == 3:
                dx, g1, g2 = lower_chain_triple(
                    g_, ctd[m0], ctd[m1], ctd[m2], m0, m1, m2, chain.tiles,
                    use_pallas=use_pallas, plan_a=adj_plan)
                infos.append({"kind": "grad_x", "backend": "fused",
                              "modes": chain.modes,
                              "vmem_bytes": chain.vmem_bytes})
            else:
                g2, g1 = lower_chain_pair(
                    g_, ctd[m0], ctd[m1], m0, m1, chain.tiles,
                    use_pallas=use_pallas, plan_a=adj_plan)
                infos.append({"kind": "grad_x", "backend": "fused",
                              "modes": chain.modes[:2],
                              "vmem_bytes": chain.vmem_bytes})
                st = adj.stages[2]
                dx, si = lower_stage(g2, ctd[st.mode], st,
                                     use_pallas=use_pallas)
                infos.append(dict(si, kind="grad_chain"))
            dcl = lower_coeff_grad_batch(ys, [g2, g1, g_], plan.order,
                                         use_pallas=use_pallas)
            infos.append({"kind": "coeff_grad", "backend": "fused",
                          "modes": plan.order})
            if not infos_cell:
                infos_cell.extend(infos)
            return (dx,) + tuple(dcl)

        hit = (jax.jit(walk_body), infos_cell)
        _SHARDED_FN_CACHE[wkey] = hit
    fn, infos = hit
    out = fn(x, g, cs[1], cs[2], cs[3], cts[1], cts[2], cts[3])
    dcs = {mode: out[1 + i] for i, mode in enumerate(plan.order)}
    return out[0], dcs, list(infos)


def _execute_vjp(plan: GemtPlan, adj: GemtPlan, chain: AdjointChainPlan, x,
                 cs: dict, cts: dict, g, use_pallas) -> tuple:
    """Single-device backward pass.  Returns ``(dx, dcs, stage_infos)``.

    Three engine-lowered pieces (see docs/engine.md "Differentiation"),
    each fused when ``chain`` (:func:`plan_adjoint_chain`) says the byte
    model wins and the tiles fit VMEM:

    1. *forward recompute* — the first two forward stages rebuild the
       stage-boundary inputs ``y0=x, y1, y2`` (residuals are just
       ``(x, C_s)``): one chain-pair launch when ``chain.rec_fused``,
       else two staged launches;
    2. *adjoint chain* — ``dX = g ×C₃ᵀ ×C₂ᵀ ×C₁ᵀ`` with the stage-boundary
       cotangents ``g1, g2`` emitted from the same launch (depth 3: one
       chain-triple launch; depth 2: a chain-pair launch plus one staged
       tail stage; depth 0: the legacy staged walk);
    3. *coefficient cotangents* — ``dC_s = unfold(y_{i-1})ᵀ @ unfold(g_i)``
       as one batched multi-output launch (staged walk: three rank-k
       launches).

    Tracers (an outer jit differentiating through us) take the staged
    walk: the fused programs are built host-side around precomputed ESOP
    schedules, which a traced coefficient cannot provide.

    With tracing disabled the pieces run as ONE composed jit
    (:func:`_execute_vjp_composed`) — same launches, one dispatch; the
    multi-dispatch walk below exists so spans time real launches.
    """
    if chain.depth < 2 or _is_traced(x, g, *cs.values(), *cts.values()):
        return _execute_vjp_staged(plan, adj, x, cs, cts, g, use_pallas)
    if not _trace.enabled():
        # hot path: the whole walk as one dispatch (identical launches)
        return _execute_vjp_composed(plan, adj, chain, x, cs, cts, g,
                                     use_pallas)

    infos = []
    # --- forward recompute: y1, y2 ---
    if chain.rec_fused:
        ma, mb = chain.rec_modes
        rkey = ("vjp_rec_chain", plan.key, chain.rec_tiles, use_pallas,
                x.ndim, _fingerprint(cs[ma]), _fingerprint(cs[mb]))
        fn = _SHARDED_FN_CACHE.get(rkey)
        if fn is None:
            rt = chain.rec_tiles
            plan_a = (ops.esop_plan_cached(cs[ma], rt[3], rt[1])
                      if _kernels_live(use_pallas, cs[ma], cs[mb]) else None)

            def rec_body(x_, ca, cb, _m=(ma, mb), _t=rt, _p=plan_a):
                return lower_chain_pair(x_, ca, cb, _m[0], _m[1], _t,
                                        use_pallas=use_pallas, plan_a=_p)

            fn = jax.jit(rec_body)
            _SHARDED_FN_CACHE[rkey] = fn
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("grad.recompute:fused",
                             {"modes": chain.rec_modes,
                              "vmem_bytes": chain.rec_vmem_bytes})
        with sp:
            y2, y1 = fn(x, cs[ma], cs[mb])
        infos.append({"kind": "grad_recompute", "backend": "fused",
                      "modes": chain.rec_modes,
                      "vmem_bytes": chain.rec_vmem_bytes})
        ys = [x, y1, y2]
    else:
        ys = [x]
        y = x
        for st in plan.stages[:-1]:
            sp = _trace.NULL_SPAN
            if _trace.enabled():
                sp = _trace.span(f"grad.recompute:m{st.mode}",
                                 {"mode": st.mode, "backend": st.backend,
                                  "macs": st.macs})
            with sp:
                y, si = lower_stage(y, cs[st.mode], st,
                                    use_pallas=use_pallas)
            si["kind"] = "grad_recompute"
            infos.append(si)
            ys.append(y)

    # --- adjoint chain: dx (+ emitted cotangents g1, g2) ---
    m0, m1, m2 = chain.modes
    if chain.depth == 3:
        akey = ("vjp_adj_chain", adj.key, chain.tiles, use_pallas, g.ndim,
                _fingerprint(cts[m0]), _fingerprint(cts[m1]),
                _fingerprint(cts[m2]))
        fn = _SHARDED_FN_CACHE.get(akey)
        if fn is None:
            t3 = chain.tiles
            plan_a = (ops.esop_plan_cached(cts[m0], t3[4], t3[1])
                      if _kernels_live(use_pallas, cts[m0], cts[m1],
                                       cts[m2]) else None)

            def adj_body(g_, c0, c1, c2, _m=(m0, m1, m2), _t=t3,
                         _p=plan_a):
                return lower_chain_triple(g_, c0, c1, c2, _m[0], _m[1],
                                          _m[2], _t, use_pallas=use_pallas,
                                          plan_a=_p)

            fn = jax.jit(adj_body)
            _SHARDED_FN_CACHE[akey] = fn
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("grad.x:fused",
                             {"modes": chain.modes, "depth": 3,
                              "vmem_bytes": chain.vmem_bytes})
        with sp:
            dx, g1, g2 = fn(g, cts[m0], cts[m1], cts[m2])
        infos.append({"kind": "grad_x", "backend": "fused",
                      "modes": chain.modes, "vmem_bytes": chain.vmem_bytes})
        gs = [g, g1, g2]
    else:  # depth == 2: chain pair + one staged tail stage
        akey = ("vjp_adj_chain", adj.key, chain.tiles, use_pallas, g.ndim,
                _fingerprint(cts[m0]), _fingerprint(cts[m1]))
        fn = _SHARDED_FN_CACHE.get(akey)
        if fn is None:
            t2 = chain.tiles
            plan_a = (ops.esop_plan_cached(cts[m0], t2[3], t2[1])
                      if _kernels_live(use_pallas, cts[m0], cts[m1])
                      else None)

            def adj_body(g_, c0, c1, _m=(m0, m1), _t=t2, _p=plan_a):
                return lower_chain_pair(g_, c0, c1, _m[0], _m[1], _t,
                                        use_pallas=use_pallas, plan_a=_p)

            fn = jax.jit(adj_body)
            _SHARDED_FN_CACHE[akey] = fn
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("grad.x:fused",
                             {"modes": chain.modes[:2], "depth": 2,
                              "vmem_bytes": chain.vmem_bytes})
        with sp:
            g2, g1 = fn(g, cts[m0], cts[m1])
        infos.append({"kind": "grad_x", "backend": "fused",
                      "modes": chain.modes[:2],
                      "vmem_bytes": chain.vmem_bytes})
        st = adj.stages[2]
        tkey = ("vjp_adj_tail", adj.key, use_pallas, g.ndim,
                _fingerprint(cts[st.mode]))
        hit = _SHARDED_FN_CACHE.get(tkey)
        if hit is None:
            si_cell: dict = {}

            def tail_body(g2_, _c=cts[st.mode], _st=st):
                # eager lower_stage pays pad/crop dispatch per call; the
                # jit replays one cached program.  The stage info is
                # static metadata — captured at trace time, reused after.
                y_, si_ = lower_stage(g2_, _c, _st, use_pallas=use_pallas)
                si_cell.update(si_)
                return y_

            hit = (jax.jit(tail_body), si_cell)
            _SHARDED_FN_CACHE[tkey] = hit
        tail_fn, tail_si = hit
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span(f"grad.chain:m{st.mode}",
                             {"mode": st.mode, "backend": st.backend,
                              "macs": st.macs})
        with sp:
            dx = tail_fn(g2)
        infos.append(dict(tail_si, kind="grad_chain"))
        gs = [g, g1, g2]

    # --- coefficient cotangents: one batched multi-output launch ---
    ckey = ("vjp_coeff_batch", plan.key, use_pallas, x.ndim)
    fn = _SHARDED_FN_CACHE.get(ckey)
    if fn is None:
        order = plan.order

        def coeff_body(y0, y1_, y2_, g0, g1_, g2_, _o=order):
            # pairing as in the staged walk: dC_{order[i]} couples the
            # stage-i input ys[i] with the matching cotangent gs[2-i]
            return tuple(lower_coeff_grad_batch(
                [y0, y1_, y2_], [g2_, g1_, g0], _o,
                use_pallas=use_pallas))

        fn = jax.jit(coeff_body)
        _SHARDED_FN_CACHE[ckey] = fn
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span("grad.coeff:batched", {"modes": plan.order})
    with sp:
        dcl = fn(ys[0], ys[1], ys[2], gs[0], gs[1], gs[2])
    infos.append({"kind": "coeff_grad", "backend": "fused",
                  "modes": plan.order})
    dcs = {mode: dcl[i] for i, mode in enumerate(plan.order)}
    return dx, dcs, infos


def _execute_vjp_staged(plan: GemtPlan, adj: GemtPlan, x, cs: dict,
                        cts: dict, g, use_pallas) -> tuple:
    """The legacy eight-launch staged backward walk (``fuse=False``, traced
    inputs, or a declined chain plan).  Returns ``(dx, dcs, stage_infos)``.
    """
    infos = []
    ys = [x]
    y = x
    for st in plan.stages[:-1]:
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span(f"grad.recompute:m{st.mode}",
                             {"mode": st.mode, "backend": st.backend,
                              "macs": st.macs})
        with sp:
            y, si = lower_stage(y, cs[st.mode], st, use_pallas=use_pallas)
        si["kind"] = "grad_recompute"
        infos.append(si)
        ys.append(y)

    gs = [g]
    gi = g
    for st in adj.stages:
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span(f"grad.x:m{st.mode}",
                             {"mode": st.mode, "backend": st.backend,
                              "macs": st.macs})
        with sp:
            gi, si = lower_stage(gi, cts[st.mode], st,
                                 use_pallas=use_pallas)
        si["kind"] = "grad_x"
        infos.append(si)
        gs.append(gi)
    dx = gs.pop()  # gs keeps [g, g1, g2]

    dcs = {}
    for i, mode in enumerate(plan.order):
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span(f"grad.coeff:m{mode}", {"mode": mode})
        with sp:
            dc, ci = lower_coeff_grad(ys[i], gs[2 - i], mode,
                                      use_pallas=use_pallas)
        infos.append(ci)
        dcs[mode] = dc
    return dx, dcs, infos


def _sharded_prefix_callable(plan: GemtPlan, mesh, use_pallas,
                             cs: dict[int, jnp.ndarray], batched: bool):
    """Jitted shard_map recomputing the first two forward stage boundaries.

    The backward pass needs the stage-input tensors ``y1, y2`` globally;
    each stage runs exactly as in the forward program (kernels on local
    shards, ``psum_scatter`` on sharded modes), and every boundary keeps
    the stationary spec — the per-mode axis assignment never changes, only
    N_s↔K_s extents do.
    """
    esop_plans = {}
    for st in plan.stages[:-1]:
        if st.backend == "esop":
            esop_plans[st.mode] = ops.esop_plan_cached(cs[st.mode], st.bk,
                                                       st.bn)
    spec = (P(plan.batch_axis, *plan.axes) if batched else P(*plan.axes))
    stage_infos: list[dict] = []

    def body(x_l, c1_l, c2_l, c3_l):
        del stage_infos[:]
        cs_l = {1: c1_l, 2: c2_l, 3: c3_l}
        y = x_l
        inter = []
        for st in plan.stages[:-1]:
            if st.axis is None:
                y, si = lower_stage(y, cs_l[st.mode], st,
                                    use_pallas=use_pallas,
                                    esop_plan=esop_plans.get(st.mode))
            else:
                y, si = lower_sharded_stage(y, cs_l[st.mode], st, mesh,
                                            use_pallas=use_pallas)
            stage_infos.append(si)
            inter.append(y)
        return tuple(inter)

    fn = shard_map(body, mesh=mesh, in_specs=(spec, P(), P(), P()),
                   out_specs=(spec, spec), check_vma=False)
    return jax.jit(fn), stage_infos


def _sharded_chain_callable(adj: GemtPlan, mesh, use_pallas,
                            cts: dict[int, jnp.ndarray], batched: bool):
    """Jitted shard_map running the full adjoint chain staged, returning
    ``(g1, g2, dx)``.

    The chain runs staged even when the adjoint plan could fuse (only
    possible in the all-modes-local corner): the intermediates *are* the
    coefficient cotangents' operands, and a sharded-mode stage's
    ``psum_scatter`` must fire between them — the X-cotangent's collective
    handling is exactly the forward schedule's, inherited through
    ``lower_sharded_stage``.
    """
    esop_plans = {}
    for st in adj.stages:
        if st.backend == "esop":
            esop_plans[st.mode] = ops.esop_plan_cached(cts[st.mode], st.bk,
                                                       st.bn)
    spec = (P(adj.batch_axis, *adj.axes) if batched else P(*adj.axes))
    stage_infos: list[dict] = []

    def body(g_l, c1t_l, c2t_l, c3t_l):
        del stage_infos[:]
        ct_l = {1: c1t_l, 2: c2t_l, 3: c3t_l}
        y = g_l
        inter = []
        for st in adj.stages:
            if st.axis is None:
                y, si = lower_stage(y, ct_l[st.mode], st,
                                    use_pallas=use_pallas,
                                    esop_plan=esop_plans.get(st.mode))
            else:
                y, si = lower_sharded_stage(y, ct_l[st.mode], st, mesh,
                                            use_pallas=use_pallas)
            si = dict(si)
            si["kind"] = "grad_x"
            stage_infos.append(si)
            inter.append(y)
        return tuple(inter)

    fn = shard_map(body, mesh=mesh, in_specs=(spec, P(), P(), P()),
                   out_specs=(spec, spec, spec), check_vma=False)
    return jax.jit(fn), stage_infos


def _plan_tiles(plan: GemtPlan) -> tuple:
    return tuple((s.bm, s.bn, s.bk) for s in plan.stages)


def _execute_vjp_sharded(plan: GemtPlan, adj: GemtPlan, mesh, x, cs: dict,
                         cts: dict, g, use_pallas) -> tuple:
    """Mesh backward pass: chain + recompute inside ``shard_map`` programs
    (cached like the forward program), coefficient cotangents on the
    resulting global arrays.  Returns ``(dx, dcs, stage_infos)``."""
    batched = x.ndim == 4
    pkey = ("vjp_prefix", plan.key, _plan_tiles(plan), use_pallas, x.ndim,
            _fingerprint(cs[1]), _fingerprint(cs[2]), _fingerprint(cs[3]))
    hit = _SHARDED_FN_CACHE.get(pkey)
    if hit is None:
        fn, infos = _sharded_prefix_callable(plan, mesh, use_pallas, cs,
                                             batched)
        hit = [fn, infos, None]
        _SHARDED_FN_CACHE[pkey] = hit
    y1, y2 = hit[0](x, cs[1], cs[2], cs[3])
    prefix_infos = [dict(si, kind="grad_recompute") for si in hit[1]]

    ckey = ("vjp_chain", adj.key, _plan_tiles(adj), use_pallas, g.ndim,
            _fingerprint(cts[1]), _fingerprint(cts[2]), _fingerprint(cts[3]))
    hit = _SHARDED_FN_CACHE.get(ckey)
    if hit is None:
        fn, infos = _sharded_chain_callable(adj, mesh, use_pallas, cts,
                                            batched)
        hit = [fn, infos, None]
        _SHARDED_FN_CACHE[ckey] = hit
    g1, g2, dx = hit[0](g, cts[1], cts[2], cts[3])
    infos = prefix_infos + [dict(si) for si in hit[1]]

    # Global-level rank-k updates: the chain/recompute arrays are global
    # (sharded) outputs, so the contraction over their rows is complete —
    # the cross-device sum GSPMD inserts here is the coefficient
    # cotangent's psum (coefficients are replicated, their cotangents must
    # be too).  Backend pinned to einsum: these operands live *outside*
    # shard_map, where only dot_general is partitionable — a pallas_call
    # on sharded global arrays has no SPMD rule.  All three run inside one
    # cached jitted program (one dispatch; GSPMD partitions each einsum).
    okey = ("vjp_coeff", plan.key, use_pallas, x.ndim)
    cfn = _SHARDED_FN_CACHE.get(okey)
    if cfn is None:
        order = plan.order

        def coeff_body(y0, y1_, y2_, g0, g1_, g2_, _o=order):
            ys_l = (y0, y1_, y2_)
            gs_l = (g0, g1_, g2_)
            return tuple(lower_coeff_grad(ys_l[i], gs_l[2 - i], mode,
                                          use_pallas=use_pallas,
                                          backend="einsum")[0]
                         for i, mode in enumerate(_o))

        cfn = jax.jit(coeff_body)
        _SHARDED_FN_CACHE[okey] = cfn
    dcl = cfn(x, y1, y2, g, g1, g2)
    infos.append({"kind": "coeff_grad", "backend": "einsum",
                  "modes": plan.order, "batched": True})
    dcs = {mode: dcl[i] for i, mode in enumerate(plan.order)}
    return dx, dcs, infos


def _count_grad_dispatch(infos: list[dict]) -> dict:
    counts = {"kernel_stages": 0, "einsum_stages": 0, "coeff_kernel": 0,
              "coeff_einsum": 0, "fused_launches": 0}
    for si in infos:
        kernel = si.get("backend") != "einsum"
        if si.get("kind") == "coeff_grad":
            counts["coeff_kernel" if kernel else "coeff_einsum"] += 1
            continue
        if si.get("backend") == "fused":
            counts["fused_launches"] += 1
        counts["kernel_stages" if kernel else "einsum_stages"] += 1
    return counts


def _vjp_backward(plan: GemtPlan, mesh, x, c1, c2, c3, g, *, use_pallas,
                  esop_threshold, block_sizes, fuse, vmem_budget,
                  autotune, autotune_cache):
    """The custom-VJP backward: re-enters the engine and returns the four
    cotangents ``(dx, dc1, dc2, dc3)``."""
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span("vjp.backward",
                         {"key": plan.key, "shape": tuple(g.shape),
                          "sharded": mesh is not None})
    with sp:
        cs = {1: c1, 2: c2, 3: c3}
        cts = {m: _transposed(cs[m]) for m in (1, 2, 3)}
        adj = _adjoint_plan(plan, g.shape, g.dtype, cts,
                            esop_threshold=esop_threshold,
                            block_sizes=block_sizes, fuse=fuse,
                            vmem_budget=vmem_budget, mesh=mesh)
        # The chain plan derives from the untuned adjoint (see _chain_plan)
        # so the forward-time grad_* prediction and the execution agree.
        chain = _chain_plan(plan, adj, g.shape, g.dtype, fuse, vmem_budget)
        if autotune and not _is_traced(c1, c2, c3):
            batch = ((int(g.shape[0]) if g.ndim == 4 else 1)
                     // max(adj.batch_shards, 1))
            adj = _tuned_plan(adj, cts, batch, autotune_cache, use_pallas,
                              vmem_budget, g.dtype)
        sharded = mesh is not None and (
            any(a is not None for a in plan.axes)
            or plan.batch_axis is not None)
        if sharded:
            dx, dcs, infos = _execute_vjp_sharded(plan, adj, mesh, x, cs,
                                                  cts, g, use_pallas)
        else:
            dx, dcs, infos = _execute_vjp(plan, adj, chain, x, cs, cts, g,
                                          use_pallas)
        _metrics.inc("grad.backward_calls")
        for k, v in _count_grad_dispatch(infos).items():
            _metrics.inc("grad." + k, v)
        return (_match_cotangent(dx, x),
                _match_cotangent(dcs[1], c1),
                _match_cotangent(dcs[2], c2),
                _match_cotangent(dcs[3], c3))


def _grad_info_fields(plan: GemtPlan, adj: GemtPlan,
                      chain: AdjointChainPlan, g_shape, g_dtype) -> dict:
    """Forward-time ``grad_*`` accounting: what the backward pass will run.

    Derived from the (cached) adjoint + chain plans, so ``info`` can prove
    — before any gradient is pulled — that the backward lowers through the
    engine (nonzero kernel counters, no silent einsum fallback on
    kernel-capable shapes).  The stage counters are computed by building
    the *predicted* ``stage_infos`` list and feeding it through the same
    :func:`_count_grad_dispatch` the backward uses — one eager backward
    call moves the ``grad.*`` counters by exactly these amounts.
    ``grad_stats()`` counts actual backward executions.  (A backward
    pulled under an outer jit takes the staged walk instead — see
    ``_execute_vjp``.)
    """
    from .lower import coeff_grad_backend

    batch = int(g_shape[0]) if len(g_shape) == 4 else 1
    dims = dict(zip((1, 2, 3), plan.in_shape))
    out_dims = dict(zip((1, 2, 3), plan.out_shape))
    sharded = (any(a is not None for a in plan.axes)
               or plan.batch_axis is not None)
    fused_walk = chain.depth >= 2 and not sharded

    coeff_backends = []
    coeff_macs = 0
    for mode in (1, 2, 3):
        # dC_s rows: every non-s forward output extent (the boundary pair
        # shares them) times the batch; extents (N_s, K_s).
        rows = batch
        for m in (1, 2, 3):
            if m != mode:
                rows *= out_dims[m] if plan.order.index(m) < plan.order.index(mode) else dims[m]
        # Sharded plans pin the coefficient cotangent to einsum (global
        # arrays outside shard_map — see _execute_vjp_sharded); the fused
        # walk batches all three into one multi-output launch.
        coeff_backends.append(
            "fused" if fused_walk else "einsum" if sharded else
            coeff_grad_backend(rows, dims[mode], out_dims[mode], g_dtype))
        coeff_macs += rows * dims[mode] * out_dims[mode]

    predicted = []  # mirrors the backward's stage_infos, entry for entry
    if fused_walk:
        if chain.rec_fused:
            predicted.append({"kind": "grad_recompute", "backend": "fused"})
        else:
            predicted += [{"kind": "grad_recompute", "backend": st.backend}
                          for st in plan.stages[:2]]
        predicted.append({"kind": "grad_x", "backend": "fused"})
        if chain.depth == 3:
            executed = (f"fused{chain.modes}",)
        else:
            executed = (f"fused{chain.modes[:2]}", adj.stages[2].backend)
            predicted.append({"kind": "grad_chain",
                              "backend": adj.stages[2].backend})
        predicted.append({"kind": "coeff_grad", "backend": "fused"})
    else:
        executed = adj.backends
        predicted += [{"kind": "grad_recompute", "backend": st.backend}
                      for st in plan.stages[:2]]
        predicted += [{"kind": "grad_x", "backend": st.backend}
                      for st in adj.stages]
        if sharded:
            predicted.append({"kind": "coeff_grad", "backend": "einsum"})
        else:
            predicted += [{"kind": "coeff_grad", "backend": b}
                          for b in coeff_backends]
    counts = _count_grad_dispatch(predicted)
    return {
        "grad_order": adj.order,
        "grad_backends": adj.backends,
        "grad_backends_executed": executed,
        "grad_coeff_backends": tuple(coeff_backends),
        "grad_kernel_stages": counts["kernel_stages"],
        "grad_einsum_stages": counts["einsum_stages"],
        "grad_coeff_kernel": counts["coeff_kernel"],
        "grad_coeff_einsum": counts["coeff_einsum"],
        "grad_fused_launches": counts["fused_launches"],
        "grad_launches": chain.launches if fused_walk else len(predicted),
        "grad_chain_depth": chain.depth if fused_walk else 0,
        "grad_rec_fused": fused_walk and chain.rec_fused,
        "grad_fused": fused_walk,
        "grad_events": list(chain.events),
        "grad_macs": adj.macs + coeff_macs,
        "grad_hbm_bytes_moved": (chain.hbm_bytes_fused if fused_walk
                                 else adj.hbm_bytes_staged),
        "grad_collective_bytes": adj.collective_bytes,
    }


def _execute_differentiable(plan: GemtPlan, mesh, x, c1, c2, c3, *,
                            use_pallas, grad_opts: dict):
    """Run ``plan`` under the engine's custom VJP.  Returns ``(y, info)``.

    The primal is the ordinary executor; the backward re-enters the engine
    (``_vjp_backward``): the X-cotangent as the derived adjoint plan over
    ``C_sᵀ`` (planned GEMT — staged/pair/triple fusion, ESOP, autotune all
    apply) and the coefficient cotangents as mode-unfolded rank-k SR-GEMM
    updates.  ``info`` gains the forward-time ``grad_*`` fields.
    """
    info_cell: dict = {}

    def prim(x, c1, c2, c3):
        if mesh is not None:
            y, info = execute_sharded_with_info(plan, mesh, x, c1, c2, c3,
                                                use_pallas=use_pallas)
        else:
            y, info = execute_with_info(plan, x, c1, c2, c3,
                                        use_pallas=use_pallas)
        info_cell.update(info)
        return y

    @jax.custom_vjp
    def f(x, c1, c2, c3):
        return prim(x, c1, c2, c3)

    def bwd(res, g):
        xr, c1r, c2r, c3r = res
        return _vjp_backward(plan, mesh, xr, c1r, c2r, c3r, g,
                             use_pallas=use_pallas, **grad_opts)

    f.defvjp(lambda x, c1, c2, c3: (prim(x, c1, c2, c3), (x, c1, c2, c3)),
             bwd)
    y = f(x, c1, c2, c3)
    info = dict(info_cell)
    # Forward-time grad accounting: derive the adjoint plan now (cached —
    # the backward reuses it) so info proves what the VJP will lower.
    g_shape = plan.out_shape if x.ndim == 3 else (x.shape[0],) + plan.out_shape
    g_dtype = jnp.result_type(x.dtype, c1.dtype)
    cts = {m: _transposed(c) for m, c in ((1, c1), (2, c2), (3, c3))}
    adj = _adjoint_plan(plan, g_shape, g_dtype, cts,
                        esop_threshold=grad_opts["esop_threshold"],
                        block_sizes=grad_opts["block_sizes"],
                        fuse=grad_opts["fuse"],
                        vmem_budget=grad_opts["vmem_budget"], mesh=mesh)
    chain = _chain_plan(plan, adj, g_shape, g_dtype, grad_opts["fuse"],
                        grad_opts["vmem_budget"])
    info.update(_grad_info_fields(plan, adj, chain, g_shape, g_dtype))
    return y, info


def gemt3_planned(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    out: jnp.ndarray | None = None,  # keyword-only: gemt3's 5th positional
    order: tuple[int, int, int] | None = None,  # is `order`, not `out`
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | str | None = None,  # see FUSE_MODES
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    backend: str | None = None,  # pin every stage ("einsum"); None = auto
    accum: str | None = None,  # "plain" | "f32" | "compensated"
    error_budget: float | None = None,  # max a-priori relative error bound
    autotune: bool = False,
    autotune_cache: AutotuneCache | str | None = None,
    use_pallas: bool | None = None,
    with_info: bool = False,
    differentiable: bool = False,
    mesh=None,
    axes=None,
    batch_axis=None,
    batch_bucket: int | None = None,
):
    """Planned three-mode GEMT ẍ = X ×₁C1 ×₂C2 ×₃C3 (+ out).

    Numerically equivalent to :func:`repro.core.gemt.gemt3` (any order gives
    the same result up to float rounding) but the stage order, per-stage
    dense/block-sparse backend, stage fusion and kernel tile sizes are
    chosen by the cost model instead of hard-coded.  ``fuse=None``
    auto-selects the deepest fusion that models the fewest HBM bytes —
    the whole-transform megakernel (all three contractions in one launch,
    both intermediates resident in VMEM) when its tiles fit
    ``vmem_budget``, degrading to the fused pair and then to staged;
    ``"pair"``/``"triple"`` pin the depth, ``True`` forces the deepest
    feasible, ``False`` stages everything.  ``backend="einsum"`` pins
    every stage to the XLA einsum lowering (fusion off, no Pallas) — the
    serving runtime's last-resort degradation tier (``docs/serving.md``);
    the pin applies to the forward plan (the adjoint keeps its own backend
    choice).  ``x`` may carry a leading batch axis.

    ``accum`` selects the guarded-numerics accumulation mode
    (``"plain"``/``"f32"``/``"compensated"`` — docs/numerics.md): ``"f32"``
    keeps float32 partials through every stage boundary, ``"compensated"``
    adds a Neumaier-compensated reduction in the kernels.  ``error_budget``
    holds the plan's a-priori staged rounding bound to a ceiling — the
    planner escalates the accumulation mode (and, through the VMEM
    footprint, may demote fusion depth) until the bound fits, recording
    ``numerics_degradation`` events; ``info["numerics"]`` reports the
    resolved mode and bound.

    ``mesh`` switches to the TriADA distributed schedule: ``x`` (global)
    is sharded per ``axes`` (default: mesh axes in order, e.g.
    ``("data", "model", None)`` on a 2-axis mesh; ``batch_axis``
    optionally shards a leading batch dim), coefficients are replicated,
    and the planned per-shard stages run inside one ``shard_map`` program
    — shard-local stages on the Pallas kernel dispatch, sharded-mode
    stages as local partial products combined by ``psum_scatter``.  The
    result matches the single-device path up to float reduction order.
    Traced coefficients (calling this under an outer ``jit``) degrade
    planning to dense sr_gemm/einsum backends and skip autotuning — zero
    structure is unreadable from a tracer.

    ``batch_bucket`` (single-device, 4-D inputs) plans and autotunes as if
    the leading batch axis had the bucket's size: stage schedules are
    batch-independent, so every batch size that maps to the same bucket
    reuses one plan-cache entry and one tuned variant, and only the byte
    model is re-evaluated for the actual batch.  This is the engine half
    of the serving layer's shape-bucketed warmup + request coalescing
    (``docs/serving.md``, "Throughput") — a warmed bucket's coalesced
    launches pay zero plan/probe work regardless of how many requests were
    stacked.

    ``differentiable=True`` wraps the execution in the engine's custom VJP
    (docs/engine.md, "Differentiation"): ``jax.grad``/``jax.vjp`` then
    lower the backward pass *through the engine* — the X-cotangent as the
    derived adjoint plan (another planned GEMT over the transposed
    coefficients, with the same fusion tiers / ESOP schedules / autotune
    caches) and the three coefficient cotangents as mode-unfolded rank-k
    SR-GEMM updates.  ``info`` gains ``grad_*`` fields describing the
    planned backward; ``grad_stats()`` counts executed backward passes.
    """
    if mesh is not None and axes is None:
        axes = default_mode_axes(mesh, batch_axis)
    # Batched-entry plan reuse: ``batch_bucket`` plans (and tunes) as if the
    # batch were the bucket size, so coalesced launches of varying batch
    # share one plan-cache entry — the serving layer's warmed buckets
    # (docs/serving.md, "Throughput").  Single-device only: under a mesh
    # the per-shard batch is part of the schedule.
    plan_shape = tuple(x.shape)
    if (batch_bucket is not None and mesh is None and x.ndim == 4
            and int(batch_bucket) != int(x.shape[0])):
        plan_shape = (int(batch_bucket),) + tuple(x.shape[1:])
    plan = plan_gemt3(plan_shape, x.dtype, c1, c2, c3, order=order,
                      esop_threshold=esop_threshold, block_sizes=block_sizes,
                      fuse=fuse, vmem_budget=vmem_budget, backend=backend,
                      accum=accum, error_budget=error_budget,
                      mesh=mesh, axes=axes, batch_axis=batch_axis)
    if autotune and not _is_traced(c1, c2, c3):
        # Per-shard batch: the tuned tiles must see the local GEMM rows
        # (the bucket batch when bucketed, so tuned variants are shared).
        batch = ((plan_shape[0] if len(plan_shape) == 4 else 1)
                 // max(plan.batch_shards, 1))
        plan = _tuned_plan(plan, {1: c1, 2: c2, 3: c3}, batch,
                           autotune_cache, use_pallas, vmem_budget, x.dtype)
    if plan_shape != tuple(x.shape):
        plan = _rebatched_plan(plan, int(x.shape[0]),
                               jnp.dtype(x.dtype).itemsize)
    if differentiable:
        y, info = _execute_differentiable(
            plan, mesh, x, c1, c2, c3, use_pallas=use_pallas,
            grad_opts=dict(esop_threshold=esop_threshold,
                           block_sizes=block_sizes, fuse=fuse,
                           vmem_budget=vmem_budget, autotune=autotune,
                           autotune_cache=autotune_cache))
        if out is not None:
            y = out + y  # differentiates natively: d(out) = g
        return (y, info) if with_info else y
    if mesh is not None:
        y, info = execute_sharded_with_info(plan, mesh, x, c1, c2, c3, out,
                                            use_pallas=use_pallas)
    else:
        y, info = execute_with_info(plan, x, c1, c2, c3, out,
                                    use_pallas=use_pallas)
    return (y, info) if with_info else y
