"""Plan caching + execution — the engine's public entry points.

``gemt3_planned`` is the drop-in, data-driven counterpart of
``core.gemt.gemt3``: it builds (or fetches from the in-process plan cache) a
:class:`~repro.engine.plan.GemtPlan`, optionally autotunes per-stage block
sizes against the persisted JSON cache, and executes the three lowered
stages through the Pallas kernel dispatch.  Batched inputs (a leading batch
axis) run each stage as a single fused GEMM.

With ``mesh=``/``axes=`` the same entry point runs the TriADA distributed
schedule (paper §4–§5): the planned per-shard stages execute inside a
``shard_map`` body — Pallas/interpret kernels on the local shards, one
``psum_scatter`` per sharded-mode stage — and ``info`` splits the byte
accounting into per-shard local HBM traffic and modeled collective ICI
bytes.  See ``docs/distributed.md``.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map
from ..kernels import ops
from ..memo import ArrayMemo
from .autotune import (AutotuneCache, autotune_fused, autotune_fused3,
                       autotune_gemm, make_key)
from .lower import (lower_fused_pair, lower_fused_triple,
                    lower_sharded_stage, lower_stage)
from .plan import (DEFAULT_ESOP_THRESHOLD, DEFAULT_VMEM_BUDGET, GemtPlan,
                   _is_traced, build_plan, normalize_axes, plan_hbm_bytes,
                   refresh_fused_pair, refresh_fused_triple)

__all__ = [
    "plan_gemt3",
    "execute",
    "execute_with_info",
    "execute_sharded_with_info",
    "gemt3_planned",
    "clear_plan_cache",
    "plan_cache_info",
]

_PLAN_CACHE: dict[tuple, GemtPlan] = {}
_TUNED_PLAN_CACHE: dict[tuple, GemtPlan] = {}  # post-autotune variants
_SHARDED_FN_CACHE: dict[tuple, tuple] = {}  # plan+cs -> (jitted shard_map, infos)
_FP_MEMO = ArrayMemo()  # per-array-identity digests: plan-cache hits stay cheap


def _fingerprint(c: jnp.ndarray) -> str:
    """Digest of a coefficient matrix's shape/dtype/zero structure.

    Memoized on array identity so a hot loop reusing the same coefficient
    arrays doesn't pay a device sync + full-matrix hash per call.  Tracers
    (an outer jit is planning through us) digest to a shape/dtype tag —
    consistent with the planner, whose traced plans are dense-only and
    depend on nothing else.
    """
    if isinstance(c, jax.core.Tracer):
        return f"traced:{tuple(c.shape)}:{jnp.dtype(c.dtype).name}"

    def compute():
        cn = np.asarray(c)
        h = hashlib.sha1(f"{cn.shape}|{cn.dtype}".encode())
        h.update(np.packbits(cn != 0).tobytes())
        return h.hexdigest()[:16]

    return _FP_MEMO.get_or_compute(c, "fp", compute)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _TUNED_PLAN_CACHE.clear()
    _SHARDED_FN_CACHE.clear()


def plan_cache_info() -> dict:
    return {"entries": len(_PLAN_CACHE), "tuned": len(_TUNED_PLAN_CACHE),
            "sharded_fns": len(_SHARDED_FN_CACHE)}


def default_mode_axes(mesh, batch_axis=None) -> tuple:
    """Default per-mode axis assignment: mesh axes in order, modes beyond
    the mesh rank unsharded — e.g. a ``("data", "model")`` mesh shards
    modes 1–2 and keeps mode 3 local (the paper's single-pod placement).
    Axes claimed by ``batch_axis`` are excluded (an axis can shard only
    one dim of the stationary tensor)."""
    taken = (set() if batch_axis is None else
             set(batch_axis if isinstance(batch_axis, tuple)
                 else (batch_axis,)))
    names = tuple(a for a in mesh.axis_names if a not in taken)
    return (names + (None, None, None))[:3]


def plan_gemt3(
    x_shape: tuple[int, ...],
    x_dtype,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    order: tuple[int, int, int] | None = None,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | str | None = None,  # see FUSE_MODES
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    mesh=None,
    axes=None,
    batch_axis=None,
) -> GemtPlan:
    """Build (or fetch) the plan for this problem; memoized in-process."""
    mesh_desc = (None if mesh is None else
                 (tuple(mesh.shape.items()), normalize_axes(axes),
                  batch_axis))
    key = (
        tuple(x_shape), jnp.dtype(x_dtype).name,
        tuple(order) if order is not None else None,
        esop_threshold, block_sizes, fuse, vmem_budget,
        _fingerprint(c1), _fingerprint(c2), _fingerprint(c3), mesh_desc,
    )
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = build_plan(x_shape, x_dtype, c1, c2, c3, order=order,
                          esop_threshold=esop_threshold,
                          block_sizes=block_sizes, fuse=fuse,
                          vmem_budget=vmem_budget, mesh=mesh, axes=axes,
                          batch_axis=batch_axis)
        _PLAN_CACHE[key] = plan
    return plan


def _autotuned_plan(
    plan: GemtPlan,
    cs: dict[int, jnp.ndarray],
    batch: int,
    cache: AutotuneCache,
    use_pallas: bool | None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    x_dtype=jnp.float32,
) -> GemtPlan:
    """Replace each kernel stage's (and the fused pair's/triple's) tiles
    with tuned ones."""
    fused_idx = (set() if plan.fused is None
                 else {plan.fused.first, plan.fused.first + 1})
    if plan.fused3 is not None:
        fused_idx = {0, 1, 2}  # the megakernel covers the whole schedule
    stages = []
    for i, st in enumerate(plan.stages):
        if st.backend == "einsum" or i in fused_idx:
            # fused stages never run their staged tiles — don't probe them
            stages.append(st)
            continue
        rows = st.rows * max(batch, 1)
        c = cs[st.mode]
        sig = _fingerprint(c)
        key = make_key(rows, st.k, st.n, c.dtype, st.backend, sig)
        hit = cache.get(key)
        knobs_live = use_pallas is True or ops.on_tpu()
        # Warm-cache fast path (no probe allocation) — unless the entry is
        # an untuned off-TPU default and the knobs are live here.
        if hit is not None and (hit.get("tuned", True) or not knobs_live):
            bm, bn, bk = int(hit["bm"]), int(hit["bn"]), int(hit["bk"])
        else:
            probe = jnp.ones((rows, st.n), dtype=c.dtype)
            # Sharded-mode stages contract an N_s/P row slice of C; probe
            # with a representative slice so shapes match the local GEMM.
            c_arg = c if int(c.shape[0]) == st.n else c[: st.n]
            bm, bn, bk = autotune_gemm(probe, c_arg, st.backend, sig=sig,
                                       cache=cache, use_pallas=use_pallas)
        stages.append(dataclasses.replace(st, bm=bm, bn=bn, bk=bk))

    fused = plan.fused
    fused3 = plan.fused3
    isz = jnp.dtype(x_dtype).itemsize
    if fused3 is not None:
        ca, cb, cc = cs[fused3.mode_a], cs[fused3.mode_b], cs[fused3.mode_c]
        bu, bka, bnb, bnc = autotune_fused3(
            ca, cb, cc, rows=fused3.rows * max(batch, 1), dtype=x_dtype,
            start=(fused3.bu, fused3.bka, fused3.bnb, fused3.bnc),
            bna=fused3.bna, kbp=fused3.kbp, kcp=fused3.kcp,
            sig=":".join(_fingerprint(c) for c in (ca, cb, cc)), cache=cache,
            use_pallas=use_pallas, vmem_budget=vmem_budget)
        if (bu, bka, bnb, bnc) != (fused3.bu, fused3.bka, fused3.bnb,
                                   fused3.bnc):
            fused3 = refresh_fused_triple(
                dataclasses.replace(fused3, bu=bu, bka=bka, bnb=bnb,
                                    bnc=bnc),
                ca, cb, cc, batch, isz)
    if fused is not None:
        ca, cb = cs[fused.mode_a], cs[fused.mode_b]
        bu, bka, bnb = autotune_fused(
            ca, cb, rows=fused.rows * max(batch, 1), dtype=x_dtype,
            start=(fused.bu, fused.bka, fused.bnb),
            bna=fused.bna, kbp=fused.kbp,
            sig=f"{_fingerprint(ca)}:{_fingerprint(cb)}", cache=cache,
            use_pallas=use_pallas, vmem_budget=vmem_budget)
        if (bu, bka, bnb) != (fused.bu, fused.bka, fused.bnb):
            fused = refresh_fused_pair(
                dataclasses.replace(fused, bu=bu, bka=bka, bnb=bnb),
                ca, cb, batch, isz)
    # Tuning moved tiles, so the byte model must be re-evaluated on what
    # will actually run — stale numbers describe a configuration that never
    # executes (the revisit factors depend on bm/bn and the fused tiles).
    # x's itemsize keeps the units identical to build_plan's model.
    stages_t = tuple(stages)
    return dataclasses.replace(
        plan, stages=stages_t, fused=fused, fused3=fused3,
        hbm_bytes_staged=plan_hbm_bytes(stages_t, None, batch, isz),
        hbm_bytes_moved=plan_hbm_bytes(stages_t, fused, batch, isz,
                                       fused3=fused3))


def execute_with_info(
    plan: GemtPlan,
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    out: jnp.ndarray | None = None,
    *,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Run a plan; returns ``(y, info)`` with per-stage dispatch accounting.

    When the plan carries a fused pair, those two stages run as one fused
    kernel launch (``info["fused"]`` reports its modes, VMEM footprint and
    the modeled pair-traffic saving); the surrounding stages run staged.
    ``info["hbm_bytes_moved"]`` / ``"hbm_bytes_staged"`` expose the modeled
    traffic of the executed vs. the all-staged schedule.
    """
    cs = {1: c1, 2: c2, 3: c3}
    y = x
    stage_infos = []
    i = 0
    while i < len(plan.stages):
        if plan.fused3 is not None and i == 0:
            ft = plan.fused3
            y, finfo = lower_fused_triple(y, cs[ft.mode_a], cs[ft.mode_b],
                                          cs[ft.mode_c], ft,
                                          use_pallas=use_pallas)
            stage_infos.append(finfo)
            i += 3
            continue
        if plan.fused is not None and i == plan.fused.first:
            fp = plan.fused
            y, finfo = lower_fused_pair(y, cs[fp.mode_a], cs[fp.mode_b], fp,
                                        use_pallas=use_pallas)
            stage_infos.append(finfo)
            i += 2
            continue
        st = plan.stages[i]
        y, sinfo = lower_stage(y, cs[st.mode], st, use_pallas=use_pallas)
        stage_infos.append(sinfo)
        i += 1
    if out is not None:
        y = out + y
    return y, _assemble_info(plan, stage_infos)


def _assemble_info(plan: GemtPlan, stage_infos: list[dict]) -> dict:
    """Shared info-dict builder for the local and sharded executors.

    Byte accounting is three-way: ``hbm_bytes_moved`` /
    ``hbm_bytes_staged`` are the modeled (per-shard, under a mesh) HBM
    traffic of the executed vs. all-staged schedule, ``hbm_bytes_local``
    aliases the executed number explicitly, and ``collective_bytes`` is
    the modeled per-device psum_scatter ICI traffic (0 on a single
    device).
    """
    fused_info = next((i for i in stage_infos if i.get("backend") == "fused"),
                      None)
    # Aggregate fetch savings over *staged* stages only: the fused pair's
    # counts live in a product space (C_a blocks × C_b slabs) whose units
    # don't sum with per-stage grids — its own savings are under
    # info["fused"]["fetch_savings"].
    staged_infos = [i for i in stage_infos if i.get("backend") != "fused"]
    dense = sum(i.get("blocks_dense", 0) for i in staged_infos)
    live = sum(i.get("blocks_live", 0) for i in staged_infos)
    return {
        "order": plan.order,
        "backends": plan.backends,  # the per-stage (staged-fallback) plan
        # what actually ran: the fused pair collapses to one entry
        "backends_executed": tuple(
            ("fused" + str(i["modes"]) if i.get("backend") == "fused"
             else i["backend"]) for i in stage_infos),
        "macs": plan.macs,
        "macs_effective": plan.macs_effective,
        "stages": stage_infos,
        "fused": fused_info,
        "axes": plan.axes,
        "shards": plan.shards,
        "batch_axis": plan.batch_axis,
        "hbm_bytes_staged": plan.hbm_bytes_staged,
        "hbm_bytes_moved": plan.hbm_bytes_moved,
        "hbm_bytes_local": plan.hbm_bytes_moved,
        "collective_bytes": plan.collective_bytes,
        "fetch_savings": ((1.0 - live / dense) if dense
                          else (fused_info or {}).get("fetch_savings", 0.0)),
    }


def _sharded_callable(plan: GemtPlan, mesh, use_pallas,
                      cs: dict[int, jnp.ndarray], batched: bool):
    """Build the jitted ``shard_map`` program executing ``plan`` on ``mesh``.

    ESOP / fused-pair prefetch schedules are precomputed host-side from the
    concrete coefficient matrices *before* entering the body — inside it
    the replicated operands are tracers (traced plans carry no such stages,
    so they precompute nothing).  Returns ``(fn, stage_infos)`` where
    ``stage_infos`` is populated at trace time (all entries are static
    host-side accounting, identical for every call of this program).
    """
    fp = plan.fused
    ft = plan.fused3
    fused_idx = set() if fp is None else {fp.first, fp.first + 1}
    if ft is not None:
        fused_idx = {0, 1, 2}
    esop_plans = {}
    for i, st in enumerate(plan.stages):
        if st.backend == "esop" and i not in fused_idx:
            esop_plans[st.mode] = ops.esop_plan_cached(cs[st.mode], st.bk,
                                                       st.bn)
    fused_plans = None
    if fp is not None:
        fused_plans = (ops.esop_plan_cached(cs[fp.mode_a], fp.bna, fp.bka),
                       ops.esop_plan_cached(cs[fp.mode_b], fp.bnb, fp.kbp))
    fused3_plans = None
    if ft is not None:
        fused3_plans = (ops.esop_plan_cached(cs[ft.mode_a], ft.bna, ft.bka),
                        ops.esop_plan_cached(cs[ft.mode_b], ft.bnb, ft.kbp),
                        ops.esop_plan_cached(cs[ft.mode_c], ft.bnc, ft.kcp))

    spec = (P(plan.batch_axis, *plan.axes) if batched else P(*plan.axes))
    stage_infos: list[dict] = []

    def body(x_l, c1_l, c2_l, c3_l):
        del stage_infos[:]  # body re-traces refill, they never duplicate
        cs_l = {1: c1_l, 2: c2_l, 3: c3_l}
        y = x_l
        i = 0
        while i < len(plan.stages):
            if ft is not None and i == 0:
                y, finfo = lower_fused_triple(y, cs_l[ft.mode_a],
                                              cs_l[ft.mode_b],
                                              cs_l[ft.mode_c], ft,
                                              use_pallas=use_pallas,
                                              plans=fused3_plans)
                stage_infos.append(finfo)
                i += 3
                continue
            if fp is not None and i == fp.first:
                y, finfo = lower_fused_pair(y, cs_l[fp.mode_a],
                                            cs_l[fp.mode_b], fp,
                                            use_pallas=use_pallas,
                                            plans=fused_plans)
                stage_infos.append(finfo)
                i += 2
                continue
            st = plan.stages[i]
            if st.axis is None:
                y, sinfo = lower_stage(y, cs_l[st.mode], st,
                                       use_pallas=use_pallas,
                                       esop_plan=esop_plans.get(st.mode))
            else:
                y, sinfo = lower_sharded_stage(y, cs_l[st.mode], st, mesh,
                                               use_pallas=use_pallas)
            stage_infos.append(sinfo)
            i += 1
        return y

    fn = shard_map(body, mesh=mesh, in_specs=(spec, P(), P(), P()),
                   out_specs=spec, check_vma=False)
    return jax.jit(fn), stage_infos


def execute_sharded_with_info(
    plan: GemtPlan,
    mesh,
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    out: jnp.ndarray | None = None,
    *,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Run a mesh plan through the TriADA ``shard_map`` schedule.

    The jitted program is cached per (plan, coefficient content,
    ``use_pallas``), so serving hot loops pay neither the shard_map
    retrace nor the ESOP schedule recompute.  ``info`` matches the
    single-device executor's, with ``collective_bytes`` > 0 for sharded
    stages and all HBM numbers per-shard.
    """
    if plan.axes == (None, None, None) and plan.batch_axis is None:
        # Nothing is sharded: the shard_map program would just replicate
        # the whole computation on every device — run the local executor.
        return execute_with_info(plan, x, c1, c2, c3, out,
                                 use_pallas=use_pallas)
    # The autotuner replaces tiles without touching plan.key, so the tile
    # state must be part of the program key — a tuned plan may not reuse
    # the untuned plan's compiled stages (and vice versa).
    tiles = tuple((s.bm, s.bn, s.bk) for s in plan.stages)
    ftiles = (None if plan.fused is None else
              (plan.fused.bu, plan.fused.bka, plan.fused.bnb))
    f3tiles = (None if plan.fused3 is None else
               (plan.fused3.bu, plan.fused3.bka, plan.fused3.bnb,
                plan.fused3.bnc))
    key = (plan.key, tiles, ftiles, f3tiles, use_pallas, x.ndim,
           _fingerprint(c1), _fingerprint(c2), _fingerprint(c3))
    hit = _SHARDED_FN_CACHE.get(key)
    if hit is None:
        fn, stage_infos = _sharded_callable(
            plan, mesh, use_pallas, {1: c1, 2: c2, 3: c3},
            batched=x.ndim == 4)
        hit = [fn, stage_infos, None]  # assembled info filled post-trace
        _SHARDED_FN_CACHE[key] = hit
    fn, stage_infos, info = hit
    y = fn(x, c1, c2, c3)
    if out is not None:
        y = out + y
    if info is None:
        # stage_infos is static trace-time accounting, identical for every
        # call of this program — assemble once, not per request (the
        # serving hot loop measured the per-call dict building).
        info = _assemble_info(plan, list(stage_infos))
        hit[2] = info
    return y, dict(info)


def execute(plan, x, c1, c2, c3, out=None, *, use_pallas=None):
    """Run a plan, result only."""
    y, _ = execute_with_info(plan, x, c1, c2, c3, out, use_pallas=use_pallas)
    return y


def gemt3_planned(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    out: jnp.ndarray | None = None,  # keyword-only: gemt3's 5th positional
    order: tuple[int, int, int] | None = None,  # is `order`, not `out`
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | str | None = None,  # see FUSE_MODES
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    autotune: bool = False,
    autotune_cache: AutotuneCache | str | None = None,
    use_pallas: bool | None = None,
    with_info: bool = False,
    mesh=None,
    axes=None,
    batch_axis=None,
):
    """Planned three-mode GEMT ẍ = X ×₁C1 ×₂C2 ×₃C3 (+ out).

    Numerically equivalent to :func:`repro.core.gemt.gemt3` (any order gives
    the same result up to float rounding) but the stage order, per-stage
    dense/block-sparse backend, stage fusion and kernel tile sizes are
    chosen by the cost model instead of hard-coded.  ``fuse=None``
    auto-selects the deepest fusion that models the fewest HBM bytes —
    the whole-transform megakernel (all three contractions in one launch,
    both intermediates resident in VMEM) when its tiles fit
    ``vmem_budget``, degrading to the fused pair and then to staged;
    ``"pair"``/``"triple"`` pin the depth, ``True`` forces the deepest
    feasible, ``False`` stages everything.  ``x`` may carry a leading
    batch axis.

    ``mesh`` switches to the TriADA distributed schedule: ``x`` (global)
    is sharded per ``axes`` (default: mesh axes in order, e.g.
    ``("data", "model", None)`` on a 2-axis mesh; ``batch_axis``
    optionally shards a leading batch dim), coefficients are replicated,
    and the planned per-shard stages run inside one ``shard_map`` program
    — shard-local stages on the Pallas kernel dispatch, sharded-mode
    stages as local partial products combined by ``psum_scatter``.  The
    result matches the single-device path up to float reduction order.
    Traced coefficients (calling this under an outer ``jit``) degrade
    planning to dense sr_gemm/einsum backends and skip autotuning — zero
    structure is unreadable from a tracer.
    """
    if mesh is not None and axes is None:
        axes = default_mode_axes(mesh, batch_axis)
    plan = plan_gemt3(x.shape, x.dtype, c1, c2, c3, order=order,
                      esop_threshold=esop_threshold, block_sizes=block_sizes,
                      fuse=fuse, vmem_budget=vmem_budget, mesh=mesh,
                      axes=axes, batch_axis=batch_axis)
    if autotune and not _is_traced(c1, c2, c3):
        cache = (autotune_cache if isinstance(autotune_cache, AutotuneCache)
                 else AutotuneCache(autotune_cache))
        # Per-shard batch: the tuned tiles must see the local GEMM rows.
        batch = ((int(x.shape[0]) if x.ndim == 4 else 1)
                 // max(plan.batch_shards, 1))
        # Memoize the tuned variant: a warm hot loop must not pay the
        # cache probes + fused-mask refresh (a device pad + host sync)
        # per call.  plan.key only digests the zero *structure*, so the
        # content fingerprints are added — different coefficient matrices
        # of identical sparsity must still tune under their own sigs.
        tkey = (plan.key, cache.path, batch, use_pallas,
                _fingerprint(c1), _fingerprint(c2), _fingerprint(c3))
        tuned = _TUNED_PLAN_CACHE.get(tkey)
        if tuned is None:
            tuned = _autotuned_plan(plan, {1: c1, 2: c2, 3: c3}, batch,
                                    cache, use_pallas,
                                    vmem_budget=vmem_budget, x_dtype=x.dtype)
            _TUNED_PLAN_CACHE[tkey] = tuned
        plan = tuned
    if mesh is not None:
        y, info = execute_sharded_with_info(plan, mesh, x, c1, c2, c3, out,
                                            use_pallas=use_pallas)
    else:
        y, info = execute_with_info(plan, x, c1, c2, c3, out,
                                    use_pallas=use_pallas)
    return (y, info) if with_info else y
