"""Plan caching + execution — the engine's public entry points.

``gemt3_planned`` is the drop-in, data-driven counterpart of
``core.gemt.gemt3``: it builds (or fetches from the in-process plan cache) a
:class:`~repro.engine.plan.GemtPlan`, optionally autotunes per-stage block
sizes against the persisted JSON cache, and executes the three lowered
stages through the Pallas kernel dispatch.  Batched inputs (a leading batch
axis) run each stage as a single fused GEMM.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..memo import ArrayMemo
from .autotune import (AutotuneCache, autotune_fused, autotune_gemm,
                       make_key)
from .lower import lower_fused_pair, lower_stage
from .plan import (DEFAULT_ESOP_THRESHOLD, DEFAULT_VMEM_BUDGET, GemtPlan,
                   build_plan, plan_hbm_bytes, refresh_fused_pair)

__all__ = [
    "plan_gemt3",
    "execute",
    "execute_with_info",
    "gemt3_planned",
    "clear_plan_cache",
    "plan_cache_info",
]

_PLAN_CACHE: dict[tuple, GemtPlan] = {}
_TUNED_PLAN_CACHE: dict[tuple, GemtPlan] = {}  # post-autotune variants
_FP_MEMO = ArrayMemo()  # per-array-identity digests: plan-cache hits stay cheap


def _fingerprint(c: jnp.ndarray) -> str:
    """Digest of a coefficient matrix's shape/dtype/zero structure.

    Memoized on array identity so a hot loop reusing the same coefficient
    arrays doesn't pay a device sync + full-matrix hash per call.
    """
    def compute():
        cn = np.asarray(c)
        h = hashlib.sha1(f"{cn.shape}|{cn.dtype}".encode())
        h.update(np.packbits(cn != 0).tobytes())
        return h.hexdigest()[:16]

    return _FP_MEMO.get_or_compute(c, "fp", compute)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()
    _TUNED_PLAN_CACHE.clear()


def plan_cache_info() -> dict:
    return {"entries": len(_PLAN_CACHE), "tuned": len(_TUNED_PLAN_CACHE)}


def plan_gemt3(
    x_shape: tuple[int, ...],
    x_dtype,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    order: tuple[int, int, int] | None = None,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> GemtPlan:
    """Build (or fetch) the plan for this problem; memoized in-process."""
    key = (
        tuple(x_shape), jnp.dtype(x_dtype).name,
        tuple(order) if order is not None else None,
        esop_threshold, block_sizes, fuse, vmem_budget,
        _fingerprint(c1), _fingerprint(c2), _fingerprint(c3),
    )
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = build_plan(x_shape, x_dtype, c1, c2, c3, order=order,
                          esop_threshold=esop_threshold,
                          block_sizes=block_sizes, fuse=fuse,
                          vmem_budget=vmem_budget)
        _PLAN_CACHE[key] = plan
    return plan


def _autotuned_plan(
    plan: GemtPlan,
    cs: dict[int, jnp.ndarray],
    batch: int,
    cache: AutotuneCache,
    use_pallas: bool | None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    x_dtype=jnp.float32,
) -> GemtPlan:
    """Replace each kernel stage's (and the fused pair's) tiles with tuned ones."""
    fused_idx = (set() if plan.fused is None
                 else {plan.fused.first, plan.fused.first + 1})
    stages = []
    for i, st in enumerate(plan.stages):
        if st.backend == "einsum" or i in fused_idx:
            # fused stages never run their staged tiles — don't probe them
            stages.append(st)
            continue
        rows = st.rows * max(batch, 1)
        c = cs[st.mode]
        sig = _fingerprint(c)
        key = make_key(rows, st.k, st.n, c.dtype, st.backend, sig)
        hit = cache.get(key)
        knobs_live = use_pallas is True or ops.on_tpu()
        # Warm-cache fast path (no probe allocation) — unless the entry is
        # an untuned off-TPU default and the knobs are live here.
        if hit is not None and (hit.get("tuned", True) or not knobs_live):
            bm, bn, bk = int(hit["bm"]), int(hit["bn"]), int(hit["bk"])
        else:
            probe = jnp.ones((rows, st.n), dtype=c.dtype)
            bm, bn, bk = autotune_gemm(probe, c, st.backend, sig=sig,
                                       cache=cache, use_pallas=use_pallas)
        stages.append(dataclasses.replace(st, bm=bm, bn=bn, bk=bk))

    fused = plan.fused
    isz = jnp.dtype(x_dtype).itemsize
    if fused is not None:
        ca, cb = cs[fused.mode_a], cs[fused.mode_b]
        bu, bka, bnb = autotune_fused(
            ca, cb, rows=fused.rows * max(batch, 1), dtype=x_dtype,
            start=(fused.bu, fused.bka, fused.bnb),
            bna=fused.bna, kbp=fused.kbp,
            sig=f"{_fingerprint(ca)}:{_fingerprint(cb)}", cache=cache,
            use_pallas=use_pallas, vmem_budget=vmem_budget)
        if (bu, bka, bnb) != (fused.bu, fused.bka, fused.bnb):
            fused = refresh_fused_pair(
                dataclasses.replace(fused, bu=bu, bka=bka, bnb=bnb),
                ca, cb, batch, isz)
    # Tuning moved tiles, so the byte model must be re-evaluated on what
    # will actually run — stale numbers describe a configuration that never
    # executes (the revisit factors depend on bm/bn and the fused tiles).
    # x's itemsize keeps the units identical to build_plan's model.
    stages_t = tuple(stages)
    return dataclasses.replace(
        plan, stages=stages_t, fused=fused,
        hbm_bytes_staged=plan_hbm_bytes(stages_t, None, batch, isz),
        hbm_bytes_moved=plan_hbm_bytes(stages_t, fused, batch, isz))


def execute_with_info(
    plan: GemtPlan,
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    out: jnp.ndarray | None = None,
    *,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Run a plan; returns ``(y, info)`` with per-stage dispatch accounting.

    When the plan carries a fused pair, those two stages run as one fused
    kernel launch (``info["fused"]`` reports its modes, VMEM footprint and
    the modeled pair-traffic saving); the surrounding stages run staged.
    ``info["hbm_bytes_moved"]`` / ``"hbm_bytes_staged"`` expose the modeled
    traffic of the executed vs. the all-staged schedule.
    """
    cs = {1: c1, 2: c2, 3: c3}
    y = x
    stage_infos = []
    fused_info = None
    i = 0
    while i < len(plan.stages):
        if plan.fused is not None and i == plan.fused.first:
            fp = plan.fused
            y, finfo = lower_fused_pair(y, cs[fp.mode_a], cs[fp.mode_b], fp,
                                        use_pallas=use_pallas)
            stage_infos.append(finfo)
            fused_info = finfo
            i += 2
            continue
        st = plan.stages[i]
        y, sinfo = lower_stage(y, cs[st.mode], st, use_pallas=use_pallas)
        stage_infos.append(sinfo)
        i += 1
    if out is not None:
        y = out + y
    # Aggregate fetch savings over *staged* stages only: the fused pair's
    # counts live in a product space (C_a blocks × C_b slabs) whose units
    # don't sum with per-stage grids — its own savings are under
    # info["fused"]["fetch_savings"].
    staged_infos = [i for i in stage_infos if i.get("backend") != "fused"]
    dense = sum(i.get("blocks_dense", 0) for i in staged_infos)
    live = sum(i.get("blocks_live", 0) for i in staged_infos)
    info = {
        "order": plan.order,
        "backends": plan.backends,  # the per-stage (staged-fallback) plan
        # what actually ran: the fused pair collapses to one entry
        "backends_executed": tuple(
            ("fused" + str(i["modes"]) if i.get("backend") == "fused"
             else i["backend"]) for i in stage_infos),
        "macs": plan.macs,
        "macs_effective": plan.macs_effective,
        "stages": stage_infos,
        "fused": fused_info,
        "hbm_bytes_staged": plan.hbm_bytes_staged,
        "hbm_bytes_moved": plan.hbm_bytes_moved,
        "fetch_savings": ((1.0 - live / dense) if dense
                          else (fused_info or {}).get("fetch_savings", 0.0)),
    }
    return y, info


def execute(plan, x, c1, c2, c3, out=None, *, use_pallas=None):
    """Run a plan, result only."""
    y, _ = execute_with_info(plan, x, c1, c2, c3, out, use_pallas=use_pallas)
    return y


def gemt3_planned(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    out: jnp.ndarray | None = None,  # keyword-only: gemt3's 5th positional
    order: tuple[int, int, int] | None = None,  # is `order`, not `out`
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    autotune: bool = False,
    autotune_cache: AutotuneCache | str | None = None,
    use_pallas: bool | None = None,
    with_info: bool = False,
):
    """Planned three-mode GEMT ẍ = X ×₁C1 ×₂C2 ×₃C3 (+ out).

    Numerically equivalent to :func:`repro.core.gemt.gemt3` (any order gives
    the same result up to float rounding) but the stage order, per-stage
    dense/block-sparse backend, stage fusion (``fuse=None`` auto-fuses the
    pair with the largest modeled HBM saving whose tiles fit
    ``vmem_budget``) and kernel tile sizes are chosen by the cost model
    instead of hard-coded.  ``x`` may carry a leading batch axis.
    """
    plan = plan_gemt3(x.shape, x.dtype, c1, c2, c3, order=order,
                      esop_threshold=esop_threshold, block_sizes=block_sizes,
                      fuse=fuse, vmem_budget=vmem_budget)
    if autotune:
        cache = (autotune_cache if isinstance(autotune_cache, AutotuneCache)
                 else AutotuneCache(autotune_cache))
        batch = int(x.shape[0]) if x.ndim == 4 else 1
        # Memoize the tuned variant: a warm hot loop must not pay the
        # cache probes + fused-mask refresh (a device pad + host sync)
        # per call.  plan.key only digests the zero *structure*, so the
        # content fingerprints are added — different coefficient matrices
        # of identical sparsity must still tune under their own sigs.
        tkey = (plan.key, cache.path, batch, use_pallas,
                _fingerprint(c1), _fingerprint(c2), _fingerprint(c3))
        tuned = _TUNED_PLAN_CACHE.get(tkey)
        if tuned is None:
            tuned = _autotuned_plan(plan, {1: c1, 2: c2, 3: c3}, batch,
                                    cache, use_pallas,
                                    vmem_budget=vmem_budget, x_dtype=x.dtype)
            _TUNED_PLAN_CACHE[tkey] = tuned
        plan = tuned
    y, info = execute_with_info(plan, x, c1, c2, c3, out,
                                use_pallas=use_pallas)
    return (y, info) if with_info else y
