"""Plan caching + execution — the engine's public entry points.

``gemt3_planned`` is the drop-in, data-driven counterpart of
``core.gemt.gemt3``: it builds (or fetches from the in-process plan cache) a
:class:`~repro.engine.plan.GemtPlan`, optionally autotunes per-stage block
sizes against the persisted JSON cache, and executes the three lowered
stages through the Pallas kernel dispatch.  Batched inputs (a leading batch
axis) run each stage as a single fused GEMM.
"""
from __future__ import annotations

import dataclasses
import hashlib

import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from ..memo import ArrayMemo
from .autotune import AutotuneCache, autotune_gemm, make_key
from .lower import lower_stage
from .plan import DEFAULT_ESOP_THRESHOLD, GemtPlan, build_plan

__all__ = [
    "plan_gemt3",
    "execute",
    "execute_with_info",
    "gemt3_planned",
    "clear_plan_cache",
    "plan_cache_info",
]

_PLAN_CACHE: dict[tuple, GemtPlan] = {}
_FP_MEMO = ArrayMemo()  # per-array-identity digests: plan-cache hits stay cheap


def _fingerprint(c: jnp.ndarray) -> str:
    """Digest of a coefficient matrix's shape/dtype/zero structure.

    Memoized on array identity so a hot loop reusing the same coefficient
    arrays doesn't pay a device sync + full-matrix hash per call.
    """
    def compute():
        cn = np.asarray(c)
        h = hashlib.sha1(f"{cn.shape}|{cn.dtype}".encode())
        h.update(np.packbits(cn != 0).tobytes())
        return h.hexdigest()[:16]

    return _FP_MEMO.get_or_compute(c, "fp", compute)


def clear_plan_cache() -> None:
    _PLAN_CACHE.clear()


def plan_cache_info() -> dict:
    return {"entries": len(_PLAN_CACHE)}


def plan_gemt3(
    x_shape: tuple[int, ...],
    x_dtype,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    order: tuple[int, int, int] | None = None,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
) -> GemtPlan:
    """Build (or fetch) the plan for this problem; memoized in-process."""
    key = (
        tuple(x_shape), jnp.dtype(x_dtype).name,
        tuple(order) if order is not None else None,
        esop_threshold, block_sizes,
        _fingerprint(c1), _fingerprint(c2), _fingerprint(c3),
    )
    plan = _PLAN_CACHE.get(key)
    if plan is None:
        plan = build_plan(x_shape, x_dtype, c1, c2, c3, order=order,
                          esop_threshold=esop_threshold,
                          block_sizes=block_sizes)
        _PLAN_CACHE[key] = plan
    return plan


def _autotuned_plan(
    plan: GemtPlan,
    cs: dict[int, jnp.ndarray],
    batch: int,
    cache: AutotuneCache,
    use_pallas: bool | None,
) -> GemtPlan:
    """Replace each kernel stage's block sizes with tuned ones."""
    stages = []
    for st in plan.stages:
        if st.backend == "einsum":
            stages.append(st)
            continue
        rows = st.rows * max(batch, 1)
        c = cs[st.mode]
        sig = _fingerprint(c)
        key = make_key(rows, st.k, st.n, c.dtype, st.backend, sig)
        hit = cache.get(key)
        knobs_live = use_pallas is True or ops.on_tpu()
        # Warm-cache fast path (no probe allocation) — unless the entry is
        # an untuned off-TPU default and the knobs are live here.
        if hit is not None and (hit.get("tuned", True) or not knobs_live):
            bm, bn, bk = int(hit["bm"]), int(hit["bn"]), int(hit["bk"])
        else:
            probe = jnp.ones((rows, st.n), dtype=c.dtype)
            bm, bn, bk = autotune_gemm(probe, c, st.backend, sig=sig,
                                       cache=cache, use_pallas=use_pallas)
        stages.append(dataclasses.replace(st, bm=bm, bn=bn, bk=bk))
    return dataclasses.replace(plan, stages=tuple(stages))


def execute_with_info(
    plan: GemtPlan,
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    out: jnp.ndarray | None = None,
    *,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Run a plan; returns ``(y, info)`` with per-stage dispatch accounting."""
    cs = {1: c1, 2: c2, 3: c3}
    y = x
    stage_infos = []
    for st in plan.stages:
        y, info = lower_stage(y, cs[st.mode], st, use_pallas=use_pallas)
        stage_infos.append(info)
    if out is not None:
        y = out + y
    dense = sum(i.get("blocks_dense", 0) for i in stage_infos)
    live = sum(i.get("blocks_live", 0) for i in stage_infos)
    info = {
        "order": plan.order,
        "backends": plan.backends,
        "macs": plan.macs,
        "macs_effective": plan.macs_effective,
        "stages": stage_infos,
        "fetch_savings": (1.0 - live / dense) if dense else 0.0,
    }
    return y, info


def execute(plan, x, c1, c2, c3, out=None, *, use_pallas=None):
    """Run a plan, result only."""
    y, _ = execute_with_info(plan, x, c1, c2, c3, out, use_pallas=use_pallas)
    return y


def gemt3_planned(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    out: jnp.ndarray | None = None,  # keyword-only: gemt3's 5th positional
    order: tuple[int, int, int] | None = None,  # is `order`, not `out`
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    autotune: bool = False,
    autotune_cache: AutotuneCache | str | None = None,
    use_pallas: bool | None = None,
    with_info: bool = False,
):
    """Planned three-mode GEMT ẍ = X ×₁C1 ×₂C2 ×₃C3 (+ out).

    Numerically equivalent to :func:`repro.core.gemt.gemt3` (any order gives
    the same result up to float rounding) but the stage order, per-stage
    dense/block-sparse backend and kernel tile sizes are chosen by the cost
    model instead of hard-coded.  ``x`` may carry a leading batch axis.
    """
    plan = plan_gemt3(x.shape, x.dtype, c1, c2, c3, order=order,
                      esop_threshold=esop_threshold, block_sizes=block_sizes)
    if autotune:
        cache = (autotune_cache if isinstance(autotune_cache, AutotuneCache)
                 else AutotuneCache(autotune_cache))
        batch = int(x.shape[0]) if x.ndim == 4 else 1
        plan = _autotuned_plan(plan, {1: c1, 2: c2, 3: c3}, batch, cache,
                               use_pallas)
    y, info = execute_with_info(plan, x, c1, c2, c3, out,
                                use_pallas=use_pallas)
    return (y, info) if with_info else y
