"""Lowering: mode-s contractions as 2D GEMMs on the repo's Pallas kernels.

A mode-s contraction of a (optionally batched) 3-mode tensor is exactly the
unfolded GEMM ``(B·A·B', N_s) @ (N_s, K_s)`` (Kolda–Bader mode-unfolding
with the contracted mode innermost).  ``lower_stage`` performs one planned
stage: unfold → dispatch to ``kernels.ops.sr_gemm`` / ``esop_gemm`` / an
einsum fallback → fold.  Batched execution folds the leading batch axis
into the GEMM rows, so a whole service batch is one kernel launch per
stage.

``lower_sharded_stage`` is the distributed counterpart, meant to run
*inside* a ``shard_map`` body (paper §4–§5, ``docs/distributed.md``): it
slices this device's coefficient rows by mesh position, runs the same
unfold→kernel→fold local GEMM as a partial rank-k update of the full
output extent, and combines shards with one ``psum_scatter`` — the TriADA
schedule with the planned Pallas kernels doing the local work.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import gemt as _gemt
from ..kernels import ops
from ..obs import trace as _trace
from .plan import FusedPairPlan, FusedTriplePlan, StagePlan

__all__ = ["mode_unfold", "mode_fold", "lower_stage", "lower_fused_pair",
           "lower_fused_triple", "lower_chain_pair", "lower_chain_triple",
           "lower_sharded_stage", "lower_coeff_grad",
           "lower_coeff_grad_batch", "coeff_grad_backend"]

# The einsum backend contracts in place (XLA folds the relayout into one
# dot_general) instead of the unfold→matmul→fold chain, whose
# reshape-of-transpose materializes two copies — measurably slower exactly
# where the planner picks einsum, i.e. stages too small to amortize a
# kernel launch.  Specs are mode_product's table plus a leading batch axis.
_EINSUM3 = _gemt._EINSUM


def _batched_spec(spec: str) -> str:
    lhs, rest = spec.split(",")
    c, out = rest.split("->")
    return f"z{lhs},{c}->z{out}"


_EINSUM4 = {m: _batched_spec(s) for m, s in _EINSUM3.items()}


def _einsum_stage(x: jnp.ndarray, c: jnp.ndarray, mode: int,
                  accum: str = "plain") -> jnp.ndarray:
    spec = (_EINSUM4 if x.ndim == 4 else _EINSUM3)[mode]
    if accum != "plain" and not jnp.iscomplexobj(x):
        # Promoted accumulation on the einsum fallback: contract in f32 and
        # keep the f32 result (no Neumaier variant here — einsum stages are
        # the planner's tiny/complex fallback; see docs/numerics.md).
        return jnp.einsum(spec, x.astype(jnp.float32),
                          c.astype(jnp.float32))
    return jnp.einsum(spec, x, c)


def mode_unfold(x: jnp.ndarray, mode: int) -> tuple[jnp.ndarray, tuple[int, ...]]:
    """Unfold tensor ``x`` for a mode-``mode`` contraction.

    The last three axes are the tensor modes (a leading batch axis, if any,
    is folded into the rows).  Returns ``(matrix (rows, N_s), lead_shape)``
    where ``lead_shape`` re-folds the rows.
    """
    if x.ndim not in (3, 4):
        raise ValueError(f"x must be 3D or 4D-batched, got ndim={x.ndim}")
    ax = x.ndim - 3 + (mode - 1)
    xm = jnp.moveaxis(x, ax, -1)
    return xm.reshape(-1, xm.shape[-1]), xm.shape[:-1]


def mode_fold(y2d: jnp.ndarray, lead_shape: tuple[int, ...], mode: int) -> jnp.ndarray:
    """Inverse of :func:`mode_unfold` with the new extent K_s in place."""
    ndim = len(lead_shape) + 1
    ax = ndim - 3 + (mode - 1)
    y = y2d.reshape(*lead_shape, y2d.shape[-1])
    return jnp.moveaxis(y, -1, ax)


def lower_stage(
    x: jnp.ndarray,
    c: jnp.ndarray,
    stage: StagePlan,
    *,
    use_pallas: bool | None = None,
    esop_plan: tuple | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Execute one planned contraction stage.  Returns ``(y, info)``.

    ``info`` carries the backend actually used plus the block-ESOP fetch
    accounting when that path engages (backend-independent: the reference
    path reports the same savings the TPU kernel realizes).  ``esop_plan``
    optionally supplies the precomputed ``esop_plan_cached`` tuple — the
    distributed executor computes it host-side before entering the
    ``shard_map`` body, where ``c`` is a tracer.
    """
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span(f"stage:m{stage.mode}:{stage.backend}",
                         {"mode": stage.mode, "backend": stage.backend,
                          "macs": stage.macs, "shape": tuple(x.shape)})
    with sp:
        if stage.backend == "einsum":
            rows = x.size // max(x.shape[x.ndim - 3 + stage.mode - 1], 1)
            info = {"mode": stage.mode, "backend": "einsum",
                    "rows": int(rows), "macs": stage.macs}
            return _einsum_stage(x, c, stage.mode, stage.accum), info
        x2d, lead = mode_unfold(x, stage.mode)
        info: dict = {"mode": stage.mode, "backend": stage.backend,
                      "rows": int(x2d.shape[0]), "macs": stage.macs}
        if stage.backend == "esop":
            y2d, esop_info = ops.esop_gemm(x2d, c, bm=stage.bm, bn=stage.bn,
                                           bk=stage.bk, use_pallas=use_pallas,
                                           plan=esop_plan,
                                           accum=stage.accum)
            info.update(esop_info)
        elif stage.backend == "sr_gemm":
            y2d = ops.sr_gemm(x2d, c, bm=stage.bm, bn=stage.bn, bk=stage.bk,
                              use_pallas=use_pallas, accum=stage.accum)
        else:
            raise ValueError(f"unknown backend {stage.backend!r}")
        return mode_fold(y2d, lead, stage.mode), info


def lower_sharded_stage(
    x: jnp.ndarray,
    c: jnp.ndarray,
    stage: StagePlan,
    mesh,
    *,
    use_pallas: bool | None = None,
) -> tuple[jnp.ndarray, dict]:
    """One sharded-mode stage inside a ``shard_map`` body: local partial
    rank-k update + one ``psum_scatter`` over the stage's mesh axis.

    ``x`` is the local shard; ``c`` the (replicated) full coefficient
    matrix.  This device contracts its rows ``[idx·n, (idx+1)·n)`` of
    ``c`` — the outer-product schedule restricted to the local coefficient
    rows, producing the full ``K_s`` extent as a partial sum — then the
    tiled ``psum_scatter`` reduces across the axis and lands each device's
    ``K_s / shards`` chunk in place.  The tensor never moves; only partial
    sums do (paper §5's stationary-tensor invariant).
    """
    sp = _trace.NULL_SPAN
    if _trace.enabled():  # trace-time inside shard_map: structure is exact
        sp = _trace.span(f"stage:m{stage.mode}:{stage.backend}:sharded",
                         {"mode": stage.mode, "backend": stage.backend,
                          "macs": stage.macs, "axis": str(stage.axis),
                          "shards": stage.shards,
                          "collective_bytes": stage.collective_bytes})
    with sp:
        names = stage.axis if isinstance(stage.axis, tuple) else (stage.axis,)
        idx = jnp.zeros((), jnp.int32)
        for name in names:  # row-major linear index over the (tuple) axis
            idx = idx * mesh.shape[name] + jax.lax.axis_index(name)
        c_rows = jax.lax.dynamic_slice_in_dim(c, idx * stage.n, stage.n, 0)

        rows = x.size // max(x.shape[x.ndim - 3 + stage.mode - 1], 1)
        info: dict = {"mode": stage.mode, "backend": stage.backend,
                      "rows": int(rows), "macs": stage.macs,
                      "axis": stage.axis, "shards": stage.shards,
                      "collective_bytes": stage.collective_bytes}
        if stage.backend == "einsum":
            partial = _einsum_stage(x, c_rows, stage.mode, stage.accum)
        elif stage.backend == "sr_gemm":
            x2d, lead = mode_unfold(x, stage.mode)
            y2d = ops.sr_gemm(x2d, c_rows, bm=stage.bm, bn=stage.bn,
                              bk=stage.bk, use_pallas=use_pallas,
                              accum=stage.accum)
            partial = mode_fold(y2d, lead, stage.mode)
        else:
            # The planner never assigns esop here: the row slice is selected
            # by axis_index at run time, so its zero structure is
            # device-dependent and the host-side block schedule cannot exist.
            raise ValueError(
                f"backend {stage.backend!r} cannot run a sharded-mode stage")
        # partial holds the full K_s extent as a partial sum
        ax = partial.ndim - 3 + (stage.mode - 1)
        moved = jnp.moveaxis(partial, ax, 0)
        csp = _trace.NULL_SPAN
        if _trace.enabled():
            csp = _trace.span("collective:psum_scatter",
                              {"mode": stage.mode, "axis": str(stage.axis),
                               "collective_bytes": stage.collective_bytes})
        with csp:
            combined = jax.lax.psum_scatter(moved, names,
                                            scatter_dimension=0, tiled=True)
        return jnp.moveaxis(combined, 0, ax), info


def coeff_grad_backend(rows_total: int, n: int, k: int, dtype) -> str:
    """Backend for a coefficient-cotangent GEMM ``(N_s, rows) @ (rows, K_s)``.

    The cotangent of a coefficient matrix is a mode-unfolded rank-``rows``
    product — dense regardless of C's zero structure (the linearization in
    C does not inherit its sparsity), so the menu is SR-GEMM vs the einsum
    fallback, by the same complex-dtype and minimum-extent rules as
    forward stages.
    """
    from .plan import MIN_KERNEL_DIM

    if jnp.issubdtype(jnp.dtype(dtype), jnp.complexfloating):
        return "einsum"
    if min(rows_total, n, k) < MIN_KERNEL_DIM:
        return "einsum"
    return "sr_gemm"


def lower_coeff_grad(
    a: jnp.ndarray,
    g: jnp.ndarray,
    mode: int,
    *,
    use_pallas: bool | None = None,
    backend: str | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Coefficient cotangent ``dC_s = unfold_s(A)ᵀ @ unfold_s(G)``.

    ``a`` is the forward stage's *input* tensor (mode ``s`` still at extent
    N_s) and ``g`` the cotangent of that stage's *output* (mode ``s`` at
    K_s); every other axis — including a leading batch — is identical on
    both sides and folds into the contraction rows, so the whole update is
    one SR-GEMM rank-``rows`` product.  Returns ``(dC, info)`` with the
    ``kind="coeff_grad"`` dispatch accounting the VJP executor aggregates
    into the ``grad_*`` counters.

    ``backend`` overrides :func:`coeff_grad_backend` — the sharded
    executor pins ``"einsum"`` because its operands are *global* sharded
    arrays outside any ``shard_map``: only a plain ``dot_general`` gives
    GSPMD something it can partition (and psum across shards); a
    ``pallas_call`` on multi-device operands has no SPMD rule.
    """
    from .plan import _pow2_clamp

    a2d, _ = mode_unfold(a, mode)
    g2d, _ = mode_unfold(g, mode)
    rows, n = a2d.shape
    k = g2d.shape[1]
    if backend is None:
        backend = coeff_grad_backend(rows, n, k,
                                     jnp.result_type(a2d.dtype, g2d.dtype))
    info = {"mode": mode, "backend": backend, "kind": "coeff_grad",
            "rows": int(rows), "macs": int(rows) * int(n) * int(k)}
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span(f"coeff_grad:m{mode}:{backend}",
                         {"mode": mode, "backend": backend,
                          "rows": int(rows), "macs": info["macs"]})
    with sp:
        if backend == "einsum":
            dc = jnp.swapaxes(a2d, 0, 1) @ g2d
        else:
            dc = ops.sr_gemm(jnp.swapaxes(a2d, 0, 1), g2d,
                             bm=_pow2_clamp(n), bn=_pow2_clamp(k),
                             bk=_pow2_clamp(rows), use_pallas=use_pallas)
    return dc, info


def lower_fused_pair(
    x: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    fp: FusedPairPlan,
    *,
    use_pallas: bool | None = None,
    plans: tuple | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Execute a fused consecutive stage pair.  Returns ``(y, info)``.

    Unfolds ``x`` into the u-major ``(U, Nb, Na)`` layout the fused kernel
    streams (batch and the untouched mode fold into U), runs both
    contractions in one launch — the stage-a partial never leaves VMEM, so
    there is no intermediate fold/unfold transpose between them — and
    folds ``(U, Ka, Kb)`` back into tensor modes.  ``plans`` optionally
    carries the two precomputed ``esop_plan_cached`` tuples (a/b), for
    callers whose ``ca``/``cb`` are tracers inside a ``shard_map`` body.
    """
    if x.ndim not in (3, 4):
        raise ValueError(f"x must be 3D or 4D-batched, got ndim={x.ndim}")
    axa = x.ndim - 3 + (fp.mode_a - 1)
    axb = x.ndim - 3 + (fp.mode_b - 1)
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span(f"fused_pair:m{fp.mode_a}{fp.mode_b}",
                         {"modes": (fp.mode_a, fp.mode_b), "macs": fp.macs,
                          "vmem_bytes": fp.vmem_bytes,
                          "hbm_bytes_fused": fp.hbm_bytes_fused,
                          "shape": tuple(x.shape)})
    with sp:
        xm = jnp.moveaxis(x, (axb, axa), (-2, -1))
        lead = xm.shape[:-2]
        x3 = xm.reshape(-1, xm.shape[-2], xm.shape[-1])
        y3, kinfo = ops.fused_gemt(x3, ca, cb, bu=fp.bu, bka=fp.bka,
                                   bnb=fp.bnb, bna=fp.bna,
                                   use_pallas=use_pallas, plans=plans,
                                   accum=fp.accum)
        y = jnp.moveaxis(y3.reshape(*lead, fp.ka, fp.kb), (-2, -1),
                         (axa, axb))
    info: dict = {"modes": (fp.mode_a, fp.mode_b), "backend": "fused",
                  "rows": int(x3.shape[0]), "macs": fp.macs,
                  "vmem_bytes": fp.vmem_bytes,
                  "hbm_bytes_staged": fp.hbm_bytes_staged,
                  "hbm_bytes_fused": fp.hbm_bytes_fused,
                  "hbm_savings": fp.hbm_savings}
    info.update(kinfo)
    return y, info


def lower_chain_pair(
    x: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    mode_a: int,
    mode_b: int,
    tiles: tuple,
    *,
    use_pallas: bool | None = None,
    plan_a: tuple | None = None,
    accum: str = "plain",
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Two consecutive stages as one chain launch, the inter-stage
    intermediate emitted.  Returns ``(y, y1)`` folded back into tensor
    modes (``y1`` has mode ``a`` at its new extent K_a, mode ``b``
    untouched).

    Deliberately span/info-free: the backward walk traces this into a
    cached jitted program, where a span would fire once at trace time and
    then lie — the executor wraps the *call* instead.  ``tiles`` is the
    chain plan's ``(bu, bka, bnb, bna, kbp)``; ``plan_a`` the precomputed
    a-side ESOP schedule (required when ``ca`` is a tracer).
    """
    if x.ndim not in (3, 4):
        raise ValueError(f"x must be 3D or 4D-batched, got ndim={x.ndim}")
    axa = x.ndim - 3 + (mode_a - 1)
    axb = x.ndim - 3 + (mode_b - 1)
    ka, kb = ca.shape[1], cb.shape[1]
    xm = jnp.moveaxis(x, (axb, axa), (-2, -1))
    lead = xm.shape[:-2]
    nb = xm.shape[-2]
    x3 = xm.reshape(-1, xm.shape[-2], xm.shape[-1])
    bu, bka, bnb, bna = tiles[0], tiles[1], tiles[2], tiles[3]
    y3, y13, _ = ops.chain_gemt(x3, ca, cb, bu=bu, bka=bka, bnb=bnb,
                                bna=bna, use_pallas=use_pallas,
                                plan_a=plan_a, accum=accum)
    y = jnp.moveaxis(y3.reshape(*lead, ka, kb), (-2, -1), (axa, axb))
    y1 = jnp.moveaxis(y13.reshape(*lead, nb, ka), (-2, -1), (axb, axa))
    return y, y1


def lower_chain_triple(
    x: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    cc: jnp.ndarray,
    mode_a: int,
    mode_b: int,
    mode_c: int,
    tiles: tuple,
    *,
    use_pallas: bool | None = None,
    plan_a: tuple | None = None,
    accum: str = "plain",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """All three stages as one chain launch with both intermediates
    emitted.  Returns ``(y, y1, y2)`` folded back into tensor modes
    (``y1``: mode ``a`` contracted; ``y2``: modes ``a`` and ``b``).

    Span/info-free for the same reason as :func:`lower_chain_pair`.
    ``tiles`` is the chain plan's ``(bu, bka, bnb, bnc, bna, kbp, kcp)``.
    """
    if x.ndim not in (3, 4):
        raise ValueError(f"x must be 3D or 4D-batched, got ndim={x.ndim}")
    off = x.ndim - 3
    axa = off + mode_a - 1
    axb = off + mode_b - 1
    axc = off + mode_c - 1
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    xm = jnp.moveaxis(x, (axc, axb, axa), (-3, -2, -1))
    lead = xm.shape[:-3]
    nc, nb = xm.shape[-3], xm.shape[-2]
    x4 = xm.reshape(-1, *xm.shape[-3:])
    bu, bka, bnb, bnc, bna = (tiles[0], tiles[1], tiles[2], tiles[3],
                              tiles[4])
    y4, y14, y24, _ = ops.chain3_gemt(x4, ca, cb, cc, bu=bu, bka=bka,
                                      bnb=bnb, bnc=bnc, bna=bna,
                                      use_pallas=use_pallas, plan_a=plan_a,
                                      accum=accum)
    y = jnp.moveaxis(y4.reshape(*lead, ka, kb, kc), (-3, -2, -1),
                     (axa, axb, axc))
    y1 = jnp.moveaxis(y14.reshape(*lead, nc, nb, ka), (-3, -2, -1),
                      (axc, axb, axa))
    y2 = jnp.moveaxis(y24.reshape(*lead, nc, ka, kb), (-3, -2, -1),
                      (axc, axa, axb))
    return y, y1, y2


def lower_coeff_grad_batch(
    as_: list,
    gs: list,
    modes: tuple,
    *,
    use_pallas: bool | None = None,
) -> list:
    """All three coefficient cotangents in one batched launch.

    ``as_[i]`` / ``gs[i]`` / ``modes[i]`` pair the stage-input tensor and
    stage-output cotangent of one forward stage (same operand contract as
    :func:`lower_coeff_grad`); the mode-unfolded rank-k products run as a
    single stacked kernel (``ops.coeff_grad_batch``).  Span/info-free for
    the same reason as :func:`lower_chain_pair` — the executor owns the
    accounting.

    Off-TPU (and for complex operands) the three products lower as direct
    full-tensor contractions instead: the operand pair shares every axis
    except the contracted mode, so one einsum per mode contracts in place
    — no unfold/pad/stack copies of batch-sized tensors (~1.2x on CPU).
    """
    live = use_pallas if use_pallas is not None else ops.on_tpu()
    if any(jnp.iscomplexobj(t) for t in (*as_, *gs)):
        live = False
    if live:
        a2ds = [mode_unfold(a, m)[0] for a, m in zip(as_, modes)]
        g2ds = [mode_unfold(g, m)[0] for g, m in zip(gs, modes)]
        return ops.coeff_grad_batch(a2ds, g2ds, use_pallas=use_pallas)
    out = []
    for a, g, m in zip(as_, gs, modes):
        ax = a.ndim - 3 + m - 1
        shared = [chr(ord("a") + i) for i in range(a.ndim)]
        la, lg = shared.copy(), shared.copy()
        la[ax], lg[ax] = "n", "k"
        spec = f"{''.join(la)},{''.join(lg)}->nk"
        dt = jnp.result_type(a.dtype, g.dtype)
        if jnp.issubdtype(dt, jnp.complexfloating):
            out.append(jnp.einsum(spec, a, g).astype(dt))
        else:
            out.append(jnp.einsum(
                spec, a, g,
                preferred_element_type=jnp.float32).astype(dt))
    return out


def lower_fused_triple(
    x: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    cc: jnp.ndarray,
    ft: FusedTriplePlan,
    *,
    use_pallas: bool | None = None,
    plans: tuple | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Execute the whole transform as one fused launch.  Returns ``(y, info)``.

    Unfolds ``x`` into the u-major ``(U, Nc, Nb, Na)`` layout the
    megakernel streams (only the batch folds into U — every tensor mode is
    contracted), runs all three contractions in one launch — neither
    inter-stage intermediate ever exists in HBM, so both fold/unfold
    transposes dissolve into the kernel's BlockSpec index maps — and folds
    ``(U, Ka, Kb, Kc)`` back into tensor modes.  ``plans`` optionally
    carries the three precomputed ``esop_plan_cached`` tuples (a/b/c), for
    callers whose coefficients are tracers inside a ``shard_map`` body.
    """
    if x.ndim not in (3, 4):
        raise ValueError(f"x must be 3D or 4D-batched, got ndim={x.ndim}")
    off = x.ndim - 3
    axa = off + ft.mode_a - 1
    axb = off + ft.mode_b - 1
    axc = off + ft.mode_c - 1
    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span(f"fused_triple:m{ft.mode_a}{ft.mode_b}{ft.mode_c}",
                         {"modes": (ft.mode_a, ft.mode_b, ft.mode_c),
                          "macs": ft.macs, "vmem_bytes": ft.vmem_bytes,
                          "hbm_bytes_fused": ft.hbm_bytes_fused,
                          "shape": tuple(x.shape)})
    with sp:
        xm = jnp.moveaxis(x, (axc, axb, axa), (-3, -2, -1))
        lead = xm.shape[:-3]
        x4 = xm.reshape(-1, *xm.shape[-3:])
        y4, kinfo = ops.fused3_gemt(x4, ca, cb, cc, bu=ft.bu, bka=ft.bka,
                                    bnb=ft.bnb, bnc=ft.bnc, bna=ft.bna,
                                    use_pallas=use_pallas, plans=plans,
                                    accum=ft.accum)
        y = jnp.moveaxis(y4.reshape(*lead, ft.ka, ft.kb, ft.kc),
                         (-3, -2, -1), (axa, axb, axc))
    info: dict = {"modes": (ft.mode_a, ft.mode_b, ft.mode_c),
                  "backend": "fused", "rows": int(x4.shape[0]),
                  "macs": ft.macs, "vmem_bytes": ft.vmem_bytes,
                  "hbm_bytes_staged": ft.hbm_bytes_staged,
                  "hbm_bytes_fused": ft.hbm_bytes_fused,
                  "hbm_savings": ft.hbm_savings}
    info.update(kinfo)
    return y, info
