"""GEMT execution engine: cost-model planner → kernel lowering → autotune.

The bridge between the algorithm layer (``core.gemt``) and the kernel layer
(``kernels.ops``): plans the stage order and per-stage backend from the
problem's shapes and block sparsity, lowers each mode contraction to a 2D
GEMM on the Pallas kernels, and tunes tile sizes against a persisted cache.
See ``docs/engine.md``.
"""
from .plan import (DEFAULT_ESOP_THRESHOLD, GemtPlan, StagePlan, build_plan,
                   macs_for_order, order_costs, sparsity_signature)
from .lower import lower_stage, mode_fold, mode_unfold
from .autotune import AutotuneCache, autotune_gemm, default_cache_path, make_key
from .executor import (clear_plan_cache, execute, execute_with_info,
                       gemt3_planned, plan_cache_info, plan_gemt3)

__all__ = [
    "DEFAULT_ESOP_THRESHOLD", "GemtPlan", "StagePlan", "build_plan",
    "macs_for_order", "order_costs", "sparsity_signature",
    "lower_stage", "mode_fold", "mode_unfold",
    "AutotuneCache", "autotune_gemm", "default_cache_path", "make_key",
    "clear_plan_cache", "execute", "execute_with_info", "gemt3_planned",
    "plan_cache_info", "plan_gemt3",
]
