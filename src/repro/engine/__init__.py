"""GEMT execution engine: cost-model planner → kernel lowering → autotune.

The bridge between the algorithm layer (``core.gemt``) and the kernel layer
(``kernels.ops``): plans the stage order and per-stage backend from the
problem's shapes and block sparsity, lowers each mode contraction to a 2D
GEMM on the Pallas kernels, and tunes tile sizes against a persisted cache.
Topology-aware since PR 3: given a ``Mesh`` + per-mode axes, the planner
scores collective bytes and the executor runs the per-shard schedule inside
``shard_map`` (paper §3–§5).  Differentiable since PR 5:
``gemt3_planned(differentiable=True)`` installs a custom VJP whose backward
pass re-enters the engine — the X-cotangent as the derived adjoint plan
(transposed coefficients, reversed order; §2.2's orthonormality makes it
the inverse transform) and the coefficient cotangents as rank-k SR-GEMM
updates.  Fused adjoint since PR 8: the backward walk runs as chain
kernels — the recompute prefix, the cotangent chain (intermediates
emitted from the launch that produces them) and the three coefficient
cotangents collapse from eight launches to as few as three
(``plan_adjoint_chain`` extends the pair/triple fusion byte model to the
backward).  Numerics-guarded since PR 9: ``accum=`` selects plain / f32 /
Neumaier-compensated accumulation, ``error_budget=`` holds the planner's
a-priori rounding bound to a ceiling (escalating the accumulation mode and
demoting fusion depth as needed), and the ``numerics`` module's
finite-guard classifies NaN/Inf outputs as retryable (``docs/numerics.md``).
See ``docs/engine.md`` and ``docs/distributed.md``; the
paper-section→module map is in ``docs/architecture.md``.
"""
from .plan import (DEFAULT_ESOP_THRESHOLD, DEFAULT_VMEM_BUDGET, FUSE_MODES,
                   AdjointChainPlan, FusedPairPlan, FusedTriplePlan,
                   GemtPlan, SHARDED_EINSUM_BREAKEVEN_MACS, StagePlan,
                   build_plan, chain3_tile_sizes, chain3_vmem_bytes,
                   chain_tile_sizes, chain_vmem_bytes, derive_adjoint_plan,
                   fused3_tile_sizes, fused3_vmem_bytes, fused_tile_sizes,
                   fused_vmem_bytes, macs_for_order, mesh_axis_size,
                   normalize_axes, order_costs, plan_adjoint_chain,
                   plan_hbm_bytes, refresh_fused_pair, refresh_fused_triple,
                   sparsity_signature, stage_hbm_bytes,
                   staged_pair_hbm_bytes)
from .lower import (coeff_grad_backend, lower_chain_pair, lower_chain_triple,
                    lower_coeff_grad, lower_coeff_grad_batch,
                    lower_fused_pair, lower_fused_triple,
                    lower_sharded_stage, lower_stage, mode_fold, mode_unfold)
from .autotune import (AutotuneCache, autotune_fused, autotune_fused3,
                       autotune_gemm, default_cache_path, make_fused3_key,
                       make_fused_key, make_key)
from .numerics import (ACCUM_MODES, NonfiniteOutput, accum_out_dtype,
                       enforce_error_budget, finite_guard, normalize_accum,
                       plan_error_bound, stage_error_bound, unit_roundoff)
from .executor import (clear_plan_cache, default_mode_axes, execute,
                       execute_sharded_with_info, execute_with_info,
                       gemt3_planned, grad_stats, invalidate_plans,
                       plan_cache_info, plan_gemt3, reset_grad_stats)

__all__ = [
    "DEFAULT_ESOP_THRESHOLD", "DEFAULT_VMEM_BUDGET", "FUSE_MODES",
    "AdjointChainPlan", "FusedPairPlan", "FusedTriplePlan", "GemtPlan",
    "SHARDED_EINSUM_BREAKEVEN_MACS", "StagePlan", "build_plan",
    "chain3_tile_sizes", "chain3_vmem_bytes", "chain_tile_sizes",
    "chain_vmem_bytes", "derive_adjoint_plan",
    "fused3_tile_sizes", "fused3_vmem_bytes", "fused_tile_sizes",
    "fused_vmem_bytes", "macs_for_order", "mesh_axis_size", "normalize_axes",
    "order_costs", "plan_adjoint_chain", "plan_hbm_bytes",
    "refresh_fused_pair", "refresh_fused_triple", "sparsity_signature",
    "stage_hbm_bytes", "staged_pair_hbm_bytes",
    "coeff_grad_backend", "lower_chain_pair", "lower_chain_triple",
    "lower_coeff_grad", "lower_coeff_grad_batch",
    "lower_fused_pair", "lower_fused_triple", "lower_sharded_stage",
    "lower_stage", "mode_fold", "mode_unfold",
    "AutotuneCache", "autotune_fused", "autotune_fused3", "autotune_gemm",
    "default_cache_path", "make_fused3_key", "make_fused_key", "make_key",
    "ACCUM_MODES", "NonfiniteOutput", "accum_out_dtype",
    "enforce_error_budget", "finite_guard", "normalize_accum",
    "plan_error_bound", "stage_error_bound", "unit_roundoff",
    "clear_plan_cache", "default_mode_axes", "execute",
    "execute_sharded_with_info", "execute_with_info", "gemt3_planned",
    "grad_stats", "invalidate_plans", "plan_cache_info", "plan_gemt3",
    "reset_grad_stats",
]
