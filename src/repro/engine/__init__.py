"""GEMT execution engine: cost-model planner → kernel lowering → autotune.

The bridge between the algorithm layer (``core.gemt``) and the kernel layer
(``kernels.ops``): plans the stage order and per-stage backend from the
problem's shapes and block sparsity, lowers each mode contraction to a 2D
GEMM on the Pallas kernels, and tunes tile sizes against a persisted cache.
See ``docs/engine.md``.
"""
from .plan import (DEFAULT_ESOP_THRESHOLD, DEFAULT_VMEM_BUDGET, FusedPairPlan,
                   GemtPlan, StagePlan, build_plan, fused_tile_sizes,
                   fused_vmem_bytes, macs_for_order, order_costs,
                   plan_hbm_bytes, refresh_fused_pair, sparsity_signature,
                   stage_hbm_bytes, staged_pair_hbm_bytes)
from .lower import lower_fused_pair, lower_stage, mode_fold, mode_unfold
from .autotune import (AutotuneCache, autotune_fused, autotune_gemm,
                       default_cache_path, make_fused_key, make_key)
from .executor import (clear_plan_cache, execute, execute_with_info,
                       gemt3_planned, plan_cache_info, plan_gemt3)

__all__ = [
    "DEFAULT_ESOP_THRESHOLD", "DEFAULT_VMEM_BUDGET", "FusedPairPlan",
    "GemtPlan", "StagePlan", "build_plan", "fused_tile_sizes",
    "fused_vmem_bytes", "macs_for_order", "order_costs", "plan_hbm_bytes",
    "refresh_fused_pair", "sparsity_signature", "stage_hbm_bytes",
    "staged_pair_hbm_bytes",
    "lower_fused_pair", "lower_stage", "mode_fold", "mode_unfold",
    "AutotuneCache", "autotune_fused", "autotune_gemm", "default_cache_path",
    "make_fused_key", "make_key",
    "clear_plan_cache", "execute", "execute_with_info", "gemt3_planned",
    "plan_cache_info", "plan_gemt3",
]
