"""GEMT schedule planner — cost model over the six stage orders (paper §3).

The paper enumerates six parenthesizations of the 3-stage GEMT; with
rectangular coefficient matrices (Tucker expansion/compression, §2.3) the
order changes both the MAC count and the intermediate-tensor sizes by large
factors — contracting compressive modes (K_s < N_s) first shrinks everything
downstream.  Deinsum-style planning: the cost of contracting mode ``s`` on a
tensor of current dims ``d`` is

    MACs(s) = prod(d) / d[s] * N_s * K_s        (rows · N_s · K_s)

and the intermediate after the stage has ``d[s] -> K_s``.  The planner
scores every order by (effective MACs, peak intermediate bytes) and also
chooses a per-stage backend from the coefficient matrix's *block* sparsity
(``block_nonzero_mask``, shared with the Pallas block-ESOP kernel):

  * ``esop``    — zero-block fraction >= ``esop_threshold``: the block-ESOP
                  kernel skips fetching/multiplying those blocks, so the
                  stage's effective MACs scale by the live-block fraction;
  * ``sr_gemm`` — dense streaming outer-product kernel;
  * ``einsum``  — fallback for complex dtypes (DFT) and tiny operands where
                  kernel/padding overhead dominates.

``build_plan`` is pure and host-side: it never touches device values beyond
reading the coefficient matrices' zero structure.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math

import jax.numpy as jnp
import numpy as np

from ..core.esop import block_nonzero_mask

__all__ = [
    "StagePlan",
    "GemtPlan",
    "build_plan",
    "order_costs",
    "macs_for_order",
    "sparsity_signature",
    "DEFAULT_ESOP_THRESHOLD",
    "MIN_KERNEL_DIM",
]

DEFAULT_ESOP_THRESHOLD = 0.3  # zero-block fraction at which block-ESOP wins
MIN_KERNEL_DIM = 8  # below this, padding overhead beats the kernels


def _pow2_clamp(d: int, lo: int = 8, hi: int = 128) -> int:
    """Largest power of two <= d, clamped to [lo, hi]."""
    if d <= lo:
        return lo
    return min(hi, 1 << (int(d).bit_length() - 1))


def _pad_up(d: int, b: int) -> int:
    return -(-d // b) * b


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One lowered mode-s contraction: ``(rows, N_s) @ (N_s, K_s)``."""

    mode: int  # which tensor mode (1, 2, 3) this stage contracts
    n: int  # contraction extent N_s
    k: int  # output extent K_s
    rows: int  # unfolded GEMM rows (prod of untouched dims, excl. batch)
    backend: str  # "sr_gemm" | "esop" | "einsum"
    macs: int  # dense MACs = rows * n * k
    macs_effective: int  # after live-block scaling (== macs unless esop)
    zero_block_frac: float  # fraction of (bk, bn) blocks of C_s that are 0
    bm: int
    bn: int
    bk: int


@dataclasses.dataclass(frozen=True)
class GemtPlan:
    """A fully scheduled 3-stage GEMT: order + per-stage lowering choices."""

    order: tuple[int, int, int]
    stages: tuple[StagePlan, ...]
    in_shape: tuple[int, int, int]
    out_shape: tuple[int, int, int]
    macs: int  # total dense MACs over the three stages
    macs_effective: int  # with block-sparsity scaling
    peak_intermediate_bytes: int
    key: str  # cache key this plan was built under

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["stages"] = [dataclasses.asdict(s) for s in self.stages]
        return d

    @property
    def backends(self) -> tuple[str, ...]:
        return tuple(s.backend for s in self.stages)


def macs_for_order(
    dims: tuple[int, int, int],
    ks: tuple[int, int, int],
    order: tuple[int, int, int],
) -> int:
    """Dense MAC count of staging ``order`` on input dims with C_s: N_s→K_s."""
    d = list(dims)
    total = 0
    for mode in order:
        rows = math.prod(d) // d[mode - 1]
        total += rows * dims[mode - 1] * ks[mode - 1]
        d[mode - 1] = ks[mode - 1]
    return total


def sparsity_signature(cs: dict[int, jnp.ndarray],
                       blocks: dict[int, tuple[int, int]]) -> str:
    """Stable digest of the coefficient matrices' block-zero structure.

    Two problems with the same shapes but different zero patterns must not
    share an autotune/plan cache entry — the ESOP schedule differs.
    """
    h = hashlib.sha1()
    for mode in (1, 2, 3):
        c = cs[mode]
        bk, bn = blocks[mode]
        mask = np.asarray(_padded_block_mask(c, bk, bn))
        h.update(f"{mode}:{c.shape}:{bk}x{bn}:".encode())
        h.update(np.packbits(mask).tobytes())
    return h.hexdigest()[:16]


def _padded_block_mask(c: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    n, k = c.shape
    pad = ((0, (-n) % bk), (0, (-k) % bn))
    cp = jnp.pad(c, pad) if any(p[1] for p in pad) else c
    return block_nonzero_mask(cp, (bk, bn))


def _stage_blocks(rows: int, n: int, k: int,
                  block_sizes: tuple[int, int, int] | None) -> tuple[int, int, int]:
    if block_sizes is not None:
        return block_sizes
    # Default: MXU-aligned 128, shrunk (power of two) for small operands so
    # block-sparsity detection and padding stay proportionate.
    return (_pow2_clamp(rows), _pow2_clamp(k), _pow2_clamp(n))


def _plan_stage(
    mode: int,
    rows: int,
    c: jnp.ndarray,
    *,
    batch: int,
    esop_threshold: float,
    block_sizes: tuple[int, int, int] | None,
    mask_cache: dict[int, np.ndarray] | None = None,
) -> StagePlan:
    n, k = c.shape
    # The lowering folds any batch axis into the GEMM rows, so backend and
    # tile choices must see the batched row count (a large batch of skinny
    # tensors is still a big GEMM).  MAC fields stay per-sample: the batch
    # scales every order equally and cancels in the order search.
    rows_total = rows * max(batch, 1)
    bm, bn, bk = _stage_blocks(rows_total, n, k, block_sizes)
    dense_macs = rows * n * k

    if jnp.iscomplexobj(c):
        # The Pallas kernels are real-valued; DFT stages stay on einsum.
        return StagePlan(mode, n, k, rows, "einsum", dense_macs, dense_macs,
                         0.0, bm, bn, bk)

    # (bk, bn) depend only on C's shape, never on the stage order, so the
    # mask (a device pad + host sync) is shared across all six candidates.
    if mask_cache is not None and mode in mask_cache:
        mask = mask_cache[mode]
    else:
        mask = np.asarray(_padded_block_mask(c, bk, bn))
        if mask_cache is not None:
            mask_cache[mode] = mask
    zero_frac = 1.0 - float(mask.mean()) if mask.size else 0.0

    if min(rows_total, n, k) < MIN_KERNEL_DIM:
        backend = "einsum"
        eff = dense_macs
    elif zero_frac >= esop_threshold:
        backend = "esop"
        # Live blocks bound the executed MACs (block granularity on the
        # streamed C grid; rows scale both sides equally, so they stay
        # unpadded — padding them to bm would saturate the discount to
        # dense for small-row/batched stages).
        padded_c = _pad_up(n, bk) * _pad_up(k, bn)
        eff = min(dense_macs, int(rows * padded_c * float(mask.mean())))
    else:
        backend = "sr_gemm"
        eff = dense_macs
    return StagePlan(mode, n, k, rows, backend, dense_macs, eff, zero_frac,
                     bm, bn, bk)


def _plan_for_order(
    dims: tuple[int, int, int],
    cs: dict[int, jnp.ndarray],
    order: tuple[int, int, int],
    *,
    batch: int,
    itemsize: int,
    esop_threshold: float,
    block_sizes: tuple[int, int, int] | None,
    mask_cache: dict[int, np.ndarray] | None = None,
) -> tuple[tuple[StagePlan, ...], int, int, int]:
    d = list(dims)
    stages = []
    peak_bytes = 0
    for mode in order:
        rows = math.prod(d) // d[mode - 1]
        stages.append(_plan_stage(mode, rows, cs[mode], batch=batch,
                                  esop_threshold=esop_threshold,
                                  block_sizes=block_sizes,
                                  mask_cache=mask_cache))
        d[mode - 1] = cs[mode].shape[1]
        peak_bytes = max(peak_bytes, math.prod(d) * itemsize)
    macs = sum(s.macs for s in stages)
    eff = sum(s.macs_effective for s in stages)
    return tuple(stages), macs, eff, peak_bytes


def order_costs(
    dims: tuple[int, int, int],
    cs: dict[int, jnp.ndarray],
    *,
    batch: int = 1,
    itemsize: int = 4,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
) -> dict[tuple[int, int, int], dict]:
    """Cost-model summary for all six orders (introspection/benchmarks)."""
    out = {}
    mask_cache: dict[int, np.ndarray] = {}
    for order in itertools.permutations((1, 2, 3)):
        _, macs, eff, peak = _plan_for_order(
            dims, cs, order, batch=batch, itemsize=itemsize,
            esop_threshold=esop_threshold, block_sizes=block_sizes,
            mask_cache=mask_cache)
        out[order] = {"macs": macs, "macs_effective": eff,
                      "peak_intermediate_bytes": peak}
    return out


def build_plan(
    x_shape: tuple[int, ...],
    x_dtype,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    order: tuple[int, int, int] | None = None,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
) -> GemtPlan:
    """Plan a 3-stage GEMT for a tensor of ``x_shape`` (3D, or 4D batched).

    ``order=None`` searches all six parenthesizations and keeps the one with
    minimal (effective MACs, peak intermediate bytes); passing an explicit
    order pins it (the paper's reference chain is ``(3, 1, 2)``).
    """
    dims = tuple(int(d) for d in x_shape[-3:])
    if len(x_shape) not in (3, 4):
        raise ValueError(f"x must be 3D or 4D-batched, got shape {x_shape}")
    batch = int(x_shape[0]) if len(x_shape) == 4 else 1
    cs = {1: c1, 2: c2, 3: c3}
    for mode in (1, 2, 3):
        if cs[mode].ndim != 2 or cs[mode].shape[0] != dims[mode - 1]:
            raise ValueError(
                f"C{mode} shape {cs[mode].shape} incompatible with mode "
                f"extent {dims[mode - 1]}")
    itemsize = jnp.dtype(x_dtype).itemsize * max(batch, 1)

    candidates = ([tuple(order)] if order is not None
                  else list(itertools.permutations((1, 2, 3))))
    best = None
    mask_cache: dict[int, np.ndarray] = {}
    for cand in candidates:
        if sorted(cand) != [1, 2, 3]:
            raise ValueError(f"order must be a permutation of (1,2,3), got {cand}")
        stages, macs, eff, peak = _plan_for_order(
            dims, cs, cand, batch=batch, itemsize=itemsize,
            esop_threshold=esop_threshold, block_sizes=block_sizes,
            mask_cache=mask_cache)
        score = (eff, peak, cand)
        if best is None or score < best[0]:
            best = (score, cand, stages, macs, eff, peak)
    _, chosen, stages, macs, eff, peak = best

    out_shape = tuple(cs[m].shape[1] for m in (1, 2, 3))
    blocks = {s.mode: (s.bk, s.bn) for s in stages}
    key = "|".join([
        f"x={tuple(x_shape)}", f"dt={jnp.dtype(x_dtype).name}",
        f"o={chosen}", f"th={esop_threshold}",
        f"bs={block_sizes}", f"sig={sparsity_signature(cs, blocks)}",
    ])
    return GemtPlan(order=chosen, stages=stages, in_shape=dims,
                    out_shape=out_shape, macs=macs, macs_effective=eff,
                    peak_intermediate_bytes=peak, key=key)
