"""GEMT schedule planner — cost model over the six stage orders (paper §3).

The paper enumerates six parenthesizations of the 3-stage GEMT; with
rectangular coefficient matrices (Tucker expansion/compression, §2.3) the
order changes both the MAC count and the intermediate-tensor sizes by large
factors — contracting compressive modes (K_s < N_s) first shrinks everything
downstream.  Deinsum-style planning: the cost of contracting mode ``s`` on a
tensor of current dims ``d`` is

    MACs(s) = prod(d) / d[s] * N_s * K_s        (rows · N_s · K_s)

and the intermediate after the stage has ``d[s] -> K_s``.  The planner
scores every order by (effective MACs, peak intermediate bytes) and also
chooses a per-stage backend from the coefficient matrix's *block* sparsity
(``block_nonzero_mask``, shared with the Pallas block-ESOP kernel):

  * ``esop``    — zero-block fraction >= ``esop_threshold``: the block-ESOP
                  kernel skips fetching/multiplying those blocks, so the
                  stage's effective MACs scale by the live-block fraction;
  * ``sr_gemm`` — dense streaming outer-product kernel;
  * ``einsum``  — fallback for complex dtypes (DFT) and tiny operands where
                  kernel/padding overhead dominates.

``build_plan`` is pure and host-side: it never touches device values beyond
reading the coefficient matrices' zero structure.

**Topology-aware planning** (``mesh=``/``axes=``): when a
:class:`jax.sharding.Mesh` and a per-mode axis assignment are given, the
plan describes the *per-shard* schedule of the TriADA distribution
(``core/distributed.py``, paper §4–§5 / Eq. 7): the tensor is stationary
with mode ``s`` sharded over ``axes[s-1]``; a stage contracting an
unsharded mode is fully local; a stage contracting a sharded mode runs a
local partial rank-k update against this device's coefficient rows and
combines with one ``psum_scatter`` over that axis.  The cost model then
scores orders by ``(effective per-shard MACs, collective bytes, peak local
bytes)`` — contracting compressive *unsharded* modes first shrinks the
partial that the sharded stage must scatter, so the planner prefers
shard-local stages early.  Fusion is offered only when both modes of the
pair are shard-local (the fused kernel has no collective between its two
contractions).  See ``docs/distributed.md``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math

import jax
import jax.numpy as jnp
import numpy as np

from ..core.esop import block_nonzero_mask
from ..kernels.fused_gemt import kb_padded
from .numerics import enforce_error_budget, normalize_accum, plan_error_bound

AxisName = str | tuple[str, ...] | None

__all__ = [
    "StagePlan",
    "FusedPairPlan",
    "FusedTriplePlan",
    "GemtPlan",
    "build_plan",
    "derive_adjoint_plan",
    "AdjointChainPlan",
    "plan_adjoint_chain",
    "order_costs",
    "macs_for_order",
    "sparsity_signature",
    "fused_tile_sizes",
    "fused_vmem_bytes",
    "fused3_tile_sizes",
    "fused3_vmem_bytes",
    "chain_tile_sizes",
    "chain_vmem_bytes",
    "chain3_tile_sizes",
    "chain3_vmem_bytes",
    "refresh_fused_pair",
    "refresh_fused_triple",
    "stage_hbm_bytes",
    "staged_pair_hbm_bytes",
    "plan_hbm_bytes",
    "mesh_axis_size",
    "normalize_axes",
    "DEFAULT_ESOP_THRESHOLD",
    "DEFAULT_VMEM_BUDGET",
    "MIN_KERNEL_DIM",
    "SHARDED_EINSUM_BREAKEVEN_MACS",
    "FUSE_MODES",
]

DEFAULT_ESOP_THRESHOLD = 0.3  # zero-block fraction at which block-ESOP wins
MIN_KERNEL_DIM = 8  # below this, padding overhead beats the kernels
# VMEM the fused kernel may claim for its tiles + scratch: roughly half a
# TPU core's ~16 MB, leaving headroom for Pallas pipelining internals.
DEFAULT_VMEM_BUDGET = 8 * 1024 * 1024
# Per-shard stages below this many (batched) MACs run the einsum fallback:
# at these sizes the kernel launch + unfold padding overhead beats any
# streaming win (BENCH_distributed_engine D3_dense_32 measured the kernel
# path at 0.82x vs einsum before this break-even existed).
SHARDED_EINSUM_BREAKEVEN_MACS = 1 << 20
# Valid values of the ``fuse`` knob (build_plan / gemt3_planned):
#   None     auto — deepest fusion that models the fewest HBM bytes
#   True     force the deepest feasible fusion (triple, else pair)
#   False    never fuse (all-staged schedule)
#   "pair"   pair fusion only (never the whole-transform megakernel)
#   "triple" whole-transform fusion or nothing (no pair fallback)
FUSE_MODES = (None, True, False, "pair", "triple")


def _pow2_clamp(d: int, lo: int = 8, hi: int = 128) -> int:
    """Largest power of two <= d, clamped to [lo, hi]."""
    if d <= lo:
        return lo
    return min(hi, 1 << (int(d).bit_length() - 1))


def _pow2_ceil_clamp(d: int, lo: int = 8, hi: int = 128) -> int:
    """Smallest power of two >= d, clamped to [lo, hi].

    Tile choices that set the padding granularity round *up*: a 48-extent
    tiled at 64 is one padded block, while flooring to 32 pads to the same
    64 but fetches it in two visits (and the revisit factors multiply).
    """
    if d <= lo:
        return lo
    return min(hi, 1 << (int(d) - 1).bit_length())


def _pad_up(d: int, b: int) -> int:
    return -(-d // b) * b


@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One lowered mode-s contraction: ``(rows, N_s) @ (N_s, K_s)``.

    Under a mesh (``axis`` not None) the fields describe the *per-shard*
    GEMM: ``n`` is this device's slice of the contracted extent
    (``N_s / shards``), ``k`` stays the **full** output extent — the stage
    produces a partial sum that one ``psum_scatter`` over ``axis`` reduces
    and re-shards to ``k / shards`` local.  ``collective_bytes`` models
    that scatter's per-device ICI traffic.
    """

    mode: int  # which tensor mode (1, 2, 3) this stage contracts
    n: int  # contraction extent N_s (per-shard slice when sharded)
    k: int  # output extent K_s (always the full extent)
    rows: int  # unfolded GEMM rows (prod of untouched dims, excl. batch)
    backend: str  # "sr_gemm" | "esop" | "einsum"
    macs: int  # dense MACs = rows * n * k
    macs_effective: int  # after live-block scaling (== macs unless esop)
    zero_block_frac: float  # fraction of (bk, bn) blocks of C_s that are 0
    bm: int
    bn: int
    bk: int
    axis: AxisName = None  # mesh axis sharding this mode (None = local stage)
    shards: int = 1  # size of that axis (1 = unsharded)
    collective_bytes: int = 0  # modeled per-device psum_scatter ICI bytes
    accum: str = "plain"  # accumulation mode (engine/numerics.py)

    @property
    def k_local(self) -> int:
        """Per-shard output extent after the stage's psum_scatter."""
        return self.k // self.shards


@dataclasses.dataclass(frozen=True)
class FusedPairPlan:
    """Two consecutive stages fused into one kernel: ``(X ×_a C_a) ×_b C_b``.

    ``first`` indexes the pair's first stage within ``GemtPlan.order`` /
    ``.stages``; the two ``StagePlan`` entries it covers stay in the plan
    untouched — they are the documented (and runtime) staged fallback.
    """

    first: int  # index of the pair's first stage in the order (0 or 1)
    mode_a: int  # contracted first (innermost stream)
    mode_b: int  # contracted second (slab stream)
    rows: int  # untouched u-major GEMM rows U (excl. batch)
    na: int
    ka: int
    nb: int
    kb: int
    bu: int  # fused tile sizes (the autotunable triple is bu/bka/bnb)
    bka: int
    bnb: int
    bna: int
    kbp: int  # padded full-width Kb slab resident in VMEM
    vmem_bytes: int  # modeled on-chip footprint at these tiles
    hbm_bytes_staged: int  # modeled pair traffic if executed staged
    hbm_bytes_fused: int  # modeled pair traffic fused
    macs: int  # dense MACs of the two covered stages
    zero_block_frac_a: float
    zero_block_frac_b: float
    accum: str = "plain"  # accumulation mode (folds comp scratch into VMEM)

    @property
    def hbm_savings(self) -> float:
        """Staged-over-fused modeled HBM traffic ratio (>1 means fusing wins)."""
        return self.hbm_bytes_staged / max(self.hbm_bytes_fused, 1)


@dataclasses.dataclass(frozen=True)
class FusedTriplePlan:
    """All three stages fused into one whole-transform megakernel:
    ``Y = ((X ×_a C_a) ×_b C_b) ×_c C_c`` with both intermediates resident
    in VMEM (``kernels/fused3_gemt.py``).

    Covers the entire ``GemtPlan.order`` (there is no "first" index — the
    triple always starts at stage 0 and ends the schedule); the three
    ``StagePlan`` entries stay in the plan untouched as the staged
    fallback.  ``mode_a`` is contracted first (innermost stream, full 2D
    ESOP skipping), ``mode_b`` second and ``mode_c`` third (slab-resident,
    slab-level skipping).
    """

    mode_a: int
    mode_b: int
    mode_c: int
    rows: int  # untouched GEMM rows excl. batch — always 1 (all modes fuse)
    na: int
    ka: int
    nb: int
    kb: int
    nc: int
    kc: int
    bu: int  # fused tiles (the autotunable quadruple is bu/bka/bnb/bnc)
    bka: int
    bnb: int
    bnc: int
    bna: int
    kbp: int  # padded full-width Kb slab resident in VMEM
    kcp: int  # padded full-width Kc slab resident in VMEM
    vmem_bytes: int  # modeled on-chip footprint at these tiles
    hbm_bytes_staged: int  # modeled whole-schedule traffic executed staged
    hbm_bytes_fused: int  # modeled whole-schedule traffic fused
    macs: int  # dense MACs of the three covered stages (per sample)
    zero_block_frac_a: float
    zero_block_frac_b: float
    zero_block_frac_c: float
    accum: str = "plain"  # accumulation mode (folds comp scratch into VMEM)

    @property
    def hbm_savings(self) -> float:
        """Staged-over-fused modeled HBM traffic ratio (>1 means fusing wins)."""
        return self.hbm_bytes_staged / max(self.hbm_bytes_fused, 1)


@dataclasses.dataclass(frozen=True)
class GemtPlan:
    """A fully scheduled 3-stage GEMT: order + per-stage lowering choices."""

    order: tuple[int, int, int]
    stages: tuple[StagePlan, ...]
    in_shape: tuple[int, int, int]
    out_shape: tuple[int, int, int]
    macs: int  # total dense MACs over the three stages
    macs_effective: int  # with block-sparsity scaling
    peak_intermediate_bytes: int
    key: str  # cache key this plan was built under
    fused: FusedPairPlan | None = None  # stage pair run as one kernel
    fused3: FusedTriplePlan | None = None  # all 3 stages as one megakernel
    hbm_bytes_staged: int = 0  # modeled traffic of the all-staged schedule
    hbm_bytes_moved: int = 0  # modeled traffic of the planned schedule
    # --- topology (all defaults = single-device; byte fields above are
    # *per-shard* when a mesh is planned) ---
    axes: tuple[AxisName, AxisName, AxisName] = (None, None, None)
    shards: tuple[int, int, int] = (1, 1, 1)  # axis sizes per mode
    batch_axis: AxisName = None  # mesh axis sharding the leading batch dim
    batch_shards: int = 1
    collective_bytes: int = 0  # modeled per-device ICI bytes (psum_scatters)
    # Plan-time degradation record: fusion demotions (triple→pair→staged)
    # forced by the VMEM budget or the byte model, each with the numbers
    # that forced it, plus numerics_degradation accumulation escalations
    # (engine/numerics.py).  Replayed as info["events"] on every execution
    # of this (cached) plan — see docs/observability.md.
    events: tuple = ()
    # --- guarded numerics (engine/numerics.py, docs/numerics.md) ---
    accum: str = "plain"  # resolved accumulation mode (after budget walk)
    error_bound: float = 0.0  # a-priori staged-schedule rounding bound
    error_budget: float | None = None  # the knob the bound was held to

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["stages"] = [dataclasses.asdict(s) for s in self.stages]
        return d

    @property
    def backends(self) -> tuple[str, ...]:
        return tuple(s.backend for s in self.stages)


def mesh_axis_size(mesh, axis: AxisName) -> int:
    """Total device count of a (possibly tuple) mesh axis; 1 for None."""
    if mesh is None or axis is None:
        return 1
    names = axis if isinstance(axis, tuple) else (axis,)
    return math.prod(int(mesh.shape[a]) for a in names)


def normalize_axes(axes) -> tuple[AxisName, AxisName, AxisName]:
    """Canonicalize a 3-entry per-mode axis assignment (lists → tuples)."""
    if axes is None:
        return (None, None, None)
    axes = tuple(tuple(a) if isinstance(a, list) else a for a in axes)
    if len(axes) != 3:
        raise ValueError(f"axes must name one mesh axis per mode, got {axes}")
    return axes


def _is_traced(*arrays) -> bool:
    """True when any coefficient is an abstract tracer (planning under jit).

    Traced coefficients have shape/dtype but no host-readable values, so
    every zero-structure decision (ESOP backends, fusion masks, sparsity
    signatures) degrades to the dense assumption.
    """
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


def macs_for_order(
    dims: tuple[int, int, int],
    ks: tuple[int, int, int],
    order: tuple[int, int, int],
) -> int:
    """Dense MAC count of staging ``order`` on input dims with C_s: N_s→K_s."""
    d = list(dims)
    total = 0
    for mode in order:
        rows = math.prod(d) // d[mode - 1]
        total += rows * dims[mode - 1] * ks[mode - 1]
        d[mode - 1] = ks[mode - 1]
    return total


def sparsity_signature(cs: dict[int, jnp.ndarray],
                       blocks: dict[int, tuple[int, int]]) -> str:
    """Stable digest of the coefficient matrices' block-zero structure.

    Two problems with the same shapes but different zero patterns must not
    share an autotune/plan cache entry — the ESOP schedule differs.
    Traced coefficients (planning under an outer jit) digest to a shared
    ``"traced"`` tag — correct because traced plans are dense-only, so they
    depend on nothing beyond shapes and dtype.
    """
    if _is_traced(*cs.values()):
        return "traced"
    h = hashlib.sha1()
    for mode in (1, 2, 3):
        c = cs[mode]
        bk, bn = blocks[mode]
        mask = np.asarray(_padded_block_mask(c, bk, bn))
        h.update(f"{mode}:{c.shape}:{bk}x{bn}:".encode())
        h.update(np.packbits(mask).tobytes())
    return h.hexdigest()[:16]


def _padded_block_mask(c: jnp.ndarray, bk: int, bn: int) -> jnp.ndarray:
    n, k = c.shape
    pad = ((0, (-n) % bk), (0, (-k) % bn))
    cp = jnp.pad(c, pad) if any(p[1] for p in pad) else c
    return block_nonzero_mask(cp, (bk, bn))


def _stage_blocks(rows: int, n: int, k: int,
                  block_sizes: tuple[int, int, int] | None) -> tuple[int, int, int]:
    if block_sizes is not None:
        return block_sizes
    # Default: MXU-aligned 128, shrunk (power of two) for small operands so
    # block-sparsity detection and padding stay proportionate.
    return (_pow2_clamp(rows), _pow2_clamp(k), _pow2_clamp(n))


def _plan_stage(
    mode: int,
    rows: int,
    c: jnp.ndarray,
    *,
    batch: int,
    esop_threshold: float,
    block_sizes: tuple[int, int, int] | None,
    mask_cache: dict[int, np.ndarray] | None = None,
    axis: AxisName = None,
    shards: int = 1,
    itemsize_total: int = 4,
) -> StagePlan:
    n, k = c.shape
    if shards > 1:
        # Sharded contraction mode: the local GEMM contracts this device's
        # N_s/P slice of the coefficient rows into the FULL K_s extent (a
        # partial sum); one psum_scatter over `axis` then reduces and
        # re-shards it.  The slice is selected by axis_index at run time,
        # so its zero structure is device-dependent — block-ESOP (whose
        # schedule is host-side per-matrix) is off the table; the stage
        # runs sr_gemm or einsum.
        n = n // shards
    # The lowering folds any batch axis into the GEMM rows, so backend and
    # tile choices must see the batched row count (a large batch of skinny
    # tensors is still a big GEMM).  MAC fields stay per-sample: the batch
    # scales every order equally and cancels in the order search.
    rows_total = rows * max(batch, 1)
    bm, bn, bk = _stage_blocks(rows_total, n, k, block_sizes)
    dense_macs = rows * n * k
    # psum_scatter per-device ICI bytes: each device sends (P-1)/P of its
    # (rows, K_s) partial (itemsize_total folds the batch factor in).
    coll = (rows * k * itemsize_total * (shards - 1)) // shards

    if jnp.iscomplexobj(c):
        # The Pallas kernels are real-valued; DFT stages stay on einsum.
        return StagePlan(mode, n, k, rows, "einsum", dense_macs, dense_macs,
                         0.0, bm, bn, bk, axis, shards, coll)

    if shards > 1 or _is_traced(c):
        # Break-even fallback (sharded modes only): the per-shard GEMM of a
        # small serving tensor is too little work to amortize the kernel
        # dispatch + unfold padding, and the row slice rules out ESOP
        # anyway — the modeled size decides, not a hard-coded backend.
        # Off-TPU every sharded kernel stage is below break-even by
        # construction: the reference dispatch is the same matmul plus the
        # unfold's transpose copies, so einsum strictly dominates
        # (BENCH_distributed_engine D3 measured 0.82x before this existed).
        from ..kernels import ops
        below_breakeven = (shards > 1 and
                           (not ops.on_tpu()
                            or rows_total * n * k
                            < SHARDED_EINSUM_BREAKEVEN_MACS))
        backend = ("einsum" if below_breakeven
                   or min(rows_total, n, k) < MIN_KERNEL_DIM
                   else "sr_gemm")
        return StagePlan(mode, n, k, rows, backend, dense_macs, dense_macs,
                         0.0, bm, bn, bk, axis, shards, coll)

    # (bk, bn) depend only on C's shape, never on the stage order, so the
    # mask (a device pad + host sync) is shared across all six candidates.
    if mask_cache is not None and mode in mask_cache:
        mask = mask_cache[mode]
    else:
        mask = np.asarray(_padded_block_mask(c, bk, bn))
        if mask_cache is not None:
            mask_cache[mode] = mask
    zero_frac = 1.0 - float(mask.mean()) if mask.size else 0.0

    if min(rows_total, n, k) < MIN_KERNEL_DIM:
        backend = "einsum"
        eff = dense_macs
    elif zero_frac >= esop_threshold:
        backend = "esop"
        # Live blocks bound the executed MACs (block granularity on the
        # streamed C grid; rows scale both sides equally, so they stay
        # unpadded — padding them to bm would saturate the discount to
        # dense for small-row/batched stages).
        padded_c = _pad_up(n, bk) * _pad_up(k, bn)
        eff = min(dense_macs, int(rows * padded_c * float(mask.mean())))
    else:
        backend = "sr_gemm"
        eff = dense_macs
    return StagePlan(mode, n, k, rows, backend, dense_macs, eff, zero_frac,
                     bm, bn, bk)


def _plan_for_order(
    dims: tuple[int, int, int],
    cs: dict[int, jnp.ndarray],
    order: tuple[int, int, int],
    *,
    batch: int,
    itemsize: int,
    esop_threshold: float,
    block_sizes: tuple[int, int, int] | None,
    mask_cache: dict[int, np.ndarray] | None = None,
    axes: tuple[AxisName, AxisName, AxisName] = (None, None, None),
    shards: tuple[int, int, int] = (1, 1, 1),
) -> tuple[tuple[StagePlan, ...], int, int, int, int]:
    """Plan one order over the (per-shard) ``dims``; returns
    ``(stages, macs, macs_effective, peak_bytes, collective_bytes)``."""
    d = list(dims)
    stages = []
    peak_bytes = 0
    coll_bytes = 0
    for mode in order:
        rows = math.prod(d) // d[mode - 1]
        st = _plan_stage(mode, rows, cs[mode], batch=batch,
                         esop_threshold=esop_threshold,
                         block_sizes=block_sizes, mask_cache=mask_cache,
                         axis=axes[mode - 1], shards=shards[mode - 1],
                         itemsize_total=itemsize)
        stages.append(st)
        # A sharded stage materializes the full-K_s partial before the
        # scatter shrinks it to K_s/P local — that partial is the stage's
        # peak, not the post-scatter tensor.
        peak_bytes = max(peak_bytes, rows * st.k * itemsize)
        coll_bytes += st.collective_bytes
        d[mode - 1] = st.k_local
        peak_bytes = max(peak_bytes, math.prod(d) * itemsize)
    macs = sum(s.macs for s in stages)
    eff = sum(s.macs_effective for s in stages)
    return tuple(stages), macs, eff, peak_bytes, coll_bytes


def order_costs(
    dims: tuple[int, int, int],
    cs: dict[int, jnp.ndarray],
    *,
    batch: int = 1,
    itemsize: int = 4,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    mesh=None,
    axes=None,
) -> dict[tuple[int, int, int], dict]:
    """Cost-model summary for all six orders (introspection/benchmarks).

    With ``mesh``/``axes``, ``dims`` are the **global** extents; the
    summary reports per-shard MACs/bytes plus the modeled psum_scatter
    ``collective_bytes`` of each order.
    """
    out = {}
    axes = normalize_axes(axes)
    shards = tuple(mesh_axis_size(mesh, a) for a in axes)
    for mode in (1, 2, 3):
        if dims[mode - 1] % shards[mode - 1]:
            raise ValueError(
                f"mode-{mode} extent {dims[mode - 1]} not divisible by "
                f"axis {axes[mode - 1]!r} (size {shards[mode - 1]})")
    local = tuple(d // p for d, p in zip(dims, shards))
    mask_cache: dict[int, np.ndarray] = {}
    for order in itertools.permutations((1, 2, 3)):
        _, macs, eff, peak, coll = _plan_for_order(
            local, cs, order, batch=batch, itemsize=itemsize,
            esop_threshold=esop_threshold, block_sizes=block_sizes,
            mask_cache=mask_cache, axes=axes, shards=shards)
        out[order] = {"macs": macs, "macs_effective": eff,
                      "peak_intermediate_bytes": peak,
                      "collective_bytes": coll}
    return out


def fused_vmem_bytes(bu: int, bka: int, bnb: int, bna: int, kbp: int,
                     itemsize: int, accum: str = "plain") -> int:
    """Modeled VMEM footprint of the fused kernel at these tile sizes.

    Streamed operands are double-buffered by the Pallas pipeline (×2); the
    stage-a partial and the output accumulator are fp32 scratch.
    ``accum="compensated"`` adds the Neumaier comp register mirroring the
    output accumulator (engine/numerics.py) — the footprint the budget
    ladder sees, so forcing compensation can itself demote fusion depth.
    """
    comp = 4 * bu * bka * kbp if accum == "compensated" else 0
    return (2 * bu * bnb * bna * itemsize   # streamed X slab
            + 2 * bna * bka * itemsize      # streamed C_a block
            + 2 * bnb * kbp * itemsize      # resident C_b slab
            + 4 * bu * bnb * bka            # stage-a partial (f32)
            + 4 * bu * bka * kbp            # output accumulator (f32)
            + comp                          # Neumaier comp (f32, optional)
            + 2 * bu * bka * kbp * itemsize)  # output tile


def fused_tile_sizes(
    rows_total: int, na: int, ka: int, nb: int, kb: int,
    itemsize: int, vmem_budget: int = DEFAULT_VMEM_BUDGET,
    start: tuple[int, int, int] | None = None,
    accum: str = "plain",
) -> tuple[int, int, int, int, int] | None:
    """Pick ``(bu, bka, bnb, bna, kbp)`` fitting the VMEM budget, or None.

    ``start`` optionally seeds ``(bka, bna, bnb)`` (the planner aligns them
    with the staged stages' ESOP block grids so sparse skipping composes).
    Kb is not blocked (the accumulator holds the full padded slab width so
    stage b never revisits a partial), which is what bounds fusability:
    when no power-of-two shrink of the other tiles fits, the pair must run
    staged.
    """
    kbp = kb_padded(kb)
    bka0, bna0, bnb0 = start if start is not None else (None, None, None)
    tiles = {
        "bu": _pow2_clamp(rows_total),
        "bka": min(bka0 or 128, _pow2_ceil_clamp(ka)),
        # bnb only sizes the on-chip partial (total traffic is bnb-
        # independent), so it starts small
        "bnb": min(bnb0 or 32, _pow2_ceil_clamp(nb, hi=32)),
        "bna": min(bna0 or 128, _pow2_ceil_clamp(na)),
    }

    def footprint():
        return fused_vmem_bytes(tiles["bu"], tiles["bka"], tiles["bnb"],
                                tiles["bna"], kbp, itemsize, accum)

    while footprint() > vmem_budget:
        shrinkable = [k for k in ("bu", "bka", "bnb", "bna") if tiles[k] > 8]
        if not shrinkable:
            return None
        k = max(shrinkable, key=lambda k: tiles[k])
        # snap to the next power of two below (ESOP-aligned seeds may be
        # non-pow2, e.g. 48 -> 32, never 24): keeps the autotune lattice
        # and the TPU sublane/lane multiples intact, floor 8
        tiles[k] = 1 << ((tiles[k] - 1).bit_length() - 1)
    return tiles["bu"], tiles["bka"], tiles["bnb"], tiles["bna"], kbp


def fused3_vmem_bytes(bu: int, bka: int, bnb: int, bnc: int, bna: int,
                      kbp: int, kcp: int, itemsize: int,
                      accum: str = "plain") -> int:
    """Modeled VMEM footprint of the whole-transform megakernel.

    Streamed operands are double-buffered by the Pallas pipeline (×2); the
    two inter-stage partials and the output accumulator are fp32 scratch.
    The ``bu·bka·Kbp·Kcp`` accumulator term dominates and is what bounds
    triple fusability as the transform extents grow —
    ``accum="compensated"`` doubles it (the Neumaier comp register), the
    numerics lever that demotes triple → pair under a tight budget.
    """
    comp = 4 * bu * bka * kbp * kcp if accum == "compensated" else 0
    return (2 * bu * bnc * bnb * bna * itemsize  # streamed X slab
            + 2 * bna * bka * itemsize           # streamed C_a block
            + 2 * bnb * kbp * itemsize           # resident C_b slab
            + 2 * bnc * kcp * itemsize           # resident C_c slab
            + 4 * bu * bnc * bnb * bka           # stage-1 partial (f32)
            + 4 * bu * bnc * bka * kbp           # stage-2 partial (f32)
            + 4 * bu * bka * kbp * kcp           # output accumulator (f32)
            + comp                               # Neumaier comp (optional)
            + 2 * bu * bka * kbp * kcp * itemsize)  # output tile


def fused3_tile_sizes(
    rows_total: int, na: int, ka: int, nb: int, kb: int, nc: int, kc: int,
    itemsize: int, vmem_budget: int = DEFAULT_VMEM_BUDGET,
    start: tuple[int, int, int, int] | None = None,
    accum: str = "plain",
) -> tuple[int, int, int, int, int, int, int] | None:
    """Pick ``(bu, bka, bnb, bnc, bna, kbp, kcp)`` fitting the VMEM budget,
    or None.

    ``start`` optionally seeds ``(bka, bna, bnb, bnc)`` (the planner aligns
    them with the staged stages' ESOP block grids so sparse skipping
    composes).  Kb and Kc are not blocked (the partials/accumulator hold
    the full padded slab widths so stages 2–3 never revisit a partial);
    shrinking ``bka`` is the pressure valve, at the cost of one extra X
    re-stream per ka-block — the HBM model, not this function, judges
    whether that trade still beats the pair kernel.
    """
    kbp, kcp = kb_padded(kb), kb_padded(kc)
    bka0, bna0, bnb0, bnc0 = start if start is not None else (None,) * 4
    tiles = {
        "bu": _pow2_clamp(rows_total),
        "bka": min(bka0 or 128, _pow2_ceil_clamp(ka)),
        # bnb/bnc only size the on-chip partials (total traffic is
        # independent of both), so they start small
        "bnb": min(bnb0 or 16, _pow2_ceil_clamp(nb, hi=16)),
        "bnc": min(bnc0 or 16, _pow2_ceil_clamp(nc, hi=16)),
        "bna": min(bna0 or 128, _pow2_ceil_clamp(na)),
    }

    def footprint():
        return fused3_vmem_bytes(tiles["bu"], tiles["bka"], tiles["bnb"],
                                 tiles["bnc"], tiles["bna"], kbp, kcp,
                                 itemsize, accum)

    while footprint() > vmem_budget:
        shrinkable = [k for k in ("bu", "bka", "bnb", "bnc", "bna")
                      if tiles[k] > 8]
        if not shrinkable:
            return None
        k = max(shrinkable, key=lambda k: tiles[k])
        tiles[k] = 1 << ((tiles[k] - 1).bit_length() - 1)
    return (tiles["bu"], tiles["bka"], tiles["bnb"], tiles["bnc"],
            tiles["bna"], kbp, kcp)


def _fused3_hbm_bytes(rows_total: int, ka: int,
                      tiles: tuple[int, int, int, int, int, int, int],
                      live_a: int, live_b: int, live_c: int,
                      itemsize: int) -> int:
    """Modeled HBM traffic of the megakernel (dense grid × live blocks).

    X and C_a are fetched once per live ``(j, t_c, t_b, t_a)`` step and
    u-block; C_b once per live slab and (i, j, t_c); C_c once per live
    slab and (i, j); both intermediates move zero bytes.  The only revisit
    factor is ``Ka/bka`` on X — the price of blocking one output mode so
    the accumulator fits VMEM.
    """
    bu, bka, bnb, bnc, bna, kbp, kcp = tiles
    u_p = _pad_up(rows_total, bu)
    ka_p = _pad_up(ka, bka)
    t_b = max(live_b, 1)
    t_c = max(live_c, 1)
    x_bytes = u_p * bnc * bnb * bna * live_a * t_b * t_c
    ca_bytes = (u_p // bu) * t_c * t_b * live_a * bna * bka
    cb_bytes = (u_p // bu) * (ka_p // bka) * t_c * t_b * bnb * kbp
    cc_bytes = (u_p // bu) * (ka_p // bka) * t_c * bnc * kcp
    y_bytes = u_p * ka_p * kbp * kcp
    return (x_bytes + ca_bytes + cb_bytes + cc_bytes + y_bytes) * itemsize


def stage_hbm_bytes(stage: StagePlan, batch: int, itemsize: int) -> int:
    """Modeled HBM traffic of one staged contraction.

    Kernel stages refetch X once per output column-block and C once per
    output row-block (the BlockSpec revisit factors); only ESOP stages
    skip zero C blocks — SR-GEMM streams every block regardless of the
    zero fraction.  The einsum fallback is modeled as a fully fused single
    pass.  ``itemsize`` is the raw element size (batch is folded into the
    rows here, unlike the planner's peak-bytes accounting).
    """
    rows = stage.rows * max(batch, 1)
    n, k = stage.n, stage.k
    if stage.backend == "einsum":
        return (rows * n + n * k + rows * k) * itemsize
    live = 1.0 - stage.zero_block_frac if stage.backend == "esop" else 1.0
    # ESOP skips the X fetch on dead steps too (the dead-step index repeats
    # the last live block, so the revisit is elided), hence both scale.
    x_bytes = int(rows * n * _ceil_div(k, stage.bn) * live)
    c_bytes = int(n * k * live) * _ceil_div(rows, stage.bm)
    return (x_bytes + c_bytes + rows * k) * itemsize


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def staged_pair_hbm_bytes(stage_a: StagePlan, stage_b: StagePlan,
                          batch: int, itemsize: int) -> int:
    """Modeled HBM traffic of running a consecutive pair staged.

    The inter-stage boundary costs a full read+write of the intermediate:
    the fold of one unfolding into the next is a ``moveaxis``+``reshape``
    transpose copy materialized between the two kernel launches.
    """
    t_elems = stage_a.rows * max(batch, 1) * stage_a.k
    return (stage_hbm_bytes(stage_a, batch, itemsize)
            + 2 * t_elems * itemsize
            + stage_hbm_bytes(stage_b, batch, itemsize))


def _fused_hbm_bytes(rows_total: int, ka: int,
                     tiles: tuple[int, int, int, int, int],
                     live_a: int, live_b: int, itemsize: int) -> int:
    """Modeled HBM traffic of the fused kernel (dense grid × live blocks).

    X and C_a are fetched once per live ``(j, t_b, t_a)`` step and u-block;
    C_b once per live slab and (i, j); the intermediate moves zero bytes.
    """
    bu, bka, bnb, bna, kbp = tiles
    u_p = _pad_up(rows_total, bu)
    ka_p = _pad_up(ka, bka)
    t_b = max(live_b, 1)
    x_bytes = u_p * bnb * bna * live_a * t_b
    ca_bytes = (u_p // bu) * t_b * live_a * bna * bka
    cb_bytes = (u_p // bu) * (ka_p // bka) * t_b * bnb * kbp
    y_bytes = u_p * ka_p * kbp
    return (x_bytes + ca_bytes + cb_bytes + y_bytes) * itemsize


def plan_hbm_bytes(stages: tuple[StagePlan, ...],
                   fused: FusedPairPlan | None,
                   batch: int, itemsize: int,
                   fused3: FusedTriplePlan | None = None) -> int:
    """Modeled HBM bytes of executing the schedule (with optional fusion).

    Every boundary between executed steps adds the intermediate's transpose
    copy; the fused pair replaces its two stages *and* their internal
    boundary with the fused kernel's traffic.  A ``fused3`` triple covers
    the whole schedule — its modeled traffic *is* the plan's.  Under a
    mesh the stage fields are per-shard, so the total is the per-device
    local HBM traffic (a sharded stage's boundary intermediate is its
    *post-scatter* ``k_local`` extent; the scatter's ICI bytes live in
    ``collective_bytes``, not here).
    """
    if fused3 is not None:
        return fused3.hbm_bytes_fused
    b = max(batch, 1)
    total = 0
    i = 0
    while i < len(stages):
        if fused is not None and i == fused.first:
            total += fused.hbm_bytes_fused
            nxt = i + 2
        else:
            total += stage_hbm_bytes(stages[i], batch, itemsize)
            nxt = i + 1
        if nxt < len(stages):
            total += (2 * stages[nxt - 1].rows * b
                      * stages[nxt - 1].k_local * itemsize)
        i = nxt
    return total


def refresh_fused_pair(fp: FusedPairPlan, ca: jnp.ndarray, cb: jnp.ndarray,
                       batch: int, itemsize: int) -> FusedPairPlan:
    """Recompute a FusedPairPlan's modeled accounting for its current tiles.

    The autotuner replaces (bu, bka, bnb) after planning; the VMEM
    footprint, fused HBM bytes and block masks must follow, or the
    reported numbers describe a configuration that never ran.
    """
    rows_total = fp.rows * max(batch, 1)
    mask_a = np.asarray(_padded_block_mask(ca, fp.bna, fp.bka))
    mask_b = np.asarray(_padded_block_mask(cb, fp.bnb, fp.kbp))
    live_a, dense_a = int(mask_a.sum()), max(mask_a.size, 1)
    live_b, dense_b = int(mask_b.sum()), max(mask_b.size, 1)
    tiles = (fp.bu, fp.bka, fp.bnb, fp.bna, fp.kbp)
    return dataclasses.replace(
        fp,
        vmem_bytes=fused_vmem_bytes(*tiles, itemsize, fp.accum),
        hbm_bytes_fused=_fused_hbm_bytes(rows_total, fp.ka, tiles, live_a,
                                         live_b, itemsize),
        zero_block_frac_a=1.0 - live_a / dense_a,
        zero_block_frac_b=1.0 - live_b / dense_b,
    )


def refresh_fused_triple(ft: FusedTriplePlan, ca: jnp.ndarray,
                         cb: jnp.ndarray, cc: jnp.ndarray,
                         batch: int, itemsize: int) -> FusedTriplePlan:
    """Recompute a FusedTriplePlan's modeled accounting for its current tiles.

    The autotuner replaces (bu, bka, bnb, bnc) after planning; the VMEM
    footprint, fused HBM bytes and block masks must follow, or the
    reported numbers describe a configuration that never ran.
    """
    rows_total = ft.rows * max(batch, 1)
    mask_a = np.asarray(_padded_block_mask(ca, ft.bna, ft.bka))
    mask_b = np.asarray(_padded_block_mask(cb, ft.bnb, ft.kbp))
    mask_c = np.asarray(_padded_block_mask(cc, ft.bnc, ft.kcp))
    live_a, dense_a = int(mask_a.sum()), max(mask_a.size, 1)
    live_b, dense_b = int(mask_b.sum()), max(mask_b.size, 1)
    live_c, dense_c = int(mask_c.sum()), max(mask_c.size, 1)
    tiles = (ft.bu, ft.bka, ft.bnb, ft.bnc, ft.bna, ft.kbp, ft.kcp)
    return dataclasses.replace(
        ft,
        vmem_bytes=fused3_vmem_bytes(*tiles, itemsize, ft.accum),
        hbm_bytes_fused=_fused3_hbm_bytes(rows_total, ft.ka, tiles, live_a,
                                          live_b, live_c, itemsize),
        zero_block_frac_a=1.0 - live_a / dense_a,
        zero_block_frac_b=1.0 - live_b / dense_b,
        zero_block_frac_c=1.0 - live_c / dense_c,
    )


def _plan_fusion3(
    order: tuple[int, int, int],
    stages: tuple[StagePlan, ...],
    cs: dict[int, jnp.ndarray],
    *,
    batch: int,
    itemsize: int,
    vmem_budget: int,
    force: bool,
    axes: tuple[AxisName, AxisName, AxisName] = (None, None, None),
    events: list | None = None,
    accum: str = "plain",
) -> FusedTriplePlan | None:
    """Evaluate fusing the whole three-stage transform into the megakernel.

    All six (a, b, c) mode assignments are scored — the a-stream carries
    full 2D ESOP skipping while b/c get slab-level skipping only, so a
    block-sparse coefficient matrix wants the a slot — and the one moving
    the fewest modeled HBM bytes (MACs break ties) wins.  Returns the
    candidate when it is kernel-capable, fits the VMEM budget and (unless
    ``force``) moves strictly fewer modeled bytes than the all-staged
    schedule; None declines and the planner degrades to pair fusion.

    **Fusion-under-sharding rule**: every mode must be shard-local — the
    megakernel has no collective anywhere inside, and a sharded mode's
    contraction needs its psum_scatter between stages.  A sharded *batch*
    axis is fine (the rows just split).  Traced coefficients and complex
    dtypes decline as for the pair.  ``rows_total`` (= the local batch) is
    exempt from the MIN_KERNEL_DIM floor: the u-padding cost is already in
    the byte model, which decides honestly.
    """
    if any(a is not None for a in axes):
        return None  # a sharded mode needs its collective between stages
    if _is_traced(*cs.values()):
        return None
    if any(jnp.iscomplexobj(c) for c in cs.values()):
        return None  # DFT stages stay on einsum — the kernel is real-valued
    rows_total = max(batch, 1)
    stage_of = {s.mode: s for s in stages}
    staged = plan_hbm_bytes(stages, None, batch, itemsize)

    best = None
    vmem_floors = []  # minimal-tile footprints of VMEM-declined candidates
    for mode_a, mode_b, mode_c in itertools.permutations((1, 2, 3)):
        ca, cb, cc = cs[mode_a], cs[mode_b], cs[mode_c]
        na, ka = ca.shape
        nb, kb = cb.shape
        nc, kc = cc.shape
        if min(na, ka, nb, kb, nc, kc) < MIN_KERNEL_DIM:
            continue  # padding overhead beats the kernel
        st_a = stage_of[mode_a]
        tiles = fused3_tile_sizes(
            rows_total, na, ka, nb, kb, nc, kc, itemsize, vmem_budget,
            start=(st_a.bn if st_a.zero_block_frac > 0 else None,
                   st_a.bk if st_a.zero_block_frac > 0 else None,
                   None, None),
            accum=accum)
        if tiles is None:
            # no tiling keeps both partials on-chip: record the footprint
            # at the floor tiles (8 everywhere) — the smallest this
            # assignment could ever need vs what the budget allows
            vmem_floors.append(fused3_vmem_bytes(
                8, 8, 8, 8, 8, kb_padded(kb), kb_padded(kc), itemsize,
                accum))
            continue
        bu, bka, bnb, bnc, bna, kbp, kcp = tiles
        mask_a = np.asarray(_padded_block_mask(ca, bna, bka))
        mask_b = np.asarray(_padded_block_mask(cb, bnb, kbp))
        mask_c = np.asarray(_padded_block_mask(cc, bnc, kcp))
        live_a, dense_a = int(mask_a.sum()), max(mask_a.size, 1)
        live_b, dense_b = int(mask_b.sum()), max(mask_b.size, 1)
        live_c, dense_c = int(mask_c.sum()), max(mask_c.size, 1)
        fused = _fused3_hbm_bytes(rows_total, ka, tiles, live_a, live_b,
                                  live_c, itemsize)
        macs = nc * nb * na * ka + nc * ka * nb * kb + ka * kb * nc * kc
        cand = FusedTriplePlan(
            mode_a=mode_a, mode_b=mode_b, mode_c=mode_c, rows=1,
            na=na, ka=ka, nb=nb, kb=kb, nc=nc, kc=kc,
            bu=bu, bka=bka, bnb=bnb, bnc=bnc, bna=bna, kbp=kbp, kcp=kcp,
            vmem_bytes=fused3_vmem_bytes(*tiles, itemsize, accum),
            hbm_bytes_staged=staged, hbm_bytes_fused=fused, macs=macs,
            zero_block_frac_a=1.0 - live_a / dense_a,
            zero_block_frac_b=1.0 - live_b / dense_b,
            zero_block_frac_c=1.0 - live_c / dense_c,
            accum=accum,
        )
        if best is None or ((cand.hbm_bytes_fused, cand.macs)
                            < (best.hbm_bytes_fused, best.macs)):
            best = cand
    if best is None:
        if events is not None and vmem_floors:
            events.append({
                "kind": "fusion_degradation", "from": "triple",
                "reason": "vmem_budget",
                "vmem_bytes_min": min(vmem_floors),
                "vmem_budget": vmem_budget,
            })
        return None
    if not force and best.hbm_bytes_fused >= staged:
        if events is not None:
            events.append({
                "kind": "fusion_degradation", "from": "triple",
                "reason": "byte_model",
                "hbm_bytes_fused": best.hbm_bytes_fused,
                "hbm_bytes_staged": staged,
                "vmem_bytes": best.vmem_bytes,
                "vmem_budget": vmem_budget,
            })
        return None
    return best


def _plan_fusion(
    first: int,
    order: tuple[int, int, int],
    stages: tuple[StagePlan, ...],
    dims: tuple[int, int, int],
    cs: dict[int, jnp.ndarray],
    *,
    batch: int,
    itemsize: int,
    vmem_budget: int,
    force: bool,
    axes: tuple[AxisName, AxisName, AxisName] = (None, None, None),
    shards: tuple[int, int, int] = (1, 1, 1),
    events: list | None = None,
    accum: str = "plain",
) -> FusedPairPlan | None:
    """Evaluate fusing the consecutive pair starting at stage ``first``.

    The kernel is algebraically symmetric in which mode streams as C_a
    (2D-blocked, full ESOP skipping) vs C_b (slab-resident, slab-level
    skipping only), so both assignments are scored and the one moving
    fewer modeled bytes wins — a block-sparse coefficient matrix lands on
    the a-stream where its zero blocks are never fetched.  Returns the
    candidate when it is kernel-capable, fits the VMEM budget and (unless
    ``force``) moves strictly fewer modeled HBM bytes than the staged
    pair; None declines.

    **Fusion-under-sharding rule**: both modes of the pair must be
    shard-local (``axes[m-1] is None``).  A sharded mode's contraction
    needs a psum_scatter between the two stages, and the fused kernel has
    no collective inside — fusing across it would silently drop the
    cross-device partial sums.  Traced coefficients also decline (the
    fused kernel's ESOP prefetch schedules need host-readable values).
    """
    pair = (order[first], order[first + 1])
    if any(axes[m - 1] is not None for m in pair):
        return None  # sharded mode: a collective must run between stages
    if _is_traced(*(cs[m] for m in pair)):
        return None
    if any(jnp.iscomplexobj(cs[m]) for m in pair):
        return None  # DFT stages stay on einsum — the kernel is real-valued
    d = list(dims)
    for m in order[:first]:
        d[m - 1] = cs[m].shape[1] // shards[m - 1]
    rows = math.prod(d) // (d[pair[0] - 1] * d[pair[1] - 1])
    rows_total = rows * max(batch, 1)
    stage_of = {stages[first].mode: stages[first],
                stages[first + 1].mode: stages[first + 1]}
    staged = staged_pair_hbm_bytes(stages[first], stages[first + 1], batch,
                                   itemsize)

    best = None
    vmem_floors = []  # minimal-tile footprints of VMEM-declined candidates
    for mode_a, mode_b in (pair, pair[::-1]):
        ca, cb = cs[mode_a], cs[mode_b]
        na, ka = ca.shape
        nb, kb = cb.shape
        if min(rows_total, na, ka, nb, kb) < MIN_KERNEL_DIM:
            continue  # padding overhead beats the kernel, as for single stages
        # For *sparse* coefficients, seed the streamed-side grid from the
        # staged stage's ESOP blocks so the fused mask sees the same zero
        # structure the planner scored; dense stages take the pow2-ceil
        # defaults (one padded block per visit, no extra revisit factor).
        st_a, st_b = stage_of[mode_a], stage_of[mode_b]
        sparse_a = st_a.zero_block_frac > 0
        tiles = fused_tile_sizes(
            rows_total, na, ka, nb, kb, itemsize, vmem_budget,
            start=(st_a.bn if sparse_a else None,
                   st_a.bk if sparse_a else None,
                   st_b.bk if st_b.zero_block_frac > 0 else None),
            accum=accum)
        if tiles is None:
            # no tiling keeps the resident slab on-chip: record the floor
            # footprint (8-everywhere tiles) vs the budget
            vmem_floors.append(
                fused_vmem_bytes(8, 8, 8, 8, kb_padded(kb), itemsize, accum))
            continue
        bu, bka, bnb, bna, kbp = tiles
        mask_a = np.asarray(_padded_block_mask(ca, bna, bka))
        mask_b = np.asarray(_padded_block_mask(cb, bnb, kbp))
        live_a, dense_a = int(mask_a.sum()), max(mask_a.size, 1)
        live_b, dense_b = int(mask_b.sum()), max(mask_b.size, 1)
        fused = _fused_hbm_bytes(rows_total, ka, tiles, live_a, live_b,
                                 itemsize)
        cand = FusedPairPlan(
            first=first, mode_a=mode_a, mode_b=mode_b, rows=rows,
            na=na, ka=ka, nb=nb, kb=kb,
            bu=bu, bka=bka, bnb=bnb, bna=bna, kbp=kbp,
            vmem_bytes=fused_vmem_bytes(bu, bka, bnb, bna, kbp, itemsize,
                                        accum),
            hbm_bytes_staged=staged, hbm_bytes_fused=fused,
            macs=rows * (nb * na * ka + nb * ka * kb),
            zero_block_frac_a=1.0 - live_a / dense_a,
            zero_block_frac_b=1.0 - live_b / dense_b,
            accum=accum,
        )
        if best is None or cand.hbm_bytes_fused < best.hbm_bytes_fused:
            best = cand
    if best is None:
        if events is not None and vmem_floors:
            events.append({
                "kind": "fusion_degradation", "from": "pair",
                "reason": "vmem_budget", "first": first,
                "vmem_bytes_min": min(vmem_floors),
                "vmem_budget": vmem_budget,
            })
        return None
    if not force and best.hbm_bytes_fused >= staged:
        if events is not None:
            events.append({
                "kind": "fusion_degradation", "from": "pair",
                "reason": "byte_model", "first": first,
                "hbm_bytes_fused": best.hbm_bytes_fused,
                "hbm_bytes_staged": staged,
                "vmem_bytes": best.vmem_bytes,
                "vmem_budget": vmem_budget,
            })
        return None
    return best


def derive_adjoint_plan(
    plan: GemtPlan,
    g_shape: tuple[int, ...],
    g_dtype,
    c1t: jnp.ndarray,
    c2t: jnp.ndarray,
    c3t: jnp.ndarray,
    *,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | str | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    mesh=None,
) -> GemtPlan:
    """Plan the backward 3D-GEMT of ``plan`` — the X-cotangent problem.

    The VJP of ``Y = X ×₁C1 ×₂C2 ×₃C3`` with respect to X is itself a
    three-stage GEMT over the transposed coefficient matrices,
    ``dX = g ×₁C1ᵀ ×₂C2ᵀ ×₃C3ᵀ`` (for the paper's orthonormal transforms,
    §2.2, ``Cᵀ = C⁻¹`` — the backward pass *is* the inverse transform) —
    so it re-enters the same planner, fusion tiers, ESOP schedules and
    autotune caches as any forward problem.

    The stage order is **pinned to the reverse of the forward order**, not
    searched: the adjoint chain's intermediates ``g_i`` (cotangents of the
    forward stage boundaries) are exactly what the three coefficient
    cotangents contract against, and only the reversed order produces
    them.  It is also the cost-symmetric choice — compressive forward
    modes (planned early) become expansive adjoint modes (planned late).

    Topology: the adjoint inherits the forward plan's ``axes`` and
    ``batch_axis`` verbatim — the cotangent carries the output's sharding,
    which equals the input's (the stationary-tensor invariant), and the
    forward divisibility checks (N_s *and* K_s divide the axis) already
    guarantee the adjoint's.  The derived plan's key is the forward key
    plus an ``|adjoint`` tag, so forward and backward programs share the
    plan cache without colliding.
    """
    adj = build_plan(
        g_shape, g_dtype, c1t, c2t, c3t, order=plan.order[::-1],
        esop_threshold=esop_threshold, block_sizes=block_sizes, fuse=fuse,
        vmem_budget=vmem_budget, mesh=mesh,
        axes=plan.axes if mesh is not None else None,
        batch_axis=plan.batch_axis if mesh is not None else None,
        accum=plan.accum, error_budget=plan.error_budget)
    return dataclasses.replace(adj, key=plan.key + "|adjoint")


def chain_vmem_bytes(bu: int, bka: int, bnb: int, bna: int, kbp: int,
                     itemsize: int, accum: str = "plain") -> int:
    """Modeled VMEM footprint of the chain-pair kernel at these tiles.

    The fused-pair footprint plus the double-buffered ``y1`` output tile:
    emitting the intermediate costs one extra ``(bu, bnb, bka)`` output
    window, nothing else — the partial it is copied from already exists.
    """
    return (fused_vmem_bytes(bu, bka, bnb, bna, kbp, itemsize, accum)
            + 2 * bu * bnb * bka * itemsize)


def chain_tile_sizes(
    rows_total: int, na: int, ka: int, nb: int, kb: int,
    itemsize: int, vmem_budget: int = DEFAULT_VMEM_BUDGET,
    accum: str = "plain",
) -> tuple[int, int, int, int, int] | None:
    """Pick ``(bu, bka, bnb, bna, kbp)`` for the chain-pair kernel, or None.

    Same shrink ladder as :func:`fused_tile_sizes` under the chain
    footprint (:func:`chain_vmem_bytes`).  No ESOP seeds: the chain's b
    stream is dense by construction (every emitted ``y1`` block must be
    written), so only the a-side compaction applies and the default
    lattice is the right one.
    """
    kbp = kb_padded(kb)
    tiles = {
        "bu": _pow2_clamp(rows_total),
        "bka": _pow2_ceil_clamp(ka),
        "bnb": _pow2_ceil_clamp(nb, hi=32),
        "bna": _pow2_ceil_clamp(na),
    }

    def footprint():
        return chain_vmem_bytes(tiles["bu"], tiles["bka"], tiles["bnb"],
                                tiles["bna"], kbp, itemsize, accum)

    while footprint() > vmem_budget:
        shrinkable = [k for k in ("bu", "bka", "bnb", "bna") if tiles[k] > 8]
        if not shrinkable:
            return None
        k = max(shrinkable, key=lambda k: tiles[k])
        tiles[k] = 1 << ((tiles[k] - 1).bit_length() - 1)
    return tiles["bu"], tiles["bka"], tiles["bnb"], tiles["bna"], kbp


def chain3_vmem_bytes(bu: int, bka: int, bnb: int, bnc: int, bna: int,
                      kbp: int, kcp: int, itemsize: int,
                      accum: str = "plain") -> int:
    """Modeled VMEM footprint of the chain-triple kernel at these tiles.

    The megakernel footprint plus the double-buffered ``y1`` and ``y2``
    output tiles — the price of emitting both intermediates, and what
    makes the chain triple degrade to the pair earlier than the forward
    triple does (the documented N=64 boundary).
    """
    return (fused3_vmem_bytes(bu, bka, bnb, bnc, bna, kbp, kcp, itemsize,
                              accum)
            + 2 * bu * bnc * bnb * bka * itemsize
            + 2 * bu * bnc * bka * kbp * itemsize)


def chain3_tile_sizes(
    rows_total: int, na: int, ka: int, nb: int, kb: int, nc: int, kc: int,
    itemsize: int, vmem_budget: int = DEFAULT_VMEM_BUDGET,
    accum: str = "plain",
) -> tuple[int, int, int, int, int, int, int] | None:
    """Pick ``(bu, bka, bnb, bnc, bna, kbp, kcp)`` for the chain triple,
    or None — the :func:`fused3_tile_sizes` ladder under the chain
    footprint (:func:`chain3_vmem_bytes`)."""
    kbp, kcp = kb_padded(kb), kb_padded(kc)
    tiles = {
        "bu": _pow2_clamp(rows_total),
        "bka": _pow2_ceil_clamp(ka),
        "bnb": _pow2_ceil_clamp(nb, hi=16),
        "bnc": _pow2_ceil_clamp(nc, hi=16),
        "bna": _pow2_ceil_clamp(na),
    }

    def footprint():
        return chain3_vmem_bytes(tiles["bu"], tiles["bka"], tiles["bnb"],
                                 tiles["bnc"], tiles["bna"], kbp, kcp,
                                 itemsize, accum)

    while footprint() > vmem_budget:
        shrinkable = [k for k in ("bu", "bka", "bnb", "bnc", "bna")
                      if tiles[k] > 8]
        if not shrinkable:
            return None
        k = max(shrinkable, key=lambda k: tiles[k])
        tiles[k] = 1 << ((tiles[k] - 1).bit_length() - 1)
    return (tiles["bu"], tiles["bka"], tiles["bnb"], tiles["bnc"],
            tiles["bna"], kbp, kcp)


def _chain_hbm_bytes(rows_total: int, ka: int, nb: int,
                     tiles: tuple[int, int, int, int, int],
                     live_a: int, itemsize: int) -> int:
    """Modeled HBM traffic of the chain-pair kernel.

    The fused-pair traffic at a **dense** b stream (every slab is live —
    the emitted intermediate forbids slab skipping) plus the single write
    of ``y1``: the intermediate crosses HBM once as a result, against the
    staged pair's write+transpose-read round-trip.
    """
    bu, bka, bnb, bna, kbp = tiles
    t_b = _pad_up(nb, bnb) // bnb
    u_p = _pad_up(rows_total, bu)
    ka_p = _pad_up(ka, bka)
    y1_bytes = u_p * t_b * bnb * ka_p * itemsize
    return (_fused_hbm_bytes(rows_total, ka, tiles, live_a, t_b, itemsize)
            + y1_bytes)


def _chain3_hbm_bytes(rows_total: int, ka: int, nb: int, nc: int,
                      tiles: tuple[int, int, int, int, int, int, int],
                      live_a: int, itemsize: int) -> int:
    """Modeled HBM traffic of the chain-triple kernel: megakernel traffic
    at dense b/c streams plus the single writes of ``y1`` and ``y2``."""
    bu, bka, bnb, bnc, bna, kbp, kcp = tiles
    t_b = _pad_up(nb, bnb) // bnb
    t_c = _pad_up(nc, bnc) // bnc
    u_p = _pad_up(rows_total, bu)
    ka_p = _pad_up(ka, bka)
    y1_bytes = u_p * t_c * bnc * t_b * bnb * ka_p
    y2_bytes = u_p * t_c * bnc * ka_p * kbp
    return (_fused3_hbm_bytes(rows_total, ka, tiles, live_a, t_b, t_c,
                              itemsize)
            + (y1_bytes + y2_bytes) * itemsize)


@dataclasses.dataclass(frozen=True)
class AdjointChainPlan:
    """The backward walk's fusion schedule, derived from a forward plan and
    its adjoint plan (``plan_adjoint_chain``).

    ``depth`` is how many of the three adjoint stages run inside one chain
    launch: 3 (chain triple — ``dX`` plus both cotangent intermediates in
    one ``pallas_call``), 2 (chain pair plus one staged tail stage), or 0
    (the walk stays on the legacy staged schedule).  ``rec_fused`` says
    whether the forward-prefix recompute (``y1``, ``y2``) runs as one
    chain-pair launch instead of two staged ones.  ``launches`` is the
    predicted backward kernel-launch count including the batched
    coefficient-cotangent launch — the number the G1 bench gates.
    """

    depth: int  # 3 | 2 fused adjoint stages, 0 = staged backward walk
    rec_fused: bool  # recompute prefix fused into one chain-pair launch
    launches: int  # predicted backward launches (recompute + chain + coeff)
    modes: tuple  # adjoint stage order (= forward order reversed)
    rec_modes: tuple  # recompute chain modes (forward order[:2])
    tiles: tuple | None  # chain kernel tiles (None when depth == 0)
    rec_tiles: tuple | None  # recompute chain-pair tiles
    vmem_bytes: int  # chain kernel footprint at those tiles
    rec_vmem_bytes: int
    hbm_bytes_staged: int  # adjoint plan's modeled all-staged traffic
    hbm_bytes_fused: int  # modeled chain traffic (+ staged tail at depth 2)
    events: tuple = ()  # adjoint_fusion_degradation records


def plan_adjoint_chain(
    plan: GemtPlan,
    adj: GemtPlan,
    g_shape: tuple[int, ...],
    g_dtype,
    *,
    fuse: bool | str | None = None,
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
) -> AdjointChainPlan:
    """Extend the pair/triple fusion decision to the backward walk.

    Scores fusing the adjoint chain ``dX = g ×C₃ᵀ ×C₂ᵀ ×C₁ᵀ`` into one
    chain-triple launch (emitting the cotangent intermediates ``g1, g2``
    for the coefficient cotangents) or a chain-pair launch plus a staged
    tail, and fusing the forward-prefix recompute ``y1, y2`` into one
    chain-pair launch.  The same VMEM ladder and HBM byte model as the
    forward fusion tiers decide (``_chain_hbm_bytes`` vs the adjoint
    plan's staged traffic), honoring the ``fuse`` knob (``False`` pins the
    legacy staged walk; ``"pair"``/``"triple"``/``True`` force tiers).

    The chain's mode assignment is **pinned to the adjoint stage order**
    (not permutation-searched like the forward triple): the emitted
    intermediates must be the stage-boundary cotangents, and only the
    stage-order assignment produces them.  Sharded plans decline — the
    chain has no collective inside, and each sharded adjoint stage needs
    its psum_scatter (the sharded walk keeps its one-program schedule).
    Einsum-pinned adjoint stages (complex DFT factors, tiny extents)
    decline too: the planner already judged those modes kernel-hostile.
    """
    itemsize = jnp.dtype(g_dtype).itemsize
    batch = g_shape[0] if len(g_shape) == 4 else 1
    rows_total = max(batch, 1)
    events: list = []
    modes = tuple(adj.order)
    rec_modes = (plan.order[0], plan.order[1])
    # Chain footprints inherit the plans' accumulation modes: the comp
    # scratch of a compensated walk is real VMEM the ladder must budget.
    accum = adj.accum
    rec_accum = plan.accum
    sharded = (any(a is not None for a in plan.axes)
               or plan.batch_axis is not None)

    def declined(reason_events=()):
        return AdjointChainPlan(
            depth=0, rec_fused=False, launches=2 + 3 + 3, modes=modes,
            rec_modes=rec_modes, tiles=None, rec_tiles=None, vmem_bytes=0,
            rec_vmem_bytes=0, hbm_bytes_staged=adj.hbm_bytes_staged,
            hbm_bytes_fused=0, events=tuple(reason_events))

    if fuse is False or sharded:
        return declined()
    a0, a1, a2 = adj.stages
    if a0.backend == "einsum" or a1.backend == "einsum":
        return declined()

    # Recompute-prefix feasibility is independent of the adjoint depth.
    s0, s1 = plan.stages[0], plan.stages[1]
    rec_rows = rows_total * plan.stages[2].n
    rec_fused, rec_tiles, rec_vmem = False, None, 0
    if (s0.backend != "einsum" and s1.backend != "einsum"
            and min(rec_rows, s0.n, s0.k, s1.n, s1.k) >= MIN_KERNEL_DIM):
        rt = chain_tile_sizes(rec_rows, s0.n, s0.k, s1.n, s1.k, itemsize,
                              vmem_budget, accum=rec_accum)
        if rt is not None:
            # One launch, no inter-stage round-trip: always fewer bytes
            # than the staged recompute pair — no byte compare needed.
            rec_fused, rec_tiles = True, rt
            rec_vmem = chain_vmem_bytes(*rt, itemsize, rec_accum)

    def live_a_blocks(stage, bna, bka):
        dense = ((_pad_up(stage.n, bna) // bna)
                 * (_pad_up(stage.k, bka) // bka))
        return max(1, round(dense * (1.0 - stage.zero_block_frac)))

    # Depth 3: the whole adjoint chain in one chain-triple launch.
    if (fuse in (None, True, "triple") and a2.backend != "einsum"
            and min(a0.n, a0.k, a1.n, a1.k, a2.n, a2.k) >= MIN_KERNEL_DIM):
        t3 = chain3_tile_sizes(rows_total, a0.n, a0.k, a1.n, a1.k,
                               a2.n, a2.k, itemsize, vmem_budget,
                               accum=accum)
        if t3 is None:
            events.append({
                "kind": "adjoint_fusion_degradation", "from": "triple",
                "reason": "vmem_budget",
                "vmem_bytes_min": chain3_vmem_bytes(
                    8, 8, 8, 8, 8, kb_padded(a1.k), kb_padded(a2.k),
                    itemsize, accum),
                "vmem_budget": vmem_budget,
            })
        else:
            fused_bytes = _chain3_hbm_bytes(
                rows_total, a0.k, a1.n, a2.n, t3,
                live_a_blocks(a0, t3[4], t3[1]), itemsize)
            if fuse in (True, "triple") or fused_bytes < adj.hbm_bytes_staged:
                return AdjointChainPlan(
                    depth=3, rec_fused=rec_fused,
                    launches=(1 if rec_fused else 2) + 1 + 1,
                    modes=modes, rec_modes=rec_modes, tiles=t3,
                    rec_tiles=rec_tiles,
                    vmem_bytes=chain3_vmem_bytes(*t3, itemsize, accum),
                    rec_vmem_bytes=rec_vmem,
                    hbm_bytes_staged=adj.hbm_bytes_staged,
                    hbm_bytes_fused=fused_bytes, events=tuple(events))
            events.append({
                "kind": "adjoint_fusion_degradation", "from": "triple",
                "reason": "byte_model", "hbm_bytes_fused": fused_bytes,
                "hbm_bytes_staged": adj.hbm_bytes_staged,
                "vmem_budget": vmem_budget,
            })
    if fuse == "triple":
        return declined(events)

    # Depth 2: chain pair over the first two adjoint stages + staged tail.
    rows2 = rows_total * a2.n
    if min(rows2, a0.n, a0.k, a1.n, a1.k) >= MIN_KERNEL_DIM:
        t2 = chain_tile_sizes(rows2, a0.n, a0.k, a1.n, a1.k, itemsize,
                              vmem_budget, accum=accum)
        if t2 is None:
            events.append({
                "kind": "adjoint_fusion_degradation", "from": "pair",
                "reason": "vmem_budget",
                "vmem_bytes_min": chain_vmem_bytes(
                    8, 8, 8, 8, kb_padded(a1.k), itemsize, accum),
                "vmem_budget": vmem_budget,
            })
            return declined(events)
        fused_bytes = (_chain_hbm_bytes(rows2, a0.k, a1.n, t2,
                                        live_a_blocks(a0, t2[3], t2[1]),
                                        itemsize)
                       + stage_hbm_bytes(a2, batch, itemsize))
        if fuse in (True, "pair") or fused_bytes < adj.hbm_bytes_staged:
            return AdjointChainPlan(
                depth=2, rec_fused=rec_fused,
                launches=(1 if rec_fused else 2) + 2 + 1,
                modes=modes, rec_modes=rec_modes, tiles=t2,
                rec_tiles=rec_tiles,
                vmem_bytes=chain_vmem_bytes(*t2, itemsize, accum),
                rec_vmem_bytes=rec_vmem,
                hbm_bytes_staged=adj.hbm_bytes_staged,
                hbm_bytes_fused=fused_bytes, events=tuple(events))
        events.append({
            "kind": "adjoint_fusion_degradation", "from": "pair",
            "reason": "byte_model", "hbm_bytes_fused": fused_bytes,
            "hbm_bytes_staged": adj.hbm_bytes_staged,
            "vmem_budget": vmem_budget,
        })
    return declined(events)


def build_plan(
    x_shape: tuple[int, ...],
    x_dtype,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    order: tuple[int, int, int] | None = None,
    esop_threshold: float = DEFAULT_ESOP_THRESHOLD,
    block_sizes: tuple[int, int, int] | None = None,
    fuse: bool | str | None = None,  # see FUSE_MODES
    vmem_budget: int = DEFAULT_VMEM_BUDGET,
    backend: str | None = None,  # pin every stage ("einsum"); None = auto
    mesh=None,
    axes=None,
    batch_axis: AxisName = None,
    accum: str | None = None,  # accumulation mode (engine/numerics.py)
    error_budget: float | None = None,  # max a-priori plan rounding bound
) -> GemtPlan:
    """Plan a 3-stage GEMT for a tensor of ``x_shape`` (3D, or 4D batched).

    ``order=None`` searches all six parenthesizations and keeps the one with
    minimal (effective MACs, collective bytes, peak intermediate bytes);
    passing an explicit order pins it (the paper's reference chain is
    ``(3, 1, 2)``).

    ``fuse`` controls stage fusion (see ``FUSE_MODES``): ``None`` (default)
    picks the deepest fusion that models the fewest HBM bytes — the
    whole-transform triple megakernel when its tiles fit ``vmem_budget``
    and it beats the best pair schedule, else the consecutive pair with
    the largest modeled saving, else staged; ``True`` forces the deepest
    feasible fusion; ``False`` never fuses; ``"pair"`` / ``"triple"``
    restrict the search to that depth.  The per-stage plans are kept
    either way — they are the staged fallback the executor uses outside
    the fused stages.

    ``backend="einsum"`` pins every stage to the XLA einsum lowering and
    disables fusion — the bottom rung of the serving runtime's degradation
    ladder (``docs/serving.md``): no Pallas kernels, no fused VMEM
    residency, maximally conservative.  ``None`` (default) keeps the
    per-stage backend choice with the cost model.

    ``mesh``/``axes`` make the plan topology-aware: ``axes[s-1]`` names the
    mesh axis sharding mode ``s`` of the stationary tensor (None = local;
    tuple = a folded multi-axis shard).  ``x_shape`` stays **global**; the
    stages describe the per-shard schedule (see the module docstring) and
    every mode extent — and the matching ``K_s``, for the psum_scatter —
    must divide its axis size.  ``batch_axis`` optionally shards a leading
    batch dim (data parallelism; no collective, the rows just split).

    ``accum`` selects the accumulation mode every stage (and any fused
    kernel) runs under — see ``engine/numerics.py`` and
    ``docs/numerics.md``.  ``error_budget`` caps the plan's a-priori
    rounding bound (:func:`repro.engine.numerics.plan_error_bound`): when
    the bound at the requested mode blows the budget, the mode escalates
    ``plain`` → ``f32`` → ``compensated`` and each step is recorded as a
    ``numerics_degradation`` event.  The escalation runs *before* fusion
    planning — compensation's comp scratch inflates every fused VMEM
    footprint, so a tight ``(error_budget, vmem_budget)`` pair can
    legitimately demote triple → pair → staged.
    """
    if backend not in (None, "einsum"):
        raise ValueError(
            f"backend must be None (auto) or 'einsum', got {backend!r}")
    dims = tuple(int(d) for d in x_shape[-3:])
    if len(x_shape) not in (3, 4):
        raise ValueError(f"x must be 3D or 4D-batched, got shape {x_shape}")
    batch_global = int(x_shape[0]) if len(x_shape) == 4 else 1
    cs = {1: c1, 2: c2, 3: c3}
    for mode in (1, 2, 3):
        if cs[mode].ndim != 2 or cs[mode].shape[0] != dims[mode - 1]:
            raise ValueError(
                f"C{mode} shape {cs[mode].shape} incompatible with mode "
                f"extent {dims[mode - 1]}")

    axes = normalize_axes(axes) if mesh is not None else (None, None, None)
    shards = tuple(mesh_axis_size(mesh, a) for a in axes)
    batch_shards = mesh_axis_size(mesh, batch_axis) if mesh is not None else 1
    if mesh is None:
        batch_axis = None
    # A mesh axis can shard only one dim of the stationary tensor: a repeat
    # across modes (or with batch_axis) would build a duplicate-entry
    # PartitionSpec and fail far from the user's mistake.
    named = [n for a in (*axes, batch_axis) if a is not None
             for n in (a if isinstance(a, tuple) else (a,))]
    dupes = sorted({n for n in named if named.count(n) > 1})
    if dupes:
        raise ValueError(
            f"mesh axes {dupes} assigned to more than one of "
            f"axes={axes} / batch_axis={batch_axis!r}")
    for mode in (1, 2, 3):
        p = shards[mode - 1]
        if dims[mode - 1] % p:
            raise ValueError(
                f"mode-{mode} extent {dims[mode - 1]} not divisible by "
                f"axis {axes[mode - 1]!r} (size {p})")
        if int(cs[mode].shape[1]) % p:
            raise ValueError(
                f"C{mode} output extent {cs[mode].shape[1]} not divisible "
                f"by axis {axes[mode - 1]!r} (size {p}) — the psum_scatter "
                f"re-shards K{mode} over it")
    if batch_global % max(batch_shards, 1):
        raise ValueError(
            f"batch {batch_global} not divisible by batch_axis "
            f"{batch_axis!r} (size {batch_shards})")
    batch = batch_global // max(batch_shards, 1)
    local = tuple(d // p for d, p in zip(dims, shards))
    itemsize = jnp.dtype(x_dtype).itemsize * max(batch, 1)

    candidates = ([tuple(order)] if order is not None
                  else list(itertools.permutations((1, 2, 3))))
    best = None
    mask_cache: dict[int, np.ndarray] = {}
    for cand in candidates:
        if sorted(cand) != [1, 2, 3]:
            raise ValueError(f"order must be a permutation of (1,2,3), got {cand}")
        stages, macs, eff, peak, coll = _plan_for_order(
            local, cs, cand, batch=batch, itemsize=itemsize,
            esop_threshold=esop_threshold, block_sizes=block_sizes,
            mask_cache=mask_cache, axes=axes, shards=shards)
        # Collective bytes rank above peak bytes: ICI is the scarcer
        # resource, and the term is what pushes shard-local (especially
        # compressive) stages ahead of the sharded-mode scatter.
        score = (eff, coll, peak, cand)
        if best is None or score < best[0]:
            best = (score, cand, stages, macs, eff, peak, coll)
    _, chosen, stages, macs, eff, peak, coll = best

    # Guarded numerics: resolve the accumulation mode against the a-priori
    # error model BEFORE fusion planning — the comp scratch of a forced
    # compensation inflates every fused footprint below, so the budget can
    # demote fusion depth (docs/numerics.md).
    accum_requested = accum
    accum = normalize_accum(accum)
    if jnp.issubdtype(jnp.dtype(x_dtype), jnp.complexfloating):
        accum = "plain"  # DFT stages stay plain — kernels are real-valued
    if error_budget is not None:
        accum, error_bound, numerics_events = enforce_error_budget(
            stages, x_dtype, accum, error_budget)
    else:
        error_bound = plan_error_bound(stages, x_dtype, accum)
        numerics_events = []
    if accum != "plain":
        stages = tuple(dataclasses.replace(s, accum=accum) for s in stages)

    isz_raw = jnp.dtype(x_dtype).itemsize
    fused = None
    fused3 = None
    fusion_events: list[dict] = []  # demotion records, filtered below
    if fuse not in FUSE_MODES:
        raise ValueError(f"fuse must be one of {FUSE_MODES}, got {fuse!r}")
    if backend is not None:
        # Pinned backend: every stage runs it dense (no block skipping) and
        # fusion is off — the pin exists to take Pallas out of the loop.
        stages = tuple(dataclasses.replace(s, backend=backend,
                                           macs_effective=s.macs)
                       for s in stages)
        eff = macs
        fuse = False
    if fuse in (None, True, "triple"):
        fused3 = _plan_fusion3(chosen, stages, cs, batch=batch,
                               itemsize=isz_raw, vmem_budget=vmem_budget,
                               force=fuse in (True, "triple"), axes=axes,
                               events=fusion_events, accum=accum)
    if fuse in (None, True, "pair") and not (fused3 and fuse is True):
        cands = []
        for first in (0, 1):
            fp = _plan_fusion(first, chosen, stages, local, cs, batch=batch,
                              itemsize=isz_raw, vmem_budget=vmem_budget,
                              force=(fuse is True), axes=axes, shards=shards,
                              events=fusion_events, accum=accum)
            if fp is not None:
                cands.append(fp)
        if cands:  # fuse the pair that saves the most modeled bytes
            fused = max(cands,
                        key=lambda f: f.hbm_bytes_staged - f.hbm_bytes_fused)
    # Graceful degradation triple → pair → staged: in auto mode (the only
    # way both candidates exist — fuse=True skips the pair search when the
    # triple is feasible) the deeper fusion must also *model* fewer bytes
    # than the best pair schedule — a budget-starved triple whose shrunken
    # bka re-streams X many times can lose to the pair kernel, and then
    # the pair runs.
    if fused3 is not None and fused is not None:
        if (fused3.hbm_bytes_fused
                <= plan_hbm_bytes(stages, fused, batch, isz_raw)):
            fused = None
        else:
            fusion_events.append({
                "kind": "fusion_degradation", "from": "triple",
                "reason": "byte_model_vs_pair",
                "hbm_bytes_fused": fused3.hbm_bytes_fused,
                "hbm_bytes_pair_plan": plan_hbm_bytes(stages, fused, batch,
                                                      isz_raw),
                "vmem_bytes": fused3.vmem_bytes,
                "vmem_budget": vmem_budget,
            })
            fused3 = None
    # Keep only genuine demotions: an event whose "from" tier still ended
    # up running (e.g. one pair candidate declined but the other fused, or
    # the triple engaged after a pair decline) is not a degradation.
    tier_rank = {"staged": 0, "pair": 1, "triple": 2}
    final_tier = ("triple" if fused3 is not None
                  else "pair" if fused is not None else "staged")
    events = tuple(
        dict(ev, to=final_tier) for ev in fusion_events
        if tier_rank[final_tier] < tier_rank[ev["from"]])
    # Numerics events bypass the tier filter: they record accumulation
    # escalations, not fusion demotions, and carry no "from" tier.
    events = tuple(numerics_events) + events

    out_shape = tuple(cs[m].shape[1] for m in (1, 2, 3))
    blocks = {s.mode: (s.bk, s.bn) for s in stages}
    key_parts = [
        f"x={tuple(x_shape)}", f"dt={jnp.dtype(x_dtype).name}",
        f"o={chosen}", f"th={esop_threshold}",
        f"bs={block_sizes}", f"fu={fuse}", f"vb={vmem_budget}",
        f"sig={sparsity_signature(cs, blocks)}",
    ]
    if backend is not None:  # unpinned keys stay byte-identical to PR 1–6
        key_parts.append(f"be={backend}")
    # default-numerics keys stay byte-identical to PR 1–8
    if accum_requested not in (None, "plain"):
        key_parts.append(f"ac={accum_requested}")
    if error_budget is not None:
        key_parts.append(f"eb={error_budget}")
    if mesh is not None:  # single-device keys stay byte-identical to PR 1–2
        key_parts.append(
            f"mesh={tuple(mesh.shape.items())};ax={axes};ba={batch_axis}")
    return GemtPlan(order=chosen, stages=stages, in_shape=dims,
                    out_shape=out_shape, macs=macs, macs_effective=eff,
                    peak_intermediate_bytes=peak, key="|".join(key_parts),
                    fused=fused, fused3=fused3,
                    hbm_bytes_staged=plan_hbm_bytes(stages, None, batch,
                                                    isz_raw),
                    hbm_bytes_moved=plan_hbm_bytes(stages, fused, batch,
                                                   isz_raw, fused3=fused3),
                    axes=axes, shards=shards, batch_axis=batch_axis,
                    batch_shards=batch_shards, collective_bytes=coll,
                    events=events, accum=accum, error_bound=error_bound,
                    error_budget=error_budget)
