"""Guarded numerics: accumulation modes, an a-priori error model, and the
nonfinite-output fault class.

TriADA pitches the ESOP method as "enhancing computational accuracy and
stability"; this module is the repro's numerics layer — the correctness
prerequisite for the quantized-coefficient roadmap item (narrow datatypes
only pay off when error is budgeted per accumulation).

Three pieces:

* **Accumulation modes** (:data:`ACCUM_MODES`) — how a kernel folds its
  contraction stream into the output:

  ========== ============================================================
  ``plain``        fp32 accumulator scratch, result rounded back to the
                   operand dtype (the PR 1–8 behavior).
  ``f32``          fp32 accumulator, result **kept** in float32 — sub-fp32
                   operands (bf16/fp16) skip the output downcast, the
                   dominant error term at serving precisions.
  ``compensated``  ``f32`` plus a Neumaier-compensated reduction across
                   the streamed K chunks: the accumulated rounding error
                   is carried in a second register and folded back at the
                   flush, making the bound independent of contraction
                   depth K.
  ========== ============================================================

  Complex operands (DFT factors) always run ``plain`` — the planner pins
  those stages to einsum anyway and the compensation algebra is specified
  for reals.

* **Error model** — a first-order a-priori rounding bound per stage
  (:func:`stage_error_bound`) and per plan (:func:`plan_error_bound`):

  .. math::

      \\beta_{stage} \\approx K\\,u_{acc} + u_{out}
      \\qquad\\text{(plain / f32)}

      \\beta_{stage} \\approx 2\\,u_{acc} + u_{out}
      \\qquad\\text{(compensated)}

  where ``u_acc`` is the fp32 accumulator's unit roundoff, ``u_out`` the
  output dtype's (the operand dtype under ``plain``, fp32 otherwise) and
  K the stage's contraction depth.  The plan bound sums the three stage
  bounds — a conservative staged-schedule bound (fused schedules skip the
  intermediate downcasts, so they only do better).  ``build_plan``
  evaluates it against the ``error_budget`` knob and escalates the
  accumulation mode (``plain`` → ``f32`` → ``compensated``) until the
  bound fits, recording ``numerics_degradation`` events
  (:func:`enforce_error_budget`) next to the ``fusion_degradation``
  stream.  The compensated scratch is folded into the ``*_vmem_bytes``
  ladders, so forcing compensation can itself demote triple → pair.

* **Nonfinite recovery** — :class:`NonfiniteOutput` classifies a NaN/Inf
  result as a *retryable* fault; :func:`finite_guard` is the cheap
  post-launch verdict (one ``jnp.isfinite`` reduction + host sync, off
  the hot path by default, sampled every N requests in serve — see
  ``ResilientDxtServer(finite_check_every=...)`` and the ``nan`` fault
  kind in :mod:`repro.runtime.faults`).

See ``docs/numerics.md`` for the worked examples.
"""
from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "ACCUM_MODES",
    "NonfiniteOutput",
    "normalize_accum",
    "accum_out_dtype",
    "unit_roundoff",
    "stage_error_bound",
    "plan_error_bound",
    "enforce_error_budget",
    "finite_guard",
]

# Accumulation modes, cheapest first — the escalation order
# enforce_error_budget walks when a bound blows its budget.
ACCUM_MODES = ("plain", "f32", "compensated")


class NonfiniteOutput(RuntimeError):
    """A kernel/plan produced NaN/Inf output — retryable: the serving
    runtime retries one ladder rung down with compensation forced, the
    training step skips the update (``docs/numerics.md``)."""


def normalize_accum(accum) -> str:
    """Validate and default an ``accum`` knob (None -> ``"plain"``)."""
    if accum is None:
        return "plain"
    if accum not in ACCUM_MODES:
        raise ValueError(
            f"accum must be one of {ACCUM_MODES} (or None), got {accum!r}")
    return accum


def accum_out_dtype(dtype, accum: str):
    """Output dtype under ``accum``: the operand dtype for ``plain``,
    float32 for the promoted modes (complex dtypes never promote — see
    module docstring)."""
    dtype = jnp.dtype(dtype)
    if accum == "plain" or jnp.issubdtype(dtype, jnp.complexfloating):
        return dtype
    if jnp.issubdtype(dtype, jnp.floating) and dtype.itemsize < 4:
        return jnp.dtype(jnp.float32)
    return dtype


def unit_roundoff(dtype) -> float:
    """Unit roundoff u = eps/2 of a float dtype (complex uses its real
    component's)."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.complexfloating):
        dtype = jnp.dtype(jnp.float32 if dtype.itemsize == 8 else jnp.float64)
    if not jnp.issubdtype(dtype, jnp.floating):
        raise ValueError(f"unit_roundoff needs a float dtype, got {dtype}")
    return float(jnp.finfo(dtype).eps) / 2.0


def stage_error_bound(depth: int, x_dtype, accum: str = "plain") -> float:
    """First-order relative rounding bound of one K-deep contraction stage.

    ``depth`` is the contraction extent K (a stage contracts N_s terms —
    ``StagePlan.n``).  The kernels always accumulate in fp32 scratch, so
    ``u_acc`` is fp32's roundoff; ``u_out`` is the flush rounding — the
    operand dtype under ``plain`` (the bf16 downcast that dominates at
    serving precisions), fp32 under the promoted modes.  Compensated
    summation replaces the K-proportional term with a depth-independent
    ``2 u_acc`` (Neumaier's bound, to first order).
    """
    accum = normalize_accum(accum)
    u_acc = unit_roundoff(jnp.float32)
    u_out = unit_roundoff(accum_out_dtype(x_dtype, accum))
    k_term = 2.0 * u_acc if accum == "compensated" else depth * u_acc
    return k_term + u_out


def plan_error_bound(stages, x_dtype, accum: str = "plain") -> float:
    """Composed bound of a 3-stage plan: the sum of its stage bounds.

    ``stages`` is any iterable of objects with an ``n`` attribute (the
    stage's contraction depth — ``GemtPlan.stages`` works directly).
    This is the **staged** schedule's bound, the conservative envelope:
    fused schedules keep intermediates in fp32 VMEM and skip the
    inter-stage downcasts, so their true error is never worse.
    """
    return float(sum(stage_error_bound(int(s.n), x_dtype, accum)
                     for s in stages))


def enforce_error_budget(stages, x_dtype, accum: str,
                         error_budget: float) -> tuple[str, float, list]:
    """Escalate ``accum`` until the plan bound fits ``error_budget``.

    Returns ``(accum, bound, events)``: the (possibly escalated)
    accumulation mode, its bound, and one ``numerics_degradation`` event
    per escalation step carrying the bound numbers — the planner surfaces
    these next to the ``fusion_degradation`` stream.  Complex operands
    never escalate (see module docstring); if even ``compensated`` blows
    the budget the last mode is kept and the final event says so
    (``"budget_met": False``) — the planner has no cheaper lever left.
    """
    accum = normalize_accum(accum)
    bound = plan_error_bound(stages, x_dtype, accum)
    events: list[dict] = []
    if jnp.issubdtype(jnp.dtype(x_dtype), jnp.complexfloating):
        return accum, bound, events
    idx = ACCUM_MODES.index(accum)
    while bound > error_budget and idx + 1 < len(ACCUM_MODES):
        nxt = ACCUM_MODES[idx + 1]
        nbound = plan_error_bound(stages, x_dtype, nxt)
        events.append({
            "kind": "numerics_degradation", "reason": "error_budget",
            "accum_from": ACCUM_MODES[idx], "accum_to": nxt,
            "bound_before": bound, "bound_after": nbound,
            "error_budget": float(error_budget),
            "budget_met": nbound <= error_budget,
        })
        accum, bound, idx = nxt, nbound, idx + 1
    return accum, bound, events


def finite_guard(y) -> bool:
    """Post-launch finiteness verdict: True when every element of ``y``
    is finite.  One ``jnp.isfinite`` reduction plus a scalar host sync —
    cheap, but a sync, which is why serve samples it
    (``finite_check_every``) instead of running it per request."""
    return bool(jnp.isfinite(y).all())
