"""Timing-based block-size autotuner with a JSON-persisted cache.

Kernel tile sizes (``bm``/``bn``/``bk``) are a hardware- and shape-dependent
choice; hard-coding 128³ leaves VMEM and MXU utilization on the table for
skinny Tucker stages.  ``autotune_gemm`` hill-climbs the (power-of-two)
block-size lattice by measuring the actual dispatch (``kernels.ops.sr_gemm``
or ``esop_gemm``) and persists the winner in an :class:`AutotuneCache` keyed
on ``(m, n, k, dtype, kind, sparsity signature)`` — the same signature the
planner uses, so a C matrix with a different zero structure never reuses a
stale ESOP tuning.

The cache is a plain JSON file (default ``~/.cache/repro/autotune.json``,
overridable via ``REPRO_AUTOTUNE_CACHE`` or the ``path`` argument), tolerant
of missing/corrupt files so a cold or broken cache never fails a run.

Paper anchor: §5.1 (the P³-cell tiling the tiles discretize).  See
``docs/engine.md`` ("Autotune"); under a mesh the tuned shapes are the
*per-shard* GEMMs (``docs/distributed.md``).
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from ..kernels import ops
from ..obs import metrics as _metrics
from ..obs import trace as _trace

__all__ = ["AutotuneCache", "autotune_gemm", "autotune_fused",
           "autotune_fused3", "default_cache_path", "make_key",
           "make_fused_key", "make_fused3_key"]

_BOUNDS = (8, 512)  # power-of-two block-size lattice bounds
_MIN_GAIN = 0.02  # relative speedup required to accept a move


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def make_key(m: int, n: int, k: int, dtype, kind: str, sig: str = "",
             adjoint: bool = False, accum: str = "plain") -> str:
    """Autotune-cache key for a staged GEMM (cache version v4).

    ``adjoint`` gives the backward pass its own tuning role: earlier
    versions let adjoint stages hit the forward entries ("a transposed
    square problem matches a forward one"), but the measured dispatch is
    not the same — the adjoint contracts against ``C_sᵀ``, whose *column*
    zero structure drives a different ESOP compaction, and the backward
    runs the stage inside the chain/recompute walk with different operand
    residency.  Forward-tuned tiles replaying for the adjoint was a live
    bug (tile-sharing), so the role is part of the key and the v3 bump
    orphans every v2 entry that was written without one.

    ``accum`` (v4) keys the guarded-numerics accumulation mode: a
    compensated dispatch carries an extra comp scratch and per-step adds,
    so its best tiles are not the plain dispatch's best tiles.
    """
    role = "adj" if adjoint else "fwd"
    return (f"v4:{m}x{n}x{k}|{jnp.dtype(dtype).name}|{kind}|{role}"
            f"|{accum}|{sig}")


def make_fused_key(u: int, na: int, ka: int, nb: int, kb: int,
                   dtype, sig: str = "",
                   vmem_budget: int | None = None,
                   adjoint: bool = False, accum: str = "plain") -> str:
    """Autotune-cache key for the fused pair kernel (cache version v5).

    The VMEM budget is part of the problem, exactly as in the plan cache's
    ``vb=`` component: tiles tuned under a roomy budget must never replay
    under a stricter one (the budget filter would not re-run on a cache
    hit).  The v4 bump adds the forward/adjoint role — see
    :func:`make_key` — and orphans role-less v3 entries; v5 adds the
    accumulation mode (the comp scratch changes the footprint the budget
    filter sees).
    """
    role = "adj" if adjoint else "fwd"
    return (f"fused:v5:{u}x{na}x{ka}x{nb}x{kb}|{jnp.dtype(dtype).name}"
            f"|{role}|{accum}|{sig}|vb{vmem_budget}")


def make_fused3_key(u: int, na: int, ka: int, nb: int, kb: int,
                    nc: int, kc: int, dtype, sig: str = "",
                    vmem_budget: int | None = None,
                    adjoint: bool = False, accum: str = "plain") -> str:
    """Autotune-cache key for the whole-transform megakernel (v3 adds the
    forward/adjoint role and orphans role-less v2 entries, v4 the
    accumulation mode — see :func:`make_key`)."""
    role = "adj" if adjoint else "fwd"
    return (f"fused3:v4:{u}x{na}x{ka}x{nb}x{kb}x{nc}x{kc}"
            f"|{jnp.dtype(dtype).name}|{role}|{accum}|{sig}|vb{vmem_budget}")


# Key prefixes the current key builders emit.  Anything else in a loaded
# cache file is an orphan from an earlier key version (the v3/v4/v5 bumps
# that added the adjoint role and the accumulation mode) — those entries
# can never be hit again and only bloat the file, so load() prunes them.
_LIVE_KEY_PREFIXES = ("v4:", "fused:v5:", "fused3:v4:")


class AutotuneCache:
    """JSON-backed ``key -> {bm, bn, bk, us}`` store."""

    def __init__(self, path: str | None = None):
        self.path = path or default_cache_path()
        self._entries: dict[str, dict] = {}
        self.load()

    def load(self) -> None:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except OSError:
            self._entries = {}  # cold cache: no file yet (or unreadable)
            return
        except ValueError:
            # Corrupt JSON (e.g. a torn write from a pre-atomic-rename
            # version, or external truncation): recover to empty rather
            # than fail the run, and count it so operators can see it.
            self._entries = {}
            _metrics.inc("autotune.cache.corrupt_recovered")
            return
        if isinstance(data, dict):
            self._entries = {k: v for k, v in data.items()
                             if isinstance(v, dict)}
        else:
            self._entries = {}
            _metrics.inc("autotune.cache.corrupt_recovered")
            return
        self.prune()
        _metrics.inc("autotune.cache.loads")

    def prune(self) -> int:
        """Drop entries whose key no longer matches a live key version.

        The v3/v4/v5 key bumps (adjoint role, accumulation mode) orphaned
        every entry written under the old scheme — they are unreachable by
        ``get`` yet were re-persisted on every ``save``, growing the file
        forever.  Runs on every ``load``; counted in
        ``autotune.cache.pruned``.  Returns how many entries fell.
        """
        stale = [k for k in self._entries
                 if not k.startswith(_LIVE_KEY_PREFIXES)]
        for k in stale:
            del self._entries[k]
        if stale:
            _metrics.inc("autotune.cache.pruned", len(stale))
        return len(stale)

    def save(self) -> None:
        """Atomically persist: write a *uniquely named* temp file in the
        destination directory, then ``os.replace``.  A fixed temp name
        would let two concurrent savers interleave (one renames the
        other's half-written file); mkstemp gives each writer its own."""
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(
            dir=d, prefix=os.path.basename(self.path) + ".", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._entries, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        _metrics.inc("autotune.cache.writes")

    def get(self, key: str) -> dict | None:
        entry = self._entries.get(key)
        _metrics.inc("autotune.cache.hits" if entry is not None
                     else "autotune.cache.misses")
        return entry

    def put(self, key: str, entry: dict) -> None:
        self._entries[key] = entry

    def __len__(self) -> int:
        return len(self._entries)


def _time_us(fn, reps: int = 2) -> float:
    jax.block_until_ready(fn())  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def _pow2_floor(d: int) -> int:
    return 1 << (max(int(d), 1).bit_length() - 1)


def _neighbors(cfg: tuple[int, ...],
               caps: tuple[int, ...]) -> list[tuple[int, ...]]:
    lo, hi = _BOUNDS
    out = []
    for i in range(len(cfg)):
        for factor in (2, 0.5):
            v = int(cfg[i] * factor)
            if lo <= v <= min(hi, caps[i]):
                cand = list(cfg)
                cand[i] = v
                if tuple(cand) != cfg:
                    out.append(tuple(cand))
    return out


def autotune_gemm(
    x: jnp.ndarray,
    c: jnp.ndarray,
    kind: str = "sr_gemm",
    *,
    sig: str = "",
    cache: AutotuneCache | None = None,
    max_steps: int = 6,
    reps: int = 2,
    use_pallas: bool | None = None,
    adjoint: bool = False,
    accum: str = "plain",
) -> tuple[int, int, int]:
    """Hill-climb (bm, bn, bk) for ``x @ c`` under dispatch ``kind``.

    Returns the best block sizes; a cache hit skips all measurement.
    ``adjoint`` selects the backward tuning role (its own cache entries —
    see :func:`make_key`); ``accum`` keys and measures the guarded
    accumulation mode's dispatch.
    """
    m, kdim = x.shape
    n = c.shape[1]
    cache = cache if cache is not None else AutotuneCache()
    key = make_key(m, n, kdim, x.dtype, kind, sig, adjoint=adjoint,
                   accum=accum)
    knobs_live = use_pallas is True or ops.on_tpu()
    hit = cache.get(key)
    # An untuned entry (defaults recorded off-TPU) must not suppress real
    # tuning once the cache file reaches a host where the knobs matter.
    if hit is not None and (hit.get("tuned", True) or not knobs_live):
        return int(hit["bm"]), int(hit["bn"]), int(hit["bk"])

    lo, _hi = _BOUNDS
    caps = tuple(max(lo, _pow2_floor(d)) for d in (m, n, kdim))

    if not knobs_live:
        # The reference paths ignore bm/bn/bk, so timing candidates here
        # would hill-climb on pure noise and persist a meaningless winner.
        # Cache the clamped defaults instead (still shape-correct for the
        # Pallas path if this cache later reaches a TPU host).
        cfg = tuple(min(128, cap) for cap in caps)
        cache.put(key, {"bm": cfg[0], "bn": cfg[1], "bk": cfg[2],
                        "us": 0.0, "kind": kind, "tuned": False})
        try:
            cache.save()
        except OSError:
            pass
        return cfg

    dispatch = {"sr_gemm": ops.sr_gemm, "esop": ops.esop_gemm,
                "esop_gemm": ops.esop_gemm}[kind]

    def measure(cfg):
        bm, bn, bk = cfg

        def call():
            y = dispatch(x, c, bm=bm, bn=bn, bk=bk, use_pallas=use_pallas,
                         accum=accum)
            return y[0] if isinstance(y, tuple) else y

        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("autotune.probe",
                             {"kind": kind, "cfg": cfg, "key": key})
        with sp:
            return _time_us(call, reps=reps)

    cur = tuple(min(128, cap) for cap in caps)
    cur_us = measure(cur)
    for _ in range(max_steps):
        moved = False
        for cand in _neighbors(cur, caps):
            us = measure(cand)
            if us < cur_us * (1.0 - _MIN_GAIN):
                cur, cur_us, moved = cand, us, True
        if not moved:
            break
    cache.put(key, {"bm": cur[0], "bn": cur[1], "bk": cur[2],
                    "us": round(cur_us, 2), "kind": kind, "tuned": True})
    try:
        cache.save()
    except OSError:
        pass  # read-only FS: tuning still applies in-process
    return cur


def autotune_fused(
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    *,
    rows: int,
    dtype,
    start: tuple[int, int, int],
    bna: int,
    kbp: int,
    sig: str = "",
    cache: AutotuneCache | None = None,
    max_steps: int = 4,
    reps: int = 2,
    use_pallas: bool | None = None,
    vmem_budget: int | None = None,
    adjoint: bool = False,
    accum: str = "plain",
) -> tuple[int, int, int]:
    """Hill-climb the fused kernel's ``(bu, bka, bnb)`` tile triple.

    ``rows``/``dtype`` describe the u-major input ``(rows, Nb, Na)``; the
    ones-probe is only materialized when a measurement actually runs, so a
    warm cache costs no device allocation.  ``start`` is the planner's
    (VMEM-feasible) choice; every candidate is re-checked against the
    footprint model so tuning can never climb out of the budget.
    ``bna``/``kbp`` stay pinned (Kb is not grid-blocked and the na tile
    only trades partial-width for step count).
    """
    from .plan import DEFAULT_VMEM_BUDGET, fused_vmem_bytes

    u = int(rows)
    na, ka = ca.shape
    nb, kb = cb.shape
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    cache = cache if cache is not None else AutotuneCache()
    # bna/kbp are part of the problem too: a hit tuned with a different
    # pinned na tile must not leak mismatched tiles (the budget itself is
    # keyed inside make_fused_key since the v2 bump).
    key = (make_fused_key(u, na, ka, nb, kb, dtype, sig, vmem_budget=budget,
                          adjoint=adjoint, accum=accum)
           + f"|bna{bna}|kbp{kbp}")
    isz = jnp.dtype(dtype).itemsize
    lo, _hi = _BOUNDS
    caps = tuple(max(lo, _pow2_floor(d)) for d in (u, ka, nb))

    def fits(cfg):
        return fused_vmem_bytes(cfg[0], cfg[1], cfg[2], bna, kbp,
                                isz, accum) <= budget

    knobs_live = use_pallas is True or ops.on_tpu()
    hit = cache.get(key)
    if hit is not None and (hit.get("tuned", True) or not knobs_live):
        cfg = (int(hit["bu"]), int(hit["bka"]), int(hit["bnb"]))
        if fits(cfg):  # belt-and-braces: never trust a cache into VMEM OOM
            return cfg

    cur = tuple(start)
    if not knobs_live:
        cache.put(key, {"bu": cur[0], "bka": cur[1], "bnb": cur[2],
                        "us": 0.0, "kind": "fused", "tuned": False})
        try:
            cache.save()
        except OSError:
            pass
        return cur

    x3 = jnp.ones((u, nb, na), dtype=dtype)  # probe: measured path only

    def measure(cfg):
        bu, bka, bnb = cfg

        def call():
            y, _ = ops.fused_gemt(x3, ca, cb, bu=bu, bka=bka, bnb=bnb,
                                  bna=bna, use_pallas=use_pallas,
                                  accum=accum)
            return y

        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("autotune.probe",
                             {"kind": "fused", "cfg": cfg, "key": key})
        with sp:
            return _time_us(call, reps=reps)

    cur_us = measure(cur)
    for _ in range(max_steps):
        moved = False
        for cand in _neighbors(cur, caps):
            if not fits(cand):
                continue
            us = measure(cand)
            if us < cur_us * (1.0 - _MIN_GAIN):
                cur, cur_us, moved = cand, us, True
        if not moved:
            break
    cache.put(key, {"bu": cur[0], "bka": cur[1], "bnb": cur[2],
                    "us": round(cur_us, 2), "kind": "fused", "tuned": True})
    try:
        cache.save()
    except OSError:
        pass
    return cur


def autotune_fused3(
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    cc: jnp.ndarray,
    *,
    rows: int,
    dtype,
    start: tuple[int, int, int, int],
    bna: int,
    kbp: int,
    kcp: int,
    sig: str = "",
    cache: AutotuneCache | None = None,
    max_steps: int = 4,
    reps: int = 2,
    use_pallas: bool | None = None,
    vmem_budget: int | None = None,
    adjoint: bool = False,
    accum: str = "plain",
) -> tuple[int, int, int, int]:
    """Hill-climb the megakernel's ``(bu, bka, bnb, bnc)`` tile quadruple.

    ``rows``/``dtype`` describe the u-major input ``(rows, Nc, Nb, Na)``;
    the ones-probe is only materialized when a measurement actually runs.
    ``start`` is the planner's (VMEM-feasible) choice; every candidate is
    re-checked against the footprint model so tuning can never climb out
    of the budget.  ``bna``/``kbp``/``kcp`` stay pinned (Kb/Kc are not
    grid-blocked and the na tile only trades partial-width for step
    count).
    """
    from .plan import DEFAULT_VMEM_BUDGET, fused3_vmem_bytes

    u = int(rows)
    na, ka = ca.shape
    nb, kb = cb.shape
    nc, kc = cc.shape
    budget = DEFAULT_VMEM_BUDGET if vmem_budget is None else vmem_budget
    cache = cache if cache is not None else AutotuneCache()
    key = (make_fused3_key(u, na, ka, nb, kb, nc, kc, dtype, sig,
                           vmem_budget=budget, adjoint=adjoint, accum=accum)
           + f"|bna{bna}|kbp{kbp}|kcp{kcp}")
    isz = jnp.dtype(dtype).itemsize
    lo, _hi = _BOUNDS
    caps = tuple(max(lo, _pow2_floor(d)) for d in (u, ka, nb, nc))

    def fits(cfg):
        return fused3_vmem_bytes(cfg[0], cfg[1], cfg[2], cfg[3], bna, kbp,
                                 kcp, isz, accum) <= budget

    knobs_live = use_pallas is True or ops.on_tpu()
    hit = cache.get(key)
    if hit is not None and (hit.get("tuned", True) or not knobs_live):
        cfg = (int(hit["bu"]), int(hit["bka"]), int(hit["bnb"]),
               int(hit["bnc"]))
        if fits(cfg):  # belt-and-braces: never trust a cache into VMEM OOM
            return cfg

    cur = tuple(start)
    if not knobs_live:
        cache.put(key, {"bu": cur[0], "bka": cur[1], "bnb": cur[2],
                        "bnc": cur[3], "us": 0.0, "kind": "fused3",
                        "tuned": False})
        try:
            cache.save()
        except OSError:
            pass
        return cur

    x4 = jnp.ones((u, nc, nb, na), dtype=dtype)  # probe: measured path only

    def measure(cfg):
        bu, bka, bnb, bnc_ = cfg

        def call():
            y, _ = ops.fused3_gemt(x4, ca, cb, cc, bu=bu, bka=bka, bnb=bnb,
                                   bnc=bnc_, bna=bna, use_pallas=use_pallas,
                                   accum=accum)
            return y

        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("autotune.probe",
                             {"kind": "fused3", "cfg": cfg, "key": key})
        with sp:
            return _time_us(call, reps=reps)

    cur_us = measure(cur)
    for _ in range(max_steps):
        moved = False
        for cand in _neighbors(cur, caps):
            if not fits(cand):
                continue
            us = measure(cand)
            if us < cur_us * (1.0 - _MIN_GAIN):
                cur, cur_us, moved = cand, us, True
        if not moved:
            break
    cache.put(key, {"bu": cur[0], "bka": cur[1], "bnb": cur[2],
                    "bnc": cur[3], "us": round(cur_us, 2), "kind": "fused3",
                    "tuned": True})
    try:
        cache.save()
    except OSError:
        pass
    return cur
