"""Deterministic synthetic data pipeline with sharded host→device transfer.

Real deployments swap ``TokenSource`` for a tokenized corpus reader; the
interface (seeded, stateless ``batch(step)``) is what the fault-tolerance
layer relies on for exact resume-after-restart (data order is a pure
function of the step number — no iterator state to checkpoint).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp


@dataclasses.dataclass
class TokenSource:
    """Zipf-distributed token stream; batch content = f(seed, step)."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    n_codebooks: int = 0  # >0: (B, S, n_codebooks) frames (musicgen)
    embedding_dim: int = 0  # >0: continuous embeddings (vlm stub)

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        b, s = self.global_batch, self.seq_len
        if self.embedding_dim:
            emb = rng.normal(size=(b, s, self.embedding_dim)).astype(np.float32)
            labels = self._tokens(rng, (b, s))
            return {"embeddings": emb, "labels": labels}
        shape = (b, s + 1, self.n_codebooks) if self.n_codebooks else (b, s + 1)
        toks = self._tokens(rng, shape)
        if self.n_codebooks:
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:, 0]}
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _tokens(self, rng, shape) -> np.ndarray:
        z = rng.zipf(self.zipf_a, size=shape)
        return np.minimum(z - 1, self.vocab_size - 1).astype(np.int32)


def shard_batch(batch: dict, mesh, batch_axes) -> dict:
    """Host numpy batch -> globally-sharded device arrays (batch dim over DP)."""
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in batch.items()}
    from jax.sharding import NamedSharding, PartitionSpec as P
    out = {}
    for k, v in batch.items():
        spec = P(batch_axes) + P(*([None] * (v.ndim - 1)))
        out[k] = jax.device_put(v, NamedSharding(mesh, spec))
    return out


def make_source(cfg, shape, seed: int = 0) -> TokenSource:
    return TokenSource(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        n_codebooks=cfg.n_codebooks if cfg.input_mode == "codebooks" else 0,
        embedding_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0,
    )
