"""Synthetic token pipeline feeding the train/serve loops.

Not a paper subsystem — production scaffolding (``docs/architecture.md``,
"Production substrate"); ``shard_batch`` places global batches onto the
mesh's data axis.
"""
from .pipeline import TokenSource, make_source, shard_batch
