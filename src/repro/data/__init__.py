from .pipeline import TokenSource, make_source, shard_batch
