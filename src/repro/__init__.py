"""repro — TriADA (trilinear matrix-by-tensor multiply-add) JAX framework.

Paper-section→module map: ``docs/architecture.md``.  Engine internals:
``docs/engine.md``; distributed schedule: ``docs/distributed.md``.
"""
__version__ = "0.1.0"
