"""repro — TriADA (trilinear matrix-by-tensor multiply-add) JAX framework."""
__version__ = "0.1.0"
