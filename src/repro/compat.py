"""Version-compat shims for jax APIs that moved between releases.

``shard_map`` lived in ``jax.experimental.shard_map`` through jax 0.4/0.5
(with a ``check_rep`` kwarg) and graduated to ``jax.shard_map`` (with the
kwarg renamed to ``check_vma``).  This module exposes one ``shard_map``
callable with the modern keyword spelling that works on both.
"""
from __future__ import annotations

import inspect

try:  # jax >= 0.6: top-level export
    from jax import shard_map as _shard_map_impl
except ImportError:  # jax 0.4/0.5: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_CHECK_KW = (
    "check_vma"
    if "check_vma" in inspect.signature(_shard_map_impl).parameters
    else "check_rep"
)

__all__ = ["shard_map"]


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the modern signature on any supported jax."""
    return _shard_map_impl(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )
