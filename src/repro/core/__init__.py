"""TriADA core — the paper's algorithm layer (§2–§6).

Trilinear matrix-by-tensor multiply-add: the staged/outer-product GEMT
(§2–§3), DXT coefficient matrices (§2.2), ESOP sparse skipping (§6), the
cell-grid device simulator (§5), Tucker compression (§2.3), and the
distributed TriADA schedule (§4–§5, Eq. 7).  The paper-section→module map
lives in ``docs/architecture.md``; the distributed recipes in
``docs/distributed.md``.
"""
from .gemt import (PAREN_ORDERS, dxt3d, gemt3, gemt3_outer, gemt3_planned,
                   macs, mode_product, time_steps)
from .transforms import (TRANSFORM_KINDS, coefficient_matrix, dct2_matrix,
                         dft_matrix, dht_matrix, dwht_matrix,
                         inverse_coefficient_matrix)
from .esop import (EsopStats, accumulation_error, block_nonzero_mask,
                   energy_joules, esop_gemt3, esop_stage_counts, prune,
                   sparsity)
from .cellsim import TriadaCellGrid, simulate_dxt3
from .tucker import hosvd, tucker_compress, tucker_expand, tucker_roundtrip_error
from .distributed import gemt3_auto, gemt3_shardmap, tensor_spec
from .layers import (apply_dxt3d_layer, apply_triada_dense,
                     apply_triada_mixer, init_dxt3d_layer, init_triada_dense,
                     make_mixer_coeffs)

__all__ = [
    "PAREN_ORDERS", "dxt3d", "gemt3", "gemt3_outer", "gemt3_planned",
    "macs", "mode_product", "time_steps",
    "TRANSFORM_KINDS", "coefficient_matrix", "dct2_matrix", "dft_matrix",
    "dht_matrix", "dwht_matrix", "inverse_coefficient_matrix",
    "EsopStats", "accumulation_error", "block_nonzero_mask", "energy_joules",
    "esop_gemt3", "esop_stage_counts", "prune", "sparsity",
    "TriadaCellGrid", "simulate_dxt3",
    "hosvd", "tucker_compress", "tucker_expand", "tucker_roundtrip_error",
    "gemt3_auto", "gemt3_shardmap", "tensor_spec",
    "apply_dxt3d_layer", "apply_triada_dense", "apply_triada_mixer",
    "init_dxt3d_layer", "init_triada_dense", "make_mixer_coeffs",
]
