"""3-mode generalized matrix-by-tensor multiplication (3D-GEMT) and 3D-DXT.

Implements the paper's §2–§3:

* ``mode_product``       — one n_s-mode contraction X ×_s C (Kolda–Bader).
* ``gemt3``              — the chained three-stage GEMT, any of the paper's
                           six parenthesization orders (§3), rectangular
                           coefficient matrices allowed (expansion/compression,
                           i.e. Tucker, §2.3), affine ``+=`` init supported.
* ``gemt3_outer``        — the *outer-product (low-rank) formulation*,
                           Eqs. (6.1)–(6.3): each stage as an explicit
                           lax.scan of rank-1 updates.  This is the faithful
                           algorithmic form the TriADA device executes; it is
                           numerically identical to ``gemt3`` and serves as
                           the oracle for the cell simulator and kernels.
* ``dxt3d``              — forward/inverse trilinear orthogonal transform for
                           the DFT/DHT/DCT/DWHT family.
* complexity model       — MACs = N1·N2·N3·(N1+N2+N3); time-steps = N1+N2+N3.

Index convention matches the paper: X[n1, n2, n3]; C_s maps n_s → k_s with
C_s[n_s, k_s]; the forward transform is ẍ = Σ x·C1[n1,k1]·C2[n2,k2]·C3[n3,k3].
"""
from __future__ import annotations

import itertools
from typing import Sequence

import jax
import jax.numpy as jnp

__all__ = [
    "mode_product",
    "gemt3",
    "gemt3_outer",
    "gemt3_planned",
    "dxt3d",
    "macs",
    "time_steps",
    "PAREN_ORDERS",
]

# The six admissible stage orders (§3: which mode is contracted 1st/2nd/3rd).
PAREN_ORDERS: tuple[tuple[int, int, int], ...] = tuple(itertools.permutations((1, 2, 3)))

_EINSUM = {
    1: "abc,ax->xbc",
    2: "abc,bx->axc",
    3: "abc,cx->abx",
}


def mode_product(x: jnp.ndarray, c: jnp.ndarray, mode: int) -> jnp.ndarray:
    """n_s-mode product X ×_s C: contract axis ``mode-1`` of x with axis 0 of c.

    ``c`` has shape (N_s, K_s); rectangular K_s ≠ N_s gives tensor
    expansion/compression (paper §2.3).
    """
    if mode not in (1, 2, 3):
        raise ValueError(f"mode must be 1, 2 or 3, got {mode}")
    if x.ndim != 3:
        raise ValueError(f"x must be a 3-mode tensor, got ndim={x.ndim}")
    if x.shape[mode - 1] != c.shape[0]:
        raise ValueError(
            f"mode-{mode} extent {x.shape[mode - 1]} != coefficient rows {c.shape[0]}"
        )
    return jnp.einsum(_EINSUM[mode], x, c)


def gemt3(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    order: Sequence[int] = (3, 1, 2),
    out: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Three-mode GEMT ẍ = X ×₁C1 ×₂C2 ×₃C3 (+ out), staged per ``order``.

    ``order`` is the contraction order of the modes; the paper's reference
    chain (Eqs. 4/6: horizontal slicing first, then frontal reslice) is
    (3, 1, 2).  All orders produce identical results up to float rounding.
    ``out`` (if given) is the affine ``+=`` initialization of Eq. (1).
    """
    order = tuple(order)
    if sorted(order) != [1, 2, 3]:
        raise ValueError(f"order must be a permutation of (1,2,3), got {order}")
    cs = {1: c1, 2: c2, 3: c3}
    y = x
    for mode in order:
        y = mode_product(y, cs[mode], mode)
    if out is not None:
        y = out + y
    return y


def _stage_outer(resident: jnp.ndarray, coeff: jnp.ndarray, mode: int) -> jnp.ndarray:
    """One GEMT stage as a lax.scan over rank-1 (outer-product) updates.

    Faithful to Eqs. (6.1)–(6.3): at time-step n the actuator streams
    coefficient row c(n) (vector of length K_s) to the core; the pivotal
    cells (the n-th mode-s slice of the resident tensor) broadcast the data
    vector; every cell does one MAC.  The resident tensor never moves.

    The scan axis *is* the paper's discrete-time axis: the stage takes
    exactly N_s time-steps.
    """
    # Move the contracted mode to the front: resident -> (N_s, A, B)
    r = jnp.moveaxis(resident, mode - 1, 0)
    n_s, a, b = r.shape
    k_s = coeff.shape[1]
    acc0 = jnp.zeros(r.shape[1:] + (k_s,), dtype=jnp.result_type(r.dtype, coeff.dtype))

    def step(acc, inputs):
        x_slice, c_row = inputs  # (A, B), (K_s,)
        # rank-1 update per (a, b) fibre: acc[a, b, :] += x_slice[a, b] * c_row
        return acc + x_slice[..., None] * c_row[None, None, :], None

    acc, _ = jax.lax.scan(step, acc0, (r, coeff))
    # acc: (A, B, K_s) where (A, B) are the two untouched modes in order.
    return jnp.moveaxis(acc, -1, mode - 1)


def gemt3_outer(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    order: Sequence[int] = (3, 1, 2),
    out: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Outer-product (low-rank) 3-stage GEMT — the TriADA algorithm proper."""
    order = tuple(order)
    if sorted(order) != [1, 2, 3]:
        raise ValueError(f"order must be a permutation of (1,2,3), got {order}")
    cs = {1: c1, 2: c2, 3: c3}
    y = x
    for mode in order:
        y = _stage_outer(y, cs[mode], mode)
    if out is not None:
        y = out + y
    return y


def gemt3_planned(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    *,
    out: jnp.ndarray | None = None,
    **engine_kwargs,
):
    """Engine-scheduled GEMT: cost-model order search + kernel lowering.

    Thin re-export of :func:`repro.engine.gemt3_planned` (lazy import keeps
    ``core`` free of a hard dependency on the engine/kernels layers).  Unlike
    ``gemt3`` it accepts a leading batch axis and, with ``with_info=True``,
    returns per-stage dispatch accounting.  ``differentiable=True`` makes
    the call ``jax.grad``-safe with a backward pass that re-enters the
    engine: the X-cotangent is the adjoint GEMT over the transposed
    coefficients (for the orthonormal DXT families of §2.2 that is the
    inverse transform) and the coefficient cotangents are mode-unfolded
    rank-k SR-GEMM updates — see docs/engine.md ("Differentiation").
    """
    from ..engine import gemt3_planned as _planned

    return _planned(x, c1, c2, c3, out=out, **engine_kwargs)


def dxt3d(
    x: jnp.ndarray,
    kind: str = "dct",
    inverse: bool = False,
    order: Sequence[int] = (3, 1, 2),
    out: jnp.ndarray | None = None,
    outer: bool = False,
    engine: bool = False,
    **engine_kwargs,
) -> jnp.ndarray:
    """Forward/inverse separable 3D discrete orthogonal transform (Eq. 1/2).

    ``engine=True`` routes through the planned execution engine
    (``repro.engine``): the stage order is chosen by the cost model (the
    ``order`` argument is ignored) and each stage runs on the Pallas kernel
    dispatch; ``engine_kwargs`` (e.g. ``autotune=True``, or
    ``differentiable=True`` for a ``jax.grad``-safe engine-lowered
    backward pass) pass through.
    """
    from ..obs import trace as _trace
    from .transforms import coefficient_matrix, inverse_coefficient_matrix

    sp = _trace.NULL_SPAN
    if _trace.enabled():
        sp = _trace.span(f"dxt3d:{kind}",
                         {"kind": kind, "inverse": bool(inverse),
                          "engine": bool(engine), "shape": tuple(x.shape)})
    with sp:
        build = inverse_coefficient_matrix if inverse else coefficient_matrix
        n1, n2, n3 = x.shape
        c1, c2, c3 = build(kind, n1), build(kind, n2), build(kind, n3)
        if jnp.iscomplexobj(c1) and not jnp.iscomplexobj(x):
            x = x.astype(c1.dtype)
        if engine:
            return gemt3_planned(x, c1, c2, c3, out=out, **engine_kwargs)
        fn = gemt3_outer if outer else gemt3
        return fn(x, c1, c2, c3, order=order, out=out)


def macs(n1: int, n2: int, n3: int) -> int:
    """Hypercubic arithmetic complexity of the staged GEMT (paper §3)."""
    return n1 * n2 * n3 * (n1 + n2 + n3)


def time_steps(n1: int, n2: int, n3: int) -> int:
    """Linear number of TriADA time-steps (paper §5.4)."""
    return n1 + n2 + n3
