"""Distributed 3D-GEMT: the TriADA dataflow on a TPU mesh.

The paper's central distribution insight (§4–§5): the data tensor is
**stationary** — it keeps one placement through all three stages — while the
small square coefficient matrices are **streamed/broadcast** into the
processing space.  On a TPU mesh this becomes:

  * the 3-mode tensor is sharded once, e.g. ``P('data', 'model', None)``
    (single-pod) or ``P('data', 'model', 'pod')`` (multi-pod: the mesh *is*
    the 3D processing space — mode-s ↔ mesh-axis isomorphism, paper Eq. 7),
  * coefficient matrices are replicated (``P()``): the ICI broadcast is the
    Actuator's operand-bus multicast,
  * a stage contracting an *unsharded* mode is entirely local,
  * a stage contracting a *sharded* mode computes local partial rank-k
    updates (the outer-product schedule restricted to the local coefficient
    rows) and combines them with a single ``psum_scatter`` over that axis —
    the output lands with exactly the input's sharding.  **No resharding,
    no transposition, no tensor movement between stages.**

Two implementations:

  * ``gemt3_shardmap`` — the TriADA schedule (shard_map + psum_scatter,
    collectives hand-placed).  Since PR 3 it **delegates to the execution
    engine** (``repro.engine.gemt3_planned(mesh=...)``): the local stages
    run the planned Pallas kernel dispatch (sr_gemm / block-ESOP / fused
    VMEM pairs where shard-local) instead of raw einsum, and the planner's
    sharded cost model owns the stage ordering.  ``engine=False`` keeps
    the original pure-einsum schedule as a measurable baseline,
  * ``gemt3_auto``     — jit + sharding constraints (XLA GSPMD chooses the
    collectives) — the baseline the roofline compares against.

Mesh recipes and the per-stage data-movement walkthrough live in
``docs/distributed.md``; the paper↔module map in ``docs/architecture.md``.
"""
from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from ..compat import shard_map

__all__ = ["gemt3_shardmap", "gemt3_auto", "tensor_spec"]

AxisName = str | tuple[str, ...] | None


def tensor_spec(axes: Sequence[AxisName]) -> P:
    """PartitionSpec for the stationary tensor from per-mode mesh axes."""
    return P(*axes)


def _axis_size(mesh: Mesh, axis: AxisName) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        return int(jnp.prod(jnp.array([mesh.shape[a] for a in axis])))
    return mesh.shape[axis]


def _local_stage(y_local: jnp.ndarray, coeff: jnp.ndarray, mode: int,
                 axis: AxisName, mesh: Mesh) -> jnp.ndarray:
    """One GEMT stage on the local shard; combine over ``axis`` if sharded."""
    from .gemt import mode_product

    if axis is None:
        # Unsharded contraction mode: stage is fully local (the streamed
        # coefficient matrix is already replicated on every device).
        return mode_product(y_local, coeff, mode)

    # Sharded contraction mode: this device owns rows
    # [idx*local_n, (idx+1)*local_n) of the contracted extent.  It executes
    # the outer-product schedule for *its* coefficient rows — a partial
    # rank-(local_n) update of the full output extent — and one
    # psum_scatter re-distributes k_s over the same mesh axis: the tensor
    # never moves, only partial sums are combined.
    names = axis if isinstance(axis, tuple) else (axis,)
    idx = jnp.zeros((), jnp.int32)
    for name in names:  # row-major linear index over the (possibly tuple) axis
        idx = idx * mesh.shape[name] + jax.lax.axis_index(name)
    local_n = y_local.shape[mode - 1]
    rows = jax.lax.dynamic_slice_in_dim(coeff, idx * local_n, local_n, 0)
    partial = mode_product(y_local, rows, mode)  # full K_s extent, partial sum
    moved = jnp.moveaxis(partial, mode - 1, 0)
    combined = jax.lax.psum_scatter(moved, names, scatter_dimension=0, tiled=True)
    return jnp.moveaxis(combined, 0, mode - 1)


def gemt3_shardmap(
    mesh: Mesh,
    axes: Sequence[AxisName] = ("data", "model", None),
    order: Sequence[int] | None = (3, 1, 2),
    *,
    engine: bool = True,
    **engine_kwargs,
):
    """Build the TriADA-scheduled distributed GEMT: f(x, c1, c2, c3) -> y.

    ``axes[s-1]`` is the mesh axis sharding mode s of the stationary tensor
    (None = unsharded).  Every mode extent (and, for sharded modes, the
    coefficient output extent K_s) must divide its axis size.

    ``engine=True`` (default) delegates to the topology-aware execution
    engine: the identical collective schedule, with the local stages
    lowered through the planned Pallas kernel dispatch and ``order=None``
    unlocking the sharded cost-model order search.  ``engine_kwargs``
    (``use_pallas``, ``fuse``, ``autotune``, ``batch_axis``, …) pass
    through to :func:`repro.engine.gemt3_planned`.  ``engine=False`` is
    the original einsum-only schedule (benchmark baseline).
    """
    if engine:
        from ..engine import gemt3_planned as _planned

        axes_t = tuple(tuple(a) if isinstance(a, list) else a for a in axes)
        order_t = tuple(order) if order is not None else None

        def f(x, c1, c2, c3):
            return _planned(x, c1, c2, c3, mesh=mesh, axes=axes_t,
                            order=order_t, **engine_kwargs)

        return f

    if engine_kwargs:
        raise TypeError(f"engine=False takes no engine kwargs, "
                        f"got {sorted(engine_kwargs)}")
    spec = tensor_spec(axes)

    def f(x, c1, c2, c3):
        cs = {1: c1, 2: c2, 3: c3}
        y = x
        for mode in order:
            y = _local_stage(y, cs[mode], mode, axes[mode - 1], mesh)
        return y

    return shard_map(
        f,
        mesh=mesh,
        in_specs=(spec, P(), P(), P()),
        out_specs=spec,
        check_vma=False,
    )


def gemt3_auto(
    mesh: Mesh,
    axes: Sequence[AxisName] = ("data", "model", None),
    order: Sequence[int] = (3, 1, 2),
):
    """GSPMD baseline: same stationary-spec pinning, XLA picks collectives."""
    spec = tensor_spec(axes)

    def f(x, c1, c2, c3):
        from .gemt import mode_product

        cs = {1: c1, 2: c2, 3: c3}
        y = jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
        for mode in order:
            y = mode_product(y, cs[mode], mode)
            y = jax.lax.with_sharding_constraint(y, NamedSharding(mesh, spec))
        return y

    return jax.jit(
        f,
        in_shardings=(NamedSharding(mesh, spec),) + (NamedSharding(mesh, P()),) * 3,
        out_shardings=NamedSharding(mesh, spec),
    )
