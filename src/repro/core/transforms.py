"""Coefficient (change-of-basis) matrix builders for the 3D-DXT family.

The paper (§2.2) parameterizes the whole family of trilinear discrete
orthogonal transforms by the square, invertible coefficient matrix C:

  * DFT  — complex, symmetric, unitary:      c[n,k] = exp(-2πi·nk/N)/√N
  * DHT  — real, symmetric, orthogonal:      c[n,k] = (cos+sin)(2π·nk/N)/√N
  * DCT  — real, orthogonal (DCT-II):        c[n,k] = s_k·cos(π(2n+1)k/2N)
  * DWHT — ±1, symmetric, orthogonal:        Hadamard/√N (N = power of two)

All builders return *orthonormal* matrices so that the inverse transform is
the (conjugate) transpose — `C⁻¹ = C*ᵀ` — and `forward ∘ inverse = id` holds
to float tolerance.  None of them require N to be a power of two (except the
Walsh–Hadamard transform, where pow-2 is intrinsic to the transform itself,
not to the algorithm — paper §1 & §3 stress this generality).
"""
from __future__ import annotations

import functools

import jax.numpy as jnp
import numpy as np

__all__ = [
    "dft_matrix",
    "dht_matrix",
    "dct2_matrix",
    "dwht_matrix",
    "coefficient_matrix",
    "inverse_coefficient_matrix",
    "TRANSFORM_KINDS",
]

TRANSFORM_KINDS = ("dft", "dht", "dct", "dwht")


@functools.lru_cache(maxsize=64)
def _grid(n: int) -> np.ndarray:
    i = np.arange(n)
    return np.outer(i, i)


def dft_matrix(n: int, dtype=jnp.complex64) -> jnp.ndarray:
    """Unitary DFT matrix: C[n,k] = exp(-2πi nk / N) / sqrt(N)."""
    nk = _grid(n)
    mat = np.exp(-2j * np.pi * nk / n) / np.sqrt(n)
    return jnp.asarray(mat, dtype=dtype)


def dht_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthonormal Hartley matrix: C[n,k] = cas(2π nk/N)/sqrt(N), cas = cos+sin."""
    ang = 2.0 * np.pi * _grid(n) / n
    mat = (np.cos(ang) + np.sin(ang)) / np.sqrt(n)
    return jnp.asarray(mat, dtype=dtype)


def dct2_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthonormal DCT-II matrix: C[n,k] = s_k cos(π (2n+1) k / 2N).

    s_0 = sqrt(1/N), s_k = sqrt(2/N) for k > 0.  C is orthogonal but (unlike
    DFT/DHT) not symmetric: C ≠ Cᵀ (paper §2.2).
    """
    n_idx = np.arange(n)[:, None]
    k_idx = np.arange(n)[None, :]
    mat = np.cos(np.pi * (2 * n_idx + 1) * k_idx / (2 * n))
    scale = np.full((1, n), np.sqrt(2.0 / n))
    scale[0, 0] = np.sqrt(1.0 / n)
    return jnp.asarray(mat * scale, dtype=dtype)


def dwht_matrix(n: int, dtype=jnp.float32) -> jnp.ndarray:
    """Orthonormal Walsh–Hadamard matrix (natural/Hadamard order); N must be 2^k."""
    if n & (n - 1):
        raise ValueError(f"DWHT requires power-of-two size, got {n}")
    h = np.array([[1.0]])
    while h.shape[0] < n:
        h = np.block([[h, h], [h, -h]])
    return jnp.asarray(h / np.sqrt(n), dtype=dtype)


_BUILDERS = {
    "dft": dft_matrix,
    "dht": dht_matrix,
    "dct": dct2_matrix,
    "dwht": dwht_matrix,
}


def coefficient_matrix(kind: str, n: int, dtype=None) -> jnp.ndarray:
    """Forward coefficient matrix for a named transform kind."""
    kind = kind.lower()
    if kind not in _BUILDERS:
        raise ValueError(f"unknown transform kind {kind!r}; choose from {TRANSFORM_KINDS}")
    if dtype is None:
        return _BUILDERS[kind](n)
    return _BUILDERS[kind](n, dtype=dtype)


def inverse_coefficient_matrix(kind: str, n: int, dtype=None) -> jnp.ndarray:
    """Inverse = conjugate transpose (orthonormal builders)."""
    c = coefficient_matrix(kind, n, dtype=dtype)
    return jnp.conj(c).T
