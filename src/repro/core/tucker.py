"""Tucker compression/expansion via 3D-GEMT (paper §2.3).

The GEMT engine with rectangular coefficient matrices *is* the Tucker
reconstruction (expansion) and — with factor transposes — the core-tensor
projection (compression).  HOSVD factor initialization is provided so the
round-trip is a best-rank-(K1,K2,K3) approximation.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from .gemt import gemt3

__all__ = ["hosvd", "tucker_compress", "tucker_expand", "tucker_roundtrip_error"]


def _mode_unfold(x: np.ndarray, mode: int) -> np.ndarray:
    return np.moveaxis(x, mode - 1, 0).reshape(x.shape[mode - 1], -1)


def hosvd(x: jnp.ndarray, ranks: tuple[int, int, int]) -> tuple[jnp.ndarray, ...]:
    """Truncated higher-order SVD factors U_s (N_s × K_s), per mode."""
    xn = np.asarray(x)
    factors = []
    for mode, k in zip((1, 2, 3), ranks):
        u, _, _ = np.linalg.svd(_mode_unfold(xn, mode), full_matrices=False)
        factors.append(jnp.asarray(u[:, :k]))
    return tuple(factors)


def tucker_compress(x: jnp.ndarray, factors: tuple[jnp.ndarray, ...]) -> jnp.ndarray:
    """Core tensor G = X ×₁U1ᵀ ×₂U2ᵀ ×₃U3ᵀ — GEMT with compressive C_s."""
    u1, u2, u3 = factors
    return gemt3(x, u1, u2, u3)  # C_s = U_s: (N_s, K_s), K_s <= N_s


def tucker_expand(core: jnp.ndarray, factors: tuple[jnp.ndarray, ...]) -> jnp.ndarray:
    """Reconstruction X̂ = G ×₁U1 ×₂U2 ×₃U3 — GEMT with expansive C_s."""
    u1, u2, u3 = factors
    return gemt3(core, u1.T, u2.T, u3.T)


def tucker_roundtrip_error(x: jnp.ndarray, ranks: tuple[int, int, int]) -> dict:
    """Relative Frobenius error of the rank-(K1,K2,K3) GEMT round trip."""
    factors = hosvd(x, ranks)
    core = tucker_compress(x, factors)
    xhat = tucker_expand(core, factors)
    num = float(jnp.linalg.norm((xhat - x).ravel()))
    den = float(jnp.linalg.norm(jnp.asarray(x).ravel())) or 1.0
    n1, n2, n3 = x.shape
    k1, k2, k3 = ranks
    return {
        "rel_fro_err": num / den,
        "compression": (n1 * n2 * n3) / (k1 * k2 * k3 + n1 * k1 + n2 * k2 + n3 * k3),
    }
