"""ESOP — Elastic Sparse Outer-Product processing (paper §6).

The outer-product formulation lets TriADA skip *both* compute and
communication on zero operands:

  * an all-zero streamed coefficient vector is never sent by the actuator
    (saves a whole time-step),
  * zero coefficients (tag=0) are never put on an operand bus,
  * pivot cells holding a zero data element do not broadcast it, leaving all
    cells on that bus idle for the step.

On TPU the per-element mechanism has no MXU analogue, so the production path
is **block-ESOP** (`kernels/esop_gemm.py`): whole MXU blocks are skipped when
a block of the streamed coefficient matrix (or of the resident tensor) is
zero.  This module provides

  * exact, vectorized *accounting* of the paper's per-element model
    (`esop_stage_counts`, `esop_gemt3`) — how many MACs / sends / time-steps
    the cellular device would skip,
  * a simple energy model (`energy_joules`) used by the benchmarks,
  * block-mask construction shared with the Pallas kernel,
  * threshold pruning for the "insignificant values" regime and an
    accuracy-accounting helper (`accumulation_error`) for the paper's
    accuracy/stability claim.

Note on exactness: skipping true zeros is *bit-exact* (x + 0·c == x in IEEE
arithmetic except for signed-zero), so ESOP results equal the dense results;
the accuracy benefit materializes in the pruning regime, where shorter
accumulation chains accumulate less rounding error — quantified in
``benchmarks/esop_accuracy.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "EsopStats",
    "sparsity",
    "esop_stage_counts",
    "esop_gemt3",
    "block_nonzero_mask",
    "prune",
    "energy_joules",
    "accumulation_error",
]


@dataclasses.dataclass
class EsopStats:
    """Operation accounting for one or more ESOP stages (device model units)."""

    macs_dense: int  # MACs the dense schedule would execute
    macs_done: int  # MACs actually executed under ESOP
    steps_dense: int  # time-steps of the dense schedule (Σ N_s)
    steps_done: int  # time-steps after all-zero-vector skipping
    coeff_sends_dense: int  # coefficient-element bus transactions, dense
    coeff_sends_done: int  # after zero-coefficient suppression
    data_sends_dense: int  # pivot-cell data broadcasts, dense
    data_sends_done: int  # after zero-data suppression

    def __add__(self, other: "EsopStats") -> "EsopStats":
        return EsopStats(*(getattr(self, f.name) + getattr(other, f.name)
                           for f in dataclasses.fields(self)))

    @property
    def macs_skipped(self) -> int:
        return self.macs_dense - self.macs_done

    @property
    def mac_savings(self) -> float:
        return self.macs_skipped / max(self.macs_dense, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mac_savings"] = self.mac_savings
        return d


def sparsity(x: jnp.ndarray) -> float:
    """Fraction of exactly-zero elements."""
    return float(jnp.mean((x == 0).astype(jnp.float32)))


def esop_stage_counts(resident: jnp.ndarray, coeff: jnp.ndarray, mode: int) -> EsopStats:
    """Exact ESOP accounting for one stage contracting ``mode`` (vectorized).

    At time-step n the actuator streams coefficient row ``coeff[n, :]``
    (length K) and the n-th mode-``mode`` slice of ``resident`` (A×B cells)
    forms the data vector.  Cell (a, b, k) executes a MAC iff both its data
    element and its coefficient are nonzero.
    """
    r = np.moveaxis(np.asarray(resident), mode - 1, 0)  # (N, A, B)
    n = r.shape[0]
    ab = r.shape[1] * r.shape[2]
    coeff = np.asarray(coeff)
    k = coeff.shape[1]

    x_nnz = np.sum((r != 0).reshape(n, -1), axis=1, dtype=np.int64)  # per step
    c_nnz = np.sum(coeff != 0, axis=1, dtype=np.int64)
    step_live = (c_nnz > 0).astype(np.int64)  # all-zero vector => skip step

    macs_done = int(np.sum(x_nnz * c_nnz))
    return EsopStats(
        macs_dense=int(n) * ab * k,
        macs_done=macs_done,
        steps_dense=int(n),
        steps_done=int(np.sum(step_live)),
        coeff_sends_dense=int(n) * k,
        coeff_sends_done=int(np.sum(c_nnz)),
        data_sends_dense=int(n) * ab,
        data_sends_done=int(np.sum(x_nnz * step_live)),
    )


def esop_gemt3(
    x: jnp.ndarray,
    c1: jnp.ndarray,
    c2: jnp.ndarray,
    c3: jnp.ndarray,
    order: Sequence[int] = (3, 1, 2),
) -> tuple[jnp.ndarray, EsopStats]:
    """3-stage GEMT with ESOP accounting.  Result is bit-identical to dense."""
    from .gemt import mode_product

    cs = {1: c1, 2: c2, 3: c3}
    stats: EsopStats | None = None
    y = x
    for mode in order:
        s = esop_stage_counts(y, cs[mode], mode)
        stats = s if stats is None else stats + s
        y = mode_product(y, cs[mode], mode)
    assert stats is not None
    return y, stats


def block_nonzero_mask(a: jnp.ndarray, block: tuple[int, int]) -> jnp.ndarray:
    """(rows/bm, cols/bn) boolean mask: True where the block has any nonzero.

    Shared between the ESOP accounting and the Pallas block-ESOP kernel
    (`kernels/esop_gemm.py`).  Dimensions must divide evenly (pad upstream).
    """
    bm, bn = block
    m, n = a.shape
    if m % bm or n % bn:
        raise ValueError(f"shape {a.shape} not divisible by block {block}")
    blocks = a.reshape(m // bm, bm, n // bn, bn)
    return jnp.any(blocks != 0, axis=(1, 3))


def prune(x: jnp.ndarray, threshold: float) -> jnp.ndarray:
    """Zero out 'insignificant' values (|x| < threshold) — paper §6 regime."""
    return jnp.where(jnp.abs(x) < threshold, jnp.zeros_like(x), x)


def energy_joules(
    stats: EsopStats,
    e_mac: float = 1.0e-12,
    e_coeff_send: float = 2.0e-12,
    e_data_send: float = 2.0e-12,
) -> dict:
    """Simple dynamic-energy model (defaults ~pJ-scale per op/transaction).

    Returns dense vs ESOP energy and the saving fraction.  The absolute
    constants are placeholders for a device model; the *ratio* is the
    paper-relevant quantity.
    """
    dense = (stats.macs_dense * e_mac
             + stats.coeff_sends_dense * e_coeff_send
             + stats.data_sends_dense * e_data_send)
    esop = (stats.macs_done * e_mac
            + stats.coeff_sends_done * e_coeff_send
            + stats.data_sends_done * e_data_send)
    return {"dense_j": dense, "esop_j": esop,
            "saving": (dense - esop) / max(dense, 1e-30)}


def accumulation_error(x, c1, c2, c3, order=(3, 1, 2)) -> dict:
    """Rounding-error accounting: fp32 staged GEMT vs fp64 oracle.

    Used by ``benchmarks/esop_accuracy.py`` to quantify the paper's claim
    that shorter accumulation chains (ESOP + pruning) reduce rounding error.
    """
    from .gemt import gemt3

    f64 = [np.asarray(a, dtype=np.float64) for a in (x, c1, c2, c3)]
    ref = gemt3(*[jnp.asarray(a) for a in f64], order=order)
    f32 = gemt3(*[jnp.asarray(a, dtype=jnp.float32) for a in (x, c1, c2, c3)],
                order=order)
    err = jnp.asarray(f32, jnp.float64) - ref
    denom = float(jnp.max(jnp.abs(ref))) or 1.0
    return {
        "max_abs_err": float(jnp.max(jnp.abs(err))),
        "rel_err": float(jnp.max(jnp.abs(err)) / denom),
        "rms_err": float(jnp.sqrt(jnp.mean(err * err))),
    }
