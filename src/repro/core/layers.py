"""The paper's technique as first-class NN layers.

* ``TriadaDense`` — a Tucker-factorized linear layer ``y = x·U_in·G·U_out``:
  the GEMT compression/expansion case (paper §2.3) applied to a weight
  matrix; backed by the same chained-GEMM dataflow the SR-GEMM kernel
  implements (square-ish core streamed, activations resident).
* ``Triada3DMixer`` — DXT-based token/channel mixing (FNet-style): activations
  ``(B, S, D)`` are treated as a 3-mode tensor and transformed along S and D
  by orthonormal DCT/DHT matrices via the GEMT engine.  This is literally the
  paper's bilinear transform of each batch slice (identity on mode 1).
* ``Dxt3dLayer`` — a *learned* trilinear transform on volumetric batches
  ``(B, N1, N2, N3)``: the three coefficient factors are parameters
  (initialized at the orthonormal DXT basis, optionally truncated to
  Tucker ranks) and the forward pass runs the planned engine with
  ``differentiable=True``, so ``jax.grad`` lowers the backward pass as the
  adjoint-planned GEMT + SR-GEMM factor updates (docs/engine.md,
  "Differentiation").  ``train.step.build_dxt_fit_step`` trains it.

Pure-functional: ``init_*`` returns a params pytree; ``apply_*`` consumes it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gemt import gemt3_planned, mode_product
from .transforms import coefficient_matrix

__all__ = [
    "init_triada_dense",
    "apply_triada_dense",
    "make_mixer_coeffs",
    "apply_triada_mixer",
    "init_dxt3d_layer",
    "apply_dxt3d_layer",
]


def init_triada_dense(key, d_in: int, d_out: int, rank: int,
                      dtype=jnp.float32) -> dict:
    """Tucker-2 factorization of a (d_in, d_out) weight: U_in·G·U_out."""
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_in ** -0.5
    return {
        "u_in": (jax.random.normal(k1, (d_in, rank)) * scale_in).astype(dtype),
        "core": (jax.random.normal(k2, (rank, rank)) * rank ** -0.5).astype(dtype),
        "u_out": (jax.random.normal(k3, (rank, d_out)) * rank ** -0.5).astype(dtype),
    }


def apply_triada_dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Chained GEMM schedule: each stage's output is the next stage's resident
    operand (the SR-GEMM chaining case of paper §5.1)."""
    y = x @ params["u_in"]
    y = y @ params["core"]
    return y @ params["u_out"]


def make_mixer_coeffs(seq_len: int, d_model: int, kind: str = "dct",
                      dtype=jnp.float32) -> dict:
    """Precomputed orthonormal coefficient matrices for the mixer (the
    'Actuator contents' — constants, as paper §2.2 notes they can be)."""
    return {
        "c_seq": coefficient_matrix(kind, seq_len, dtype=dtype),
        "c_dim": coefficient_matrix(kind, d_model, dtype=dtype),
    }


def apply_triada_mixer(coeffs: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Bilinear DXT mixing of (B, S, D): X ×₂ C_seq ×₃ C_dim via the GEMT
    engine (mode 1 = batch is untouched)."""
    y = mode_product(x, coeffs["c_seq"].astype(x.dtype), 2)
    y = mode_product(y, coeffs["c_dim"].astype(x.dtype), 3)
    return y


def init_dxt3d_layer(dims: tuple[int, int, int],
                     ranks: tuple[int, int, int] | None = None,
                     kind: str = "dct", key=None, init_scale: float = 0.0,
                     dtype=None) -> dict:
    """Learnable trilinear-transform parameters ``{"c1", "c2", "c3"}``.

    Each factor starts at the orthonormal DXT coefficient matrix (paper
    §2.2), truncated to the first ``ranks[s]`` basis columns for Tucker
    compression (§2.3) — the exact-transform starting point that fitting
    then refines.  ``key``/``init_scale`` optionally add Gaussian noise to
    break the symmetry of the orthonormal start.  ``dtype=None`` keeps the
    transform's natural dtype (complex for the DFT); requesting a real
    dtype for a complex kind raises rather than silently dropping the
    imaginary part.
    """
    ranks = tuple(ranks) if ranks is not None else tuple(dims)
    params = {}
    for i, (n, k) in enumerate(zip(dims, ranks), 1):
        if k > n:
            raise ValueError(f"rank {k} exceeds mode-{i} extent {n}")
        c = coefficient_matrix(kind, n)[:, :k]
        if dtype is not None:
            if (jnp.iscomplexobj(c)
                    and not jnp.issubdtype(jnp.dtype(dtype),
                                           jnp.complexfloating)):
                raise ValueError(
                    f"kind={kind!r} has complex coefficients; dtype={dtype} "
                    f"would drop the imaginary part (use dtype=None or a "
                    f"complex dtype)")
            c = c.astype(dtype)
        if key is not None and init_scale > 0.0:
            key, sub = jax.random.split(key)
            noise = jax.random.normal(sub, c.shape)
            if jnp.iscomplexobj(c):
                key, sub = jax.random.split(key)
                noise = noise + 1j * jax.random.normal(sub, c.shape)
            c = c + init_scale * noise.astype(c.dtype)
        params[f"c{i}"] = c
    return params


def apply_dxt3d_layer(params: dict, x: jnp.ndarray,
                      **engine_kwargs) -> jnp.ndarray:
    """Apply the learned trilinear transform to ``(B, N1, N2, N3)`` (or
    unbatched 3D) input through the planned engine, differentiably.

    The engine's custom VJP makes the whole layer ``jax.grad``-safe at
    engine speed: the input cotangent replans as the adjoint GEMT over the
    transposed factors, the factor cotangents are mode-unfolded rank-k
    SR-GEMM updates.  ``engine_kwargs`` (``fuse=``, ``autotune=``,
    ``mesh=``, …) pass through to :func:`repro.engine.gemt3_planned`;
    ``differentiable`` defaults to True here (the layer exists to be
    trained) but an explicit override is honoured.
    """
    engine_kwargs.setdefault("differentiable", True)
    return gemt3_planned(x, params["c1"], params["c2"], params["c3"],
                         **engine_kwargs)
