"""The paper's technique as first-class NN layers.

* ``TriadaDense`` — a Tucker-factorized linear layer ``y = x·U_in·G·U_out``:
  the GEMT compression/expansion case (paper §2.3) applied to a weight
  matrix; backed by the same chained-GEMM dataflow the SR-GEMM kernel
  implements (square-ish core streamed, activations resident).
* ``Triada3DMixer`` — DXT-based token/channel mixing (FNet-style): activations
  ``(B, S, D)`` are treated as a 3-mode tensor and transformed along S and D
  by orthonormal DCT/DHT matrices via the GEMT engine.  This is literally the
  paper's bilinear transform of each batch slice (identity on mode 1).

Pure-functional: ``init_*`` returns a params pytree; ``apply_*`` consumes it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .gemt import mode_product
from .transforms import coefficient_matrix

__all__ = [
    "init_triada_dense",
    "apply_triada_dense",
    "make_mixer_coeffs",
    "apply_triada_mixer",
]


def init_triada_dense(key, d_in: int, d_out: int, rank: int,
                      dtype=jnp.float32) -> dict:
    """Tucker-2 factorization of a (d_in, d_out) weight: U_in·G·U_out."""
    k1, k2, k3 = jax.random.split(key, 3)
    scale_in = d_in ** -0.5
    return {
        "u_in": (jax.random.normal(k1, (d_in, rank)) * scale_in).astype(dtype),
        "core": (jax.random.normal(k2, (rank, rank)) * rank ** -0.5).astype(dtype),
        "u_out": (jax.random.normal(k3, (rank, d_out)) * rank ** -0.5).astype(dtype),
    }


def apply_triada_dense(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Chained GEMM schedule: each stage's output is the next stage's resident
    operand (the SR-GEMM chaining case of paper §5.1)."""
    y = x @ params["u_in"]
    y = y @ params["core"]
    return y @ params["u_out"]


def make_mixer_coeffs(seq_len: int, d_model: int, kind: str = "dct",
                      dtype=jnp.float32) -> dict:
    """Precomputed orthonormal coefficient matrices for the mixer (the
    'Actuator contents' — constants, as paper §2.2 notes they can be)."""
    return {
        "c_seq": coefficient_matrix(kind, seq_len, dtype=dtype),
        "c_dim": coefficient_matrix(kind, d_model, dtype=dtype),
    }


def apply_triada_mixer(coeffs: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Bilinear DXT mixing of (B, S, D): X ×₂ C_seq ×₃ C_dim via the GEMT
    engine (mode 1 = batch is untouched)."""
    y = mode_product(x, coeffs["c_seq"].astype(x.dtype), 2)
    y = mode_product(y, coeffs["c_dim"].astype(x.dtype), 3)
    return y
