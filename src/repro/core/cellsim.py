"""Cycle-level simulator of the TriADA cell network (paper §5, Figs. 2–5).

A software model of the isomorphic device: an ``N1×N2×N3`` grid of
compute-storage-communication cells, three face-attached Decoupled Active
Streaming Memories ("Actuators"), tag-driven coordinate-free cell activity,
and the ESOP skip rules.  One simulator step == one TriADA time-step.

Used by tests and benchmarks to validate, at small N, that

  * the device computes exactly ``gemt3`` (all six stage orders),
  * the dense schedule takes exactly ``N1+N2+N3`` time-steps,
  * the MAC count matches ``N1·N2·N3·(N1+N2+N3)``,
  * ESOP skips match the analytic accounting in ``core/esop.py``,
  * cell activity is coordinate-free: the per-step rule consults only the
    streamed (c, tag) pair and local state, never the cell's coordinates or
    the problem size.

The per-time-step loop is intentionally explicit (this is a device model,
not a performance path); the within-step cell updates are vectorized since
all cells act simultaneously in one time-step.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .esop import EsopStats

__all__ = ["TriadaCellGrid", "simulate_dxt3"]


@dataclasses.dataclass
class StageTrace:
    time_steps: int
    macs: int
    coeff_sends: int
    data_sends: int


class TriadaCellGrid:
    """The 3D processing/storage/communication space PS (paper Eq. 7)."""

    def __init__(self, n1: int, n2: int, n3: int, esop: bool = True,
                 dtype=np.float32):
        self.shape = (n1, n2, n3)
        self.esop = esop
        self.dtype = dtype
        # Local cell memories: resident tensor element + accumulator.
        self.resident = np.zeros(self.shape, dtype)
        self.acc = np.zeros(self.shape, dtype)
        self.trace: list[StageTrace] = []

    def load(self, x: np.ndarray) -> None:
        if x.shape != self.shape:
            raise ValueError(f"tensor {x.shape} != grid {self.shape}")
        self.resident = np.array(x, dtype=self.dtype)

    # -- one stage = one actuator streaming its tagged coefficient matrix ----
    def run_stage(self, coeff: np.ndarray, mode: int, init: np.ndarray | None = None) -> None:
        """Stream ``coeff`` (N_s × K_s, diagonal-tagged) along mode ``mode``.

        Each iteration of the loop below is one global time-step: the
        actuator broadcasts one tagged coefficient vector; tag=1 activates
        the pivotal cell plane, which broadcasts the data vector on the
        orthogonal buses; every cell then MACs its (c_in, x_in) pair.
        """
        n_s, k_s = coeff.shape
        if self.resident.shape[mode - 1] != n_s:
            raise ValueError("coefficient rows must match contracted extent")
        if k_s != self.resident.shape[mode - 1]:
            # Rectangular C (GEMT proper) changes the mode extent; the
            # resident grid must be pre-sized to max — enforce square here
            # (the DXT case the device chapter describes) for simplicity.
            raise ValueError("cell simulator models the square-C DXT case")
        r = np.moveaxis(self.resident, mode - 1, 0)  # (N_s, A, B) view
        acc = np.zeros_like(r) if init is None else np.moveaxis(
            np.array(init, self.dtype), mode - 1, 0).copy()
        # acc laid out as (K_s, A, B): acc[k] lives in the cells' k-plane.
        steps = macs = c_sends = d_sends = 0
        for n in range(n_s):  # ---- discrete time (paper's ↻N_s) ----
            c_vec = coeff[n]  # tagged vector; tag=1 at pivot position n
            if self.esop and not c_vec.any():
                continue  # actuator skips all-zero vector: no time-step
            steps += 1
            # tag=1 reaches the pivotal plane regardless of value; zero
            # non-pivot coefficients are never put on the bus (ESOP).
            c_live = c_vec != 0
            c_sends += int(c_live.sum()) if self.esop else k_s
            x_plane = r[n]  # (A, B) pivotal data plane
            if self.esop:
                x_live = x_plane != 0
                d_sends += int(x_live.sum())
                # Cells on a bus whose pivot holds zero stay waiting — no MAC.
                upd = np.where(x_live[None, :, :],
                               c_vec[:, None, None] * x_plane[None, :, :], 0)
                macs += int(x_live.sum()) * int(c_live.sum())
            else:
                d_sends += x_plane.size
                upd = c_vec[:, None, None] * x_plane[None, :, :]
                macs += x_plane.size * k_s
            acc += upd.astype(self.dtype)
        self.resident = np.moveaxis(acc, 0, mode - 1)
        self.trace.append(StageTrace(steps, macs, c_sends, d_sends))

    # -- full trilinear transform -------------------------------------------
    def run_gemt3(self, c1, c2, c3, order=(3, 1, 2)) -> np.ndarray:
        cs = {1: np.asarray(c1), 2: np.asarray(c2), 3: np.asarray(c3)}
        for mode in order:
            self.run_stage(cs[mode].astype(self.dtype), mode)
        return self.resident

    @property
    def stats(self) -> EsopStats:
        n1, n2, n3 = self.shape
        total = EsopStats(
            macs_dense=n1 * n2 * n3 * (n1 + n2 + n3),
            macs_done=sum(t.macs for t in self.trace),
            steps_dense=n1 + n2 + n3,
            steps_done=sum(t.time_steps for t in self.trace),
            coeff_sends_dense=n1 * n1 + n2 * n2 + n3 * n3,
            coeff_sends_done=sum(t.coeff_sends for t in self.trace),
            data_sends_dense=n1 * n2 * n3 * 3,
            data_sends_done=sum(t.data_sends for t in self.trace),
        )
        return total


def simulate_dxt3(x: np.ndarray, c1, c2, c3, order=(3, 1, 2), esop: bool = True):
    """Run a full trilinear transform on the simulated device.

    Returns (result, EsopStats).
    """
    grid = TriadaCellGrid(*x.shape, esop=esop, dtype=np.asarray(x).dtype)
    grid.load(np.asarray(x))
    out = grid.run_gemt3(c1, c2, c3, order=order)
    return out, grid.stats
