"""Resilient serving runtime: request lifecycle around ``DxtServeSession``.

:class:`ResilientDxtServer` wraps a session with the full lifecycle a
production transform service needs (``docs/serving.md``):

* **bounded admission** — a FIFO queue of ``max_queue`` requests;
  :meth:`submit` sheds (returns None, counts ``serve.shed``) when full,
  so overload backpressure is explicit instead of an unbounded backlog;
* **deadlines/timeouts** — an optional per-request deadline and a
  per-attempt latency SLO; an attempt that overruns the SLO counts
  ``serve.timeout`` and is retried like a failure (its result is
  discarded — a real RPC would have been cancelled);
* **retry with backoff** — bounded exponential backoff with
  *deterministic* jitter (hashed from request id + attempt, so drills
  and replays reproduce exactly), counted in ``serve.retry``;
* **a per-tier circuit breaker** driving the **degradation ladder**.

The ladder extends the planner's triple→pair→staged fusion fallback to
runtime failures.  Tiers, best first::

    auto    session defaults (cost-model fusion, Pallas kernels)
    pair    fuse="pair"
    staged  fuse=False
    einsum  fuse=False, backend="einsum"  (no Pallas at all)

Each tier has a :class:`CircuitBreaker`; repeated kernel failure opens a
tier's breaker and the next attempt replans one tier down (counted in
``serve.degraded`` and recorded as a ``runtime_degradation`` event on the
request's ``info["events"]``, next to the planner's own
``fusion_degradation`` events).  After ``cooldown_s`` the breaker goes
half-open, one probe request runs the higher tier again, and on success
the breaker closes (``serve.recovered``) — the ladder climbs back up.
The einsum tier is the floor: it is attempted even with its breaker open,
because shedding a request the queue already admitted is the one thing
the runtime never does.

A **finite-guard** (off by default; ``finite_check_every=N`` checks every
N-th attempt) catches *silent* corruption the exception paths never see:
a NaN/Inf output classifies as a retryable
:class:`repro.engine.numerics.NonfiniteOutput`, counted in
``numerics.nonfinite.detected``.  Recovery pins the request one ladder
rung below the failing tier (a per-request floor — the breaker ladder
still applies on top) and forces ``accum="compensated"`` on every
subsequent attempt, so the retry runs with guarded accumulation
(``docs/numerics.md``).  The ``nan`` fault kind of
:mod:`repro.runtime.faults` drills exactly this path: the injector arms a
poison flag, the runtime multiplies the transform output by NaN when the
flag is armed (:func:`repro.runtime.faults.consume_nan_poison`), and the
drill balances ``serve.retry`` / ``numerics.nonfinite.detected`` against
``faults.injected.nan``.

Two fault kinds bypass the ladder:

* **VMEM pressure** (:class:`repro.runtime.faults.VmemPressure`) —
  the request replans under a tightened ``vmem_budget`` (halved, floored
  at ``min_vmem_budget``); the engine's plan keys include the budget, so
  this is a fresh plan whose own fusion ladder may demote tiers;
* **device loss** (:class:`repro.runtime.faults.DeviceLoss`) — the mesh
  is rebuilt on the survivors via ``elastic.remesh_plan`` semantics (the
  leading axis absorbs the shrink, trailing model-parallel axes keep
  their degree), the session re-binds (``DxtServeSession.rebind_mesh``
  invalidates every plan and jitted ``shard_map`` program of the dead
  mesh), and the request replays on the surviving devices — counted in
  ``serve.remesh``.

All recovery is synchronous and per-request: an admitted request either
returns a result numerically matching the fault-free run or raises with
its last error after the retry budget/deadline is exhausted — it is never
silently dropped.  Chaos drills script faults with
:mod:`repro.runtime.faults` and balance the ``serve.*`` counters against
``faults.injected.*``.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Any, Callable

from ..engine.numerics import NonfiniteOutput, finite_guard
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..runtime.faults import DeviceLoss, VmemPressure, consume_nan_poison
from .decode import DxtServeSession

__all__ = [
    "LADDER_TIERS",
    "RetryPolicy",
    "CircuitBreaker",
    "Request",
    "Overloaded",
    "DeadlineExceeded",
    "ResilientDxtServer",
]

# Degradation ladder, best tier first; knobs are per-request overrides
# passed to DxtServeSession.transform (None = session default for "auto").
LADDER_TIERS = ("auto", "pair", "staged", "einsum")
_TIER_KNOBS: dict[str, dict] = {
    "auto": {},
    "pair": {"fuse": "pair"},
    "staged": {"fuse": False},
    "einsum": {"fuse": False, "backend": "einsum", "use_pallas": False},
}


class Overloaded(RuntimeError):
    """Admission queue full — the request was shed, not queued."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before an attempt succeeded."""


@dataclasses.dataclass
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``delay(attempt, token)`` is a pure function of its arguments: the
    jitter is hashed from ``(token, attempt)``, not drawn from a PRNG, so
    a replayed drill backs off identically.  ``max_attempts`` bounds the
    per-request retry budget (the einsum floor still failing that many
    times means the failure is real, not transient).
    """

    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.5  # fraction of the delay shaved off, in [0, 1)
    max_attempts: int = 16

    def delay(self, attempt: int, token: int = 0) -> float:
        d = min(self.base_delay_s * self.multiplier ** max(attempt - 1, 0),
                self.max_delay_s)
        if self.jitter <= 0.0:
            return d
        h = hashlib.sha256(f"{token}:{attempt}".encode()).digest()
        u = int.from_bytes(h[:4], "big") / 2.0 ** 32
        return d * (1.0 - self.jitter * u)


class CircuitBreaker:
    """closed → open after ``threshold`` consecutive failures → half-open
    after ``cooldown_s`` → closed on a successful probe (or re-open on a
    failed one).  ``clock`` is injectable for deterministic tests."""

    def __init__(self, threshold: int = 2, cooldown_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self.state = "closed"
        self.failures = 0
        self.opened_at = 0.0

    def allow(self) -> bool:
        if self.state == "open":
            if self._clock() - self.opened_at >= self.cooldown_s:
                self.state = "half_open"
        return self.state != "open"

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            self.state = "open"
            self.opened_at = self._clock()
            self.failures = 0

    def record_success(self) -> bool:
        """Returns True when this success *closed* a half-open breaker
        (a recovery, not steady state)."""
        recovered = self.state == "half_open"
        self.state = "closed"
        self.failures = 0
        return recovered


@dataclasses.dataclass
class Request:
    """One admitted transform request and its lifecycle record."""

    id: int
    batch: Any
    inverse: bool | None = None
    deadline: float | None = None  # absolute, on the server's clock
    status: str = "queued"  # queued | done | failed
    tier: str = "auto"  # tier of the last attempt
    attempts: int = 0
    retries: int = 0
    result: Any = None
    info: dict | None = None
    error: BaseException | None = None
    events: list = dataclasses.field(default_factory=list)
    # Nonfinite-recovery state: a per-request ladder floor (the failing
    # tier's successor) and a forced accumulation mode for retries.
    tier_floor: str | None = None
    force_accum: str | None = None


class ResilientDxtServer:
    """Fault-tolerant request lifecycle around a :class:`DxtServeSession`.

    Synchronous single-worker runtime: :meth:`submit` admits (or sheds),
    :meth:`drain` processes the queue in order, :meth:`transform` is the
    submit-and-drain convenience with the session's call signature.
    ``clock``/``sleep`` are injectable so tests drive breaker cooldowns
    and backoff deterministically.  ``devices`` overrides where remesh
    recovery looks for survivors (default ``jax.devices()``).
    """

    def __init__(self, session: DxtServeSession | None = None, *,
                 max_queue: int = 64,
                 default_deadline_s: float | None = None,
                 attempt_timeout_s: float | None = None,
                 retry: RetryPolicy | None = None,
                 breaker_threshold: int = 2,
                 breaker_cooldown_s: float = 1.0,
                 vmem_shrink: float = 0.5,
                 min_vmem_budget: int = 1 << 18,
                 finite_check_every: int = 0,
                 devices=None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 **session_kwargs):
        if session is not None and session_kwargs:
            raise ValueError("pass either a session or session kwargs")
        self.session = session or DxtServeSession(**session_kwargs)
        self.max_queue = int(max_queue)
        self.default_deadline_s = default_deadline_s
        self.attempt_timeout_s = attempt_timeout_s
        self.retry = retry or RetryPolicy()
        self.vmem_shrink = float(vmem_shrink)
        self.min_vmem_budget = int(min_vmem_budget)
        # 0 = finite-guard off; N > 0 checks every N-th attempt for
        # NaN/Inf (a host sync — sample, don't pay it on every request).
        self.finite_check_every = int(finite_check_every)
        self._finite_seq = 0
        self._devices = devices
        self._clock = clock
        self._sleep = sleep
        self._queue: deque[Request] = deque()
        self._next_id = 0
        self.breakers = {
            tier: CircuitBreaker(breaker_threshold, breaker_cooldown_s,
                                 clock=clock)
            for tier in LADDER_TIERS
        }
        # Runtime-tightened budget override; None = session/engine default.
        self.vmem_budget: int | None = None
        self.counts = {k: 0 for k in
                       ("admitted", "completed", "failed", "shed", "retries",
                        "timeouts", "degraded", "remeshes", "recovered",
                        "deadline_exceeded", "nonfinite")}

    # -- admission ---------------------------------------------------------

    def submit(self, batch, inverse: bool | None = None,
               deadline_s: float | None = None) -> Request | None:
        """Admit a request, or shed it (returns None) when the queue is
        full — mirroring ``SlotManager.admit``'s admit-on-free contract."""
        if len(self._queue) >= self.max_queue:
            self._count("shed")
            return None
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline = None if deadline_s is None else self._clock() + deadline_s
        req = Request(id=self._next_id, batch=batch, inverse=inverse,
                      deadline=deadline)
        self._next_id += 1
        self._queue.append(req)
        self._count("admitted")
        return req

    def drain(self) -> list[Request]:
        """Process every queued request in admission order."""
        done = []
        while self._queue:
            done.append(self._process(self._queue.popleft()))
        return done

    def transform(self, batch, inverse: bool | None = None, *,
                  deadline_s: float | None = None):
        """Submit-and-drain convenience: returns the transformed batch or
        raises (:class:`Overloaded`, :class:`DeadlineExceeded`, or the
        request's final error)."""
        req = self.submit(batch, inverse=inverse, deadline_s=deadline_s)
        if req is None:
            raise Overloaded(
                f"admission queue full ({self.max_queue} requests)")
        self.drain()
        if req.status != "done":
            raise req.error
        return req.result

    # -- lifecycle ---------------------------------------------------------

    def _count(self, key: str, n: int = 1) -> None:
        self.counts[key] += n
        _metrics.inc(_COUNTERS[key], n)

    def _pick_tier(self, req: Request | None = None) -> str:
        start = 0
        if req is not None and req.tier_floor is not None:
            # Nonfinite recovery pinned this request at (or below) the
            # failing tier's successor; breaker health applies below it.
            start = LADDER_TIERS.index(req.tier_floor)
        for tier in LADDER_TIERS[start:]:
            if self.breakers[tier].allow():
                return tier
        # Every breaker open: the einsum floor runs anyway — admitted
        # requests are never shed because the ladder is unhealthy.
        return LADDER_TIERS[-1]

    def _degrade(self, req: Request, tier: str, reason: str) -> None:
        self._count("degraded")
        req.events.append({"kind": "runtime_degradation", "reason": reason,
                           "from": req.tier, "to": tier,
                           "request": req.id, "attempt": req.attempts})

    def _attempt(self, req: Request, tier: str):
        knobs = dict(_TIER_KNOBS[tier])
        if self.vmem_budget is not None:
            knobs["vmem_budget"] = self.vmem_budget
        if req.force_accum is not None:
            knobs["accum"] = req.force_accum
        t0 = self._clock()
        y = self.session.transform(req.batch, inverse=req.inverse, **knobs)
        if consume_nan_poison():
            # An armed "nan" drill fault: the span hook fired before the
            # work, so the corruption is applied here — after the
            # transform, before the guard, exactly where a kernel with
            # rotted accumulators would hand the runtime a poisoned array.
            y = y * float("nan")
        self._finite_seq += 1
        if (self.finite_check_every > 0
                and self._finite_seq % self.finite_check_every == 0
                and not finite_guard(y)):
            self._count("nonfinite")
            raise NonfiniteOutput(
                f"nonfinite transform output (tier {tier}, "
                f"request {req.id}, attempt {req.attempts})")
        elapsed = self._clock() - t0
        if (self.attempt_timeout_s is not None
                and elapsed > self.attempt_timeout_s):
            # The work finished but blew the per-attempt SLO; a real RPC
            # would have been cancelled mid-flight — discard and retry.
            self._count("timeouts")
            raise TimeoutError(
                f"attempt took {elapsed:.3f}s > SLO "
                f"{self.attempt_timeout_s:.3f}s (tier {tier})")
        return y

    def _process(self, req: Request) -> Request:
        sp = _trace.NULL_SPAN
        if _trace.get_tracer().enabled:
            sp = _trace.Span(_trace.get_tracer(), "serve.lifecycle",
                             {"request": req.id})
        with sp:
            return self._process_inner(req)

    def _process_inner(self, req: Request) -> Request:
        prev_tier = None
        cause = "kernel_failure"
        while True:
            tier = self._pick_tier(req)
            if (prev_tier is not None
                    and LADDER_TIERS.index(tier) > LADDER_TIERS.index(prev_tier)):
                self._degrade(req, tier, reason=cause)
            req.attempts += 1
            req.tier = tier
            breaker = self.breakers[tier]
            try:
                y = self._attempt(req, tier)
            except VmemPressure as e:
                self._on_vmem_pressure(req, e)
                cause = "vmem_pressure"
                err = e
            except DeviceLoss as e:
                self._on_device_loss(req, e)
                cause = "device_loss"
                err = e
            except NonfiniteOutput as e:
                # Silent corruption caught by the finite-guard: health-wise
                # a tier failure, recovery-wise a *numerics* failure — the
                # retry is pinned one rung down with compensated
                # accumulation forced, so it cannot re-run the exact
                # configuration that produced the NaN.
                breaker.record_failure()
                floor = LADDER_TIERS[min(LADDER_TIERS.index(tier) + 1,
                                         len(LADDER_TIERS) - 1)]
                req.tier_floor = floor
                req.force_accum = "compensated"
                req.events.append({"kind": "numerics_recovery",
                                   "reason": "nonfinite_output",
                                   "tier": tier, "tier_floor": floor,
                                   "force_accum": "compensated",
                                   "attempt": req.attempts})
                cause = "nonfinite_output"
                err = e
            except TimeoutError as e:
                # timeouts count against the tier's health: a tier that is
                # chronically slow should open and let a leaner tier serve
                breaker.record_failure()
                req.events.append({"kind": "attempt_timeout", "tier": tier,
                                   "attempt": req.attempts})
                cause = "attempt_timeout"
                err = e
            except (ValueError, TypeError) as e:
                # malformed request: not transient, no retry budget burned
                req.status = "failed"
                req.error = e
                self._count("failed")
                return req
            except Exception as e:  # kernel/collective failure
                breaker.record_failure()
                cause = "kernel_failure"
                err = e
            else:
                if breaker.record_success():
                    self._count("recovered")
                    req.events.append({"kind": "runtime_recovery",
                                       "tier": tier,
                                       "attempt": req.attempts})
                req.status = "done"
                req.result = y
                info = dict(self.session.last_info or {})
                info["events"] = tuple(info.get("events", ())) \
                    + tuple(req.events)
                req.info = info
                self._count("completed")
                return req
            req.error = err
            # -- failed attempt: retry, fail on deadline, or give up ------
            if (req.deadline is not None and self._clock() >= req.deadline):
                req.status = "failed"
                req.error = DeadlineExceeded(
                    f"request {req.id} deadline expired after "
                    f"{req.attempts} attempts: {err}")
                self._count("deadline_exceeded")
                self._count("failed")
                return req
            if req.attempts >= self.retry.max_attempts:
                req.status = "failed"
                self._count("failed")
                return req
            prev_tier = tier
            req.retries += 1
            self._count("retries")
            self._sleep(self.retry.delay(req.attempts, req.id))

    # -- recovery paths ----------------------------------------------------

    def _on_vmem_pressure(self, req: Request, e: VmemPressure) -> None:
        from ..engine import DEFAULT_VMEM_BUDGET

        cur = (self.vmem_budget
               or self.session.vmem_budget or DEFAULT_VMEM_BUDGET)
        new = max(int(cur * self.vmem_shrink), self.min_vmem_budget)
        self.vmem_budget = new
        self._count("degraded")
        req.events.append({"kind": "runtime_degradation",
                           "reason": "vmem_pressure",
                           "vmem_budget_from": cur, "vmem_budget_to": new,
                           "request": req.id, "attempt": req.attempts})

    def _survivors(self, e: DeviceLoss):
        import jax

        devices = list(self._devices
                       if self._devices is not None else jax.devices())
        if e.survivors is not None:
            devices = devices[: int(e.survivors)]
        return devices

    def _on_device_loss(self, req: Request, e: DeviceLoss) -> None:
        import numpy as np
        from jax.sharding import Mesh

        from ..runtime.elastic import remesh_plan

        mesh = self.session.mesh
        if mesh is None:
            return  # nothing to remesh; plain retry
        survivors = self._survivors(e)
        names = tuple(mesh.axis_names)
        # Trailing axes keep their degree (the model-parallel posture of
        # elastic.remesh_plan: TP is baked in, the leading axis absorbs
        # the shrink).
        tp = 1
        for n in names[1:]:
            tp *= int(mesh.shape[n])
        dp, tp = remesh_plan(len(survivors), tp)
        shape = (dp,) + tuple(int(mesh.shape[n]) for n in names[1:])
        new_mesh = Mesh(
            np.asarray(survivors[: dp * tp]).reshape(shape), names)
        dropped = self.session.rebind_mesh(new_mesh)
        self._count("remeshes")
        req.events.append({"kind": "runtime_remesh",
                           "from": dict(mesh.shape),
                           "to": dict(new_mesh.shape),
                           "plans_invalidated": dropped,
                           "request": req.id, "attempt": req.attempts})

    # -- telemetry ---------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def stats(self) -> dict:
        """Runtime counters + breaker states + the wrapped session stats."""
        return {
            **dict(self.counts),
            "queued": len(self._queue),
            "vmem_budget": self.vmem_budget,
            "breakers": {t: b.state for t, b in self.breakers.items()},
            "session": self.session.stats(),
        }


_COUNTERS = {
    "admitted": "serve.admitted",
    "completed": "serve.completed",
    "failed": "serve.failed",
    "shed": "serve.shed",
    "retries": "serve.retry",
    "timeouts": "serve.timeout",
    "degraded": "serve.degraded",
    "remeshes": "serve.remesh",
    "recovered": "serve.recovered",
    "deadline_exceeded": "serve.deadline_exceeded",
    "nonfinite": "numerics.nonfinite.detected",
}
