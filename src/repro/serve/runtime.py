"""Resilient serving runtime: request lifecycle around ``DxtServeSession``.

:class:`ResilientDxtServer` wraps a session with the full lifecycle a
production transform service needs (``docs/serving.md``):

* **bounded admission** — a FIFO queue of ``max_queue`` requests;
  :meth:`submit` sheds (returns None, counts ``serve.shed``) when full,
  so overload backpressure is explicit instead of an unbounded backlog;
* **deadlines/timeouts** — an optional per-request deadline and a
  per-attempt latency SLO; an attempt that overruns the SLO counts
  ``serve.timeout`` and is retried like a failure (its result is
  discarded — a real RPC would have been cancelled);
* **retry with backoff** — bounded exponential backoff with
  *deterministic* jitter (hashed from request id + attempt, so drills
  and replays reproduce exactly), counted in ``serve.retry``;
* **a per-tier circuit breaker** driving the **degradation ladder**.

The ladder extends the planner's triple→pair→staged fusion fallback to
runtime failures.  Tiers, best first::

    auto    session defaults (cost-model fusion, Pallas kernels)
    pair    fuse="pair"
    staged  fuse=False
    einsum  fuse=False, backend="einsum"  (no Pallas at all)

Each tier has a :class:`CircuitBreaker`; repeated kernel failure opens a
tier's breaker and the next attempt replans one tier down (counted in
``serve.degraded`` and recorded as a ``runtime_degradation`` event on the
request's ``info["events"]``, next to the planner's own
``fusion_degradation`` events).  After ``cooldown_s`` the breaker goes
half-open, one probe request runs the higher tier again, and on success
the breaker closes (``serve.recovered``) — the ladder climbs back up.
The einsum tier is the floor: it is attempted even with its breaker open,
because shedding a request the queue already admitted is the one thing
the runtime never does.

A **finite-guard** (off by default; ``finite_check_every=N`` checks every
N-th attempt) catches *silent* corruption the exception paths never see:
a NaN/Inf output classifies as a retryable
:class:`repro.engine.numerics.NonfiniteOutput`, counted in
``numerics.nonfinite.detected``.  Recovery pins the request one ladder
rung below the failing tier (a per-request floor — the breaker ladder
still applies on top) and forces ``accum="compensated"`` on every
subsequent attempt, so the retry runs with guarded accumulation
(``docs/numerics.md``).  The ``nan`` fault kind of
:mod:`repro.runtime.faults` drills exactly this path: the injector arms a
poison flag, the runtime multiplies the transform output by NaN when the
flag is armed (:func:`repro.runtime.faults.consume_nan_poison`), and the
drill balances ``serve.retry`` / ``numerics.nonfinite.detected`` against
``faults.injected.nan``.

Two fault kinds bypass the ladder:

* **VMEM pressure** (:class:`repro.runtime.faults.VmemPressure`) —
  the request replans under a tightened ``vmem_budget`` (halved, floored
  at ``min_vmem_budget``); the engine's plan keys include the budget, so
  this is a fresh plan whose own fusion ladder may demote tiers;
* **device loss** (:class:`repro.runtime.faults.DeviceLoss`) — the mesh
  is rebuilt on the survivors via ``elastic.remesh_plan`` semantics (the
  leading axis absorbs the shrink, trailing model-parallel axes keep
  their degree), the session re-binds (``DxtServeSession.rebind_mesh``
  invalidates every plan and jitted ``shard_map`` program of the dead
  mesh), and the request replays on the surviving devices — counted in
  ``serve.remesh``.

All recovery is synchronous and per-request: an admitted request either
returns a result numerically matching the fault-free run or raises with
its last error after the retry budget/deadline is exhausted — it is never
silently dropped.  Chaos drills script faults with
:mod:`repro.runtime.faults` and balance the ``serve.*`` counters against
``faults.injected.*``.

**Throughput mode** (``docs/serving.md``, "Throughput") is opt-in and
layers three mechanisms on the same lifecycle:

* **request coalescing** — ``max_coalesce > 1`` stacks queued requests
  that share a *bucket* (trailing dims, dtype, direction, per-request
  overrides) and were submitted within ``coalesce_window_s`` (default
  5 ms; a zero window stacks only same-instant submissions and warns) of
  the bucket head into one batched launch, de-stacked per caller afterwards
  (``serve.coalesced`` counts the stacked requests, ``serve.batch``
  spans the launch).  A request with a different override set simply
  lands in its own bucket — it splits the batch, it never poisons it;
* **double-buffered dispatch** — ``pipeline_depth=2`` keeps two batches
  in flight using JAX async dispatch: batch *n+1* is assembled (donating
  server-owned *staging copies* where the backend supports donation —
  caller arrays and the retained ``Request.batch`` are never donated, so
  retries always have a live buffer to replay) and dispatched while
  batch *n*'s results are still being synced, so host assembly and HBM
  transfer overlap device compute;
* **shape-bucketed warmup** — :meth:`warmup` delegates to
  :meth:`DxtServeSession.warmup` per ladder tier so steady-state
  requests (and every coalesced batch size) hit pre-built, pre-tuned,
  pre-compiled plans.

Failure semantics are preserved per *sub-request*: a fault that corrupts
a batched launch re-enqueues only the failing members (one
``serve.retry`` each — the ``faults.injected.* == serve.retry`` drill
identities keep balancing), a deadline that expires while a request sits
queued sheds it before any launch is paid, and with the defaults
(``max_coalesce=1``, ``pipeline_depth=1``) the runtime runs the exact
historical one-request-at-a-time path.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from collections import deque
from typing import Any, Callable

from ..engine.numerics import NonfiniteOutput, finite_guard
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..runtime.faults import DeviceLoss, VmemPressure, consume_nan_poison
from .decode import _UNSET, DxtServeSession

__all__ = [
    "LADDER_TIERS",
    "RetryPolicy",
    "CircuitBreaker",
    "Request",
    "Overloaded",
    "DeadlineExceeded",
    "ResilientDxtServer",
]

# Degradation ladder, best tier first; knobs are per-request overrides
# passed to DxtServeSession.transform (None = session default for "auto").
LADDER_TIERS = ("auto", "pair", "staged", "einsum")
_TIER_KNOBS: dict[str, dict] = {
    "auto": {},
    "pair": {"fuse": "pair"},
    "staged": {"fuse": False},
    "einsum": {"fuse": False, "backend": "einsum", "use_pallas": False},
}


class Overloaded(RuntimeError):
    """Admission queue full — the request was shed, not queued."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before an attempt succeeded."""


@dataclasses.dataclass
class RetryPolicy:
    """Bounded exponential backoff with deterministic jitter.

    ``delay(attempt, token)`` is a pure function of its arguments: the
    jitter is hashed from ``(token, attempt)``, not drawn from a PRNG, so
    a replayed drill backs off identically.  ``max_attempts`` bounds the
    per-request retry budget (the einsum floor still failing that many
    times means the failure is real, not transient).
    """

    base_delay_s: float = 0.01
    max_delay_s: float = 1.0
    multiplier: float = 2.0
    jitter: float = 0.5  # fraction of the delay shaved off, in [0, 1)
    max_attempts: int = 16

    def delay(self, attempt: int, token: int = 0) -> float:
        d = min(self.base_delay_s * self.multiplier ** max(attempt - 1, 0),
                self.max_delay_s)
        if self.jitter <= 0.0:
            return d
        h = hashlib.sha256(f"{token}:{attempt}".encode()).digest()
        u = int.from_bytes(h[:4], "big") / 2.0 ** 32
        return d * (1.0 - self.jitter * u)


class CircuitBreaker:
    """closed → open after ``threshold`` consecutive failures → half-open
    after ``cooldown_s`` → closed on a successful probe (or re-open on a
    failed one).  ``clock`` is injectable for deterministic tests."""

    def __init__(self, threshold: int = 2, cooldown_s: float = 1.0,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = int(threshold)
        self.cooldown_s = float(cooldown_s)
        self._clock = clock
        self.state = "closed"
        self.failures = 0
        self.opened_at = 0.0

    def allow(self) -> bool:
        if self.state == "open":
            if self._clock() - self.opened_at >= self.cooldown_s:
                self.state = "half_open"
        return self.state != "open"

    def record_failure(self) -> None:
        self.failures += 1
        if self.state == "half_open" or self.failures >= self.threshold:
            self.state = "open"
            self.opened_at = self._clock()
            self.failures = 0

    def record_success(self) -> bool:
        """Returns True when this success *closed* a half-open breaker
        (a recovery, not steady state)."""
        recovered = self.state == "half_open"
        self.state = "closed"
        self.failures = 0
        return recovered


@dataclasses.dataclass
class Request:
    """One admitted transform request and its lifecycle record."""

    id: int
    batch: Any
    inverse: bool | None = None
    deadline: float | None = None  # absolute, on the server's clock
    status: str = "queued"  # queued | done | failed
    tier: str = "auto"  # tier of the last attempt
    attempts: int = 0
    retries: int = 0
    result: Any = None
    info: dict | None = None
    error: BaseException | None = None
    events: list = dataclasses.field(default_factory=list)
    # Nonfinite-recovery state: a per-request ladder floor (the failing
    # tier's successor) and a forced accumulation mode for retries.
    tier_floor: str | None = None
    force_accum: str | None = None
    # Throughput-mode fields: submit/finish timestamps (server clock, for
    # queue-inclusive latency), the per-request knob overrides that define
    # the request's coalescing bucket, and how many requests shared the
    # launch that produced the result (1 = solo).
    submitted_at: float = 0.0
    finished_at: float | None = None
    overrides: dict = dataclasses.field(default_factory=dict)
    coalesced: int = 1


class ResilientDxtServer:
    """Fault-tolerant request lifecycle around a :class:`DxtServeSession`.

    Synchronous single-worker runtime: :meth:`submit` admits (or sheds),
    :meth:`drain` processes the queue in order, :meth:`transform` is the
    submit-and-drain convenience with the session's call signature.
    ``clock``/``sleep`` are injectable so tests drive breaker cooldowns
    and backoff deterministically.  ``devices`` overrides where remesh
    recovery looks for survivors (default ``jax.devices()``).
    """

    def __init__(self, session: DxtServeSession | None = None, *,
                 max_queue: int = 64,
                 default_deadline_s: float | None = None,
                 attempt_timeout_s: float | None = None,
                 retry: RetryPolicy | None = None,
                 breaker_threshold: int = 2,
                 breaker_cooldown_s: float = 1.0,
                 vmem_shrink: float = 0.5,
                 min_vmem_budget: int = 1 << 18,
                 finite_check_every: int = 0,
                 max_coalesce: int = 1,
                 coalesce_window_s: float = 0.005,
                 pipeline_depth: int = 1,
                 donate_inputs: bool = True,
                 devices=None,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 **session_kwargs):
        if session is not None and session_kwargs:
            raise ValueError("pass either a session or session kwargs")
        self.session = session or DxtServeSession(**session_kwargs)
        self.max_queue = int(max_queue)
        self.default_deadline_s = default_deadline_s
        self.attempt_timeout_s = attempt_timeout_s
        self.retry = retry or RetryPolicy()
        # Throughput knobs: >1 turns on batched draining (coalescing /
        # double-buffered dispatch); the defaults keep the historical
        # strictly-serial per-request path.
        self.max_coalesce = int(max_coalesce)
        self.coalesce_window_s = float(coalesce_window_s)
        if self.max_coalesce > 1 and self.coalesce_window_s <= 0.0:
            import warnings

            # A zero window only stacks submissions with *identical*
            # monotonic timestamps — on a real clock essentially nothing
            # coalesces, which silently defeats max_coalesce.
            warnings.warn(
                "max_coalesce > 1 with coalesce_window_s <= 0: only "
                "same-instant submissions coalesce; set a positive "
                "window (default 0.005s) for real clocks",
                RuntimeWarning, stacklevel=2)
        self.pipeline_depth = int(pipeline_depth)
        self.donate_inputs = bool(donate_inputs)
        self._concat_fns: dict = {}  # arity -> jitted donating concat
        self.vmem_shrink = float(vmem_shrink)
        self.min_vmem_budget = int(min_vmem_budget)
        # 0 = finite-guard off; N > 0 checks every N-th attempt for
        # NaN/Inf (a host sync — sample, don't pay it on every request).
        self.finite_check_every = int(finite_check_every)
        self._finite_seq = 0
        self._devices = devices
        self._clock = clock
        self._sleep = sleep
        self._queue: deque[Request] = deque()
        self._next_id = 0
        self.breakers = {
            tier: CircuitBreaker(breaker_threshold, breaker_cooldown_s,
                                 clock=clock)
            for tier in LADDER_TIERS
        }
        # Runtime-tightened budget override; None = session/engine default.
        self.vmem_budget: int | None = None
        self.counts = {k: 0 for k in
                       ("admitted", "completed", "failed", "shed", "retries",
                        "timeouts", "degraded", "remeshes", "recovered",
                        "deadline_exceeded", "nonfinite", "coalesced",
                        "batches")}

    # -- admission ---------------------------------------------------------

    def submit(self, batch, inverse: bool | None = None,
               deadline_s: float | None = None, *,
               fuse=_UNSET, use_pallas=_UNSET, backend=_UNSET,
               vmem_budget=_UNSET, accum=_UNSET,
               error_budget=_UNSET) -> Request | None:
        """Admit a request, or shed it (returns None) when the queue is
        full — mirroring ``SlotManager.admit``'s admit-on-free contract.

        The keyword-only engine knobs are per-request overrides (same
        meaning as :meth:`DxtServeSession.transform`).  They become part
        of the request's coalescing bucket: requests with different
        override sets are never stacked into one launch — an override
        splits the batch rather than changing how everyone else runs.
        """
        if len(self._queue) >= self.max_queue:
            self._count("shed")
            return None
        if deadline_s is None:
            deadline_s = self.default_deadline_s
        deadline = None if deadline_s is None else self._clock() + deadline_s
        overrides = {k: v for k, v in (("fuse", fuse),
                                       ("use_pallas", use_pallas),
                                       ("backend", backend),
                                       ("vmem_budget", vmem_budget),
                                       ("accum", accum),
                                       ("error_budget", error_budget))
                     if v is not _UNSET}
        req = Request(id=self._next_id, batch=batch, inverse=inverse,
                      deadline=deadline, submitted_at=self._clock(),
                      overrides=overrides)
        self._next_id += 1
        self._queue.append(req)
        self._count("admitted")
        _metrics.set_gauge("serve.queue_depth", len(self._queue))
        return req

    def drain(self) -> list[Request]:
        """Process every queued request in admission order.

        With the default ``max_coalesce=1`` / ``pipeline_depth=1`` this is
        the historical strictly-serial path; either knob above 1 switches
        to the batched drain (coalesced launches, up to ``pipeline_depth``
        batches in flight)."""
        if self.max_coalesce > 1 or self.pipeline_depth > 1:
            return self._drain_batched()
        done = []
        while self._queue:
            req = self._queue.popleft()
            _metrics.set_gauge("serve.queue_depth", len(self._queue))
            done.append(self._process(req))
        return done

    def transform(self, batch, inverse: bool | None = None, *,
                  deadline_s: float | None = None, **overrides):
        """Submit-and-drain convenience: returns the transformed batch or
        raises (:class:`Overloaded`, :class:`DeadlineExceeded`, or the
        request's final error).  ``overrides`` are :meth:`submit`'s
        per-request engine knobs."""
        req = self.submit(batch, inverse=inverse, deadline_s=deadline_s,
                          **overrides)
        if req is None:
            raise Overloaded(
                f"admission queue full ({self.max_queue} requests)")
        self.drain()
        if req.status != "done":
            raise req.error
        return req.result

    def warmup(self, shapes, *, tiers=("auto",), **kwargs) -> list[dict]:
        """Pre-build plans/tunings/kernels for the given shape buckets —
        :meth:`DxtServeSession.warmup` run once per requested ladder tier
        (each tier's knobs become warmup overrides), so a degraded server
        replans into warm caches too.  When the server coalesces, the
        batch-assembly programs (member concat, per-member de-stack
        slices) are warmed for every bucket as well — the first real
        coalesced launch then pays zero host-side compiles.  ``kwargs``
        pass through to the session (``inverse``/``adjoint``/``dtype`` +
        engine knobs)."""
        import jax
        import jax.numpy as jnp

        done = []
        for tier in tiers:
            if tier not in _TIER_KNOBS:
                raise ValueError(
                    f"unknown tier {tier!r} (tiers: {LADDER_TIERS})")
            done.extend(
                self.session.warmup(shapes, **{**_TIER_KNOBS[tier],
                                               **kwargs}))
        if self.max_coalesce > 1 or self.pipeline_depth > 1:
            for rec in done:
                dims, dtype = rec["dims"], rec["dtype"]
                for bb in rec["buckets"]:
                    if bb < 2:
                        continue
                    # bb *distinct* member arrays: the donating concat
                    # must never see the same buffer twice.
                    xs = [jnp.zeros((1,) + tuple(dims), dtype)
                          for _ in range(bb)]
                    y = self._assemble(xs)
                    jax.block_until_ready([y[i: i + 1] for i in range(bb)])
        return done

    # -- lifecycle ---------------------------------------------------------

    def _count(self, key: str, n: int = 1) -> None:
        self.counts[key] += n
        _metrics.inc(_COUNTERS[key], n)

    def _pick_tier(self, req: Request | None = None) -> str:
        start = 0
        if req is not None and req.tier_floor is not None:
            # Nonfinite recovery pinned this request at (or below) the
            # failing tier's successor; breaker health applies below it.
            start = LADDER_TIERS.index(req.tier_floor)
        for tier in LADDER_TIERS[start:]:
            if self.breakers[tier].allow():
                return tier
        # Every breaker open: the einsum floor runs anyway — admitted
        # requests are never shed because the ladder is unhealthy.
        return LADDER_TIERS[-1]

    def _degrade(self, req: Request, tier: str, reason: str) -> None:
        self._count("degraded")
        req.events.append({"kind": "runtime_degradation", "reason": reason,
                           "from": req.tier, "to": tier,
                           "request": req.id, "attempt": req.attempts})

    def _attempt(self, req: Request, tier: str):
        knobs = dict(_TIER_KNOBS[tier])
        if self.vmem_budget is not None:
            knobs["vmem_budget"] = self.vmem_budget
        if req.force_accum is not None:
            knobs["accum"] = req.force_accum
        t0 = self._clock()
        y = self.session.transform(req.batch, inverse=req.inverse, **knobs)
        if consume_nan_poison():
            # An armed "nan" drill fault: the span hook fired before the
            # work, so the corruption is applied here — after the
            # transform, before the guard, exactly where a kernel with
            # rotted accumulators would hand the runtime a poisoned array.
            y = y * float("nan")
        self._finite_seq += 1
        if (self.finite_check_every > 0
                and self._finite_seq % self.finite_check_every == 0
                and not finite_guard(y)):
            self._count("nonfinite")
            raise NonfiniteOutput(
                f"nonfinite transform output (tier {tier}, "
                f"request {req.id}, attempt {req.attempts})")
        elapsed = self._clock() - t0
        if (self.attempt_timeout_s is not None
                and elapsed > self.attempt_timeout_s):
            # The work finished but blew the per-attempt SLO; a real RPC
            # would have been cancelled mid-flight — discard and retry.
            self._count("timeouts")
            raise TimeoutError(
                f"attempt took {elapsed:.3f}s > SLO "
                f"{self.attempt_timeout_s:.3f}s (tier {tier})")
        return y

    def _process(self, req: Request) -> Request:
        sp = _trace.NULL_SPAN
        if _trace.get_tracer().enabled:
            sp = _trace.Span(_trace.get_tracer(), "serve.lifecycle",
                             {"request": req.id})
        with sp:
            req = self._process_inner(req)
        req.finished_at = self._clock()
        return req

    def _process_inner(self, req: Request) -> Request:
        prev_tier = None
        cause = "kernel_failure"
        while True:
            tier = self._pick_tier(req)
            if (prev_tier is not None
                    and LADDER_TIERS.index(tier) > LADDER_TIERS.index(prev_tier)):
                self._degrade(req, tier, reason=cause)
            req.attempts += 1
            req.tier = tier
            breaker = self.breakers[tier]
            try:
                y = self._attempt(req, tier)
            except VmemPressure as e:
                self._on_vmem_pressure(req, e)
                cause = "vmem_pressure"
                err = e
            except DeviceLoss as e:
                self._on_device_loss(req, e)
                cause = "device_loss"
                err = e
            except NonfiniteOutput as e:
                # Silent corruption caught by the finite-guard: health-wise
                # a tier failure, recovery-wise a *numerics* failure — the
                # retry is pinned one rung down with compensated
                # accumulation forced, so it cannot re-run the exact
                # configuration that produced the NaN.
                breaker.record_failure()
                floor = LADDER_TIERS[min(LADDER_TIERS.index(tier) + 1,
                                         len(LADDER_TIERS) - 1)]
                req.tier_floor = floor
                req.force_accum = "compensated"
                req.events.append({"kind": "numerics_recovery",
                                   "reason": "nonfinite_output",
                                   "tier": tier, "tier_floor": floor,
                                   "force_accum": "compensated",
                                   "attempt": req.attempts})
                cause = "nonfinite_output"
                err = e
            except TimeoutError as e:
                # timeouts count against the tier's health: a tier that is
                # chronically slow should open and let a leaner tier serve
                breaker.record_failure()
                req.events.append({"kind": "attempt_timeout", "tier": tier,
                                   "attempt": req.attempts})
                cause = "attempt_timeout"
                err = e
            except (ValueError, TypeError) as e:
                # malformed request: not transient, no retry budget burned
                req.status = "failed"
                req.error = e
                self._count("failed")
                return req
            except Exception as e:  # kernel/collective failure
                breaker.record_failure()
                cause = "kernel_failure"
                err = e
            else:
                if breaker.record_success():
                    self._count("recovered")
                    req.events.append({"kind": "runtime_recovery",
                                       "tier": tier,
                                       "attempt": req.attempts})
                req.status = "done"
                req.result = y
                info = dict(self.session.last_info or {})
                info["events"] = tuple(info.get("events", ())) \
                    + tuple(req.events)
                req.info = info
                self._count("completed")
                return req
            req.error = err
            # -- failed attempt: retry, fail on deadline, or give up ------
            if (req.deadline is not None and self._clock() >= req.deadline):
                req.status = "failed"
                req.error = DeadlineExceeded(
                    f"request {req.id} deadline expired after "
                    f"{req.attempts} attempts: {err}")
                self._count("deadline_exceeded")
                self._count("failed")
                return req
            if req.attempts >= self.retry.max_attempts:
                req.status = "failed"
                self._count("failed")
                return req
            prev_tier = tier
            req.retries += 1
            self._count("retries")
            self._sleep(self.retry.delay(req.attempts, req.id))

    # -- batched drain: coalescing + double-buffered dispatch --------------

    def _bucket_key(self, req: Request):
        """Coalescing bucket: trailing dims + dtype + direction + the
        per-request override set (+ any nonfinite-recovery pins, so a
        recovering request never drags a clean batch to its floor).
        None = never co-batch (malformed inputs run — and fail — alone)."""
        import numpy as np

        shape = np.shape(req.batch)
        if len(shape) != 4:
            return None
        inv = self.session.inverse if req.inverse is None else bool(
            req.inverse)
        return (tuple(shape[1:]), str(getattr(req.batch, "dtype", "")), inv,
                tuple(sorted(req.overrides.items())), req.tier_floor,
                req.force_accum)

    def _expired(self, req: Request, done: list, *, queued: bool) -> bool:
        """Fail ``req`` with DeadlineExceeded if its deadline has passed
        (before paying a launch when ``queued``); True = it was shed."""
        if req.deadline is None or self._clock() < req.deadline:
            return False
        req.status = "failed"
        req.error = DeadlineExceeded(
            f"request {req.id} deadline expired "
            + ("while queued (shed before launch)" if queued
               else f"after {req.attempts} attempts"))
        if queued:
            req.events.append({"kind": "queued_shed",
                               "reason": "deadline_exceeded",
                               "request": req.id})
        req.finished_at = self._clock()
        self._count("deadline_exceeded")
        self._count("failed")
        done.append(req)
        return True

    def _next_group(self, done: list) -> list[Request]:
        """Pop the queue head and every queued request in its bucket that
        was submitted within ``coalesce_window_s`` of it (admission order,
        up to ``max_coalesce``); expired members shed before launch."""
        head = self._queue.popleft()
        group = [head]
        key = self._bucket_key(head)
        if self.max_coalesce > 1 and key is not None:
            rest = []
            for r in self._queue:
                if (len(group) < self.max_coalesce
                        and self._bucket_key(r) == key
                        and (r.submitted_at - head.submitted_at
                             <= self.coalesce_window_s)):
                    group.append(r)
                else:
                    rest.append(r)
            self._queue = deque(rest)
        _metrics.set_gauge("serve.queue_depth", len(self._queue))
        group = [r for r in group if not self._expired(r, done, queued=True)]
        if len(group) > 1:
            self._count("coalesced", len(group))
            for r in group:
                r.coalesced = len(group)
        return group

    def _assemble(self, parts: list):
        """Stack member batches along axis 0.  On backends that support
        buffer donation (TPU/GPU) the concat is a jitted program donating
        every input — but only ever *server-owned staging buffers*.  A
        host input (numpy/list) is staged onto the device by
        ``jnp.asarray`` (a fresh buffer, safe to donate); a member that
        already is a ``jax.Array`` would be aliased by ``asarray``, so it
        is staged through an explicit device copy first.  The caller's
        array therefore always survives the launch, and every retry path
        (batch re-assembly, ``_process`` replay) can reuse ``r.batch``
        untouched."""
        import jax
        import jax.numpy as jnp

        if len(parts) == 1:
            return jnp.asarray(parts[0])
        if self._donation_enabled():
            arrs = [jnp.copy(p) if isinstance(p, jax.Array)
                    else jnp.asarray(p) for p in parts]
            fn = self._concat_fns.get(len(arrs))
            if fn is None:
                fn = jax.jit(lambda *xs: jnp.concatenate(xs, axis=0),
                             donate_argnums=tuple(range(len(arrs))))
                self._concat_fns[len(arrs)] = fn
            return fn(*arrs)
        return jnp.concatenate([jnp.asarray(p) for p in parts], axis=0)

    def _donation_enabled(self) -> bool:
        """True when batch assembly should donate its staging buffers —
        only on backends where donation actually aliases (TPU/GPU; XLA
        ignores it on CPU)."""
        import jax

        return self.donate_inputs and jax.default_backend() in ("tpu", "gpu")

    def _drain_batched(self) -> list[Request]:
        """Coalescing drain with up to ``pipeline_depth`` batches in
        flight: batch *n+1* is assembled and dispatched (JAX async
        dispatch — ``session.transform`` returns unsynced futures) before
        batch *n* is finalized, so host-side assembly and input transfer
        overlap device compute."""
        done: list[Request] = []
        inflight: deque = deque()
        depth = max(self.pipeline_depth, 1)
        while self._queue or inflight:
            while self._queue and len(inflight) < depth:
                group = self._next_group(done)
                if not group:
                    continue
                state = self._launch(group, done)
                if state is not None:
                    inflight.append(state)
            if inflight:
                self._finalize(inflight.popleft(), done)
        return done

    def _launch(self, group: list[Request], done: list):
        """Dispatch one coalesced batch; retries launch-time failures
        (VMEM pressure, device loss, kernel raise) as a batch — one
        ``serve.retry`` per failed batch attempt, so an injected fault
        still balances one-for-one.  Returns the in-flight state (result
        future + bookkeeping) or None if every member resolved here."""
        prev_tier = None
        cause = "kernel_failure"
        while True:
            group = [r for r in group
                     if not self._expired(r, done, queued=False)]
            if not group:
                return None
            head = group[0]
            tier = self._pick_tier(head)
            if (prev_tier is not None
                    and LADDER_TIERS.index(tier)
                    > LADDER_TIERS.index(prev_tier)):
                self._degrade(head, tier, reason=cause)
            for r in group:
                r.attempts += 1
                r.tier = tier
            breaker = self.breakers[tier]
            knobs = dict(_TIER_KNOBS[tier])
            knobs.update(head.overrides)
            if self.vmem_budget is not None:
                knobs["vmem_budget"] = self.vmem_budget
            if head.force_accum is not None:
                knobs["accum"] = head.force_accum
            _metrics.set_gauge("serve.batch_size", len(group))
            sp = _trace.NULL_SPAN
            if _trace.get_tracer().enabled:
                sp = _trace.Span(_trace.get_tracer(), "serve.batch",
                                 {"requests": len(group), "tier": tier,
                                  "head": head.id})
            t0 = self._clock()
            try:
                with sp:
                    x = self._assemble([r.batch for r in group])
                    y = self.session.transform(x, inverse=head.inverse,
                                               **knobs)
            except VmemPressure as e:
                self._on_vmem_pressure(head, e)
                cause = "vmem_pressure"
                err = e
            except DeviceLoss as e:
                self._on_device_loss(head, e)
                cause = "device_loss"
                err = e
            except (ValueError, TypeError) as e:
                # malformed batch: not transient, no retry budget burned
                for r in group:
                    r.status = "failed"
                    r.error = e
                    r.finished_at = self._clock()
                    self._count("failed")
                    done.append(r)
                return None
            except Exception as e:  # kernel/collective failure
                breaker.record_failure()
                cause = "kernel_failure"
                err = e
            else:
                self._count("batches")
                # Snapshot the session info for *this* dispatch now: with
                # pipeline_depth >= 2 the next batch is dispatched before
                # this one is finalized, so session.last_info will have
                # moved on by sync time.
                return {"group": group, "y": y, "tier": tier, "t0": t0,
                        "info": dict(self.session.last_info or {}),
                        "poisoned": consume_nan_poison()}
            for r in group:
                r.error = err
            if head.attempts >= self.retry.max_attempts:
                for r in group:
                    r.status = "failed"
                    r.finished_at = self._clock()
                    self._count("failed")
                    done.append(r)
                return None
            prev_tier = tier
            head.retries += 1
            self._count("retries")
            self._sleep(self.retry.delay(head.attempts, head.id))

    def _finalize(self, state: dict, done: list) -> None:
        """Sync one in-flight batch, de-stack per member, and resolve.

        An armed ``nan`` drill poison corrupts exactly one member's slice
        (the batch head's) — the finite-guard then re-enqueues *only the
        failing sub-requests*, one ``serve.retry`` each, through the
        standard per-request lifecycle (which pins the recovery floor and
        forces compensated accumulation); clean members complete
        untouched from the same launch."""
        import jax
        import numpy as np

        group, tier = state["group"], state["tier"]
        breaker = self.breakers[tier]
        try:
            y = jax.block_until_ready(state["y"])
        except Exception:
            # Async dispatch surfaced the failure at sync time: retry
            # every member through the per-request lifecycle.
            breaker.record_failure()
            for r in group:
                r.retries += 1
                self._count("retries")
                done.append(self._process(r))
            return
        elapsed = self._clock() - state["t0"]
        if (self.attempt_timeout_s is not None
                and elapsed > self.attempt_timeout_s):
            # Whole-batch SLO breach: one timeout, one retry, and the
            # members replay individually (a leaner launch each).
            self._count("timeouts")
            group[0].events.append({"kind": "attempt_timeout", "tier": tier,
                                    "attempt": group[0].attempts,
                                    "batched": len(group)})
            group[0].retries += 1
            self._count("retries")
            self._sleep(self.retry.delay(group[0].attempts, group[0].id))
            for r in group:
                done.append(self._process(r))
            return
        info = state["info"]
        bad: list[Request] = []
        off = 0
        for i, r in enumerate(group):
            n = int(np.shape(r.batch)[0])
            part = y[off: off + n]
            off += n
            if state["poisoned"] and i == 0:
                part = part * float("nan")
            failed = False
            if self.finite_check_every > 0:
                self._finite_seq += 1
                if (self._finite_seq % self.finite_check_every == 0
                        and not finite_guard(part)):
                    failed = True
            if failed:
                self._count("nonfinite")
                floor = LADDER_TIERS[min(LADDER_TIERS.index(tier) + 1,
                                         len(LADDER_TIERS) - 1)]
                r.tier_floor = floor
                r.force_accum = "compensated"
                r.events.append({"kind": "numerics_recovery",
                                 "reason": "nonfinite_output",
                                 "tier": tier, "tier_floor": floor,
                                 "force_accum": "compensated",
                                 "attempt": r.attempts})
                bad.append(r)
                continue
            r.status = "done"
            r.result = part
            r.info = {**info, "coalesced": r.coalesced,
                      "batched_rows": int(np.shape(y)[0]),
                      "events": tuple(info.get("events", ()))
                      + tuple(r.events)}
            r.finished_at = self._clock()
            self._count("completed")
            done.append(r)
        if bad:
            breaker.record_failure()
            for r in bad:
                r.retries += 1
                self._count("retries")
                self._sleep(self.retry.delay(r.attempts, r.id))
                done.append(self._process(r))
        elif group and breaker.record_success():
            self._count("recovered")
            group[0].events.append({"kind": "runtime_recovery", "tier": tier,
                                    "attempt": group[0].attempts})

    # -- recovery paths ----------------------------------------------------

    def _on_vmem_pressure(self, req: Request, e: VmemPressure) -> None:
        from ..engine import DEFAULT_VMEM_BUDGET

        cur = (self.vmem_budget
               or self.session.vmem_budget or DEFAULT_VMEM_BUDGET)
        new = max(int(cur * self.vmem_shrink), self.min_vmem_budget)
        self.vmem_budget = new
        self._count("degraded")
        req.events.append({"kind": "runtime_degradation",
                           "reason": "vmem_pressure",
                           "vmem_budget_from": cur, "vmem_budget_to": new,
                           "request": req.id, "attempt": req.attempts})

    def _survivors(self, e: DeviceLoss):
        import jax

        devices = list(self._devices
                       if self._devices is not None else jax.devices())
        if e.survivors is not None:
            devices = devices[: int(e.survivors)]
        return devices

    def _on_device_loss(self, req: Request, e: DeviceLoss) -> None:
        import numpy as np
        from jax.sharding import Mesh

        from ..runtime.elastic import remesh_plan

        mesh = self.session.mesh
        if mesh is None:
            return  # nothing to remesh; plain retry
        survivors = self._survivors(e)
        names = tuple(mesh.axis_names)
        # Trailing axes keep their degree (the model-parallel posture of
        # elastic.remesh_plan: TP is baked in, the leading axis absorbs
        # the shrink).
        tp = 1
        for n in names[1:]:
            tp *= int(mesh.shape[n])
        dp, tp = remesh_plan(len(survivors), tp)
        shape = (dp,) + tuple(int(mesh.shape[n]) for n in names[1:])
        new_mesh = Mesh(
            np.asarray(survivors[: dp * tp]).reshape(shape), names)
        dropped = self.session.rebind_mesh(new_mesh)
        self._count("remeshes")
        req.events.append({"kind": "runtime_remesh",
                           "from": dict(mesh.shape),
                           "to": dict(new_mesh.shape),
                           "plans_invalidated": dropped,
                           "request": req.id, "attempt": req.attempts})

    # -- telemetry ---------------------------------------------------------

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def stats(self) -> dict:
        """Runtime counters + breaker states + the wrapped session stats."""
        return {
            **dict(self.counts),
            "queued": len(self._queue),
            "vmem_budget": self.vmem_budget,
            "breakers": {t: b.state for t, b in self.breakers.items()},
            "session": self.session.stats(),
        }


_COUNTERS = {
    "admitted": "serve.admitted",
    "completed": "serve.completed",
    "failed": "serve.failed",
    "shed": "serve.shed",
    "retries": "serve.retry",
    "timeouts": "serve.timeout",
    "degraded": "serve.degraded",
    "remeshes": "serve.remesh",
    "recovered": "serve.recovered",
    "deadline_exceeded": "serve.deadline_exceeded",
    "nonfinite": "numerics.nonfinite.detected",
    "coalesced": "serve.coalesced",
    "batches": "serve.batches",
}
