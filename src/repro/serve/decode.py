"""Serving substrate: prefill/decode step builders + a batched greedy/temp
sampling loop with a simple continuous-batching slot manager.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..models import ShardCtx, apply_decode, apply_prefill, init_cache
from ..obs import metrics as _metrics
from ..obs import trace as _trace

# Per-request override sentinel: None is a meaningful value for the
# engine knobs (auto fusion, default budget), so "not given" needs its own.
_UNSET = object()


def build_prefill_step(cfg, ctx: ShardCtx):
    def prefill_step(params, batch):
        return apply_prefill(params, batch, cfg, ctx)
    return prefill_step


def build_decode_step(cfg, ctx: ShardCtx):
    def decode_step(params, batch, cache, pos):
        return apply_decode(params, batch, cache, cfg, ctx, pos)
    return decode_step


@dataclasses.dataclass
class ServeSession:
    """Batched autoregressive generation (greedy or temperature sampling)."""

    cfg: Any
    params: Any
    ctx: ShardCtx = dataclasses.field(default_factory=ShardCtx)
    temperature: float = 0.0

    def generate(self, prompts: np.ndarray, max_new: int, seed: int = 0):
        """prompts: (B, S0) int32 -> (B, max_new) generated ids."""
        cfg = self.cfg
        b, s0 = prompts.shape[:2]
        max_len = s0 + max_new
        prefill = jax.jit(
            lambda p, batch: apply_prefill(p, batch, cfg, self.ctx,
                                           cache_len=max_len))
        decode = jax.jit(build_decode_step(cfg, self.ctx))

        batch_tok = jnp.asarray(prompts, jnp.int32)
        logits, cache = prefill(self.params, {"tokens": batch_tok})
        key = jax.random.PRNGKey(seed)
        out = []
        tok = self._sample(logits, key)
        for t in range(max_new):
            out.append(np.asarray(tok))
            key, sub = jax.random.split(key)
            logits, cache = decode(self.params, {"tokens": tok[:, None]},
                                   cache, jnp.int32(s0 + t))
            tok = self._sample(logits, sub)
        return np.stack(out, axis=1)

    def _sample(self, logits, key):
        logits = logits[..., : self.cfg.vocab_size]
        if self.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / self.temperature, axis=-1).astype(jnp.int32)


@dataclasses.dataclass
class DxtServeSession:
    """Batched 3D-transform serving on the planned GEMT engine.

    Requests are (B, N1, N2, N3) tensor batches; the engine plan (stage
    order, backend, tile sizes) is built once per distinct (shape, kind,
    direction) and reused — the batch axis is folded into the lowered GEMM
    rows so each stage is a single kernel launch for the whole batch.

    ``mesh`` (+ ``axes``/``batch_axis``) serves through the TriADA
    distributed schedule instead: the same engine plan runs per-shard
    inside ``shard_map`` (``docs/distributed.md``), and the session's
    byte counters gain the collective split (``collective_bytes`` is the
    modeled per-device psum_scatter ICI traffic; the HBM counters are
    per-shard when a mesh is set).

    ``inverse=True`` serves the inverse transform via
    ``inverse_coefficient_matrix``; ``transform(batch, inverse=...)``
    overrides it per request, so one session serves both directions from
    the same per-dims coefficient/plan caches.  Forward and inverse share
    autotuned tiles for free: the autotune key digests shapes + the
    *zero-structure* fingerprint, and a dense orthonormal C and its
    transposed inverse have identical shapes and structure.
    """

    kind: str = "dct"
    inverse: bool = False
    autotune: bool = False
    autotune_cache: Any = None  # AutotuneCache | path | None
    use_pallas: bool | None = None
    # appended (not inserted) so existing positional constructions keep
    # their meaning; None = auto stage fusion via the engine cost model
    fuse: bool | str | None = None  # see engine.FUSE_MODES
    mesh: Any = None  # jax.sharding.Mesh | None
    axes: Any = None  # per-mode mesh axes (None = engine default for mesh)
    batch_axis: Any = None  # mesh axis sharding the request batch dim
    vmem_budget: int | None = None  # None = engine.DEFAULT_VMEM_BUDGET
    backend: str | None = None  # pin every stage ("einsum"); None = auto
    accum: str | None = None  # accumulation mode (engine.numerics)
    error_budget: float | None = None  # a-priori rounding-bound ceiling
    # Plan-key batch bucketing: round the leading batch axis up to the next
    # power of two when keying the engine's plan cache, so coalesced
    # launches of varying size reuse one plan per bucket.  Off by default
    # (exact-shape keys, the historical behaviour); warmup() turns it on.
    bucket_batches: bool = False

    def __post_init__(self):
        self._coeffs: dict[tuple, tuple] = {}
        self.warmed: list[dict] = []  # bucket records from warmup()
        self.requests_served = 0
        self.fused_served = 0  # requests that ran any fused kernel
        self.fused3_served = 0  # … of those, the whole-transform megakernel
        self.hbm_bytes_moved = 0  # modeled traffic of everything served
        self.hbm_bytes_staged = 0  # what the all-staged schedule would move
        self.collective_bytes = 0  # modeled ICI traffic (0 without a mesh)
        self.last_info: dict | None = None
        # Per-request host dispatch latency (µs): wall time of transform()
        # — under jit this is dispatch time, not device execution time.
        self._latency_us = _metrics.Histogram()

    def _coeffs_for(self, dims: tuple[int, int, int],
                    inverse: bool | None = None) -> tuple:
        inv = self.inverse if inverse is None else bool(inverse)
        key = (self.kind, inv, dims)
        if key not in self._coeffs:
            from ..core.transforms import (coefficient_matrix,
                                           inverse_coefficient_matrix)
            build = inverse_coefficient_matrix if inv else coefficient_matrix
            self._coeffs[key] = tuple(build(self.kind, n) for n in dims)
        return self._coeffs[key]

    def rebind_mesh(self, mesh, axes=_UNSET, batch_axis=_UNSET) -> int:
        """Re-point the session at a new (possibly smaller) mesh.

        The elastic-recovery hook (``docs/serving.md``): plans built for
        the old mesh — including the jitted ``shard_map`` programs whose
        closures hold the old mesh's devices — are dropped from the engine
        caches via :func:`repro.engine.invalidate_plans`, so the next
        request replans on the surviving devices instead of dispatching
        onto dead ones.  ``axes``/``batch_axis`` default to keeping the
        session's current assignment.  Returns how many plans fell.
        """
        from ..engine import invalidate_plans

        dropped = 0
        if self.mesh is not None:
            dropped = invalidate_plans(mesh=self.mesh)
        self.mesh = mesh
        if axes is not _UNSET:
            self.axes = axes
        if batch_axis is not _UNSET:
            self.batch_axis = batch_axis
        return dropped

    # -- warmup / bucketing ------------------------------------------------

    _KNOB_NAMES = ("fuse", "use_pallas", "vmem_budget", "backend", "accum",
                   "error_budget")

    def _resolve_knobs(self, fuse=_UNSET, use_pallas=_UNSET,
                       vmem_budget=_UNSET, backend=_UNSET, accum=_UNSET,
                       error_budget=_UNSET) -> dict:
        """Per-request knobs resolved against the session defaults."""
        from ..engine import DEFAULT_VMEM_BUDGET

        if vmem_budget is _UNSET:
            vmem_budget = self.vmem_budget
        if vmem_budget is None:
            vmem_budget = DEFAULT_VMEM_BUDGET
        return {
            "fuse": self.fuse if fuse is _UNSET else fuse,
            "use_pallas": (self.use_pallas if use_pallas is _UNSET
                           else use_pallas),
            "backend": self.backend if backend is _UNSET else backend,
            "accum": self.accum if accum is _UNSET else accum,
            "error_budget": (self.error_budget if error_budget is _UNSET
                             else error_budget),
            "vmem_budget": vmem_budget,
        }

    @staticmethod
    def _pow2_bucket(b) -> int:
        """Smallest power of two >= ``b`` — the plan-key batch bucket."""
        return 1 << max(int(b) - 1, 0).bit_length()

    def _batch_bucket(self, batch: int) -> int | None:
        """Plan-cache batch bucket for a live request (None = exact keys).

        Bucketing applies only on a single device — under a mesh the
        per-shard batch is part of the distributed schedule, so those
        plans stay exact-shape."""
        if not self.bucket_batches or self.mesh is not None:
            return None
        return self._pow2_bucket(batch)

    def _warmup_spec(self, cfg, inverse, dtype, overrides: dict) -> dict:
        """Normalize one warmup entry (shape tuple or config dict) into
        ``{dims, batch, dtype, inverse, knobs}``."""
        per: dict = {}
        if isinstance(cfg, dict):
            cfg = dict(cfg)
            shape = tuple(cfg.pop("dims", None) or cfg.pop("shape"))
            batch = int(cfg.pop("batch", 0))
            dtype = cfg.pop("dtype", dtype)
            inverse = cfg.pop("inverse", inverse)
            unknown = sorted(set(cfg) - set(self._KNOB_NAMES))
            if unknown:
                raise ValueError(f"unknown warmup config keys {unknown} "
                                 f"(knobs: {self._KNOB_NAMES})")
            per = cfg
        else:
            shape = tuple(int(d) for d in cfg)
            batch = 0
        if len(shape) == 4:
            batch, shape = (batch or int(shape[0])), shape[1:]
        if len(shape) != 3:
            raise ValueError(
                f"warmup shape must be (N1, N2, N3) or (B, N1, N2, N3), "
                f"got {shape}")
        knobs = {k: overrides.get(k, _UNSET) for k in self._KNOB_NAMES}
        for k, v in per.items():
            knobs[k] = v
        return {
            "dims": tuple(int(d) for d in shape),
            "batch": max(int(batch), 1),
            "dtype": jnp.dtype(dtype or jnp.float32),
            "inverse": (self.inverse if inverse is None else bool(inverse)),
            "knobs": self._resolve_knobs(**knobs),
        }

    def warmup(self, shapes, *, inverse: bool | None = None,
               adjoint: bool = True, dtype=None, **overrides) -> list[dict]:
        """Pre-build plans, adjoint plans and autotune entries per bucket.

        ``shapes`` is an iterable of ``(N1, N2, N3)`` / ``(B, N1, N2, N3)``
        tuples or config dicts (``{"dims"|"shape", "batch", "dtype",
        "inverse"}`` plus any per-request knob — ``fuse``/``use_pallas``/
        ``vmem_budget``/``backend``/``accum``/``error_budget``).  Each
        entry describes a *(dims, dtype, fuse, accum)* bucket; keyword
        ``overrides`` apply to every entry (a per-entry knob wins).

        For each bucket every power-of-two batch up to the entry's batch
        is warmed — one dummy ``gemt3_planned`` call per sub-bucket builds
        the plan, runs autotuning (when the session tunes), and compiles
        the kernels; ``adjoint=True`` additionally pulls a VJP through the
        differentiable engine so the adjoint/chain plans and their
        autotune role are warm too (skipped for complex-coefficient kinds
        — the adjoint kernels are real-valued).  Warmup also flips
        ``bucket_batches`` on, so steady-state requests key the plan cache
        by the same power-of-two buckets: a warmed session pays **zero**
        ``plan`` / ``autotune.probe`` spans for any batch size that lands
        in a warmed bucket — in particular every coalesced batch the
        server can assemble under ``max_coalesce <= B``.

        Warmup work is counted in ``serve.warmup`` (one per sub-bucket,
        under a ``serve.warmup`` span) and deliberately does **not** touch
        the served-request telemetry (``serve.requests``, latency
        histogram, byte counters).  Returns one record per entry.
        """
        import jax

        from ..engine import gemt3_planned

        done = []
        for cfg in shapes:
            spec = self._warmup_spec(cfg, inverse, dtype, overrides)
            c1, c2, c3 = self._coeffs_for(spec["dims"], spec["inverse"])
            self.bucket_batches = True
            buckets, bb = [], 1
            while bb <= self._pow2_bucket(spec["batch"]):
                buckets.append(bb)
                bb *= 2
            for bb in buckets:
                sp = _trace.NULL_SPAN
                if _trace.enabled():
                    sp = _trace.span("serve.warmup",
                                     {"kind": self.kind,
                                      "dims": spec["dims"], "batch": bb,
                                      "dtype": spec["dtype"].name,
                                      "inverse": spec["inverse"]})
                with sp:
                    x0 = jnp.zeros((bb,) + spec["dims"], spec["dtype"])
                    if jnp.iscomplexobj(c1) and not jnp.iscomplexobj(x0):
                        x0 = x0.astype(c1.dtype)
                    kw = dict(spec["knobs"], autotune=self.autotune,
                              autotune_cache=self.autotune_cache,
                              mesh=self.mesh, axes=self.axes,
                              batch_axis=self.batch_axis, batch_bucket=bb)
                    y = gemt3_planned(x0, c1, c2, c3, **kw)
                    if adjoint and not jnp.iscomplexobj(c1):
                        yv, vjp = jax.vjp(
                            lambda t: gemt3_planned(t, c1, c2, c3,
                                                    differentiable=True,
                                                    **kw), x0)
                        jax.block_until_ready(vjp(yv))
                    jax.block_until_ready(y)
                _metrics.inc("serve.warmup")
            rec = {"dims": spec["dims"], "dtype": spec["dtype"].name,
                   "inverse": spec["inverse"], "buckets": tuple(buckets),
                   "fuse": spec["knobs"]["fuse"],
                   "accum": spec["knobs"]["accum"]}
            self.warmed.append(rec)
            done.append(rec)
        return done

    def transform(self, batch, inverse: bool | None = None, *,
                  fuse=_UNSET, use_pallas=_UNSET, vmem_budget=_UNSET,
                  backend=_UNSET, accum=_UNSET,
                  error_budget=_UNSET) -> jnp.ndarray:
        """Apply the transform to a (B, N1, N2, N3) batch.

        ``inverse`` overrides the session's direction for this request
        (None = the session default): round-trip serving — forward then
        inverse on the same session — reuses the per-dims coefficient
        cache and, since the directions share shapes and zero structure,
        the same engine plans and autotuned tiles.

        The keyword-only ``fuse``/``use_pallas``/``vmem_budget``/
        ``backend``/``accum``/``error_budget`` override the session
        defaults for this request — the degradation-ladder hooks
        :class:`repro.serve.ResilientDxtServer` uses to replan a failing
        request one tier down (or with compensated accumulation forced,
        after a nonfinite output) without touching the session's
        steady-state configuration.
        """
        from ..engine import DEFAULT_VMEM_BUDGET, gemt3_planned

        fuse = self.fuse if fuse is _UNSET else fuse
        use_pallas = self.use_pallas if use_pallas is _UNSET else use_pallas
        backend = self.backend if backend is _UNSET else backend
        accum = self.accum if accum is _UNSET else accum
        if error_budget is _UNSET:
            error_budget = self.error_budget
        if vmem_budget is _UNSET:
            vmem_budget = self.vmem_budget
        if vmem_budget is None:
            vmem_budget = DEFAULT_VMEM_BUDGET

        x = jnp.asarray(batch)
        if x.ndim != 4:
            raise ValueError(f"expected (B, N1, N2, N3), got shape {x.shape}")
        dims = tuple(int(d) for d in x.shape[1:])
        c1, c2, c3 = self._coeffs_for(dims, inverse)
        if jnp.iscomplexobj(c1) and not jnp.iscomplexobj(x):
            x = x.astype(c1.dtype)

        # Plans and tunings are memoized inside the engine (keyed on shape,
        # dtype, and the coefficient matrices' identity/zero structure —
        # the session's _coeffs dict keeps those identities stable).
        sp = _trace.NULL_SPAN
        if _trace.enabled():
            sp = _trace.span("serve.request",
                             {"kind": self.kind, "dims": dims,
                              "batch": int(x.shape[0])})
        t0 = time.perf_counter_ns()
        with sp:
            y, info = gemt3_planned(x, c1, c2, c3, fuse=fuse,
                                    vmem_budget=vmem_budget,
                                    backend=backend, accum=accum,
                                    error_budget=error_budget,
                                    autotune=self.autotune,
                                    autotune_cache=self.autotune_cache,
                                    use_pallas=use_pallas,
                                    with_info=True, mesh=self.mesh,
                                    axes=self.axes,
                                    batch_axis=self.batch_axis,
                                    batch_bucket=self._batch_bucket(
                                        int(x.shape[0])))
        self._latency_us.record((time.perf_counter_ns() - t0) / 1e3)
        _metrics.inc("serve.requests")
        self.requests_served += int(x.shape[0])
        if info.get("fused"):
            self.fused_served += int(x.shape[0])
            if len(info["fused"].get("modes", ())) == 3:
                self.fused3_served += int(x.shape[0])
        self.hbm_bytes_moved += int(info.get("hbm_bytes_moved", 0))
        self.hbm_bytes_staged += int(info.get("hbm_bytes_staged", 0))
        self.collective_bytes += int(info.get("collective_bytes", 0))
        self.last_info = info
        return y

    def stats(self) -> dict:
        """Session telemetry: the served counters plus a per-request host
        dispatch latency summary (``latency_us``: count/mean/min/max and
        p50/p90/p99 over the most recent window — see
        :class:`repro.obs.Histogram`)."""
        return {
            "requests_served": self.requests_served,
            "fused_served": self.fused_served,
            "fused3_served": self.fused3_served,
            "hbm_bytes_moved": self.hbm_bytes_moved,
            "hbm_bytes_staged": self.hbm_bytes_staged,
            "collective_bytes": self.collective_bytes,
            "latency_us": self._latency_us.summary(),
            "warmed": list(self.warmed),
            "bucket_batches": self.bucket_batches,
        }


class SlotManager:
    """Continuous-batching bookkeeping: fixed decode slots, per-slot position,
    admit-on-free semantics.  Host-side; the device step is shape-stable."""

    def __init__(self, n_slots: int, max_len: int):
        self.n_slots = n_slots
        self.max_len = max_len
        self.free = list(range(n_slots))
        self.pos = np.zeros((n_slots,), np.int64)
        self.active: dict[int, Any] = {}

    def admit(self, request_id) -> int | None:
        if not self.free:
            return None
        slot = self.free.pop()
        self.active[slot] = request_id
        self.pos[slot] = 0
        return slot

    def step(self, slot: int) -> int:
        self.pos[slot] += 1
        return int(self.pos[slot])

    def finish(self, slot: int):
        # idempotent: a double-finish must not put the slot on the free
        # list twice (it would later be handed to two requests at once)
        if slot in self.active:
            self.active.pop(slot)
            self.free.append(slot)

    @property
    def utilization(self) -> float:
        return 1.0 - len(self.free) / self.n_slots
