"""Serving layer: LM decode sessions + batched 3D-transform serving.

``DxtServeSession`` fronts the planned GEMT engine (paper §3 order search
+ §6 ESOP + stage fusion; ``docs/engine.md``) and, with ``mesh=``, the
distributed TriADA schedule (§4–§5; ``docs/distributed.md``).
``ResilientDxtServer`` wraps a session with the fault-tolerant request
lifecycle — admission/shedding, retry/backoff, the runtime degradation
ladder, elastic remesh-replan (``docs/serving.md``).
"""
from .decode import (DxtServeSession, ServeSession, SlotManager,
                     build_decode_step, build_prefill_step)
from .runtime import (LADDER_TIERS, CircuitBreaker, DeadlineExceeded,
                      Overloaded, Request, ResilientDxtServer, RetryPolicy)
