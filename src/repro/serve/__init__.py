from .decode import (DxtServeSession, ServeSession, SlotManager,
                     build_decode_step, build_prefill_step)
