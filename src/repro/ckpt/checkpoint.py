"""Sharded checkpointing: per-leaf .npy files + JSON manifest, atomic step
directories, async save thread, retention policy.

Integrity: every leaf file's on-disk bytes are SHA-256'd at save time and
the digest stored in the manifest; :func:`restore` re-hashes before
loading, so a torn write, bit rot, or external truncation surfaces as
:class:`CorruptCheckpoint` instead of silently restoring garbage weights.
When restoring "latest", a corrupt step falls back to the next older one
(counted in ``ckpt.restore.corrupt_recovered``); an explicitly requested
step raises.

Multi-host note: each host would write only its addressable shards (the
leaf loop uses ``jax.experimental.multihost_utils`` hooks in a real pod);
on this single-host container the full array is written.  Restore reshards
onto whatever mesh the caller provides (elastic restarts — see
runtime/elastic.py).
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from ..obs import metrics as _metrics

_SEP = "."


class CorruptCheckpoint(RuntimeError):
    """A step directory failed integrity verification (bad manifest,
    checksum mismatch, or unreadable leaf file)."""


class SaveHandle:
    """Join-able handle for a checkpoint write.

    ``save(blocking=False)`` returns one wrapping the writer thread;
    :meth:`join` waits for the write and **re-raises** any error the
    thread hit — an async save failure must surface at the join point
    (``run_resilient`` drains handles before restoring), not vanish in a
    daemon thread.  Blocking saves return an already-done handle so
    callers can treat both modes uniformly.  ``os.fspath(handle)`` /
    ``str(handle)`` give the checkpoint path for compatibility with the
    old str return.
    """

    def __init__(self, path: str, thread: threading.Thread | None = None):
        self.path = path
        self._thread = thread
        self._error: BaseException | None = None

    def done(self) -> bool:
        return self._thread is None or not self._thread.is_alive()

    def join(self, timeout: float | None = None) -> str:
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"checkpoint write still running: {self.path}")
            self._thread = None
        if self._error is not None:
            raise self._error
        return self.path

    def __fspath__(self) -> str:
        return self.path

    def __str__(self) -> str:
        return self.path


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}{_SEP}"))
    else:
        out[prefix.rstrip(_SEP)] = tree
    return out


def _unflatten(flat: dict):
    tree: dict = {}
    for k, v in flat.items():
        parts = k.split(_SEP)
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = v
    return tree


def save(ckpt_dir: str, step: int, state, keep: int = 3,
         blocking: bool = True) -> SaveHandle:
    """Write state to <ckpt_dir>/step_<N> atomically; prune old steps.

    Returns a :class:`SaveHandle`; with ``blocking=False`` the write runs
    on a thread and errors surface on ``handle.join()`` (plus the
    ``ckpt.save.error`` counter) instead of dying with the daemon thread.
    """
    flat = _flatten(state)
    host = {k: np.asarray(v) for k, v in flat.items()}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        manifest = {}
        for k, v in host.items():
            fname = re.sub(r"[^A-Za-z0-9_.-]", "_", k) + ".npy"
            dtype_name = str(v.dtype)
            if v.dtype.kind == "V" or dtype_name == "bfloat16":
                # ml_dtypes (bf16/fp8): persist as raw uint bits
                dtype_name = "bfloat16" if v.dtype.itemsize == 2 else dtype_name
                v = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
            fpath = os.path.join(tmp, fname)
            np.save(fpath, v)
            manifest[k] = {"file": fname, "shape": list(v.shape),
                           "dtype": dtype_name,
                           "sha256": _file_sha256(fpath)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": manifest}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _prune(ckpt_dir, keep)

    handle = SaveHandle(os.path.join(ckpt_dir, f"step_{step:08d}"))

    def _run():
        try:
            _write()
            _metrics.inc("ckpt.save.ok")
        except BaseException as e:
            handle._error = e
            _metrics.inc("ckpt.save.error")
            if blocking:
                raise

    if blocking:
        _run()
    else:
        handle._thread = threading.Thread(target=_run, daemon=True)
        handle._thread.start()
    return handle


def _file_sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def _load_verified(d: str) -> tuple[dict, int]:
    """Load one step directory with integrity verification.

    Raises :class:`CorruptCheckpoint` on a missing/unparsable manifest, a
    leaf whose on-disk bytes no longer hash to the manifest's digest (torn
    write, truncation, bit rot), or an unloadable ``.npy``.  Manifests
    predating the checksum field load unverified (back-compat).
    """
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, ValueError) as e:
        raise CorruptCheckpoint(f"unreadable manifest in {d}: {e}") from e
    flat = {}
    for k, meta in manifest.get("leaves", {}).items():
        path = os.path.join(d, meta["file"])
        want = meta.get("sha256")
        if want is not None:
            try:
                got = _file_sha256(path)
            except OSError as e:
                raise CorruptCheckpoint(
                    f"missing leaf {meta['file']} in {d}: {e}") from e
            if got != want:
                raise CorruptCheckpoint(
                    f"checksum mismatch for {meta['file']} in {d}: "
                    f"stored {want[:12]}…, found {got[:12]}…")
        try:
            arr = np.load(path)
        except (OSError, ValueError) as e:
            raise CorruptCheckpoint(
                f"unloadable leaf {meta['file']} in {d}: {e}") from e
        if meta["dtype"] not in (str(arr.dtype),):
            import ml_dtypes
            target = getattr(ml_dtypes, meta["dtype"], None)
            if target is not None:
                arr = arr.view(target)
        flat[k] = arr
    return flat, manifest["step"]


def restore(ckpt_dir: str, step: int | None = None, shardings=None,
            dtypes=None):
    """Load a checkpoint; optionally device_put onto ``shardings`` (a pytree
    of NamedSharding matching the saved structure) for elastic re-meshing.

    Every leaf is checksum-verified against the manifest before use.  With
    ``step=None`` a corrupt latest step falls back to the next older one
    (each fallback counts ``ckpt.restore.corrupt_recovered``); naming a
    ``step`` explicitly raises :class:`CorruptCheckpoint` instead — the
    caller asked for *those* bytes.
    """
    if not os.path.isdir(ckpt_dir):
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    steps = sorted((int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                    if d.startswith("step_") and not d.endswith(".tmp")),
                   reverse=True)
    if not steps:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    candidates = [step] if step is not None else steps
    flat = None
    for i, s in enumerate(candidates):
        d = os.path.join(ckpt_dir, f"step_{s:08d}")
        try:
            flat, step = _load_verified(d)
            break
        except CorruptCheckpoint:
            if step is not None or i == len(candidates) - 1:
                raise
            _metrics.inc("ckpt.restore.corrupt_recovered")
    tree = _unflatten(flat)
    if shardings is not None:
        flat_sh = _flatten(shardings)
        tree = _unflatten({
            k: jax.device_put(jnp.asarray(v), flat_sh[k]) if k in flat_sh
            else jnp.asarray(v)
            for k, v in _flatten(tree).items()})
    return tree, step
