"""Checkpointing substrate (save/restore/latest_step) for the train loop.

Not a paper subsystem — production scaffolding for the north-star training
path; re-meshed restores are exercised by the elastic runtime.  See
``docs/architecture.md`` ("Production substrate").
"""
from .checkpoint import (CorruptCheckpoint, SaveHandle, latest_step,
                         restore, save)
