"""Fault tolerance: checkpoint/restart training driver with failure
injection, plus straggler-mitigation accounting.

Large-scale posture (1000+ nodes):
  * **Checkpoint/restart** — synchronous data parallelism means any node
    failure is a global restart; recovery cost is bounded by the checkpoint
    cadence.  ``run_resilient`` implements the restart loop; data order is a
    pure function of the step (see data/pipeline.py), so restarts are
    bit-reproducible.
  * **Straggler mitigation** — per-step wall-time is monitored; steps slower
    than ``straggler_factor`` × rolling median are counted and surfaced.  On
    a real pod this feeds the backup-replica / re-shard decision; here the
    policy hook (``on_straggler``) is injectable (tested with synthetic
    delays).
  * **Elastic re-mesh** — see runtime/elastic.py: restore onto a smaller
    mesh from the same checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from .. import ckpt as ckpt_lib


class InjectedFailure(RuntimeError):
    """Simulated node failure (tests / chaos drills)."""


@dataclasses.dataclass
class ResilienceConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = False
    max_restarts: int = 10
    straggler_factor: float = 2.0


@dataclasses.dataclass
class RunReport:
    steps_done: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: list = dataclasses.field(default_factory=list)
    step_times: list = dataclasses.field(default_factory=list)


def run_resilient(
    init_state_fn: Callable[[], Any],
    train_step: Callable[[Any, Any], tuple[Any, dict]],
    batch_fn: Callable[[int], Any],
    n_steps: int,
    rcfg: ResilienceConfig,
    fail_at: set[int] | None = None,
    on_straggler: Callable[[int, float], None] | None = None,
) -> tuple[Any, RunReport]:
    """Train for n_steps with checkpoint/restart; injected failures at the
    step numbers in ``fail_at`` raise once each, exercising recovery."""
    fail_at = set(fail_at or ())
    report = RunReport()
    restarts = 0
    while True:
        # -- (re)start: restore latest checkpoint or cold-init -------------
        last = ckpt_lib.latest_step(rcfg.ckpt_dir)
        if last is not None:
            state, step = ckpt_lib.restore(rcfg.ckpt_dir)
        else:
            state, step = init_state_fn(), 0
        try:
            while step < n_steps:
                if step in fail_at:
                    fail_at.discard(step)
                    raise InjectedFailure(f"simulated node loss at step {step}")
                t0 = time.perf_counter()
                batch = batch_fn(step)
                state, metrics = train_step(state, batch)
                dt = time.perf_counter() - t0
                report.step_times.append(dt)
                med = float(np.median(report.step_times[-20:]))
                if dt > rcfg.straggler_factor * med and len(report.step_times) > 5:
                    report.stragglers += 1
                    if on_straggler:
                        on_straggler(step, dt)
                report.losses.append(float(metrics.get("loss", np.nan)))
                step += 1
                report.steps_done = step
                if step % rcfg.ckpt_every == 0 or step == n_steps:
                    ckpt_lib.save(rcfg.ckpt_dir, step, state, keep=rcfg.keep,
                                  blocking=not rcfg.async_save)
            return state, report
        except InjectedFailure:
            restarts += 1
            report.restarts = restarts
            if restarts > rcfg.max_restarts:
                raise
            # loop back: restore from the last durable checkpoint
