"""Fault tolerance: checkpoint/restart training driver with failure
injection, plus straggler-mitigation accounting.

Large-scale posture (1000+ nodes):
  * **Checkpoint/restart** — synchronous data parallelism means any node
    failure is a global restart; recovery cost is bounded by the checkpoint
    cadence.  ``run_resilient`` implements the restart loop; data order is a
    pure function of the step (see data/pipeline.py), so restarts are
    bit-reproducible.
  * **Straggler mitigation** — per-step wall-time is monitored; steps slower
    than ``straggler_factor`` × rolling median are counted and surfaced.  On
    a real pod this feeds the backup-replica / re-shard decision; here the
    policy hook (``on_straggler``) is injectable (tested with synthetic
    delays).
  * **Elastic re-mesh** — see runtime/elastic.py: restore onto a smaller
    mesh from the same checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import numpy as np

from .. import ckpt as ckpt_lib


class InjectedFailure(RuntimeError):
    """Simulated node failure (tests / chaos drills)."""


@dataclasses.dataclass
class ResilienceConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    async_save: bool = False
    max_restarts: int = 10
    straggler_factor: float = 2.0
    # Exception types that trigger checkpoint/restart instead of
    # propagating — widen to (InjectedFailure, OSError) to also recover
    # from transient checkpoint I/O errors.
    retryable: tuple = (InjectedFailure,)


@dataclasses.dataclass
class RunReport:
    steps_done: int = 0
    restarts: int = 0
    stragglers: int = 0
    losses: list = dataclasses.field(default_factory=list)
    step_times: list = dataclasses.field(default_factory=list)


def run_resilient(
    init_state_fn: Callable[[], Any],
    train_step: Callable[[Any, Any], tuple[Any, dict]],
    batch_fn: Callable[[int], Any],
    n_steps: int,
    rcfg: ResilienceConfig,
    fail_at: set[int] | None = None,
    on_straggler: Callable[[int, float], None] | None = None,
) -> tuple[Any, RunReport]:
    """Train for n_steps with checkpoint/restart; injected failures at the
    step numbers in ``fail_at`` raise once each, exercising recovery.

    Any exception in ``rcfg.retryable`` triggers restore-and-replay (up to
    ``max_restarts``); replayed steps overwrite — never duplicate — the
    lost segment's ``losses``/``step_times`` entries, so the report holds
    exactly one entry per step.  Async saves are drained (joined, errors
    surfaced as retryable restarts) before any restore and before
    returning.
    """
    fail_at = set(fail_at or ())
    report = RunReport()
    restarts = 0
    pending: list = []  # in-flight async SaveHandles
    retryable = tuple(rcfg.retryable)
    while True:
        try:
            # -- (re)start: restore latest checkpoint or cold-init ---------
            # Drain in-flight saves first: a restore racing an async write
            # could read a half-renamed step, and a failed write must
            # surface here (as a retryable error) rather than vanish.
            while pending:
                pending.pop().join()
            last = ckpt_lib.latest_step(rcfg.ckpt_dir)
            if last is not None:
                state, step = ckpt_lib.restore(rcfg.ckpt_dir)
            else:
                state, step = init_state_fn(), 0
            # The lost segment's entries beyond the restored step are about
            # to be replayed — truncate so losses/step_times hold exactly
            # one entry per step (no double counting).
            del report.losses[step:]
            del report.step_times[step:]
            while step < n_steps:
                if step in fail_at:
                    fail_at.discard(step)
                    raise InjectedFailure(f"simulated node loss at step {step}")
                t0 = time.perf_counter()
                batch = batch_fn(step)
                state, metrics = train_step(state, batch)
                dt = time.perf_counter() - t0
                report.step_times.append(dt)
                med = float(np.median(report.step_times[-20:]))
                if dt > rcfg.straggler_factor * med and len(report.step_times) > 5:
                    report.stragglers += 1
                    if on_straggler:
                        on_straggler(step, dt)
                report.losses.append(float(metrics.get("loss", np.nan)))
                step += 1
                report.steps_done = step
                if step % rcfg.ckpt_every == 0 or step == n_steps:
                    handle = ckpt_lib.save(rcfg.ckpt_dir, step, state,
                                           keep=rcfg.keep,
                                           blocking=not rcfg.async_save)
                    if rcfg.async_save:
                        pending.append(handle)
            while pending:  # the return must not race a trailing write
                pending.pop().join()
            return state, report
        except retryable:
            restarts += 1
            report.restarts = restarts
            if restarts > rcfg.max_restarts:
                raise
            # loop back: restore from the last durable checkpoint
