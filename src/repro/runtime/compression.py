"""Gradient compression for slow/oversubscribed interconnects.

``compressed_psum_tree``: int8 block-quantized all-reduce inside shard_map —
each device quantizes its local gradient shard (per-block absmax scale),
psums the int8 payload (+ fp32 scales), and dequantizes.  8× lower ICI
traffic on the gradient all-reduce at ~1e-2 relative error (validated in
tests).  ``error_feedback`` keeps the residual locally so the bias vanishes
across steps (standard EF-SGD trick).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(x: jnp.ndarray, block: int = 256):
    """Per-block absmax int8 quantization.  Returns (q, scales, orig_shape)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, x.shape


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[: int(np.prod(shape))].reshape(shape)


def compressed_psum(x: jnp.ndarray, axis_name, block: int = 256):
    """int8-quantized psum over ``axis_name`` (call inside shard_map).

    Every rank quantizes against the *group-max* per-block scale (one tiny
    pmax round for the scales), so the int32 payload sum dequantizes
    exactly: Σᵢ round(xᵢ/s)·s.  Traffic: 1 byte/elem + scale vector, vs 4
    bytes/elem for the fp32 psum.  int32 accumulation cannot overflow for
    group sizes ≤ 2²³.
    """
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jax.lax.pmax(jnp.maximum(scale, 1e-12), axis_name)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return dequantize_int8(qsum, scale, x.shape)


def compressed_psum_tree(tree, axis_name, block: int = 256):
    return jax.tree.map(lambda x: compressed_psum(x, axis_name, block), tree)


def error_feedback_update(grads, residual, compress_fn):
    """EF: compress (g + r), keep the quantization error as next residual."""
    g_plus_r = jax.tree.map(jnp.add, grads, residual)
    compressed = compress_fn(g_plus_r)
    new_residual = jax.tree.map(jnp.subtract, g_plus_r, compressed)
    return compressed, new_residual
