"""Chaos layer: scripted fault injection keyed to obs span names.

Every engine/serve hot path is already instrumented with spans
(``stage:m2:sr_gemm``, ``fused_triple:m312``, ``collective:psum_scatter``,
``execute.sharded``, ``serve.request`` — see ``docs/observability.md``),
and :func:`repro.obs.trace.span` fires an installed *fault hook* with the
span name before any work the span would time.  A :class:`FaultInjector`
is such a hook: it matches names against scripted :class:`FaultSpec`
patterns and injects

* ``exception`` — raise :class:`FaultError` (a failed kernel launch),
* ``delay`` — sleep ``delay_s`` (a straggling launch / slow collective),
* ``vmem_pressure`` — raise :class:`VmemPressure` (RESOURCE_EXHAUSTED:
  the tile working set no longer fits on-chip),
* ``device_loss`` — raise :class:`DeviceLoss` with the surviving device
  count (half the pod disappears mid-request),
* ``nan`` — arm a *poison* flag instead of raising: the hook fires before
  the work a span times, so a silent-corruption drill cannot corrupt the
  output from here.  The serving runtime polls
  :func:`consume_nan_poison` after each transform and multiplies the
  result by NaN when armed — modeling a kernel that completed with
  corrupted accumulators, which only a finite-guard can catch
  (``docs/numerics.md``).

Each spec carries a ``times`` budget and an ``after`` skip so drills can
script "the second fused_triple launch fails twice, then heals".  The
injector counts every injection in ``faults.injected.{kind}`` obs
counters, so a drill's recovery accounting (``serve.retry`` etc., see
:mod:`repro.serve.runtime`) can be balanced against what was injected.

Span names fire *per call* on the single-device engine path; inside a
jitted ``shard_map`` body they fire once per compilation (see
``docs/observability.md``), so device-loss drills key on the per-call
``serve.request`` / ``execute.sharded`` spans instead of ``stage:*``.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import time

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .fault_tolerance import InjectedFailure

__all__ = [
    "FAULT_KINDS",
    "FaultSpec",
    "FaultInjector",
    "FaultError",
    "VmemPressure",
    "DeviceLoss",
    "inject_faults",
    "consume_nan_poison",
]

FAULT_KINDS = ("exception", "delay", "vmem_pressure", "device_loss", "nan")

# Pending silent-corruption injections ("nan" kind): armed by the hook,
# drained by the runtime's finite-guard path via consume_nan_poison().
_nan_poison_pending = 0


def consume_nan_poison() -> bool:
    """Drain one armed ``nan`` fault; True if one was pending.

    The serving runtime calls this after each transform and poisons the
    output itself — the span hook runs *before* the work, so this is the
    only way an injector can model silent output corruption.
    """
    global _nan_poison_pending
    if _nan_poison_pending > 0:
        _nan_poison_pending -= 1
        return True
    return False


class FaultError(InjectedFailure):
    """Injected kernel/collective launch failure (retryable)."""


class VmemPressure(FaultError):
    """Injected RESOURCE_EXHAUSTED: plan's working set exceeds VMEM."""


class DeviceLoss(FaultError):
    """Injected loss of devices mid-request; ``survivors`` is the count
    still alive (None = let the handler ask the platform)."""

    def __init__(self, message: str, survivors: int | None = None):
        super().__init__(message)
        self.survivors = survivors


@dataclasses.dataclass
class FaultSpec:
    """One scripted fault: ``match`` is an ``fnmatch`` pattern over span
    names; the first ``after`` matching hits pass through, then up to
    ``times`` injections fire (``times <= 0`` = unlimited)."""

    match: str
    kind: str = "exception"
    times: int = 1
    after: int = 0
    delay_s: float = 0.0
    survivors: int | None = None
    message: str = ""
    # runtime accounting (mutated by the injector)
    hits: int = 0
    injected: int = 0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")

    @property
    def exhausted(self) -> bool:
        return 0 < self.times <= self.injected


class FaultInjector:
    """A fault hook (see :func:`repro.obs.trace.set_fault_hook`) driving a
    scripted schedule of :class:`FaultSpec`\\ s.  Use :func:`inject_faults`
    for scoped installation."""

    def __init__(self, *specs: FaultSpec, sleep=time.sleep):
        self.specs = list(specs)
        self._sleep = sleep
        self._prev = None

    def __call__(self, name: str) -> None:
        for spec in self.specs:
            if not fnmatch.fnmatchcase(name, spec.match):
                continue
            spec.hits += 1
            if spec.hits <= spec.after or spec.exhausted:
                continue
            spec.injected += 1
            _metrics.inc(f"faults.injected.{spec.kind}")
            tracer = _trace.get_tracer()
            if tracer.enabled:
                # record the injection itself (Span directly: going through
                # trace.span() would re-enter this hook)
                with _trace.Span(tracer, f"fault:{spec.kind}",
                                 {"at": name, "match": spec.match}):
                    pass
            msg = spec.message or f"injected {spec.kind} at span {name!r}"
            if spec.kind == "nan":
                global _nan_poison_pending
                _nan_poison_pending += 1
            elif spec.kind == "delay":
                self._sleep(spec.delay_s)
            elif spec.kind == "vmem_pressure":
                raise VmemPressure(msg)
            elif spec.kind == "device_loss":
                raise DeviceLoss(msg, survivors=spec.survivors)
            else:
                raise FaultError(msg)

    def install(self) -> "FaultInjector":
        self._prev = _trace.set_fault_hook(self)
        return self

    def uninstall(self) -> None:
        _trace.set_fault_hook(self._prev)
        self._prev = None
        # Unconsumed poison must not leak into the next drill (a request
        # admitted after the injector leaves would fail its finite-guard
        # with no matching faults.injected.nan in *its* accounting window).
        global _nan_poison_pending
        _nan_poison_pending = 0

    @property
    def exhausted(self) -> bool:
        """True once every bounded spec has spent its budget."""
        return all(s.exhausted for s in self.specs if s.times > 0)

    def stats(self) -> dict:
        return {s.match: {"kind": s.kind, "hits": s.hits,
                          "injected": s.injected} for s in self.specs}


@contextlib.contextmanager
def inject_faults(*specs: FaultSpec, sleep=time.sleep):
    """Install a :class:`FaultInjector` for the ``with`` body (previous
    hook restored on exit); yields the injector for accounting."""
    inj = FaultInjector(*specs, sleep=sleep).install()
    try:
        yield inj
    finally:
        inj.uninstall()
