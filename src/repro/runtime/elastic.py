"""Elastic scaling: re-mesh a checkpointed run onto a different device count.

Scenario: a pod loses a rack mid-run.  The job restarts on the surviving
devices with the same *logical* sharding rules; only the mesh shape changes.
Because checkpoints are stored as full logical arrays (per-leaf .npy) and
shardings are derived from logical axes + rules at load time, restore is a
``device_put`` onto the new mesh — no resharding tool needed.

``remesh_plan`` computes the largest valid (data, model) sub-mesh for a
surviving device count (model axis preserved first: TP degree is baked into
padding choices; the data axis absorbs elasticity — the standard posture).

Multi-pod fleets add one placement constraint: a model-parallel group's
all-to-all traffic must stay on intra-pod ICI, so a TP group must never
straddle a pod boundary.  ``multi_pod=True`` takes the *per-pod* surviving
counts and each pod contributes ``count // tp`` data-parallel groups —
stragglers on a partially-dead pod are left idle rather than paired with
devices across the (slow) inter-pod fabric.  ``make_elastic_mesh`` applies
the same rule to device selection via ``pod_of``.

The serving runtime reuses ``remesh_plan``'s validation for its device-loss
recovery (``docs/serving.md``): shrink the leading axis, keep TP, replan.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh_plan(n_devices: int, tp: int, multi_pod: bool = False,
                pod_counts=None):
    """Largest (dp, tp) grid with dp*tp <= n_devices, tp fixed.

    ``multi_pod=True`` requires ``pod_counts`` — the surviving device
    count of each pod, summing to ``n_devices`` — and keeps every TP group
    within one pod: ``dp = sum(count // tp per pod)``, which can be
    smaller than the single-fabric ``n_devices // tp`` when survivors are
    scattered across pods.  Passing ``pod_counts`` without ``multi_pod``
    raises (an ignored placement constraint would silently produce
    straddling groups).
    """
    if not multi_pod:
        if pod_counts is not None:
            raise ValueError(
                "pod_counts is only meaningful with multi_pod=True — "
                "refusing to silently ignore a placement constraint")
        if n_devices < tp:
            raise ValueError(
                f"cannot keep TP={tp} with only {n_devices} devices; "
                "TP degree is baked into head/vocab padding — restore requires "
                "at least one full model-parallel group")
        return (n_devices // tp, tp)
    if pod_counts is None:
        raise ValueError("multi_pod=True requires pod_counts (surviving "
                         "devices per pod)")
    pod_counts = tuple(int(c) for c in pod_counts)
    if any(c < 0 for c in pod_counts) or sum(pod_counts) != n_devices:
        raise ValueError(
            f"pod_counts {pod_counts} must be non-negative and sum to "
            f"n_devices={n_devices}")
    dp = sum(c // tp for c in pod_counts)
    if dp < 1:
        raise ValueError(
            f"cannot keep TP={tp} within any pod of {pod_counts}; "
            "TP groups must not straddle a pod boundary and no pod has a "
            "full model-parallel group left")
    return (dp, tp)


def make_elastic_mesh(devices, tp: int, multi_pod: bool = False,
                      pod_of=None) -> Mesh:
    """Build the (data, model) mesh on ``devices``.

    ``multi_pod=True`` groups devices by ``pod_of(device)`` (default:
    ``device.id // tp`` is *not* assumed — ``pod_of`` is required) and
    keeps each TP group within one pod, dropping per-pod stragglers.
    """
    import numpy as np

    if not multi_pod:
        if pod_of is not None:
            raise ValueError(
                "pod_of is only meaningful with multi_pod=True — "
                "refusing to silently ignore a placement constraint")
        dp, tp = remesh_plan(len(devices), tp)
        devs = devices[: dp * tp]
        return Mesh(np.asarray(devs).reshape(dp, tp), ("data", "model"))
    if pod_of is None:
        raise ValueError("multi_pod=True requires pod_of (device -> pod id)")
    pods: dict = {}
    for d in devices:
        pods.setdefault(pod_of(d), []).append(d)
    counts = tuple(len(v) for _, v in sorted(pods.items()))
    dp, tp = remesh_plan(len(devices), tp, multi_pod=True,
                         pod_counts=counts)
    devs = [d for _, pod in sorted(pods.items())
            for d in pod[: (len(pod) // tp) * tp]]
    return Mesh(np.asarray(devs).reshape(dp, tp), ("data", "model"))


def reshard_state(state, old_shardings, new_mesh, spec_tree):
    """device_put a (restored) state onto the new mesh's shardings."""
    return jax.tree.map(
        lambda x, spec: jax.device_put(x, NamedSharding(new_mesh, spec)),
        state, spec_tree,
        is_leaf=lambda x: not isinstance(x, dict))
