"""Elastic scaling: re-mesh a checkpointed run onto a different device count.

Scenario: a pod loses a rack mid-run.  The job restarts on the surviving
devices with the same *logical* sharding rules; only the mesh shape changes.
Because checkpoints are stored as full logical arrays (per-leaf .npy) and
shardings are derived from logical axes + rules at load time, restore is a
``device_put`` onto the new mesh — no resharding tool needed.

``remesh_plan`` computes the largest valid (data, model) sub-mesh for a
surviving device count (model axis preserved first: TP degree is baked into
padding choices; the data axis absorbs elasticity — the standard posture).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def remesh_plan(n_devices: int, tp: int, multi_pod: bool = False):
    """Largest (dp, tp) grid with dp*tp <= n_devices, tp fixed."""
    if n_devices < tp:
        raise ValueError(
            f"cannot keep TP={tp} with only {n_devices} devices; "
            "TP degree is baked into head/vocab padding — restore requires "
            "at least one full model-parallel group")
    dp = n_devices // tp
    return (dp, tp)


def make_elastic_mesh(devices, tp: int) -> Mesh:
    dp, tp = remesh_plan(len(devices), tp)
    devs = devices[: dp * tp]
    import numpy as np
    return Mesh(np.asarray(devs).reshape(dp, tp), ("data", "model"))


def reshard_state(state, old_shardings, new_mesh, spec_tree):
    """device_put a (restored) state onto the new mesh's shardings."""
    return jax.tree.map(
        lambda x, spec: jax.device_put(x, NamedSharding(new_mesh, spec)),
        state, spec_tree,
        is_leaf=lambda x: not isinstance(x, dict))
