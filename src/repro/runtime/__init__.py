"""Production runtime: fault tolerance, elastic re-meshing, compressed
collectives, chaos fault injection.

Scales the TriADA schedule to unreliable fleets — ``compressed_psum`` is
the lossy analogue of the paper's operand-bus multicast for gradient
combines; :mod:`repro.runtime.faults` scripts failures onto the engine's
obs span names so the serving runtime's recovery paths are drill-testable
(``docs/serving.md``).  See ``docs/architecture.md`` ("Production
substrate").
"""
from .fault_tolerance import (InjectedFailure, ResilienceConfig, RunReport,
                              run_resilient)
from .compression import (compressed_psum, compressed_psum_tree,
                          dequantize_int8, error_feedback_update,
                          quantize_int8)
from .elastic import make_elastic_mesh, remesh_plan, reshard_state
from .faults import (FAULT_KINDS, DeviceLoss, FaultError, FaultInjector,
                     FaultSpec, VmemPressure, inject_faults)
