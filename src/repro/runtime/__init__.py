from .fault_tolerance import (InjectedFailure, ResilienceConfig, RunReport,
                              run_resilient)
from .compression import (compressed_psum, compressed_psum_tree,
                          dequantize_int8, error_feedback_update,
                          quantize_int8)
from .elastic import make_elastic_mesh, remesh_plan, reshard_state
