"""Assigned architecture configs (+ the paper's own DXT workload)."""
from .base import (ARCH_IDS, LONG_CONTEXT_OK, SHAPES, BlockCfg, ModelConfig,
                   ShapeCfg, all_configs, input_specs, load_config)
