"""Assigned architecture configs (+ the paper's own DXT workload).

See ``docs/architecture.md`` ("Production substrate").
"""
from .base import (ARCH_IDS, LONG_CONTEXT_OK, SHAPES, BlockCfg, ModelConfig,
                   ShapeCfg, all_configs, input_specs, load_config)
