"""Yi-34B [dense] — arXiv:2403.04652 (llama arch, GQA).

60L, d_model=7168, 56H (GQA kv=8), d_ff=20480, vocab=64000; RMSNorm,
SwiGLU, RoPE theta=5e6.  56 heads pad to 64 for TP=16.
"""
from .base import BlockCfg, ModelConfig

_BLK = (BlockCfg("attn", "swiglu"),)

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=20480, vocab_size=64000,
    segments=((_BLK, 60),),
    rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-34b-smoke", family="dense",
    n_layers=2, d_model=112, n_heads=7, n_kv_heads=1,
    d_ff=320, vocab_size=256,
    segments=((_BLK, 2),),
    rope_theta=5_000_000.0,
)
