"""DeepSeek-Coder-33B [dense] — arXiv:2401.14196 (llama arch).

62L, d_model=7168, 56H (GQA kv=8), d_ff=19200, vocab=32256; RMSNorm,
SwiGLU, RoPE theta=1e5 (linear scaling omitted — base arch).
56 heads pad to 64 for TP=16 (DESIGN.md §4).
"""
from .base import BlockCfg, ModelConfig

_BLK = (BlockCfg("attn", "swiglu"),)

CONFIG = ModelConfig(
    name="deepseek-coder-33b", family="dense",
    n_layers=62, d_model=7168, n_heads=56, n_kv_heads=8,
    d_ff=19200, vocab_size=32256,
    segments=((_BLK, 62),),
    rope_theta=100_000.0,
)

SMOKE = ModelConfig(
    name="deepseek-coder-33b-smoke", family="dense",
    n_layers=2, d_model=112, n_heads=7, n_kv_heads=1,
    d_ff=320, vocab_size=256,
    segments=((_BLK, 2),),
    rope_theta=100_000.0,
)
