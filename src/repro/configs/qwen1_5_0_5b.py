"""Qwen1.5-0.5B [dense] — hf:Qwen/Qwen1.5-0.5B.

24L, d_model=1024, 16H (kv=16), d_ff=2816, vocab=151936; QKV bias; tied
embeddings; RoPE theta=1e6 (Qwen1.5 family); RMSNorm + SwiGLU.
"""
from .base import BlockCfg, ModelConfig

_BLK = (BlockCfg("attn", "swiglu"),)

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=2816, vocab_size=151936,
    segments=((_BLK, 24),),
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=176, vocab_size=256,
    segments=((_BLK, 2),),
    qkv_bias=True, tie_embeddings=True, rope_theta=1_000_000.0,
)
