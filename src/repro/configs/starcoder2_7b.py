"""StarCoder2-7B [dense] — arXiv:2402.19173.

32L, d_model=4608, 36H (GQA kv=4), d_ff=18432, vocab=49152; sliding-window
attention (w=4096); LayerNorm; GELU MLP; RoPE theta=1e5; QKV bias.
Window attention makes the rolling-cache long_500k decode cell admissible.
"""
from .base import BlockCfg, ModelConfig

_BLK = (BlockCfg("attn", "gelu", window=4096),)

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    n_layers=32, d_model=4608, n_heads=36, n_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    segments=((_BLK, 32),),
    norm="ln", qkv_bias=True, rope_theta=100_000.0,
)

SMOKE = ModelConfig(
    name="starcoder2-7b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=384, vocab_size=256,
    segments=(((BlockCfg("attn", "gelu", window=16),), 2),),
    norm="ln", qkv_bias=True, rope_theta=100_000.0,
)
