"""RecurrentGemma-9B [hybrid] — arXiv:2402.19427 (Griffin).

38L, d_model=4096, 16H (MQA kv=1), d_ff=12288, vocab=256000; pattern
(recurrent, recurrent, local-attention) at 1:2 attention:recurrent ratio,
local window 2048; RG-LRU + GeGLU MLP; RMSNorm.
38 = 12×(rec,rec,attn) + (rec,rec).
"""
from .base import BlockCfg, ModelConfig

_REC = BlockCfg("rglru", "geglu")
_ATT = BlockCfg("attn", "geglu", window=2048)

CONFIG = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1,
    d_ff=12288, vocab_size=256000, head_dim=256,
    segments=(((_REC, _REC, _ATT), 12), ((_REC, _REC), 1)),
    lru_width=4096, conv_width=4, rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="recurrentgemma-9b-smoke", family="hybrid",
    n_layers=3, d_model=64, n_heads=2, n_kv_heads=1,
    d_ff=192, vocab_size=256, head_dim=32,
    segments=(((_REC, _REC, BlockCfg("attn", "geglu", window=8)), 1),),
    lru_width=64, conv_width=4,
)
