"""DeepSeek-V3-671B [moe] — arXiv:2412.19437.

61L, d_model=7168, 128H MLA (q_lora=1536, kv_lora=512, qk_nope=128,
qk_rope=64, v=128), MoE 1 shared + 256 routed top-8 with expert d_ff=2048,
first 3 layers dense (d_ff=18432), vocab=129280.
MTP (multi-token prediction) head is out of scope (DESIGN.md §5).
"""
from .base import BlockCfg, ModelConfig

_DENSE = (BlockCfg("mla", "swiglu"),)
_MOE = (BlockCfg("mla", "moe"),)

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432, vocab_size=129280,
    segments=((_DENSE, 3), (_MOE, 58)),
    n_experts=256, top_k=8, n_shared_experts=1, moe_d_ff=2048,
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="deepseek-v3-671b-smoke", family="moe",
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256,
    segments=((_DENSE, 1), (_MOE, 2)),
    n_experts=4, top_k=2, n_shared_experts=1, moe_d_ff=32,
    capacity_factor=4.0,  # dropless at smoke scale: train==decode exactly
    q_lora_rank=32, kv_lora_rank=16,
    qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
)
