"""MusicGen-large [audio] — arXiv:2306.05284.

Decoder-only over EnCodec tokens: 48L, d_model=2048, 32H (MHA kv=32),
d_ff=8192, vocab=2048 per codebook; LayerNorm, GELU MLP, sinusoidal
positions.  The EnCodec frontend + delay-pattern interleaving is a STUB:
``input_specs`` provides 4-codebook token frames (B, S, 4); the embedding
sums the per-codebook tables (faithful to the backbone input interface).
"""
from .base import BlockCfg, ModelConfig

_BLK = (BlockCfg("attn", "gelu"),)

CONFIG = ModelConfig(
    name="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    segments=((_BLK, 48),),
    norm="ln", pos="sinusoidal", input_mode="codebooks", n_codebooks=4,
)

SMOKE = ModelConfig(
    name="musicgen-large-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=256, vocab_size=64,
    segments=((_BLK, 2),),
    norm="ln", pos="sinusoidal", input_mode="codebooks", n_codebooks=4,
)
