"""xLSTM-350M [ssm] — arXiv:2405.04517.

24L, d_model=1024, 4 heads, d_ff=0 (no separate MLP — the m/sLSTM blocks
carry their own up/gate/down projections), vocab=50304; 7:1 mLSTM:sLSTM
pattern (3 super-blocks of 7 mLSTM + 1 sLSTM = 24 layers); no positional
encoding (recurrence carries order).
"""
from .base import BlockCfg, ModelConfig

_M = BlockCfg("mlstm", "none")
_S = BlockCfg("slstm", "none")

CONFIG = ModelConfig(
    name="xlstm-350m", family="ssm",
    n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    segments=(((_M,) * 7 + (_S,), 3),),
    pos="none", n_lstm_heads=4, mlstm_chunk=128,
    shard_attn_heads=False,  # 4 heads < TP: replicate mixers, TP on vocab
)

SMOKE = ModelConfig(
    name="xlstm-350m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=2, n_kv_heads=2,
    d_ff=0, vocab_size=256,
    segments=(((_M, _S), 1),),
    pos="none", n_lstm_heads=2, mlstm_chunk=16,
    shard_attn_heads=False,
)
