"""Granite-3.0-1B-A400M [moe] — hf:ibm-granite/granite-3.0-1b-a400m-base.

24L, d_model=1024, 16H (GQA kv=8), MoE 32 experts top-8, expert d_ff=512,
vocab=49155; RMSNorm + SwiGLU experts; tied embeddings; RoPE.
Vocab pads 49155 → TP multiple (DESIGN.md §4).
"""
from .base import BlockCfg, ModelConfig

_BLK = (BlockCfg("attn", "moe"),)

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=512, vocab_size=49155,
    segments=((_BLK, 24),),
    n_experts=32, top_k=8, moe_d_ff=512,
    tie_embeddings=True, rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="granite-moe-1b-a400m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=64, vocab_size=259,  # deliberately non-multiple: exercises padding
    segments=((_BLK, 2),),
    n_experts=4, top_k=2, moe_d_ff=64,
    capacity_factor=4.0,  # dropless at smoke scale: train==decode exactly
    tie_embeddings=True,
)
