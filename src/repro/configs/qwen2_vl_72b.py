"""Qwen2-VL-72B [vlm] — arXiv:2409.12191.

Backbone only (assignment): 80L, d_model=8192, 64H (GQA kv=8), d_ff=29568,
vocab=152064; M-RoPE (sections t=16, h=24, w=24 over head_dim/2=64);
QKV bias; RMSNorm + SwiGLU.  The vision frontend is a STUB — ``input_specs``
provides precomputed patch embeddings (B, S, d_model) and position triples.
"""
from .base import BlockCfg, ModelConfig

_BLK = (BlockCfg("attn", "swiglu"),)

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    segments=((_BLK, 80),),
    qkv_bias=True, pos="mrope", mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0, input_mode="embeddings",
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
    d_ff=256, vocab_size=256, head_dim=32,
    segments=((_BLK, 2),),
    qkv_bias=True, pos="mrope", mrope_sections=(4, 6, 6),
    rope_theta=1_000_000.0, input_mode="embeddings",
)
