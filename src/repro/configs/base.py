"""Config schema: ModelConfig, assigned input shapes, input_specs(), registry.

Every assigned architecture provides ``CONFIG`` (exact published config) and
``SMOKE`` (reduced same-family config for CPU smoke tests) in its module.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Block / model configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class BlockCfg:
    mixer: str  # attn | mla | rglru | mlstm | slstm
    mlp: str  # swiglu | geglu | gelu | moe | none
    window: int | None = None  # sliding-window size for attn


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | vlm | audio | dxt
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    segments: tuple[tuple[BlockCfg, int], ...] = ()
    norm: str = "rms"  # rms | ln
    qkv_bias: bool = False
    pos: str = "rope"  # rope | mrope | sinusoidal | none
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] | None = None
    tie_embeddings: bool = False
    input_mode: str = "tokens"  # tokens | embeddings | codebooks
    n_codebooks: int = 1
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # MLA (DeepSeek-V3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # Recurrent families
    lru_width: int = 0
    conv_width: int = 4
    mlstm_chunk: int = 128
    n_lstm_heads: int = 4
    # numerics / execution
    param_dtype: Any = jnp.bfloat16
    act_dtype: Any = jnp.bfloat16
    remat: str = "block"  # none | block | dots
    q_chunk: int = 512
    kv_chunk: int = 1024
    scan_layers: bool = True
    # sharding-time padding (set by finalize_for_mesh; identity by default)
    pad_heads_to: int = 1
    pad_kv_heads_to: int = 1
    pad_vocab_to: int = 1
    shard_attn_heads: bool = True
    # paper-technique toggles (TriADA)
    use_triada_mixer: bool = False
    triada_kind: str = "dct"

    # -- derived ------------------------------------------------------------
    @property
    def eff_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def eff_n_heads(self) -> int:
        return _ceil_to(self.n_heads, self.pad_heads_to)

    @property
    def eff_n_kv_heads(self) -> int:
        if self.n_kv_heads >= self.pad_kv_heads_to:
            return _ceil_to(self.n_kv_heads, self.pad_kv_heads_to)
        # Fewer KV heads than TP degree: replicate (vLLM-style) to TP degree,
        # exact math (each replica serves a subset of the query groups).
        return self.pad_kv_heads_to

    @property
    def eff_vocab(self) -> int:
        return _ceil_to(self.vocab_size, self.pad_vocab_to)

    @property
    def eff_segments(self) -> tuple[tuple[tuple[BlockCfg, ...], int], ...]:
        """Normalized segments: ((sub_blocks...), repeat_count) per segment.

        A segment scans ``repeat_count`` super-blocks; each super-block
        applies its sub-blocks in order (heterogeneous patterns like
        rgemma's (rec, rec, attn) or xLSTM's 7 mLSTM + 1 sLSTM).
        """
        if self.segments:
            out = []
            for blocks, count in self.segments:
                if isinstance(blocks, BlockCfg):
                    blocks = (blocks,)
                out.append((tuple(blocks), count))
            return tuple(out)
        return (((BlockCfg("attn", "swiglu"),), self.n_layers),)

    def finalize_for_mesh(self, tp: int) -> "ModelConfig":
        """Apply TP-divisibility padding (heads, kv heads, vocab)."""
        if not self.shard_attn_heads:
            tp_heads = 1
        else:
            tp_heads = tp
        return dataclasses.replace(
            self,
            pad_heads_to=tp_heads,
            pad_kv_heads_to=tp_heads,
            pad_vocab_to=_ceil_to_mult(tp),
        )


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _ceil_to_mult(tp: int) -> int:
    return max(tp, 1)


# ---------------------------------------------------------------------------
# Assigned input shapes (seq_len × global_batch per the task spec)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic / windowed state); see
# DESIGN.md §5 for the skip rationale on pure full-attention archs.
LONG_CONTEXT_OK = {"recurrentgemma-9b", "xlstm-350m", "starcoder2-7b"}

ARCH_IDS = (
    "qwen1_5_0_5b",
    "starcoder2_7b",
    "deepseek_coder_33b",
    "yi_34b",
    "qwen2_vl_72b",
    "musicgen_large",
    "recurrentgemma_9b",
    "xlstm_350m",
    "granite_moe_1b",
    "deepseek_v3_671b",
)


def load_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = arch.replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> dict[str, ModelConfig]:
    return {a: load_config(a, smoke=smoke) for a in ARCH_IDS}


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for the dry-run (no allocation)
# ---------------------------------------------------------------------------


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> dict:
    """Model inputs as ShapeDtypeStructs for the given (arch × shape) cell.

    train:   {tokens/embeddings, labels}
    prefill: {tokens/embeddings}
    decode:  {tokens/embeddings for ONE new token}  (cache comes separately)
    """
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "decode":
        s_in = 1
    else:
        s_in = s
    if cfg.input_mode == "tokens":
        inputs = {"tokens": sds((b, s_in), jnp.int32)}
    elif cfg.input_mode == "codebooks":
        inputs = {"tokens": sds((b, s_in, cfg.n_codebooks), jnp.int32)}
    else:  # embeddings (modality frontend stub: precomputed patch/frame embs)
        inputs = {"embeddings": sds((b, s_in, cfg.d_model), cfg.act_dtype)}
    if cfg.pos == "mrope":
        inputs["positions"] = sds((3, b, s_in), jnp.int32)
    if shape.kind == "train":
        inputs["labels"] = sds((b, s), jnp.int32)
    return inputs
