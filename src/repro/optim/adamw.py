"""AdamW with global-norm clipping, warmup-cosine schedule, and ZeRO-3-ready
state layout (m/v inherit the parameter sharding, so FSDP rules shard them).

``state_dtype`` lets the giant configs trade optimizer-state precision for
HBM (fp32 default; bf16 for the 671B cell — recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    state_dtype: Any = jnp.float32


def schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    """Linear warmup then cosine decay to ``min_lr_frac``·lr."""
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps)
                 / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig) -> dict:
    # m mirrors the gradient (complex for complex params — learned DFT
    # factors); v holds |g|² and stays real either way.
    def zeros_m(p):
        dt = jnp.complex64 if jnp.iscomplexobj(p) else cfg.state_dtype
        return jnp.zeros(p.shape, dt)

    return {
        "m": jax.tree.map(zeros_m, params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.state_dtype),
                          params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    # |x|² so complex leaves contribute their modulus (== x² for real).
    return jnp.sqrt(sum(jnp.sum(jnp.square(jnp.abs(x)).astype(jnp.float32))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: OptConfig):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"]
    lr = schedule(step, cfg)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    b1c = 1.0 - cfg.b1 ** (step.astype(jnp.float32) + 1)
    b2c = 1.0 - cfg.b2 ** (step.astype(jnp.float32) + 1)

    def upd(p, g, m, v):
        work = jnp.complex64 if jnp.iscomplexobj(g) else jnp.float32
        g = g.astype(work) * scale
        m_new = cfg.b1 * m.astype(work) + (1 - cfg.b1) * g
        # |g|² (real, == g·g for real grads): complex parameters — e.g.
        # learned DFT factors — need the modulus for the second moment.
        v_new = (cfg.b2 * v.astype(jnp.float32)
                 + (1 - cfg.b2) * jnp.real(g * jnp.conj(g)))
        mhat = m_new / b1c
        vhat = v_new / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(work)
        p_new = p.astype(work) - lr * delta
        m_dtype = work if jnp.iscomplexobj(m_new) else cfg.state_dtype
        return (p_new.astype(p.dtype), m_new.astype(m_dtype),
                v_new.astype(cfg.state_dtype))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step + 1,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
