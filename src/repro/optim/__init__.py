"""AdamW + schedule for the training substrate.

Not a paper subsystem — production scaffolding for the north-star training
path (``docs/architecture.md``, "Production substrate").
"""
from .adamw import OptConfig, adamw_update, global_norm, init_opt_state, schedule
