from .adamw import OptConfig, adamw_update, global_norm, init_opt_state, schedule
