"""Production mesh + logical-axis → mesh-axis rule sets.

Importing this module never touches jax device state (mesh construction is
inside functions only).
"""
from __future__ import annotations

from typing import Any

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def dp_axes(multi_pod: bool):
    return ("pod", "data") if multi_pod else ("data",)


TP = 16  # model-parallel degree of the production mesh (both variants)


def param_rules(cfg, multi_pod: bool, serve: bool = False,
                overrides: dict | None = None) -> dict:
    """Logical param axes -> mesh axes.

    Train: TP over 'model' + ZeRO-3/FSDP over the data axes (params, grads
    and optimizer state all sharded; GSPMD all-gathers per layer inside the
    scan).  Serve: TP only (no per-token FSDP gathers).
    """
    fsdp = None if serve else dp_axes(multi_pod)
    rules = {
        "embed": fsdp,
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        "expert_mlp": None,
        "lora": None,
        "layers": None,
        "lru_in": "model",  # RG-LRU recurrent gates: row-parallel default
        "lru_out": None,
    }
    if not cfg.shard_attn_heads:
        # Tiny-width archs (xlstm): replicate mixer internals, keep TP on
        # vocab + FSDP on the embed dim only (DESIGN.md §4).
        rules.update(heads=None, kv_heads=None, mlp=None)
    if overrides:
        rules.update(overrides)
    return rules


def act_rules(cfg, multi_pod: bool, batch_shardable: bool = True,
              overrides: dict | None = None) -> dict:
    dp = dp_axes(multi_pod)
    rules = {
        "batch": dp if batch_shardable else None,
        "heads_act": "model",
        "kv_heads_act": "model",
        "mlp_act": "model",
        "vocab_act": "model",
        "seq_act": None,  # 'model' under sequence parallelism (hillclimb)
        "expert": "model",
    }
    if not cfg.shard_attn_heads:
        rules.update(heads_act=None, kv_heads_act=None, mlp_act=None)
    if overrides:
        rules.update(overrides)
    return rules


def spec_of(axes: tuple, rules: dict) -> P:
    return P(*(rules.get(a) if a is not None else None for a in axes))


def specs_from_axes(axes_tree, rules: dict):
    return jax.tree.map(lambda ax: spec_of(ax, rules), axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def shardings_from_axes(mesh, axes_tree, rules: dict):
    return jax.tree.map(
        lambda ax: NamedSharding(mesh, spec_of(ax, rules)), axes_tree,
        is_leaf=lambda x: isinstance(x, tuple))


def batch_specs(cfg, shape_kind: str, rules: dict) -> dict:
    """PartitionSpecs for the input batch dict (batch dim over DP)."""
    b = rules.get("batch")
    specs = {}
    if cfg.input_mode == "tokens":
        specs["tokens"] = P(b, None) if shape_kind != "codebooks" else None
    if cfg.input_mode == "codebooks":
        specs["tokens"] = P(b, None, None)
    if cfg.input_mode == "embeddings":
        specs["embeddings"] = P(b, None, None)
    if cfg.pos == "mrope":
        specs["positions"] = P(None, b, None)
    if shape_kind == "train":
        specs["labels"] = P(b, None)
    return specs
