"""Offline re-analysis: regenerate roofline terms in dry-run JSONs from the
saved (gzipped) HLO — lets parser/model refinements apply without
recompiling 66 cells.

    PYTHONPATH=src python -m repro.launch.reanalyze artifacts/dryrun
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from repro.launch.roofline import analyze_hlo, roofline_terms


def reanalyze(art_dir: str) -> int:
    n = 0
    for jpath in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        hpath = jpath.replace(".json", ".hlo.txt.gz")
        if not os.path.exists(hpath):
            continue
        with open(jpath) as f:
            a = json.load(f)
        with gzip.open(hpath, "rt") as f:
            hlo = f.read()
        counts = analyze_hlo(hlo, a["n_devices"])
        a["roofline"] = roofline_terms(
            counts, a["n_devices"], a["model_flops"]["model_flops"])
        with open(jpath, "w") as f:
            json.dump(a, f, indent=1)
        n += 1
        print(f"re-analyzed {os.path.basename(jpath)}: "
              f"bound={a['roofline']['bound']}")
    return n


if __name__ == "__main__":
    d = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    print(f"{reanalyze(d)} artifacts re-analyzed")
