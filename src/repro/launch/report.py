"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from artifacts.

    PYTHONPATH=src python -m repro.launch.report artifacts/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def load(art_dir: str) -> list[dict]:
    arts = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            arts.append(json.load(f))
    order = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2, "long_500k": 3}
    arts.sort(key=lambda a: (a["arch"], order.get(a["shape"], 9), a["mesh"]))
    return arts


def dryrun_table(arts: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compile s | GiB/device | HLO flops/dev "
           "| ICI GB/dev | dominant collective |\n"
           "|---|---|---|---|---|---|---|---|\n")
    rows = []
    for a in arts:
        r = a["roofline"]
        kinds = r.get("coll_by_kind", {})
        top = max(kinds, key=kinds.get) if kinds else "-"
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} | {a['compile_s']} "
            f"| {a['memory']['per_device_total'] / 2**30:.1f} "
            f"| {r['flops_per_device']:.2e} "
            f"| {r['ici_bytes_per_device'] / 1e9:.2f} | {top} |")
    return hdr + "\n".join(rows) + "\n"


def roofline_table(arts: list[dict], mesh: str = "16x16") -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | bound "
           "| MODEL_FLOPS | useful | roofline frac | what would move the "
           "dominant term |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for a in arts:
        if a["mesh"] != mesh:
            continue
        r = a["roofline"]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| **{r['bound']}** | {a['model_flops']['model_flops']:.2e} "
            f"| {r.get('useful_flops_ratio', 0):.2f} "
            f"| {r.get('roofline_fraction', 0):.4f} | {note(a)} |")
    return hdr + "\n".join(rows) + "\n"


def note(a) -> str:
    r = a["roofline"]
    b = r["bound"]
    kinds = r.get("coll_by_kind", {})
    if b == "collective":
        top = max(kinds, key=kinds.get) if kinds else "?"
        return (f"{top} dominates — sequence-parallel norms / reduce-scatter "
                "grads / reshard embedding")
    if b == "memory":
        if a["shape"].startswith("decode") or a["shape"].startswith("long"):
            return "KV-cache reads dominate (bandwidth-bound by design); " \
                   "quantize cache / widen batch"
        return "fused loss + bf16 residuals + remat policy to cut traffic"
    return "compute-bound — keep MXU fed (good place to be)"


def main():
    art_dir = sys.argv[1] if len(sys.argv) > 1 else "artifacts/dryrun"
    arts = load(art_dir)
    print("## §Dry-run (both meshes)\n")
    print(dryrun_table(arts))
    print("\n## §Roofline (single-pod 16x16 baseline)\n")
    print(roofline_table(arts, "16x16"))
    print("\n## §Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(arts, "2x16x16"))


if __name__ == "__main__":
    main()
