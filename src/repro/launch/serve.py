"""Batched serving driver: prefill + decode loop with slot management."""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.configs import load_config
from repro.models import init_model
from repro.serve import ServeSession, SlotManager

import jax


def serve(arch: str, batch: int = 4, prompt_len: int = 16,
          max_new: int = 32, smoke: bool = True, temperature: float = 0.0):
    cfg = load_config(arch, smoke=smoke)
    params = init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(batch, prompt_len)).astype(np.int32)
    if cfg.input_mode == "codebooks":
        prompts = rng.integers(0, cfg.vocab_size,
                               size=(batch, prompt_len, cfg.n_codebooks)
                               ).astype(np.int32)
    session = ServeSession(cfg=cfg, params=params, temperature=temperature)
    slots = SlotManager(n_slots=batch, max_len=prompt_len + max_new)
    for rid in range(batch):
        slots.admit(rid)
    t0 = time.time()
    if cfg.input_mode == "tokens":
        out = session.generate(prompts, max_new)
    else:
        raise SystemExit(f"serving loop demo targets token archs; "
                         f"{arch} uses {cfg.input_mode} inputs")
    dt = time.time() - t0
    tok_s = batch * max_new / dt
    print(f"[serve] {arch}: {batch}×{max_new} tokens in {dt:.2f}s "
          f"({tok_s:.1f} tok/s), slot utilization={slots.utilization:.2f}")
    print(f"[serve] sample output ids: {out[0][:16].tolist()}")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    serve(args.arch, batch=args.batch, prompt_len=args.prompt_len,
          max_new=args.max_new, temperature=args.temperature)


if __name__ == "__main__":
    main()
