import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Perf-iteration driver (§Perf): lower one cell with lever overrides,
# compare its roofline terms against the paper-faithful baseline artifact,
# and log the hypothesis→change→before→after record.
#
#   PYTHONPATH=src python -m repro.launch.hillclimb \
#       --arch yi_34b --shape train_4k --mesh single --tag fused_loss \
#       --fused-loss --hypothesis "CE loss materializes ~7 (B,S,V) f32 ..."
#
# Levers: --fused-loss, --act k=v (activation rules), --param k=v (param
# rules), --cfg k=v (ModelConfig fields, e.g. remat=dots q_chunk=256),
# --microbatch N.

import argparse
import gzip
import json

import jax.numpy as jnp


def _parse_kv(pairs):
    out = {}
    for kv in pairs or []:
        k, v = kv.split("=", 1)
        if v in ("None", "none", "null"):
            out[k] = None
        elif v in ("True", "False"):
            out[k] = v == "True"
        elif v.startswith("(") or "," in v:
            out[k] = tuple(x.strip() for x in v.strip("()").split(",") if x.strip())
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
        if k.endswith("dtype") and isinstance(out[k], str):
            out[k] = getattr(jnp, out[k])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--tag", required=True)
    ap.add_argument("--hypothesis", default="")
    ap.add_argument("--fused-loss", action="store_true")
    ap.add_argument("--loss-chunk", type=int, default=8192)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--act", nargs="*", default=None)
    ap.add_argument("--param", nargs="*", default=None)
    ap.add_argument("--cfg", nargs="*", default=None)
    ap.add_argument("--baseline-dir", default="artifacts/dryrun")
    ap.add_argument("--out", default="artifacts/hillclimb")
    args = ap.parse_args()

    from repro.launch.dryrun import lower_cell

    multi = args.mesh == "multi"
    cell = f"{args.arch}__{args.shape}__{args.mesh}"
    artifact, hlo = lower_cell(
        args.arch, args.shape, multi,
        act_overrides=_parse_kv(args.act),
        param_overrides=_parse_kv(args.param),
        cfg_overrides=_parse_kv(args.cfg),
        microbatch=args.microbatch,
        fused_loss=args.fused_loss,
        loss_chunk=args.loss_chunk,
    )
    os.makedirs(args.out, exist_ok=True)
    artifact["tag"] = args.tag
    artifact["hypothesis"] = args.hypothesis
    artifact["levers"] = {
        "fused_loss": args.fused_loss, "microbatch": args.microbatch,
        "act": args.act, "param": args.param, "cfg": args.cfg,
    }
    out_json = os.path.join(args.out, f"{cell}__{args.tag}.json")
    with open(out_json, "w") as f:
        json.dump(artifact, f, indent=1)
    with gzip.open(out_json.replace(".json", ".hlo.txt.gz"), "wt") as f:
        f.write(hlo)

    base_path = os.path.join(args.baseline_dir, cell + ".json")
    print(f"\n=== {cell} [{args.tag}] ===")
    if args.hypothesis:
        print(f"hypothesis: {args.hypothesis}")
    r = artifact["roofline"]
    if os.path.exists(base_path):
        with open(base_path) as f:
            b = json.load(f)["roofline"]
        for k in ("compute_s", "memory_s", "collective_s"):
            delta = (r[k] - b[k]) / max(b[k], 1e-12)
            print(f"{k:14s} {b[k]:.3e} -> {r[k]:.3e}  ({delta:+.1%})")
        print(f"bound          {b['bound']} -> {r['bound']}")
        print(f"step lower bnd {b['step_time_lower_bound_s']:.3e} -> "
              f"{r['step_time_lower_bound_s']:.3e}  "
              f"({(r['step_time_lower_bound_s'] / b['step_time_lower_bound_s'] - 1):+.1%})")
        print(f"roofline frac  {b.get('roofline_fraction', 0):.4f} -> "
              f"{r.get('roofline_fraction', 0):.4f}")
    else:
        print("(no baseline artifact found)")
        for k in ("compute_s", "memory_s", "collective_s", "bound"):
            print(f"{k:14s} {r[k]}")


if __name__ == "__main__":
    main()
