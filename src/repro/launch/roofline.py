"""Roofline-term extraction from compiled HLO.

Why a hand-rolled parser: XLA's ``compiled.cost_analysis()`` counts
``while`` (scan) bodies **once** (verified empirically — see EXPERIMENTS.md
§Methodology), which under-counts layer-scanned models by ~n_layers×, and
it reports no collective traffic at all.  This module parses
``compiled.as_text()`` into computations, counts per-computation

  * dot FLOPs (from dot_dimension_numbers),
  * HBM traffic (operand+output bytes of memory-moving top-level ops),
  * per-device ICI collective traffic (ring-model per collective kind),

then walks the call graph (fusion/call/while/conditional) multiplying
while-bodies by trip counts recovered from their loop-condition constants.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (task-prescribed constants).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link / chip

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# HBM model (TPU-oriented): every materialized buffer is written once
# (output bytes of all real ops), but operand *reads* are charged only at
# compute-heavy consumers — elementwise chains that the CPU backend leaves
# unfused would be fused on the TPU target, so their reads collapse into
# their producers' writes.  See EXPERIMENTS.md §Methodology.
_NO_OUTPUT_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "while", "conditional", "call",
}
_READ_CHARGED_OPS = {
    "dot", "convolution", "fusion", "custom-call", "gather", "scatter",
    "reduce", "sort", "select-and-scatter", "reduce-window", "copy",
    "concatenate", "cholesky", "triangular-solve",
}

# Buffers below this size are assumed VMEM-resident on the TPU target
# (loop-carried recurrent states, softmax stats, norms): no HBM charge.
# The CPU backend materializes them per step, which would otherwise make
# sequential-scan models (sLSTM) look absurdly memory-bound.
VMEM_RESIDENT_BYTES = 2**20


# ---------------------------------------------------------------------------
# Shape parsing
# ---------------------------------------------------------------------------


def _shape_bytes(type_str: str) -> float:
    """Bytes of one HLO type string (handles tuples)."""
    total = 0.0
    for m in re.finditer(r"([a-z0-9]+)\[([\d,]*)\]", type_str):
        dt, dims = m.group(1), m.group(2)
        nbytes = _DTYPE_BYTES.get(dt)
        if nbytes is None:
            continue  # token[] / opaque
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * nbytes
    return total


def _shape_dims(type_str: str) -> list[int]:
    m = re.search(r"[a-z0-9]+\[([\d,]*)\]", type_str)
    if not m or not m.group(1):
        return []
    return [int(d) for d in m.group(1).split(",")]


# ---------------------------------------------------------------------------
# HLO text -> computations
# ---------------------------------------------------------------------------


# Computation header: `%name (args...) -> type {` (instr lines have ` = `).
_COMP_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s+\(.*->.*\{\s*$")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"\s*([\w\-]+)\(")


def _parse_instr(line: str):
    """Parse `%name = TYPE opcode(...), attrs`.  TYPE may be a tuple type
    containing nested parens and `/*index=N*/` comments."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):  # tuple type: scan to matching close paren
        depth = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, rest = rest[: i + 1], rest[i + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, rest = rest[:sp], rest[sp:]
    m = _OPCODE_RE.match(rest)
    if not m:
        return None
    return Instr(name, type_str, m.group(1), rest[m.end():],
                 is_root=line.lstrip().startswith("ROOT "))


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes (raw tail of the line)
    is_root: bool = False


def parse_computations(hlo: str) -> tuple[dict, str]:
    """Returns ({comp_name: [Instr, ...]}, entry_name)."""
    comps: dict[str, list[Instr]] = {}
    entry = None
    cur: list[Instr] | None = None
    cur_name = None
    for line in hlo.splitlines():
        if cur is None:
            if " = " in line:
                continue
            m = _COMP_RE.match(line.strip())
            if m:
                cur_name = m.group(2)
                cur = []
                if m.group(1):
                    entry = cur_name
            continue
        s = line.strip()
        if s == "}" or s.startswith("} "):
            comps[cur_name] = cur
            cur = None
            continue
        ins = _parse_instr(line)
        if ins is not None:
            cur.append(ins)
    if entry is None and comps:
        entry = next(iter(comps))
    return comps, entry


def _operands(instr: Instr) -> list[str]:
    """Operand instruction names — the argument list of ``opcode( ... )``.

    ``instr.rest`` is everything after the opening paren; scan to its
    matching close (attributes after it may also contain %names — excluded).
    """
    depth = 1
    buf = []
    for ch in instr.rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    return re.findall(r"%[\w.\-]+", "".join(buf))


def _attr(instr: Instr, key: str) -> str | None:
    m = re.search(key + r"=([^,]+(?:\{[^}]*\})?)", instr.rest)
    return m.group(1) if m else None


# ---------------------------------------------------------------------------
# Per-computation direct counts + call graph walk
# ---------------------------------------------------------------------------


def _group_size(instr: Instr, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", instr.rest)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _dot_flops(instr: Instr, symtab: dict) -> float:
    out_dims = _shape_dims(instr.type_str)
    ops = _operands(instr)
    if not ops:
        return 0.0
    lhs_type = symtab.get(ops[0], "")
    lhs_dims = _shape_dims(lhs_type)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.rest)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            if int(d) < len(lhs_dims):
                contract *= lhs_dims[int(d)]
    out_n = 1
    for d in out_dims:
        out_n *= d
    return 2.0 * out_n * contract


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0  # per-device collective traffic (ring model)
    coll_by_kind: dict = dataclasses.field(default_factory=dict)
    while_trips: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Counts", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.ici_bytes += other.ici_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        self.while_trips.update(other.while_trips)


def _collective_bytes(instr: Instr, symtab: dict, opcode: str,
                      total_devices: int) -> float:
    n = max(_group_size(instr, total_devices), 1)
    ring = (n - 1) / n
    in_bytes = sum(_shape_bytes(symtab.get(o, "")) for o in _operands(instr))
    out_bytes = _shape_bytes(instr.type_str)
    if opcode.startswith("all-gather"):
        return out_bytes * ring
    if opcode.startswith("reduce-scatter"):
        return in_bytes * ring
    if opcode.startswith("all-reduce"):
        return 2.0 * in_bytes * ring
    if opcode.startswith("all-to-all"):
        return in_bytes * ring
    if opcode.startswith("collective-permute"):
        return in_bytes
    return 0.0


def _resolve_root(instrs: list[Instr]) -> Instr | None:
    """Fused-computation root, looking through bitcast/copy/convert."""
    by_name = {i.name: i for i in instrs}
    root = next((i for i in instrs if i.is_root), None)
    seen = 0
    while root is not None and root.opcode in ("bitcast", "copy", "convert") \
            and seen < 8:
        ops = _operands(root)
        root = by_name.get(ops[0]) if ops else None
        seen += 1
    return root


def _fusion_param_reads(instrs: list[Instr], operand_types: list[str]) -> float:
    """HBM bytes a fusion actually reads from its operands.

    A parameter consumed only via (dynamic-)slice reads just the slices —
    the scan-saved-activations pattern (per-trip slice of a stacked (L, …)
    buffer) must not be charged the full buffer each trip.
    """
    by_name = {i.name: i for i in instrs}
    consumers: dict[str, list[Instr]] = {}
    for ins in instrs:
        for o in _operands(ins):
            consumers.setdefault(o, []).append(ins)

    def effective_read(name: str, full_bytes: float, depth: int = 0) -> float:
        if depth > 6:
            return full_bytes
        total = 0.0
        for cons in consumers.get(name, []):
            if cons.opcode in ("bitcast", "reshape", "copy", "transpose"):
                total += effective_read(cons.name, full_bytes, depth + 1)
            elif cons.opcode in ("dynamic-slice", "slice"):
                total += _shape_bytes(cons.type_str)
            elif cons.opcode == "dynamic-update-slice":
                # reads only the update operand; base buffer is aliased
                ops = _operands(cons)
                if ops and ops[0] == name:
                    continue
                total += full_bytes
            elif cons.opcode == "get-tuple-element":
                total += effective_read(cons.name, full_bytes, depth + 1)
            else:
                return full_bytes  # generic consumer: full read
        return min(total, full_bytes)

    params = sorted((i for i in instrs if i.opcode == "parameter"),
                    key=lambda i: int(re.match(r"(\d+)", i.rest).group(1)))
    total = 0.0
    for i, p in enumerate(params):
        full = _shape_bytes(operand_types[i]) if i < len(operand_types) \
            else _shape_bytes(p.type_str)
        if full < VMEM_RESIDENT_BYTES:
            continue
        total += effective_read(p.name, full)
    return total


def _trip_count(instr: Instr, cond_instrs: list[Instr]) -> int:
    """Loop trip count: XLA's backend_config known_trip_count when present,
    else the max integer constant in the loop condition (≈ scan length)."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.rest)
    if m:
        return int(m.group(1))
    best = 1
    for ins in cond_instrs:
        if ins.opcode == "constant":
            m = re.search(r"^(\d+)\)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


def analyze_hlo(hlo: str, total_devices: int) -> Counts:
    comps, entry = parse_computations(hlo)
    symtabs = {cn: {i.name: i.type_str for i in instrs}
               for cn, instrs in comps.items()}
    cache: dict[str, Counts] = {}

    def walk(comp_name: str, stack=(), as_fusion: bool = False) -> Counts:
        key = (comp_name, as_fusion)
        if key in cache:
            return cache[key]
        if comp_name in stack or comp_name not in comps:
            return Counts()
        c = Counts()
        symtab = symtabs[comp_name]
        is_fusion = as_fusion
        for ins in comps[comp_name]:
            op = ins.opcode
            if op == "while":
                body = _attr(ins, "body")
                cond = _attr(ins, "condition")
                body_name = body.lstrip("%") if body else None
                cond_name = cond.lstrip("%") if cond else None
                trips = _trip_count(ins, comps.get(cond_name, []))
                c.while_trips[body_name] = trips
                if body_name:
                    c.add(walk(body_name, stack + (comp_name,)), trips)
                continue
            if op == "conditional":
                m = re.findall(r"%[\w.\-]+", _attr(ins, "branch_computations")
                               or "")
                for br in m:  # upper bound: sum all branches
                    c.add(walk(br.lstrip("%"), stack + (comp_name,)))
                continue
            if op == "dot":
                c.flops += _dot_flops(ins, symtab)
            elif any(op.startswith(k) for k in _COLLECTIVES):
                if op.endswith("-done"):
                    continue
                base = next(k for k in _COLLECTIVES if op.startswith(k))
                b = _collective_bytes(ins, symtab, op, total_devices)
                c.ici_bytes += b
                c.coll_by_kind[base] = c.coll_by_kind.get(base, 0.0) + b
            elif op in ("fusion", "call", "async-start"):
                callee = _attr(ins, "calls") or _attr(ins, "to_apply")
                callee_name = callee.lstrip("%") if callee else None
                if callee_name and callee_name in comps:
                    inner = walk(callee_name, stack + (comp_name,),
                                 as_fusion=True)
                    # Only flops/collectives propagate out of fusions: the
                    # fusion's HBM traffic is charged here at the call site.
                    c.flops += inner.flops
                    c.ici_bytes += inner.ici_bytes
                    for k, v in inner.coll_by_kind.items():
                        c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
                    if is_fusion:
                        continue  # nested fusion: outermost caller charges
                    callee_instrs = comps[callee_name]
                    callee_tab = symtabs[callee_name]
                    op_types = [symtab.get(o, "") for o in _operands(ins)]
                    # reads: slice-aware per-parameter accounting
                    c.hbm_bytes += _fusion_param_reads(callee_instrs, op_types)
                    # write: in-place DUS root writes only the slice
                    root = _resolve_root(callee_instrs)
                    if root is not None and root.opcode == "dynamic-update-slice":
                        ops2 = _operands(root)
                        if len(ops2) >= 2:
                            b2 = _shape_bytes(callee_tab.get(ops2[1], ""))
                            if b2 >= VMEM_RESIDENT_BYTES:
                                c.hbm_bytes += b2
                    else:
                        ob = _shape_bytes(ins.type_str)
                        if ob >= VMEM_RESIDENT_BYTES:
                            c.hbm_bytes += ob
                    continue
            # ---- HBM model (skip inside fusion computations: the caller
            # charges the fused region's in/out) -------------------------
            if is_fusion:
                continue
            if op in _NO_OUTPUT_OPS:
                continue
            if op == "dynamic-update-slice":
                # In-place slice update: traffic = read+write of the slice,
                # not of the full (aliased) buffer the output type names.
                ops_ = _operands(ins)
                if len(ops_) >= 2:
                    b_ = _shape_bytes(symtab.get(ops_[1], ""))
                    if b_ >= VMEM_RESIDENT_BYTES:
                        c.hbm_bytes += 2 * b_
                continue
            out_b = _shape_bytes(ins.type_str)
            if out_b >= VMEM_RESIDENT_BYTES:
                c.hbm_bytes += out_b  # one write per materialized buffer
            if op in _READ_CHARGED_OPS or any(
                    op.startswith(k) for k in _COLLECTIVES):
                c.hbm_bytes += sum(
                    b_ for o in _operands(ins)
                    if (b_ := _shape_bytes(symtab.get(o, "")))
                    >= VMEM_RESIDENT_BYTES)
        cache[key] = c
        return c

    # Fusion computations are only counted via their callers; walk from entry.
    return walk(entry)


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------


def roofline_terms(counts: Counts, n_devices: int,
                   model_flops_global: float | None = None) -> dict:
    """All terms are per-chip per-step seconds.

    ``counts`` comes from the SPMD-partitioned module, i.e. already
    per-device quantities.
    """
    compute_s = counts.flops / PEAK_FLOPS
    memory_s = counts.hbm_bytes / HBM_BW
    collective_s = counts.ici_bytes / ICI_BW
    bound = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])
    out = {
        "flops_per_device": counts.flops,
        "hbm_bytes_per_device": counts.hbm_bytes,
        "ici_bytes_per_device": counts.ici_bytes,
        "coll_by_kind": counts.coll_by_kind,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bound": bound[0],
        "step_time_lower_bound_s": max(compute_s, memory_s, collective_s),
    }
    if model_flops_global:
        hlo_global = counts.flops * n_devices
        out["model_flops_global"] = model_flops_global
        out["useful_flops_ratio"] = (model_flops_global / hlo_global
                                     if hlo_global else 0.0)
        # roofline fraction: useful work vs what the chips could do in the
        # bottleneck-bound step time
        t = out["step_time_lower_bound_s"]
        out["roofline_fraction"] = (
            model_flops_global / (n_devices * PEAK_FLOPS * t) if t else 0.0)
    return out
