"""Launch layer: production mesh, dry-run, roofline, train/serve drivers.

The roofline parser is the ground truth for the distributed schedule's
collective bytes (``docs/distributed.md``, "Verifying the schedule");
the mesh builders encode the paper's Eq. 7 processing-space shapes.  See
``docs/architecture.md``.
"""
