"""Analytic MODEL_FLOPS (the §Roofline 'useful work' numerator).

Convention (standard MFU accounting): MODEL_FLOPS = 6·N_eff·tokens for
training (fwd+bwd), 2·N_eff·tokens for prefill/decode forward, where N_eff
is the matmul-visible parameter count — embedding *lookup* excluded, tied
LM head *matmul* included, MoE experts scaled to the active fraction
(top_k + shared)/E.  Attention's quadratic term is excluded (convention),
which makes the reported useful-flops ratio conservative for long-seq cells.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _param_sizes(cfg) -> tuple[float, float]:
    """(n_total_matmul, n_active_matmul) parameter counts."""
    from ..models import init_model

    sds = jax.eval_shape(lambda k: init_model(k, cfg), jax.random.PRNGKey(0))
    total = 0.0
    expert_total = 0.0
    embed = 0.0
    flat = jax.tree_util.tree_flatten_with_path(sds)[0]
    for path, leaf in flat:
        keys = [getattr(p, "key", str(p)) for p in path]
        n = float(leaf.size)
        if "embed" in keys and "lm_head" not in keys:
            embed += n
            continue
        total += n
        if any("moe" == k for k in keys) and any(
                k in ("w_gate", "w_up", "w_down") for k in keys):
            expert_total += n
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        total += cfg.eff_vocab * cfg.d_model  # tied head matmul
    active_frac = (cfg.top_k / cfg.n_experts) if cfg.n_experts else 1.0
    active = total - expert_total * (1.0 - active_frac)
    return total, active


def model_flops(cfg, shape) -> dict:
    """Global per-step MODEL_FLOPS for this (arch × shape) cell."""
    n_total, n_active = _param_sizes(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        flops = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        flops = 2.0 * n_active * tokens
    return {"n_params_total": n_total, "n_params_active": n_active,
            "tokens": tokens, "model_flops": flops}
