import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ the two lines above MUST run before any jax import: jax locks the device
# count at first init.  512 placeholder CPU devices back both production
# meshes (multi-pod 2×16×16 = 512; single-pod 16×16 = 256 uses the first
# 256 devices).  The dry-run proves every (arch × shape × mesh) cell
# lowers, SPMD-partitions, and compiles; memory/cost/collective artifacts
# feed EXPERIMENTS.md §Dry-run and §Roofline.

import argparse
import dataclasses
import functools
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, LONG_CONTEXT_OK, SHAPES, input_specs, load_config
from repro.launch.mesh import (TP, act_rules, batch_specs, dp_axes,
                               param_rules, shardings_from_axes, specs_from_axes)
from repro.launch.flops import model_flops
from repro.launch.roofline import analyze_hlo, roofline_terms
from repro.models import ShardCtx, cache_axes_tree, init_cache, init_model, model_axes
from repro.optim import OptConfig
from repro.serve import build_decode_step, build_prefill_step
from repro.train import build_train_step, init_train_state, train_state_axes


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    if len(jax.devices()) == n:
        return jax.make_mesh(shape, axes)
    # 512 placeholder devices back both meshes: single-pod = first 256.
    devs = np.asarray(jax.devices()[:n]).reshape(shape)
    return Mesh(devs, axes)


def _opt_cfg(cfg) -> OptConfig:
    # The 671B cell trades optimizer-state precision for HBM (DESIGN.md §4).
    state_dtype = jnp.bfloat16 if cfg.n_experts >= 256 else jnp.float32
    return OptConfig(state_dtype=state_dtype)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               act_overrides: dict | None = None,
               param_overrides: dict | None = None,
               cfg_overrides: dict | None = None,
               microbatch: int = 1,
               fused_loss: bool = False,
               loss_chunk: int = 8192):
    """Lower + compile one (arch × shape × mesh) cell.  Returns artifacts."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_devices = mesh.size
    shape = SHAPES[shape_name]
    cfg = load_config(arch).finalize_for_mesh(TP)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    dp = int(np.prod([mesh.shape[a] for a in dp_axes(multi_pod)]))
    batch_shardable = shape.global_batch % dp == 0
    serve = shape.kind != "train"
    prules = param_rules(cfg, multi_pod, serve=serve, overrides=param_overrides)
    arules = act_rules(cfg, multi_pod, batch_shardable, overrides=act_overrides)
    ctx = ShardCtx(mesh=mesh, rules=arules)
    key = jax.random.PRNGKey(0)

    ins = input_specs(cfg, shape)
    bspecs = batch_specs(cfg, shape.kind, arules)
    batch_sh = {k: NamedSharding(mesh, bspecs.get(k) or P())
                for k in ins}

    t0 = time.time()
    if shape.kind == "train":
        ocfg = _opt_cfg(cfg)
        state_sds = jax.eval_shape(
            lambda k: init_train_state(k, cfg, ocfg), key)
        state_sh = shardings_from_axes(mesh, train_state_axes(cfg), prules)
        step = build_train_step(cfg, ctx, ocfg, microbatch=microbatch,
                                fused_loss=fused_loss, loss_chunk=loss_chunk)
        jf = jax.jit(step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=(0,))
        lowered = jf.lower(state_sds, ins)
    else:
        params_sds = jax.eval_shape(lambda k: init_model(k, cfg), key)
        params_sh = shardings_from_axes(mesh, model_axes(cfg), prules)
        if shape.kind == "prefill":
            step = build_prefill_step(cfg, ctx)
            jf = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jf.lower(params_sds, ins)
        else:  # decode: one token against a seq_len cache
            cache_sds = jax.eval_shape(
                lambda: init_cache(cfg, shape.global_batch, shape.seq_len))
            cache_sh = shardings_from_axes(mesh, cache_axes_tree(cfg), arules)
            step = build_decode_step(cfg, ctx)
            jf = jax.jit(step,
                         in_shardings=(params_sh, batch_sh, cache_sh, None),
                         out_shardings=(None, cache_sh),
                         donate_argnums=(2,))
            lowered = jf.lower(params_sds, ins, cache_sds,
                               jnp.int32(shape.seq_len - 1))
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    counts = analyze_hlo(hlo, n_devices)
    mf = model_flops(cfg, shape)
    terms = roofline_terms(counts, n_devices, mf["model_flops"])
    artifact = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_devices": n_devices,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_total": (mem.argument_size_in_bytes
                                 + mem.output_size_in_bytes
                                 + mem.temp_size_in_bytes
                                 - mem.alias_size_in_bytes),
        },
        "cost_analysis": {k: cost.get(k) for k in
                          ("flops", "bytes accessed") if k in cost},
        "model_flops": mf,
        "roofline": terms,
    }
    return artifact, hlo


def run_cells(cells, out_dir: str, save_hlo: bool = True, **kw):
    os.makedirs(out_dir, exist_ok=True)
    ok, failed = [], []
    for arch, shape_name, multi_pod in cells:
        tag = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
        path = os.path.join(out_dir, tag + ".json")
        if os.path.exists(path):
            print(f"[skip-cached] {tag}", flush=True)
            ok.append(tag)
            continue
        print(f"[lower+compile] {tag}", flush=True)
        try:
            artifact, hlo = lower_cell(arch, shape_name, multi_pod, **kw)
            with open(path, "w") as f:
                json.dump(artifact, f, indent=1)
            if save_hlo:
                import gzip
                with gzip.open(os.path.join(out_dir, tag + ".hlo.txt.gz"),
                               "wt") as f:
                    f.write(hlo)
            r = artifact["roofline"]
            print(f"  OK compile={artifact['compile_s']}s "
                  f"bound={r['bound']} "
                  f"compute={r['compute_s']:.2e}s mem={r['memory_s']:.2e}s "
                  f"coll={r['collective_s']:.2e}s "
                  f"bytes/dev={artifact['memory']['per_device_total']/2**30:.2f}GiB",
                  flush=True)
            ok.append(tag)
        except Exception as e:
            failed.append((tag, repr(e)))
            with open(os.path.join(out_dir, tag + ".FAILED.txt"), "w") as f:
                f.write(traceback.format_exc())
            print(f"  FAILED: {e!r}", flush=True)
    return ok, failed


def default_cells(mesh_filter: str | None = None):
    cells = []
    for arch in ARCH_IDS:
        cfg = load_config(arch)
        for shape_name in SHAPES:
            if (shape_name == "long_500k"
                    and cfg.name not in LONG_CONTEXT_OK):
                continue  # pure full-attention arch: skip documented in DESIGN.md
            for multi_pod in (False, True):
                if mesh_filter == "single" and multi_pod:
                    continue
                if mesh_filter == "multi" and not multi_pod:
                    continue
                cells.append((arch, shape_name, multi_pod))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id or 'all'")
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-save-hlo", dest="save_hlo", action="store_false")
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    if args.arch and args.arch != "all":
        meshes = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
        shapes = [args.shape] if args.shape else list(SHAPES)
        cfg = load_config(args.arch)
        cells = [(args.arch, s, m) for s in shapes for m in meshes
                 if not (s == "long_500k" and cfg.name not in LONG_CONTEXT_OK)]
    else:
        cells = default_cells(None if args.mesh == "both" else args.mesh)

    ok, failed = run_cells(cells, args.out, save_hlo=args.save_hlo,
                           microbatch=args.microbatch)
    print(f"\n== dry-run summary: {len(ok)} ok, {len(failed)} failed ==")
    for tag, err in failed:
        print(f"  FAIL {tag}: {err}")
    sys.exit(1 if failed else 0)


if __name__ == "__main__":
    main()
