"""End-to-end training driver (real execution, any device count).

Composes the full substrate: config → mesh/rules → sharded init → synthetic
data pipeline → jitted train_step → resilient loop (checkpoint/restart,
straggler accounting).  On the CPU container this drives the ~100M-class
example (examples/train_lm.py); on a pod the same driver scales via the
production mesh.
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SHAPES, load_config
from repro.data import make_source, shard_batch
from repro.launch.mesh import act_rules, dp_axes, param_rules, shardings_from_axes
from repro.models import ShardCtx
from repro.optim import OptConfig
from repro.runtime import ResilienceConfig, run_resilient
from repro.train import build_train_step, init_train_state, train_state_axes


def train(arch: str, steps: int = 100, seq_len: int = 256,
          global_batch: int = 8, ckpt_dir: str = "artifacts/ckpt",
          smoke: bool = True, mesh=None, multi_pod: bool = False,
          microbatch: int = 1, ckpt_every: int = 50,
          fail_at: set[int] | None = None, lr: float = 3e-4,
          log_every: int = 10):
    cfg = load_config(arch, smoke=smoke)
    if mesh is not None:
        cfg = cfg.finalize_for_mesh(mesh.shape.get("model", 1))
        prules = param_rules(cfg, multi_pod)
        arules = act_rules(cfg, multi_pod)
        ctx = ShardCtx(mesh=mesh, rules=arules)
    else:
        prules = arules = None
        ctx = ShardCtx()

    ocfg = OptConfig(lr=lr, total_steps=steps, warmup_steps=max(steps // 20, 5),
                     weight_decay=0.01)

    import dataclasses

    shape = dataclasses.replace(SHAPES["train_4k"], seq_len=seq_len,
                                global_batch=global_batch)
    source = make_source(cfg, shape)

    step_fn = build_train_step(cfg, ctx, ocfg, microbatch=microbatch)
    if mesh is not None:
        state_sh = shardings_from_axes(mesh, train_state_axes(cfg), prules)
        step_fn = jax.jit(step_fn, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None), donate_argnums=(0,))
    else:
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    def init_state():
        return init_train_state(jax.random.PRNGKey(0), cfg, ocfg)

    def batch_fn(step):
        b = source.batch(step)
        return shard_batch(b, mesh, dp_axes(multi_pod) if mesh else None)

    t0 = time.time()
    losses = []

    def logged_step(state, batch):
        state, metrics = step_fn(state, batch)
        return state, metrics

    rcfg = ResilienceConfig(ckpt_dir=ckpt_dir, ckpt_every=ckpt_every)
    state, report = run_resilient(init_state, logged_step, batch_fn, steps,
                                  rcfg, fail_at=fail_at)
    dt = time.time() - t0
    print(f"[train] {arch}: {report.steps_done} steps in {dt:.1f}s, "
          f"restarts={report.restarts}, stragglers={report.stragglers}")
    ls = report.losses
    if ls:
        print(f"[train] loss: first={ls[0]:.4f} min={min(ls):.4f} "
              f"last={ls[-1]:.4f}")
    return state, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default="artifacts/ckpt")
    args = ap.parse_args()
    train(args.arch, steps=args.steps, seq_len=args.seq_len,
          global_batch=args.batch, smoke=not args.full_config,
          ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
