"""CLI entry point: ``python -m repro.obs TRACE.json [--json]``."""
from .export import main

raise SystemExit(main())
