"""Metrics registry: counters, gauges and latency histograms.

One :class:`MetricsRegistry` holds the engine's quantitative telemetry —
the numbers the per-call ``info`` dicts used to be the only window into:

* counters — monotonically increasing event/byte/MAC totals
  (``engine.hbm_bytes_moved``, ``grad.backward_calls``,
  ``autotune.cache.hits``, ``memo.esop.misses``,
  ``plan.fusion_degradations``, …);
* gauges — last-written values;
* histograms — bounded-window value recorders with percentile summaries
  (serve per-request latency).

A process-global default registry collects everything; ``obs.session()``
swaps in a fresh registry (and tracer) for per-session isolation, and
``reset(prefix)`` zeroes a namespace explicitly.  The legacy process-global
counters (``repro.engine.grad_stats()``, the ESOP memo stats) are thin
shims over this registry — see ``docs/observability.md``.

Recording is always on (a counter bump is a dict lookup + integer add);
only spans have an enabled/disabled switch.
"""
from __future__ import annotations

from collections import deque

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "set_registry",
    "inc",
    "observe",
    "set_gauge",
]

DEFAULT_WINDOW = 2048


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0.0


class Histogram:
    """Value recorder: exact count/sum/min/max over everything recorded,
    percentiles over a bounded most-recent window (``window`` values) so a
    long-lived serve session cannot grow host memory without bound."""

    __slots__ = ("values", "count", "total", "min", "max")

    def __init__(self, window: int = DEFAULT_WINDOW):
        self.values: deque[float] = deque(maxlen=int(window))
        self.count = 0
        self.total = 0.0
        self.min: float | None = None
        self.max: float | None = None

    def record(self, v) -> None:
        v = float(v)
        self.values.append(v)
        self.count += 1
        self.total += v
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    def percentile(self, q: float) -> float:
        """q-th percentile (0–100, nearest-rank) of the retained window."""
        if not self.values:
            return 0.0
        vals = sorted(self.values)
        idx = int(round(q / 100.0 * (len(vals) - 1)))
        return vals[min(max(idx, 0), len(vals) - 1)]

    def summary(self) -> dict:
        if not self.count:
            return {"count": 0, "sum": 0.0, "mean": 0.0, "min": 0.0,
                    "max": 0.0, "p50": 0.0, "p90": 0.0, "p99": 0.0}
        return {
            "count": self.count,
            # exact running total (not window-bounded): throughput math
            # (requests / sum-of-latency) no longer estimates from
            # count x p50
            "sum": self.total,
            "mean": self.total / self.count,
            "min": self.min,
            "max": self.max,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
        }

    def reset(self) -> None:
        self.values.clear()
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None


class MetricsRegistry:
    """Named counters/gauges/histograms with dotted-namespace reset."""

    def __init__(self, name: str = "default"):
        self.name = name
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    # -- access (create on first use) --------------------------------
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, window: int = DEFAULT_WINDOW) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(window)
        return h

    # -- recording ----------------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v) -> None:
        self.histogram(name).record(v)

    # -- reading ------------------------------------------------------
    def value(self, name: str) -> int:
        """Current value of counter ``name`` (0 if never incremented)."""
        c = self._counters.get(name)
        return 0 if c is None else c.value

    def snapshot(self) -> dict:
        """Flat ``{name: number}`` view: counters and gauges verbatim,
        histograms expanded to ``name.count`` / ``.sum`` / ``.mean`` /
        ``.p50`` / ``.p90`` / ``.p99`` / ``.max`` entries.  The ``.sum``
        stat is additive over the historical schema — consumers comparing
        recorded snapshots (``benchmarks/run.py``) iterate the *recorded*
        keys, so artifacts written before it appeared still check clean."""
        out: dict = {}
        for name, c in sorted(self._counters.items()):
            out[name] = c.value
        for name, g in sorted(self._gauges.items()):
            out[name] = g.value
        for name, h in sorted(self._histograms.items()):
            s = h.summary()
            for stat in ("count", "sum", "mean", "p50", "p90", "p99", "max"):
                out[f"{name}.{stat}"] = s[stat]
        return out

    def reset(self, prefix: str | None = None) -> None:
        """Zero every metric whose name starts with ``prefix`` (all of
        them when None).  Metrics stay registered — readers holding a
        Counter/Histogram object keep a live reference."""
        for group in (self._counters, self._gauges, self._histograms):
            for name, metric in group.items():
                if prefix is None or name.startswith(prefix):
                    metric.reset()


_REGISTRY = MetricsRegistry("global")


def get_registry() -> MetricsRegistry:
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Install ``registry`` as process-current; returns the previous one
    (``obs.session()`` uses this for per-session isolation)."""
    global _REGISTRY
    prev = _REGISTRY
    _REGISTRY = registry
    return prev


def inc(name: str, n: int = 1) -> None:
    _REGISTRY.inc(name, n)


def observe(name: str, v) -> None:
    _REGISTRY.observe(name, v)


def set_gauge(name: str, v) -> None:
    _REGISTRY.set_gauge(name, v)
