"""Structured spans: nested, timed, attribute-carrying trace records.

The engine's host-side control flow (plan, autotune probe, lower, each
kernel/einsum stage launch, the psum_scatter collective, the VJP's
recompute + adjoint chain, serve requests) is instrumented with spans —
``with span("stage:m2:sr_gemm", {...}):`` regions that record wall time,
nesting and structured attributes (plan key, fuse tier, backend, modeled
MACs/HBM/collective bytes, shapes).  Completed spans land in a per-tracer
ring buffer (:class:`Tracer`, bounded by ``capacity``) and export to
Chrome-trace JSON via :mod:`repro.obs.export`.

Timing semantics under jax: spans measure the *host* — dispatch plus any
compile — not device execution (jax dispatch is asynchronous).  Inside a
``jit``/``shard_map`` body the span records trace time, once per
compilation; the span *structure* (which stages lower, in what nesting)
is exact either way.

Disabled-mode cost is the contract: :func:`span` returns the preallocated
:data:`NULL_SPAN` singleton without allocating, and hot call sites guard
attribute construction behind :func:`enabled`, so an untraced serve hot
path pays one global load + attribute check per site.

Fault hook: the chaos layer (:mod:`repro.runtime.faults`) registers a
callable via :func:`set_fault_hook` that receives every span *name* at the
moment the span would start — before any work the span guards.  While a
hook is installed :func:`enabled` reports True so the guarded call sites
actually reach :func:`span` (tracing itself may stay off; :func:`span`
still returns :data:`NULL_SPAN` then).  The hook may raise (injected
kernel/collective failure) or sleep (injected delay); with no hook
installed the hot path is unchanged — one extra global load.
"""
from __future__ import annotations

import functools
import itertools
import threading
import time
from collections import deque

__all__ = [
    "Span",
    "Tracer",
    "NULL_SPAN",
    "get_tracer",
    "set_tracer",
    "enabled",
    "enable",
    "disable",
    "span",
    "spans",
    "clear",
    "traced",
    "set_fault_hook",
    "get_fault_hook",
    "DEFAULT_CAPACITY",
]

DEFAULT_CAPACITY = 8192


class _NullSpan:
    """Preallocated no-op span: the disabled-mode zero-allocation fast path.

    ``span()`` returns this singleton whenever tracing is off; entering,
    exiting and ``set()`` do nothing, and it is falsy so call sites can
    skip attribute construction with ``if sp:``.
    """

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def __bool__(self):
        return False

    def __repr__(self):
        return "<NULL_SPAN>"


NULL_SPAN = _NullSpan()


class Span:
    """One timed region.  Use as a context manager; ``set(**attrs)`` adds
    attributes (before, during or right after the region — the record is
    buffered at ``__exit__``)."""

    __slots__ = ("name", "attrs", "span_id", "parent_id", "depth",
                 "t0_ns", "dur_ns", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict | None):
        self._tracer = tracer
        self.name = name
        self.attrs = dict(attrs) if attrs else {}
        self.span_id = next(tracer._ids)
        self.parent_id = 0
        self.depth = 0
        self.t0_ns = 0
        self.dur_ns = 0

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        stack = self._tracer._stack()
        if stack:
            self.parent_id = stack[-1].span_id
            self.depth = len(stack)
        stack.append(self)
        self.t0_ns = time.perf_counter_ns()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.dur_ns = time.perf_counter_ns() - self.t0_ns
        stack = self._tracer._stack()
        if stack and stack[-1] is self:
            stack.pop()
        self._tracer._buf.append(self)
        return False

    def __repr__(self):
        return (f"Span({self.name!r}, {self.dur_ns / 1e3:.1f}us, "
                f"id={self.span_id}, parent={self.parent_id})")


class Tracer:
    """Ring-buffered span recorder (one per session; thread-safe nesting
    via a per-thread active-span stack)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        self.enabled = False
        self._buf: deque[Span] = deque(maxlen=int(capacity))
        self._ids = itertools.count(1)
        self._tls = threading.local()

    @property
    def capacity(self) -> int:
        return self._buf.maxlen

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def start(self, name: str, attrs: dict | None = None) -> Span:
        return Span(self, name, attrs)

    def spans(self) -> list[Span]:
        return list(self._buf)

    def clear(self) -> None:
        self._buf.clear()

    def resize(self, capacity: int) -> None:
        if capacity != self._buf.maxlen:
            self._buf = deque(self._buf, maxlen=int(capacity))


_TRACER = Tracer()

# Chaos hook (see module docstring): callable(name) invoked at span start.
_FAULT_HOOK = None


def set_fault_hook(hook):
    """Install ``hook(span_name)`` on the span hot path; returns the
    previous hook (``None`` if none) so injectors can nest/restore."""
    global _FAULT_HOOK
    prev = _FAULT_HOOK
    _FAULT_HOOK = hook
    return prev


def get_fault_hook():
    return _FAULT_HOOK


def get_tracer() -> Tracer:
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Install ``tracer`` as the process-current tracer; returns the
    previous one (``obs.session()`` uses this for per-session isolation)."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev


def enabled() -> bool:
    """Cheap guard for hot call sites: build span names/attrs only when
    this returns True, else use :data:`NULL_SPAN` directly.  True whenever
    a fault hook is installed, so chaos drills reach :func:`span` (and the
    hook) even with tracing off."""
    return _TRACER.enabled or _FAULT_HOOK is not None


def span(name: str, attrs: dict | None = None):
    """Start a span on the current tracer; :data:`NULL_SPAN` when disabled.

    ``attrs`` may be a zero-arg callable, evaluated only when tracing is
    enabled (lazy construction for attribute dicts that cost something).
    An installed fault hook fires first — it may raise or delay, standing
    in for the kernel/collective failure the span would have timed.
    """
    hook = _FAULT_HOOK
    if hook is not None:
        hook(name)
    t = _TRACER
    if not t.enabled:
        return NULL_SPAN
    if callable(attrs):
        attrs = attrs()
    return Span(t, name, attrs)


def enable(capacity: int | None = None) -> Tracer:
    if capacity is not None:
        _TRACER.resize(capacity)
    _TRACER.enabled = True
    return _TRACER


def disable() -> None:
    _TRACER.enabled = False


def spans() -> list[Span]:
    return _TRACER.spans()


def clear() -> None:
    _TRACER.clear()


def traced(name: str | None = None, **static_attrs):
    """Decorator form: ``@traced("plan")`` wraps calls in a span.  When
    tracing is disabled the wrapper adds one attribute check per call."""

    def deco(fn):
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            hook = _FAULT_HOOK
            if hook is not None:
                hook(label)
            t = _TRACER
            if not t.enabled:
                return fn(*args, **kwargs)
            with Span(t, label, static_attrs):
                return fn(*args, **kwargs)

        return wrapper

    return deco
