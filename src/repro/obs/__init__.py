"""Engine observability: structured spans, a metrics registry, exporters.

Three pieces (see ``docs/observability.md``):

* :mod:`repro.obs.trace` — nested timed spans over the engine's host-side
  control flow (plan → autotune → lower → kernel launches → collectives →
  VJP chain → serve requests), ring-buffered, near-zero cost when disabled;
* :mod:`repro.obs.metrics` — counters/gauges/histograms absorbing the
  engine's scattered accounting (``info`` byte/MAC fields, grad stats,
  memo + autotune-cache hit/miss, fusion-degradation events, serve
  latency percentiles);
* :mod:`repro.obs.export` — Chrome-trace (Perfetto) JSON export, text/JSON
  reports, and the ``python -m repro.obs`` CLI.

Typical use::

    from repro import obs

    obs.enable()                       # start recording spans
    y, info = gemt3_planned(x, c1, c2, c3, with_info=True,
                            differentiable=True)
    jax.grad(lambda x: gemt3_planned(x, c1, c2, c3,
                                     differentiable=True).sum())(x)
    obs.write_chrome_trace("trace.json")   # open in ui.perfetto.dev
    print(obs.format_report())

``obs.session()`` scopes both the tracer and the metrics registry for
isolated measurements (e.g. one serve session, one bench run).
"""
from __future__ import annotations

import contextlib
from types import SimpleNamespace

from . import export, metrics, trace
from .export import (chrome_trace, format_report, report_dict,
                     span_tree_lines, write_chrome_trace)
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, inc, observe, set_gauge, set_registry)
from .trace import (NULL_SPAN, Span, Tracer, clear, disable, enable,
                    enabled, get_fault_hook, get_tracer, set_fault_hook,
                    set_tracer, span, spans, traced)

__all__ = [
    # spans
    "Span", "Tracer", "NULL_SPAN", "span", "traced", "enable", "disable",
    "enabled", "spans", "clear", "get_tracer", "set_tracer",
    "set_fault_hook", "get_fault_hook",
    # metrics
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "set_registry", "inc", "observe", "set_gauge",
    # exporters
    "chrome_trace", "write_chrome_trace", "span_tree_lines",
    "format_report", "report_dict",
    # scoping
    "session",
    # submodules
    "trace", "metrics", "export",
]


@contextlib.contextmanager
def session(name: str = "session", capacity: int | None = None,
            enable_tracing: bool = True):
    """Scope a fresh tracer + metrics registry for the ``with`` body.

    Everything the engine records inside the block lands in the session's
    own objects (per-session isolation of the formerly process-global
    counters); the previous tracer/registry are restored on exit, so
    nothing leaks either way.  Yields a namespace with ``.tracer`` and
    ``.registry``.
    """
    tracer = Tracer(capacity or trace.DEFAULT_CAPACITY)
    tracer.enabled = bool(enable_tracing)
    registry = MetricsRegistry(name)
    prev_tracer = set_tracer(tracer)
    prev_registry = set_registry(registry)
    try:
        yield SimpleNamespace(tracer=tracer, registry=registry)
    finally:
        set_tracer(prev_tracer)
        set_registry(prev_registry)
