"""Exporters: Chrome-trace (Perfetto) JSON, span-tree text, and a CLI.

Chrome-trace format (the subset emitted here): a JSON object with a
``traceEvents`` list of *complete* events — ``ph: "X"`` with ``ts``/``dur``
in microseconds — one per recorded span, ``args`` carrying the span's
structured attributes plus ``span_id``/``parent_id``.  Load the file in
``chrome://tracing`` or https://ui.perfetto.dev.  A ``counters`` key (not
part of the Chrome schema; both viewers ignore unknown keys) embeds the
metrics-registry snapshot taken at export time.

The snapshot schema is *additive-only*: histogram stats may gain keys
(``.sum`` joined ``.count/.mean/.p50/.p90/.p99/.max``) but existing keys
keep their meaning, so traces and BENCH artifacts recorded under an older
schema still load and compare — ``benchmarks/run.py`` iterates the
recorded keys and never requires the fresh snapshot to be key-identical.

``python -m repro.obs TRACE.json [--json]`` prints a per-span-name
aggregate report (count / total / mean µs) of a saved trace.
"""
from __future__ import annotations

import json
import sys

from . import metrics as _metrics
from . import trace as _trace

__all__ = [
    "chrome_trace",
    "write_chrome_trace",
    "span_tree_lines",
    "format_report",
    "report_dict",
    "summarize_events",
    "main",
]


def _jsonable(v):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (tuple, list)):
        return [_jsonable(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _jsonable(x) for k, x in v.items()}
    return str(v)


def chrome_trace(spans=None, registry=None) -> dict:
    """Build the Chrome-trace document for ``spans`` (default: the current
    tracer's ring buffer) with ``registry``'s counter snapshot attached
    (default: the current registry)."""
    if spans is None:
        spans = _trace.spans()
    if registry is None:
        registry = _metrics.get_registry()
    t0 = min((s.t0_ns for s in spans), default=0)
    events = []
    for s in spans:
        args = {k: _jsonable(v) for k, v in s.attrs.items()}
        args["span_id"] = s.span_id
        args["parent_id"] = s.parent_id
        events.append({
            "name": s.name,
            "cat": str(s.attrs.get("cat", "engine")),
            "ph": "X",
            "ts": (s.t0_ns - t0) / 1e3,
            "dur": s.dur_ns / 1e3,
            "pid": 0,
            "tid": 0,
            "args": args,
        })
    events.sort(key=lambda e: e["ts"])
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "counters": registry.snapshot(),
    }


def write_chrome_trace(path: str, spans=None, registry=None) -> dict:
    """Export to ``path``; returns the document that was written."""
    doc = chrome_trace(spans, registry)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc


def span_tree_lines(spans=None) -> list[str]:
    """Render the span forest as indented ``name  dur  attrs`` lines.

    Children are grouped under their parent by ``parent_id``; spans whose
    parent fell out of the ring buffer render as roots.  Within a level,
    start time orders siblings.
    """
    if spans is None:
        spans = _trace.spans()
    by_id = {s.span_id: s for s in spans}
    children: dict[int, list] = {}
    roots = []
    for s in spans:
        if s.parent_id in by_id:
            children.setdefault(s.parent_id, []).append(s)
        else:
            roots.append(s)
    lines: list[str] = []

    def walk(s, depth):
        attrs = " ".join(
            f"{k}={v}" for k, v in s.attrs.items()
            if isinstance(v, (int, float, str, bool, tuple)))
        pad = "  " * depth
        lines.append(f"{pad}{s.name}  {s.dur_ns / 1e3:.1f}us"
                     + (f"  [{attrs}]" if attrs else ""))
        for c in sorted(children.get(s.span_id, ()), key=lambda c: c.t0_ns):
            walk(c, depth + 1)

    for r in sorted(roots, key=lambda s: s.t0_ns):
        walk(r, 0)
    return lines


def summarize_events(events: list[dict]) -> dict:
    """Per-name aggregate of Chrome-trace events: count/total/mean µs."""
    agg: dict[str, dict] = {}
    for e in events:
        a = agg.setdefault(e["name"], {"count": 0, "total_us": 0.0})
        a["count"] += 1
        a["total_us"] += float(e.get("dur", 0.0))
    for a in agg.values():
        a["total_us"] = round(a["total_us"], 1)
        a["mean_us"] = round(a["total_us"] / a["count"], 1)
    return dict(sorted(agg.items(), key=lambda kv: -kv[1]["total_us"]))


def report_dict(spans=None, registry=None) -> dict:
    """Machine-readable report: span aggregates + counter snapshot."""
    doc = chrome_trace(spans, registry)
    return {
        "spans": summarize_events(doc["traceEvents"]),
        "counters": doc["counters"],
    }


def format_report(spans=None, registry=None) -> str:
    """Human-readable report: the span tree, per-name totals, counters."""
    if registry is None:
        registry = _metrics.get_registry()
    lines = ["== span tree =="]
    lines += span_tree_lines(spans) or ["(no spans recorded)"]
    rep = report_dict(spans, registry)
    lines.append("== spans by total time ==")
    for name, a in rep["spans"].items():
        lines.append(f"{a['total_us']:>12.1f}us  x{a['count']:<5d} {name}")
    lines.append("== counters ==")
    for k, v in rep["counters"].items():
        lines.append(f"{k} = {v}")
    return "\n".join(lines)


def main(argv=None) -> int:
    """CLI: summarize a saved Chrome-trace file (text or ``--json``)."""
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="Summarize a Chrome-trace JSON exported by repro.obs")
    ap.add_argument("trace", help="path to a Chrome-trace JSON file")
    ap.add_argument("--json", action="store_true",
                    help="emit the aggregate report as JSON")
    args = ap.parse_args(argv)
    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"error: cannot read trace {args.trace!r}: {e}", file=sys.stderr)
        return 1
    if not isinstance(doc, dict):
        print(f"error: {args.trace!r} is not a Chrome-trace document",
              file=sys.stderr)
        return 1
    events = doc.get("traceEvents", [])
    rep = {"spans": summarize_events(events),
           "counters": doc.get("counters", {})}
    if args.json:
        print(json.dumps(rep, indent=1))
        return 0
    print(f"{len(events)} events")
    print("== spans by total time ==")
    for name, a in rep["spans"].items():
        print(f"{a['total_us']:>12.1f}us  x{a['count']:<5d} {name}")
    if rep["counters"]:
        print("== counters ==")
        for k, v in rep["counters"].items():
            print(f"{k} = {v}")
    return 0
