"""Pallas TPU kernels for the perf-critical hot spots (+ ops wrappers, refs)."""
from .ops import esop_gemm, flash_attention, on_tpu, sr_gemm
