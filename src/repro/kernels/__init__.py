"""Pallas TPU kernels for the perf-critical hot spots (+ ops wrappers, refs)."""
from .ops import (esop_gemm, esop_plan_cached, flash_attention, fused_gemt,
                  on_tpu, sr_gemm)
