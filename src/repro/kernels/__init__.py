"""Pallas TPU kernels for the perf-critical hot spots (+ ops wrappers, refs).

Paper anchor: §5 (SR-GEMM, the streaming outer-product cell array), §6
(block-ESOP skipping), the fused two-stage GEMT (VMEM-resident
intermediate — ``docs/engine.md`` "Stage fusion") and the whole-transform
megakernel (all three contractions in one launch, both intermediates
on-chip — "Whole-transform fusion").  ``ref.py`` holds the jnp oracles;
dispatch and padding live in ``ops.py``.
"""
from .ops import (esop_gemm, esop_plan_cached, flash_attention, fused3_gemt,
                  fused_gemt, on_tpu, sr_gemm)
