"""Block-ESOP kernel — Elastic Sparse Outer Product on the MXU (paper §6).

TPU-native adaptation of ESOP: the MXU cannot skip scalar zeros, so zeros
are skipped at **block** granularity.  For each output column-block j we
precompute the compacted list of contraction blocks k where the streamed
coefficient matrix C[k-block, j-block] is nonzero:

  * ``counts[j]``  — number of nonzero C blocks in block-column j,
  * ``idx[j, t]``  — the t-th nonzero k-block index (padded with 0).

The grid's streaming dimension runs only to ``max(counts)``; the BlockSpec
``index_map`` reads the *prefetched* index list, so zero blocks of C are
**never fetched from HBM** (the paper's "never sent by the actuator") and
their MACs are never executed (``pl.when`` guard) — compute *and*
communication skipping, as §6 prescribes.

Bit-exactness: skipped blocks are exactly zero, so the result equals the
dense SR-GEMM product (adding 0 is exact in IEEE arithmetic).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.esop import block_nonzero_mask

__all__ = ["esop_plan", "esop_gemm_pallas"]


def esop_plan(c: jnp.ndarray, bk: int, bn: int) -> tuple[np.ndarray, np.ndarray, int]:
    """Host-side ESOP schedule: per column-block compacted nonzero k-blocks.

    Returns (counts[j], idx[j, t], t_steps) with t_steps = max(counts) (>=1).
    One device sync (the block mask); the compaction itself is vectorized —
    a stable argsort that floats each column's nonzero k-blocks to the
    front in ascending order.
    """
    mask = np.asarray(block_nonzero_mask(c, (bk, bn)))  # (K/bk, N/bn)
    counts = mask.sum(axis=0).astype(np.int32)  # (N/bn,)
    t_steps = max(int(counts.max(initial=0)), 1)
    # Stable sort on ~mask: per column, nonzero rows first, index order kept.
    order = np.argsort(~mask, axis=0, kind="stable")[:t_steps].T  # (nb, t)
    # Dead steps repeat the column's last live index (not 0): the kernel
    # guards their MACs, and a repeated BlockSpec index lets Pallas elide
    # the refetch — a dead step then moves zero HBM bytes, as modeled.
    last_live = order[np.arange(order.shape[0]),
                      np.maximum(counts - 1, 0)]
    live = np.arange(t_steps, dtype=np.int32)[None, :] < counts[:, None]
    idx = np.where(live, order, last_live[:, None]).astype(np.int32)
    return counts, idx, t_steps


def _esop_kernel(*refs, t_steps: int, affine: bool, accum: str = "plain"):
    compensated = accum == "compensated"
    if compensated:
        *refs, comp_ref = refs
    if affine:
        counts_ref, idx_ref, o_init_ref, x_ref, c_ref, o_ref, acc_ref = refs
    else:
        counts_ref, idx_ref, x_ref, c_ref, o_ref, acc_ref = refs
    j = pl.program_id(1)
    t = pl.program_id(2)

    @pl.when(t == 0)
    def _init():
        # Affine += (Eq. 1) seeds from the aliased output; otherwise the
        # accumulator starts at zero in-kernel — no HBM seed buffer.
        acc_ref[...] = (o_init_ref[...].astype(acc_ref.dtype) if affine
                        else jnp.zeros(acc_ref.shape, acc_ref.dtype))
        if compensated:
            comp_ref[...] = jnp.zeros(comp_ref.shape, comp_ref.dtype)

    # Live step: this (j, t) names a nonzero streamed block — do the rank-bk
    # update.  Dead steps (t >= counts[j]) leave every cell waiting (§6);
    # skipping their (exactly zero) Neumaier update is equally exact.
    @pl.when(t < counts_ref[j])
    def _update():
        p = jnp.dot(x_ref[...], c_ref[...],
                    preferred_element_type=jnp.float32)
        if compensated:
            acc = acc_ref[...]
            tot = acc + p
            comp_ref[...] += jnp.where(jnp.abs(acc) >= jnp.abs(p),
                                       (acc - tot) + p, (p - tot) + acc)
            acc_ref[...] = tot
        else:
            acc_ref[...] += p

    @pl.when(t == t_steps - 1)
    def _flush():
        flushed = acc_ref[...] + comp_ref[...] if compensated else acc_ref[...]
        o_ref[...] = flushed.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "t_steps",
                                             "interpret", "accum"))
def _esop_call(x, c, out, counts, idx, bm, bn, bk, t_steps, interpret,
               accum="plain"):
    m, kdim = x.shape
    n = c.shape[1]
    grid = (m // bm, n // bn, t_steps)
    affine = out is not None
    out_dtype = (jnp.float32 if accum != "plain"
                 else (out.dtype if affine else x.dtype))
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]
    if accum == "compensated":
        scratch.append(pltpu.VMEM((bm, bn), jnp.float32))  # Neumaier comp

    def x_map(i, j, t, counts_ref, idx_ref):
        return (i, idx_ref[j, t])

    def c_map(i, j, t, counts_ref, idx_ref):
        return (idx_ref[j, t], j)

    def o_map(i, j, t, counts_ref, idx_ref):
        return (i, j)

    in_specs = [
        pl.BlockSpec((bm, bk), x_map),  # resident X (sparse-indexed)
        pl.BlockSpec((bk, bn), c_map),  # streamed C (only live blocks)
    ]
    operands = [x, c]
    if affine:
        in_specs.insert(0, pl.BlockSpec((bm, bn), o_map))  # o_init (aliased)
        operands.insert(0, out)

    return pl.pallas_call(
        functools.partial(_esop_kernel, t_steps=t_steps, affine=affine,
                          accum=accum),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # counts, idx drive the dataflow
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((bm, bn), o_map),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        # (after the 2 scalar-prefetch operands) — affine path only, and
        # only when the promoted flush dtype still matches the seed's
        input_output_aliases=(
            {2: 0} if affine and out_dtype == out.dtype else {}),
        interpret=interpret,
    )(counts, idx, *operands)


def esop_gemm_pallas(
    x: jnp.ndarray,
    c: jnp.ndarray,
    out: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    plan: tuple | None = None,
    accum: str = "plain",
) -> tuple[jnp.ndarray, dict]:
    """Y = (out +) X @ C, skipping zero blocks of C.  Returns (y, esop_info).

    ``plan`` optionally carries a precomputed ``(counts, idx, t_steps)``
    schedule (``ops.esop_gemm`` memoizes it per C identity so neither the
    host-side compaction nor the counts device→host sync reruns every
    call).  With a supplied plan the caller already owns the accounting and
    ``esop_info`` is None — the memoized stats are the single source of
    truth; standalone calls get the streamed-block savings computed here
    (blocks_dense, blocks_live, fetch_savings — the paper's energy proxy).
    """
    m, kdim = x.shape
    k2, n = c.shape
    assert kdim == k2 and (out is None or out.shape == (m, n))
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    if plan is None:
        counts, idx, t_steps = esop_plan(c, bk, bn)
        live_blocks = int(counts.sum())  # host-side: counts is still np
        counts, idx = jnp.asarray(counts), jnp.asarray(idx)
    else:
        counts, idx, t_steps = plan
        live_blocks = None
    y = _esop_call(x, c, out, counts, idx, bm, bn, bk, t_steps, interpret,
                   accum=accum)
    if live_blocks is None:
        return y, None
    dense_blocks = (kdim // bk) * (n // bn)
    info = {
        "blocks_dense": dense_blocks,
        "blocks_live": live_blocks,
        "fetch_savings": 1.0 - live_blocks / max(dense_blocks, 1),
        "t_steps": t_steps,
        "t_steps_dense": kdim // bk,
    }
    return y, info
