"""jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, dtype policy, and the CPU/TPU dispatch:
on a TPU backend the kernels run compiled; elsewhere they run in
``interpret=True`` mode (bit-faithful emulation) unless ``use_pallas=False``
routes to the jnp reference (the default inside the big-model dry-run, where
interpret-mode loops would bloat compile times — see DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .attention import flash_attention_pallas
from .esop_gemm import esop_gemm_pallas
from .sr_gemm import sr_gemm_pallas

__all__ = ["sr_gemm", "esop_gemm", "flash_attention", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def sr_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
            bm: int = 128, bn: int = 128, bk: int = 128,
            use_pallas: bool | None = None) -> jnp.ndarray:
    """Y = (out +) X @ C via the streaming outer-product kernel."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if not use_pallas and not on_tpu():
        interpret = True
    else:
        interpret = not on_tpu()
    if use_pallas is False:
        return ref.ref_sr_gemm(x, c, out)
    m, n = x.shape[0], c.shape[1]
    o = out if out is not None else jnp.zeros((m, n), dtype=x.dtype)
    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(c, (bk, bn))
    op = _pad_to(o, (bm, bn))
    y = sr_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n]


def esop_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
              bm: int = 128, bn: int = 128, bk: int = 128,
              use_pallas: bool | None = None):
    """Block-ESOP Y = (out +) X @ C skipping zero C blocks. Returns (y, info)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas is False:
        return ref.ref_esop_gemm(x, c, (bk, bn), out), {"fetch_savings": 0.0}
    interpret = not on_tpu()
    m, n = x.shape[0], c.shape[1]
    o = out if out is not None else jnp.zeros((m, n), dtype=x.dtype)
    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(c, (bk, bn))
    op = _pad_to(o, (bm, bn))
    y, info = esop_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk,
                               interpret=interpret)
    return y[:m, :n], info


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 128, bkv: int = 128,
                    use_pallas: bool | None = None) -> jnp.ndarray:
    """(B, H, S, D) flash attention; jnp blockwise reference off-TPU by default."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas is False:
        return ref.ref_attention(q, k, v, causal=causal)
    b, h, s, d = q.shape
    fold = lambda t: t.reshape(b * h, s, d)
    y = flash_attention_pallas(fold(q), fold(k), fold(v), bq=bq, bkv=bkv,
                               causal=causal, interpret=not on_tpu())
    return y.reshape(b, h, s, d)
