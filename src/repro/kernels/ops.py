"""jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, dtype policy, and the CPU/TPU dispatch:
on a TPU backend the kernels run compiled; elsewhere they run in
``interpret=True`` mode (bit-faithful emulation) unless ``use_pallas=False``
routes to the jnp reference (the default inside the big-model dry-run, where
interpret-mode loops would bloat compile times).  Paper anchor: §5–§6
(streaming outer-product cell array + ESOP skipping); the engine-facing
contract is documented in ``docs/engine.md`` ("Lowering").
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from ..memo import ArrayMemo
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from . import ref
from .attention import flash_attention_pallas
from .esop_gemm import esop_gemm_pallas, esop_plan
from .fused3_gemt import fused3_gemt_pallas
from .fused_chain import (chain3_gemt_pallas, chain_gemt_pallas,
                          coeff_grad_batch_pallas)
from .fused_gemt import fused_gemt_pallas, kb_padded
from .sr_gemm import sr_gemm_pallas

__all__ = ["sr_gemm", "esop_gemm", "fused_gemt", "fused3_gemt",
           "chain_gemt", "chain3_gemt", "coeff_grad_batch",
           "flash_attention", "esop_plan_cached", "esop_memo_stats",
           "set_esop_memo_size", "transposed_cached", "on_tpu"]

# Host-side ESOP schedules are memoized per coefficient-matrix identity.
# Long-running serve sessions stream *distinct* matrices through, so the
# memo is LRU-bounded (satellite of the differentiable-engine PR); the knob
# is REPRO_ESOP_MEMO_SIZE (entries, default 256) or set_esop_memo_size().
_ESOP_MEMO_DEFAULT = int(os.environ.get("REPRO_ESOP_MEMO_SIZE", "256"))


def _memo_sink(prefix: str):
    """Mirror a memo's hit/miss/evict events into the *current* metrics
    registry (resolved per event, so ``obs.session()`` scoping applies)."""
    def sink(event: str) -> None:
        _metrics.inc(prefix + event)
    return sink


_ESOP_PLAN_MEMO = ArrayMemo(maxsize=_ESOP_MEMO_DEFAULT,
                            on_event=_memo_sink("memo.esop."))
# Adjoint reuse: the VJP paths contract against C^T.  Recomputing the
# transpose per backward call would give it a fresh identity every time and
# defeat every identity-keyed memo downstream (esop plans, fingerprints,
# plan caches) — so the transpose itself is memoized on C's identity.
_TRANSPOSED_MEMO = ArrayMemo(maxsize=_ESOP_MEMO_DEFAULT,
                             on_event=_memo_sink("memo.transposed."))


def esop_memo_stats() -> dict:
    """Hit/miss/evict accounting of the bounded ESOP-schedule memo.

    Surfaced in the engine's ``info["esop_memo"]`` so serve telemetry can
    prove the schedule cache is neither thrashing nor growing unbounded.
    """
    return {"entries": len(_ESOP_PLAN_MEMO),
            "maxsize": _ESOP_PLAN_MEMO.maxsize,
            **_ESOP_PLAN_MEMO.stats}


def set_esop_memo_size(maxsize: int | None) -> None:
    """Re-bound the ESOP-schedule (and transpose) memos; LRU-evicts now."""
    _ESOP_PLAN_MEMO.set_maxsize(maxsize)
    _TRANSPOSED_MEMO.set_maxsize(maxsize)


def transposed_cached(c: jnp.ndarray) -> jnp.ndarray:
    """``C^T`` memoized on C's identity (tracers transpose uncached).

    The adjoint of every GEMT stage contracts against the transposed
    coefficient matrix; returning the *same* transposed array object per
    forward matrix keeps the identity-keyed ESOP/plan/fingerprint memos hot
    across backward passes.
    """
    if isinstance(c, jax.core.Tracer):
        return jnp.swapaxes(c, 0, 1)
    return _TRANSPOSED_MEMO.get_or_compute(
        c, "T", lambda: jnp.swapaxes(c, 0, 1))


def esop_plan_cached(c: jnp.ndarray, bk: int, bn: int):
    """Padded block-ESOP schedule for C, memoized on C's identity.

    Returns ``(counts, idx, t_steps, stats)``: the scalar-prefetch operands
    as device arrays plus the host-side accounting dict.  The ``esop_plan``
    sweep (a device sync + block compaction) and the host→device upload run
    once per distinct ``(C, block)`` — not once per call — so hot loops
    reusing the same coefficient matrices pay nothing, on the reference
    *and* the Pallas path alike.
    """
    def compute():
        sp = _trace.NULL_SPAN
        if _trace.enabled():  # memo misses only: the sweep + upload cost
            sp = _trace.span("esop.plan",
                             {"shape": tuple(c.shape), "bk": bk, "bn": bn})
        with sp:
            cp = _pad_to(c, (bk, bn))
            counts, idx, t_steps = esop_plan(cp, bk, bn)
            dense_blocks = (cp.shape[0] // bk) * (cp.shape[1] // bn)
            live_blocks = int(counts.sum())
            stats = {
                "blocks_dense": dense_blocks,
                "blocks_live": live_blocks,
                "fetch_savings": 1.0 - live_blocks / max(dense_blocks, 1),
                "t_steps": t_steps,
                "t_steps_dense": cp.shape[0] // bk,
            }
            return jnp.asarray(counts), jnp.asarray(idx), t_steps, stats

    return _ESOP_PLAN_MEMO.get_or_compute(c, (bk, bn), compute)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _is_traced(*arrays) -> bool:
    return any(isinstance(a, jax.core.Tracer) for a in arrays)


_ACCUM_MODES = ("plain", "f32", "compensated")


def _norm_accum(accum, *arrays) -> str:
    """Default/validate an ``accum`` knob; complex operands force
    ``"plain"`` (the kernels and the compensation algebra are real-valued —
    the planner pins complex stages to einsum anyway)."""
    accum = "plain" if accum is None else accum
    if accum not in _ACCUM_MODES:
        raise ValueError(
            f"accum must be one of {_ACCUM_MODES} (or None), got {accum!r}")
    if accum != "plain" and any(
            a is not None and jnp.iscomplexobj(a) for a in arrays):
        return "plain"
    return accum


def _linear_custom_vjp(prim, bwd_x, bwd_c, x, c, out):
    """Wrap the bilinear kernel dispatch ``prim(x, c, out)`` in a custom VJP.

    ``pallas_call`` defines no differentiation rule, so without this any
    ``jax.grad`` touching the kernel dispatch would fail (compiled) or
    differentiate through kernel internals (interpret mode).  The wrapper
    makes every public op VJP-safe: the backward GEMMs re-enter the same
    kernel dispatch (``bwd_x``/``bwd_c`` callables), so a gradient never
    silently leaves the kernel path.  ``out``'s cotangent is ``g`` itself
    (the affine seed adds straight through, Eq. 1's ``+=``).

    Built per call because ESOP's ``prim`` closes over unhashable
    prefetch-plan device arrays; SR-GEMM, the forward hot path, gets the
    memoized :func:`_sr_gemm_vjp` factory instead.

    Cotangents are cast back to the primal dtypes: under a promoted
    ``accum`` the forward output (hence ``g``) is float32 while the
    operands may be bf16 — ``custom_vjp`` requires matching avals.  The
    casts are identities on the plain path.
    """
    if out is None:
        @jax.custom_vjp
        def f(x, c):
            return prim(x, c, None)

        f.defvjp(lambda x, c: (prim(x, c, None), (x, c)),
                 lambda res, g: (bwd_x(g, res[1]).astype(res[0].dtype),
                                 bwd_c(res[0], g).astype(res[1].dtype)))
        return f(x, c)

    odt = out.dtype

    @jax.custom_vjp
    def fo(x, c, out):
        return prim(x, c, out)

    fo.defvjp(lambda x, c, out: (prim(x, c, out), (x, c)),
              lambda res, g: (bwd_x(g, res[1]).astype(res[0].dtype),
                              bwd_c(res[0], g).astype(res[1].dtype),
                              g.astype(odt)))
    return fo(x, c, out)


def _sr_dispatch(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None,
                 bm: int, bn: int, bk: int, use_pallas: bool,
                 accum: str = "plain") -> jnp.ndarray:
    """Raw (non-differentiable) SR-GEMM dispatch: pad → kernel → crop."""
    if not use_pallas:
        return ref.ref_sr_gemm(x, c, out, accum=accum)
    interpret = not on_tpu()
    m, n = x.shape[0], c.shape[1]
    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(c, (bk, bn))
    op = _pad_to(out, (bm, bn)) if out is not None else None
    y = sr_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk, interpret=interpret,
                       accum=accum)
    return y[:m, :n]


@functools.lru_cache(maxsize=None)
def _sr_gemm_vjp(bm: int, bn: int, bk: int, use_pallas: bool,
                 has_out: bool, accum: str = "plain"):
    """Module-level custom-VJP builder for SR-GEMM, memoized per static
    config.

    SR-GEMM is the engine's dense workhorse and runs on forward-only
    serving hot loops too, so — unlike the rarer ESOP/fused ops, whose
    unhashable prefetch-plan operands force per-call closures — its
    wrapper is built once per ``(tiles, dispatch, out, accum)`` config,
    not per call.  The backward GEMMs always run plain accumulation (the
    cotangent is already float32 under a promoted forward) and cast back
    to the primal dtypes — identities on the plain path.
    """
    def prim(x, c, out):
        return _sr_dispatch(x, c, out, bm, bn, bk, use_pallas, accum=accum)

    def bwd_x(g, c):
        # dX (m, k) = g (m, n) @ C^T (n, k): output cols k, contraction n.
        return _sr_dispatch(g, transposed_cached(c), None, bm, bk, bn,
                            use_pallas)

    def bwd_c(x, g):
        # dC (k, n) = X^T (k, m) @ g (m, n): rows k, contraction m.
        return _sr_dispatch(jnp.swapaxes(x, 0, 1), g, None, bk, bn, bm,
                            use_pallas)

    if has_out:
        @jax.custom_vjp
        def fo(x, c, out):
            return prim(x, c, out)

        fo.defvjp(lambda x, c, out: (prim(x, c, out), (x, c, out)),
                  lambda res, g: (bwd_x(g, res[1]).astype(res[0].dtype),
                                  bwd_c(res[0], g).astype(res[1].dtype),
                                  g.astype(res[2].dtype)))
        return fo

    @jax.custom_vjp
    def f(x, c):
        return prim(x, c, None)

    f.defvjp(lambda x, c: (prim(x, c, None), (x, c)),
             lambda res, g: (bwd_x(g, res[1]).astype(res[0].dtype),
                             bwd_c(res[0], g).astype(res[1].dtype)))
    return f


def sr_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
            bm: int = 128, bn: int = 128, bk: int = 128,
            use_pallas: bool | None = None,
            accum: str | None = None) -> jnp.ndarray:
    """Y = (out +) X @ C via the streaming outer-product kernel.

    VJP-safe: ``dX = g @ C^T`` and ``dC = X^T @ g`` run the same kernel
    dispatch with the tile roles swapped.  ``accum`` selects the
    accumulation mode (``docs/numerics.md``): promoted modes flush in
    float32 instead of rounding back to the operand dtype.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    accum = _norm_accum(accum, x, c, out)
    f = _sr_gemm_vjp(bm, bn, bk, use_pallas, out is not None, accum)
    return f(x, c, out) if out is not None else f(x, c)


def esop_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
              bm: int = 128, bn: int = 128, bk: int = 128,
              use_pallas: bool | None = None, plan: tuple | None = None,
              accum: str | None = None):
    """Block-ESOP Y = (out +) X @ C skipping zero C blocks. Returns (y, info).

    The block schedule and its accounting are memoized on C's identity
    (``esop_plan_cached``); the reference path reports the same
    streamed-block savings the Pallas kernel realizes.  ``plan`` optionally
    supplies that ``(counts, idx, t_steps, stats)`` tuple precomputed from
    the concrete matrix — required when ``c`` here is a tracer (e.g. a
    replicated operand inside a ``shard_map`` body).  ``accum`` as in
    :func:`sr_gemm` (``docs/numerics.md``).
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    accum = _norm_accum(accum, x, c, out)
    counts, idx, t_steps, stats = (plan if plan is not None
                                   else esop_plan_cached(c, bk, bn))

    def prim(x, c, out):
        if not use_pallas:
            return ref.ref_esop_gemm(x, c, (bk, bn), out, accum=accum)
        interpret = not on_tpu()
        m, n = x.shape[0], c.shape[1]
        xp = _pad_to(x, (bm, bk))
        cp = _pad_to(c, (bk, bn))
        op = _pad_to(out, (bm, bn)) if out is not None else None
        yk, _ = esop_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk,
                                 interpret=interpret,
                                 plan=(counts, idx, t_steps), accum=accum)
        return yk[:m, :n]

    def bwd_x(g, c):
        # dX = g @ C^T reuses block skipping on the transposed structure
        # (same zero blocks, transposed grid).  A traced C has no
        # host-readable schedule — dense SR-GEMM then (still the kernel).
        if _is_traced(c):
            return _sr_dispatch(g, jnp.swapaxes(c, 0, 1), None,
                                bm, bk, bn, use_pallas)
        dx, _ = esop_gemm(g, transposed_cached(c), bm=bm, bn=bk, bk=bn,
                          use_pallas=use_pallas)
        return dx

    def bwd_c(x, g):
        # dC = X^T @ g is dense regardless of C's zeros: the linearization
        # of Y = X @ C in C does not inherit C's sparsity.
        return _sr_dispatch(jnp.swapaxes(x, 0, 1), g, None, bk, bn, bm,
                            use_pallas)

    # dict(stats): the memoized entry is shared across calls — handing the
    # caller the cached object would let an info-dict mutation poison it
    return _linear_custom_vjp(prim, bwd_x, bwd_c, x, c, out), dict(stats)


def fused_gemt(x3: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
               bu: int = 128, bka: int = 128, bnb: int = 32, bna: int = 128,
               use_pallas: bool | None = None, plans: tuple | None = None,
               accum: str | None = None):
    """Fused two-stage GEMT ``Y = (X3 ×_a C_a) ×_b C_b``. Returns (y, info).

    ``x3`` is the u-major unfolding ``(U, Nb, Na)`` (``engine.lower``
    produces it); the result is ``(U, Ka, Kb)``.  The stage-a partial
    product never touches HBM — see ``kernels/fused_gemt.py``.  Complex
    coefficients (DFT) route to the einsum reference (the kernel is
    real-valued), with identical accounting.  ``plans`` optionally supplies
    the two precomputed ``esop_plan_cached`` tuples ``(plan_a, plan_b)``
    for tracer ``ca``/``cb`` (inside a ``shard_map`` body).
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if jnp.iscomplexobj(x3) or jnp.iscomplexobj(ca) or jnp.iscomplexobj(cb):
        use_pallas = False
    accum = _norm_accum(accum, x3, ca, cb)
    u, nb, na = x3.shape
    # Validate before padding: post-pad extents can line up by accident and
    # the kernel would silently contract against garbage rows.
    if ca.shape[0] != na or cb.shape[0] != nb:
        raise ValueError(
            f"x3 {x3.shape} incompatible with C_a {ca.shape} (na) / "
            f"C_b {cb.shape} (nb)")
    ka, kb = ca.shape[1], cb.shape[1]
    kbp = kb_padded(kb)
    # Both schedules memoized on the coefficient identities: C_a's 2D block
    # compaction and C_b's nb-slab compaction (one "column" of width kbp).
    counts_a, idx_a, t_a, stats_a = (plans[0] if plans is not None
                                     else esop_plan_cached(ca, bna, bka))
    # counts_b is unused: the slab stream is a single block column, so every
    # t_b step is live by construction — the kernel needs no b-side guard.
    _counts_b, idx_b, t_b, stats_b = (plans[1] if plans is not None
                                      else esop_plan_cached(cb, bnb, kbp))
    info = {
        "blocks_dense_a": stats_a["blocks_dense"],
        "blocks_live_a": stats_a["blocks_live"],
        "slabs_dense_b": stats_b["blocks_dense"],
        "slabs_live_b": stats_b["blocks_live"],
        # The streamed grid is the product space (C_a blocks × C_b slabs):
        # a dead entry on either axis skips the fetch.  blocks_dense/_live
        # use the same keys as esop_gemm so per-call savings aggregate.
        "blocks_dense": stats_a["blocks_dense"] * stats_b["blocks_dense"],
        "blocks_live": stats_a["blocks_live"] * max(stats_b["blocks_live"], 1),
        "t_steps": (t_a, t_b),
        "t_steps_dense": (stats_a["t_steps_dense"], stats_b["t_steps_dense"]),
    }
    info["fetch_savings"] = 1.0 - (info["blocks_live"]
                                   / max(info["blocks_dense"], 1))

    def prim(x3, ca, cb):
        if not use_pallas:
            return ref.ref_fused_gemt(x3, ca, cb, accum=accum)
        interpret = not on_tpu()
        xp = _pad_to(x3, (bu, bnb, bna))
        cap = _pad_to(ca, (bna, bka))
        cbp = _pad_to(cb, (bnb, kbp))
        yk, _ = fused_gemt_pallas(
            xp, cap, cbp, bu=bu, bka=bka, bnb=bnb, bna=bna,
            interpret=interpret, plan=(counts_a, idx_a, t_a, idx_b, t_b),
            accum=accum)
        return yk[:u, :ka, :kb]

    @jax.custom_vjp
    def f(x3, ca, cb):
        return prim(x3, ca, cb)

    def bwd(res, g):
        x3r, car, cbr = res
        # dX3 is itself a fused two-stage GEMT over the transposed
        # coefficients (the orthonormal-transform adjoint, paper §2.2):
        # the (Ka, Kb) output modes slide into the kernel's (na', nb')
        # slots.  Traced coefficients have no host-readable ESOP schedule,
        # so they take the fused jnp oracle instead of the kernel.
        gsw = jnp.swapaxes(g, 1, 2)  # (U, Kb, Ka)
        if _is_traced(car, cbr):
            dx3 = ref.ref_fused_gemt(gsw, jnp.swapaxes(car, 0, 1),
                                     jnp.swapaxes(cbr, 0, 1))
        else:
            dx3, _ = fused_gemt(gsw, transposed_cached(car),
                                transposed_cached(cbr), bu=bu,
                                use_pallas=use_pallas)
        dx3 = jnp.swapaxes(dx3, 1, 2).astype(x3r.dtype)
        # Coefficient cotangents are mode-unfolded rank-k products; the
        # engine-level VJP owns the training hot path with planned kernels,
        # this direct-op safety net contracts them in place.  Casts are
        # identities unless a promoted accum made g float32.
        dca = jnp.einsum("uba,ukl,bl->ak", x3r, g, cbr).astype(car.dtype)
        dcb = jnp.einsum("uba,ak,ukl->bl", x3r, car, g).astype(cbr.dtype)
        return dx3, dca, dcb

    f.defvjp(lambda x3, ca, cb: (prim(x3, ca, cb), (x3, ca, cb)), bwd)
    return f(x3, ca, cb), info


def fused3_gemt(x4: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
                cc: jnp.ndarray, bu: int = 8, bka: int = 128, bnb: int = 16,
                bnc: int = 16, bna: int = 128,
                use_pallas: bool | None = None, plans: tuple | None = None,
                accum: str | None = None):
    """Whole-transform fused GEMT ``Y = ((X4 ×_a C_a) ×_b C_b) ×_c C_c``.
    Returns (y, info).

    ``x4`` is the u-major unfolding ``(U, Nc, Nb, Na)`` (``engine.lower``
    produces it; U is the folded batch); the result is ``(U, Ka, Kb, Kc)``.
    Neither intermediate ever touches HBM — see ``kernels/fused3_gemt.py``.
    Complex coefficients (DFT) route to the einsum reference (the kernel is
    real-valued), with identical accounting.  ``plans`` optionally supplies
    the three precomputed ``esop_plan_cached`` tuples ``(a, b, c)`` for
    tracer coefficients (inside a ``shard_map`` body).
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if any(jnp.iscomplexobj(t) for t in (x4, ca, cb, cc)):
        use_pallas = False
    accum = _norm_accum(accum, x4, ca, cb, cc)
    u, nc, nb, na = x4.shape
    # Validate before padding: post-pad extents can line up by accident and
    # the kernel would silently contract against garbage rows.
    if ca.shape[0] != na or cb.shape[0] != nb or cc.shape[0] != nc:
        raise ValueError(
            f"x4 {x4.shape} incompatible with C_a {ca.shape} (na) / "
            f"C_b {cb.shape} (nb) / C_c {cc.shape} (nc)")
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    kbp, kcp = kb_padded(kb), kb_padded(kc)
    # All three schedules memoized on the coefficient identities: C_a's 2D
    # block compaction, C_b's nb-slab and C_c's nc-slab compactions (each a
    # single block column of the padded slab width).
    counts_a, idx_a, t_a, stats_a = (plans[0] if plans is not None
                                     else esop_plan_cached(ca, bna, bka))
    # counts_b/c are unused in-kernel: the slab streams are single block
    # columns, so every t_b / t_c step is live by construction.
    _cb_counts, idx_b, t_b, stats_b = (plans[1] if plans is not None
                                       else esop_plan_cached(cb, bnb, kbp))
    _cc_counts, idx_c, t_c, stats_c = (plans[2] if plans is not None
                                       else esop_plan_cached(cc, bnc, kcp))
    live_bc = max(stats_b["blocks_live"], 1) * max(stats_c["blocks_live"], 1)
    info = {
        "blocks_dense_a": stats_a["blocks_dense"],
        "blocks_live_a": stats_a["blocks_live"],
        "slabs_dense_b": stats_b["blocks_dense"],
        "slabs_live_b": stats_b["blocks_live"],
        "slabs_dense_c": stats_c["blocks_dense"],
        "slabs_live_c": stats_c["blocks_live"],
        # The streamed grid is the product space (C_a blocks × C_b slabs ×
        # C_c slabs): a dead entry on any axis skips the fetch.
        # blocks_dense/_live use the same keys as esop_gemm so per-call
        # savings aggregate.
        "blocks_dense": (stats_a["blocks_dense"] * stats_b["blocks_dense"]
                         * stats_c["blocks_dense"]),
        "blocks_live": stats_a["blocks_live"] * live_bc,
        "t_steps": (t_a, t_b, t_c),
        "t_steps_dense": (stats_a["t_steps_dense"], stats_b["t_steps_dense"],
                          stats_c["t_steps_dense"]),
    }
    info["fetch_savings"] = 1.0 - (info["blocks_live"]
                                   / max(info["blocks_dense"], 1))

    def prim(x4, ca, cb, cc):
        if not use_pallas:
            return ref.ref_fused3_gemt(x4, ca, cb, cc, accum=accum)
        interpret = not on_tpu()
        xp = _pad_to(x4, (bu, bnc, bnb, bna))
        cap = _pad_to(ca, (bna, bka))
        cbp = _pad_to(cb, (bnb, kbp))
        ccp = _pad_to(cc, (bnc, kcp))
        yk, _ = fused3_gemt_pallas(
            xp, cap, cbp, ccp, bu=bu, bka=bka, bnb=bnb, bnc=bnc, bna=bna,
            interpret=interpret,
            plan=(counts_a, idx_a, t_a, idx_b, t_b, idx_c, t_c),
            accum=accum)
        return yk[:u, :ka, :kb, :kc]

    @jax.custom_vjp
    def f(x4, ca, cb, cc):
        return prim(x4, ca, cb, cc)

    def bwd(res, g):
        x4r, car, cbr, ccr = res
        # dX4 is the whole-transform adjoint — another fused triple over
        # the transposed coefficients, with the (Ka, Kb, Kc) output modes
        # reversed into the kernel's (nc', nb', na') streaming slots.
        gsw = jnp.transpose(g, (0, 3, 2, 1))  # (U, Kc, Kb, Ka)
        if _is_traced(car, cbr, ccr):
            dx4 = ref.ref_fused3_gemt(gsw, jnp.swapaxes(car, 0, 1),
                                      jnp.swapaxes(cbr, 0, 1),
                                      jnp.swapaxes(ccr, 0, 1))
        else:
            dx4, _ = fused3_gemt(gsw, transposed_cached(car),
                                 transposed_cached(cbr),
                                 transposed_cached(ccr), bu=bu,
                                 use_pallas=use_pallas)
        dx4 = jnp.transpose(dx4, (0, 3, 2, 1)).astype(x4r.dtype)
        dca = jnp.einsum("ucba,uklm,bl,cm->ak",
                         x4r, g, cbr, ccr).astype(car.dtype)
        dcb = jnp.einsum("ucba,ak,uklm,cm->bl",
                         x4r, car, g, ccr).astype(cbr.dtype)
        dcc = jnp.einsum("ucba,ak,bl,uklm->cm",
                         x4r, car, cbr, g).astype(ccr.dtype)
        return dx4, dca, dcb, dcc

    f.defvjp(lambda x4, ca, cb, cc: (prim(x4, ca, cb, cc), (x4, ca, cb, cc)),
             bwd)
    return f(x4, ca, cb, cc), info


def chain_gemt(x3: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
               bu: int = 128, bka: int = 128, bnb: int = 32, bna: int = 128,
               use_pallas: bool | None = None, plan_a: tuple | None = None,
               accum: str | None = None):
    """Chain pair ``y, y1 = (X3 ×_a C_a) ×_b C_b`` with the intermediate
    emitted.  Returns ``(y, y1, info)``; layouts ``(U, Ka, Kb)`` /
    ``(U, Nb, Ka)``.

    The backward-walk workhorse: the recompute prefix and the contraction
    that consumes it share one launch, so ``y1`` crosses HBM once as a
    result instead of round-tripping (``kernels/fused_chain.py``).  The b
    stream is dense by construction; a-side ESOP compaction applies.
    ``plan_a`` optionally supplies the precomputed ``esop_plan_cached``
    tuple for a tracer ``ca`` (inside a jitted backward program).  Not
    VJP-wrapped: this op *is* a VJP building block.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if jnp.iscomplexobj(x3) or jnp.iscomplexobj(ca) or jnp.iscomplexobj(cb):
        use_pallas = False
    accum = _norm_accum(accum, x3, ca, cb)
    u, nb, na = x3.shape
    if ca.shape[0] != na or cb.shape[0] != nb:
        raise ValueError(
            f"x3 {x3.shape} incompatible with C_a {ca.shape} (na) / "
            f"C_b {cb.shape} (nb)")
    if use_pallas and plan_a is None and _is_traced(ca):
        use_pallas = False  # no host-readable ESOP schedule for a tracer
    if not use_pallas:
        y, y1 = ref.ref_chain_gemt(x3, ca, cb, accum=accum)
        return y, y1, {"t_steps_dense": (-(-na // bna), nb // bnb)}
    ka, kb = ca.shape[1], cb.shape[1]
    kbp = kb_padded(kb)
    counts_a, idx_a, t_a, stats_a = (plan_a if plan_a is not None
                                     else esop_plan_cached(ca, bna, bka))
    xp = _pad_to(x3, (bu, bnb, bna))
    cap = _pad_to(ca, (bna, bka))
    cbp = _pad_to(cb, (bnb, kbp))
    yk, y1k, _ = chain_gemt_pallas(
        xp, cap, cbp, bu=bu, bka=bka, bnb=bnb, bna=bna,
        interpret=not on_tpu(), plan_a=(counts_a, idx_a, t_a), accum=accum)
    info = {
        "blocks_dense_a": stats_a["blocks_dense"],
        "blocks_live_a": stats_a["blocks_live"],
        "t_steps": (t_a, xp.shape[1] // bnb),
        "t_steps_dense": (stats_a["t_steps_dense"], xp.shape[1] // bnb),
    }
    return yk[:u, :ka, :kb], y1k[:u, :nb, :ka], info


def chain3_gemt(x4: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
                cc: jnp.ndarray, bu: int = 8, bka: int = 128, bnb: int = 16,
                bnc: int = 16, bna: int = 128,
                use_pallas: bool | None = None, plan_a: tuple | None = None,
                accum: str | None = None):
    """Chain triple ``y, y1, y2 = ((X4 ×_a C_a) ×_b C_b) ×_c C_c`` with both
    intermediates emitted.  Returns ``(y, y1, y2, info)``; layouts
    ``(U, Ka, Kb, Kc)`` / ``(U, Nc, Nb, Ka)`` / ``(U, Nc, Ka, Kb)``.

    One launch replaces the staged backward's two recompute launches and
    the cotangent chain's intermediate round-trips.  The b and c streams
    are dense by construction; a-side ESOP compaction applies.  ``plan_a``
    as in :func:`chain_gemt`.  Not VJP-wrapped.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if any(jnp.iscomplexobj(t) for t in (x4, ca, cb, cc)):
        use_pallas = False
    accum = _norm_accum(accum, x4, ca, cb, cc)
    u, nc, nb, na = x4.shape
    if ca.shape[0] != na or cb.shape[0] != nb or cc.shape[0] != nc:
        raise ValueError(
            f"x4 {x4.shape} incompatible with C_a {ca.shape} (na) / "
            f"C_b {cb.shape} (nb) / C_c {cc.shape} (nc)")
    if use_pallas and plan_a is None and _is_traced(ca):
        use_pallas = False
    if not use_pallas:
        y, y1, y2 = ref.ref_chain3_gemt(x4, ca, cb, cc, accum=accum)
        return y, y1, y2, {"t_steps_dense": (-(-na // bna), nb // bnb,
                                             nc // bnc)}
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    kbp, kcp = kb_padded(kb), kb_padded(kc)
    counts_a, idx_a, t_a, stats_a = (plan_a if plan_a is not None
                                     else esop_plan_cached(ca, bna, bka))
    xp = _pad_to(x4, (bu, bnc, bnb, bna))
    cap = _pad_to(ca, (bna, bka))
    cbp = _pad_to(cb, (bnb, kbp))
    ccp = _pad_to(cc, (bnc, kcp))
    yk, y1k, y2k, _ = chain3_gemt_pallas(
        xp, cap, cbp, ccp, bu=bu, bka=bka, bnb=bnb, bnc=bnc, bna=bna,
        interpret=not on_tpu(), plan_a=(counts_a, idx_a, t_a), accum=accum)
    info = {
        "blocks_dense_a": stats_a["blocks_dense"],
        "blocks_live_a": stats_a["blocks_live"],
        "t_steps": (t_a, xp.shape[2] // bnb, xp.shape[1] // bnc),
        "t_steps_dense": (stats_a["t_steps_dense"], xp.shape[2] // bnb,
                          xp.shape[1] // bnc),
    }
    return (yk[:u, :ka, :kb, :kc], y1k[:u, :nc, :nb, :ka],
            y2k[:u, :nc, :ka, :kb], info)


def coeff_grad_batch(as_list, gs_list, br: int = 128,
                     use_pallas: bool | None = None):
    """The three coefficient cotangents ``dC_s = A_sᵀ @ G_s`` in one
    multi-output launch.  ``as_list`` / ``gs_list`` are the per-mode
    unfolded operands ``(R_s, N_s)`` / ``(R_s, K_s)``; returns the list of
    three ``(N_s, K_s)`` cotangents.

    The operands are zero-padded to a common ``(R, N, K)`` envelope and
    stacked on a leading s-axis (zero rows contribute nothing to the
    products), replacing three rank-k SR-GEMM dispatches with a single
    grid ``(3, T_r)`` kernel.  Complex operands route to the einsum
    reference.  Not VJP-wrapped.
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if any(jnp.iscomplexobj(t) for t in (*as_list, *gs_list)):
        use_pallas = False
    rmax = max(a.shape[0] for a in as_list)
    nmax = max(a.shape[1] for a in as_list)
    kmax = max(g.shape[1] for g in gs_list)
    br_eff = min(br, kb_padded(rmax))
    rp = -(-rmax // br_eff) * br_eff
    np_, kp = kb_padded(nmax), kb_padded(kmax)

    def pad2(t, rows, cols):
        return jnp.pad(t, ((0, rows - t.shape[0]), (0, cols - t.shape[1])))

    a = jnp.stack([pad2(t, rp, np_) for t in as_list])
    g = jnp.stack([pad2(t, rp, kp) for t in gs_list])
    if use_pallas:
        out_dtype = jnp.result_type(*(t.dtype for t in (*as_list, *gs_list)))
        dc = coeff_grad_batch_pallas(a, g, br=br_eff,
                                     interpret=not on_tpu(),
                                     out_dtype=out_dtype)
    else:
        dc = ref.ref_coeff_grad_batch(a, g)
    return [dc[i, :as_list[i].shape[1], :gs_list[i].shape[1]]
            for i in range(len(as_list))]


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 128, bkv: int = 128,
                    use_pallas: bool | None = None) -> jnp.ndarray:
    """(B, H, S, D) flash attention; jnp blockwise reference off-TPU by default."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas is False:
        return ref.ref_attention(q, k, v, causal=causal)
    b, h, s, d = q.shape
    fold = lambda t: t.reshape(b * h, s, d)
    y = flash_attention_pallas(fold(q), fold(k), fold(v), bq=bq, bkv=bkv,
                               causal=causal, interpret=not on_tpu())
    return y.reshape(b, h, s, d)
