"""jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, dtype policy, and the CPU/TPU dispatch:
on a TPU backend the kernels run compiled; elsewhere they run in
``interpret=True`` mode (bit-faithful emulation) unless ``use_pallas=False``
routes to the jnp reference (the default inside the big-model dry-run, where
interpret-mode loops would bloat compile times).  Paper anchor: §5–§6
(streaming outer-product cell array + ESOP skipping); the engine-facing
contract is documented in ``docs/engine.md`` ("Lowering").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..memo import ArrayMemo
from . import ref
from .attention import flash_attention_pallas
from .esop_gemm import esop_gemm_pallas, esop_plan
from .fused3_gemt import fused3_gemt_pallas
from .fused_gemt import fused_gemt_pallas, kb_padded
from .sr_gemm import sr_gemm_pallas

__all__ = ["sr_gemm", "esop_gemm", "fused_gemt", "fused3_gemt",
           "flash_attention", "esop_plan_cached", "on_tpu"]

_ESOP_PLAN_MEMO = ArrayMemo()  # per-C-identity padded schedule + block stats


def esop_plan_cached(c: jnp.ndarray, bk: int, bn: int):
    """Padded block-ESOP schedule for C, memoized on C's identity.

    Returns ``(counts, idx, t_steps, stats)``: the scalar-prefetch operands
    as device arrays plus the host-side accounting dict.  The ``esop_plan``
    sweep (a device sync + block compaction) and the host→device upload run
    once per distinct ``(C, block)`` — not once per call — so hot loops
    reusing the same coefficient matrices pay nothing, on the reference
    *and* the Pallas path alike.
    """
    def compute():
        cp = _pad_to(c, (bk, bn))
        counts, idx, t_steps = esop_plan(cp, bk, bn)
        dense_blocks = (cp.shape[0] // bk) * (cp.shape[1] // bn)
        live_blocks = int(counts.sum())
        stats = {
            "blocks_dense": dense_blocks,
            "blocks_live": live_blocks,
            "fetch_savings": 1.0 - live_blocks / max(dense_blocks, 1),
            "t_steps": t_steps,
            "t_steps_dense": cp.shape[0] // bk,
        }
        return jnp.asarray(counts), jnp.asarray(idx), t_steps, stats

    return _ESOP_PLAN_MEMO.get_or_compute(c, (bk, bn), compute)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def sr_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
            bm: int = 128, bn: int = 128, bk: int = 128,
            use_pallas: bool | None = None) -> jnp.ndarray:
    """Y = (out +) X @ C via the streaming outer-product kernel."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if not use_pallas:
        return ref.ref_sr_gemm(x, c, out)
    interpret = not on_tpu()
    m, n = x.shape[0], c.shape[1]
    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(c, (bk, bn))
    op = _pad_to(out, (bm, bn)) if out is not None else None
    y = sr_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n]


def esop_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
              bm: int = 128, bn: int = 128, bk: int = 128,
              use_pallas: bool | None = None, plan: tuple | None = None):
    """Block-ESOP Y = (out +) X @ C skipping zero C blocks. Returns (y, info).

    The block schedule and its accounting are memoized on C's identity
    (``esop_plan_cached``); the reference path reports the same
    streamed-block savings the Pallas kernel realizes.  ``plan`` optionally
    supplies that ``(counts, idx, t_steps, stats)`` tuple precomputed from
    the concrete matrix — required when ``c`` here is a tracer (e.g. a
    replicated operand inside a ``shard_map`` body).
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    counts, idx, t_steps, stats = (plan if plan is not None
                                   else esop_plan_cached(c, bk, bn))
    # dict(stats): the memoized entry is shared across calls — handing the
    # caller the cached object would let an info-dict mutation poison it
    if not use_pallas:
        return ref.ref_esop_gemm(x, c, (bk, bn), out), dict(stats)
    interpret = not on_tpu()
    m, n = x.shape[0], c.shape[1]
    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(c, (bk, bn))
    op = _pad_to(out, (bm, bn)) if out is not None else None
    y, _ = esop_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk,
                            interpret=interpret, plan=(counts, idx, t_steps))
    return y[:m, :n], dict(stats)


def fused_gemt(x3: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
               bu: int = 128, bka: int = 128, bnb: int = 32, bna: int = 128,
               use_pallas: bool | None = None, plans: tuple | None = None):
    """Fused two-stage GEMT ``Y = (X3 ×_a C_a) ×_b C_b``. Returns (y, info).

    ``x3`` is the u-major unfolding ``(U, Nb, Na)`` (``engine.lower``
    produces it); the result is ``(U, Ka, Kb)``.  The stage-a partial
    product never touches HBM — see ``kernels/fused_gemt.py``.  Complex
    coefficients (DFT) route to the einsum reference (the kernel is
    real-valued), with identical accounting.  ``plans`` optionally supplies
    the two precomputed ``esop_plan_cached`` tuples ``(plan_a, plan_b)``
    for tracer ``ca``/``cb`` (inside a ``shard_map`` body).
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if jnp.iscomplexobj(x3) or jnp.iscomplexobj(ca) or jnp.iscomplexobj(cb):
        use_pallas = False
    u, nb, na = x3.shape
    # Validate before padding: post-pad extents can line up by accident and
    # the kernel would silently contract against garbage rows.
    if ca.shape[0] != na or cb.shape[0] != nb:
        raise ValueError(
            f"x3 {x3.shape} incompatible with C_a {ca.shape} (na) / "
            f"C_b {cb.shape} (nb)")
    ka, kb = ca.shape[1], cb.shape[1]
    kbp = kb_padded(kb)
    # Both schedules memoized on the coefficient identities: C_a's 2D block
    # compaction and C_b's nb-slab compaction (one "column" of width kbp).
    counts_a, idx_a, t_a, stats_a = (plans[0] if plans is not None
                                     else esop_plan_cached(ca, bna, bka))
    # counts_b is unused: the slab stream is a single block column, so every
    # t_b step is live by construction — the kernel needs no b-side guard.
    _counts_b, idx_b, t_b, stats_b = (plans[1] if plans is not None
                                      else esop_plan_cached(cb, bnb, kbp))
    info = {
        "blocks_dense_a": stats_a["blocks_dense"],
        "blocks_live_a": stats_a["blocks_live"],
        "slabs_dense_b": stats_b["blocks_dense"],
        "slabs_live_b": stats_b["blocks_live"],
        # The streamed grid is the product space (C_a blocks × C_b slabs):
        # a dead entry on either axis skips the fetch.  blocks_dense/_live
        # use the same keys as esop_gemm so per-call savings aggregate.
        "blocks_dense": stats_a["blocks_dense"] * stats_b["blocks_dense"],
        "blocks_live": stats_a["blocks_live"] * max(stats_b["blocks_live"], 1),
        "t_steps": (t_a, t_b),
        "t_steps_dense": (stats_a["t_steps_dense"], stats_b["t_steps_dense"]),
    }
    info["fetch_savings"] = 1.0 - (info["blocks_live"]
                                   / max(info["blocks_dense"], 1))
    if not use_pallas:
        return ref.ref_fused_gemt(x3, ca, cb), info
    interpret = not on_tpu()
    xp = _pad_to(x3, (bu, bnb, bna))
    cap = _pad_to(ca, (bna, bka))
    cbp = _pad_to(cb, (bnb, kbp))
    y, _ = fused_gemt_pallas(
        xp, cap, cbp, bu=bu, bka=bka, bnb=bnb, bna=bna, interpret=interpret,
        plan=(counts_a, idx_a, t_a, idx_b, t_b))
    return y[:u, :ka, :kb], info


def fused3_gemt(x4: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
                cc: jnp.ndarray, bu: int = 8, bka: int = 128, bnb: int = 16,
                bnc: int = 16, bna: int = 128,
                use_pallas: bool | None = None, plans: tuple | None = None):
    """Whole-transform fused GEMT ``Y = ((X4 ×_a C_a) ×_b C_b) ×_c C_c``.
    Returns (y, info).

    ``x4`` is the u-major unfolding ``(U, Nc, Nb, Na)`` (``engine.lower``
    produces it; U is the folded batch); the result is ``(U, Ka, Kb, Kc)``.
    Neither intermediate ever touches HBM — see ``kernels/fused3_gemt.py``.
    Complex coefficients (DFT) route to the einsum reference (the kernel is
    real-valued), with identical accounting.  ``plans`` optionally supplies
    the three precomputed ``esop_plan_cached`` tuples ``(a, b, c)`` for
    tracer coefficients (inside a ``shard_map`` body).
    """
    if use_pallas is None:
        use_pallas = on_tpu()
    if any(jnp.iscomplexobj(t) for t in (x4, ca, cb, cc)):
        use_pallas = False
    u, nc, nb, na = x4.shape
    # Validate before padding: post-pad extents can line up by accident and
    # the kernel would silently contract against garbage rows.
    if ca.shape[0] != na or cb.shape[0] != nb or cc.shape[0] != nc:
        raise ValueError(
            f"x4 {x4.shape} incompatible with C_a {ca.shape} (na) / "
            f"C_b {cb.shape} (nb) / C_c {cc.shape} (nc)")
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    kbp, kcp = kb_padded(kb), kb_padded(kc)
    # All three schedules memoized on the coefficient identities: C_a's 2D
    # block compaction, C_b's nb-slab and C_c's nc-slab compactions (each a
    # single block column of the padded slab width).
    counts_a, idx_a, t_a, stats_a = (plans[0] if plans is not None
                                     else esop_plan_cached(ca, bna, bka))
    # counts_b/c are unused in-kernel: the slab streams are single block
    # columns, so every t_b / t_c step is live by construction.
    _cb_counts, idx_b, t_b, stats_b = (plans[1] if plans is not None
                                       else esop_plan_cached(cb, bnb, kbp))
    _cc_counts, idx_c, t_c, stats_c = (plans[2] if plans is not None
                                       else esop_plan_cached(cc, bnc, kcp))
    live_bc = max(stats_b["blocks_live"], 1) * max(stats_c["blocks_live"], 1)
    info = {
        "blocks_dense_a": stats_a["blocks_dense"],
        "blocks_live_a": stats_a["blocks_live"],
        "slabs_dense_b": stats_b["blocks_dense"],
        "slabs_live_b": stats_b["blocks_live"],
        "slabs_dense_c": stats_c["blocks_dense"],
        "slabs_live_c": stats_c["blocks_live"],
        # The streamed grid is the product space (C_a blocks × C_b slabs ×
        # C_c slabs): a dead entry on any axis skips the fetch.
        # blocks_dense/_live use the same keys as esop_gemm so per-call
        # savings aggregate.
        "blocks_dense": (stats_a["blocks_dense"] * stats_b["blocks_dense"]
                         * stats_c["blocks_dense"]),
        "blocks_live": stats_a["blocks_live"] * live_bc,
        "t_steps": (t_a, t_b, t_c),
        "t_steps_dense": (stats_a["t_steps_dense"], stats_b["t_steps_dense"],
                          stats_c["t_steps_dense"]),
    }
    info["fetch_savings"] = 1.0 - (info["blocks_live"]
                                   / max(info["blocks_dense"], 1))
    if not use_pallas:
        return ref.ref_fused3_gemt(x4, ca, cb, cc), info
    interpret = not on_tpu()
    xp = _pad_to(x4, (bu, bnc, bnb, bna))
    cap = _pad_to(ca, (bna, bka))
    cbp = _pad_to(cb, (bnb, kbp))
    ccp = _pad_to(cc, (bnc, kcp))
    y, _ = fused3_gemt_pallas(
        xp, cap, cbp, ccp, bu=bu, bka=bka, bnb=bnb, bnc=bnc, bna=bna,
        interpret=interpret,
        plan=(counts_a, idx_a, t_a, idx_b, t_b, idx_c, t_c))
    return y[:u, :ka, :kb, :kc], info


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 128, bkv: int = 128,
                    use_pallas: bool | None = None) -> jnp.ndarray:
    """(B, H, S, D) flash attention; jnp blockwise reference off-TPU by default."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas is False:
        return ref.ref_attention(q, k, v, causal=causal)
    b, h, s, d = q.shape
    fold = lambda t: t.reshape(b * h, s, d)
    y = flash_attention_pallas(fold(q), fold(k), fold(v), bq=bq, bkv=bkv,
                               causal=causal, interpret=not on_tpu())
    return y.reshape(b, h, s, d)
