"""jit'd public wrappers around the Pallas kernels.

Handle padding to block multiples, dtype policy, and the CPU/TPU dispatch:
on a TPU backend the kernels run compiled; elsewhere they run in
``interpret=True`` mode (bit-faithful emulation) unless ``use_pallas=False``
routes to the jnp reference (the default inside the big-model dry-run, where
interpret-mode loops would bloat compile times — see DESIGN.md §6).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..memo import ArrayMemo
from . import ref
from .attention import flash_attention_pallas
from .esop_gemm import esop_gemm_pallas, esop_plan
from .sr_gemm import sr_gemm_pallas

__all__ = ["sr_gemm", "esop_gemm", "flash_attention", "on_tpu"]

_ESOP_INFO_MEMO = ArrayMemo()  # per-C-identity block stats (host-side loop)


def _esop_ref_info(c: jnp.ndarray, bk: int, bn: int) -> dict:
    """Block-ESOP accounting for the reference path, memoized on C.

    The stats only depend on C's zero structure; recomputing the host-side
    ``esop_plan`` loop per call would dominate small GEMMs and skew
    autotune timings.
    """
    def compute():
        cp = _pad_to(c, (bk, bn))
        counts, _idx, t_steps = esop_plan(cp, bk, bn)
        dense_blocks = (cp.shape[0] // bk) * (cp.shape[1] // bn)
        live_blocks = int(counts.sum())
        return {
            "blocks_dense": dense_blocks,
            "blocks_live": live_blocks,
            "fetch_savings": 1.0 - live_blocks / max(dense_blocks, 1),
            "t_steps": t_steps,
            "t_steps_dense": cp.shape[0] // bk,
        }

    return _ESOP_INFO_MEMO.get_or_compute(c, (bk, bn), compute)


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x: jnp.ndarray, mults: tuple[int, ...]) -> jnp.ndarray:
    pads = [(0, (-d) % m) for d, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def sr_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
            bm: int = 128, bn: int = 128, bk: int = 128,
            use_pallas: bool | None = None) -> jnp.ndarray:
    """Y = (out +) X @ C via the streaming outer-product kernel."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if not use_pallas and not on_tpu():
        interpret = True
    else:
        interpret = not on_tpu()
    if use_pallas is False:
        return ref.ref_sr_gemm(x, c, out)
    m, n = x.shape[0], c.shape[1]
    o = out if out is not None else jnp.zeros((m, n), dtype=x.dtype)
    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(c, (bk, bn))
    op = _pad_to(o, (bm, bn))
    y = sr_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:m, :n]


def esop_gemm(x: jnp.ndarray, c: jnp.ndarray, out: jnp.ndarray | None = None,
              bm: int = 128, bn: int = 128, bk: int = 128,
              use_pallas: bool | None = None):
    """Block-ESOP Y = (out +) X @ C skipping zero C blocks. Returns (y, info)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas is False:
        # Backend-independent accounting: the reference path reports the same
        # streamed-block savings the Pallas kernel would realize.
        return ref.ref_esop_gemm(x, c, (bk, bn), out), _esop_ref_info(c, bk, bn)
    interpret = not on_tpu()
    m, n = x.shape[0], c.shape[1]
    o = out if out is not None else jnp.zeros((m, n), dtype=x.dtype)
    xp = _pad_to(x, (bm, bk))
    cp = _pad_to(c, (bk, bn))
    op = _pad_to(o, (bm, bn))
    y, info = esop_gemm_pallas(xp, cp, op, bm=bm, bn=bn, bk=bk,
                               interpret=interpret)
    return y[:m, :n], info


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, bq: int = 128, bkv: int = 128,
                    use_pallas: bool | None = None) -> jnp.ndarray:
    """(B, H, S, D) flash attention; jnp blockwise reference off-TPU by default."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if use_pallas is False:
        return ref.ref_attention(q, k, v, causal=causal)
    b, h, s, d = q.shape
    fold = lambda t: t.reshape(b * h, s, d)
    y = flash_attention_pallas(fold(q), fold(k), fold(v), bq=bq, bkv=bkv,
                               causal=causal, interpret=not on_tpu())
    return y.reshape(b, h, s, d)
