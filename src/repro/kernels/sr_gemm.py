"""SR-GEMM — the paper's output-stationary streaming outer-product kernel (§5.1).

TPU-native adaptation of the TriADA cell-array dataflow:

  * the output tile (and, through chaining, the resident tensor slice) stays
    **stationary in VMEM scratch** across the whole contraction — the Tensor
    Core cells of the paper;
  * the coefficient matrix C is **streamed** HBM→VMEM block-by-block along
    the innermost grid dimension — the Decoupled Active Streaming Memory
    ("Actuator") of the paper;
  * each grid step applies a rank-``bk`` update (``x_blk @ c_blk``) — the
    MXU-granular analogue of the paper's rank-1 time-step; one stage of
    N_s/bk grid steps realizes the rank-N_s update of Eq. (6);
  * the affine ``+=`` of Eq. (1) is supported by seeding the accumulator
    from an aliased output operand.

Block shapes default to MXU-aligned (128, 128, 128); fp32 accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sr_gemm_kernel", "sr_gemm_pallas"]


def sr_gemm_kernel(*refs, k_steps: int, affine: bool, accum: str = "plain"):
    """One (i, j) output tile; grid dim 2 streams C's contraction blocks.

    ``accum="compensated"`` carries a second VMEM scratch (``comp_ref``)
    holding the Neumaier compensation: the low-order bits lost by each
    ``acc + p`` rank-update are banked there and folded back at the flush,
    so the reduction error stops growing with ``k_steps``
    (``docs/numerics.md``).  ``"f32"`` needs no kernel change — it is the
    same fp32 accumulator with a float32 ``o_ref`` (no downcast).
    """
    compensated = accum == "compensated"
    if compensated:
        *refs, comp_ref = refs
    if affine:
        o_init_ref, x_ref, c_ref, o_ref, acc_ref = refs
    else:
        x_ref, c_ref, o_ref, acc_ref = refs
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        # Affine += (Eq. 1) seeds the accumulator from the prior (aliased)
        # output; the plain product starts at zero in-kernel — no HBM seed
        # buffer is ever allocated or fetched.
        acc_ref[...] = (o_init_ref[...].astype(acc_ref.dtype) if affine
                        else jnp.zeros(acc_ref.shape, acc_ref.dtype))
        if compensated:
            comp_ref[...] = jnp.zeros(comp_ref.shape, comp_ref.dtype)

    # Rank-bk update: the streamed coefficient block crosses the resident
    # data block exactly like the paper's (column-vector ∘ row-vector) step.
    p = jnp.dot(x_ref[...], c_ref[...], preferred_element_type=jnp.float32)
    if compensated:
        acc = acc_ref[...]
        t = acc + p
        comp_ref[...] += jnp.where(jnp.abs(acc) >= jnp.abs(p),
                                   (acc - t) + p, (p - t) + acc)
        acc_ref[...] = t
    else:
        acc_ref[...] += p

    @pl.when(k == k_steps - 1)
    def _flush():
        flushed = acc_ref[...] + comp_ref[...] if compensated else acc_ref[...]
        o_ref[...] = flushed.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bm", "bn", "bk", "interpret", "accum")
)
def sr_gemm_pallas(
    x: jnp.ndarray,
    c: jnp.ndarray,
    out: jnp.ndarray | None = None,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
    interpret: bool = False,
    accum: str = "plain",
) -> jnp.ndarray:
    """Y = (out +) X @ C with X: (M, K), C: (K, N), out: (M, N) or None.

    Shapes must be multiples of the block shape (``ops.sr_gemm`` pads).
    ``out=None`` initializes the accumulator to zero in-kernel; an affine
    seed is only streamed (and aliased) when actually provided.  Promoted
    ``accum`` modes flush in float32 (``"compensated"`` adds the Neumaier
    scratch — one extra f32 output tile of VMEM, folded into the planner's
    footprint ladders).
    """
    m, kdim = x.shape
    k2, n = c.shape
    assert kdim == k2, (x.shape, c.shape)
    assert out is None or out.shape == (m, n)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (x.shape, c.shape, (bm, bn, bk))
    k_steps = kdim // bk
    affine = out is not None
    out_dtype = (jnp.float32 if accum != "plain"
                 else (out.dtype if affine else x.dtype))
    scratch = [pltpu.VMEM((bm, bn), jnp.float32)]  # stationary tile
    if accum == "compensated":
        scratch.append(pltpu.VMEM((bm, bn), jnp.float32))  # Neumaier comp

    grid = (m // bm, n // bn, k_steps)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # resident X
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # streamed C
    ]
    operands = [x, c]
    if affine:
        in_specs.insert(0, pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)))
        operands.insert(0, out)  # o_init (aliased)
    return pl.pallas_call(
        functools.partial(sr_gemm_kernel, k_steps=k_steps, affine=affine,
                          accum=accum),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=scratch,
        input_output_aliases=(
            {0: 0} if affine and out_dtype == out.dtype else {}),
        interpret=interpret,
    )(*operands)
