"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle is the bit-level specification its kernel is tested against
(paper §5–§6 algorithms in plain jnp); off-TPU ``use_pallas=False``
dispatch in ``ops.py`` runs these in production too.  See
``docs/engine.md`` ("Lowering").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["ref_sr_gemm", "ref_esop_gemm", "ref_fused_gemt",
           "ref_fused3_gemt", "ref_chain_gemt", "ref_chain3_gemt",
           "ref_coeff_grad_batch", "ref_attention"]

# K-chunk width of the compensated reference reduction — mirrors the
# kernels' bk streaming granularity (docs/numerics.md).
_NEUMAIER_CHUNK = 64


def _accum_out_dtype(dtype, accum: str):
    """Flush dtype under an accumulation mode (kernel-local mirror of
    ``engine.numerics.accum_out_dtype`` — kernels stay engine-free)."""
    dtype = jnp.dtype(dtype)
    if accum == "plain" or jnp.issubdtype(dtype, jnp.complexfloating):
        return dtype
    if jnp.issubdtype(dtype, jnp.floating) and dtype.itemsize < 4:
        return jnp.dtype(jnp.float32)
    return dtype


def _promoted(accum: str, *operands) -> bool:
    """True when ``accum`` promotes these operands (real, non-plain)."""
    return accum != "plain" and not any(
        jnp.issubdtype(o.dtype, jnp.complexfloating) for o in operands)


def _neumaier_matmul(a: jnp.ndarray, b: jnp.ndarray,
                     out: jnp.ndarray | None = None) -> jnp.ndarray:
    """f32 matmul with a Neumaier-compensated reduction across K chunks.

    Each ``_NEUMAIER_CHUNK``-wide slab is a plain f32 dot; the slabs are
    folded with Neumaier's update — the lost low-order bits of every
    ``acc + p`` ride in ``comp`` and are added back at the flush, so the
    reduction error is independent of K (the reference-path analogue of
    the kernels' comp scratch).  Shapes are static, so the python loop
    unrolls under jit.
    """
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    k = a.shape[1]
    if out is None:
        acc = jnp.zeros((a.shape[0], b.shape[1]), jnp.float32)
    else:
        acc = out.astype(jnp.float32)
    comp = jnp.zeros_like(acc)
    for s in range(0, k, _NEUMAIER_CHUNK):
        p = jnp.dot(a[:, s:s + _NEUMAIER_CHUNK], b[s:s + _NEUMAIER_CHUNK, :])
        t = acc + p
        comp = comp + jnp.where(jnp.abs(acc) >= jnp.abs(p),
                                (acc - t) + p, (p - t) + acc)
        acc = t
    return acc + comp


def _ref_matmul(a: jnp.ndarray, b: jnp.ndarray, accum: str,
                out: jnp.ndarray | None = None) -> jnp.ndarray:
    """One f32 contraction under an accumulation mode (f32 in, f32 out)."""
    if accum == "compensated":
        return _neumaier_matmul(a, b, out=out)
    y = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    if out is not None:
        y = y + out.astype(jnp.float32)
    return y


def ref_sr_gemm(x: jnp.ndarray, c: jnp.ndarray,
                out: jnp.ndarray | None = None,
                accum: str = "plain") -> jnp.ndarray:
    """Oracle for the streaming outer-product SR-GEMM: Y (+)= X @ C.

    ``accum`` selects the flush: ``"plain"`` rounds back to the operand
    dtype, ``"f32"``/``"compensated"`` keep float32 (the latter with the
    Neumaier-compensated chunk reduction).  See ``docs/numerics.md``.
    """
    if _promoted(accum, x, c):
        y = _ref_matmul(x, c, accum, out=out)
        return y.astype(_accum_out_dtype(x.dtype, accum))
    y = jnp.dot(x.astype(jnp.float32), c.astype(jnp.float32))
    if out is not None:
        y = y + out.astype(jnp.float32)
    return y.astype(x.dtype)


def ref_esop_gemm(x: jnp.ndarray, c: jnp.ndarray,
                  block: tuple[int, int],
                  out: jnp.ndarray | None = None,
                  accum: str = "plain") -> jnp.ndarray:
    """Oracle for block-ESOP: identical to SR-GEMM with *block-zeroed* C.

    Zero blocks of C contribute nothing; the kernel skips them.  Because
    skipped blocks are exactly zero, the oracle is just the dense product.
    """
    del block  # exactness of zero-skipping: dense result is the oracle
    return ref_sr_gemm(x, c, out=out, accum=accum)


@functools.partial(jax.jit, static_argnames=("accum",))
def ref_fused_gemt(x3: jnp.ndarray, ca: jnp.ndarray,
                   cb: jnp.ndarray, accum: str = "plain") -> jnp.ndarray:
    """Oracle for the fused two-stage GEMT (u-major layout).

    ``Y[u, ka, kb] = Σ_nb Σ_na X3[u, nb, na] · C_a[na, ka] · C_b[nb, kb]``
    as two flat GEMMs under one jit, so the stage-a partial only exists
    inside the compiled computation — the reference-path analogue of the
    kernel's VMEM-resident intermediate.  (The explicit two-step form beats
    the equivalent three-operand einsum on CPU by ~1.7× at serving sizes.)
    Handles complex dtypes (DFT stages).  Promoted ``accum`` modes run
    both GEMMs in f32 (Neumaier-compensated when ``"compensated"``) and
    flush in float32.
    """
    u, nb, na = x3.shape
    ka, kb = ca.shape[1], cb.shape[1]
    if _promoted(accum, x3, ca, cb):
        p = _ref_matmul(x3.reshape(u * nb, na), ca, accum).reshape(u, nb, ka)
        y = _ref_matmul(jnp.swapaxes(p, 1, 2).reshape(u * ka, nb), cb, accum)
        return y.reshape(u, ka, kb).astype(_accum_out_dtype(x3.dtype, accum))
    p = (x3.reshape(u * nb, na) @ ca).reshape(u, nb, ka)
    return (jnp.swapaxes(p, 1, 2).reshape(u * ka, nb) @ cb).reshape(u, ka, kb)


@functools.partial(jax.jit, static_argnames=("accum",))
def ref_fused3_gemt(x4: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
                    cc: jnp.ndarray, accum: str = "plain") -> jnp.ndarray:
    """Oracle for the whole-transform fused GEMT (u-major layout).

    ``Y[u,ka,kb,kc] = Σ_nc Σ_nb Σ_na X4[u,nc,nb,na]·C_a·C_b·C_c`` as three
    flat GEMMs under one jit, so neither intermediate ever exists outside
    the compiled computation — the reference-path analogue of the
    megakernel's two VMEM-resident partials.  Handles complex dtypes
    (DFT stages); promoted ``accum`` modes as in :func:`ref_fused_gemt`.
    """
    u, nc, nb, na = x4.shape
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    if _promoted(accum, x4, ca, cb, cc):
        p1 = _ref_matmul(x4.reshape(u * nc * nb, na), ca,
                         accum).reshape(u, nc, nb, ka)
        p2 = _ref_matmul(jnp.swapaxes(p1, 2, 3).reshape(u * nc * ka, nb),
                         cb, accum).reshape(u, nc, ka, kb)
        y = _ref_matmul(jnp.moveaxis(p2, 1, 3).reshape(u * ka * kb, nc),
                        cc, accum)
        return y.reshape(u, ka, kb, kc).astype(
            _accum_out_dtype(x4.dtype, accum))
    p1 = (x4.reshape(u * nc * nb, na) @ ca).reshape(u, nc, nb, ka)
    p2 = (jnp.swapaxes(p1, 2, 3).reshape(u * nc * ka, nb)
          @ cb).reshape(u, nc, ka, kb)
    return (jnp.moveaxis(p2, 1, 3).reshape(u * ka * kb, nc)
            @ cc).reshape(u, ka, kb, kc)


@functools.partial(jax.jit, static_argnames=("accum",))
def ref_chain_gemt(x3: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
                   accum: str = "plain") -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the chain pair: fused result *plus* the emitted
    intermediate ``y1 = X ×_a C_a`` in its ``(U, Nb, Ka)`` layout.
    Promoted ``accum`` modes emit both in float32."""
    u, nb, na = x3.shape
    ka, kb = ca.shape[1], cb.shape[1]
    if _promoted(accum, x3, ca, cb):
        odt = _accum_out_dtype(x3.dtype, accum)
        p = _ref_matmul(x3.reshape(u * nb, na), ca, accum).reshape(u, nb, ka)
        y = _ref_matmul(jnp.swapaxes(p, 1, 2).reshape(u * ka, nb), cb, accum)
        return y.reshape(u, ka, kb).astype(odt), p.astype(odt)
    p = (x3.reshape(u * nb, na) @ ca).reshape(u, nb, ka)
    y = (jnp.swapaxes(p, 1, 2).reshape(u * ka, nb) @ cb).reshape(u, ka, kb)
    return y, p


@functools.partial(jax.jit, static_argnames=("accum",))
def ref_chain3_gemt(
        x4: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
        cc: jnp.ndarray, accum: str = "plain"
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for the chain triple: fused result plus both emitted
    intermediates ``y1 (U, Nc, Nb, Ka)`` and ``y2 (U, Nc, Ka, Kb)``.
    Promoted ``accum`` modes emit all three in float32."""
    u, nc, nb, na = x4.shape
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    if _promoted(accum, x4, ca, cb, cc):
        odt = _accum_out_dtype(x4.dtype, accum)
        p1 = _ref_matmul(x4.reshape(u * nc * nb, na), ca,
                         accum).reshape(u, nc, nb, ka)
        p2 = _ref_matmul(jnp.swapaxes(p1, 2, 3).reshape(u * nc * ka, nb),
                         cb, accum).reshape(u, nc, ka, kb)
        y = _ref_matmul(jnp.moveaxis(p2, 1, 3).reshape(u * ka * kb, nc),
                        cc, accum)
        return (y.reshape(u, ka, kb, kc).astype(odt),
                p1.astype(odt), p2.astype(odt))
    p1 = (x4.reshape(u * nc * nb, na) @ ca).reshape(u, nc, nb, ka)
    p2 = (jnp.swapaxes(p1, 2, 3).reshape(u * nc * ka, nb)
          @ cb).reshape(u, nc, ka, kb)
    y = (jnp.moveaxis(p2, 1, 3).reshape(u * ka * kb, nc)
         @ cc).reshape(u, ka, kb, kc)
    return y, p1, p2


@jax.jit
def ref_coeff_grad_batch(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the batched coefficient cotangent:
    ``dC[s] = A[s]ᵀ @ G[s]`` over the stacked ``(S, R, N)``/``(S, R, K)``
    operands, f32 accumulation.  Handles complex dtypes."""
    out_dtype = jnp.result_type(a.dtype, g.dtype)
    if jnp.issubdtype(out_dtype, jnp.complexfloating):
        return jnp.einsum("srn,srk->snk", a, g).astype(out_dtype)
    return jnp.einsum("srn,srk->snk", a.astype(jnp.float32),
                      g.astype(jnp.float32)).astype(out_dtype)


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Oracle for flash attention: q,k,v are (B, H, S, D); returns (B, H, S, D)."""
    s = q.shape[-2]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
