"""Pure-jnp oracles for every Pallas kernel in this package.

Each oracle is the bit-level specification its kernel is tested against
(paper §5–§6 algorithms in plain jnp); off-TPU ``use_pallas=False``
dispatch in ``ops.py`` runs these in production too.  See
``docs/engine.md`` ("Lowering").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["ref_sr_gemm", "ref_esop_gemm", "ref_fused_gemt",
           "ref_fused3_gemt", "ref_chain_gemt", "ref_chain3_gemt",
           "ref_coeff_grad_batch", "ref_attention"]


def ref_sr_gemm(x: jnp.ndarray, c: jnp.ndarray,
                out: jnp.ndarray | None = None) -> jnp.ndarray:
    """Oracle for the streaming outer-product SR-GEMM: Y (+)= X @ C."""
    y = jnp.dot(x.astype(jnp.float32), c.astype(jnp.float32))
    if out is not None:
        y = y + out.astype(jnp.float32)
    return y.astype(x.dtype)


def ref_esop_gemm(x: jnp.ndarray, c: jnp.ndarray,
                  block: tuple[int, int],
                  out: jnp.ndarray | None = None) -> jnp.ndarray:
    """Oracle for block-ESOP: identical to SR-GEMM with *block-zeroed* C.

    Zero blocks of C contribute nothing; the kernel skips them.  Because
    skipped blocks are exactly zero, the oracle is just the dense product.
    """
    del block  # exactness of zero-skipping: dense result is the oracle
    return ref_sr_gemm(x, c, out=out)


@jax.jit
def ref_fused_gemt(x3: jnp.ndarray, ca: jnp.ndarray,
                   cb: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the fused two-stage GEMT (u-major layout).

    ``Y[u, ka, kb] = Σ_nb Σ_na X3[u, nb, na] · C_a[na, ka] · C_b[nb, kb]``
    as two flat GEMMs under one jit, so the stage-a partial only exists
    inside the compiled computation — the reference-path analogue of the
    kernel's VMEM-resident intermediate.  (The explicit two-step form beats
    the equivalent three-operand einsum on CPU by ~1.7× at serving sizes.)
    Handles complex dtypes (DFT stages).
    """
    u, nb, na = x3.shape
    ka, kb = ca.shape[1], cb.shape[1]
    p = (x3.reshape(u * nb, na) @ ca).reshape(u, nb, ka)
    return (jnp.swapaxes(p, 1, 2).reshape(u * ka, nb) @ cb).reshape(u, ka, kb)


@jax.jit
def ref_fused3_gemt(x4: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
                    cc: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the whole-transform fused GEMT (u-major layout).

    ``Y[u,ka,kb,kc] = Σ_nc Σ_nb Σ_na X4[u,nc,nb,na]·C_a·C_b·C_c`` as three
    flat GEMMs under one jit, so neither intermediate ever exists outside
    the compiled computation — the reference-path analogue of the
    megakernel's two VMEM-resident partials.  Handles complex dtypes
    (DFT stages).
    """
    u, nc, nb, na = x4.shape
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    p1 = (x4.reshape(u * nc * nb, na) @ ca).reshape(u, nc, nb, ka)
    p2 = (jnp.swapaxes(p1, 2, 3).reshape(u * nc * ka, nb)
          @ cb).reshape(u, nc, ka, kb)
    return (jnp.moveaxis(p2, 1, 3).reshape(u * ka * kb, nc)
            @ cc).reshape(u, ka, kb, kc)


@jax.jit
def ref_chain_gemt(x3: jnp.ndarray, ca: jnp.ndarray,
                   cb: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for the chain pair: fused result *plus* the emitted
    intermediate ``y1 = X ×_a C_a`` in its ``(U, Nb, Ka)`` layout."""
    u, nb, na = x3.shape
    ka, kb = ca.shape[1], cb.shape[1]
    p = (x3.reshape(u * nb, na) @ ca).reshape(u, nb, ka)
    y = (jnp.swapaxes(p, 1, 2).reshape(u * ka, nb) @ cb).reshape(u, ka, kb)
    return y, p


@jax.jit
def ref_chain3_gemt(
        x4: jnp.ndarray, ca: jnp.ndarray, cb: jnp.ndarray,
        cc: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for the chain triple: fused result plus both emitted
    intermediates ``y1 (U, Nc, Nb, Ka)`` and ``y2 (U, Nc, Ka, Kb)``."""
    u, nc, nb, na = x4.shape
    ka, kb, kc = ca.shape[1], cb.shape[1], cc.shape[1]
    p1 = (x4.reshape(u * nc * nb, na) @ ca).reshape(u, nc, nb, ka)
    p2 = (jnp.swapaxes(p1, 2, 3).reshape(u * nc * ka, nb)
          @ cb).reshape(u, nc, ka, kb)
    y = (jnp.moveaxis(p2, 1, 3).reshape(u * ka * kb, nc)
         @ cc).reshape(u, ka, kb, kc)
    return y, p1, p2


@jax.jit
def ref_coeff_grad_batch(a: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the batched coefficient cotangent:
    ``dC[s] = A[s]ᵀ @ G[s]`` over the stacked ``(S, R, N)``/``(S, R, K)``
    operands, f32 accumulation.  Handles complex dtypes."""
    out_dtype = jnp.result_type(a.dtype, g.dtype)
    if jnp.issubdtype(out_dtype, jnp.complexfloating):
        return jnp.einsum("srn,srk->snk", a, g).astype(out_dtype)
    return jnp.einsum("srn,srk->snk", a.astype(jnp.float32),
                      g.astype(jnp.float32)).astype(out_dtype)


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """Oracle for flash attention: q,k,v are (B, H, S, D); returns (B, H, S, D)."""
    s = q.shape[-2]
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask, logits, -jnp.inf)
    p = jnp.exp(logits - jnp.max(logits, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
