"""Blockwise (flash) attention forward kernel — perf-critical LM substrate.

Output-stationary in the same sense as SR-GEMM: the (q-block × head-dim)
output tile and the running softmax statistics stay in VMEM scratch while
K/V blocks are streamed along the innermost grid dimension.  Causal blocks
strictly above the diagonal are skipped with ``pl.when`` (no MACs; on real
TPU the fetch is also elided for fully-masked blocks via the same
scalar-prefetch technique as the ESOP kernel — kept simple here).

Layout: q, k, v are (B*H, S, D); grid = (B*H, S/bq, S/bkv).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_pallas"]

_NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, kv_steps: int, bq: int, bkv: int, scale: float,
                  causal: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _update():
        q = q_ref[0]  # (bq, d)
        k = k_ref[0]  # (bkv, d)
        v = v_ref[0]  # (bkv, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
            k_pos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
            s = jnp.where(q_pos >= k_pos, s, _NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    if causal:
        # Skip blocks strictly above the diagonal: all their MACs are masked.
        pl.when(ki * bkv <= qi * bq + (bq - 1))(_update)
    else:
        _update()

    @pl.when(ki == kv_steps - 1)
    def _flush():
        o_ref[0] = (acc_ref[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "causal", "interpret"))
def flash_attention_pallas(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    bq: int = 128,
    bkv: int = 128,
    causal: bool = True,
    interpret: bool = False,
) -> jnp.ndarray:
    """q, k, v: (BH, S, D) -> (BH, S, D).  S divisible by bq and bkv."""
    bh, s, d = q.shape
    assert k.shape == v.shape == (bh, s, d)
    assert s % bq == 0 and s % bkv == 0
    kv_steps = s // bkv
    grid = (bh, s // bq, kv_steps)
    scale = d ** -0.5
    return pl.pallas_call(
        functools.partial(_flash_kernel, kv_steps=kv_steps, bq=bq, bkv=bkv,
                          scale=scale, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
            pltpu.VMEM((bq, d), jnp.float32),    # stationary output tile
        ],
        interpret=interpret,
    )(q, k, v)
