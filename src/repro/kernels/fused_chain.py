"""Fused adjoint-chain kernels — intermediates *emitted*, not discarded.

The forward fused kernels (``fused_gemt.py`` / ``fused3_gemt.py``) keep the
inter-stage partials in VMEM scratch and throw them away once consumed —
exactly right for inference, exactly wrong for the backward pass: the VJP
needs ``y1 = X ×_a C_a`` (and ``y2`` for the triple) as the left operands
of the coefficient cotangents ``dC_s = unfold(y)ᵀ @ unfold(g)``.  The
staged backward therefore recomputes the chain prefix with separate
launches and full HBM round-trips, which is where the 3x backward gap
lives.

These kernels run the same fused dataflow but *also* write each completed
VMEM partial to an extra output the moment it is finalized, so one launch
yields the contraction result **and** every intermediate the adjoint will
contract against — the intermediate crosses HBM exactly once, as a result,
never as a round-trip.

Two structural differences from the forward kernels:

* the b (and c) coefficient streams must be **dense**: every streamed slab
  owns a block of the emitted intermediate, and an ESOP-skipped slab would
  leave its ``y1``/``y2`` block unwritten (``y1`` does not involve ``C_b``,
  so a dead ``C_b`` slab still carries nonzero ``y1``).  The a-side ESOP
  compaction stays: dead ``C_a`` blocks contribute exactly zero to every
  partial, so skipping them changes nothing that is emitted.
* ``pallas_call`` is multi-output: each intermediate gets its own
  BlockSpec whose index map revisits a block only while it is still being
  accumulated, and the write is guarded to the step that completes it.

``coeff_grad_batch_kernel`` is the companion: the three rank-k coefficient
cotangents ``dC_s = A_sᵀ G_s`` stacked on a leading s-axis and reduced in
one launch — grid ``(3, T_r)`` with a shared f32 accumulator, replacing
three separate SR-GEMM dispatches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .esop_gemm import esop_plan

__all__ = [
    "chain_gemt_kernel", "chain_gemt_pallas",
    "chain3_gemt_kernel", "chain3_gemt_pallas",
    "coeff_grad_batch_kernel", "coeff_grad_batch_pallas",
]


def dense_slab_plan(n: int, bn: int):
    """Identity streaming schedule: every slab live, in natural order."""
    t = n // bn
    idx = jnp.arange(t, dtype=jnp.int32).reshape(1, t)
    return idx, t


def chain_gemt_kernel(counts_a_ref, idx_a_ref, idx_b_ref, x_ref, ca_ref,
                      cb_ref, o_ref, o1_ref, p_ref, acc_ref, *scratch,
                      t_a: int, t_b: int, accum: str = "plain"):
    """Fused pair with the stage-a partial emitted as a second output.

    ``accum="compensated"`` Neumaier-compensates the t_b reduction into
    the output accumulator, like ``fused_gemt_kernel``; the emitted
    intermediate needs none (its accumulation restarts every slab).
    """
    compensated = accum == "compensated"
    comp_ref = scratch[0] if compensated else None
    j = pl.program_id(1)
    tb = pl.program_id(2)
    ta = pl.program_id(3)

    @pl.when((tb == 0) & (ta == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
        if compensated:
            comp_ref[...] = jnp.zeros(comp_ref.shape, comp_ref.dtype)

    @pl.when(ta == 0)
    def _init_partial():
        p_ref[...] = jnp.zeros(p_ref.shape, p_ref.dtype)

    @pl.when(ta < counts_a_ref[j])
    def _stage_a():
        x = x_ref[...]  # (bu, bnb, bna)
        bu, bnb, bna = x.shape
        p = jnp.dot(x.reshape(bu * bnb, bna), ca_ref[...],
                    preferred_element_type=jnp.float32)
        p_ref[...] += p.reshape(bu, bnb, p.shape[-1])

    @pl.when(ta == t_a - 1)
    def _stage_b():
        p = jax.lax.dot_general(
            p_ref[...], cb_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if compensated:
            acc = acc_ref[...]
            tot = acc + p
            comp_ref[...] += jnp.where(jnp.abs(acc) >= jnp.abs(p),
                                       (acc - tot) + p, (p - tot) + acc)
            acc_ref[...] = tot
        else:
            acc_ref[...] += p

    # The completed partial IS y1 for this (i, tb, j) block — emit it.
    @pl.when(ta == t_a - 1)
    def _emit_y1():
        o1_ref[...] = p_ref[...].astype(o1_ref.dtype)

    @pl.when((tb == t_b - 1) & (ta == t_a - 1))
    def _flush():
        flushed = acc_ref[...] + comp_ref[...] if compensated else acc_ref[...]
        o_ref[...] = flushed.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bu", "bka", "bnb", "bna",
                                             "t_a", "t_b", "interpret",
                                             "accum"))
def _chain_call(x3, ca, cb, counts_a, idx_a, idx_b,
                bu, bka, bnb, bna, t_a, t_b, interpret, accum="plain"):
    u, nb, na = x3.shape
    ka = ca.shape[1]
    kb = cb.shape[1]
    grid = (u // bu, ka // bka, t_b, t_a)
    out_dtype = jnp.float32 if accum != "plain" else x3.dtype
    scratch = [
        pltpu.VMEM((bu, bnb, bka), jnp.float32),  # stage-a partial
        pltpu.VMEM((bu, bka, kb), jnp.float32),   # output accumulator
    ]
    if accum == "compensated":
        scratch.append(pltpu.VMEM((bu, bka, kb), jnp.float32))  # comp

    def x_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (i, idx_b_ref[0, tb], idx_a_ref[j, ta])

    def ca_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (idx_a_ref[j, ta], j)

    def cb_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (idx_b_ref[0, tb], 0)

    def o_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (i, j, 0)

    def o1_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (i, idx_b_ref[0, tb], j)

    return pl.pallas_call(
        functools.partial(chain_gemt_kernel, t_a=t_a, t_b=t_b, accum=accum),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bnb, bna), x_map),  # streamed X slab
                pl.BlockSpec((bna, bka), ca_map),     # streamed C_a block
                pl.BlockSpec((bnb, kb), cb_map),      # resident C_b slab
            ],
            out_specs=[
                pl.BlockSpec((bu, bka, kb), o_map),
                pl.BlockSpec((bu, bnb, bka), o1_map),  # emitted y1 block
            ],
            scratch_shapes=scratch,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((u, ka, kb), out_dtype),
            jax.ShapeDtypeStruct((u, nb, ka), out_dtype),
        ),
        interpret=interpret,
    )(counts_a, idx_a, idx_b, x3, ca, cb)


def chain_gemt_pallas(
    x3: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    bu: int = 128,
    bka: int = 128,
    bnb: int = 32,
    bna: int = 128,
    interpret: bool = False,
    plan_a: tuple | None = None,
    accum: str = "plain",
) -> tuple[jnp.ndarray, jnp.ndarray, dict | None]:
    """``y, y1 = (X3 ×_a C_a) ×_b C_b`` with the intermediate emitted.

    Returns ``(y, y1)`` in layouts ``(U, Ka, Kb)`` / ``(U, Nb, Ka)``.
    ``plan_a`` optionally carries the a-side ESOP schedule
    ``(counts_a, idx_a, t_a)``; the b stream is always dense (see module
    docstring).  With a supplied plan ``info`` is None.
    """
    u, nb, na = x3.shape
    na2, ka = ca.shape
    nb2, kb = cb.shape
    assert na == na2 and nb == nb2, (x3.shape, ca.shape, cb.shape)
    assert u % bu == 0 and ka % bka == 0, ((u, ka), (bu, bka))
    assert nb % bnb == 0 and na % bna == 0, ((nb, na), (bnb, bna))

    if plan_a is None:
        counts_a, idx_a, t_a = esop_plan(ca, bna, bka)
        live_a = int(counts_a.sum())
        counts_a, idx_a = jnp.asarray(counts_a), jnp.asarray(idx_a)
    else:
        counts_a, idx_a, t_a = plan_a
        live_a = None
    idx_b, t_b = dense_slab_plan(nb, bnb)

    y, y1 = _chain_call(x3, ca, cb, counts_a, idx_a, idx_b,
                        bu, bka, bnb, bna, t_a, t_b, interpret, accum=accum)
    if live_a is None:
        return y, y1, None
    dense_a = (na // bna) * (ka // bka)
    info = {
        "blocks_dense_a": dense_a,
        "blocks_live_a": live_a,
        "t_steps": (t_a, t_b),
        "t_steps_dense": (na // bna, t_b),
    }
    return y, y1, info


def chain3_gemt_kernel(counts_a_ref, idx_a_ref, idx_b_ref, idx_c_ref,
                       x_ref, ca_ref, cb_ref, cc_ref, o_ref, o1_ref, o2_ref,
                       p1_ref, p2_ref, acc_ref, *scratch,
                       t_a: int, t_b: int, t_c: int, accum: str = "plain"):
    """Fused triple with both partials emitted as extra outputs.

    ``accum="compensated"`` Neumaier-compensates the outermost (t_c)
    reduction into the output accumulator, like ``fused3_gemt_kernel``.
    """
    compensated = accum == "compensated"
    comp_ref = scratch[0] if compensated else None
    j = pl.program_id(1)
    tc = pl.program_id(2)
    tb = pl.program_id(3)
    ta = pl.program_id(4)

    @pl.when((tc == 0) & (tb == 0) & (ta == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
        if compensated:
            comp_ref[...] = jnp.zeros(comp_ref.shape, comp_ref.dtype)

    @pl.when((tb == 0) & (ta == 0))
    def _init_p2():
        p2_ref[...] = jnp.zeros(p2_ref.shape, p2_ref.dtype)

    @pl.when(ta == 0)
    def _init_p1():
        p1_ref[...] = jnp.zeros(p1_ref.shape, p1_ref.dtype)

    @pl.when(ta < counts_a_ref[j])
    def _stage_1():
        x = x_ref[...]  # (bu, bnc, bnb, bna)
        bu, bnc, bnb, bna = x.shape
        p = jnp.dot(x.reshape(bu * bnc * bnb, bna), ca_ref[...],
                    preferred_element_type=jnp.float32)
        p1_ref[...] += p.reshape(bu, bnc, bnb, p.shape[-1])

    @pl.when(ta == t_a - 1)
    def _stage_2():
        p2_ref[...] += jax.lax.dot_general(
            p1_ref[...], cb_ref[...].astype(jnp.float32),
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # The completed stage-1 partial IS y1 for this (i, tc, tb, j) block.
    @pl.when(ta == t_a - 1)
    def _emit_y1():
        o1_ref[...] = p1_ref[...].astype(o1_ref.dtype)

    @pl.when((tb == t_b - 1) & (ta == t_a - 1))
    def _stage_3():
        p = jax.lax.dot_general(
            p2_ref[...], cc_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if compensated:
            acc = acc_ref[...]
            tot = acc + p
            comp_ref[...] += jnp.where(jnp.abs(acc) >= jnp.abs(p),
                                       (acc - tot) + p, (p - tot) + acc)
            acc_ref[...] = tot
        else:
            acc_ref[...] += p

    # The completed stage-2 partial IS y2 for this (i, tc, j) block.
    @pl.when((tb == t_b - 1) & (ta == t_a - 1))
    def _emit_y2():
        o2_ref[...] = p2_ref[...].astype(o2_ref.dtype)

    @pl.when((tc == t_c - 1) & (tb == t_b - 1) & (ta == t_a - 1))
    def _flush():
        flushed = acc_ref[...] + comp_ref[...] if compensated else acc_ref[...]
        o_ref[...] = flushed.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bu", "bka", "bnb", "bnc",
                                             "bna", "t_a", "t_b", "t_c",
                                             "interpret", "accum"))
def _chain3_call(x4, ca, cb, cc, counts_a, idx_a, idx_b, idx_c,
                 bu, bka, bnb, bnc, bna, t_a, t_b, t_c, interpret,
                 accum="plain"):
    u, nc, nb, na = x4.shape
    ka = ca.shape[1]
    kb = cb.shape[1]
    kc = cc.shape[1]
    grid = (u // bu, ka // bka, t_c, t_b, t_a)
    out_dtype = jnp.float32 if accum != "plain" else x4.dtype
    scratch = [
        pltpu.VMEM((bu, bnc, bnb, bka), jnp.float32),  # stage-1 P1
        pltpu.VMEM((bu, bnc, bka, kb), jnp.float32),   # stage-2 P2
        pltpu.VMEM((bu, bka, kb, kc), jnp.float32),    # accumulator
    ]
    if accum == "compensated":
        scratch.append(pltpu.VMEM((bu, bka, kb, kc), jnp.float32))  # comp

    def x_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
              idx_c_ref):
        return (i, idx_c_ref[0, tc], idx_b_ref[0, tb], idx_a_ref[j, ta])

    def ca_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (idx_a_ref[j, ta], j)

    def cb_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (idx_b_ref[0, tb], 0)

    def cc_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (idx_c_ref[0, tc], 0)

    def o_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
              idx_c_ref):
        return (i, j, 0, 0)

    def o1_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (i, idx_c_ref[0, tc], idx_b_ref[0, tb], j)

    def o2_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (i, idx_c_ref[0, tc], j, 0)

    return pl.pallas_call(
        functools.partial(chain3_gemt_kernel, t_a=t_a, t_b=t_b, t_c=t_c,
                          accum=accum),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bnc, bnb, bna), x_map),  # streamed X slab
                pl.BlockSpec((bna, bka), ca_map),          # streamed C_a
                pl.BlockSpec((bnb, kb), cb_map),           # resident C_b slab
                pl.BlockSpec((bnc, kc), cc_map),           # resident C_c slab
            ],
            out_specs=[
                pl.BlockSpec((bu, bka, kb, kc), o_map),
                pl.BlockSpec((bu, bnc, bnb, bka), o1_map),  # emitted y1
                pl.BlockSpec((bu, bnc, bka, kb), o2_map),   # emitted y2
            ],
            scratch_shapes=scratch,
        ),
        out_shape=(
            jax.ShapeDtypeStruct((u, ka, kb, kc), out_dtype),
            jax.ShapeDtypeStruct((u, nc, nb, ka), out_dtype),
            jax.ShapeDtypeStruct((u, nc, ka, kb), out_dtype),
        ),
        interpret=interpret,
    )(counts_a, idx_a, idx_b, idx_c, x4, ca, cb, cc)


def chain3_gemt_pallas(
    x4: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    cc: jnp.ndarray,
    bu: int = 8,
    bka: int = 128,
    bnb: int = 16,
    bnc: int = 16,
    bna: int = 128,
    interpret: bool = False,
    plan_a: tuple | None = None,
    accum: str = "plain",
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, dict | None]:
    """``y, y1, y2 = ((X4 ×_a C_a) ×_b C_b) ×_c C_c`` with both
    intermediates emitted.

    Layouts: ``y (U, Ka, Kb, Kc)``, ``y1 (U, Nc, Nb, Ka)``,
    ``y2 (U, Nc, Ka, Kb)``.  ``plan_a`` optionally carries the a-side ESOP
    schedule ``(counts_a, idx_a, t_a)``; the b and c streams are always
    dense (see module docstring).  With a supplied plan ``info`` is None.
    """
    u, nc, nb, na = x4.shape
    na2, ka = ca.shape
    nb2, kb = cb.shape
    nc2, kc = cc.shape
    assert na == na2 and nb == nb2 and nc == nc2, (
        x4.shape, ca.shape, cb.shape, cc.shape)
    assert u % bu == 0 and ka % bka == 0, ((u, ka), (bu, bka))
    assert nb % bnb == 0 and nc % bnc == 0 and na % bna == 0, (
        (nc, nb, na), (bnc, bnb, bna))

    if plan_a is None:
        counts_a, idx_a, t_a = esop_plan(ca, bna, bka)
        live_a = int(counts_a.sum())
        counts_a, idx_a = jnp.asarray(counts_a), jnp.asarray(idx_a)
    else:
        counts_a, idx_a, t_a = plan_a
        live_a = None
    idx_b, t_b = dense_slab_plan(nb, bnb)
    idx_c, t_c = dense_slab_plan(nc, bnc)

    y, y1, y2 = _chain3_call(x4, ca, cb, cc, counts_a, idx_a, idx_b, idx_c,
                             bu, bka, bnb, bnc, bna, t_a, t_b, t_c,
                             interpret, accum=accum)
    if live_a is None:
        return y, y1, y2, None
    dense_a = (na // bna) * (ka // bka)
    info = {
        "blocks_dense_a": dense_a,
        "blocks_live_a": live_a,
        "t_steps": (t_a, t_b, t_c),
        "t_steps_dense": (na // bna, t_b, t_c),
    }
    return y, y1, y2, info


def coeff_grad_batch_kernel(a_ref, g_ref, o_ref, acc_ref, *, t_r: int):
    """One stacked coefficient cotangent ``dC_s = A_sᵀ G_s``; r streams
    row blocks of the shared reduction axis."""
    r = pl.program_id(1)

    @pl.when(r == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[0].astype(jnp.float32), g_ref[0].astype(jnp.float32),
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(r == t_r - 1)
    def _flush():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "t_r", "interpret",
                                             "out_dtype"))
def _coeff_batch_call(a, g, br, t_r, interpret, out_dtype):
    s, rp, np_ = a.shape
    kp = g.shape[2]

    def a_map(si, r):
        return (si, r, 0)

    def g_map(si, r):
        return (si, r, 0)

    def o_map(si, r):
        return (si, 0, 0)

    return pl.pallas_call(
        functools.partial(coeff_grad_batch_kernel, t_r=t_r),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=0,
            grid=(s, t_r),
            in_specs=[
                pl.BlockSpec((1, br, np_), a_map),
                pl.BlockSpec((1, br, kp), g_map),
            ],
            out_specs=pl.BlockSpec((1, np_, kp), o_map),
            scratch_shapes=[
                pltpu.VMEM((np_, kp), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((s, np_, kp), out_dtype),
        interpret=interpret,
    )(a, g)


def coeff_grad_batch_pallas(
    a: jnp.ndarray,
    g: jnp.ndarray,
    br: int = 128,
    interpret: bool = False,
    out_dtype=None,
) -> jnp.ndarray:
    """``dC[s] = A[s]ᵀ @ G[s]`` for the stacked ``(S, R, N)`` / ``(S, R, K)``
    operands in one launch; R must be a multiple of ``br``.

    Zero-padded rows contribute nothing to the products, so callers pad the
    per-mode operands to a common ``(R, N, K)`` envelope and crop after.
    """
    s, rp, n = a.shape
    s2, rp2, k = g.shape
    assert s == s2 and rp == rp2, (a.shape, g.shape)
    assert rp % br == 0, (rp, br)
    if out_dtype is None:
        out_dtype = jnp.result_type(a.dtype, g.dtype)
    return _coeff_batch_call(a, g, br, rp // br, interpret, out_dtype)
