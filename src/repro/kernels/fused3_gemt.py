"""Whole-transform megakernel — all three mode contractions in one
``pallas_call``, both intermediates resident in VMEM.

The fused *pair* kernel (``fused_gemt.py``) already keeps the stage-a
partial on-chip, but the third contraction of a 3D-DXT still round-trips
the full ``(X ×_a C_a) ×_b C_b`` intermediate through HBM — plus the
``moveaxis``+``reshape`` transpose into the last unfolding.  The paper's
cell array holds the tensor resident across *all three* stages (§5: the
resident tensor never leaves the cells); extending Deinsum's I/O-optimality
argument one stage further, this kernel computes

  ``Y = ((X ×_a C_a) ×_b C_b) ×_c C_c``

with **zero** intermediate HBM bytes: the stage-1 partial and the stage-2
partial both live in VMEM scratch, each consumed by the next contraction
the moment its streaming sweep completes.

Layout (u-major; U is the folded batch — all three tensor modes are
contracted, so no mode is left untouched):

  X4 (U, Nc, Nb, Na),  C_a (Na, Ka),  C_b (Nb, Kb),  C_c (Nc, Kc)
  Y  (U, Ka, Kb, Kc)
  Y[u,ka,kb,kc] = Σ_nc Σ_nb Σ_na X4[u,nc,nb,na]·C_a[na,ka]·C_b[nb,kb]·C_c[nc,kc]

grid = (U/bu, Ka/bka, T_c, T_b, T_a), sequential on TPU with t_a innermost:

  * t_a streams C_a's na-blocks: the stage-1 partial P1 (bu, bnc, bnb, bka)
    accumulates rank-``bna`` updates in VMEM scratch;
  * when the na sweep completes, P1 is contracted with the resident C_b
    slab (bnb, Kb) into the stage-2 partial P2 (bu, bnc, bka, Kb) —
    the first intermediate never exists in HBM;
  * when the nb sweep completes, P2 is contracted with the resident C_c
    slab (bnc, Kc) into the output accumulator (bu, bka, Kb, Kc) — nor
    does the second;
  * t_c streams the nc slabs; (i, j) tile the output on (U, Ka).

ESOP block-skipping composes across all three streamed coefficient
matrices through the same scalar-prefetch machinery as ``esop_gemm``:
``idx_a[j, t]`` compacts C_a's nonzero (na, ka)-blocks per ka-column (dead
steps are ``pl.when``-guarded and their X/C_a blocks never fetched),
``idx_b[0, t]`` compacts C_b's nonzero nb-slabs and ``idx_c[0, t]`` C_c's
nonzero nc-slabs — a zero slab of either skips the X fetches of its whole
streaming plane.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fused_gemt import kb_padded

__all__ = ["fused3_gemt_kernel", "fused3_gemt_pallas"]


def fused3_gemt_kernel(counts_a_ref, idx_a_ref, idx_b_ref, idx_c_ref,
                       x_ref, ca_ref, cb_ref, cc_ref, o_ref,
                       p1_ref, p2_ref, acc_ref, *scratch,
                       t_a: int, t_b: int, t_c: int, accum: str = "plain"):
    """One (i, j) output tile; dims 2/3/4 stream C_c/C_b slabs, C_a blocks.

    ``accum="compensated"`` Neumaier-compensates the outermost (t_c)
    reduction into the output accumulator — the only one whose depth the
    inner sweeps reset — banking the bits each ``acc + p`` drops in a comp
    scratch folded back at the flush (``docs/numerics.md``).
    """
    compensated = accum == "compensated"
    comp_ref = scratch[0] if compensated else None
    j = pl.program_id(1)
    tc = pl.program_id(2)
    tb = pl.program_id(3)
    ta = pl.program_id(4)

    @pl.when((tc == 0) & (tb == 0) & (ta == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
        if compensated:
            comp_ref[...] = jnp.zeros(comp_ref.shape, comp_ref.dtype)

    @pl.when((tb == 0) & (ta == 0))
    def _init_p2():
        p2_ref[...] = jnp.zeros(p2_ref.shape, p2_ref.dtype)

    @pl.when(ta == 0)
    def _init_p1():
        p1_ref[...] = jnp.zeros(p1_ref.shape, p1_ref.dtype)

    # Stage 1, live steps only: rank-bna update of the on-chip partial.
    # Dead steps (ta >= counts_a[j]) fetch nothing and compute nothing.
    @pl.when(ta < counts_a_ref[j])
    def _stage_1():
        x = x_ref[...]  # (bu, bnc, bnb, bna)
        bu, bnc, bnb, bna = x.shape
        p = jnp.dot(x.reshape(bu * bnc * bnb, bna), ca_ref[...],
                    preferred_element_type=jnp.float32)
        p1_ref[...] += p.reshape(bu, bnc, bnb, p.shape[-1])

    # Stage 2: the completed stage-1 partial is contracted against the
    # resident C_b slab without leaving VMEM.
    @pl.when(ta == t_a - 1)
    def _stage_2():
        p2_ref[...] += jax.lax.dot_general(
            p1_ref[...], cb_ref[...].astype(jnp.float32),
            dimension_numbers=(((2,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    # Stage 3: the completed stage-2 partial is contracted against the
    # resident C_c slab — the second intermediate never exists in HBM
    # either, which is what this kernel exists for.
    @pl.when((tb == t_b - 1) & (ta == t_a - 1))
    def _stage_3():
        p = jax.lax.dot_general(
            p2_ref[...], cc_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if compensated:
            acc = acc_ref[...]
            tot = acc + p
            comp_ref[...] += jnp.where(jnp.abs(acc) >= jnp.abs(p),
                                       (acc - tot) + p, (p - tot) + acc)
            acc_ref[...] = tot
        else:
            acc_ref[...] += p

    @pl.when((tc == t_c - 1) & (tb == t_b - 1) & (ta == t_a - 1))
    def _flush():
        flushed = acc_ref[...] + comp_ref[...] if compensated else acc_ref[...]
        o_ref[...] = flushed.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bu", "bka", "bnb", "bnc",
                                             "bna", "t_a", "t_b", "t_c",
                                             "interpret", "accum"))
def _fused3_call(x4, ca, cb, cc, counts_a, idx_a, idx_b, idx_c,
                 bu, bka, bnb, bnc, bna, t_a, t_b, t_c, interpret,
                 accum="plain"):
    u, nc, nb, na = x4.shape
    ka = ca.shape[1]
    kb = cb.shape[1]
    kc = cc.shape[1]
    grid = (u // bu, ka // bka, t_c, t_b, t_a)
    out_dtype = jnp.float32 if accum != "plain" else x4.dtype
    scratch = [
        pltpu.VMEM((bu, bnc, bnb, bka), jnp.float32),  # stage-1 P1
        pltpu.VMEM((bu, bnc, bka, kb), jnp.float32),   # stage-2 P2
        pltpu.VMEM((bu, bka, kb, kc), jnp.float32),    # accumulator
    ]
    if accum == "compensated":
        scratch.append(pltpu.VMEM((bu, bka, kb, kc), jnp.float32))  # comp

    def x_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
              idx_c_ref):
        return (i, idx_c_ref[0, tc], idx_b_ref[0, tb], idx_a_ref[j, ta])

    def ca_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (idx_a_ref[j, ta], j)

    def cb_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (idx_b_ref[0, tb], 0)

    def cc_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
               idx_c_ref):
        return (idx_c_ref[0, tc], 0)

    def o_map(i, j, tc, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref,
              idx_c_ref):
        return (i, j, 0, 0)

    return pl.pallas_call(
        functools.partial(fused3_gemt_kernel, t_a=t_a, t_b=t_b, t_c=t_c,
                          accum=accum),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=4,  # counts_a, idx_a/b/c drive the dataflow
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bnc, bnb, bna), x_map),  # streamed X slab
                pl.BlockSpec((bna, bka), ca_map),          # streamed C_a
                pl.BlockSpec((bnb, kb), cb_map),           # resident C_b slab
                pl.BlockSpec((bnc, kc), cc_map),           # resident C_c slab
            ],
            out_specs=pl.BlockSpec((bu, bka, kb, kc), o_map),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((u, ka, kb, kc), out_dtype),
        interpret=interpret,
    )(counts_a, idx_a, idx_b, idx_c, x4, ca, cb, cc)


def fused3_gemt_pallas(
    x4: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    cc: jnp.ndarray,
    bu: int = 8,
    bka: int = 128,
    bnb: int = 16,
    bnc: int = 16,
    bna: int = 128,
    interpret: bool = False,
    plan: tuple | None = None,
    accum: str = "plain",
) -> tuple[jnp.ndarray, dict | None]:
    """Y = ((X4 ×_a C_a) ×_b C_b) ×_c C_c fused; shapes must be block
    multiples.

    ``plan`` optionally carries precomputed ESOP schedules
    ``(counts_a, idx_a, t_a, idx_b, t_b, idx_c, t_c)`` (``ops.fused3_gemt``
    memoizes them per coefficient identity).  With a supplied plan the
    caller already owns the accounting and ``info`` is None; standalone
    calls get the streamed-block accounting for all three matrices
    computed here.
    """
    from .esop_gemm import esop_plan

    u, nc, nb, na = x4.shape
    na2, ka = ca.shape
    nb2, kb = cb.shape
    nc2, kc = cc.shape
    assert na == na2 and nb == nb2 and nc == nc2, (
        x4.shape, ca.shape, cb.shape, cc.shape)
    assert u % bu == 0 and ka % bka == 0, ((u, ka), (bu, bka))
    assert nb % bnb == 0 and nc % bnc == 0 and na % bna == 0, (
        (nc, nb, na), (bnc, bnb, bna))

    if plan is None:
        counts_a, idx_a, t_a = esop_plan(ca, bna, bka)
        counts_b, idx_b, t_b = esop_plan(cb, bnb, kb)
        counts_c, idx_c, t_c = esop_plan(cc, bnc, kc)
        live = (int(counts_a.sum()), int(counts_b.sum()),
                int(counts_c.sum()))
        counts_a, idx_a, idx_b, idx_c = (
            jnp.asarray(counts_a), jnp.asarray(idx_a), jnp.asarray(idx_b),
            jnp.asarray(idx_c))
    else:
        counts_a, idx_a, t_a, idx_b, t_b, idx_c, t_c = plan
        live = None

    y = _fused3_call(x4, ca, cb, cc, counts_a, idx_a, idx_b, idx_c,
                     bu, bka, bnb, bnc, bna, t_a, t_b, t_c, interpret,
                     accum=accum)
    if live is None:
        return y, None
    live_a, live_b, live_c = live
    dense_a = (na // bna) * (ka // bka)
    dense_b = nb // bnb
    dense_c = nc // bnc
    info = {
        "blocks_dense_a": dense_a,
        "blocks_live_a": live_a,
        "slabs_dense_b": dense_b,
        "slabs_live_b": live_b,
        "slabs_dense_c": dense_c,
        "slabs_live_c": live_c,
        # fraction of the dense streaming grid never fetched (the grid is
        # the product space C_a blocks × C_b slabs × C_c slabs; a dead
        # entry on any axis skips the X fetch of its whole plane)
        "fetch_savings": 1.0 - (live_a * max(live_b, 1) * max(live_c, 1))
                               / max(dense_a * dense_b * dense_c, 1),
        "t_steps": (t_a, t_b, t_c),
        "t_steps_dense": (na // bna, nb // bnb, nc // bnc),
    }
    return y, info
