"""Fused two-stage GEMT kernel — both mode contractions in one pallas_call.

The staged engine executes ``(X ×_a C_a) ×_b C_b`` as two kernel launches
with the full intermediate tensor ``T = X ×_a C_a`` written to HBM, copied
through a ``moveaxis``+``reshape`` transpose into the next unfolding, and
read back for stage b.  For serving-sized tensors (N ≤ 256) the dominant
cost is exactly that HBM round-trip, not the MACs — Deinsum's
communication-optimality argument, and the reason the paper's cell array
never lets the resident tensor leave the cells between stages.

This kernel reproduces that on the TPU memory hierarchy: the stage-a
partial product lives in a VMEM scratch tile and is contracted against the
streamed C_b slab the moment it completes, so ``T`` never exists in HBM and
the inter-stage transpose dissolves into the BlockSpec index maps.

Layout (u-major; U = batch · untouched mode, folded by the lowering):

  X3 (U, Nb, Na),  C_a (Na, Ka),  C_b (Nb, Kb)
  Y  (U, Ka, Kb),  Y[u,ka,kb] = Σ_nb Σ_na X3[u,nb,na] · C_a[na,ka] · C_b[nb,kb]

grid = (U/bu, Ka/bka, T_b, T_a), sequential on TPU with t_a innermost:

  * t_a streams C_a's na-blocks: the stage-a partial P (bu, bnb, bka)
    accumulates rank-``bna`` updates in VMEM scratch — the paper's
    time-stepped outer-product chain at MXU granularity;
  * when the na sweep completes, P is immediately contracted with the
    resident C_b slab (bnb, Kb) into the output accumulator (bu, bka, Kb)
    — stage b consumes the intermediate while it is still on-chip;
  * t_b streams the nb slabs; (i, j) tile the output.

ESOP block-skipping composes on *both* streamed matrices through the same
scalar-prefetch machinery as ``esop_gemm``: ``idx_a[j, t]`` compacts C_a's
nonzero (na, ka)-blocks per ka-column (dead steps are ``pl.when``-guarded
and their X/C_a blocks never fetched), and ``idx_b[0, t]`` compacts C_b's
nonzero nb-slabs — a zero slab of C_b skips the whole X slab fetch too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .esop_gemm import esop_plan

__all__ = ["fused_gemt_kernel", "fused_gemt_pallas", "kb_padded"]


def kb_padded(kb: int) -> int:
    """Padded full width of the C_b slab / output accumulator held in VMEM.

    Kb is not grid-blocked (the whole slab stays resident so stage b never
    revisits the partial), so it is padded to a lane-friendly multiple:
    128 once large enough, the nearest power of two below it otherwise.
    """
    base = min(128, 1 << (max(int(kb), 8).bit_length() - 1))
    return -(-int(kb) // base) * base


def fused_gemt_kernel(counts_a_ref, idx_a_ref, idx_b_ref, x_ref, ca_ref,
                      cb_ref, o_ref, p_ref, acc_ref, *scratch,
                      t_a: int, t_b: int, accum: str = "plain"):
    """One (i, j) output tile; dims 2/3 stream C_b slabs / C_a blocks.

    ``accum="compensated"`` adds a Neumaier comp scratch on the output
    accumulator: the final contraction streams one slab-contribution per
    t_b step, and the bits each ``acc + p`` drops are banked and folded
    back at the flush (``docs/numerics.md``).  The stage-a partial is
    already exact-in-f32 per slab (its accumulation depth is bounded by
    t_a, restarted every slab), so only the long t_b reduction is
    compensated — matching the reference oracle's final-stage treatment.
    """
    compensated = accum == "compensated"
    comp_ref = scratch[0] if compensated else None
    j = pl.program_id(1)
    tb = pl.program_id(2)
    ta = pl.program_id(3)

    @pl.when((tb == 0) & (ta == 0))
    def _init_acc():
        acc_ref[...] = jnp.zeros(acc_ref.shape, acc_ref.dtype)
        if compensated:
            comp_ref[...] = jnp.zeros(comp_ref.shape, comp_ref.dtype)

    @pl.when(ta == 0)
    def _init_partial():
        p_ref[...] = jnp.zeros(p_ref.shape, p_ref.dtype)

    # Stage a, live steps only: rank-bna update of the on-chip partial.
    # Dead steps (ta >= counts_a[j]) fetch nothing and compute nothing.
    @pl.when(ta < counts_a_ref[j])
    def _stage_a():
        x = x_ref[...]  # (bu, bnb, bna)
        bu, bnb, bna = x.shape
        p = jnp.dot(x.reshape(bu * bnb, bna), ca_ref[...],
                    preferred_element_type=jnp.float32)
        p_ref[...] += p.reshape(bu, bnb, p.shape[-1])

    # Stage b: the completed partial is contracted against the resident C_b
    # slab without ever leaving VMEM — the fusion this kernel exists for.
    @pl.when(ta == t_a - 1)
    def _stage_b():
        p = jax.lax.dot_general(
            p_ref[...], cb_ref[...].astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        if compensated:
            acc = acc_ref[...]
            tot = acc + p
            comp_ref[...] += jnp.where(jnp.abs(acc) >= jnp.abs(p),
                                       (acc - tot) + p, (p - tot) + acc)
            acc_ref[...] = tot
        else:
            acc_ref[...] += p

    @pl.when((tb == t_b - 1) & (ta == t_a - 1))
    def _flush():
        flushed = acc_ref[...] + comp_ref[...] if compensated else acc_ref[...]
        o_ref[...] = flushed.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bu", "bka", "bnb", "bna",
                                             "t_a", "t_b", "interpret",
                                             "accum"))
def _fused_call(x3, ca, cb, counts_a, idx_a, idx_b,
                bu, bka, bnb, bna, t_a, t_b, interpret, accum="plain"):
    u, nb, na = x3.shape
    ka = ca.shape[1]
    kb = cb.shape[1]
    grid = (u // bu, ka // bka, t_b, t_a)
    out_dtype = jnp.float32 if accum != "plain" else x3.dtype
    scratch = [
        pltpu.VMEM((bu, bnb, bka), jnp.float32),  # stage-a partial
        pltpu.VMEM((bu, bka, kb), jnp.float32),   # output accumulator
    ]
    if accum == "compensated":
        scratch.append(pltpu.VMEM((bu, bka, kb), jnp.float32))  # comp

    def x_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (i, idx_b_ref[0, tb], idx_a_ref[j, ta])

    def ca_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (idx_a_ref[j, ta], j)

    def cb_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (idx_b_ref[0, tb], 0)

    def o_map(i, j, tb, ta, counts_a_ref, idx_a_ref, idx_b_ref):
        return (i, j, 0)

    return pl.pallas_call(
        functools.partial(fused_gemt_kernel, t_a=t_a, t_b=t_b, accum=accum),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,  # counts_a, idx_a, idx_b drive the dataflow
            grid=grid,
            in_specs=[
                pl.BlockSpec((bu, bnb, bna), x_map),  # streamed X slab
                pl.BlockSpec((bna, bka), ca_map),     # streamed C_a block
                pl.BlockSpec((bnb, kb), cb_map),      # resident C_b slab
            ],
            out_specs=pl.BlockSpec((bu, bka, kb), o_map),
            scratch_shapes=scratch,
        ),
        out_shape=jax.ShapeDtypeStruct((u, ka, kb), out_dtype),
        interpret=interpret,
    )(counts_a, idx_a, idx_b, x3, ca, cb)


def fused_gemt_pallas(
    x3: jnp.ndarray,
    ca: jnp.ndarray,
    cb: jnp.ndarray,
    bu: int = 128,
    bka: int = 128,
    bnb: int = 32,
    bna: int = 128,
    interpret: bool = False,
    plan: tuple | None = None,
    accum: str = "plain",
) -> tuple[jnp.ndarray, dict | None]:
    """Y = (X3 ×_a C_a) ×_b C_b fused; shapes must be block multiples.

    ``plan`` optionally carries precomputed ESOP schedules
    ``(counts_a, idx_a, t_a, idx_b, t_b)`` (``ops.fused_gemt`` memoizes
    them per coefficient identity).  With a supplied plan the caller
    already owns the accounting and ``info`` is None — the memoized stats
    are the single source of truth; standalone calls get the streamed-block
    accounting for both matrices computed here.
    """
    u, nb, na = x3.shape
    na2, ka = ca.shape
    nb2, kb = cb.shape
    assert na == na2 and nb == nb2, (x3.shape, ca.shape, cb.shape)
    assert u % bu == 0 and ka % bka == 0, ((u, ka), (bu, bka))
    assert nb % bnb == 0 and na % bna == 0, ((nb, na), (bnb, bna))

    if plan is None:
        counts_a, idx_a, t_a = esop_plan(ca, bna, bka)
        counts_b, idx_b, t_b = esop_plan(cb, bnb, kb)
        live_a, live_b = int(counts_a.sum()), int(counts_b.sum())
        counts_a, idx_a, idx_b = (jnp.asarray(counts_a), jnp.asarray(idx_a),
                                  jnp.asarray(idx_b))
    else:
        counts_a, idx_a, t_a, idx_b, t_b = plan
        live_a = None

    y = _fused_call(x3, ca, cb, counts_a, idx_a, idx_b,
                    bu, bka, bnb, bna, t_a, t_b, interpret, accum=accum)
    if live_a is None:
        return y, None
    dense_a = (na // bna) * (ka // bka)
    dense_b = nb // bnb
    info = {
        "blocks_dense_a": dense_a,
        "blocks_live_a": live_a,
        "slabs_dense_b": dense_b,
        "slabs_live_b": live_b,
        # fraction of the dense streaming grid never fetched (X and C_a
        # scale with both factors; a dead C_b slab skips the X fetch too)
        "fetch_savings": 1.0 - (live_a * max(live_b, 1))
                               / max(dense_a * dense_b, 1),
        "t_steps": (t_a, t_b),
        "t_steps_dense": (na // bna, nb // bnb),
    }
    return y, info
