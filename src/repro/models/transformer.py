"""Unified causal LM assembly for all assigned architectures.

A model is a sequence of *segments*, each a homogeneous stack of blocks
scanned with ``lax.scan`` (stacked params) — heterogeneous architectures
(rgemma's (rec, rec, attn) pattern, xLSTM's 7:1 mLSTM:sLSTM, DeepSeek-V3's
dense→MoE split) are expressed as multiple segments.  Three entry modes:

  * ``apply_train``   — full-sequence logits (B, S, V_eff) + MoE aux loss,
  * ``apply_prefill`` — last-token logits + a filled decode cache,
  * ``apply_decode``  — one-token step against the cache.

Vocab is padded to ``cfg.eff_vocab`` for TP divisibility; padded logits are
masked with -1e30 so they never receive probability mass.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from . import ffn as ffn_mod
from . import recurrent as rec_mod
from . import xlstm as xlstm_mod
from .common import ShardCtx, apply_norm, embed_init, init_norm, norm_axes, \
    sinusoidal_positions, dense_init

# ---------------------------------------------------------------------------
# Mixer dispatch
# ---------------------------------------------------------------------------

_MIXERS = {
    "attn": (attn_mod.init_attn, attn_mod.attn_axes, attn_mod.apply_attn,
             attn_mod.apply_attn_decode, attn_mod.init_attn_cache,
             attn_mod.cache_axes),
    "mla": (attn_mod.init_mla, attn_mod.mla_axes, attn_mod.apply_mla,
            attn_mod.apply_mla_decode, attn_mod.init_mla_cache,
            attn_mod.mla_cache_axes),
    "rglru": (rec_mod.init_rglru, rec_mod.rglru_axes, rec_mod.apply_rglru,
              rec_mod.apply_rglru_decode, rec_mod.init_rglru_cache,
              rec_mod.rglru_cache_axes),
    "mlstm": (xlstm_mod.init_mlstm, xlstm_mod.mlstm_axes,
              xlstm_mod.apply_mlstm, xlstm_mod.apply_mlstm_decode,
              xlstm_mod.init_mlstm_cache, xlstm_mod.mlstm_cache_axes),
    "slstm": (xlstm_mod.init_slstm, xlstm_mod.slstm_axes,
              xlstm_mod.apply_slstm, xlstm_mod.apply_slstm_decode,
              xlstm_mod.init_slstm_cache, xlstm_mod.slstm_cache_axes),
}


def _mixer(block):
    return _MIXERS[block.mixer]


# ---------------------------------------------------------------------------
# Block = mixer + (optional) mlp/moe, pre-norm residual
# ---------------------------------------------------------------------------


def init_block(key, cfg, block) -> dict:
    k1, k2 = jax.random.split(key)
    p = {"mixer": _mixer(block)[0](k1, cfg, block)}
    if block.mlp == "moe":
        p["moe"] = ffn_mod.init_moe(k2, cfg, block)
    elif block.mlp != "none":
        p["mlp"] = ffn_mod.init_mlp(k2, cfg, block)
    return p


def block_axes(cfg, block) -> dict:
    a = {"mixer": _mixer(block)[1](cfg, block)}
    if block.mlp == "moe":
        a["moe"] = ffn_mod.moe_axes(cfg, block)
    elif block.mlp != "none":
        a["mlp"] = ffn_mod.mlp_axes(cfg, block)
    return a


def apply_block(bp, x, cfg, block, ctx, positions):
    """Train-mode block.  Returns (x, aux)."""
    x = x + _mixer(block)[2](bp["mixer"], x, cfg, block, ctx, positions)
    aux = jnp.zeros((), jnp.float32)
    if block.mlp == "moe":
        y, aux = ffn_mod.apply_moe(bp["moe"], x, cfg, block, ctx)
        x = x + y
    elif block.mlp != "none":
        x = x + ffn_mod.apply_mlp(bp["mlp"], x, cfg, block, ctx)
    return x, aux


def apply_block_decode(bp, x, cache, cfg, block, ctx, pos):
    y, new_cache = _mixer(block)[3](bp["mixer"], x, cache, cfg, block, ctx, pos)
    x = x + y
    if block.mlp == "moe":
        y, _ = ffn_mod.apply_moe(bp["moe"], x, cfg, block, ctx)
        x = x + y
    elif block.mlp != "none":
        x = x + ffn_mod.apply_mlp(bp["mlp"], x, cfg, block, ctx)
    return x, new_cache


def init_block_cache(cfg, block, batch: int, max_len: int) -> dict:
    return _mixer(block)[4](cfg, block, batch, max_len)


def block_cache_axes(cfg, block) -> dict:
    return _mixer(block)[5](cfg, block)


# ---------------------------------------------------------------------------
# Super-block = ordered tuple of sub-blocks (heterogeneous patterns)
# ---------------------------------------------------------------------------


def init_superblock(key, cfg, blocks) -> dict:
    ks = jax.random.split(key, len(blocks))
    return {f"sub{i}": init_block(ks[i], cfg, bc)
            for i, bc in enumerate(blocks)}


def superblock_axes(cfg, blocks) -> dict:
    return {f"sub{i}": block_axes(cfg, bc) for i, bc in enumerate(blocks)}


def apply_superblock(sp, x, cfg, blocks, ctx, positions):
    aux = jnp.zeros((), jnp.float32)
    for i, bc in enumerate(blocks):
        x, a = apply_block(sp[f"sub{i}"], x, cfg, bc, ctx, positions)
        aux = aux + a
    return x, aux


def apply_superblock_decode(sp, x, cache, cfg, blocks, ctx, pos):
    new_cache = {}
    for i, bc in enumerate(blocks):
        x, nc = apply_block_decode(sp[f"sub{i}"], x, cache[f"sub{i}"], cfg,
                                   bc, ctx, pos)
        new_cache[f"sub{i}"] = nc
    return x, new_cache


def init_superblock_cache(cfg, blocks, batch, max_len) -> dict:
    return {f"sub{i}": init_block_cache(cfg, bc, batch, max_len)
            for i, bc in enumerate(blocks)}


def superblock_cache_axes(cfg, blocks) -> dict:
    return {f"sub{i}": block_cache_axes(cfg, bc)
            for i, bc in enumerate(blocks)}


def apply_superblock_prefill(sp, x, cfg, blocks, ctx, positions, seq_len,
                             cache_len):
    cache = {}
    for i, bc in enumerate(blocks):
        x, c = _prefill_block(sp[f"sub{i}"], x, cfg, bc, ctx, positions,
                              seq_len, cache_len)
        cache[f"sub{i}"] = c
    return x, cache


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------


def init_model(key, cfg) -> dict:
    ks = jax.random.split(key, 3 + len(cfg.eff_segments))
    p: dict = {"final_norm": init_norm(cfg)}
    if cfg.input_mode == "tokens":
        p["embed"] = embed_init(ks[0], (cfg.eff_vocab, cfg.d_model),
                                cfg.param_dtype)
    elif cfg.input_mode == "codebooks":
        p["embed"] = embed_init(
            ks[0], (cfg.n_codebooks, cfg.vocab_size, cfg.d_model),
            cfg.param_dtype)
    if not cfg.tie_embeddings or cfg.input_mode == "embeddings":
        p["lm_head"] = dense_init(ks[1], (cfg.d_model, cfg.eff_vocab),
                                  cfg.d_model, cfg.param_dtype)
    for si, (blocks, count) in enumerate(cfg.eff_segments):
        seg_keys = jax.random.split(ks[3 + si], count)
        p[f"seg{si}"] = jax.vmap(
            lambda k: init_superblock(k, cfg, blocks))(seg_keys)
    return p


def model_axes(cfg) -> dict:
    a: dict = {"final_norm": norm_axes(cfg)}
    if cfg.input_mode == "tokens":
        a["embed"] = ("vocab", "embed")
    elif cfg.input_mode == "codebooks":
        a["embed"] = (None, "vocab", "embed")
    if not cfg.tie_embeddings or cfg.input_mode == "embeddings":
        a["lm_head"] = ("embed", "vocab")
    for si, (blocks, count) in enumerate(cfg.eff_segments):
        a[f"seg{si}"] = jax.tree.map(
            lambda ax: ("layers",) + ax, superblock_axes(cfg, blocks),
            is_leaf=lambda x: isinstance(x, tuple))
    return a


def _embed(p, batch, cfg, ctx, pos0: jnp.ndarray | int = 0):
    if cfg.input_mode == "embeddings":
        x = batch["embeddings"].astype(cfg.act_dtype)
    elif cfg.input_mode == "codebooks":
        toks = batch["tokens"]  # (B, S, n_codebooks)
        x = jnp.zeros(toks.shape[:2] + (cfg.d_model,), cfg.act_dtype)
        for cb in range(cfg.n_codebooks):
            x = x + jnp.take(p["embed"][cb], toks[..., cb], axis=0)
    else:
        x = jnp.take(p["embed"], batch["tokens"], axis=0)
    b, s = x.shape[:2]
    if cfg.pos == "mrope":
        positions = batch.get("positions")
        if positions is None:
            base = pos0 + jnp.arange(s)[None]
            positions = jnp.broadcast_to(base, (3, b, s))
    else:
        positions = jnp.broadcast_to(pos0 + jnp.arange(s)[None], (b, s))
    if cfg.pos == "sinusoidal":
        x = x + sinusoidal_positions(
            positions if positions.ndim == 2 else positions[0],
            cfg.d_model).astype(x.dtype)
    x = ctx.shard(x, "batch", "seq_act", None)
    return x, positions


def _lm_head(p, x, cfg, ctx):
    w = p["lm_head"] if "lm_head" in p else p["embed"].T
    logits = x @ w
    if cfg.eff_vocab != cfg.vocab_size:
        mask = jnp.where(jnp.arange(cfg.eff_vocab) < cfg.vocab_size, 0.0,
                         -1e30).astype(jnp.float32)
        logits = logits.astype(jnp.float32) + mask
    return ctx.shard(logits, "batch", None, "vocab_act")


def _remat(fn, cfg):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)  # "block": save nothing


# ---------------------------------------------------------------------------
# Entry modes
# ---------------------------------------------------------------------------


def apply_backbone(p, batch, cfg, ctx: ShardCtx):
    """Full-sequence forward up to the final norm (no LM head).
    Returns (x (B, S, D), aux_loss)."""
    x, positions = _embed(p, batch, cfg, ctx)
    aux_total = jnp.zeros((), jnp.float32)
    for si, (blocks, count) in enumerate(cfg.eff_segments):
        def block_body(lp, x, _blocks=blocks):
            # Residual-stream constraint: under sequence parallelism
            # (act rule seq_act='model') GSPMD gathers/scatters around the
            # per-block compute; default (None) is a no-op.
            x = ctx.shard(x, "batch", "seq_act", None)
            return apply_superblock(lp, x, cfg, _blocks, ctx, positions)

        body = _remat(block_body, cfg)

        if cfg.scan_layers and count > 1:
            def scan_fn(carry, lp):
                x, aux = carry
                x, a = body(lp, x)
                return (x, aux + a), None
            (x, aux_total), _ = jax.lax.scan(
                scan_fn, (x, aux_total), p[f"seg{si}"])
        else:
            for li in range(count):
                lp = jax.tree.map(lambda t: t[li], p[f"seg{si}"])
                x, a = body(lp, x)
                aux_total = aux_total + a
    x = apply_norm(p["final_norm"], x, cfg.norm)
    return x, aux_total


def apply_train(p, batch, cfg, ctx: ShardCtx):
    """Full-sequence forward.  Returns (logits_f32, aux_loss)."""
    x, aux_total = apply_backbone(p, batch, cfg, ctx)
    return _lm_head(p, x, cfg, ctx), aux_total


def init_cache(cfg, batch: int, max_len: int) -> dict:
    cache = {}
    for si, (blocks, count) in enumerate(cfg.eff_segments):
        one = init_superblock_cache(cfg, blocks, batch, max_len)
        cache[f"seg{si}"] = jax.tree.map(
            lambda t: jnp.broadcast_to(t[None], (count,) + t.shape)
            .astype(t.dtype), one)
    return cache


def cache_axes_tree(cfg) -> dict:
    return {f"seg{si}": jax.tree.map(
        lambda ax: ("layers",) + ax, superblock_cache_axes(cfg, blocks),
        is_leaf=lambda x: isinstance(x, tuple))
        for si, (blocks, count) in enumerate(cfg.eff_segments)}


def apply_decode(p, batch, cache, cfg, ctx: ShardCtx, pos):
    """One-token step.  batch holds the new token; pos is its position.
    Returns (logits (B, V_eff), new_cache)."""
    x, _ = _embed(p, batch, cfg, ctx, pos0=pos)
    new_cache = {}
    for si, (blocks, count) in enumerate(cfg.eff_segments):
        seg_cache = cache[f"seg{si}"]

        def step(x, layer_in, _blocks=blocks):
            lp, lc = layer_in
            x, nc = apply_superblock_decode(lp, x, lc, cfg, _blocks, ctx, pos)
            return x, nc

        if cfg.scan_layers and count > 1:
            x, nc = jax.lax.scan(step, x, (p[f"seg{si}"], seg_cache))
        else:
            ncs = []
            for li in range(count):
                lp = jax.tree.map(lambda t: t[li], p[f"seg{si}"])
                lc = jax.tree.map(lambda t: t[li], seg_cache)
                x, c1 = apply_superblock_decode(lp, x, lc, cfg, blocks, ctx,
                                                pos)
                ncs.append(c1)
            nc = jax.tree.map(lambda *ts: jnp.stack(ts), *ncs)
        new_cache[f"seg{si}"] = nc
    x = apply_norm(p["final_norm"], x, cfg.norm)
    logits = _lm_head(p, x[:, -1:, :], cfg, ctx)[:, 0]
    return logits, new_cache


def apply_prefill(p, batch, cfg, ctx: ShardCtx, cache_len: int | None = None):
    """Full-sequence forward that also fills the decode cache.

    ``cache_len`` sizes the returned KV caches (≥ seq_len leaves headroom
    for subsequent decode steps; default = seq_len, the dry-run cell shape).
    Returns (last_token_logits (B, V_eff), cache).
    """
    x, positions = _embed(p, batch, cfg, ctx)
    s = x.shape[1]
    cache_len = cache_len or s
    cache = {}
    for si, (blocks, count) in enumerate(cfg.eff_segments):
        def body(lp, x, _blocks=blocks):
            return apply_superblock_prefill(lp, x, cfg, _blocks, ctx,
                                            positions, s, cache_len)

        if cfg.scan_layers and count > 1:
            def scan_fn(x, lp):
                x, c = body(lp, x)
                return x, c
            x, seg_cache = jax.lax.scan(scan_fn, x, p[f"seg{si}"])
        else:
            cs = []
            for li in range(count):
                lp = jax.tree.map(lambda t: t[li], p[f"seg{si}"])
                x, c1 = body(lp, x)
                cs.append(c1)
            seg_cache = jax.tree.map(lambda *ts: jnp.stack(ts), *cs)
        cache[f"seg{si}"] = seg_cache
    x = apply_norm(p["final_norm"], x, cfg.norm)
    logits = _lm_head(p, x[:, -1:, :], cfg, ctx)[:, 0]
    return logits, cache


def _prefill_block(bp, x, cfg, block, ctx, positions, seq_len, cache_len=None):
    y, cache_entry = _PREFILL[block.mixer](
        bp["mixer"], x, cfg, block, ctx, positions, seq_len,
        cache_len or seq_len)
    x = x + y
    if block.mlp == "moe":
        y, _ = ffn_mod.apply_moe(bp["moe"], x, cfg, block, ctx)
        x = x + y
    elif block.mlp != "none":
        x = x + ffn_mod.apply_mlp(bp["mlp"], x, cfg, block, ctx)
    return x, cache_entry


# -- per-mixer prefill hooks (forward + cache extraction) -------------------


def _prefill_attn(p, x, cfg, block, ctx, positions, seq_len, cache_len):
    h = apply_norm(p["norm"], x, cfg.norm)
    q, k, v = attn_mod._qkv(p, h, cfg)
    q = attn_mod._rope(cfg, q, positions)
    k = attn_mod._rope(cfg, k, positions)
    q = ctx.shard(q, "batch", None, "heads_act", None)
    k = ctx.shard(k, "batch", None, "kv_heads_act", None)
    from .common import blockwise_attention
    o = blockwise_attention(q, k, v, causal=True, window=block.window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    y = o.reshape(*x.shape[:2], -1) @ p["wo"]
    y = ctx.shard(y, "batch", "seq_act", None)
    w = min(block.window or cache_len, cache_len)
    if w <= seq_len:  # keep the last w positions, decode-compatible slots
        slots_pos = _rolling_positions(seq_len, w)
        cache = {
            "k": jnp.take(k, slots_pos, axis=1),
            "v": jnp.take(v, slots_pos, axis=1),
            "pos": slots_pos.astype(jnp.int32),
        }
    else:  # headroom: slots [seq_len, w) stay empty
        pad = [(0, 0), (0, w - seq_len), (0, 0), (0, 0)]
        cache = {
            "k": jnp.pad(k, pad),
            "v": jnp.pad(v, pad),
            "pos": jnp.concatenate([
                jnp.arange(seq_len, dtype=jnp.int32),
                jnp.full((w - seq_len,), -1, jnp.int32)]),
        }
    return y, cache


def _rolling_positions(seq_len: int, w: int) -> jnp.ndarray:
    """positions p ∈ [S-w, S) placed at slot p % w (decode-compatible)."""
    base = seq_len - w
    offs = (jnp.arange(w) - base) % w
    return base + offs


def _prefill_mla(p, x, cfg, block, ctx, positions, seq_len, cache_len):
    h = apply_norm(p["norm"], x, cfg.norm)
    q, k, v, ckv, k_rope = attn_mod._mla_qkv(p, h, cfg, positions)
    q = ctx.shard(q, "batch", None, "heads_act", None)
    from .common import blockwise_attention
    dk, dv = q.shape[-1], v.shape[-1]
    if dv < dk:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dk - dv)))
    o = blockwise_attention(q, k, v, causal=True,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    y = o[..., :dv].reshape(*x.shape[:2], -1) @ p["wo"]
    y = ctx.shard(y, "batch", "seq_act", None)
    extra = max(cache_len - seq_len, 0)
    pad2 = [(0, 0), (0, extra), (0, 0)]
    cache = {"ckv": jnp.pad(ckv, pad2),
             "k_rope": jnp.pad(k_rope[:, :, 0, :], pad2),
             "pos": jnp.concatenate([
                 jnp.arange(seq_len, dtype=jnp.int32),
                 jnp.full((extra,), -1, jnp.int32)])}
    return y, cache


def _prefill_rglru(p, x, cfg, block, ctx, positions, seq_len, cache_len):
    h = apply_norm(p["norm"], x, cfg.norm)
    gate = jax.nn.gelu((h @ p["w_y"]).astype(jnp.float32))
    u = h @ p["w_x"]
    u, conv_state = rec_mod._causal_conv(u, p["conv_w"], p["conv_b"])
    a, gated = rec_mod._rglru_gates(p, u)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br

    _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (hs * gate).astype(x.dtype) @ p["w_out"]
    y = ctx.shard(y, "batch", "seq_act", None)
    cache = {"h": hs[:, -1], "conv": conv_state}
    return y, cache


def _prefill_mlstm(p, x, cfg, block, ctx, positions, seq_len, cache_len):
    h = apply_norm(p["norm"], x, cfg.norm)
    u = h @ p["w_up"]
    gate = jax.nn.silu(h @ p["w_gate"])
    q, k, v, i_t, f_t = xlstm_mod._mlstm_heads(p, u, cfg)
    y, carry = xlstm_mod._mlstm_chunk_scan_with_state(
        q, k, v, i_t, f_t, min(cfg.mlstm_chunk, x.shape[1]))
    y = (y.astype(x.dtype) * gate) @ p["w_down"]
    y = ctx.shard(y, "batch", "seq_act", None)
    C, n, m = carry
    return y, {"C": C, "n": n, "m": m}


def _prefill_slstm(p, x, cfg, block, ctx, positions, seq_len, cache_len):
    b, s, d = x.shape
    nh = cfg.n_lstm_heads
    dh = d // nh
    h0 = apply_norm(p["norm"], x, cfg.norm)
    xw = (h0 @ p["w_in"]).astype(jnp.float32)

    def step(carry, xt):
        return xlstm_mod._slstm_step(p, carry, xt, cfg)

    init = (jnp.zeros((b, nh, dh), jnp.float32),
            jnp.zeros((b, nh, dh), jnp.float32),
            jnp.full((b, nh, dh), -1e30, jnp.float32),
            jnp.zeros((b, nh, dh), jnp.float32))
    carry, hs = jax.lax.scan(step, init, xw.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype) @ p["w_out"]
    y = ctx.shard(y, "batch", "seq_act", None)
    return y, dict(zip(("c", "n", "m", "h"), carry))


_PREFILL = {
    "attn": _prefill_attn,
    "mla": _prefill_mla,
    "rglru": _prefill_rglru,
    "mlstm": _prefill_mlstm,
    "slstm": _prefill_slstm,
}
