"""Feed-forward blocks: dense (SwiGLU / GEGLU / GELU) and Mixture-of-Experts.

MoE uses **replicated-activation expert parallelism** inside ``shard_map``:
activations are sharded over the data axes and replicated over `model`, while
experts are sharded over `model`.  Dispatch is therefore a *local* gather
(each device selects, from its replicated token shard, the tokens routed to
its resident experts, up to capacity) and combine is a single `psum` over
`model` — the same collective a dense row-parallel MLP needs.  No all-to-all,
no (T, E, C) dispatch tensors.  This is the ESOP philosophy at the routing
level: tokens that a device's experts don't own are never fetched/computed.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from ..compat import shard_map

from .common import ShardCtx, apply_norm, dense_init, init_norm, norm_axes

# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def init_mlp(key, cfg, block) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    dt = cfg.param_dtype
    p = {"norm": init_norm(cfg), "w_down": dense_init(ks[2], (f, d), f, dt)}
    if block.mlp in ("swiglu", "geglu"):
        p["w_gate"] = dense_init(ks[0], (d, f), d, dt)
        p["w_up"] = dense_init(ks[1], (d, f), d, dt)
    else:  # gelu
        p["w_up"] = dense_init(ks[1], (d, f), d, dt)
    return p


def mlp_axes(cfg, block) -> dict:
    a = {"norm": norm_axes(cfg), "w_down": ("mlp", "embed"),
         "w_up": ("embed", "mlp")}
    if block.mlp in ("swiglu", "geglu"):
        a["w_gate"] = ("embed", "mlp")
    return a


def apply_mlp(p, x, cfg, block, ctx: ShardCtx) -> jnp.ndarray:
    h = apply_norm(p["norm"], x, cfg.norm)
    if block.mlp == "swiglu":
        a = jax.nn.silu(h @ p["w_gate"]) * (h @ p["w_up"])
    elif block.mlp == "geglu":
        a = jax.nn.gelu(h @ p["w_gate"]) * (h @ p["w_up"])
    else:
        a = jax.nn.gelu(h @ p["w_up"])
    a = ctx.shard(a, "batch", None, "mlp_act")
    from .common import row_parallel_matmul
    y = row_parallel_matmul(a, p["w_down"], ctx, "mlp_act")
    return ctx.shard(y, "batch", "seq_act", None)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(key, cfg, block) -> dict:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 7)
    dt = cfg.param_dtype
    p = {
        "norm": init_norm(cfg),
        "w_router": dense_init(ks[0], (d, e), d, jnp.float32),
        "w_gate": dense_init(ks[1], (e, d, f), d, dt),
        "w_up": dense_init(ks[2], (e, d, f), d, dt),
        "w_down": dense_init(ks[3], (e, f, d), f, dt),
    }
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["ws_gate"] = dense_init(ks[4], (d, fs), d, dt)
        p["ws_up"] = dense_init(ks[5], (d, fs), d, dt)
        p["ws_down"] = dense_init(ks[6], (fs, d), fs, dt)
    return p


def moe_axes(cfg, block) -> dict:
    a = {
        "norm": norm_axes(cfg),
        "w_router": ("embed", None),
        # expert_mlp is deliberately distinct from the dense "mlp" logical
        # axis: experts are already TP'd on the expert axis.
        "w_gate": ("expert", "embed", "expert_mlp"),
        "w_up": ("expert", "embed", "expert_mlp"),
        "w_down": ("expert", "expert_mlp", "embed"),
    }
    if cfg.n_shared_experts:
        a.update(ws_gate=("embed", "mlp"), ws_up=("embed", "mlp"),
                 ws_down=("mlp", "embed"))
    return a


def _capacity(t_local: int, cfg) -> int:
    c = math.ceil(t_local * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(1, min(t_local, max(c, min(t_local, 16))))


def _moe_local(t, vals, idx, w_gate, w_up, w_down, first_e: jnp.ndarray,
               capacity: int, cfg):
    """Dispatch/compute/combine for the experts resident on this device.

    t: (T, D) tokens; vals/idx: (T, K) top-k gates & expert ids;
    w_*: (E_l, ...) local expert weights; first_e: global id of expert 0.
    Returns the partial output (T, D) — caller psums over the expert axis.
    """
    e_l = w_gate.shape[0]
    tcount, _ = t.shape

    def one_expert(we_gate, we_up, we_down, e_off):
        e_id = first_e + e_off
        match = idx == e_id  # (T, K)
        m = jnp.any(match, axis=1)  # (T,)
        gate = jnp.sum(jnp.where(match, vals, 0.0), axis=1)  # (T,)
        # Stable priority order: routed tokens first, then position.
        order = jnp.argsort(jnp.where(m, 0, 1) * tcount + jnp.arange(tcount))
        take = order[:capacity]  # (C,) token ids (padded w/ unrouted)
        took = m[take]
        xe = t[take] * took[:, None].astype(t.dtype)  # (C, D)
        h = jax.nn.silu(xe @ we_gate) * (xe @ we_up)
        ye = (h @ we_down) * (gate[take] * took)[:, None].astype(t.dtype)
        return take, ye

    take, ye = jax.vmap(one_expert)(
        w_gate, w_up, w_down, jnp.arange(e_l))
    out = jnp.zeros_like(t)
    out = out.at[take.reshape(-1)].add(ye.reshape(-1, t.shape[1]))
    return out


def apply_moe(p, x, cfg, block, ctx: ShardCtx) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (y, aux_loss)."""
    b, s, d = x.shape
    h = apply_norm(p["norm"], x, cfg.norm)
    t_global = h.reshape(-1, d)

    # Router (tiny): computed in the auto-sharded region, fp32.
    logits = t_global.astype(jnp.float32) @ p["w_router"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, cfg.top_k)
    vals = (vals / jnp.sum(vals, -1, keepdims=True)).astype(x.dtype)

    # Load-balancing aux loss (Switch-style), fp32.
    e = cfg.n_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # (T, K, E)
    frac_tokens = jnp.mean(jnp.sum(onehot, axis=1), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux = cfg.router_aux_coef * e * jnp.sum(frac_tokens * frac_probs)

    expert_axis = ctx.rules.get("expert") if ctx.rules else None
    if ctx.mesh is None or expert_axis is None:
        part = _moe_local(t_global, vals, idx, p["w_gate"], p["w_up"],
                          p["w_down"], jnp.int32(0),
                          _capacity(t_global.shape[0], cfg), cfg)
        y = part.reshape(b, s, d)
    else:
        mesh = ctx.mesh
        batch_axis = ctx.rules.get("batch")
        tspec = P(batch_axis, None)
        ep = _axis_prod(mesh, expert_axis)
        ep_names = (expert_axis if isinstance(expert_axis, tuple)
                    else (expert_axis,))
        t_local_n = t_global.shape[0] // _axis_prod(mesh, batch_axis)
        capacity = _capacity(t_local_n, cfg)

        def inner(t_l, vals_l, idx_l, wg, wu, wd):
            idx0 = jnp.zeros((), jnp.int32)
            for name in ep_names:  # row-major index over the EP axes
                idx0 = idx0 * mesh.shape[name] + jax.lax.axis_index(name)
            first_e = idx0 * (cfg.n_experts // ep)
            part = _moe_local(t_l, vals_l, idx_l, wg, wu, wd, first_e,
                              capacity, cfg)
            return jax.lax.psum(part, ep_names)

        y = shard_map(
            inner, mesh=mesh,
            in_specs=(tspec, tspec, tspec,
                      P(expert_axis, None, None), P(expert_axis, None, None),
                      P(expert_axis, None, None)),
            out_specs=tspec,
            check_vma=False,
        )(t_global, vals, idx, p["w_gate"], p["w_up"], p["w_down"])
        y = y.reshape(b, s, d)

    if cfg.n_shared_experts:
        a = jax.nn.silu(h @ p["ws_gate"]) * (h @ p["ws_up"])
        y = y + a @ p["ws_down"]
    return ctx.shard(y, "batch", "seq_act", None), aux


def _axis_prod(mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        out = 1
        for a in axis:
            out *= mesh.shape[a]
        return out
    return mesh.shape[axis]
