"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, sequential scan with block-diagonal recurrent weights).

mLSTM recurrence (Beck et al., 2024), stabilized in log space:
    C_t = f_t C_{t-1} + i_t v_t k_tᵀ ;  n_t = f_t n_{t-1} + i_t k_t
    h_t = C_t q_t / max(|n_tᵀ q_t|, exp(-m_t))
with f_t = σ(f̃_t) (log-sigmoid cumulative decay), i_t = exp(ĩ_t), and running
stabilizer m.  The chunkwise train path processes chunks of ``cfg.mlstm_chunk``
tokens: quadratic (masked) attention within a chunk + carried (C, n, m) state
across chunks — MXU-friendly, O(S·chunk) memory, exact w.r.t. the recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx, apply_norm, dense_init, init_norm, norm_axes

_UP = 2  # mLSTM pre-up-projection factor (xLSTM paper)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, cfg, block) -> dict:
    d = cfg.d_model
    du = _UP * d
    nh = cfg.n_lstm_heads
    dh = du // nh
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    return {
        "norm": init_norm(cfg),
        "w_up": dense_init(ks[0], (d, du), d, dt),
        "w_gate": dense_init(ks[1], (d, du), d, dt),
        "w_q": dense_init(ks[2], (du, du), du, dt),
        "w_k": dense_init(ks[3], (du, du), du, dt),
        "w_v": dense_init(ks[4], (du, du), du, dt),
        "w_if": dense_init(ks[5], (du, 2 * nh), du, jnp.float32),
        "b_if": jnp.concatenate([jnp.zeros((nh,)),  # input-gate bias 0
                                 jnp.linspace(3.0, 6.0, nh)]),  # forget-gate
        "w_down": dense_init(ks[6], (du, d), du, dt),
    }


def mlstm_axes(cfg, block) -> dict:
    return {
        "norm": norm_axes(cfg),
        "w_up": ("embed", "mlp"), "w_gate": ("embed", "mlp"),
        "w_q": ("mlp", None), "w_k": ("mlp", None), "w_v": ("mlp", None),
        "w_if": ("mlp", None), "b_if": (None,),
        "w_down": ("mlp", "embed"),
    }


def _mlstm_heads(p, u, cfg):
    b, s, du = u.shape
    nh = cfg.n_lstm_heads
    dh = du // nh
    q = (u @ p["w_q"]).reshape(b, s, nh, dh) * dh ** -0.5
    k = (u @ p["w_k"]).reshape(b, s, nh, dh) * dh ** -0.5
    v = (u @ p["w_v"]).reshape(b, s, nh, dh)
    gif = u.astype(jnp.float32) @ p["w_if"] + p["b_if"]
    i_t, f_t = gif[..., :nh], gif[..., nh:]  # (B,S,H) pre-activations
    return q, k, v, i_t, f_t


def _mlstm_chunk_scan(q, k, v, i_t, f_t, chunk: int):
    """Chunkwise-parallel stabilized mLSTM.  All inputs (B, S, H, ...)."""
    b, s, nh, dh = q.shape
    s_orig = s
    if s % chunk:
        # Identity-pad to a chunk multiple: f=1 (log f = 0), i = 0
        # (ĩ = -inf) makes padded steps state-neutral; outputs are sliced.
        pad = chunk - s % chunk
        zpad = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(t, zpad) for t in (q, k, v))
        i_t = jnp.pad(i_t, [(0, 0), (0, pad), (0, 0)], constant_values=-1e30)
        f_t = jnp.pad(f_t, [(0, 0), (0, pad), (0, 0)], constant_values=1e30)
        s = s + pad
    nc = s // chunk
    f32 = jnp.float32
    # (B,S,H,*) -> (nc, B, H, chunk, *)
    rs = lambda t: t.reshape(b, nc, chunk, nh, -1).transpose(1, 0, 3, 2, 4)
    qc, kc, vc = rs(q.astype(f32)), rs(k.astype(f32)), rs(v.astype(f32))
    ic = i_t.reshape(b, nc, chunk, nh).transpose(1, 0, 3, 2).astype(f32)
    fc = f_t.reshape(b, nc, chunk, nh).transpose(1, 0, 3, 2).astype(f32)

    def step(carry, xs):
        C, n, m = carry  # (B,H,dh,dh), (B,H,dh), (B,H)
        qq, kk, vv, ii, ff = xs  # (B,H,L,*)
        logf = jax.nn.log_sigmoid(ff)  # (B,H,L)
        lb = jnp.cumsum(logf, axis=-1)  # inclusive cumulative log-decay
        # intra-chunk scores: decay from s+1..t plus input gate at s
        sc = lb[..., :, None] - lb[..., None, :] + ii[..., None, :]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        sc = jnp.where(mask, sc, -jnp.inf)
        inter = lb + m[..., None]  # (B,H,L): decay from chunk start + carry
        m_t = jnp.maximum(jnp.max(sc, axis=-1), inter)  # (B,H,L)
        w_intra = jnp.exp(sc - m_t[..., None])  # (B,H,L,L)
        g_inter = jnp.exp(inter - m_t)  # (B,H,L)

        qk = jnp.einsum("bhld,bhsd->bhls", qq, kk)
        num = (jnp.einsum("bhls,bhsd->bhld", w_intra * qk, vv)
               + g_inter[..., None] * jnp.einsum("bhld,bhde->bhle", qq, C))
        den = (jnp.einsum("bhls,bhls->bhl", w_intra, qk)
               + g_inter * jnp.einsum("bhld,bhd->bhl", qq, n))
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]

        # carry update to end of chunk
        total = lb[..., -1]  # (B,H)
        m_next = jnp.maximum(m + total,
                             jnp.max(total[..., None] - lb + ii, axis=-1))
        decay_state = jnp.exp(m + total - m_next)  # (B,H)
        w_new = jnp.exp(total[..., None] - lb + ii - m_next[..., None])  # (B,H,L)
        C_next = (decay_state[..., None, None] * C
                  + jnp.einsum("bhs,bhsd,bhse->bhde", w_new, kk, vv))
        n_next = (decay_state[..., None] * n
                  + jnp.einsum("bhs,bhsd->bhd", w_new, kk))
        return (C_next, n_next, m_next), h

    C0 = jnp.zeros((b, nh, dh, dh), f32)
    n0 = jnp.zeros((b, nh, dh), f32)
    m0 = jnp.full((b, nh), -1e30, f32)
    carry, hs = jax.lax.scan(step, (C0, n0, m0), (qc, kc, vc, ic, fc))
    # (nc, B, H, L, dh) -> (B, S, H*dh)
    out = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, nh * dh)
    return out[:, :s_orig], carry


def _mlstm_chunk_scan_with_state(q, k, v, i_t, f_t, chunk: int):
    return _mlstm_chunk_scan(q, k, v, i_t, f_t, chunk)


def apply_mlstm(p, x, cfg, block, ctx: ShardCtx, positions) -> jnp.ndarray:
    del positions
    h = apply_norm(p["norm"], x, cfg.norm)
    u = h @ p["w_up"]
    gate = jax.nn.silu(h @ p["w_gate"])
    q, k, v, i_t, f_t = _mlstm_heads(p, u, cfg)
    y, _ = _mlstm_chunk_scan(q, k, v, i_t, f_t,
                             min(cfg.mlstm_chunk, x.shape[1]))
    y = (y.astype(x.dtype) * gate) @ p["w_down"]
    return ctx.shard(y, "batch", "seq_act", None)


def init_mlstm_cache(cfg, block, batch: int, max_len: int) -> dict:
    du = _UP * cfg.d_model
    nh = cfg.n_lstm_heads
    dh = du // nh
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def mlstm_cache_axes(cfg, block) -> dict:
    return {"C": ("batch", None, None, None), "n": ("batch", None, None),
            "m": ("batch", None)}


def apply_mlstm_decode(p, x, cache, cfg, block, ctx: ShardCtx, pos) -> tuple:
    del pos
    h = apply_norm(p["norm"], x, cfg.norm)
    u = h @ p["w_up"]
    gate = jax.nn.silu(h @ p["w_gate"])
    q, k, v, i_t, f_t = _mlstm_heads(p, u, cfg)  # (B,1,H,dh)
    q, k, v = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    ii, ff = i_t[:, 0], f_t[:, 0]  # (B,H)
    C, n, m = cache["C"], cache["n"], cache["m"]
    logf = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(logf + m, ii)
    f_s = jnp.exp(logf + m - m_new)
    i_s = jnp.exp(ii - m_new)
    C = f_s[..., None, None] * C + i_s[..., None, None] * jnp.einsum(
        "bhd,bhe->bhde", k, v)
    n = f_s[..., None] * n + i_s[..., None] * k
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))
    y = (num / den[..., None]).reshape(x.shape[0], 1, -1)
    y = (y.astype(x.dtype) * gate) @ p["w_down"]
    return ctx.shard(y, "batch", "seq_act", None), {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM — scalar memory, truly sequential (recurrent R), per-head block-diag
# ---------------------------------------------------------------------------


def init_slstm(key, cfg, block) -> dict:
    d = cfg.d_model
    nh = cfg.n_lstm_heads
    dh = d // nh
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    return {
        "norm": init_norm(cfg),
        "w_in": dense_init(ks[0], (d, 4 * d), d, dt),  # z, o, i, f pre-acts
        "r": (jax.random.normal(ks[1], (4, nh, dh, dh)) * dh ** -0.5
              ).astype(jnp.float32),
        "b": jnp.concatenate([jnp.zeros((3 * d,)),
                              jnp.broadcast_to(jnp.linspace(3., 6., nh)[:, None],
                                               (nh, dh)).reshape(-1)]),
        "w_out": dense_init(ks[2], (d, d), d, dt),
    }


def slstm_axes(cfg, block) -> dict:
    return {"norm": norm_axes(cfg), "w_in": ("embed", None),
            "r": (None, None, None, None), "b": (None,),
            "w_out": ("embed", None)}


def _slstm_step(p, carry, xw, cfg):
    """One sLSTM time-step.  xw: (B, 4D) input pre-activations."""
    c, n, m, h = carry  # each (B, H, dh)
    b, nh, dh = c.shape
    d = nh * dh
    rh = jnp.einsum("bhd,ghde->bghe", h, p["r"]).reshape(b, 4 * d)
    pre = (xw + rh + p["b"]).reshape(b, 4, nh, dh)
    z = jnp.tanh(pre[:, 0])
    o = jax.nn.sigmoid(pre[:, 1])
    i_t = pre[:, 2]
    f_t = pre[:, 3]
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + m, i_t)
    i_s = jnp.exp(i_t - m_new)
    f_s = jnp.exp(logf + m - m_new)
    c_new = f_s * c + i_s * z
    n_new = f_s * n + i_s
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new, h_new), h_new


def apply_slstm(p, x, cfg, block, ctx: ShardCtx, positions) -> jnp.ndarray:
    del positions
    b, s, d = x.shape
    nh = cfg.n_lstm_heads
    dh = d // nh
    h0 = apply_norm(p["norm"], x, cfg.norm)
    xw = (h0 @ p["w_in"]).astype(jnp.float32)  # (B,S,4D)

    def step(carry, xt):
        return _slstm_step(p, carry, xt, cfg)

    init = tuple(jnp.zeros((b, nh, dh), jnp.float32) for _ in range(2)) + (
        jnp.full((b, nh, dh), -1e30, jnp.float32),
        jnp.zeros((b, nh, dh), jnp.float32))
    _, hs = jax.lax.scan(step, init, xw.transpose(1, 0, 2))  # scan over S
    y = hs.transpose(1, 0, 2, 3).reshape(b, s, d).astype(x.dtype) @ p["w_out"]
    return ctx.shard(y, "batch", "seq_act", None)


def init_slstm_cache(cfg, block, batch: int, max_len: int) -> dict:
    nh = cfg.n_lstm_heads
    dh = cfg.d_model // nh
    z = lambda: jnp.zeros((batch, nh, dh), jnp.float32)
    return {"c": z(), "n": z(), "m": jnp.full((batch, nh, dh), -1e30,
                                              jnp.float32), "h": z()}


def slstm_cache_axes(cfg, block) -> dict:
    return {k: ("batch", None, None) for k in ("c", "n", "m", "h")}


def apply_slstm_decode(p, x, cache, cfg, block, ctx: ShardCtx, pos) -> tuple:
    del pos
    b = x.shape[0]
    h0 = apply_norm(p["norm"], x, cfg.norm)
    xw = (h0 @ p["w_in"]).astype(jnp.float32)[:, 0]  # (B,4D)
    carry = (cache["c"], cache["n"], cache["m"], cache["h"])
    carry, h_new = _slstm_step(p, carry, xw, cfg)
    y = h_new.reshape(b, 1, -1).astype(x.dtype) @ p["w_out"]
    cache_new = dict(zip(("c", "n", "m", "h"), carry))
    return ctx.shard(y, "batch", "seq_act", None), cache_new
