"""RG-LRU recurrent block (RecurrentGemma) — Griffin-style.

Block: norm → {branch A: linear → GELU; branch B: linear → causal conv1d(w=4)
→ RG-LRU} → A ⊙ B → linear out.

RG-LRU recurrence (De et al., 2024):
    r_t = σ(W_r x_t),  i_t = σ(W_i x_t)
    a_t = exp(-c · softplus(Λ) · r_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 − a_t²) ⊙ (i_t ⊙ x_t)
Train path uses ``jax.lax.associative_scan`` over time (the recurrence is an
affine scan); decode carries (h, conv state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import ShardCtx, apply_norm, dense_init, init_norm, norm_axes

_C = 8.0  # RG-LRU temperature constant


def init_rglru(key, cfg, block) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 7)
    dt = cfg.param_dtype
    return {
        "norm": init_norm(cfg),
        "w_x": dense_init(ks[0], (d, w), d, dt),  # branch B in-proj
        "w_y": dense_init(ks[1], (d, w), d, dt),  # branch A (gate) in-proj
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, w)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((w,), dt),
        "w_rec_r": dense_init(ks[3], (w, w), w, dt),
        "w_rec_i": dense_init(ks[4], (w, w), w, dt),
        "lam": jnp.log(jnp.expm1(  # softplus^-1 so a^c in [0.9, 0.999]
            -jnp.log(jnp.linspace(0.9, 0.999, w)) / _C)).astype(jnp.float32),
        "w_out": dense_init(ks[5], (w, d), w, dt),
    }


def rglru_axes(cfg, block) -> dict:
    return {
        "norm": norm_axes(cfg),
        "w_x": ("embed", "mlp"), "w_y": ("embed", "mlp"),
        "conv_w": (None, "mlp"), "conv_b": ("mlp",),
        # Dedicated logical axes: default rules map lru_in->'model' (row-
        # parallel gates => all-reduce); the hillclimb flips to lru_out
        # (column-parallel => all-gather of u, 4x cheaper in bf16).
        "w_rec_r": ("lru_in", "lru_out"), "w_rec_i": ("lru_in", "lru_out"),
        "lam": ("mlp",),
        "w_out": ("mlp", "embed"),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv, width W.  x: (B,S,D); state: (B,W-1,D)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], width - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(width)) + b
    new_state = xp[:, -(width - 1):, :] if width > 1 else pad
    return y, new_state


def _rglru_gates(p, u):
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_rec_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ p["w_rec_i"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r  # (…, W) in log space
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gated


def apply_rglru(p, x, cfg, block, ctx: ShardCtx, positions) -> jnp.ndarray:
    del positions
    h = apply_norm(p["norm"], x, cfg.norm)
    gate = jax.nn.gelu((h @ p["w_y"]).astype(jnp.float32))
    u = h @ p["w_x"]
    u, _ = _causal_conv(u, p["conv_w"], p["conv_b"])
    a, gated = _rglru_gates(p, u)

    # h_t = a_t h_{t-1} + b_t — an affine scan: associative combine.
    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, bl * ar + br

    _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (hs * gate).astype(x.dtype) @ p["w_out"]
    return ctx.shard(y, "batch", "seq_act", None)


def init_rglru_cache(cfg, block, batch: int, max_len: int) -> dict:
    w = cfg.lru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), cfg.act_dtype),
    }


def rglru_cache_axes(cfg, block) -> dict:
    return {"h": ("batch", "mlp_act"), "conv": ("batch", None, "mlp_act")}


def apply_rglru_decode(p, x, cache, cfg, block, ctx: ShardCtx, pos) -> tuple:
    del pos
    h = apply_norm(p["norm"], x, cfg.norm)
    gate = jax.nn.gelu((h @ p["w_y"]).astype(jnp.float32))  # (B,1,W)
    u = h @ p["w_x"]
    u, conv_state = _causal_conv(u, p["conv_w"], p["conv_b"], cache["conv"])
    a, gated = _rglru_gates(p, u)  # (B,1,W)
    h_new = a[:, 0] * cache["h"] + gated[:, 0]
    y = (h_new[:, None, :] * gate).astype(x.dtype) @ p["w_out"]
    return ctx.shard(y, "batch", "seq_act", None), {"h": h_new, "conv": conv_state}
