"""Attention mixers: GQA (w/ sliding window, RoPE/M-RoPE, QKV bias) and MLA.

Head-count padding for TP divisibility (DESIGN.md §4):
  * query heads are padded up to a multiple of TP with zero-initialized
    wq columns / wo rows (exact at init; the padded heads are real capacity
    thereafter — recorded in the MODEL_FLOPS ratio);
  * KV heads below the TP degree are *replicated* (vLLM-style): replicas are
    initialized equal and stay equal under synchronized updates — exact GQA
    math at every step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (ShardCtx, apply_norm, apply_rope, blockwise_attention,
                     decode_attention, dense_init, init_norm, norm_axes)

# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def init_attn(key, cfg, block) -> dict:
    d, hd = cfg.d_model, cfg.eff_head_dim
    h, kv = cfg.eff_n_heads, cfg.eff_n_kv_heads
    ks = jax.random.split(key, 4)
    dt = cfg.param_dtype
    wq = dense_init(ks[0], (d, cfg.n_heads, hd), d, dt)
    if h > cfg.n_heads:  # zero-init padded query heads (exact at init)
        wq = jnp.concatenate(
            [wq, jnp.zeros((d, h - cfg.n_heads, hd), dt)], axis=1)
    n_kv_orig = min(cfg.n_kv_heads, kv)
    wk = dense_init(ks[1], (d, n_kv_orig, hd), d, dt)
    wv = dense_init(ks[2], (d, n_kv_orig, hd), d, dt)
    if kv > n_kv_orig:  # replicate KV heads to the TP degree (exact math)
        reps = kv // n_kv_orig
        wk = jnp.repeat(wk, reps, axis=1)
        wv = jnp.repeat(wv, reps, axis=1)
    wo = dense_init(ks[3], (cfg.n_heads, hd, d), cfg.n_heads * hd, dt)
    if h > cfg.n_heads:
        wo = jnp.concatenate(
            [wo, jnp.zeros((h - cfg.n_heads, hd, d), dt)], axis=0)
    p = {"wq": wq.reshape(d, h * hd), "wk": wk.reshape(d, kv * hd),
         "wv": wv.reshape(d, kv * hd), "wo": wo.reshape(h * hd, d),
         "norm": init_norm(cfg)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    return p


def attn_axes(cfg, block) -> dict:
    a = {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
         "wv": ("embed", "kv_heads"), "wo": ("heads", "embed"),
         "norm": norm_axes(cfg)}
    if cfg.qkv_bias:
        a.update(bq=("heads",), bk=("kv_heads",), bv=("kv_heads",))
    return a


def _qkv(p, x, cfg):
    b, s, _ = x.shape
    hd = cfg.eff_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(b, s, cfg.eff_n_heads, hd)
    k = k.reshape(b, s, cfg.eff_n_kv_heads, hd)
    v = v.reshape(b, s, cfg.eff_n_kv_heads, hd)
    return q, k, v


def _rope(cfg, t, positions):
    if cfg.pos == "rope":
        return apply_rope(t, positions, cfg.rope_theta)
    if cfg.pos == "mrope":
        return apply_rope(t, positions, cfg.rope_theta,
                          mrope_sections=cfg.mrope_sections)
    return t  # sinusoidal/none: positions handled at the embedding


def apply_attn(p, x, cfg, block, ctx: ShardCtx, positions) -> jnp.ndarray:
    """Full-sequence (train/prefill) GQA with blockwise flash attention."""
    h = apply_norm(p["norm"], x, cfg.norm)
    q, k, v = _qkv(p, h, cfg)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    q = ctx.shard(q, "batch", None, "heads_act", None)
    k = ctx.shard(k, "batch", None, "kv_heads_act", None)
    o = blockwise_attention(q, k, v, causal=True, window=block.window,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    o = o.reshape(*x.shape[:2], -1)
    from .common import row_parallel_matmul
    y = row_parallel_matmul(o, p["wo"], ctx, "heads_act")
    return ctx.shard(y, "batch", "seq_act", None)


def init_attn_cache(cfg, block, batch: int, max_len: int) -> dict:
    """Windowed archs keep a rolling cache of the window size only."""
    w = min(block.window or max_len, max_len)
    kv, hd = cfg.eff_n_kv_heads, cfg.eff_head_dim
    return {
        "k": jnp.zeros((batch, w, kv, hd), cfg.act_dtype),
        "v": jnp.zeros((batch, w, kv, hd), cfg.act_dtype),
        "pos": jnp.full((w,), -1, jnp.int32),
    }


def cache_axes(cfg, block) -> dict:
    return {"k": ("batch", None, "kv_heads_act", None),
            "v": ("batch", None, "kv_heads_act", None),
            "pos": (None,)}


def apply_attn_decode(p, x, cache, cfg, block, ctx: ShardCtx, pos) -> tuple:
    """One-token decode: x (B, 1, D); pos scalar int32 (current position)."""
    h = apply_norm(p["norm"], x, cfg.norm)
    q, k, v = _qkv(p, h, cfg)  # (B,1,H,hd)
    pvec = jnp.broadcast_to(pos, (x.shape[0], 1))
    if cfg.pos == "mrope":
        pvec = jnp.broadcast_to(pos, (3, x.shape[0], 1))
    q = _rope(cfg, q, pvec)
    k = _rope(cfg, k, pvec)
    w = cache["k"].shape[1]
    slot = pos % w  # rolling for windowed caches; plain append otherwise
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    positions = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.broadcast_to(pos, (1,)).astype(jnp.int32), slot, axis=0)
    o = decode_attention(q[:, 0], k_cache, v_cache, positions, pos,
                         window=block.window)
    y = o.reshape(x.shape[0], 1, -1) @ p["wo"]
    y = ctx.shard(y, "batch", "seq_act", None)
    return y, {"k": k_cache, "v": v_cache, "pos": positions}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank latent KV, decoupled RoPE, absorbed decode
# ---------------------------------------------------------------------------


def init_mla(key, cfg, block) -> dict:
    d, h = cfg.d_model, cfg.eff_n_heads
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    qn, qp, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    ks = jax.random.split(key, 6)
    dt = cfg.param_dtype
    return {
        "norm": init_norm(cfg),
        "wq_a": dense_init(ks[0], (d, qr), d, dt),
        "q_norm": {"scale": jnp.ones((qr,), jnp.float32)},
        "wq_b": dense_init(ks[1], (qr, h * (qn + qp)), qr, dt),
        "wkv_a": dense_init(ks[2], (d, kvr + qp), d, dt),
        "kv_norm": {"scale": jnp.ones((kvr,), jnp.float32)},
        "wk_b": dense_init(ks[3], (kvr, h * qn), kvr, dt),
        "wv_b": dense_init(ks[4], (kvr, h * vd), kvr, dt),
        "wo": dense_init(ks[5], (h * vd, d), h * vd, dt),
    }


def mla_axes(cfg, block) -> dict:
    return {
        "norm": norm_axes(cfg),
        "wq_a": ("embed", "lora"),
        "q_norm": {"scale": ("lora",)},
        "wq_b": ("lora", "heads"),
        "wkv_a": ("embed", "lora"),
        "kv_norm": {"scale": ("lora",)},
        "wk_b": ("lora", "heads"),
        "wv_b": ("lora", "heads"),
        "wo": ("heads", "embed"),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
            * scale).astype(x.dtype)


def _mla_qkv(p, h, cfg, positions):
    """Non-absorbed path (train/prefill): materialize per-head k, v."""
    b, s, _ = h.shape
    nh = cfg.eff_n_heads
    qn, qp, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    q = _rms(h @ p["wq_a"], p["q_norm"]["scale"]) @ p["wq_b"]
    q = q.reshape(b, s, nh, qn + qp)
    q_nope, q_rope = q[..., :qn], q[..., qn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    kv = h @ p["wkv_a"]
    ckv = _rms(kv[..., : cfg.kv_lora_rank], p["kv_norm"]["scale"])
    k_rope = apply_rope(kv[..., cfg.kv_lora_rank:][:, :, None, :], positions,
                        cfg.rope_theta)  # (B,S,1,qp) shared across heads
    k_nope = (ckv @ p["wk_b"]).reshape(b, s, nh, qn)
    v = (ckv @ p["wv_b"]).reshape(b, s, nh, vd)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, nh, qp))], -1)
    q_full = jnp.concatenate([q_nope, q_rope], -1)
    return q_full, k, v, ckv, k_rope


def apply_mla(p, x, cfg, block, ctx: ShardCtx, positions) -> jnp.ndarray:
    h = apply_norm(p["norm"], x, cfg.norm)
    q, k, v, _, _ = _mla_qkv(p, h, cfg, positions)
    q = ctx.shard(q, "batch", None, "heads_act", None)
    k = ctx.shard(k, "batch", None, "heads_act", None)
    # v head dim (vd) != qk dim: blockwise_attention handles d_k == d_v only;
    # pad v to qk dim if needed, slice after (vd=128, qk=192 for DSv3).
    dk, dv = q.shape[-1], v.shape[-1]
    if dv < dk:
        v = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, dk - dv)))
    o = blockwise_attention(q, k, v, causal=True,
                            q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    o = o[..., :dv].reshape(*x.shape[:2], -1)
    y = o @ p["wo"]
    return ctx.shard(y, "batch", "seq_act", None)


def init_mla_cache(cfg, block, batch: int, max_len: int) -> dict:
    return {
        "ckv": jnp.zeros((batch, max_len, cfg.kv_lora_rank), cfg.act_dtype),
        "k_rope": jnp.zeros((batch, max_len, cfg.qk_rope_dim), cfg.act_dtype),
        "pos": jnp.full((max_len,), -1, jnp.int32),
    }


def mla_cache_axes(cfg, block) -> dict:
    return {"ckv": ("batch", None, None), "k_rope": ("batch", None, None),
            "pos": (None,)}


def apply_mla_decode(p, x, cache, cfg, block, ctx: ShardCtx, pos) -> tuple:
    """Absorbed decode: scores/values computed in the latent space —
    the KV cache holds only (ckv, k_rope) per token (the MLA innovation)."""
    b = x.shape[0]
    nh = cfg.eff_n_heads
    qn, qp, vd = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    h = apply_norm(p["norm"], x, cfg.norm)
    pvec = jnp.broadcast_to(pos, (b, 1))
    q = _rms(h @ p["wq_a"], p["q_norm"]["scale"]) @ p["wq_b"]
    q = q.reshape(b, 1, nh, qn + qp)
    q_nope, q_rope = q[..., :qn], apply_rope(q[..., qn:], pvec, cfg.rope_theta)

    kv = h @ p["wkv_a"]
    ckv_new = _rms(kv[..., :kvr], p["kv_norm"]["scale"])  # (B,1,kvr)
    k_rope_new = apply_rope(kv[..., kvr:][:, :, None, :], pvec,
                            cfg.rope_theta)[:, :, 0, :]

    ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new, pos, 1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new, pos, 1)
    positions = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], jnp.broadcast_to(pos, (1,)).astype(jnp.int32), pos, 0)

    # Absorb wk_b into the query: q_abs[b,h,r] = Σ_n q_nope[b,h,n] wk_b[r,(h,n)]
    wk_b = p["wk_b"].reshape(kvr, nh, qn)
    q_abs = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0].astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scale = (qn + qp) ** -0.5
    s_nope = jnp.einsum("bhr,bwr->bhw", q_abs, ckv.astype(jnp.float32))
    s_rope = jnp.einsum("bhp,bwp->bhw", q_rope[:, 0].astype(jnp.float32),
                        k_rope.astype(jnp.float32))
    s = (s_nope + s_rope) * scale
    valid = (positions >= 0) & (positions <= pos)
    s = jnp.where(valid[None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhw,bwr->bhr", pr, ckv.astype(jnp.float32))
    wv_b = p["wv_b"].reshape(kvr, nh, vd)
    o = jnp.einsum("bhr,rhv->bhv", o_lat, wv_b.astype(jnp.float32))
    y = o.reshape(b, 1, nh * vd).astype(x.dtype) @ p["wo"]
    y = ctx.shard(y, "batch", "seq_act", None)
    return y, {"ckv": ckv, "k_rope": k_rope, "pos": positions}
