"""LM model substrate for the assigned architectures.

Not a paper subsystem — the workload layer exercising the kernels at
production scale (``docs/architecture.md``, "Production substrate").
"""
from .common import ShardCtx
from .transformer import (apply_decode, apply_prefill, apply_train,
                          cache_axes_tree, init_cache, init_model, model_axes)
