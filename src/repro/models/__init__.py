"""LM model substrate for the assigned architectures."""
from .common import ShardCtx
from .transformer import (apply_decode, apply_prefill, apply_train,
                          cache_axes_tree, init_cache, init_model, model_axes)
