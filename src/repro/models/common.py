"""Shared model substrate: norms, embeddings, RoPE/M-RoPE, blockwise attention,
sharding-annotation helpers, init utilities.

Parameter pytrees are plain nested dicts of arrays.  Every ``init_*`` has a
companion ``*_axes`` returning an identically-structured tree of *logical
axis* tuples; ``launch/mesh.py`` maps logical axes to mesh axes per rule set
(train = TP+FSDP, serve = TP only).
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Params = dict
Axes = dict

# ---------------------------------------------------------------------------
# Sharding annotation plumbing
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Activation-sharding context threaded through model code.

    ``rules`` maps logical activation axes -> mesh axes (or None).  When
    ``mesh`` is None (single-device smoke tests) annotations are no-ops.
    """

    mesh: Any = None
    rules: dict | None = None

    def spec(self, *logical: str | None):
        from jax.sharding import PartitionSpec
        if self.rules is None:
            return PartitionSpec()
        return PartitionSpec(*(self.rules.get(a) if a else None for a in logical))

    def shard(self, x: jnp.ndarray, *logical: str | None) -> jnp.ndarray:
        if self.mesh is None or self.rules is None:
            return x
        from jax.sharding import NamedSharding
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(*logical)))


def row_parallel_matmul(a: jnp.ndarray, w: jnp.ndarray, ctx: "ShardCtx",
                        in_rule: str) -> jnp.ndarray:
    """y = a @ w with the contraction dim sharded over ``rules[in_rule]``.

    Default path: plain matmul (GSPMD inserts the all-reduce — which this
    XLA CPU pipeline emits on the **f32 partials**, 2× the necessary
    traffic).  With act rule ``rowp`` set, the matmul+psum is hand-placed in
    shard_map and the partial is cast to the activation dtype *before* the
    psum — the collective the TPU pipeline's ConvertMover would produce.
    Beyond-paper §Perf lever.
    """
    axis = ctx.rules.get(in_rule) if ctx.rules else None
    if ctx.mesh is None or axis is None or not ctx.rules.get("rowp"):
        return a @ w
    from ..compat import shard_map
    from jax.sharding import PartitionSpec as P
    b_ax = ctx.rules.get("batch")

    def f(a_l, w_l):
        y = (a_l @ w_l).astype(a.dtype)  # half-width partial
        return jax.lax.psum(y, axis)

    return shard_map(
        f, mesh=ctx.mesh,
        in_specs=(P(b_ax, None, axis), P(axis, None)),
        out_specs=P(b_ax, None, None),
        check_vma=False,
    )(a, w)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis_size, dtype):
    """Truncated-normal fan-in init (std = 1/sqrt(fan_in))."""
    std = in_axis_size ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(cfg, with_bias: bool | None = None) -> Params:
    bias = cfg.norm == "ln" if with_bias is None else with_bias
    p = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    if bias:
        p["bias"] = jnp.zeros((cfg.d_model,), jnp.float32)
    return p


def norm_axes(cfg, with_bias: bool | None = None) -> Axes:
    bias = cfg.norm == "ln" if with_bias is None else with_bias
    a = {"scale": ("embed",)}
    if bias:
        a["bias"] = ("embed",)
    return a


def apply_norm(p: Params, x: jnp.ndarray, kind: str, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if kind == "rms":
        y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    else:  # layernorm
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"]
    if "bias" in p:
        y = y + p["bias"]
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10000.0,
               mrope_sections: tuple[int, ...] | None = None) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) or (3, B, S) for M-RoPE.

    M-RoPE (Qwen2-VL): the D/2 frequency slots are split into sections
    (t, h, w); each section uses its own position stream.
    """
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    if positions.ndim == 2:
        ang = positions[..., None].astype(jnp.float32) * freqs  # (B,S,D/2)
    else:
        assert mrope_sections is not None and sum(mrope_sections) == d // 2
        parts = []
        start = 0
        for sec_i, sec in enumerate(mrope_sections):
            f = freqs[start:start + sec]
            parts.append(positions[sec_i][..., None].astype(jnp.float32) * f)
            start += sec
        ang = jnp.concatenate(parts, axis=-1)  # (B,S,D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Sinusoidal positions (MusicGen)
# ---------------------------------------------------------------------------


def sinusoidal_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """positions: (B, S) -> (B, S, D) classic transformer sin/cos table."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure jnp, memory-bounded
# ---------------------------------------------------------------------------


def blockwise_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = True, window: int | None = None,
                        q_chunk: int = 512, kv_chunk: int = 1024,
                        ) -> jnp.ndarray:
    """q: (B, S, H, D); k, v: (B, S, KVH, D) with H % KVH == 0 (GQA).

    Streams KV chunks with running softmax stats — O(S·chunk) memory.
    ``window`` applies a sliding-window causal mask (StarCoder2, rgemma
    local attention).
    """
    b, s, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, s)
    nq, nkv = s // q_chunk, s // kv_chunk
    assert s % q_chunk == 0 and s % kv_chunk == 0, (s, q_chunk, kv_chunk)
    scale = d ** -0.5

    # (B, S, H, D) -> (nq, B, H, q_chunk, D); scale applied in input dtype
    qr = (q * jnp.asarray(scale, q.dtype)).reshape(
        b, nq, q_chunk, h, d).transpose(1, 0, 3, 2, 4)
    kr = k.reshape(b, nkv, kv_chunk, kvh, d).transpose(1, 0, 3, 2, 4)
    vr = v.reshape(b, nkv, kv_chunk, kvh, d).transpose(1, 0, 3, 2, 4)

    def per_q_chunk(args):
        qi, qc = args  # scalar, (B, H, q_chunk, D)
        qg = qc.reshape(b, kvh, groups * q_chunk, d)  # group heads onto kv heads

        def kv_step(carry, args2):
            m, l, acc = carry
            ki, kc, vc = args2
            # NOTE (§Perf, refuted iteration): computing this from bf16
            # operands with f32 accumulation is standard flash numerics and
            # strictly better on a real TPU, but under the CPU-HLO proxy
            # metric the inserted converts materialize extra buffers
            # (+11% memory term) — kept in f32 for metric consistency.
            sc = jnp.einsum("bkqd,bkcd->bkqc", qg.astype(jnp.float32),
                            kc.astype(jnp.float32))
            # Grouped-head layout is (g, q) along dim 2: positions tile per group.
            qp = jnp.tile(jnp.arange(q_chunk), groups) + qi * q_chunk  # (G*qc,)
            kp = ki * kv_chunk + jnp.arange(kv_chunk)  # (kvc,)
            mask = jnp.ones((groups * q_chunk, kv_chunk), bool)
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= qp[:, None] - kp[None, :] < window
            sc = jnp.where(mask, sc, -1e30)
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkqc,bkcd->bkqd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kvh, groups * q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((b, kvh, groups * q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kvh, groups * q_chunk, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nkv), kr, vr))
        o = acc / jnp.maximum(l, 1e-30)[..., None]
        return o.reshape(b, kvh, groups, q_chunk, d).transpose(0, 3, 1, 2, 4) \
                .reshape(b, q_chunk, h, d).astype(q.dtype)

    out = jax.lax.map(per_q_chunk, (jnp.arange(nq), qr))  # (nq, B, qc, H, D)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s, h, d)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     kv_positions: jnp.ndarray, q_position: jnp.ndarray,
                     window: int | None = None) -> jnp.ndarray:
    """Single-token decode attention over a (possibly rolling) KV cache.

    q: (B, H, D); caches: (B, W, KVH, D); kv_positions: (W,) absolute
    positions of cache slots (-1 = empty); q_position: scalar.
    """
    b, h, d = q.shape
    kvh = k_cache.shape[2]
    groups = h // kvh
    scale = d ** -0.5
    qg = q.reshape(b, kvh, groups, d).astype(jnp.float32) * scale
    sc = jnp.einsum("bkgd,bwkd->bkgw", qg, k_cache.astype(jnp.float32))
    valid = (kv_positions >= 0) & (kv_positions <= q_position)
    if window is not None:
        valid &= q_position - kv_positions < window
    sc = jnp.where(valid[None, None, None, :], sc, -1e30)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bkgw,bwkd->bkgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, h, d).astype(q.dtype)
