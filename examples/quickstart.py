"""Quickstart: the TriADA engine in five minutes.

Runs a forward+inverse 3D DCT via the three-stage outer-product GEMT, shows
the linear time-step count on the simulated cell device, and the ESOP
savings on sparse data.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (coefficient_matrix, dxt3d, energy_joules, esop_gemt3,
                        gemt3, macs, prune, simulate_dxt3, time_steps)


def main():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 12, 20)).astype(np.float32))

    # --- forward / inverse 3D DCT (any sizes; no power-of-two limits) ----
    y = dxt3d(x, "dct")
    xr = dxt3d(y, "dct", inverse=True)
    print(f"3D DCT roundtrip max|err| = {float(jnp.max(jnp.abs(xr - x))):.2e}")

    # --- the isomorphic device: linear time-steps, hypercubic MACs -------
    cs = [np.asarray(coefficient_matrix("dct", n)) for n in x.shape]
    out, stats = simulate_dxt3(np.asarray(x), *cs, esop=False)
    np.testing.assert_allclose(out, gemt3(x, *map(jnp.asarray, cs)),
                               rtol=1e-3, atol=1e-3)
    print(f"cell grid {x.shape}: {stats.steps_done} time-steps "
          f"(= N1+N2+N3 = {time_steps(*x.shape)}), "
          f"{stats.macs_done:,} MACs (= N1N2N3(N1+N2+N3) = {macs(*x.shape):,})")

    # --- ESOP on sparse data ---------------------------------------------
    xs = prune(x, 0.8)  # sparsify 'insignificant' values
    _, st = esop_gemt3(xs, *map(jnp.asarray, cs))
    e = energy_joules(st)
    print(f"ESOP on {100 * float(jnp.mean(xs == 0)):.0f}%-sparse data: "
          f"{100 * st.mac_savings:.0f}% MACs skipped, "
          f"{100 * e['saving']:.0f}% dynamic energy saved "
          f"(result bit-identical to dense)")


if __name__ == "__main__":
    main()
