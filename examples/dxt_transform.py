"""The paper's own workload end-to-end: 3D discrete transforms (DFT/DCT/
DHT/DWHT) through all three formulations — inner-product, outer-product
(TriADA), and the simulated cell device — plus the Pallas SR-GEMM kernel
backing one stage.

    PYTHONPATH=src python examples/dxt_transform.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (coefficient_matrix, gemt3, gemt3_outer, simulate_dxt3)
from repro.kernels import sr_gemm


def main():
    rng = np.random.default_rng(1)
    dims = (12, 10, 14)
    x = jnp.asarray(rng.normal(size=dims).astype(np.float32))

    for kind in ("dct", "dht", "dwht" if all((n & (n - 1)) == 0
                                             for n in dims) else "dct"):
        cs = [coefficient_matrix(kind, n) for n in dims]
        y_inner = gemt3(x, *cs)            # Eq. (4): inner-product staging
        y_outer = gemt3_outer(x, *cs)      # Eq. (6): rank-1 update streams
        y_cells, stats = simulate_dxt3(np.asarray(x), *map(np.asarray, cs))
        err_o = float(jnp.max(jnp.abs(y_outer - y_inner)))
        err_c = float(np.max(np.abs(y_cells - np.asarray(y_inner))))
        print(f"{kind}: inner vs outer {err_o:.2e}, vs cell device {err_c:.2e},"
              f" time-steps={stats.steps_done}")

    # One stage of the chain on the SR-GEMM kernel (Stage I: X ×₃ C3),
    # exercising the streamed-coefficient dataflow (interpret mode on CPU).
    c3 = coefficient_matrix("dct", dims[2])
    x_mat = x.reshape(-1, dims[2])  # horizontal slices stacked: (N1·N2, N3)
    y_kernel = sr_gemm(x_mat, c3, use_pallas=True)
    y_ref = x_mat @ c3
    print(f"SR-GEMM kernel stage error: "
          f"{float(jnp.max(jnp.abs(y_kernel - y_ref))):.2e}")


if __name__ == "__main__":
    main()
