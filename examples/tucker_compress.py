"""Tensor compression via the GEMT engine (paper §2.3): Tucker round trip
with rectangular coefficient matrices, plus the TriadaDense layer.

    PYTHONPATH=src python examples/tucker_compress.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (apply_triada_dense, gemt3, hosvd, init_triada_dense,
                        tucker_compress, tucker_expand, tucker_roundtrip_error)


def main():
    rng = np.random.default_rng(0)
    # A compressible tensor: low-rank core + noise
    g = rng.normal(size=(4, 4, 4))
    us = [np.linalg.qr(rng.normal(size=(n, 4)))[0] for n in (24, 20, 28)]
    x = jnp.asarray(np.einsum("abc,xa,yb,zc->xyz", g, *us)
                    + 0.01 * rng.normal(size=(24, 20, 28)))

    for ranks in [(4, 4, 4), (8, 8, 8), (16, 16, 16)]:
        r = tucker_roundtrip_error(x, ranks)
        print(f"ranks={ranks}: rel_err={r['rel_fro_err']:.4f} "
              f"compression={r['compression']:.1f}x")

    # TriadaDense: factorized projection as an NN layer
    p = init_triada_dense(jax.random.PRNGKey(0), 256, 512, rank=32)
    y = apply_triada_dense(p, jnp.asarray(rng.normal(size=(8, 256)),
                                          jnp.float32))
    n_full = 256 * 512
    n_fact = sum(v.size for v in p.values())
    print(f"TriadaDense out {y.shape}; params {n_fact:,} vs dense {n_full:,} "
          f"({n_full / n_fact:.1f}x fewer)")


if __name__ == "__main__":
    main()
