"""Tensor compression via the GEMT engine (paper §2.3): Tucker round trip
with rectangular coefficient matrices, the TriadaDense layer, and a
gradient-descent Tucker-factor fitting loop running *through* the
differentiable engine (forward and backward both engine-lowered).

    PYTHONPATH=src python examples/tucker_compress.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (apply_triada_dense, gemt3, hosvd, init_triada_dense,
                        tucker_compress, tucker_expand, tucker_roundtrip_error)
from repro.engine import (gemt3_planned, grad_stats, macs_for_order,
                          plan_gemt3, reset_grad_stats)


def fit_tucker_factors(x, ranks, steps: int = 40, lr: float = 0.05,
                       perturb: float = 0.0, seed: int = 0):
    """Refine truncated-HOSVD factors by gradient descent on the
    reconstruction error, with compression *and* expansion running through
    the planned engine's custom VJP — every backward pass is itself an
    adjoint-planned GEMT plus SR-GEMM factor updates (docs/engine.md,
    "Differentiation").  ``perturb`` adds Gaussian noise to the HOSVD
    start (fitting must then recover the subspaces)."""
    factors = list(hosvd(x, ranks))
    if perturb:
        noise = np.random.default_rng(seed)
        factors = [f + perturb * jnp.asarray(
            noise.normal(size=f.shape).astype(np.float32)) for f in factors]

    def loss_fn(fs):
        core = gemt3_planned(x, fs[0], fs[1], fs[2], differentiable=True)
        xhat = gemt3_planned(core, fs[0].T, fs[1].T, fs[2].T,
                             differentiable=True)
        return jnp.mean(jnp.square(xhat - x))

    grad_fn = jax.value_and_grad(loss_fn)
    losses = []
    for _ in range(steps):
        loss, grads = grad_fn(factors)
        factors = [f - lr * g for f, g in zip(factors, grads)]
        losses.append(float(loss))
    return factors, losses


def main():
    rng = np.random.default_rng(0)
    # A compressible tensor: low-rank core + noise
    g = rng.normal(size=(4, 4, 4))
    us = [np.linalg.qr(rng.normal(size=(n, 4)))[0] for n in (24, 20, 28)]
    x = jnp.asarray(np.einsum("abc,xa,yb,zc->xyz", g, *us)
                    + 0.01 * rng.normal(size=(24, 20, 28)))

    for ranks in [(4, 4, 4), (8, 8, 8), (16, 16, 16)]:
        r = tucker_roundtrip_error(x, ranks)
        print(f"ranks={ranks}: rel_err={r['rel_fro_err']:.4f} "
              f"compression={r['compression']:.1f}x")

    # Planned engine: the cost model contracts compressive modes first, so
    # Tucker compression costs far fewer MACs than the default (3,1,2) chain.
    factors = hosvd(x, (2, 8, 8))  # strongly compressive mode 1
    plan = plan_gemt3(x.shape, x.dtype, *factors)
    default_macs = macs_for_order(x.shape, tuple(f.shape[1] for f in factors),
                                  (3, 1, 2))
    core_ref = tucker_compress(x, factors)
    core_eng, info = gemt3_planned(x, *factors, with_info=True)
    err = float(jnp.max(jnp.abs(core_eng - core_ref)))
    print(f"engine: order={plan.order} backends={plan.backends} "
          f"macs={plan.macs:,} (default order: {default_macs:,}, "
          f"{default_macs / plan.macs:.1f}x more); |engine-einsum|={err:.2e}")

    # Differentiable engine: gradient-recover perturbed HOSVD factors.
    # The descent runs entirely through the engine's custom VJP.
    reset_grad_stats()
    _, losses = fit_tucker_factors(x, (2, 8, 8), steps=80, lr=0.5,
                                   perturb=0.1)
    gs = grad_stats()
    print(f"factor fitting: loss {losses[0]:.5f} -> {losses[-1]:.5f} "
          f"({losses[0] / max(losses[-1], 1e-12):.2f}x better); "
          f"backward passes={gs['backward_calls']} "
          f"grad kernel stages={gs['kernel_stages'] + gs['coeff_kernel']}")

    # TriadaDense: factorized projection as an NN layer
    p = init_triada_dense(jax.random.PRNGKey(0), 256, 512, rank=32)
    y = apply_triada_dense(p, jnp.asarray(rng.normal(size=(8, 256)),
                                          jnp.float32))
    n_full = 256 * 512
    n_fact = sum(v.size for v in p.values())
    print(f"TriadaDense out {y.shape}; params {n_fact:,} vs dense {n_full:,} "
          f"({n_full / n_fact:.1f}x fewer)")


if __name__ == "__main__":
    main()
