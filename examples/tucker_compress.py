"""Tensor compression via the GEMT engine (paper §2.3): Tucker round trip
with rectangular coefficient matrices, plus the TriadaDense layer.

    PYTHONPATH=src python examples/tucker_compress.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (apply_triada_dense, gemt3, hosvd, init_triada_dense,
                        tucker_compress, tucker_expand, tucker_roundtrip_error)
from repro.engine import gemt3_planned, macs_for_order, plan_gemt3


def main():
    rng = np.random.default_rng(0)
    # A compressible tensor: low-rank core + noise
    g = rng.normal(size=(4, 4, 4))
    us = [np.linalg.qr(rng.normal(size=(n, 4)))[0] for n in (24, 20, 28)]
    x = jnp.asarray(np.einsum("abc,xa,yb,zc->xyz", g, *us)
                    + 0.01 * rng.normal(size=(24, 20, 28)))

    for ranks in [(4, 4, 4), (8, 8, 8), (16, 16, 16)]:
        r = tucker_roundtrip_error(x, ranks)
        print(f"ranks={ranks}: rel_err={r['rel_fro_err']:.4f} "
              f"compression={r['compression']:.1f}x")

    # Planned engine: the cost model contracts compressive modes first, so
    # Tucker compression costs far fewer MACs than the default (3,1,2) chain.
    factors = hosvd(x, (2, 8, 8))  # strongly compressive mode 1
    plan = plan_gemt3(x.shape, x.dtype, *factors)
    default_macs = macs_for_order(x.shape, tuple(f.shape[1] for f in factors),
                                  (3, 1, 2))
    core_ref = tucker_compress(x, factors)
    core_eng, info = gemt3_planned(x, *factors, with_info=True)
    err = float(jnp.max(jnp.abs(core_eng - core_ref)))
    print(f"engine: order={plan.order} backends={plan.backends} "
          f"macs={plan.macs:,} (default order: {default_macs:,}, "
          f"{default_macs / plan.macs:.1f}x more); |engine-einsum|={err:.2e}")

    # TriadaDense: factorized projection as an NN layer
    p = init_triada_dense(jax.random.PRNGKey(0), 256, 512, rank=32)
    y = apply_triada_dense(p, jnp.asarray(rng.normal(size=(8, 256)),
                                          jnp.float32))
    n_full = 256 * 512
    n_fact = sum(v.size for v in p.values())
    print(f"TriadaDense out {y.shape}; params {n_fact:,} vs dense {n_full:,} "
          f"({n_full / n_fact:.1f}x fewer)")


if __name__ == "__main__":
    main()
