"""Batched serving: prefill + decode with KV cache, greedy and sampled,
slot-managed continuous batching.

    PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import serve


def main():
    serve("qwen1_5_0_5b", batch=4, prompt_len=12, max_new=24)
    serve("starcoder2_7b", batch=2, prompt_len=12, max_new=12,
          temperature=0.8)  # windowed (rolling-cache) arch


if __name__ == "__main__":
    main()
