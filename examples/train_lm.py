"""End-to-end LM training driver: data pipeline → sharding rules → jitted
train_step → resilient loop with checkpointing and a simulated node failure.

Defaults are sized for this CPU container (a ~1M-param qwen-family smoke
config, 150 steps); `--full` trains a ~100M-class model (slow on CPU, the
configuration a pod run would use).

    PYTHONPATH=src python examples/train_lm.py [--steps N] [--full]
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--arch", default="qwen1_5_0_5b")
    ap.add_argument("--full", action="store_true",
                    help="full published config (pod-scale; slow on CPU)")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a simulated node failure at this step")
    args = ap.parse_args()

    fail = {args.fail_at} if args.fail_at is not None else {args.steps // 2}
    state, report = train(
        args.arch,
        steps=args.steps,
        seq_len=256 if not args.full else 4096,
        global_batch=8,
        smoke=not args.full,
        ckpt_dir="artifacts/example_ckpt",
        ckpt_every=25,
        fail_at=fail,
    )
    print(f"final: steps={report.steps_done} restarts={report.restarts} "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}")
    assert report.losses[-1] < report.losses[0], "loss should decrease"


if __name__ == "__main__":
    main()
