"""S2: steady-state serving throughput — warmup, coalescing, pipelining.

The same request stream (tiny single-item S-series batches, where host
dispatch overhead dominates device work) is served twice by a
:class:`ResilientDxtServer`:

* **serial** — the historical one-request-at-a-time drain
  (``max_coalesce=1``, ``pipeline_depth=1``);
* **coalesced** — bucket-coalesced launches with double-buffered dispatch
  (``max_coalesce=8``, ``pipeline_depth=2``).

Both servers are warmed first (:meth:`ResilientDxtServer.warmup` over the
request bucket), so the steady-state phase must pay **zero** plan builds
and autotune probes — the row records the steady-state ``plan*`` /
``autotune*`` span counts as deterministic keys to pin that down, next to
the banded throughput keys (requests/sec, queue-inclusive p99 latency,
and attainment against the serial run's p99 as the SLO).  ``max_abs_err``
is the worst deviation of any coalesced result from its serial
counterpart — de-stacking must be numerically invisible.
"""
from __future__ import annotations

import os
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.serve import ResilientDxtServer

_N = 16  # S-series transform dims (N, N, N)
_REQUESTS = 32
_MAX_COALESCE = 8


def _percentile(vals, q):
    vals = sorted(vals)
    idx = int(round(q / 100.0 * (len(vals) - 1)))
    return vals[min(max(idx, 0), len(vals) - 1)]


def _serve(reqs, *, coalesce: bool, cache_path: str):
    """Warm a server, serve the stream, return (requests, stats, spans)."""
    with obs.session(name="bench-serve-throughput",
                     enable_tracing=True) as s:
        server = ResilientDxtServer(
            kind="dct", autotune=True, autotune_cache=cache_path,
            max_coalesce=_MAX_COALESCE if coalesce else 1,
            coalesce_window_s=60.0 if coalesce else 0.0,
            pipeline_depth=2 if coalesce else 1)
        server.warmup([(_MAX_COALESCE, _N, _N, _N)])
        n_warm = len(s.tracer.spans())
        t0 = time.perf_counter()
        rs = [server.submit(r) for r in reqs]
        server.drain()
        jax.block_until_ready([r.result for r in rs])
        wall_s = time.perf_counter() - t0
        steady = [sp.name for sp in s.tracer.spans()[n_warm:]]
        return rs, server.stats(), steady, wall_s


def bench_serve_throughput(rows):
    rng = np.random.default_rng(29)
    reqs = [jnp.asarray(rng.normal(size=(1, _N, _N, _N)).astype(np.float32))
            for _ in range(_REQUESTS)]

    with tempfile.TemporaryDirectory() as tmp:
        cache = os.path.join(tmp, "autotune.json")
        ser_rs, ser_st, ser_spans, ser_wall = _serve(
            reqs, coalesce=False, cache_path=cache)
        co_rs, co_st, co_spans, co_wall = _serve(
            reqs, coalesce=True, cache_path=cache)

    err = max(float(jnp.max(jnp.abs(a.result - b.result)))
              for a, b in zip(co_rs, ser_rs))
    # Queue-inclusive per-request latency (submit -> finish, server clock);
    # the serial run's p99 is the SLO the coalesced run is held to.
    ser_lat = [(r.finished_at - r.submitted_at) * 1e6 for r in ser_rs]
    co_lat = [(r.finished_at - r.submitted_at) * 1e6 for r in co_rs]
    slo_us = _percentile(ser_lat, 99)
    attain = sum(1 for v in co_lat if v <= slo_us) / len(co_lat)
    rps_serial = _REQUESTS / max(ser_wall, 1e-9)
    rps_coalesced = _REQUESTS / max(co_wall, 1e-9)

    def _steady(spans):
        return sum(1 for n in spans
                   if n == "plan" or n.startswith("autotune"))

    rows.append((
        "S2_serve_throughput_coalesced", co_wall / _REQUESTS * 1e6,
        f"serial_per_req_us={ser_wall / _REQUESTS * 1e6:.1f};"
        f"rps_serial={rps_serial:.1f};"
        f"rps_coalesced={rps_coalesced:.1f};"
        f"coalesced_vs_serial_speedup={rps_coalesced / rps_serial:.2f}x;"
        f"serial_p99_us={_percentile(ser_lat, 99):.1f};"
        f"coalesced_p99_us={_percentile(co_lat, 99):.1f};"
        f"slo_us={slo_us:.1f};"
        f"slo_attainment_coalesced={attain:.2f};"
        f"requests={_REQUESTS};"
        f"admitted={co_st['admitted']};"
        f"completed={co_st['completed']};"
        f"failed={co_st['failed']};"
        f"retries={co_st['retries']};"
        f"batches={co_st['batches']};"
        f"coalesced={co_st['coalesced']};"
        f"plan_spans_steady_serial={_steady(ser_spans)};"
        f"plan_spans_steady_coalesced={_steady(co_spans)};"
        f"warmed_buckets={len(ser_st['session']['warmed'])};"
        f"max_abs_err={err:.1e}"))
