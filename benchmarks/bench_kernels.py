"""Kernel-level benchmarks: SR-GEMM / block-ESOP structural metrics.

On this CPU container the Pallas kernels run in interpret mode, so
wall-clock is meaningless for the TPU target; we report the *structural*
quantities that determine TPU performance — VMEM working set, arithmetic
intensity, streamed-block savings — plus the XLA-CPU reference GEMM time as
a sanity baseline.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import block_nonzero_mask
from repro.kernels.esop_gemm import esop_plan


def _vmem_bytes(bm, bn, bk, dtype_bytes=4):
    # resident acc (fp32) + streamed X and C blocks (double-buffered)
    return bm * bn * 4 + 2 * (bm * bk + bk * bn) * dtype_bytes


def bench_sr_gemm_structure(rows):
    for bm, bn, bk in [(128, 128, 128), (256, 256, 128), (512, 256, 128)]:
        vmem = _vmem_bytes(bm, bn, bk, 2)
        flops_per_block = 2 * bm * bn * bk
        bytes_per_block = (bm * bk + bk * bn) * 2  # streamed operands, bf16
        ai = flops_per_block / bytes_per_block
        rows.append((f"K1_sr_gemm_{bm}x{bn}x{bk}", 0.0,
                     f"vmem_kb={vmem / 1024:.0f};arith_intensity={ai:.0f};"
                     f"fits_vmem={vmem < 16 * 2**20}"))


def bench_esop_plan(rows):
    """Streamed-block fetch savings vs block sparsity of C."""
    rng = np.random.default_rng(0)
    k = n = 2048
    for keep in (1.0, 0.5, 0.25):
        c = rng.normal(size=(k, n)).astype(np.float32)
        mask = rng.random((k // 128, n // 128)) < keep
        for i in range(k // 128):
            for j in range(n // 128):
                if not mask[i, j]:
                    c[i * 128:(i + 1) * 128, j * 128:(j + 1) * 128] = 0
        t0 = time.perf_counter()
        counts, idx, t_steps = esop_plan(jnp.asarray(c), 128, 128)
        dt = (time.perf_counter() - t0) * 1e6
        dense_blocks = (k // 128) * (n // 128)
        rows.append((f"K2_esop_plan_keep{keep}", dt,
                     f"fetch_savings={1 - counts.sum() / dense_blocks:.3f};"
                     f"t_steps={t_steps}/{k // 128}"))


def bench_xla_gemm_baseline(rows):
    """XLA-CPU GEMM throughput: the reference the kernels are checked against."""
    rng = np.random.default_rng(1)
    for m, k, n in [(512, 512, 512), (1024, 1024, 1024)]:
        x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
        f = jax.jit(lambda a, b: a @ b)
        f(x, c).block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            y = f(x, c)
        y.block_until_ready()
        dt = (time.perf_counter() - t0) / 5
        gflops = 2 * m * k * n / dt / 1e9
        rows.append((f"K3_xla_gemm_{m}", dt * 1e6, f"gflops={gflops:.1f}"))
