"""Engine benchmarks: planner order choice, block-ESOP dispatch, autotune.

  E1 planner order      cost model beats the hard-coded (3,1,2) chain on
                        rectangular (Tucker) shapes — fewer MACs and smaller
                        intermediates by contracting compressive modes first
  E2 esop dispatch      block-sparse C engages the block-ESOP path and the
                        reported fetch_savings tracks the zero-block fraction
  E3 planned vs einsum  end-to-end planned execution vs the einsum chain
  E4 autotune cache     cold hill-climb vs warm JSON-cache hit
  F1 fused GEMT         fused two-stage kernel vs staged execution on the
                        default DCT serving shapes: wall-clock both ways and
                        the analytic HBM-bytes-moved model (the intermediate
                        round-trip + transpose the fusion deletes)
  F2 fused3 GEMT        whole-transform megakernel (all three contractions,
                        both intermediates VMEM-resident) vs the fused pair
                        vs staged: wall-clock three ways + the HBM model;
                        shapes where the triple declines document the
                        triple -> pair graceful degradation
  G1 grad engine        forward+backward through the differentiable engine
                        (custom VJP: adjoint-planned GEMT + SR-GEMM factor
                        updates) vs jax.grad of the einsum chain — gradient
                        equivalence, backward dispatch counters, wall-clock
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import gemt3
from repro.engine import (AutotuneCache, autotune_gemm, gemt3_planned,
                          grad_stats, macs_for_order, order_costs,
                          plan_gemt3, reset_grad_stats)

from .bench_core import _t


def _tmin_interleaved(fns, n=9):
    """Best-of-n wall clock (us) for several callables, rounds interleaved.

    Interleaving (with the within-round order alternating) means every
    candidate sees the same drifting background load, so A/B comparisons
    stay meaningful on noisy shared hosts where back-to-back best-of runs
    can flip by 2x.
    """
    def once(fn):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())  # accepts pytrees, incl. (y, info)
        return (time.perf_counter() - t0) * 1e6

    for fn in fns:
        once(fn)  # warmup/compile
    best = [float("inf")] * len(fns)
    for r in range(n):
        order = range(len(fns)) if r % 2 == 0 else reversed(range(len(fns)))
        for i in order:
            best[i] = min(best[i], once(fns[i]))
    return best


def _tucker_problem(dims=(64, 48, 32), ranks=(4, 24, 24), seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=dims).astype(np.float32))
    cs = tuple(jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
               for n, k in zip(dims, ranks))
    return x, cs


def bench_planner_order(rows):
    """E1: planner-chosen order vs the default (3,1,2) on a Tucker shape."""
    dims, ranks = (64, 48, 32), (4, 24, 24)  # mode 1 strongly compressive
    x, cs = _tucker_problem(dims, ranks)
    t0 = time.perf_counter()
    plan = plan_gemt3(x.shape, x.dtype, *cs)
    plan_us = (time.perf_counter() - t0) * 1e6
    default_macs = macs_for_order(dims, ranks, (3, 1, 2))
    costs = order_costs(dims, {1: cs[0], 2: cs[1], 3: cs[2]})
    worst = max(c["macs"] for c in costs.values())
    rows.append((f"E1_planner_order_N{dims}_K{ranks}", plan_us,
                 f"order={plan.order};planned_macs={plan.macs};"
                 f"default_macs={default_macs};worst_macs={worst};"
                 f"planned_le_default={plan.macs <= default_macs};"
                 f"speedup_vs_default={default_macs / plan.macs:.2f}x"))


def bench_esop_dispatch(rows):
    """E2: >=50%-block-sparse C must engage block-ESOP with fetch savings."""
    rng = np.random.default_rng(1)
    n3, k3, blk = 256, 256, 64
    x = jnp.asarray(rng.normal(size=(32, 16, n3)).astype(np.float32))
    keep = rng.random((n3 // blk, k3 // blk)) >= 0.5  # ~50% zero blocks
    c3 = jnp.asarray((np.kron(keep, np.ones((blk, blk)))
                      * rng.normal(size=(n3, k3))).astype(np.float32))
    c1 = jnp.asarray(np.eye(32, dtype=np.float32))
    c2 = jnp.asarray(np.eye(16, dtype=np.float32))
    us = _t(lambda: gemt3_planned(x, c1, c2, c3, block_sizes=(128, blk, blk)))
    y, info = gemt3_planned(x, c1, c2, c3, block_sizes=(128, blk, blk),
                            with_info=True)
    err = float(jnp.max(jnp.abs(y - gemt3(x, c1, c2, c3))))
    zero_frac = 1.0 - float(keep.mean())
    rows.append((f"E2_esop_dispatch_{n3}x{k3}_b{blk}", us,
                 f"backends={'/'.join(info['backends'])};"
                 f"zero_block_frac={zero_frac:.2f};"
                 f"fetch_savings={info['fetch_savings']:.3f};"
                 f"esop_engaged={info['fetch_savings'] > 0};"
                 f"max_abs_err={err:.1e}"))


def bench_planned_vs_einsum(rows):
    """E3: planned engine vs the einsum chain, default and planned order."""
    dims, ranks = (96, 64, 48), (8, 32, 32)
    x, cs = _tucker_problem(dims, ranks, seed=2)
    us_default = _t(lambda: gemt3(x, *cs, order=(3, 1, 2)))
    us_engine = _t(lambda: gemt3_planned(x, *cs))
    plan = plan_gemt3(x.shape, x.dtype, *cs)
    rows.append((f"E3_planned_vs_einsum_N{dims}", us_engine,
                 f"einsum_default_us={us_default:.1f};order={plan.order};"
                 f"mac_ratio={macs_for_order(dims, ranks, (3, 1, 2)) / plan.macs:.2f}"))


def bench_autotune_cache(rows):
    """E4: cold tune (hill-climb on TPU, default selection off-TPU) vs
    warm JSON-cache hit."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(256, 128)).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(128, 256)).astype(np.float32))
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "autotune.json")
        cache = AutotuneCache(path)
        t0 = time.perf_counter()
        cfg = autotune_gemm(x, c, "sr_gemm", cache=cache)
        cold_us = (time.perf_counter() - t0) * 1e6
        warm = AutotuneCache(path)  # fresh object, JSON round trip
        t0 = time.perf_counter()
        cfg2 = autotune_gemm(x, c, "sr_gemm", cache=warm)
        warm_us = (time.perf_counter() - t0) * 1e6
    rows.append(("E4_autotune_cache_256x256x128", cold_us,
                 f"blocks={cfg[0]}x{cfg[1]}x{cfg[2]};warm_us={warm_us:.0f};"
                 f"roundtrip_ok={cfg == cfg2}"))


def bench_fused_gemt(rows):
    """F1: fused *pair* vs staged on the default DCT serving shapes.

    The fused kernel must be numerically equivalent, move >= 1.5x fewer
    modeled HBM bytes (the intermediate's write/read + transpose copy it
    deletes) and be no slower in wall-clock on every benched shape.
    ``fuse="pair"`` pins the depth — since the whole-transform megakernel
    landed, auto mode prefers the triple on these shapes (that sweep is
    F2 below).
    """
    from repro.core.transforms import coefficient_matrix

    rng = np.random.default_rng(7)
    # Serving-sized (N <= 256) working sets that stay timing-stable on small
    # shared CI hosts; the HBM model, not wall-clock, is the paper claim.
    for batch, n in [(8, 32), (4, 64), (16, 48)]:
        x = jnp.asarray(rng.normal(size=(batch, n, n, n)).astype(np.float32))
        c = coefficient_matrix("dct", n)
        staged_us, fused_us = _tmin_interleaved(
            [lambda: gemt3_planned(x, c, c, c, fuse=False),
             lambda: gemt3_planned(x, c, c, c, fuse="pair")])
        y, info = gemt3_planned(x, c, c, c, fuse="pair", with_info=True)
        y0 = gemt3_planned(x, c, c, c, fuse=False)
        err = float(jnp.max(jnp.abs(y - y0)))
        fp = info["fused"]
        hbm_reduction = info["hbm_bytes_staged"] / max(info["hbm_bytes_moved"], 1)
        rows.append((
            f"F1_fused_gemt_B{batch}_N{n}", fused_us,
            f"staged_us={staged_us:.1f};"
            f"speedup={staged_us / max(fused_us, 1e-9):.2f}x;"
            f"wallclock_no_worse={fused_us <= staged_us};"
            f"fused={fp is not None};"
            f"modes={fp['modes'] if fp else None};"
            f"hbm_bytes_staged={info['hbm_bytes_staged']};"
            f"hbm_bytes_moved={info['hbm_bytes_moved']};"
            f"hbm_reduction={hbm_reduction:.2f}x;"
            f"hbm_reduction_ge_1.5={hbm_reduction >= 1.5};"
            f"pair_savings={fp['hbm_savings'] if fp else 0:.2f}x;"
            f"vmem_bytes={fp['vmem_bytes'] if fp else 0};"
            f"max_abs_err={err:.1e}"))


def bench_fused3_gemt(rows):
    """F2: whole-transform triple vs fused pair vs staged (DCT serving).

    The megakernel must be numerically equivalent, move >= 2.5x fewer
    modeled HBM bytes than staged and >= 1.3x fewer than the fused pair on
    the shapes where it engages, and be faster than the pair in wall-clock.
    On shapes whose accumulator no longer fits the VMEM budget at a useful
    ka tile (N=64 here), auto mode degrades to the pair — the row records
    that boundary rather than hiding it.
    """
    from repro.core.transforms import coefficient_matrix

    rng = np.random.default_rng(11)
    for batch, n in [(8, 32), (16, 48), (4, 64)]:
        x = jnp.asarray(rng.normal(size=(batch, n, n, n)).astype(np.float32))
        c = coefficient_matrix("dct", n)
        staged_us, pair_us, auto_us = _tmin_interleaved(
            [lambda: gemt3_planned(x, c, c, c, fuse=False),
             lambda: gemt3_planned(x, c, c, c, fuse="pair"),
             lambda: gemt3_planned(x, c, c, c)])
        y, info = gemt3_planned(x, c, c, c, with_info=True)
        _, i_staged = gemt3_planned(x, c, c, c, fuse=False, with_info=True)
        _, i_pair = gemt3_planned(x, c, c, c, fuse="pair", with_info=True)
        y0 = gemt3_planned(x, c, c, c, fuse=False)
        err = float(jnp.max(jnp.abs(y - y0)))
        fp = info["fused"]
        triple = fp is not None and len(fp["modes"]) == 3
        hbm_vs_staged = (i_staged["hbm_bytes_moved"]
                         / max(info["hbm_bytes_moved"], 1))
        hbm_vs_pair = (i_pair["hbm_bytes_moved"]
                       / max(info["hbm_bytes_moved"], 1))
        rows.append((
            f"F2_fused3_gemt_B{batch}_N{n}", auto_us,
            f"staged_us={staged_us:.1f};pair_us={pair_us:.1f};"
            f"speedup_vs_staged={staged_us / max(auto_us, 1e-9):.2f}x;"
            f"speedup_vs_pair={pair_us / max(auto_us, 1e-9):.2f}x;"
            f"triple={triple};"
            f"modes={fp['modes'] if fp else None};"
            f"hbm_bytes_staged={i_staged['hbm_bytes_moved']};"
            f"hbm_bytes_pair={i_pair['hbm_bytes_moved']};"
            f"hbm_bytes_moved={info['hbm_bytes_moved']};"
            f"hbm_vs_staged={hbm_vs_staged:.2f}x;"
            f"hbm_vs_pair={hbm_vs_pair:.2f}x;"
            f"hbm_vs_staged_ge_2.5={hbm_vs_staged >= 2.5};"
            f"vmem_bytes={fp['vmem_bytes'] if fp else 0};"
            f"max_abs_err={err:.1e}"))


def bench_grad_engine(rows):
    """G1: forward+backward through the differentiable engine vs einsum.

    ``jax.grad`` of a sum-of-squares loss over (x, C1, C2, C3) must (a)
    reproduce the einsum-reference gradients (``max_abs_err`` is the max
    cotangent deviation relative to the reference magnitude), (b) lower
    the backward through the engine — nonzero kernel-stage counters, zero
    einsum stages on these kernel-capable fp32 shapes — and (c) beat the
    einsum-reference backward (``speedup_vs_ref >= 1.0``: the ratio of
    ``jax.vjp`` pull wall-clocks, the fused-adjoint chain walk closing
    the old 3x backward gap).  The pulls are timed directly — the
    engine's eager forward pays a fixed under-vjp tracing cost that a
    full-``grad`` wall-clock would fold into the backward claim.  One
    square DCT serving shape (chain-triple adjoint, 3 backward launches)
    and one rectangular Tucker shape (byte model degrades to chain pair
    + staged tail, 4 launches) are recorded; ``grad_chain_depth``/
    ``grad_launches``/``bwd_kernel_launches`` are deterministic keys the
    regression gate compares exactly.
    """
    from repro.core.transforms import coefficient_matrix

    rng = np.random.default_rng(17)
    problems = []
    n = 32
    c = coefficient_matrix("dct", n)
    problems.append((f"B8_N{n}_dct",
                     jnp.asarray(rng.normal(size=(8, n, n, n))
                                 .astype(np.float32)), (c, c, c)))
    dims, ranks = (64, 48, 32), (8, 24, 24)
    problems.append((f"tucker_N{dims}_K{ranks}",
                     jnp.asarray(rng.normal(size=dims).astype(np.float32)),
                     tuple(jnp.asarray(rng.normal(size=(nn, k))
                                       .astype(np.float32))
                           for nn, k in zip(dims, ranks))))

    for tag, x, cs in problems:
        def eng_loss(x, c1, c2, c3):
            return jnp.sum(gemt3_planned(x, c1, c2, c3,
                                         differentiable=True) ** 2)

        def ref_loss(x, c1, c2, c3):
            y = jnp.einsum("...abc,ax,by,cz->...xyz", x, c1, c2, c3)
            return jnp.sum(y ** 2)

        eng_grad = jax.grad(eng_loss, argnums=(0, 1, 2, 3))
        ref_grad = jax.grad(ref_loss, argnums=(0, 1, 2, 3))

        def eng_fn(x, c1, c2, c3):
            return gemt3_planned(x, c1, c2, c3, differentiable=True)

        def ref_fn(x, c1, c2, c3):
            return jnp.einsum("...abc,ax,by,cz->...xyz", x, c1, c2, c3)

        y_ref, pull_ref = jax.vjp(ref_fn, x, *cs)
        _, pull_eng = jax.vjp(eng_fn, x, *cs)
        ct = 2.0 * y_ref  # the sum-of-squares cotangent
        fwd_us, bwd_us, ref_bwd_us = _tmin_interleaved(
            [lambda: gemt3_planned(x, *cs, differentiable=True),
             lambda: pull_eng(ct),
             lambda: pull_ref(ct)])
        ge, gr = eng_grad(x, *cs), ref_grad(x, *cs)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  / max(float(jnp.max(jnp.abs(b))), 1.0)
                  for a, b in zip(ge, gr))
        reset_grad_stats()
        jax.block_until_ready(eng_grad(x, *cs))
        gs = grad_stats()
        _, info = gemt3_planned(x, *cs, with_info=True, differentiable=True)
        rows.append((
            f"G1_grad_engine_{tag}", bwd_us,
            f"fwd_us={fwd_us:.1f};ref_bwd_us={ref_bwd_us:.1f};"
            f"speedup_vs_ref={ref_bwd_us / max(bwd_us, 1e-9):.2f}x;"
            f"bwd_fwd_ratio_us={bwd_us / max(fwd_us, 1e-9):.2f};"
            f"grad_order={info['grad_order']};"
            f"grad_backends={'/'.join(info['grad_backends'])};"
            f"grad_coeff_backends={'/'.join(info['grad_coeff_backends'])};"
            f"grad_kernel_stages={info['grad_kernel_stages']};"
            f"grad_einsum_stages={info['grad_einsum_stages']};"
            f"grad_fused={info['grad_fused']};"
            f"grad_chain_depth={info['grad_chain_depth']};"
            f"grad_launches={info['grad_launches']};"
            f"grad_rec_fused={info['grad_rec_fused']};"
            f"grad_macs={info['grad_macs']};"
            f"bwd_kernel_launches={gs['kernel_stages'] + gs['coeff_kernel']};"
            f"bwd_einsum_stages={gs['einsum_stages'] + gs['coeff_einsum']};"
            f"engine_backward={gs['backward_calls'] == 1};"
            f"max_abs_err={err:.1e}"))


def bench_serve_resilience(rows):
    """S1: sustained serving throughput through a scripted fault schedule.

    A :class:`ResilientDxtServer` serves the same request stream twice —
    fault-free, then under a scripted chaos schedule (two kernel
    exceptions on the fused tier, which open the auto breaker and demote
    to the pair tier, then one VMEM-pressure fault, which tightens the
    budget and replans).  The row records the throughput cost of recovery
    (wall-clock keys, banded) next to the exact recovery accounting
    (deterministic keys: the lifecycle is deterministic by construction —
    scripted faults, hashed jitter, injected no-op sleep — so every
    retry/degradation/completion count must reproduce run-to-run).
    Delay/timeout faults are deliberately absent: their outcome depends
    on host speed and would make the artifact flaky.
    """
    import contextlib

    from repro.runtime.faults import FaultSpec, inject_faults
    from repro.serve import DxtServeSession, ResilientDxtServer

    rng = np.random.default_rng(23)
    n, b, n_requests = 16, 4, 24
    reqs = [jnp.asarray(rng.normal(size=(b, n, n, n)).astype(np.float32))
            for _ in range(n_requests)]

    def run(faulted):
        server = ResilientDxtServer(session=DxtServeSession(),
                                    breaker_threshold=2,
                                    breaker_cooldown_s=1e9,
                                    sleep=lambda s: None)
        jax.block_until_ready(server.transform(reqs[0]))  # warm: compile
        specs = (FaultSpec(match="fused_*", kind="exception", times=2),
                 FaultSpec(match="fused_*", kind="vmem_pressure", times=1))
        ctx = inject_faults(*specs) if faulted else contextlib.nullcontext()
        t0 = time.perf_counter()
        with ctx:
            outs = [server.transform(r) for r in reqs]
        jax.block_until_ready(outs)
        dt = time.perf_counter() - t0
        return server, outs, dt

    _, clean_out, clean_s = run(False)
    server, chaos_out, chaos_s = run(True)
    err = max(float(jnp.max(jnp.abs(a - c)))
              for a, c in zip(chaos_out, clean_out))
    st = server.stats()
    rows.append((
        "S1_serve_resilience_chaos", chaos_s / n_requests * 1e6,
        f"clean_us_per_req={clean_s / n_requests * 1e6:.1f};"
        f"clean_vs_chaos_speedup={clean_s / max(chaos_s, 1e-9):.2f}x;"
        f"requests={n_requests};"
        f"admitted={st['admitted']};"
        f"completed={st['completed']};"
        f"failed={st['failed']};"
        f"shed={st['shed']};"
        f"retries={st['retries']};"
        f"degraded={st['degraded']};"
        f"remeshes={st['remeshes']};"
        f"breaker_auto={st['breakers']['auto']};"
        f"vmem_budget_tightened={st['vmem_budget'] is not None};"
        f"max_abs_err={err:.1e}"))
