# One function per paper claim/table. Prints ``name,us_per_call,derived`` CSV.
from __future__ import annotations

import sys


def main() -> None:
    rows: list[tuple[str, float, str]] = []
    from . import bench_core, bench_distributed, bench_kernels, bench_roofline

    bench_core.bench_linear_timesteps(rows)
    bench_core.bench_esop_savings(rows)
    bench_core.bench_esop_accuracy(rows)
    bench_core.bench_staged_vs_elementwise(rows)
    bench_core.bench_generality(rows)
    bench_kernels.bench_sr_gemm_structure(rows)
    bench_kernels.bench_esop_plan(rows)
    bench_kernels.bench_xla_gemm_baseline(rows)
    bench_distributed.bench_strong_scaling_model(rows)
    bench_distributed.bench_shardmap_vs_auto(rows)
    bench_roofline.bench_roofline_summary(rows)

    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
