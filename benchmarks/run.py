# One function per paper claim/table. Prints ``name,us_per_call,derived`` CSV;
# ``--json OUT`` additionally writes the rows as a JSON artifact (e.g.
# ``BENCH_engine.json``) for the perf trajectory.
from __future__ import annotations

import argparse
import json


def collect_rows() -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    from . import (bench_core, bench_distributed, bench_engine, bench_kernels,
                   bench_roofline)

    bench_core.bench_linear_timesteps(rows)
    bench_core.bench_esop_savings(rows)
    bench_core.bench_esop_accuracy(rows)
    bench_core.bench_staged_vs_elementwise(rows)
    bench_core.bench_generality(rows)
    bench_kernels.bench_sr_gemm_structure(rows)
    bench_kernels.bench_esop_plan(rows)
    bench_kernels.bench_xla_gemm_baseline(rows)
    bench_distributed.bench_strong_scaling_model(rows)
    bench_distributed.bench_shardmap_vs_auto(rows)
    bench_roofline.bench_roofline_summary(rows)
    bench_engine.bench_planner_order(rows)
    bench_engine.bench_esop_dispatch(rows)
    bench_engine.bench_planned_vs_einsum(rows)
    bench_engine.bench_autotune_cache(rows)
    return rows


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="OUT", default=None,
                    help="also write rows as a JSON artifact "
                         "(e.g. BENCH_engine.json)")
    args = ap.parse_args(argv)

    rows = collect_rows()
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": round(us, 1), "derived": d}
                       for n, us, d in rows], f, indent=1)
        print(f"# wrote {len(rows)} rows to {args.json}")


if __name__ == "__main__":
    main()
