# One function per paper claim/table. Prints ``name,us_per_call,derived`` CSV;
# ``--json`` additionally writes the rows as a JSON artifact whose path comes
# from ``--out PATH`` (or ``--json PATH`` for backward compatibility), e.g.
#
#   python -m benchmarks.run --json BENCH_engine.json
#   python -m benchmarks.run --filter fused_gemt --json --out BENCH_fused_gemt.json
#   python -m benchmarks.run --filter fused3 --json --out BENCH_fused3_gemt.json
#
# ``--filter SUBSTR`` runs only the bench functions whose name contains the
# substring (cheap CI artifacts without paying for the whole sweep).
#
# ``--check-regression ARTIFACT.json`` re-runs exactly the bench functions
# that produced the artifact's rows and compares fresh results against the
# committed numbers: deterministic model metrics (byte counts, ratios,
# backends, error bounds) must reproduce, wall-clock numbers get a
# ``--tol-time`` tolerance band.  Exit code 1 on any regression, so CI fails
# loudly; the tier-2 ``bench_smoke`` pytest wires this against the committed
# artifacts.
from __future__ import annotations

import argparse
import json
import sys


def _benches():
    from . import (bench_core, bench_distributed, bench_engine, bench_kernels,
                   bench_numerics, bench_roofline, bench_serve_throughput)

    return [
        bench_core.bench_linear_timesteps,
        bench_core.bench_esop_savings,
        bench_core.bench_esop_accuracy,
        bench_core.bench_staged_vs_elementwise,
        bench_core.bench_generality,
        bench_kernels.bench_sr_gemm_structure,
        bench_kernels.bench_esop_plan,
        bench_kernels.bench_xla_gemm_baseline,
        bench_distributed.bench_strong_scaling_model,
        bench_distributed.bench_shardmap_vs_auto,
        bench_distributed.bench_distributed_engine,
        bench_roofline.bench_roofline_summary,
        bench_engine.bench_planner_order,
        bench_engine.bench_esop_dispatch,
        bench_engine.bench_planned_vs_einsum,
        bench_engine.bench_autotune_cache,
        bench_engine.bench_fused_gemt,
        bench_engine.bench_fused3_gemt,
        bench_engine.bench_grad_engine,
        bench_engine.bench_serve_resilience,
        bench_serve_throughput.bench_serve_throughput,
        bench_numerics.bench_compensated_accum,
    ]


# Row-name prefix (up to the first "_") -> bench function name.  Artifacts
# only record row names, so --check-regression uses this to re-run just the
# functions that produced them.
_ROW_PREFIXES = {
    "B1": "bench_linear_timesteps", "B3": "bench_esop_savings",
    "B4": "bench_esop_accuracy", "B5": "bench_staged_vs_elementwise",
    "B6": "bench_generality",
    "K1": "bench_sr_gemm_structure", "K2": "bench_esop_plan",
    "K3": "bench_xla_gemm_baseline",
    "D1": "bench_strong_scaling_model", "D2": "bench_shardmap_vs_auto",
    "D3": "bench_distributed_engine",
    "R1": "bench_roofline_summary",
    "E1": "bench_planner_order", "E2": "bench_esop_dispatch",
    "E3": "bench_planned_vs_einsum", "E4": "bench_autotune_cache",
    "F1": "bench_fused_gemt", "F2": "bench_fused3_gemt",
    "G1": "bench_grad_engine",
    "S1": "bench_serve_resilience", "S2": "bench_serve_throughput",
    "N1": "bench_compensated_accum",
}

# Derived keys whose values are wall-clock measurements (or booleans derived
# from them): compared under the --tol-time band, never exactly.  Queueing-
# sensitive serving keys (requests/sec, SLO attainment) live here too — a
# loaded CI host shifts them without any code regression.
_NOISY_MARKERS = ("_us", "us_", "speedup", "wallclock", "no_worse", "warm",
                  "rps", "slo")

# Counter-snapshot keys that legitimately vary between the recording run and
# a fresh check (process-warm plan/memo/autotune caches shift hit/miss/build
# splits; degradations only fire at build time) — skipped entirely.  Keys
# carrying timing (histogram stats, *_us) get the --tol-time band; everything
# else (MAC/byte totals, stage/launch/request counts) must reproduce exactly.
_CACHE_COUNTER_MARKERS = ("hit", "miss", "evict", "load", "write", "build",
                          "degradation", "probe")
_TIMING_COUNTER_MARKERS = ("_us", "latency", ".mean", ".p50", ".p90", ".p99",
                           ".max", ".min", ".sum")


def _parse_derived(derived: str) -> dict[str, str]:
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, v = part.split("=", 1)
            out[k] = v
    return out


def _as_float(v: str) -> float | None:
    try:
        return float(v[:-1] if v.endswith("x") else v)
    except ValueError:
        return None


def _is_noisy(key: str) -> bool:
    return any(m in key for m in _NOISY_MARKERS)


def compare_counters(recorded: dict, fresh: dict,
                     tol_time: float | None = 1.0) -> list[str]:
    """Compare a recorded registry counter snapshot against a fresh one.

    Cache-behaviour keys are skipped (warm-process hit/miss splits are not
    a contract), timing keys get the ``tol_time`` band, everything else —
    modeled MAC/byte totals, stage/launch/request counts — must reproduce
    exactly.
    """
    failures = []
    for key, rec_v in recorded.items():
        if any(m in key for m in _CACHE_COUNTER_MARKERS):
            continue
        if key not in fresh:
            failures.append(f"counters: {key} disappeared from fresh run")
            continue
        new_v = fresh[key]
        if any(m in key for m in _TIMING_COUNTER_MARKERS):
            if (tol_time is not None and float(rec_v) > 0
                    and float(new_v) > float(rec_v) * (1.0 + tol_time)):
                failures.append(
                    f"counters: {key} regressed {rec_v} -> {new_v} "
                    f"(band {tol_time:.0%})")
        elif float(new_v) != float(rec_v):
            failures.append(
                f"counters: {key} changed {rec_v} -> {new_v} (re-record "
                "the artifact if the model legitimately moved)")
    return failures


def _split_artifact(recorded):
    """A BENCH artifact is either the original bare row list or the
    counter-carrying ``{"rows": [...], "counters": {...}}`` form."""
    if isinstance(recorded, dict):
        return recorded.get("rows"), recorded.get("counters") or {}
    return recorded, {}


def check_regression(path: str, tol_time: float | None = 1.0,
                     rows: list[tuple[str, float, str]] | None = None,
                     counters: dict | None = None,
                     ) -> list[str]:
    """Compare a committed BENCH artifact against a fresh run.

    Returns a list of human-readable failure strings (empty = no
    regression).  ``tol_time`` is the relative band on wall-clock numbers
    (1.0 = fresh may be up to 2x the recorded value; speedups may shrink
    to recorded/(1+tol)); ``None`` skips wall-clock comparison entirely
    (deterministic model metrics only — useful where the committed
    artifact was recorded on different hardware).  ``rows`` injects
    pre-collected fresh rows (tests reuse one sweep for several checks);
    ``counters`` likewise injects a fresh registry snapshot for artifacts
    that embed one.
    """
    try:
        with open(path) as f:
            recorded = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: cannot read artifact ({e})"]
    recorded, rec_counters = _split_artifact(recorded)
    if not isinstance(recorded, list) or not recorded:
        return [f"{path}: not a BENCH artifact (expected a non-empty list)"]

    if rows is None:
        prefixes = {r["name"].split("_", 1)[0] for r in recorded}
        unknown = sorted(p for p in prefixes if p not in _ROW_PREFIXES)
        if unknown:
            return [f"{path}: unknown row prefixes {unknown} — update "
                    "_ROW_PREFIXES in benchmarks/run.py"]
        wanted = {_ROW_PREFIXES[p] for p in prefixes}
        from repro import obs

        # The fresh sweep runs inside its own registry so the snapshot
        # compares only what *these* benches recorded, not whatever else
        # ran in this process.
        with obs.session(name="bench-check", enable_tracing=False) as s:
            rows = []
            for fn in _benches():
                if fn.__name__ in wanted:
                    fn(rows)
            counters = s.registry.snapshot()
    fresh = {name: (us, _parse_derived(derived)) for name, us, derived in rows}

    failures = []
    if rec_counters:
        failures.extend(compare_counters(rec_counters, counters or {},
                                         tol_time=tol_time))
    for rec in recorded:
        name = rec["name"]
        if name not in fresh:
            failures.append(f"{name}: row missing from fresh run")
            continue
        fresh_us, fresh_kv = fresh[name]
        rec_us = float(rec.get("us_per_call", 0.0))
        if (tol_time is not None and rec_us > 0
                and fresh_us > rec_us * (1.0 + tol_time)):
            failures.append(
                f"{name}: us_per_call {fresh_us:.1f} exceeds recorded "
                f"{rec_us:.1f} by more than {tol_time:.0%}")
        for key, rec_v in _parse_derived(rec.get("derived", "")).items():
            if key not in fresh_kv:
                failures.append(f"{name}: derived key {key!r} disappeared")
                continue
            new_v = fresh_kv[key]
            rec_f, new_f = _as_float(rec_v), _as_float(new_v)
            if _is_noisy(key):
                if tol_time is None or rec_f is None or new_f is None:
                    continue  # timing-derived booleans flap with the host
                # direction: "us" keys = lower is better, speedup ratios =
                # higher is better; both get the same relative band
                if key.endswith("us") or key.endswith("_us"):
                    bad = rec_f > 0 and new_f > rec_f * (1.0 + tol_time)
                else:
                    bad = new_f < rec_f / (1.0 + tol_time)
                if bad:
                    failures.append(
                        f"{name}: {key} regressed {rec_v} -> {new_v} "
                        f"(band {tol_time:.0%})")
            elif key.startswith("max_abs_err"):
                # numerical-error keys (max_abs_err, max_abs_err_plain/
                # _comp): rounding detail may shift with XLA, but a 4x
                # growth (floored at 1e-5) is a real accuracy regression
                if (rec_f is not None and new_f is not None
                        and new_f > max(rec_f * 4, 1e-5)):
                    failures.append(
                        f"{name}: {key} grew {rec_v} -> {new_v}")
            elif rec_f is not None and new_f is not None:
                # deterministic model metric: must reproduce (tiny float
                # formatting slack only)
                if abs(new_f - rec_f) > max(1e-6, 1e-6 * abs(rec_f)):
                    failures.append(
                        f"{name}: model metric {key} changed "
                        f"{rec_v} -> {new_v} (re-record the artifact if "
                        "the model legitimately moved)")
            elif rec_v != new_v:
                failures.append(
                    f"{name}: {key} changed {rec_v!r} -> {new_v!r}")
    return failures


def collect_rows(name_filter: str | None = None) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    for fn in _benches():
        if name_filter and name_filter not in fn.__name__:
            continue
        fn(rows)
    return rows


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="OUT", nargs="?", const=True,
                    default=None,
                    help="also write rows as a JSON artifact (path from "
                         "--out, or given directly for compatibility)")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="JSON artifact path (implies --json; "
                         "e.g. BENCH_fused_gemt.json)")
    ap.add_argument("--filter", metavar="SUBSTR", default=None,
                    help="only run bench functions whose name contains this")
    ap.add_argument("--trace", metavar="TRACE_OUT", default=None,
                    help="record engine spans during the sweep and write a "
                         "Chrome-trace JSON (open in Perfetto / "
                         "chrome://tracing, or inspect with "
                         "`python -m repro.obs TRACE_OUT`)")
    ap.add_argument("--check-regression", metavar="ARTIFACT", default=None,
                    help="re-run the benches behind a committed BENCH "
                         "artifact and fail (exit 1) on regressions")
    ap.add_argument("--tol-time", type=float, default=1.0,
                    help="relative tolerance band on wall-clock numbers for "
                         "--check-regression (default 1.0 = 2x); negative "
                         "disables wall-clock comparison")
    args = ap.parse_args(argv)

    if args.check_regression:
        tol = None if args.tol_time < 0 else args.tol_time
        failures = check_regression(args.check_regression, tol_time=tol)
        if failures:
            for f in failures:
                print(f"REGRESSION {f}")
            sys.exit(1)
        print(f"# {args.check_regression}: no regressions")
        return

    # Resolve the artifact path before the sweep runs — a bad flag combo
    # must not waste minutes of benchmarking before erroring out.
    path = None
    if args.json or args.out:  # --out alone implies the JSON artifact
        if isinstance(args.json, str) and args.out:
            ap.error("give the artifact path via --json PATH or --out PATH, "
                     "not both")
        path = args.out or (args.json if isinstance(args.json, str) else None)
        if path is None:
            ap.error("--json without a path requires --out PATH")

    from repro import obs

    # The sweep runs inside its own tracer/registry: the artifact's counter
    # snapshot reflects this sweep only, and --trace captures its spans.
    with obs.session(name="bench", enable_tracing=args.trace is not None) as s:
        rows = collect_rows(args.filter)
        counters = s.registry.snapshot()
        spans = s.tracer.spans() if args.trace else []
    if args.filter and not rows:
        ap.error(f"--filter {args.filter!r} matched no bench function "
                 "(artifact would be empty)")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if args.trace:
        obs.write_chrome_trace(args.trace, spans, s.registry)
        print(f"# wrote {len(spans)} spans to {args.trace}")

    if path:
        with open(path, "w") as f:
            json.dump({"rows": [{"name": n, "us_per_call": round(us, 1),
                                 "derived": d} for n, us, d in rows],
                       "counters": counters}, f, indent=1)
        print(f"# wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
