# One function per paper claim/table. Prints ``name,us_per_call,derived`` CSV;
# ``--json`` additionally writes the rows as a JSON artifact whose path comes
# from ``--out PATH`` (or ``--json PATH`` for backward compatibility), e.g.
#
#   python -m benchmarks.run --json BENCH_engine.json
#   python -m benchmarks.run --filter fused --json --out BENCH_fused_gemt.json
#
# ``--filter SUBSTR`` runs only the bench functions whose name contains the
# substring (cheap CI artifacts without paying for the whole sweep).
from __future__ import annotations

import argparse
import json


def _benches():
    from . import (bench_core, bench_distributed, bench_engine, bench_kernels,
                   bench_roofline)

    return [
        bench_core.bench_linear_timesteps,
        bench_core.bench_esop_savings,
        bench_core.bench_esop_accuracy,
        bench_core.bench_staged_vs_elementwise,
        bench_core.bench_generality,
        bench_kernels.bench_sr_gemm_structure,
        bench_kernels.bench_esop_plan,
        bench_kernels.bench_xla_gemm_baseline,
        bench_distributed.bench_strong_scaling_model,
        bench_distributed.bench_shardmap_vs_auto,
        bench_distributed.bench_distributed_engine,
        bench_roofline.bench_roofline_summary,
        bench_engine.bench_planner_order,
        bench_engine.bench_esop_dispatch,
        bench_engine.bench_planned_vs_einsum,
        bench_engine.bench_autotune_cache,
        bench_engine.bench_fused_gemt,
    ]


def collect_rows(name_filter: str | None = None) -> list[tuple[str, float, str]]:
    rows: list[tuple[str, float, str]] = []
    for fn in _benches():
        if name_filter and name_filter not in fn.__name__:
            continue
        fn(rows)
    return rows


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", metavar="OUT", nargs="?", const=True,
                    default=None,
                    help="also write rows as a JSON artifact (path from "
                         "--out, or given directly for compatibility)")
    ap.add_argument("--out", metavar="PATH", default=None,
                    help="JSON artifact path (implies --json; "
                         "e.g. BENCH_fused_gemt.json)")
    ap.add_argument("--filter", metavar="SUBSTR", default=None,
                    help="only run bench functions whose name contains this")
    args = ap.parse_args(argv)

    # Resolve the artifact path before the sweep runs — a bad flag combo
    # must not waste minutes of benchmarking before erroring out.
    path = None
    if args.json or args.out:  # --out alone implies the JSON artifact
        if isinstance(args.json, str) and args.out:
            ap.error("give the artifact path via --json PATH or --out PATH, "
                     "not both")
        path = args.out or (args.json if isinstance(args.json, str) else None)
        if path is None:
            ap.error("--json without a path requires --out PATH")

    rows = collect_rows(args.filter)
    if args.filter and not rows:
        ap.error(f"--filter {args.filter!r} matched no bench function "
                 "(artifact would be empty)")
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")

    if path:
        with open(path, "w") as f:
            json.dump([{"name": n, "us_per_call": round(us, 1), "derived": d}
                       for n, us, d in rows], f, indent=1)
        print(f"# wrote {len(rows)} rows to {path}")


if __name__ == "__main__":
    main()
