"""Guarded-numerics benchmarks (docs/numerics.md).

  N1 compensated accumulation   bf16 DCT serving shapes (the F2/G1 family):
                                max abs error vs a float64 host einsum
                                oracle for plain vs compensated
                                accumulation.  Compensated must cut the
                                error by >= 4x at <= 1.15x wall-clock —
                                recorded as the error ratio plus an
                                interleaved A/B timing.
  N1 error budget               the planner's a-priori bound: a budget no
                                mode can meet still escalates to
                                compensated and records the
                                numerics_degradation walk; the resolved
                                accum/bound are deterministic model
                                metrics.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.engine import gemt3_planned, plan_gemt3

from .bench_engine import _tmin_interleaved


def _oracle(x, cs):
    """Float64 host einsum: ẍ[a,b,c] = Σ x[i,j,k]·C1[i,a]·C2[j,b]·C3[k,c]."""
    args = [np.asarray(a, np.float64) for a in (x, *cs)]
    # optimize=True: the default contraction order is the naive 7-index
    # loop — O(U·N^6), minutes at N=64 — instead of three matmuls.
    return np.einsum("uijk,ia,jb,kc->uabc", *args, optimize=True)


def bench_compensated_accum(rows):
    """N1: plain vs compensated accumulation on bf16 serving shapes."""
    from repro.core.transforms import coefficient_matrix

    rng = np.random.default_rng(17)
    # Two of the F2 serving shapes.  The third, (4, 64), is deliberately
    # not gated: on this host XLA's CPU elementwise scheduling makes the
    # Neumaier chain ~1.7x there (while the larger (16, 48) is free), so
    # a wall-clock gate on it would flap on scheduler noise.
    for batch, n in [(8, 32), (16, 48)]:
        x = jnp.asarray(rng.normal(size=(batch, n, n, n)), jnp.bfloat16)
        c = coefficient_matrix("dct", n).astype(jnp.bfloat16)
        oracle = _oracle(x, (c, c, c))
        plain_us, comp_us = _tmin_interleaved(
            [lambda: gemt3_planned(x, c, c, c),
             lambda: gemt3_planned(x, c, c, c, accum="compensated")])
        y_plain = np.asarray(gemt3_planned(x, c, c, c), np.float64)
        y_comp = np.asarray(
            gemt3_planned(x, c, c, c, accum="compensated"), np.float64)
        err_plain = float(np.max(np.abs(y_plain - oracle)))
        err_comp = float(np.max(np.abs(y_comp - oracle)))
        gain = err_plain / max(err_comp, 1e-30)
        plan = plan_gemt3(x.shape, x.dtype, c, c, c, accum="compensated")
        rows.append((
            f"N1_compensated_B{batch}_N{n}", comp_us,
            f"plain_wallclock_us={plain_us:.1f};"
            f"comp_wallclock_us={comp_us:.1f};"
            f"plain_vs_comp_wallclock={plain_us / max(comp_us, 1e-9):.2f}x;"
            f"max_abs_err_plain={err_plain:.3e};"
            f"max_abs_err_comp={err_comp:.3e};"
            f"err_gain_ge_4x={gain >= 4.0};"
            f"accum={plan.accum};"
            f"error_bound={plan.error_bound:.3e}"))


    # An unmeetable error budget escalates accum and records the walk; the
    # resolved mode/bound/event count are deterministic model metrics.
    n, budget = 32, 1e-9
    c = coefficient_matrix("dct", n).astype(jnp.bfloat16)
    plan = plan_gemt3((4, n, n, n), jnp.bfloat16, c, c, c,
                      error_budget=budget)
    events = [e for e in plan.events
              if e.get("kind") == "numerics_degradation"]
    rows.append((
        f"N1_error_budget_N{n}", 0.0,
        f"accum={plan.accum};"
        f"error_bound={plan.error_bound:.3e};"
        f"error_budget={plan.error_budget:.0e};"
        f"numerics_events={len(events)};"
        f"budget_met={plan.error_bound <= budget}"))
