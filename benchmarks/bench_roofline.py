"""Emit the §Roofline table from dry-run artifacts (benchmarks read-side)."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_artifacts(art_dir: str = ART) -> list[dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(p) as f:
            out.append(json.load(f))
    return out


def bench_roofline_summary(rows):
    arts = load_artifacts()
    if not arts:
        rows.append(("R1_roofline", 0.0, "no_dryrun_artifacts_yet"))
        return
    for a in arts:
        r = a["roofline"]
        rows.append((
            f"R1_{a['arch']}__{a['shape']}__{a['mesh']}",
            r["step_time_lower_bound_s"] * 1e6,
            f"bound={r['bound']};compute_s={r['compute_s']:.3e};"
            f"memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};"
            f"useful_ratio={r.get('useful_flops_ratio', 0):.3f};"
            f"GiB_per_dev={a['memory']['per_device_total'] / 2**30:.2f}"))


def markdown_table(arts: list[dict]) -> str:
    """The EXPERIMENTS.md §Roofline table."""
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "bound | MODEL_FLOPS | useful ratio | GiB/dev | note |\n"
           "|---|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for a in arts:
        r = a["roofline"]
        mf = a["model_flops"]["model_flops"]
        note = _note(a)
        lines.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | **{r['bound']}** "
            f"| {mf:.2e} | {r.get('useful_flops_ratio', 0):.2f} "
            f"| {a['memory']['per_device_total'] / 2**30:.1f} | {note} |")
    return hdr + "\n".join(lines) + "\n"


def _note(a) -> str:
    r = a["roofline"]
    b = r["bound"]
    if b == "collective":
        kinds = r.get("coll_by_kind", {})
        top = max(kinds, key=kinds.get) if kinds else "?"
        return f"dominant coll: {top} — reshard/SP to shrink"
    if b == "memory":
        return "fuse/chunk big intermediates; bf16 residuals"
    return "near compute roof — keep MXU fed"
