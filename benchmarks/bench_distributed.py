"""Distributed GEMT benchmarks: TriADA shard_map schedule vs GSPMD auto,
collective-byte comparison (dry-run artifacts), strong-scaling step model.

Runs in a subprocess with 8 virtual devices (the only place outside
launch/dryrun.py that needs >1 device).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import macs, time_steps

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def bench_strong_scaling_model(rows):
    """TriADA strong-scaling (§5.1 tiling): each P³-cell tile streams the
    full contracted extent (N per stage, so N1+N2+N3 steps per output
    tile); with (N/P)³ tiles, total steps scale as 1/P³ — extreme strong
    scaling at a constant 100 % MACs/cell/step efficiency."""
    n = 64
    for p in (64, 32, 16, 8):
        tiles = (n // p) ** 3
        steps = tiles * time_steps(n, n, n)
        eff = macs(n, n, n) / (steps * p ** 3)  # MACs per cell-step
        rows.append((f"D1_strong_scaling_P{p}^3", 0.0,
                     f"steps={steps};cells={p**3};efficiency={eff:.2f}"))


def bench_shardmap_vs_auto(rows):
    """Collective bytes: hand-placed TriADA schedule vs GSPMD auto."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.core import gemt3_shardmap, gemt3_auto
        from repro.launch.roofline import analyze_hlo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sds = jax.ShapeDtypeStruct
        args = (sds((32, 32, 32), jnp.float32),) + (sds((32, 32), jnp.float32),) * 3
        for name, f in [("shardmap", jax.jit(gemt3_shardmap(mesh))),
                        ("auto", gemt3_auto(mesh))]:
            hlo = f.lower(*args).compile().as_text()
            c = analyze_hlo(hlo, 8)
            print(f"{name},{c.ici_bytes:.0f},{c.flops:.0f}")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=600)
    if r.returncode != 0:
        rows.append(("D2_shardmap_vs_auto", 0.0, f"FAILED:{r.stderr[-200:]}"))
        return
    vals = {}
    for line in r.stdout.strip().splitlines():
        name, ici, flops = line.split(",")
        vals[name] = float(ici)
        rows.append((f"D2_gemt_{name}", 0.0,
                     f"ici_bytes_per_dev={float(ici):.0f};flops={flops}"))
    if vals.get("auto"):
        rows.append(("D2_collective_ratio", 0.0,
                     f"shardmap_vs_auto={vals['shardmap'] / vals['auto']:.3f}"))
