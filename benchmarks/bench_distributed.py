"""Distributed GEMT benchmarks: TriADA shard_map schedule vs GSPMD auto,
collective-byte comparison (dry-run artifacts), strong-scaling step model,
and the topology-aware engine vs the einsum schedule (D3).

Runs in a subprocess with 8 virtual devices (the only place outside
launch/dryrun.py that needs >1 device).
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import macs, time_steps

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run8(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=600)


def bench_strong_scaling_model(rows):
    """TriADA strong-scaling (§5.1 tiling): each P³-cell tile streams the
    full contracted extent (N per stage, so N1+N2+N3 steps per output
    tile); with (N/P)³ tiles, total steps scale as 1/P³ — extreme strong
    scaling at a constant 100 % MACs/cell/step efficiency."""
    n = 64
    for p in (64, 32, 16, 8):
        tiles = (n // p) ** 3
        steps = tiles * time_steps(n, n, n)
        eff = macs(n, n, n) / (steps * p ** 3)  # MACs per cell-step
        rows.append((f"D1_strong_scaling_P{p}^3", 0.0,
                     f"steps={steps};cells={p**3};efficiency={eff:.2f}"))


def bench_shardmap_vs_auto(rows):
    """Collective bytes: hand-placed TriADA schedule vs GSPMD auto."""
    r = _run8("""
        import jax, jax.numpy as jnp
        from repro.core import gemt3_shardmap, gemt3_auto
        from repro.launch.roofline import analyze_hlo
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        sds = jax.ShapeDtypeStruct
        args = (sds((32, 32, 32), jnp.float32),) + (sds((32, 32), jnp.float32),) * 3
        for name, f in [("shardmap", jax.jit(gemt3_shardmap(mesh))),
                        ("auto", gemt3_auto(mesh))]:
            hlo = f.lower(*args).compile().as_text()
            c = analyze_hlo(hlo, 8)
            print(f"{name},{c.ici_bytes:.0f},{c.flops:.0f}")
    """)
    if r.returncode != 0:
        rows.append(("D2_shardmap_vs_auto", 0.0, f"FAILED:{r.stderr[-200:]}"))
        return
    vals = {}
    for line in r.stdout.strip().splitlines():
        name, ici, flops = line.split(",")
        vals[name] = float(ici)
        rows.append((f"D2_gemt_{name}", 0.0,
                     f"ici_bytes_per_dev={float(ici):.0f};flops={flops}"))
    if vals.get("auto"):
        rows.append(("D2_collective_ratio", 0.0,
                     f"shardmap_vs_auto={vals['shardmap'] / vals['auto']:.3f}"))


def bench_distributed_engine(rows):
    """D3: topology-aware engine inside shard_map vs the einsum schedule.

    Times the local stages both ways on an 8-virtual-device mesh (engine =
    planned Pallas dispatch per shard, einsum = the legacy ``engine=False``
    schedule), checks numerical agreement, and reports the planner's
    modeled per-shard local HBM bytes + per-device psum_scatter collective
    bytes.  ``python -m benchmarks.run --filter distributed_engine --json
    --out BENCH_distributed_engine.json`` writes the artifact.
    """
    r = _run8("""
        import time
        import numpy as np, jax, jax.numpy as jnp
        from repro.core import gemt3_shardmap
        from repro.core.transforms import coefficient_matrix
        from repro.engine import gemt3_planned

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        axes = ("data", "model", None)
        rng = np.random.default_rng(0)

        def tmin(fns, n=7):
            def once(fn):
                t0 = time.perf_counter()
                jax.block_until_ready(fn())
                return (time.perf_counter() - t0) * 1e6
            for fn in fns:
                once(fn)  # warmup/compile/trace
            best = [float("inf")] * len(fns)
            for r_ in range(n):  # interleaved: shared background noise
                idxs = range(len(fns)) if r_ % 2 == 0 else reversed(range(len(fns)))
                for i in idxs:
                    best[i] = min(best[i], once(fns[i]))
            return best

        def sparse_dct(n, zero_cols):
            c = np.asarray(coefficient_matrix("dct", n)).copy()
            c[:, n - zero_cols:] = 0.0
            return jnp.asarray(c)

        cases = [
            ("dense_32", (32, 32, 32),
             tuple(coefficient_matrix("dct", 32) for _ in range(3)), {}),
            ("dense_64", (64, 64, 64),
             tuple(coefficient_matrix("dct", 64) for _ in range(3)), {}),
            ("sparse_48", (48, 48, 48),
             (coefficient_matrix("dct", 48), sparse_dct(48, 24),
              sparse_dct(48, 24)), {"block_sizes": (8, 8, 8)}),
        ]
        for name, dims, cs, kw in cases:
            x = jnp.asarray(rng.normal(size=dims).astype(np.float32))
            f_eng = gemt3_shardmap(mesh, axes=axes, order=None, **kw)
            f_ein = jax.jit(gemt3_shardmap(mesh, axes=axes, engine=False))
            y_eng, y_ein = f_eng(x, *cs), f_ein(x, *cs)
            err = float(jnp.max(jnp.abs(y_eng - y_ein)))
            us_eng, us_ein = tmin([lambda: f_eng(x, *cs),
                                   lambda: f_ein(x, *cs)])
            info = gemt3_planned(x, *cs, mesh=mesh, axes=axes,
                                 with_info=True, **kw)[1]
            backends = "+".join(b.replace(", ", "-")
                                for b in info["backends_executed"])
            print(f"{name},{us_eng:.1f},{us_ein:.1f},{err:.1e},"
                  f"{''.join(map(str, info['order']))},{backends},"
                  f"{info['hbm_bytes_local']},{info['collective_bytes']},"
                  f"{info['fetch_savings']:.3f}")
    """)
    if r.returncode != 0:
        rows.append(("D3_distributed_engine", 0.0,
                     f"FAILED:{r.stderr[-200:]}"))
        return
    for line in r.stdout.strip().splitlines():
        (name, us_eng, us_ein, err, order, backends, local_b, coll_b,
         fetch) = line.split(",")
        rows.append((
            f"D3_engine_vs_einsum_{name}", float(us_eng),
            f"einsum_us={float(us_ein):.1f};"
            f"speedup={float(us_ein) / max(float(us_eng), 1e-9):.2f}x;"
            f"order={order};backends={backends};"
            f"hbm_bytes_local={local_b};collective_bytes={coll_b};"
            f"fetch_savings={fetch};max_abs_err={err}"))
