"""Benchmarks for the paper's core claims (one per claim/figure).

The paper has no measured tables (it is an algorithm+architecture paper);
each benchmark below validates one *stated* claim:

  B1 linear time-steps      §5.4: N1+N2+N3 steps on N1·N2·N3 cells
  B2 hypercubic MACs        §3:   N1N2N3(N1+N2+N3) MACs, 100% efficiency
  B3 ESOP savings           §6:   compute+communication skipped ∝ sparsity
  B4 ESOP accuracy          §6:   shorter accumulation chains: error vs dense
  B5 staged vs element-wise §3:   6D index space -> three 4D spaces speedup
  B6 generality             §3:   non-pow2 / non-square sizes (vs FFT limits)
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import (coefficient_matrix, dxt3d, energy_joules, esop_gemt3,
                        gemt3, macs, prune, simulate_dxt3, time_steps)


def _t(fn, *args, n=3):
    fn(*args)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args)
    jax.block_until_ready(r) if hasattr(r, "block_until_ready") else None
    return (time.perf_counter() - t0) / n * 1e6  # us


def bench_linear_timesteps(rows):
    """B1+B2: simulator steps/MACs match the analytic model exactly."""
    rng = np.random.default_rng(0)
    for dims in [(4, 5, 6), (8, 8, 8), (8, 12, 10), (16, 8, 4)]:
        x = rng.normal(size=dims).astype(np.float32)
        cs = [np.asarray(coefficient_matrix("dct", n)) for n in dims]
        t0 = time.perf_counter()
        _, stats = simulate_dxt3(x, *cs, esop=False)
        dt = (time.perf_counter() - t0) * 1e6
        ok = (stats.steps_done == time_steps(*dims)
              and stats.macs_done == macs(*dims))
        rows.append((f"B1_timesteps_N{dims}", dt,
                     f"steps={stats.steps_done};macs={stats.macs_done};"
                     f"matches_model={ok}"))


def bench_esop_savings(rows):
    """B3: MAC/send/energy savings vs data sparsity."""
    rng = np.random.default_rng(1)
    dims = (16, 16, 16)
    cs = [jnp.asarray(coefficient_matrix("dht", n)) for n in dims]
    for p in (0.0, 0.5, 0.9):
        x = rng.normal(size=dims).astype(np.float32)
        x *= rng.random(dims) >= p
        t0 = time.perf_counter()
        _, stats = esop_gemt3(jnp.asarray(x), *cs)
        dt = (time.perf_counter() - t0) * 1e6
        e = energy_joules(stats)
        rows.append((f"B3_esop_sparsity_{p}", dt,
                     f"mac_savings={stats.mac_savings:.3f};"
                     f"energy_saving={e['saving']:.3f}"))


def bench_esop_accuracy(rows):
    """B4: fp32 rounding error, dense vs ESOP-pruned accumulation chains."""
    rng = np.random.default_rng(2)
    dims = (24, 24, 24)
    x64 = rng.normal(size=dims)
    cs64 = [np.asarray(coefficient_matrix("dct", n), dtype=np.float64)
            for n in dims]
    ref = np.einsum("abc,ax,by,cz->xyz", x64, *cs64)

    def err(xa, csa):
        y = gemt3(jnp.asarray(xa, jnp.float32),
                  *[jnp.asarray(c, jnp.float32) for c in csa])
        return float(np.max(np.abs(np.asarray(y, np.float64) - ref)))

    e_dense = err(x64, cs64)
    # prune 'insignificant' inputs (1e-3 of max): shorter chains
    xp = np.asarray(prune(jnp.asarray(x64), 1e-3 * np.abs(x64).max()))
    refp = np.einsum("abc,ax,by,cz->xyz", xp, *cs64)
    yp = gemt3(jnp.asarray(xp, jnp.float32),
               *[jnp.asarray(c, jnp.float32) for c in cs64])
    e_pruned = float(np.max(np.abs(np.asarray(yp, np.float64) - refp)))
    rows.append(("B4_esop_accuracy", 0.0,
                 f"err_dense={e_dense:.3e};err_pruned_vs_its_oracle={e_pruned:.3e}"))


def bench_staged_vs_elementwise(rows):
    """B5: staged GEMT (3×4D index spaces) vs direct 6D element-wise."""
    rng = np.random.default_rng(3)
    for n in (8, 16, 24):
        x = jnp.asarray(rng.normal(size=(n, n, n)).astype(np.float32))
        cs = [coefficient_matrix("dct", n) for _ in range(3)]

        direct = jax.jit(lambda x, a, b, c: jnp.einsum(
            "abc,ax,by,cz->xyz", x, a, b, c))
        staged = jax.jit(lambda x, a, b, c: gemt3(x, a, b, c))
        t_direct = _t(direct, x, *cs)
        t_staged = _t(staged, x, *cs)
        rows.append((f"B5_staged_vs_direct_N{n}", t_staged,
                     f"direct_us={t_direct:.1f};"
                     f"speedup={t_direct / max(t_staged, 1e-9):.2f};"
                     f"mac_ratio={(n**3)**2 / macs(n, n, n):.1f}"))


def bench_generality(rows):
    """B6: arbitrary (non-pow2, non-square) sizes run fine; DFT case checks
    against numpy's FFT where FFT exists."""
    rng = np.random.default_rng(4)
    for dims in [(5, 7, 11), (12, 20, 36), (9, 3, 17)]:
        x = jnp.asarray(rng.normal(size=dims).astype(np.float32))
        t0 = time.perf_counter()
        y = dxt3d(x, "dft")
        np.testing.assert_allclose(np.asarray(y),
                                   np.fft.fftn(np.asarray(x), norm="ortho"),
                                   rtol=2e-3, atol=2e-4)
        dt = (time.perf_counter() - t0) * 1e6
        rows.append((f"B6_generality_N{dims}", dt, "matches_fftn=True"))
