"""Distributed execution-engine tests: the planned/fused Pallas kernels
running inside the TriADA shard_map schedule (docs/distributed.md).

Numerical equivalence of ``gemt3_planned(mesh=...)`` vs the single-device
plan across 1D/2D/3D meshes, sharded stage orders, ESOP-sparse
coefficients, Pallas-interpret kernels inside the shard_map body, the
fusion-under-sharding rule, and the per-shard/collective byte accounting.
Every case runs under 8 virtual CPU devices via the ``virtual_devices``
conftest fixture.
"""

import textwrap

_PRELUDE = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import gemt3_shardmap
from repro.core.transforms import coefficient_matrix
from repro.engine import gemt3_planned, plan_gemt3

rng = np.random.default_rng(7)
x = jnp.asarray(rng.normal(size=(16, 12, 8)).astype(np.float32))
cs = tuple(coefficient_matrix("dct", n) for n in x.shape)
ref = gemt3_planned(x, *cs)


def check(y, r=None, atol=1e-5):
    np.testing.assert_allclose(np.asarray(y), np.asarray(r if r is not None
                                                         else ref), atol=atol)
"""


def _case(body: str) -> str:
    return _PRELUDE + textwrap.dedent(body)


class TestDistributedEngineEquivalence:
    def test_mesh_1d_2d_3d(self, virtual_devices):
        """Planned sharded path == single-device plan on 1D/2D/3D meshes."""
        virtual_devices(_case("""
        cases = [
            (jax.make_mesh((8,), ("x",)), ("x", None, None)),
            (jax.make_mesh((2, 4), ("data", "model")), ("data", "model", None)),
            (jax.make_mesh((2, 2, 2), ("a", "b", "c")), ("a", "b", "c")),
            (jax.make_mesh((2, 2, 2), ("a", "b", "c")), (("a", "c"), "b", None)),
        ]
        for mesh, axes in cases:
            y, info = gemt3_planned(x, *cs, mesh=mesh, axes=axes,
                                    with_info=True)
            check(y)
            want = tuple(1 if a is None else
                         int(np.prod([mesh.shape[n] for n in
                                      (a if isinstance(a, tuple) else (a,))]))
                         for a in axes)
            assert info["shards"] == want, (axes, info["shards"])
        print("OK")
        """))

    def test_default_axes_from_mesh(self, virtual_devices):
        """axes=None shards modes over the mesh axes in order."""
        virtual_devices(_case("""
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        y, info = gemt3_planned(x, *cs, mesh=mesh, with_info=True)
        check(y)
        assert info["axes"] == ("data", "model", None), info["axes"]
        print("OK")
        """))

    def test_all_sharded_stage_orders(self, virtual_devices):
        """Every pinned order agrees with the single-device result, with the
        sharded-mode stages placed anywhere in the chain."""
        virtual_devices(_case("""
        import itertools
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        for order in itertools.permutations((1, 2, 3)):
            y = gemt3_planned(x, *cs, mesh=mesh, axes=("data", None, "model"),
                              order=order)
            check(y, gemt3_planned(x, *cs, order=order))
        print("OK")
        """))

    def test_batched_with_batch_axis(self, virtual_devices):
        """Data-parallel batch sharding composes with mode sharding."""
        virtual_devices(_case("""
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        xb = jnp.asarray(rng.normal(size=(4, 16, 12, 8)).astype(np.float32))
        y, info = gemt3_planned(xb, *cs, mesh=mesh, axes=(None, "model", None),
                                batch_axis="data", with_info=True)
        check(y, gemt3_planned(xb, *cs))
        assert info["batch_axis"] == "data"
        assert info["collective_bytes"] > 0  # the mode-2 psum_scatter
        print("OK")
        """))

    def test_pallas_interpret_inside_shardmap(self, virtual_devices):
        """use_pallas=True runs interpret-mode Pallas kernels per shard."""
        virtual_devices(_case("""
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        y, info = gemt3_planned(x, *cs, mesh=mesh, axes=("data", None, None),
                                use_pallas=True, with_info=True)
        check(y)
        # at least one shard-local stage must be on a Pallas kernel path
        assert any(b.startswith(("sr_gemm", "esop", "fused"))
                   for b in info["backends_executed"]), info
        print("OK")
        """))

    def test_sharded_sr_gemm_branch(self, virtual_devices):
        """The sr_gemm sharded-mode lowering stays covered off-TPU.

        The planner's break-even demotes sharded stages to einsum on
        non-TPU hosts (the reference dispatch dominates there), which
        would otherwise leave lower_sharded_stage's kernel branch
        untested until real hardware: pin the backend back to sr_gemm on
        the built plan and run it with interpret-mode Pallas.
        """
        virtual_devices(_case("""
        import dataclasses
        from repro.engine import execute_sharded_with_info
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        p = plan_gemt3(x.shape, x.dtype, *cs, mesh=mesh,
                       axes=("data", None, None), fuse=False)
        stages = tuple(dataclasses.replace(s, backend="sr_gemm")
                       if s.shards > 1 else s for s in p.stages)
        assert any(s.backend == "sr_gemm" and s.shards > 1 for s in stages)
        p = dataclasses.replace(p, stages=stages,
                                key=p.key + "|pinned-sr_gemm")
        y, info = execute_sharded_with_info(p, mesh, x, *cs,
                                            use_pallas=True)
        check(y)
        assert "sr_gemm" in info["backends_executed"]
        print("OK")
        """))

    def test_esop_sparse_coefficients(self, virtual_devices):
        """Block-sparse C on an unsharded mode engages block-ESOP per shard
        (reference and Pallas-interpret paths), bit-matching the dense plan."""
        virtual_devices(_case("""
        c1s = np.asarray(cs[0]).copy(); c1s[:, 8:] = 0.0
        c1s = jnp.asarray(c1s)
        kw = dict(block_sizes=(8, 8, 8), fuse=False)
        r = gemt3_planned(x, c1s, cs[1], cs[2], **kw)
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        for up in (None, True):
            y, info = gemt3_planned(x, c1s, cs[1], cs[2], mesh=mesh,
                                    axes=(None, "model", None), use_pallas=up,
                                    with_info=True, **kw)
            check(y, r)
            assert "esop" in info["backends_executed"], info
            assert info["fetch_savings"] > 0.3
        print("OK")
        """))


class TestDistributedEnginePlanner:
    def test_fusion_only_when_pair_shard_local(self, virtual_devices):
        """The fused VMEM kernel may only cover shard-local mode pairs."""
        virtual_devices(_case("""
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        xs = jnp.asarray(rng.normal(size=(32, 32, 32)).astype(np.float32))
        css = tuple(coefficient_matrix("dct", 32) for _ in range(3))
        # modes 2+3 local: a fused pair is allowed and must avoid mode 1
        p = plan_gemt3(xs.shape, xs.dtype, *css, mesh=mesh,
                       axes=("data", None, None), fuse=True)
        if p.fused is not None:
            assert {p.fused.mode_a, p.fused.mode_b} == {2, 3}
        # all modes sharded: fusion is impossible even when forced
        mesh3 = jax.make_mesh((2, 2, 2), ("a", "b", "c"))
        p2 = plan_gemt3(xs.shape, xs.dtype, *css, mesh=mesh3,
                        axes=("a", "b", "c"), fuse=True)
        assert p2.fused is None
        # and a mesh axis may shard only one mode (clear plan-time error)
        try:
            plan_gemt3(xs.shape, xs.dtype, *css, mesh=mesh,
                       axes=("data", "model", ("data", "model")))
        except ValueError as e:
            assert "more than one" in str(e)
        else:
            raise AssertionError("expected duplicate-axis ValueError")
        y = gemt3_planned(xs, *css, mesh=mesh, axes=("data", None, None),
                          fuse=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(gemt3_planned(xs, *css)),
                                   atol=1e-5)
        print("OK")
        """))

    def test_collective_byte_model(self, virtual_devices):
        """Per-stage collective bytes follow rows·K·itemsize·(P-1)/P and
        unsharded stages model zero."""
        virtual_devices(_case("""
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        p = plan_gemt3(x.shape, x.dtype, *cs, mesh=mesh,
                       axes=("data", "model", None), order=(3, 1, 2))
        by_mode = {s.mode: s for s in p.stages}
        assert by_mode[3].collective_bytes == 0
        for mode, pshards in ((1, 2), (2, 4)):
            s = by_mode[mode]
            assert s.shards == pshards
            want = (s.rows * s.k * 4 * (pshards - 1)) // pshards
            assert s.collective_bytes == want, (mode, s.collective_bytes, want)
        assert p.collective_bytes == sum(s.collective_bytes for s in p.stages)
        assert p.hbm_bytes_moved > 0  # per-shard local traffic is tracked too
        print("OK")
        """))

    def test_order_search_prefers_unsharded_first(self, virtual_devices):
        """With equal MACs, the searched order defers the sharded mode so the
        compressive local stages shrink the scattered partial."""
        virtual_devices(_case("""
        mesh = jax.make_mesh((2,), ("x",))
        # cube with strongly compressive modes 2/3; mode 1 sharded over x
        c1 = coefficient_matrix("dct", 16)
        comp = jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))
        xs = jnp.asarray(rng.normal(size=(16, 16, 16)).astype(np.float32))
        p = plan_gemt3(xs.shape, xs.dtype, c1, comp, comp, mesh=mesh,
                       axes=("x", None, None))
        assert p.order[-1] == 1, p.order  # sharded mode contracted last
        y = gemt3_planned(xs, c1, comp, comp, mesh=mesh,
                          axes=("x", None, None))
        np.testing.assert_allclose(
            np.asarray(y), np.asarray(gemt3_planned(xs, c1, comp, comp)),
            atol=1e-5)
        print("OK")
        """))

    def test_divisibility_validation(self, virtual_devices):
        """Non-dividing mode or K extents fail loudly at plan time."""
        virtual_devices(_case("""
        mesh = jax.make_mesh((8,), ("x",))
        try:
            plan_gemt3((12, 8, 8), jnp.float32, *[
                jnp.ones((n, n), jnp.float32) for n in (12, 8, 8)],
                mesh=mesh, axes=("x", None, None))
        except ValueError as e:
            assert "not divisible" in str(e)
        else:
            raise AssertionError("expected ValueError")
        print("OK")
        """))


class TestDistributedServe:
    def test_shardmap_delegates_and_serve_mesh(self, virtual_devices):
        """gemt3_shardmap is the engine path (info-compatible with
        gemt3_planned), and DxtServeSession(mesh=...) accumulates the
        collective split."""
        virtual_devices(_case("""
        from repro.serve import DxtServeSession
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        f = gemt3_shardmap(mesh, axes=("data", "model", None), order=None)
        check(f(x, *cs))
        check(jax.jit(f)(x, *cs))  # traced coefficients: dense-only planning
        sess = DxtServeSession(kind="dct", mesh=mesh,
                               axes=("model", None, None),
                               batch_axis="data")
        batch = rng.normal(size=(4, 16, 12, 8)).astype(np.float32)
        y = sess.transform(batch)
        ref_sess = DxtServeSession(kind="dct")
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref_sess.transform(batch)),
                                   atol=1e-5)
        assert sess.requests_served == 4
        assert sess.collective_bytes > 0
        assert sess.hbm_bytes_moved > 0
        print("OK")
        """))
