"""Shared fixtures.

Tests run on the single real CPU device (the dry-run, and only the
dry-run, uses 512 placeholder devices — see launch/dryrun.py).  Multi-device
tests use the ``virtual_devices`` fixture: jax fixes its device count at
first import, so each multi-device case executes in a fresh subprocess
whose ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` is set before
jax initializes.
"""
import os
import subprocess
import sys
import textwrap

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="session")
def virtual_devices():
    """Run a code snippet under N virtual CPU devices; returns its stdout.

    Asserts a zero exit status (stdout/stderr are surfaced on failure).
    Used by the distributed GEMT / engine / train-step tests.
    """

    def run(code: str, devices: int = 8) -> str:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
        env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
        r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                           capture_output=True, text=True, env=env,
                           timeout=600)
        assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
        return r.stdout

    return run
