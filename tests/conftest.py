import os
import sys

# Tests run on the single real CPU device (the dry-run, and only the
# dry-run, uses 512 placeholder devices — see launch/dryrun.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
