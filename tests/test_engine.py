"""Engine subsystem: planner optimality, lowering correctness vs the einsum
oracle, block-ESOP dispatch, batching, autotune cache round trip."""
import itertools
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import coefficient_matrix, dxt3d, gemt3, gemt3_outer, prune
from repro.engine import (AutotuneCache, autotune_gemm, build_plan,
                          gemt3_planned, macs_for_order, mode_fold,
                          mode_unfold, order_costs, plan_gemt3)
from repro.kernels import ops

RNG = np.random.default_rng(11)


def _rand(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def _rect_problem(dims, ranks, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=dims).astype(np.float32))
    cs = tuple(jnp.asarray(rng.normal(size=(n, k)).astype(np.float32))
               for n, k in zip(dims[-3:], ranks))  # dims may carry a batch
    return x, cs


class TestPlanner:
    @pytest.mark.parametrize("dims,ranks", [
        ((64, 32, 16), (4, 16, 16)),   # compressive mode 1
        ((16, 64, 32), (16, 4, 16)),   # compressive mode 2
        ((32, 16, 64), (16, 16, 4)),   # compressive mode 3
        ((48, 48, 48), (4, 12, 24)),   # graded compression
        ((8, 8, 8), (32, 16, 8)),      # expansion
        ((24, 20, 28), (24, 20, 28)),  # square: all orders tie on MACs
    ])
    def test_picks_mac_minimizing_order(self, dims, ranks):
        """The chosen order matches the brute-force MAC minimum (all six)."""
        x, cs = _rect_problem(dims, ranks)
        plan = build_plan(x.shape, x.dtype, *cs)
        brute = min(macs_for_order(dims, ranks, o)
                    for o in itertools.permutations((1, 2, 3)))
        assert plan.macs == brute
        assert plan.macs <= macs_for_order(dims, ranks, (3, 1, 2))

    def test_order_costs_enumerates_all_six(self):
        x, cs = _rect_problem((16, 12, 8), (4, 12, 8))
        costs = order_costs((16, 12, 8), {1: cs[0], 2: cs[1], 3: cs[2]})
        assert len(costs) == 6
        for order, c in costs.items():
            assert c["macs"] == macs_for_order((16, 12, 8), (4, 12, 8), order)

    def test_explicit_order_is_pinned(self):
        x, cs = _rect_problem((32, 16, 16), (4, 16, 16))
        plan = build_plan(x.shape, x.dtype, *cs, order=(3, 1, 2))
        assert plan.order == (3, 1, 2)

    def test_esop_backend_from_block_sparsity(self):
        """>=50% zero blocks in C selects the block-ESOP backend."""
        rng = np.random.default_rng(5)
        keep = rng.random((4, 4)) < 0.5
        while keep.mean() > 0.5 or not keep.any():
            keep = rng.random((4, 4)) < 0.5
        c3 = jnp.asarray((np.kron(keep, np.ones((32, 32)))
                          * rng.normal(size=(128, 128))).astype(np.float32))
        c1, c2 = jnp.eye(16), jnp.eye(16)
        plan = build_plan((16, 16, 128), jnp.float32, c1, c2, c3,
                          block_sizes=(128, 32, 32))
        (stage3,) = [s for s in plan.stages if s.mode == 3]
        assert stage3.backend == "esop"
        assert stage3.zero_block_frac >= 0.5
        # sparsity discounts the effective MACs
        assert plan.macs_effective < plan.macs

    def test_esop_discount_survives_small_rows(self):
        """Effective MACs stay discounted when rows are far below bm."""
        rng = np.random.default_rng(21)
        keep = np.array([[1, 0, 0, 1]] * 4).astype(bool)
        c3 = jnp.asarray((np.kron(keep, np.ones((64, 64)))
                          * rng.normal(size=(256, 256))).astype(np.float32))
        c1, c2 = jnp.eye(4), jnp.eye(4)
        plan = build_plan((4, 4, 256), jnp.float32, c1, c2, c3,
                          block_sizes=(128, 64, 64))
        (s3,) = [s for s in plan.stages if s.mode == 3]
        assert s3.backend == "esop"
        assert s3.macs_effective < s3.macs  # rows<bm must not saturate

    def test_batched_rows_reach_kernels(self):
        """Backend choice sees batch-folded GEMM rows, not per-sample rows."""
        x, cs = _rect_problem((64, 2, 2, 64), (2, 2, 32), seed=8)
        plan = build_plan(x.shape, x.dtype, *cs)
        (stage3,) = [s for s in plan.stages if s.mode == 3]
        assert stage3.backend == "sr_gemm"  # 4 rows/sample, 256 batched
        unbatched = build_plan(x.shape[1:], x.dtype, *cs)
        (u3,) = [s for s in unbatched.stages if s.mode == 3]
        assert u3.backend == "einsum"

    def test_complex_falls_back_to_einsum(self):
        c = coefficient_matrix("dft", 16)
        plan = build_plan((16, 16, 16), jnp.complex64, c, c, c)
        assert plan.backends == ("einsum", "einsum", "einsum")

    def test_plan_validation(self):
        x, cs = _rect_problem((8, 8, 8), (8, 8, 8))
        with pytest.raises(ValueError):
            build_plan((8, 8), jnp.float32, *cs)
        with pytest.raises(ValueError):
            build_plan((8, 8, 9), jnp.float32, *cs)
        with pytest.raises(ValueError):
            build_plan((8, 8, 8), jnp.float32, *cs, order=(1, 1, 2))


class TestLowering:
    @pytest.mark.parametrize("mode", [1, 2, 3])
    def test_unfold_fold_roundtrip(self, mode):
        x = _rand(4, 5, 6)
        m, lead = mode_unfold(x, mode)
        assert m.shape == (x.size // x.shape[mode - 1], x.shape[mode - 1])
        np.testing.assert_array_equal(np.asarray(mode_fold(m, lead, mode)),
                                      np.asarray(x))

    @pytest.mark.parametrize("mode", [1, 2, 3])
    def test_unfold_fold_batched(self, mode):
        x = _rand(3, 4, 5, 6)
        m, lead = mode_unfold(x, mode)
        np.testing.assert_array_equal(np.asarray(mode_fold(m, lead, mode)),
                                      np.asarray(x))

    def test_dense_matches_oracles(self):
        """Engine == gemt3 einsum oracle == gemt3_outer, dense rectangular."""
        x, cs = _rect_problem((24, 20, 16), (8, 10, 12), seed=1)
        y = gemt3_planned(x, *cs)
        ref = gemt3(x, *cs)
        outer = gemt3_outer(x, *cs)
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(y, outer, rtol=1e-4, atol=1e-4)

    def test_block_sparse_matches_oracle_with_savings(self):
        rng = np.random.default_rng(9)
        keep = np.array([[1, 0, 0, 1]] * 4).astype(bool)  # 50% zero blocks
        c3 = jnp.asarray((np.kron(keep, np.ones((32, 32)))
                          * rng.normal(size=(128, 128))).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(16, 16, 128)).astype(np.float32))
        c1, c2 = _rand(16, 16), _rand(16, 16)
        # fuse=False pins the staged schedule this test is about (the fused
        # kernel may legitimately prefer a dense assignment here — sparse
        # *fused* execution is covered in test_fused_gemt.py)
        y, info = gemt3_planned(x, c1, c2, c3, block_sizes=(128, 32, 32),
                                fuse=False, with_info=True)
        np.testing.assert_allclose(y, gemt3(x, c1, c2, c3),
                                   rtol=1e-4, atol=1e-4)
        assert "esop" in info["backends"]
        assert info["fetch_savings"] > 0
        # the default (auto-fusion) schedule stays numerically identical
        yf = gemt3_planned(x, c1, c2, c3, block_sizes=(128, 32, 32))
        np.testing.assert_allclose(yf, y, rtol=1e-4, atol=1e-4)

    def test_pruned_sparse_matches_oracle(self):
        x, cs = _rect_problem((32, 32, 32), (16, 16, 16), seed=2)
        cs = tuple(prune(c, 0.8) for c in cs)  # heavy elementwise pruning
        y = gemt3_planned(x, *cs, block_sizes=(32, 8, 8))
        ref = gemt3(x, *cs)
        tol = 1e-4 * float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=max(tol, 1e-5))

    def test_affine_out(self):
        x, cs = _rect_problem((12, 10, 8), (6, 5, 4), seed=3)
        out = _rand(6, 5, 4)
        np.testing.assert_allclose(gemt3_planned(x, *cs, out=out),
                                   gemt3(x, *cs, out=out),
                                   rtol=1e-4, atol=1e-4)
        with pytest.raises(TypeError):
            # out is keyword-only: gemt3's 5th positional is `order`, and a
            # positional tuple must not silently become the affine term.
            gemt3_planned(x, *cs, (1, 2, 3))

    def test_batched_matches_vmap(self):
        x, cs = _rect_problem((4, 12, 10, 8), (6, 5, 4), seed=4)
        y = gemt3_planned(x, *cs)
        ref = jax.vmap(lambda t: gemt3(t, *cs))(x)
        assert y.shape == (4, 6, 5, 4)
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)


class TestDxtEngine:
    @pytest.mark.parametrize("kind", ["dct", "dht", "dwht", "dft"])
    def test_all_kinds_match(self, kind):
        """dxt3d(engine=True) == dxt3d for the whole DXT family (<=1e-4)."""
        x = _rand(16, 8, 4)
        y = dxt3d(x, kind, engine=True)
        ref = dxt3d(x, kind)
        scale = float(jnp.max(jnp.abs(ref)))
        assert float(jnp.max(jnp.abs(y - ref))) <= 1e-4 * max(scale, 1.0)

    def test_engine_roundtrip(self):
        x = _rand(8, 8, 8)
        xr = dxt3d(dxt3d(x, "dct", engine=True), "dct", inverse=True,
                   engine=True)
        np.testing.assert_allclose(xr, x, rtol=2e-4, atol=2e-4)


class TestAutotune:
    def test_cache_json_roundtrip(self, tmp_path):
        path = str(tmp_path / "autotune.json")
        cache = AutotuneCache(path)
        key = "v4:64x128x32|float32|dense|fwd|plain|s"
        cache.put(key, {"bm": 64, "bn": 128, "bk": 32, "us": 1.5})
        cache.save()
        reloaded = AutotuneCache(path)
        assert reloaded.get(key) == {"bm": 64, "bn": 128, "bk": 32, "us": 1.5}
        assert len(reloaded) == 1
        with open(path) as f:
            assert key in json.load(f)

    def test_cache_prunes_stale_keys_on_load(self, tmp_path):
        # pre-role/stale-version keys are dropped on load (and counted),
        # live-schema keys survive
        from repro import obs

        path = str(tmp_path / "autotune.json")
        live = "fused:v5:16x16x16|float32|dense|fwd|plain|s|vb4194304"
        stale = {"16x16x16|float32|dense|s": {"bm": 8},  # pre-role, no version
                 "v3:16x16x16|float32|dense|fwd|plain|s": {"bm": 8},
                 "fused:v4:16x16x16|float32|dense|fwd|plain|s": {"bm": 8}}
        with open(path, "w") as f:
            json.dump({live: {"bu": 8, "bka": 8, "bnb": 8}, **stale}, f)
        with obs.session() as s:
            cache = AutotuneCache(path)
            assert len(cache) == 1 and cache.get(live) is not None
            assert s.registry.value("autotune.cache.pruned") == len(stale)
            # prune() is idempotent once the rubble is gone
            assert cache.prune() == 0

    def test_corrupt_cache_tolerated(self, tmp_path):
        path = str(tmp_path / "autotune.json")
        with open(path, "w") as f:
            f.write("{not json")
        cache = AutotuneCache(path)
        assert len(cache) == 0

    def test_autotune_returns_valid_blocks_and_caches(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "a.json"))
        x, c = _rand(64, 32), _rand(32, 64)
        cfg = autotune_gemm(x, c, "sr_gemm", cache=cache, max_steps=2, reps=1)
        assert all(8 <= b <= 512 for b in cfg)
        # second call is a pure cache hit (same result, no timing)
        assert autotune_gemm(x, c, "sr_gemm", cache=cache) == cfg
        assert len(AutotuneCache(cache.path)) == 1  # persisted

    def test_autotuned_execution_matches_oracle(self, tmp_path):
        x, cs = _rect_problem((32, 24, 16), (8, 12, 16), seed=6)
        cache = AutotuneCache(str(tmp_path / "a.json"))
        y = gemt3_planned(x, *cs, autotune=True, autotune_cache=cache)
        np.testing.assert_allclose(y, gemt3(x, *cs), rtol=1e-4, atol=1e-4)


class TestExecutorCache:
    def test_plan_cache_hit(self):
        from repro.engine import clear_plan_cache, plan_cache_info
        clear_plan_cache()
        x, cs = _rect_problem((16, 12, 8), (4, 6, 8), seed=7)
        p1 = plan_gemt3(x.shape, x.dtype, *cs)
        assert plan_cache_info()["entries"] == 1
        p2 = plan_gemt3(x.shape, x.dtype, *cs)
        assert p1 is p2  # memoized
        # different zero structure => different plan entry
        p3 = plan_gemt3(x.shape, x.dtype, prune(cs[0], 1.0), cs[1], cs[2])
        assert plan_cache_info()["entries"] == 2


class TestKernelOpsInfo:
    def test_esop_ref_path_reports_real_savings(self):
        """Satellite: the non-Pallas esop_gemm path computes real stats."""
        rng = np.random.default_rng(13)
        keep = np.array([[1, 0], [0, 1]]).astype(bool)
        c = jnp.asarray((np.kron(keep, np.ones((32, 32)))
                         * rng.normal(size=(64, 64))).astype(np.float32))
        x = _rand(32, 64)
        y, info = ops.esop_gemm(x, c, bm=32, bn=32, bk=32, use_pallas=False)
        assert info["blocks_dense"] == 4
        assert info["blocks_live"] == 2
        assert info["fetch_savings"] == pytest.approx(0.5)
        np.testing.assert_allclose(
            y, jnp.dot(x, c), rtol=1e-5, atol=1e-5)


class TestServe:
    def test_dxt_serve_session_batched(self):
        from repro.serve import DxtServeSession
        sess = DxtServeSession(kind="dct")
        b = _rand(5, 16, 12, 8)
        y = sess.transform(b)
        ref = jax.vmap(lambda t: dxt3d(t, "dct"))(b)
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)
        assert sess.requests_served == 5
        # plan is memoized in the engine across calls (coeff identity stable)
        from repro.engine import plan_cache_info
        n_plans = plan_cache_info()["entries"]
        sess.transform(b)
        assert plan_cache_info()["entries"] == n_plans
        with pytest.raises(ValueError):
            sess.transform(_rand(4, 4, 4))
