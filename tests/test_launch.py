"""Launch-layer units: rules/specs, input_specs, MODEL_FLOPS accounting,
report generation, hillclimb arg parsing."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, input_specs, load_config
from repro.launch.flops import model_flops
from repro.launch.mesh import (TP, act_rules, batch_specs, dp_axes,
                               param_rules, spec_of, specs_from_axes)


class TestRules:
    def test_no_duplicate_mesh_axes_in_any_param_spec(self):
        """Every (arch, serve/train, mesh) param spec must be legal."""
        from repro.models import model_axes
        from repro.train import train_state_axes
        for arch in ARCH_IDS:
            cfg = load_config(arch).finalize_for_mesh(TP)
            for multi in (False, True):
                for serve in (False, True):
                    rules = param_rules(cfg, multi, serve=serve)
                    axes = model_axes(cfg) if serve else train_state_axes(cfg)
                    specs = specs_from_axes(axes, rules)
                    for spec in jax.tree.leaves(
                            specs, is_leaf=lambda x: isinstance(x, P)):
                        flat = []
                        for entry in spec:
                            if entry is None:
                                continue
                            flat.extend(entry if isinstance(entry, tuple)
                                        else [entry])
                        assert len(flat) == len(set(flat)), (arch, spec)

    def test_serve_rules_drop_fsdp(self):
        cfg = load_config("yi_34b").finalize_for_mesh(TP)
        assert param_rules(cfg, False, serve=False)["embed"] == ("data",)
        assert param_rules(cfg, False, serve=True)["embed"] is None

    def test_act_rules_batch_shardable(self):
        cfg = load_config("yi_34b").finalize_for_mesh(TP)
        assert act_rules(cfg, True)["batch"] == ("pod", "data")
        assert act_rules(cfg, True, batch_shardable=False)["batch"] is None

    def test_spec_of(self):
        assert spec_of(("embed", "mlp"), {"embed": None, "mlp": "model"}) \
            == P(None, "model")
        assert spec_of((), {}) == P()


class TestPadding:
    def test_head_padding(self):
        cfg = load_config("yi_34b").finalize_for_mesh(16)
        assert cfg.n_heads == 56 and cfg.eff_n_heads == 64
        assert cfg.n_kv_heads == 8 and cfg.eff_n_kv_heads == 16
        cfg2 = load_config("qwen1_5_0_5b").finalize_for_mesh(16)
        assert cfg2.eff_n_heads == 16 and cfg2.eff_n_kv_heads == 16

    def test_vocab_padding(self):
        cfg = load_config("granite_moe_1b").finalize_for_mesh(16)
        assert cfg.vocab_size == 49155
        assert cfg.eff_vocab % 16 == 0 and cfg.eff_vocab >= 49155

    def test_xlstm_keeps_mixers_unsharded(self):
        cfg = load_config("xlstm_350m").finalize_for_mesh(16)
        rules = param_rules(cfg, False)
        assert rules["heads"] is None and rules["mlp"] is None
        assert rules["vocab"] == "model"  # TP stays on the big table


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_all_cells_have_specs(self, arch):
        cfg = load_config(arch).finalize_for_mesh(TP)
        for shape in SHAPES.values():
            ins = input_specs(cfg, shape)
            assert all(isinstance(v, jax.ShapeDtypeStruct)
                       for v in ins.values())
            b = shape.global_batch
            key = ("embeddings" if cfg.input_mode == "embeddings"
                   else "tokens")
            assert ins[key].shape[0] == b
            bs = batch_specs(cfg, shape.kind, act_rules(cfg, False))
            assert set(bs) >= set(ins), (arch, shape.name)


class TestModelFlops:
    def test_dense_matches_6nd(self):
        cfg = load_config("qwen1_5_0_5b", smoke=True)
        mf = model_flops(cfg, SHAPES["train_4k"])
        assert mf["model_flops"] == pytest.approx(
            6.0 * mf["n_params_active"] * 4096 * 256)
        assert mf["n_params_active"] == mf["n_params_total"]

    def test_moe_active_fraction(self):
        cfg = load_config("deepseek_v3_671b", smoke=True)
        mf = model_flops(cfg, SHAPES["train_4k"])
        assert mf["n_params_active"] < mf["n_params_total"]

    def test_decode_counts_one_token_per_seq(self):
        cfg = load_config("qwen1_5_0_5b", smoke=True)
        mf = model_flops(cfg, SHAPES["decode_32k"])
        assert mf["tokens"] == SHAPES["decode_32k"].global_batch


class TestHillclimbParsing:
    def test_kv_parser(self):
        from repro.launch.hillclimb import _parse_kv
        out = _parse_kv(["seq_act=model", "lru_in=None", "remat=dots",
                         "q_chunk=256", "expert=(data,model)", "flag=True"])
        assert out["seq_act"] == "model"
        assert out["lru_in"] is None
        assert out["q_chunk"] == 256
        assert out["expert"] == ("data", "model")
        assert out["flag"] is True


class TestReport:
    def test_tables_from_artifacts(self, tmp_path):
        import json
        from repro.launch.report import dryrun_table, load, roofline_table
        art = {
            "arch": "x", "shape": "train_4k", "mesh": "16x16",
            "compile_s": 1.0, "n_devices": 256,
            "memory": {"per_device_total": 2**30},
            "model_flops": {"model_flops": 1e15},
            "roofline": {"compute_s": 1.0, "memory_s": 2.0,
                         "collective_s": 0.5, "bound": "memory",
                         "flops_per_device": 1e12,
                         "ici_bytes_per_device": 1e9,
                         "useful_flops_ratio": 0.5,
                         "roofline_fraction": 0.01,
                         "coll_by_kind": {"all-reduce": 1e9}},
        }
        with open(tmp_path / "a.json", "w") as f:
            json.dump(art, f)
        arts = load(str(tmp_path))
        assert "| x | train_4k |" in dryrun_table(arts)
        assert "**memory**" in roofline_table(arts)
