"""Tier-2 obs_smoke: spans, metrics registry, exporters, engine telemetry.

Covers the observability contract end to end: span nesting/timing, the
disabled-mode zero-allocation fast path, Chrome-trace JSON schema
round-trip, serve latency percentiles, counter parity with the legacy
per-call ``info`` fields on the staged/pair/triple/sharded/backward
paths, fusion-degradation events, autotune-cache atomicity + corrupt
recovery, and the ``grad_stats`` shim.
"""
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.engine import (AutotuneCache, clear_plan_cache, gemt3_planned,
                          grad_stats, reset_grad_stats)
from repro.obs import trace as trace_mod

pytestmark = pytest.mark.obs_smoke

RNG = np.random.default_rng(7)


def _rand(*shape):
    return jnp.asarray(RNG.random(shape, dtype=np.float32))


def _problem(n=16):
    return (_rand(n, n, n), _rand(n, n), _rand(n, n), _rand(n, n))


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_nesting_and_timing():
    with obs.session() as s:
        with obs.span("outer", {"k": 1}):
            time.sleep(0.002)
            with obs.span("inner"):
                time.sleep(0.001)
        spans = s.tracer.spans()
    assert [sp.name for sp in spans] == ["inner", "outer"]  # exit order
    inner, outer = spans
    assert outer.parent_id == 0 and inner.parent_id == outer.span_id
    assert inner.depth == 1 and outer.depth == 0
    assert outer.dur_ns >= inner.dur_ns > 0
    assert outer.t0_ns <= inner.t0_ns
    assert outer.attrs == {"k": 1}


def test_span_set_adds_attributes():
    with obs.session() as s:
        with obs.span("a") as sp:
            sp.set(extra=42)
        assert s.tracer.spans()[0].attrs["extra"] == 42


def test_traced_decorator():
    @obs.traced("decorated", kind="test")
    def f(v):
        return v + 1

    with obs.session() as s:
        assert f(1) == 2
        (sp,) = s.tracer.spans()
    assert sp.name == "decorated" and sp.attrs == {"kind": "test"}
    # disabled: plain call, nothing recorded
    with obs.session(enable_tracing=False) as s:
        assert f(1) == 2
        assert s.tracer.spans() == []


def test_ring_buffer_bounds_spans():
    with obs.session(capacity=4) as s:
        for i in range(10):
            with obs.span(f"s{i}"):
                pass
        names = [sp.name for sp in s.tracer.spans()]
    assert names == ["s6", "s7", "s8", "s9"]


def test_disabled_mode_is_zero_allocation():
    """span() must return the preallocated NULL_SPAN singleton (identity,
    not a fresh object) and never evaluate a callable attrs thunk."""
    with obs.session(enable_tracing=False) as s:
        assert trace_mod.span("x") is trace_mod.NULL_SPAN
        assert not trace_mod.enabled()
        called = []
        sp = trace_mod.span("x", lambda: called.append(1) or {})
        assert sp is trace_mod.NULL_SPAN and called == []
        with sp:
            pass
        assert s.tracer.spans() == []
    # enabled: the thunk *is* evaluated
    with obs.session() as s:
        with trace_mod.span("x", lambda: {"lazy": True}):
            pass
        assert s.tracer.spans()[0].attrs == {"lazy": True}


def test_untraced_engine_run_records_no_spans():
    x, c1, c2, c3 = _problem()
    with obs.session(enable_tracing=False) as s:
        gemt3_planned(x, c1, c2, c3)
        assert s.tracer.spans() == []
        # metrics are always on, even with tracing off
        assert s.registry.value("engine.executions") == 1


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_histograms():
    r = obs.MetricsRegistry("t")
    r.inc("a.b", 3)
    r.inc("a.b")
    r.set_gauge("g", 2.5)
    for v in range(1, 101):
        r.observe("h", float(v))
    assert r.value("a.b") == 4
    assert r.value("nonexistent") == 0
    snap = r.snapshot()
    assert snap["a.b"] == 4 and snap["g"] == 2.5
    assert snap["h.count"] == 100
    # sum/mean are exact running totals (not window-bounded), so
    # throughput math over a snapshot needs no percentile estimate
    assert snap["h.sum"] == 5050.0
    assert snap["h.mean"] == 50.5
    h = r.histogram("h")
    assert h.percentile(50) <= h.percentile(90) <= h.percentile(99)
    assert h.summary()["max"] == 100.0
    assert h.summary()["sum"] == 5050.0
    empty = obs.Histogram()
    assert empty.summary()["sum"] == 0.0
    r.reset("a.")
    assert r.value("a.b") == 0 and r.gauge("g").value == 2.5


def test_session_isolation():
    obs.inc("iso.test", 5)
    before = obs.get_registry().value("iso.test")
    with obs.session() as s:
        obs.inc("iso.test", 100)
        assert s.registry.value("iso.test") == 100
    assert obs.get_registry().value("iso.test") == before


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_roundtrip(tmp_path):
    path = str(tmp_path / "trace.json")
    with obs.session() as s:
        with obs.span("root", {"shape": (4, 4, 4)}):
            with obs.span("child", {"macs": 64}):
                pass
        obs.inc("engine.macs", 64)
        doc = obs.write_chrome_trace(path, s.tracer.spans(), s.registry)
    loaded = json.loads(open(path).read())
    assert loaded == json.loads(json.dumps(doc))
    events = loaded["traceEvents"]
    assert len(events) == 2
    for ev in events:
        assert ev["ph"] == "X"
        assert set(ev) >= {"name", "cat", "ts", "dur", "pid", "tid", "args"}
        assert ev["dur"] >= 0 and ev["ts"] >= 0
    by_name = {e["name"]: e for e in events}
    assert (by_name["child"]["args"]["parent_id"]
            == by_name["root"]["args"]["span_id"])
    assert by_name["root"]["args"]["shape"] == [4, 4, 4]
    assert loaded["counters"]["engine.macs"] == 64
    assert loaded["displayTimeUnit"] == "ms"


def test_report_and_cli(tmp_path, capsys):
    from repro.obs.export import main as obs_main

    path = str(tmp_path / "trace.json")
    with obs.session() as s:
        with obs.span("stage:m1:sr_gemm"):
            pass
        obs.write_chrome_trace(path, s.tracer.spans(), s.registry)
        text = obs.format_report(s.tracer.spans(), s.registry)
    assert "stage:m1:sr_gemm" in text
    assert obs_main([path]) == 0
    assert "stage:m1:sr_gemm" in capsys.readouterr().out
    assert obs_main([path, "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["spans"]["stage:m1:sr_gemm"]["count"] == 1


def test_span_tree_lines_indent_children():
    with obs.session() as s:
        with obs.span("parent"):
            with obs.span("kid"):
                pass
        lines = obs.span_tree_lines(s.tracer.spans())
    assert lines[0].startswith("parent") and lines[1].startswith("  kid")


# ---------------------------------------------------------------------------
# engine counter parity with legacy info fields
# ---------------------------------------------------------------------------


def _run_and_compare(fuse, n=24):
    x, c1, c2, c3 = _problem(n)
    clear_plan_cache()
    with obs.session() as s:
        infos = []
        for _ in range(3):
            _, info = gemt3_planned(x, c1, c2, c3, with_info=True, fuse=fuse)
            infos.append(info)
        reg = s.registry
        assert reg.value("engine.executions") == len(infos)
        assert reg.value("engine.macs") == sum(i["macs"] for i in infos)
        assert (reg.value("engine.hbm_bytes_moved")
                == sum(i["hbm_bytes_moved"] for i in infos))
        assert (reg.value("engine.hbm_bytes_staged")
                == sum(i["hbm_bytes_staged"] for i in infos))
        fused = sum(1 for i in infos
                    if i["fused"] and len(i["fused"]["modes"]) == 2)
        fused3 = sum(1 for i in infos
                     if i["fused"] and len(i["fused"]["modes"]) == 3)
        assert reg.value("engine.fused_launches") == fused
        assert reg.value("engine.fused3_launches") == fused3
        assert reg.value("plan.builds") == 1
        assert reg.value("plan.cache_hits") == len(infos) - 1
    return infos[0]


def test_counter_parity_staged():
    info = _run_and_compare(fuse=False)
    assert info["fused"] is None


def test_counter_parity_pair():
    info = _run_and_compare(fuse="pair")
    assert info["fused"] and len(info["fused"]["modes"]) == 2


def test_counter_parity_triple():
    info = _run_and_compare(fuse="triple")
    assert info["fused"] and len(info["fused"]["modes"]) == 3


def test_counter_parity_sharded():
    from jax.sharding import Mesh

    x, c1, c2, c3 = _problem(16)
    mesh = Mesh(np.array(jax.devices()[:1]), ("d",))
    clear_plan_cache()
    with obs.session() as s:
        _, info = gemt3_planned(x, c1, c2, c3, with_info=True, mesh=mesh,
                                axes=("d", None, None))
        reg = s.registry
        assert reg.value("engine.executions") == 1
        assert reg.value("engine.macs") == info["macs"]
        assert (reg.value("engine.collective_bytes")
                == info["collective_bytes"])


def test_counter_parity_backward():
    x, c1, c2, c3 = _problem(16)
    clear_plan_cache()
    with obs.session() as s:
        _, info = gemt3_planned(x, c1, c2, c3, with_info=True,
                                differentiable=True)
        loss = lambda *a: jnp.sum(jnp.abs(
            gemt3_planned(*a, differentiable=True)))
        jax.grad(loss, argnums=(0, 1, 2, 3))(x, c1, c2, c3)
        gs = grad_stats()
        assert gs["backward_calls"] == 1
        # shim parity: grad_stats() IS the grad.* namespace
        for k, v in gs.items():
            assert s.registry.value("grad." + k) == v
        # executed counters in exact parity with the predicted info
        # fields — the fused-adjoint walk dispatches what it planned
        for k in ("kernel_stages", "einsum_stages", "coeff_kernel",
                  "coeff_einsum", "fused_launches"):
            assert gs[k] == info["grad_" + k], k
        total = (gs["kernel_stages"] + gs["einsum_stages"]
                 + gs["coeff_kernel"] + gs["coeff_einsum"])
        assert total == info["grad_launches"] <= 4  # fused walk, was 8
        reset_grad_stats()
        assert grad_stats()["backward_calls"] == 0
        assert s.registry.value("grad.backward_calls") == 0


# ---------------------------------------------------------------------------
# acceptance: traced forward+backward exports a Chrome trace whose span
# tree attributes all 8 backward launches by name
# ---------------------------------------------------------------------------


def test_traced_backward_exports_eight_attributed_launches(tmp_path):
    x, c1, c2, c3 = _problem(16)
    clear_plan_cache()
    path = str(tmp_path / "bwd_trace.json")
    with obs.session() as s:
        # fuse=False pins the adjoint to the staged chain: exactly
        # 2 recompute + 3 grad.x + 3 grad.coeff = 8 attributed launches
        loss = lambda *a: jnp.sum(jnp.abs(
            gemt3_planned(*a, differentiable=True, fuse=False)))
        jax.grad(loss, argnums=(0, 1, 2, 3))(x, c1, c2, c3)
        doc = obs.write_chrome_trace(path, s.tracer.spans(), s.registry)
    loaded = json.loads(open(path).read())
    assert loaded == json.loads(json.dumps(doc))
    events = loaded["traceEvents"]
    bwd = [e for e in events if e["name"].startswith("grad.")]
    assert len(bwd) == 8, [e["name"] for e in bwd]
    names = sorted(e["name"] for e in bwd)
    assert sum(1 for n in names if n.startswith("grad.recompute:m")) == 2
    assert sum(1 for n in names if n.startswith("grad.x:")) == 3
    assert sum(1 for n in names if n.startswith("grad.coeff:m")) == 3
    # every backward launch nests under the vjp.backward parent
    vjp = [e for e in events if e["name"] == "vjp.backward"]
    assert len(vjp) == 1
    vjp_id = vjp[0]["args"]["span_id"]
    for e in bwd:
        assert e["args"]["parent_id"] == vjp_id
    # each grad.* wrapper contains its lowered kernel/einsum stage span
    stage_like = [e for e in events
                  if e["name"].startswith(("stage:", "coeff_grad:",
                                           "fused_pair:", "fused_triple:"))]
    bwd_ids = {e["args"]["span_id"] for e in bwd}
    assert sum(1 for e in stage_like
               if e["args"]["parent_id"] in bwd_ids) >= 8
    assert loaded["counters"]["grad.backward_calls"] == 1


def test_fused_backward_spans_attributed_like_forward():
    """The fused-adjoint walk's launches carry the same span-attribution
    contract as the staged one: every grad.* wrapper nests under
    vjp.backward and the span count equals the planned launch count."""
    x, c1, c2, c3 = _problem(16)
    clear_plan_cache()
    with obs.session() as s:
        _, info = gemt3_planned(x, c1, c2, c3, with_info=True,
                                differentiable=True)
        assert info["grad_fused"] and info["grad_chain_depth"] >= 2
        loss = lambda *a: jnp.sum(jnp.abs(
            gemt3_planned(*a, differentiable=True)))
        jax.grad(loss, argnums=(0, 1, 2, 3))(x, c1, c2, c3)
        spans = s.tracer.spans()
    bwd = [sp for sp in spans if sp.name.startswith("grad.")]
    assert len(bwd) == info["grad_launches"]
    names = sorted(sp.name for sp in bwd)
    assert "grad.recompute:fused" in names
    assert "grad.x:fused" in names
    assert "grad.coeff:batched" in names
    if info["grad_chain_depth"] == 2:  # staged tail stage of the pair walk
        assert sum(1 for n in names if n.startswith("grad.chain:m")) == 1
    (vjp,) = [sp for sp in spans if sp.name == "vjp.backward"]
    for sp in bwd:
        assert sp.parent_id == vjp.span_id


# ---------------------------------------------------------------------------
# fusion-degradation events
# ---------------------------------------------------------------------------


def test_fusion_degradation_events_surface_in_info():
    x, c1, c2, c3 = _problem(32)
    clear_plan_cache()
    with obs.session() as s:
        _, info = gemt3_planned(x, c1, c2, c3, with_info=True,
                                vmem_budget=20_000)
        events = info["events"]
        assert events, "tiny budget must demote fusion and record why"
        for ev in events:
            assert ev["kind"] == "fusion_degradation"
            assert ev["from"] in ("triple", "pair")
            assert ev["to"] == "staged"
            assert ev["reason"] == "vmem_budget"
            assert ev["vmem_bytes_min"] > ev["vmem_budget"] == 20_000
        assert (s.registry.value("plan.fusion_degradations") == len(events))
        # cache hit replays the same events without re-counting
        _, info2 = gemt3_planned(x, c1, c2, c3, with_info=True,
                                 vmem_budget=20_000)
        assert info2["events"] == events
        assert (s.registry.value("plan.fusion_degradations") == len(events))


def test_no_degradation_events_on_roomy_budget():
    x, c1, c2, c3 = _problem(16)
    clear_plan_cache()
    _, info = gemt3_planned(x, c1, c2, c3, with_info=True, fuse=False)
    # forced staging is a user choice, not a degradation
    assert info["events"] == []


# ---------------------------------------------------------------------------
# serve latency histogram
# ---------------------------------------------------------------------------


def test_serve_stats_latency_percentiles():
    from repro.serve.decode import DxtServeSession

    sess = DxtServeSession(kind="dct")
    batch = RNG.random((2, 8, 8, 8)).astype(np.float32)
    with obs.session() as s:
        for _ in range(5):
            sess.transform(batch)
        stats = sess.stats()
        assert s.registry.value("serve.requests") == 5
    assert stats["requests_served"] == 10  # 5 calls x batch 2
    lat = stats["latency_us"]
    assert lat["count"] == 5
    assert lat["min"] > 0
    assert lat["min"] <= lat["p50"] <= lat["p90"] <= lat["p99"] <= lat["max"]
    assert lat["mean"] > 0
    assert stats["hbm_bytes_moved"] > 0


# ---------------------------------------------------------------------------
# autotune cache: atomic writes + corrupt recovery
# ---------------------------------------------------------------------------


def test_autotune_cache_atomic_save_roundtrip(tmp_path):
    path = str(tmp_path / "autotune.json")
    with obs.session() as s:
        cache = AutotuneCache(path)
        # a live-schema key: load() prunes unrecognized (stale-version) keys
        key = "v4:16x16x16|float32|dense|fwd|plain|s"
        cache.put(key, {"bm": 64, "bn": 64, "bk": 64, "us": 1.0})
        cache.save()
        assert s.registry.value("autotune.cache.writes") == 1
        # no temp litter, and the file is complete valid JSON
        assert [f for f in os.listdir(tmp_path)] == ["autotune.json"]
        assert json.loads(open(path).read())[key]["bm"] == 64
        fresh = AutotuneCache(path)
        assert fresh.get(key)["bn"] == 64
        assert s.registry.value("autotune.cache.loads") == 1
        assert s.registry.value("autotune.cache.hits") == 1
        assert fresh.get("absent") is None
        assert s.registry.value("autotune.cache.misses") == 1


def test_autotune_cache_corrupt_recovery(tmp_path):
    path = str(tmp_path / "autotune.json")
    with open(path, "w") as f:
        f.write('{"torn": ')  # torn write
    with obs.session() as s:
        cache = AutotuneCache(path)
        assert len(cache) == 0
        assert s.registry.value("autotune.cache.corrupt_recovered") == 1
        # non-dict JSON counts as corrupt too
        with open(path, "w") as f:
            json.dump([1, 2, 3], f)
        cache.load()
        assert len(cache) == 0
        assert s.registry.value("autotune.cache.corrupt_recovered") == 2
        # recovery is silent for runs: put/save works over the rubble
        key = "v4:8x8x8|float32|dense|fwd|plain|s"
        cache.put(key, {"bm": 8, "bn": 8, "bk": 8})
        cache.save()
        assert AutotuneCache(path).get(key)["bm"] == 8


# ---------------------------------------------------------------------------
# memo counters
# ---------------------------------------------------------------------------


def test_esop_memo_counters_mirror_stats():
    from repro.kernels import ops

    c = jnp.asarray((RNG.random((16, 16)) > 0.5).astype(np.float32))
    with obs.session() as s:
        before = ops.esop_memo_stats()
        ops.esop_plan_cached(c, 8, 8)   # miss
        ops.esop_plan_cached(c, 8, 8)   # hit
        after = ops.esop_memo_stats()
        assert (s.registry.value("memo.esop.misses")
                == after["misses"] - before["misses"] == 1)
        assert (s.registry.value("memo.esop.hits")
                == after["hits"] - before["hits"] == 1)
