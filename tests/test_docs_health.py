"""Tier-2 docs-health checks (marker: ``docs_health``).

Two guards so the guides can't silently rot as the code grows:

* any ``>>>`` doctest examples inside README/docs markdown must execute
  (``--doctest-glob="*.md"`` over the pages in a subprocess — an exit
  status of "no tests collected" is fine, a failing example is not);
* every public symbol exported by ``repro.engine.__all__`` and
  ``repro.core.__all__`` must be mentioned in at least one docs page
  (README, ``docs/architecture.md``, ``docs/engine.md``,
  ``docs/distributed.md``) — new API without documentation fails here.
"""
import glob
import os
import subprocess
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _doc_pages() -> list[str]:
    return [os.path.join(_ROOT, "README.md")] + sorted(
        glob.glob(os.path.join(_ROOT, "docs", "*.md")))


@pytest.mark.docs_health
def test_markdown_doctests_execute():
    """pytest --doctest-glob over README + docs/ runs clean (rc 0 or 5)."""
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "--doctest-glob=*.md",
         "-p", "no:cacheprovider", "--override-ini=addopts=",
         *_doc_pages()],
        capture_output=True, text=True, cwd=_ROOT, timeout=300)
    # 5 == "no tests collected": pages without >>> examples are healthy.
    assert r.returncode in (0, 5), (
        f"markdown doctests failed (rc={r.returncode}):\n"
        f"{r.stdout}\n{r.stderr}")


@pytest.mark.docs_health
def test_public_api_is_documented():
    """Every repro.engine / repro.core __all__ symbol appears in the docs."""
    import repro.core
    import repro.engine

    corpus = "\n".join(open(p, encoding="utf-8").read()
                       for p in _doc_pages())
    missing = [
        f"{mod.__name__}.{sym}"
        for mod in (repro.engine, repro.core)
        for sym in mod.__all__
        if sym not in corpus
    ]
    assert not missing, (
        "public symbols absent from README/docs pages (document them in "
        f"docs/architecture.md or the subsystem page): {missing}")


@pytest.mark.docs_health
def test_doc_pages_exist_and_cover_subpackages():
    """architecture.md exists and names every src/repro subpackage."""
    arch = os.path.join(_ROOT, "docs", "architecture.md")
    assert os.path.exists(arch), "docs/architecture.md is missing"
    text = open(arch, encoding="utf-8").read()
    pkgs = sorted(
        d for d in os.listdir(os.path.join(_ROOT, "src", "repro"))
        if os.path.isdir(os.path.join(_ROOT, "src", "repro", d))
        and not d.startswith("__"))
    missing = [p for p in pkgs if f"{p}/" not in text]
    assert not missing, f"subpackages absent from architecture.md: {missing}"


@pytest.mark.docs_health
def test_serving_page_covers_lifecycle_and_is_cross_linked():
    """docs/serving.md documents the resilient runtime (lifecycle, ladder,
    fault-injection points, counter accounting) and the neighbouring pages
    link to it."""
    page = os.path.join(_ROOT, "docs", "serving.md")
    assert os.path.exists(page), "docs/serving.md is missing"
    text = open(page, encoding="utf-8").read()
    for needed in ("ResilientDxtServer", "CircuitBreaker", "RetryPolicy",
                   "degradation ladder", "einsum", "inject_faults",
                   "FaultSpec", "serve.retry", "serve.degraded",
                   "serve.remesh", "faults.injected", "invalidate_plans",
                   "rebind_mesh", "remesh_plan", "multi_pod", "SaveHandle"):
        assert needed in text, f"serving.md does not mention {needed!r}"
    for other in ("README.md", os.path.join("docs", "architecture.md"),
                  os.path.join("docs", "observability.md")):
        linked = open(os.path.join(_ROOT, other), encoding="utf-8").read()
        assert "serving.md" in linked, f"{other} does not link docs/serving.md"


@pytest.mark.docs_health
def test_serving_page_covers_throughput_and_is_cross_linked():
    """docs/serving.md's Throughput section documents the warmup API,
    coalescing-window semantics, the dispatch pipeline and the counter
    accounting; README and docs/engine.md point at it (the engine page
    owns the ``batch_bucket`` half of the contract)."""
    page = os.path.join(_ROOT, "docs", "serving.md")
    text = open(page, encoding="utf-8").read()
    for needed in ("## Throughput", "warmup", "bucket_batches",
                   "batch_bucket", "max_coalesce", "coalesce_window_s",
                   "pipeline_depth", "donate", "serve.warmup",
                   "serve.coalesced", "serve.batch", "serve.queue_depth",
                   "serve.batch_size", "queued_shed",
                   "BENCH_serve_throughput"):
        assert needed in text, f"serving.md does not mention {needed!r}"
    readme = open(os.path.join(_ROOT, "README.md"), encoding="utf-8").read()
    assert "coalesc" in readme, "README does not mention coalescing"
    engine = open(os.path.join(_ROOT, "docs", "engine.md"),
                  encoding="utf-8").read()
    assert "batch_bucket" in engine, (
        "engine.md does not document batch_bucket")
    assert "serving.md" in engine, "engine.md does not link docs/serving.md"


@pytest.mark.docs_health
def test_numerics_page_covers_guards_and_is_cross_linked():
    """docs/numerics.md documents the guarded-numerics layer (accum modes,
    error model + budget escalation, nonfinite recovery, ckpt/train guards)
    and the neighbouring pages link to it."""
    page = os.path.join(_ROOT, "docs", "numerics.md")
    assert os.path.exists(page), "docs/numerics.md is missing"
    text = open(page, encoding="utf-8").read()
    for needed in ("ACCUM_MODES", "compensated", "Neumaier",
                   "stage_error_bound", "plan_error_bound",
                   "enforce_error_budget", "numerics_degradation",
                   "error_budget", "budget_met", "NonfiniteOutput",
                   "finite_guard", "finite_check_every", "tier_floor",
                   "force_accum", "consume_nan_poison",
                   "numerics.nonfinite.detected", "faults.injected.nan",
                   "skip_nonfinite", "CorruptCheckpoint",
                   "ckpt.restore.corrupt_recovered"):
        assert needed in text, f"numerics.md does not mention {needed!r}"
    for other in ("README.md", os.path.join("docs", "engine.md"),
                  os.path.join("docs", "serving.md")):
        linked = open(os.path.join(_ROOT, other), encoding="utf-8").read()
        assert "numerics.md" in linked, (
            f"{other} does not link docs/numerics.md")
