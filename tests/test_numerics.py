"""Guarded numerics (marker: ``numerics_smoke``) — docs/numerics.md.

Four layers under test:

* the error model itself (``unit_roundoff`` / ``stage_error_bound`` /
  ``plan_error_bound`` / ``enforce_error_budget``) and its integration
  into the planner (``error_budget=`` escalates the accumulation mode,
  the compensated carry scratch demotes fusion depth);
* the kernels: a property sweep under adversarial magnitudes (denormals,
  ±1e±30, signed zeros) asserting compensated accumulation is never less
  accurate than plain against a float64 oracle, plus interpret-mode
  Pallas parity with the reference path;
* nonfinite recovery in serving: a ``nan`` chaos drill where every
  admitted request completes with the fault-free result and
  ``faults.injected.nan == numerics.nonfinite.detected == serve.retry``;
* the train-step skip-nonfinite guard and the checkpoint checksum /
  torn-file fallback.
"""
import json
import os

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro import ckpt as ckpt_lib
from repro import obs
from repro.ckpt import CorruptCheckpoint
from repro.core.transforms import coefficient_matrix
from repro.engine import (ACCUM_MODES, NonfiniteOutput, accum_out_dtype,
                          build_plan, enforce_error_budget, finite_guard,
                          gemt3_planned, normalize_accum, plan_error_bound,
                          plan_gemt3, stage_error_bound, unit_roundoff)
from repro.kernels.ops import esop_gemm, fused_gemt, sr_gemm
from repro.optim import OptConfig
from repro.runtime.faults import FaultSpec, inject_faults
from repro.serve import DxtServeSession, ResilientDxtServer
from repro.train.step import build_dxt_fit_step, init_dxt_fit_state

ATOL = 1e-5


class _Stage:
    def __init__(self, n):
        self.n = n


# ---------------------------------------------------------------------------
# error model


@pytest.mark.numerics_smoke
class TestErrorModel:
    def test_normalize_accum(self):
        assert normalize_accum(None) == "plain"
        for m in ACCUM_MODES:
            assert normalize_accum(m) == m
        with pytest.raises(ValueError):
            normalize_accum("fp64")

    def test_accum_out_dtype(self):
        bf16 = jnp.dtype(jnp.bfloat16)
        assert accum_out_dtype(bf16, "plain") == bf16
        assert accum_out_dtype(bf16, "f32") == jnp.float32
        assert accum_out_dtype(bf16, "compensated") == jnp.float32
        assert accum_out_dtype(jnp.float32, "compensated") == jnp.float32
        # complex (DFT factors) never promotes
        assert accum_out_dtype(jnp.complex64, "f32") == jnp.complex64

    def test_unit_roundoff(self):
        assert unit_roundoff(jnp.float32) == 2.0 ** -24
        assert unit_roundoff(jnp.bfloat16) > unit_roundoff(jnp.float32)
        assert unit_roundoff(jnp.complex64) == unit_roundoff(jnp.float32)
        with pytest.raises(ValueError):
            unit_roundoff(jnp.int32)

    def test_stage_bound_shapes(self):
        """Plain grows linearly with depth; compensated is depth-flat and
        strictly tighter at serving depths."""
        b32 = stage_error_bound(32, jnp.bfloat16, "plain")
        b256 = stage_error_bound(256, jnp.bfloat16, "plain")
        assert b256 > b32
        c32 = stage_error_bound(32, jnp.bfloat16, "compensated")
        c256 = stage_error_bound(256, jnp.bfloat16, "compensated")
        assert c32 == c256  # Neumaier: 2·u_acc, independent of K
        assert c32 < b32
        # f32 keeps the K-term but drops the bf16 downcast term
        f = stage_error_bound(32, jnp.bfloat16, "f32")
        assert c32 < f < b32

    def test_plan_bound_sums_stages(self):
        stages = [_Stage(16), _Stage(32), _Stage(64)]
        total = plan_error_bound(stages, jnp.bfloat16, "f32")
        assert total == pytest.approx(sum(
            stage_error_bound(s.n, jnp.bfloat16, "f32") for s in stages))

    def test_enforce_budget_escalates_with_events(self):
        stages = [_Stage(64)] * 3
        accum, bound, events = enforce_error_budget(
            stages, jnp.bfloat16, "plain", error_budget=1e-6)
        assert accum == "compensated"
        assert bound == plan_error_bound(stages, jnp.bfloat16, "compensated")
        assert [e["accum_to"] for e in events] == ["f32", "compensated"]
        for e in events:
            assert e["kind"] == "numerics_degradation"
            assert e["reason"] == "error_budget"
            assert e["bound_after"] < e["bound_before"]
            assert e["error_budget"] == 1e-6
        assert events[-1]["budget_met"] == (bound <= 1e-6)

    def test_enforce_budget_met_is_quiet(self):
        stages = [_Stage(16)] * 3
        accum, _, events = enforce_error_budget(
            stages, jnp.float32, "plain", error_budget=1.0)
        assert accum == "plain" and events == []

    def test_enforce_budget_complex_never_escalates(self):
        stages = [_Stage(64)] * 3
        accum, _, events = enforce_error_budget(
            stages, jnp.complex64, "plain", error_budget=1e-12)
        assert accum == "plain" and events == []

    def test_finite_guard(self):
        assert finite_guard(jnp.ones((4, 4)))
        assert not finite_guard(jnp.array([1.0, jnp.nan]))
        assert not finite_guard(jnp.array([1.0, jnp.inf]))


# ---------------------------------------------------------------------------
# planner integration


@pytest.mark.numerics_smoke
class TestPlannerNumerics:
    def test_budget_escalates_accum_and_surfaces_info(self):
        n = 16
        c = coefficient_matrix("dct", n).astype(jnp.bfloat16)
        x = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, n, n, n)), jnp.bfloat16)
        with obs.session("numerics-plan", enable_tracing=False) as s:
            from repro.engine import clear_plan_cache
            clear_plan_cache()
            y, info = gemt3_planned(x, c, c, c, error_budget=1e-9,
                                    with_info=True)
            num = info["numerics"]
            assert num["accum"] == "compensated"  # 1e-9 is unmeetable
            assert num["error_budget"] == 1e-9
            assert num["error_bound"] > 0
            assert [e["accum_to"] for e in num["events"]] == [
                "f32", "compensated"]
            assert num["events"][-1]["budget_met"] is False
            # promoted accumulation keeps the result in float32
            assert y.dtype == jnp.float32
            assert s.registry.value("plan.numerics_degradations") == 2

    def test_default_plan_is_untouched(self):
        n = 16
        c = coefficient_matrix("dct", n)
        plan = plan_gemt3((2, n, n, n), jnp.float32, c, c, c)
        assert plan.accum == "plain"
        assert plan.error_budget is None
        assert plan.error_bound > 0  # the bound is always evaluated
        assert not [e for e in plan.events
                    if e.get("kind") == "numerics_degradation"]
        # the memo key is byte-identical to the pre-PR-9 default form
        assert "ac=" not in plan.key and "eb=" not in plan.key
        forced = plan_gemt3((2, n, n, n), jnp.float32, c, c, c,
                            accum="compensated")
        assert "ac=compensated" in forced.key

    def test_compensated_scratch_demotes_fusion_depth(self):
        """The carry tile is real VMEM: near the triple-fusion footprint
        floor there is a budget band where a plain plan still fuses all
        three stages but a compensated one must demote to pair fusion."""
        n = 32
        c = coefficient_matrix("dct", n).astype(jnp.float32)
        shape, dt = (4, n, n, n), jnp.float32
        found = None
        budget = 1 << 24
        while budget > 1 << 12:
            plain = build_plan(shape, dt, c, c, c, fuse=True,
                               vmem_budget=budget, accum="plain")
            comp = build_plan(shape, dt, c, c, c, fuse=True,
                              vmem_budget=budget, accum="compensated")
            if plain.fused3 is not None and comp.fused3 is None:
                found = (plain, comp, budget)
                break
            budget = int(budget / 1.05)
        assert found, "no budget band separates plain/compensated triple"
        plain, comp, budget = found
        # the demotion is accounted as a fusion event, not silently
        assert any(e.get("kind") == "fusion_degradation"
                   for e in comp.events), comp.events

    def test_blown_budget_can_demote_fusion(self):
        """error_budget -> compensated -> bigger footprint -> shallower
        fusion: the numerics walk and the fusion walk compose, each leg
        leaving its own event."""
        n = 32
        c = coefficient_matrix("dct", n).astype(jnp.bfloat16)
        shape, dt = (4, n, n, n), jnp.bfloat16
        budget = 1 << 24
        while budget > 1 << 12:
            plain = build_plan(shape, dt, c, c, c, fuse=True,
                               vmem_budget=budget)
            comp = build_plan(shape, dt, c, c, c, fuse=True,
                              vmem_budget=budget, error_budget=1e-9)
            if plain.fused3 is not None and comp.fused3 is None:
                break
            budget = int(budget / 1.05)
        else:
            pytest.fail("no budget band separates plain/budgeted triple")
        assert comp.accum == "compensated"
        kinds = [e.get("kind") for e in comp.events]
        assert "numerics_degradation" in kinds
        assert "fusion_degradation" in kinds
        ev = next(e for e in comp.events
                  if e.get("kind") == "numerics_degradation")
        assert ev["bound_before"] > ev["bound_after"] > 0
        assert ev["error_budget"] == 1e-9


# ---------------------------------------------------------------------------
# kernels: adversarial property sweep + interpret parity


# Magnitude palette: signed zeros, bf16/f32 denormals, and ±1e±30 —
# products stay ≤ ~1e30 so the fp32 accumulator never overflows.
_SCALES = [0.0, -0.0, 1e-38, -1e-38, 1e-30, 1e30, -1e30, 1e-8, 1.0, -1.0]


def _adversarial(rng, shape, dtype=jnp.bfloat16):
    base = rng.normal(size=shape)
    scale = rng.choice(_SCALES, size=shape)
    return jnp.asarray(base * scale, dtype)


def _err(y, oracle):
    return float(np.max(np.abs(np.asarray(y, np.float64) - oracle)))


@pytest.mark.numerics_smoke
class TestCompensatedKernels:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 1000), st.sampled_from([16, 48, 96]))
    def test_sr_gemm_compensated_no_worse_than_plain(self, seed, k):
        rng = np.random.default_rng(seed)
        x = _adversarial(rng, (24, k))
        c = jnp.asarray(rng.normal(size=(k, 16)) / np.sqrt(k), jnp.bfloat16)
        oracle = np.asarray(x, np.float64) @ np.asarray(c, np.float64)
        e_plain = _err(sr_gemm(x, c), oracle)
        e_comp = _err(sr_gemm(x, c, accum="compensated"), oracle)
        assert e_comp <= e_plain * (1 + 1e-9) + 1e-30

    @settings(max_examples=6, deadline=None)
    @given(st.integers(0, 1000))
    def test_esop_and_fused_compensated_no_worse(self, seed):
        rng = np.random.default_rng(seed)
        # block-sparse coefficient so ESOP actually skips blocks
        c_np = rng.normal(size=(32, 32)) / np.sqrt(32)
        c_np[:16, 16:] = 0.0
        c = jnp.asarray(c_np, jnp.bfloat16)
        x = _adversarial(rng, (24, 32))
        oracle = np.asarray(x, np.float64) @ np.asarray(c, np.float64)
        (yp, _), (yc, _) = (esop_gemm(x, c),
                            esop_gemm(x, c, accum="compensated"))
        assert _err(yc, oracle) <= _err(yp, oracle) * (1 + 1e-9) + 1e-30

        x3 = _adversarial(rng, (8, 32, 32))
        oracle3 = np.einsum("unm,mk,nl->ukl",
                            np.asarray(x3, np.float64),
                            np.asarray(c, np.float64),
                            np.asarray(c, np.float64))
        (yp3, _), (yc3, _) = (fused_gemt(x3, c, c),
                              fused_gemt(x3, c, c, accum="compensated"))
        assert _err(yc3, oracle3) <= _err(yp3, oracle3) * (1 + 1e-9) + 1e-30

    def test_compensated_beats_plain_on_serving_shapes(self):
        """On well-scaled bf16 data (the bench's N1 case) the gain is
        large — the acceptance bar is >= 4x, dominated by skipping the
        bf16 output downcast."""
        rng = np.random.default_rng(7)
        n = 32
        x = jnp.asarray(rng.normal(size=(4, n, n, n)), jnp.bfloat16)
        c = coefficient_matrix("dct", n).astype(jnp.bfloat16)
        oracle = np.einsum("uijk,ia,jb,kc->uabc",
                           *[np.asarray(a, np.float64)
                             for a in (x, c, c, c)], optimize=True)
        e_plain = _err(gemt3_planned(x, c, c, c), oracle)
        e_comp = _err(gemt3_planned(x, c, c, c, accum="compensated"), oracle)
        assert e_comp * 4.0 <= e_plain

    def test_interpret_kernel_matches_reference(self):
        """Pallas interpret-mode kernels agree with the reference path for
        every accumulation mode (the comp-scratch kernels are the code
        under test; off-TPU the default dispatch is the reference)."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(24, 32)), jnp.float32)
        c = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        for accum in ACCUM_MODES:
            y_pal = sr_gemm(x, c, bm=8, bn=8, bk=8, use_pallas=True,
                            accum=accum)
            y_ref = sr_gemm(x, c, use_pallas=False, accum=accum)
            assert y_pal.dtype == y_ref.dtype
            np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                       atol=1e-5, rtol=1e-5)
        x3 = jnp.asarray(rng.normal(size=(4, 16, 16)), jnp.float32)
        c2 = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
        yp, _ = fused_gemt(x3, c2, c2, bu=8, bka=8, bnb=8, bna=8,
                           use_pallas=True, accum="compensated")
        yr, _ = fused_gemt(x3, c2, c2, use_pallas=False, accum="compensated")
        np.testing.assert_allclose(np.asarray(yp), np.asarray(yr),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# serving: the nan chaos drill


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def _server(**kw):
    clock = FakeClock()
    kw.setdefault("breaker_threshold", 1)
    kw.setdefault("breaker_cooldown_s", 60.0)
    return ResilientDxtServer(session=DxtServeSession(), clock=clock,
                              sleep=lambda s: None, **kw), clock


@pytest.mark.numerics_smoke
@pytest.mark.chaos_smoke
class TestNonfiniteRecovery:
    def test_nan_drill_recovers_and_counters_balance(self):
        """Silent NaN corruption on two consecutive attempts: the finite
        guard catches both, recovery pins the ladder floor + forces
        compensated accumulation, and the admitted request completes with
        the fault-free result.  Exact accounting:
        faults.injected.nan == numerics.nonfinite.detected == serve.retry.
        """
        rng = np.random.default_rng(0)
        x = rng.normal(size=(2, 16, 16, 16)).astype(np.float32)
        with obs.session("nan-drill", enable_tracing=False) as s:
            server, _ = _server(finite_check_every=1)
            y0 = server.transform(x)  # fault-free baseline
            with inject_faults(FaultSpec(match="serve.request", kind="nan",
                                         times=2)) as inj:
                req = server.submit(x)
                server.drain()
            assert req.status == "done"
            assert float(jnp.max(jnp.abs(req.result - y0))) <= ATOL
            assert inj.specs[0].injected == 2
            reg = s.registry
            assert reg.value("faults.injected.nan") == 2
            assert reg.value("numerics.nonfinite.detected") == 2
            assert reg.value("serve.retry") == 2
            st_ = server.stats()
            assert st_["failed"] == 0 and st_["shed"] == 0
            assert st_["nonfinite"] == 2
            # recovery state is visible on the request
            recov = [e for e in req.events
                     if e.get("kind") == "numerics_recovery"]
            assert len(recov) == 2
            assert all(e["reason"] == "nonfinite_output" for e in recov)
            assert req.force_accum == "compensated"
            assert req.tier_floor is not None

    def test_finite_guard_is_off_by_default(self):
        """finite_check_every=0 (default): the guard never runs, a
        poisoned result flows through as NaN — detection is opt-in
        because the isfinite reduction is a host sync."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(2, 8, 8, 8)).astype(np.float32)
        with obs.session("nan-off", enable_tracing=False) as s:
            server, _ = _server()
            with inject_faults(FaultSpec(match="serve.request", kind="nan",
                                         times=1)):
                y = server.transform(x)
            assert not bool(jnp.isfinite(y).all())
            assert s.registry.value("numerics.nonfinite.detected") == 0
            assert s.registry.value("serve.retry") == 0

    def test_sampled_guard_checks_every_nth(self):
        """finite_check_every=2 samples: attempt seq 1 (unchecked) lets a
        poisoned result through; the drill still balances when the check
        lands on the poisoned attempt."""
        rng = np.random.default_rng(2)
        x = rng.normal(size=(2, 8, 8, 8)).astype(np.float32)
        with obs.session("nan-sampled", enable_tracing=False) as s:
            server, _ = _server(finite_check_every=2)
            with inject_faults(FaultSpec(match="serve.request", kind="nan",
                                         times=1)):
                y = server.transform(x)  # seq 1: guard skipped
            assert not bool(jnp.isfinite(y).all())
            assert s.registry.value("numerics.nonfinite.detected") == 0
            with inject_faults(FaultSpec(match="serve.request", kind="nan",
                                         times=1)):
                y2 = server.transform(x)  # seq 2: guard fires, recovers
            assert bool(jnp.isfinite(y2).all())
            assert s.registry.value("numerics.nonfinite.detected") == 1


# ---------------------------------------------------------------------------
# train: skip-nonfinite guard


@pytest.mark.numerics_smoke
class TestTrainGuard:
    def _state_and_batch(self, nan_target=False):
        dims = (8, 8, 8)
        state = init_dxt_fit_state(dims, OptConfig(lr=1e-2),
                                   key=jax.random.PRNGKey(0),
                                   init_scale=0.1)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(2, *dims)), jnp.float32)
        y = jnp.asarray(rng.normal(size=(2, *dims)), jnp.float32)
        if nan_target:
            y = y.at[0, 0, 0, 0].set(jnp.nan)
        return state, {"x": x, "y": y}

    def test_nonfinite_update_is_skipped(self):
        state, batch = self._state_and_batch(nan_target=True)
        fit_step = build_dxt_fit_step(OptConfig(lr=1e-2))
        with obs.session("train-guard", enable_tracing=False) as s:
            new_state, metrics = fit_step(state, batch)
            assert float(metrics["skipped_nonfinite"]) == 1.0
            assert s.registry.value("train.nonfinite_skipped") == 1
        for n, o in zip(jax.tree.leaves(new_state["params"]),
                        jax.tree.leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(n), np.asarray(o))

    def test_finite_update_proceeds(self):
        state, batch = self._state_and_batch()
        fit_step = build_dxt_fit_step(OptConfig(lr=1e-2))
        new_state, metrics = fit_step(state, batch)
        assert float(metrics["skipped_nonfinite"]) == 0.0
        changed = any(
            not np.array_equal(np.asarray(n), np.asarray(o))
            for n, o in zip(jax.tree.leaves(new_state["params"]),
                            jax.tree.leaves(state["params"])))
        assert changed

    def test_guard_is_jittable(self):
        state, batch = self._state_and_batch(nan_target=True)
        fit_step = jax.jit(build_dxt_fit_step(OptConfig(lr=1e-2)))
        new_state, metrics = fit_step(state, batch)
        assert float(metrics["skipped_nonfinite"]) == 1.0
        for n, o in zip(jax.tree.leaves(new_state["params"]),
                        jax.tree.leaves(state["params"])):
            np.testing.assert_array_equal(np.asarray(n), np.asarray(o))

    def test_guard_can_be_disabled(self):
        state, batch = self._state_and_batch(nan_target=True)
        fit_step = build_dxt_fit_step(OptConfig(lr=1e-2),
                                      skip_nonfinite=False)
        new_state, metrics = fit_step(state, batch)
        assert "skipped_nonfinite" not in metrics
        assert not bool(jnp.isfinite(
            jax.tree.leaves(new_state["params"])[0]).all())


# ---------------------------------------------------------------------------
# checkpoint integrity


def _truncate_a_leaf(ckpt_dir, step):
    d = os.path.join(str(ckpt_dir), f"step_{step:08d}")
    leaf = next(f for f in sorted(os.listdir(d)) if f.endswith(".npy"))
    path = os.path.join(d, leaf)
    with open(path, "r+b") as f:
        f.truncate(os.path.getsize(path) // 2)
    return path


@pytest.mark.numerics_smoke
class TestCheckpointIntegrity:
    def _save_two(self, tmp_path):
        for s in (1, 2):
            ckpt_lib.save(str(tmp_path), s,
                          {"w": jnp.full((8, 8), float(s)),
                           "b": jnp.full((8,), float(s))})

    def test_truncated_latest_falls_back(self, tmp_path):
        self._save_two(tmp_path)
        _truncate_a_leaf(tmp_path, 2)
        with obs.session("ckpt-torn", enable_tracing=False) as s:
            tree, step = ckpt_lib.restore(str(tmp_path))
            assert step == 1
            np.testing.assert_array_equal(np.asarray(tree["w"]),
                                          np.ones((8, 8)))
            assert s.registry.value("ckpt.restore.corrupt_recovered") == 1

    def test_explicit_step_raises(self, tmp_path):
        self._save_two(tmp_path)
        _truncate_a_leaf(tmp_path, 2)
        with pytest.raises(CorruptCheckpoint):
            ckpt_lib.restore(str(tmp_path), step=2)
        # the older step is still individually restorable
        tree, step = ckpt_lib.restore(str(tmp_path), step=1)
        assert step == 1

    def test_all_corrupt_raises(self, tmp_path):
        self._save_two(tmp_path)
        _truncate_a_leaf(tmp_path, 1)
        _truncate_a_leaf(tmp_path, 2)
        with pytest.raises(CorruptCheckpoint):
            ckpt_lib.restore(str(tmp_path))

    def test_pre_checksum_manifest_loads_unverified(self, tmp_path):
        """Manifests written before the sha256 field restore fine
        (back-compat): verification is skipped, not failed."""
        ckpt_lib.save(str(tmp_path), 3, {"w": jnp.ones((4,))})
        mpath = os.path.join(str(tmp_path), "step_00000003", "manifest.json")
        with open(mpath) as f:
            manifest = json.load(f)
        for meta in manifest["leaves"].values():
            meta.pop("sha256")
        with open(mpath, "w") as f:
            json.dump(manifest, f)
        tree, step = ckpt_lib.restore(str(tmp_path))
        assert step == 3
        np.testing.assert_array_equal(np.asarray(tree["w"]), np.ones((4,)))
