"""Throughput serving: shape-bucketed warmup, coalescing, pipelining.

The ``serve_throughput_smoke``-marked tests pin the throughput-layer
contracts (``docs/serving.md``, "Throughput"):

* a warmed session/server pays **zero** plan builds and autotune probes
  in steady state, for every batch size inside a warmed bucket;
* coalesced results match the serial run element-for-element (atol 1e-5)
  and requests only ever co-batch within their bucket — mixed dims,
  directions, or per-request overrides split the batch;
* failure semantics survive batching: a queued deadline sheds before any
  launch is paid, and an injected fault re-enqueues only the failing
  sub-requests (``faults.injected.* == serve.retry`` still balances).
"""
import numpy as np
import pytest
import jax.numpy as jnp

from repro import obs
from repro.runtime.faults import FaultSpec, inject_faults
from repro.serve import DxtServeSession, ResilientDxtServer

ATOL = 1e-5


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


def _batch(n=8, b=1, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(b, n, n, n)).astype(np.float32)


def _server(clock=None, **kw):
    clock = clock or FakeClock()
    kw.setdefault("max_coalesce", 4)
    kw.setdefault("coalesce_window_s", 60.0)
    kw.setdefault("pipeline_depth", 2)
    return ResilientDxtServer(session=DxtServeSession(), clock=clock,
                              sleep=lambda s: None, **kw), clock


def _span_names(session_ns):
    return [sp.name for sp in session_ns.tracer.spans()]


# ---------------------------------------------------------------------------
# shape-bucketed warmup


@pytest.mark.serve_throughput_smoke
class TestWarmup:
    def test_pow2_buckets(self):
        f = DxtServeSession._pow2_bucket
        assert [f(b) for b in (0, 1, 2, 3, 4, 5, 8, 9)] == \
            [1, 1, 2, 4, 4, 8, 8, 16]

    def test_warmed_session_pays_zero_plan_or_probe_spans(self, tmp_path):
        """Every batch size inside a warmed bucket replans nothing: no
        ``plan`` builds, no ``autotune.*`` probes, steady state is pure
        execution."""
        sess = DxtServeSession(kind="dct", autotune=True,
                               autotune_cache=str(tmp_path / "a.json"))
        with obs.session("warm", enable_tracing=True) as s:
            recs = sess.warmup([(4, 8, 8, 8)])
            assert recs[0]["buckets"] == (1, 2, 4)
            assert sess.bucket_batches
            assert s.registry.value("serve.warmup") == 3
            n_warm = len(_span_names(s))
            for b in (1, 2, 3, 4):  # 3 rides the 4-bucket's plan
                sess.transform(_batch(b=b, seed=b))
            steady = _span_names(s)[n_warm:]
            assert steady.count("serve.request") == 4
            assert not [n for n in steady
                        if n == "plan" or n.startswith("autotune")], steady
        # de-bucketed byte model: a bucketed request still reports its own
        # batch's traffic, not the bucket's
        info_b1 = sess.last_info
        assert info_b1["hbm_bytes_moved"] > 0

    def test_warmup_config_dicts_and_unknown_keys(self):
        sess = DxtServeSession(kind="dct")
        recs = sess.warmup([{"dims": (8, 8, 8), "batch": 2, "fuse": False,
                             "inverse": True}], adjoint=False)
        assert recs[0]["inverse"] is True
        assert recs[0]["buckets"] == (1, 2)
        assert recs[0]["fuse"] is False
        with pytest.raises(ValueError, match="unknown warmup config"):
            sess.warmup([{"dims": (8, 8, 8), "nope": 1}])
        with pytest.raises(ValueError, match="warmup shape"):
            sess.warmup([(8, 8)])

    def test_server_warmup_tiers_validate(self):
        server, _ = _server()
        recs = server.warmup([(2, 8, 8, 8)], adjoint=False,
                             tiers=("auto", "staged"))
        assert len(recs) == 2  # one record per (entry, tier)
        assert server.session.warmed == recs
        with pytest.raises(ValueError, match="unknown tier"):
            server.warmup([(8, 8, 8)], tiers=("hyperspace",))

    def test_bucketed_output_matches_exact_shape_plan(self):
        x = _batch(b=3, seed=5)
        sess = DxtServeSession(kind="dct")
        y_exact = np.asarray(sess.transform(x))
        warm = DxtServeSession(kind="dct")
        warm.warmup([(4, 8, 8, 8)], adjoint=False)
        y_bucketed = np.asarray(warm.transform(x))
        assert y_bucketed.shape == x.shape
        np.testing.assert_allclose(y_bucketed, y_exact, atol=ATOL)


# ---------------------------------------------------------------------------
# request coalescing


@pytest.mark.serve_throughput_smoke
class TestCoalescing:
    def test_same_bucket_coalesces_and_matches_serial(self):
        xs = [_batch(seed=i) for i in range(4)]
        serial = ResilientDxtServer(session=DxtServeSession())
        refs = [np.asarray(serial.transform(x)) for x in xs]
        server, _ = _server()
        server.warmup([(4, 8, 8, 8)], adjoint=False)
        reqs = [server.submit(x) for x in xs]
        server.drain()
        st = server.stats()
        assert st["batches"] == 1 and st["coalesced"] == 4
        for r, ref in zip(reqs, refs):
            assert r.status == "done" and r.coalesced == 4
            assert r.info["coalesced"] == 4
            np.testing.assert_allclose(np.asarray(r.result), ref, atol=ATOL)

    def test_mixed_dims_never_co_batched(self):
        server, _ = _server()
        r8a = server.submit(_batch(n=8, seed=0))
        r4 = server.submit(_batch(n=4, seed=1))
        r8b = server.submit(_batch(n=8, seed=2))
        server.drain()
        assert [r.status for r in (r8a, r4, r8b)] == ["done"] * 3
        # the two 8-cubes coalesce around the 4-cube; it launches alone
        assert r8a.coalesced == 2 and r8b.coalesced == 2
        assert r4.coalesced == 1
        assert np.asarray(r4.result).shape == (1, 4, 4, 4)

    def test_override_splits_the_batch(self):
        """A per-request knob puts the request in its own bucket — it
        never changes how the rest of the batch runs."""
        server, _ = _server()
        plain = [server.submit(_batch(seed=i)) for i in range(2)]
        pinned = server.submit(_batch(seed=9), backend="einsum", fuse=False)
        more = server.submit(_batch(seed=3))
        server.drain()
        assert plain[0].coalesced == 3  # the three un-overridden requests
        assert more.coalesced == 3
        assert pinned.coalesced == 1 and pinned.status == "done"
        assert pinned.info["backends"] == ("einsum",) * 3

    def test_window_bounds_coalescing(self):
        """Only requests submitted within the window of the bucket head
        stack; later arrivals launch separately."""
        server, clock = _server(coalesce_window_s=1.0)
        early = [server.submit(_batch(seed=i)) for i in range(2)]
        clock.t += 5.0
        late = server.submit(_batch(seed=2))
        server.drain()
        assert early[0].coalesced == 2 and early[1].coalesced == 2
        assert late.coalesced == 1
        assert server.stats()["batches"] == 2

    def test_max_coalesce_caps_the_batch(self):
        server, _ = _server(max_coalesce=2)
        reqs = [server.submit(_batch(seed=i)) for i in range(5)]
        server.drain()
        assert server.stats()["batches"] == 3
        assert [r.coalesced for r in reqs] == [2, 2, 2, 2, 1]

    def test_queued_deadline_sheds_before_launch(self):
        """A deadline that expires while the request waits in the queue
        fails it *before* any launch is paid — no batch slot, no engine
        work, no retries."""
        server, clock = _server()
        live = server.submit(_batch(seed=0))
        doomed = server.submit(_batch(seed=1), deadline_s=1.0)
        clock.t += 5.0  # expires in the queue
        done = server.drain()
        assert doomed.status == "failed"
        assert doomed.attempts == 0 and doomed.retries == 0
        assert any(e["kind"] == "queued_shed" for e in doomed.events)
        assert live.status == "done" and live.coalesced == 1
        st = server.stats()
        assert st["deadline_exceeded"] == 1 and st["completed"] == 1
        assert {r.id for r in done} == {live.id, doomed.id}

    def test_malformed_request_fails_alone_without_retries(self):
        server, _ = _server()
        good = server.submit(_batch(seed=0))
        bad = server.submit(np.zeros((8, 8, 8), np.float32))  # 3-D
        server.drain()
        assert good.status == "done"
        assert bad.status == "failed" and bad.retries == 0
        assert isinstance(bad.error, (ValueError, TypeError))
        assert server.stats()["retries"] == 0


# ---------------------------------------------------------------------------
# double-buffered dispatch + fault identity


@pytest.mark.serve_throughput_smoke
class TestPipelinedDispatch:
    def test_pipeline_keeps_two_batches_in_flight(self):
        server, _ = _server(max_coalesce=2, pipeline_depth=2)
        reqs = [server.submit(_batch(seed=i)) for i in range(6)]
        done = server.drain()
        assert len(done) == 6
        assert all(r.status == "done" for r in reqs)
        assert server.stats()["batches"] == 3

    def test_nan_fault_retries_only_failed_sub_requests(self):
        """The chaos contract under coalescing: one injected ``nan``
        poisons one member of one batched launch; exactly that member
        retries (with the nonfinite-recovery pins) while its batchmates
        complete from the same launch — ``faults.injected.nan ==
        serve.retry == numerics.nonfinite.detected``."""
        xs = [_batch(seed=i) for i in range(4)]
        serial = ResilientDxtServer(session=DxtServeSession())
        refs = [np.asarray(serial.transform(x)) for x in xs]
        with obs.session("drill", enable_tracing=True) as s:
            server, _ = _server(finite_check_every=1)
            server.warmup([(4, 8, 8, 8)], adjoint=False)
            with inject_faults(FaultSpec(match="serve.request", kind="nan",
                                         times=1)) as inj:
                reqs = [server.submit(x) for x in xs]
                server.drain()
            injected = sum(sp.injected for sp in inj.specs)
            assert injected == 1
            reg = s.registry
            assert reg.value("serve.retry") == injected
            assert reg.value("numerics.nonfinite.detected") == injected
            st = server.stats()
            assert st["completed"] == 4 and st["failed"] == 0
            # only the poisoned member retried; its recovery pinned the
            # floor + compensated accumulation
            assert [r.retries for r in reqs] == [1, 0, 0, 0]
            assert reqs[0].force_accum == "compensated"
            assert any(e["kind"] == "numerics_recovery"
                       for e in reqs[0].events)
            for r, ref in zip(reqs, refs):
                assert np.isfinite(np.asarray(r.result)).all()
                np.testing.assert_allclose(np.asarray(r.result), ref,
                                           atol=ATOL)

    def test_vmem_pressure_retries_batch_once(self):
        """A launch-time fault (VMEM pressure) is a *batch* failure: one
        retry for the whole launch, budget tightened, then the batch
        replays — still one ``serve.retry`` per injected fault."""
        xs = [_batch(seed=i) for i in range(3)]
        with obs.session("drill", enable_tracing=True) as s:
            server, _ = _server()
            with inject_faults(FaultSpec(match="serve.request",
                                         kind="vmem_pressure",
                                         times=1)) as inj:
                reqs = [server.submit(x) for x in xs]
                server.drain()
            assert sum(sp.injected for sp in inj.specs) == 1
            assert s.registry.value("serve.retry") == 1
            assert all(r.status == "done" for r in reqs)
            assert server.vmem_budget is not None  # tightened
            assert server.stats()["degraded"] == 1

    def test_pipelined_batches_keep_their_own_info(self):
        """With two batches in flight, batch *n*'s requests carry batch
        *n*'s plan info — ``last_info`` is captured at dispatch, not at
        sync time (by then batch *n+1* has already overwritten it)."""
        ref = DxtServeSession()
        ref.transform(_batch(n=8, b=2, seed=0))
        bytes8 = ref.last_info["hbm_bytes_moved"]
        ref.transform(_batch(n=4, b=2, seed=0))
        bytes4 = ref.last_info["hbm_bytes_moved"]
        assert bytes8 != bytes4
        server, _ = _server(max_coalesce=2, pipeline_depth=2)
        r8 = [server.submit(_batch(n=8, seed=i)) for i in range(2)]
        r4 = [server.submit(_batch(n=4, seed=i)) for i in range(2)]
        server.drain()
        assert server.stats()["batches"] == 2
        assert all(r.info["hbm_bytes_moved"] == bytes8 for r in r8)
        assert all(r.info["hbm_bytes_moved"] == bytes4 for r in r4)

    def test_default_knobs_keep_serial_path(self):
        """``max_coalesce=1`` + ``pipeline_depth=1`` is the historical
        strictly-serial drain: no batches, no coalescing counters."""
        server = ResilientDxtServer(session=DxtServeSession())
        reqs = [server.submit(_batch(seed=i)) for i in range(3)]
        server.drain()
        st = server.stats()
        assert st["batches"] == 0 and st["coalesced"] == 0
        assert all(r.status == "done" and r.coalesced == 1 for r in reqs)
        assert all(r.finished_at is not None for r in reqs)


# ---------------------------------------------------------------------------
# donation safety (the caller's buffers and Request.batch survive launches)


def _spying_concat(server, arity, calls):
    """Replace the cached donating concat for ``arity`` with a spy that
    records the identities of the arrays the server hands it."""
    def spy(*parts):
        calls.append([id(p) for p in parts])
        return jnp.concatenate(parts, axis=0)

    server._concat_fns[arity] = spy


@pytest.mark.serve_throughput_smoke
class TestDonationSafety:
    def test_assemble_donates_only_staging_copies(self):
        """A caller-owned ``jax.Array`` must never reach the donating
        concat: it is staged through a device copy first, so the caller's
        array — and the retained ``Request.batch`` every retry path
        replays — survives the launch."""
        server, _ = _server()
        server._donation_enabled = lambda: True
        xs = [jnp.asarray(_batch(seed=i)) for i in range(2)]
        calls = []
        _spying_concat(server, 2, calls)
        y = server._assemble(list(xs))
        assert calls, "donating assembly path was not taken"
        assert not set(calls[0]) & {id(x) for x in xs}
        np.testing.assert_allclose(
            np.asarray(y),
            np.concatenate([np.asarray(x) for x in xs]), atol=0)
        for x in xs:  # caller arrays still live and intact
            assert np.isfinite(np.asarray(x)).all()

    def test_assemble_same_buffer_twice_stages_distinct_copies(self):
        """The same array submitted twice (or warmup's repeated zeros)
        must become two distinct staging buffers — duplicate donation of
        one buffer is a runtime error on TPU/GPU."""
        server, _ = _server()
        server._donation_enabled = lambda: True
        x = jnp.asarray(_batch(seed=0))
        calls = []
        _spying_concat(server, 2, calls)
        y = server._assemble([x, x])
        assert len(set(calls[0])) == 2
        assert np.asarray(y).shape == (2, 8, 8, 8)

    def test_warmup_assembly_never_duplicates_donated_buffers(self):
        """Server warmup pre-compiles the assembly for every bucket with
        bb *distinct* members (each staged to its own copy) — the
        donating concat never sees one buffer twice."""
        server, _ = _server()
        server._donation_enabled = lambda: True
        calls = []
        for arity in (2, 4):
            _spying_concat(server, arity, calls)
        server.warmup([(4, 8, 8, 8)], adjoint=False)
        assert len(calls) == 2  # buckets 2 and 4
        for seen in calls:
            assert len(set(seen)) == len(seen)

    @pytest.mark.filterwarnings(
        "ignore:Some donated buffers were not usable")
    def test_donated_coalesced_drain_leaves_inputs_replayable(self):
        """End-to-end on the donation path: after a coalesced drain the
        original submissions (``Request.batch``) are still live arrays —
        a retry or a chaos replay can re-assemble them."""
        server, _ = _server()
        server._donation_enabled = lambda: True
        xs = [jnp.asarray(_batch(seed=i)) for i in range(4)]
        refs = [np.asarray(x) for x in xs]
        reqs = [server.submit(x) for x in xs]
        server.drain()
        assert all(r.status == "done" for r in reqs)
        for r, ref in zip(reqs, refs):
            np.testing.assert_allclose(np.asarray(r.batch), ref, atol=0)

    def test_zero_window_with_coalescing_warns(self):
        with pytest.warns(RuntimeWarning, match="coalesce_window_s"):
            ResilientDxtServer(session=DxtServeSession(), max_coalesce=2,
                               coalesce_window_s=0.0)
        # the default window is nonzero so max_coalesce>1 alone coalesces
        assert ResilientDxtServer(
            session=DxtServeSession()).coalesce_window_s > 0.0
