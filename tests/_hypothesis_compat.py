"""Optional-``hypothesis`` shim: property tests run either way.

When ``hypothesis`` is installed the real ``given``/``settings``/``st`` are
re-exported unchanged.  On a clean interpreter a deterministic fallback runs
each property test over a small fixed grid of examples (endpoints + midpoint
per strategy, capped cartesian product), so the properties are still
exercised instead of silently skipped.
"""
from __future__ import annotations

import functools
import math

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False

    _MAX_CASES = 12  # cap on the cartesian product of example grids

    class _Strategy:
        def __init__(self, examples):
            self.examples = list(examples)

    class _St:
        """Mirror of the tiny slice of ``hypothesis.strategies`` we use."""

        @staticmethod
        def integers(min_value, max_value):
            mid = (min_value + max_value) // 2
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def floats(min_value, max_value):
            mid = (min_value + max_value) / 2.0
            return _Strategy(dict.fromkeys([min_value, mid, max_value]))

        @staticmethod
        def sampled_from(elements):
            return _Strategy(elements)

    st = _St()

    def settings(**_kwargs):  # noqa: D103 - options are meaningless here
        def deco(fn):
            return fn

        return deco

    def _stride(j, l):
        # Per-strategy stride, coprime with the example count so every
        # example still appears, varying with position j so equal-length
        # strategies don't march in lockstep (a plain diagonal would only
        # ever emit n1 == n2 == n3 shapes).
        s = (j % l) + 1
        while math.gcd(s, l) != 1:
            s += 1
        return s

    def given(*strategies):
        def deco(fn):
            # Decorrelated round-robin sampling: each strategy cycles
            # through *all* of its examples within the case budget, with
            # mixed combinations across strategies.  (A truncated cartesian
            # product would pin the leading strategies to their first
            # example.)
            lens = [len(s.examples) for s in strategies]
            n = min(_MAX_CASES, math.lcm(*lens)) if lens else 1
            grid = [tuple(s.examples[(i * _stride(j, l) + j) % l]
                          for j, (s, l) in enumerate(zip(strategies, lens)))
                    for i in range(n)]

            @functools.wraps(fn)
            def runner(*args, **kwargs):  # `self` passes through *args
                for case in grid:
                    fn(*args, *case, **kwargs)

            # Hide the strategy parameters from pytest's fixture resolution:
            # with __wrapped__ intact pytest would read fn's signature and
            # treat (n1, n2, ...) as missing fixtures.
            del runner.__wrapped__
            return runner

        return deco

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
