"""Pallas kernel validation: shape/dtype sweeps against the jnp oracles
(interpret mode on CPU; compiled on a real TPU)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import esop_gemm, flash_attention, sr_gemm
from repro.kernels.esop_gemm import esop_plan
from repro.kernels.ref import ref_attention, ref_sr_gemm

RNG = np.random.default_rng(3)


def _rand(shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32), dtype=dtype)


class TestSrGemm:
    @pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                       (512, 256, 384), (128, 512, 256)])
    def test_shapes_fp32(self, m, k, n):
        x, c, o = _rand((m, k)), _rand((k, n)), _rand((m, n))
        y = sr_gemm(x, c, o, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref_sr_gemm(x, c, o)),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("bm,bn,bk", [(64, 64, 64), (128, 128, 64)])
    def test_block_shapes(self, bm, bn, bk):
        x, c = _rand((256, 256)), _rand((256, 128))
        y = sr_gemm(x, c, bm=bm, bn=bn, bk=bk, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(x) @ np.asarray(c),
                                   rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        x = _rand((128, 256), jnp.bfloat16)
        c = _rand((256, 128), jnp.bfloat16)
        y = sr_gemm(x, c, use_pallas=True)
        ref = np.asarray(x, np.float32) @ np.asarray(c, np.float32)
        np.testing.assert_allclose(np.asarray(y, np.float32), ref,
                                   rtol=3e-2, atol=3e-1)

    def test_unaligned_padding(self):
        x, c = _rand((100, 200)), _rand((200, 72))
        y = sr_gemm(x, c, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(x) @ np.asarray(c),
                                   rtol=2e-4, atol=2e-4)

    def test_chaining_stages(self):
        """SR-GEMM chaining (paper §5.1): stage output feeds next stage."""
        x = _rand((128, 128))
        c1, c2 = _rand((128, 128)), _rand((128, 128))
        y = sr_gemm(sr_gemm(x, c1, use_pallas=True), c2, use_pallas=True)
        ref = np.asarray(x) @ np.asarray(c1) @ np.asarray(c2)
        np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-2)


class TestEsopGemm:
    def _block_sparse_c(self, k, n, keep=0.5, block=128):
        c = RNG.normal(size=(k, n)).astype(np.float32)
        for i in range(k // block):
            for j in range(n // block):
                if RNG.random() > keep:
                    c[i * block:(i + 1) * block, j * block:(j + 1) * block] = 0
        return c

    def test_skip_correctness_and_savings(self):
        c = self._block_sparse_c(512, 256)
        x = _rand((128, 512))
        y, info = esop_gemm(x, jnp.asarray(c), use_pallas=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ c,
                                   rtol=2e-4, atol=2e-4)
        assert info["blocks_live"] < info["blocks_dense"]
        assert 0.0 < info["fetch_savings"] < 1.0

    def test_fully_dense_no_savings(self):
        x, c = _rand((128, 256)), _rand((256, 128))
        y, info = esop_gemm(x, c, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(x) @ np.asarray(c),
                                   rtol=2e-4, atol=2e-4)
        assert info["fetch_savings"] == 0.0

    def test_all_zero_column_block(self):
        c = np.zeros((256, 256), np.float32)
        c[:, 128:] = RNG.normal(size=(256, 128))
        x = _rand((128, 256))
        y, info = esop_gemm(x, jnp.asarray(c), use_pallas=True)
        np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ c,
                                   rtol=2e-4, atol=2e-4)

    def test_plan(self):
        c = jnp.zeros((256, 256)).at[0, 0].set(1.0).at[200, 200].set(1.0)
        counts, idx, t = esop_plan(c, 128, 128)
        assert list(counts) == [1, 1]
        assert t == 1
        assert idx[0, 0] == 0 and idx[1, 0] == 1


class TestFlashAttention:
    @pytest.mark.parametrize("causal", [True, False])
    @pytest.mark.parametrize("s,d", [(256, 64), (128, 128)])
    def test_vs_ref(self, causal, s, d):
        q, k, v = (_rand((2, 4, s, d)) for _ in range(3))
        y = flash_attention(q, k, v, causal=causal, bq=64, bkv=64,
                            use_pallas=True)
        ref = ref_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=3e-4, atol=3e-4)

    def test_blockwise_jnp_path_matches_ref(self):
        from repro.models.common import blockwise_attention
        b, s, h, kvh, d = 2, 128, 8, 2, 32
        q = _rand((b, s, h, d))
        k = _rand((b, s, kvh, d))
        v = _rand((b, s, kvh, d))
        y = blockwise_attention(q, k, v, causal=True, q_chunk=32, kv_chunk=64)
        # GQA oracle: repeat kv heads
        g = h // kvh
        kk = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)
        vv = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
        qq = q.transpose(0, 2, 1, 3)
        ref = ref_attention(qq, kk, vv, causal=True).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_sliding_window(self):
        from repro.models.common import blockwise_attention
        b, s, h, d, w = 1, 128, 2, 16, 32
        q, k, v = (_rand((b, s, h, d)) for _ in range(3))
        y = blockwise_attention(q, k, v, causal=True, window=w,
                                q_chunk=32, kv_chunk=32)
        # oracle with explicit window mask
        logits = np.einsum("bshd,bthd->bhst", np.asarray(q),
                           np.asarray(k)) / np.sqrt(d)
        i = np.arange(s)
        mask = (i[:, None] >= i[None, :]) & (i[:, None] - i[None, :] < w)
        logits = np.where(mask, logits, -1e30)
        p = np.exp(logits - logits.max(-1, keepdims=True))
        p /= p.sum(-1, keepdims=True)
        ref = np.einsum("bhst,bthd->bshd", p, np.asarray(v))
        np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)
