"""Whole-transform megakernel: kernel vs the gemt3 oracle across dtypes,
odd shapes, batching and block sparsity on all three coefficient streams;
plan-level triple → pair → staged degradation boundaries; the budget-keyed
fused autotune caches; serve integration."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import coefficient_matrix, dxt3d, gemt3
from repro.engine import (AutotuneCache, autotune_fused3, build_plan,
                          fused3_tile_sizes, fused3_vmem_bytes,
                          fused_vmem_bytes, gemt3_planned, make_fused3_key,
                          make_fused_key)
from repro.kernels import ops

RNG = np.random.default_rng(23)


def _rand(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32), dtype=dtype)


def _problem(dims, ranks, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=dims).astype(np.float32), dtype=dtype)
    cs = tuple(jnp.asarray(rng.normal(size=(n, k)).astype(np.float32),
                           dtype=dtype)
               for n, k in zip(dims[-3:], ranks))
    return x, cs


def _block_sparse(n, k, keep, block):
    dense = RNG.normal(size=(n, k)).astype(np.float32)
    return jnp.asarray(np.kron(keep, np.ones((block, block))) * dense)


def _ref4(x4, ca, cb, cc):
    return jnp.einsum("ucba,ak,bl,cm->uklm", x4, ca, cb, cc)


class TestFused3Op:
    """ops.fused3_gemt directly: reference path and interpret-mode Pallas."""

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_square_matches_einsum(self, use_pallas):
        x4 = _rand(8, 16, 16, 16)
        ca, cb, cc = _rand(16, 16), _rand(16, 16), _rand(16, 16)
        y, info = ops.fused3_gemt(x4, ca, cb, cc, bu=8, bka=8, bnb=8, bnc=8,
                                  bna=8, use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(_ref4(x4, ca, cb, cc)),
                                   rtol=2e-4, atol=2e-4)
        assert info["fetch_savings"] == 0.0  # dense: nothing skipped
        assert info["t_steps"] == (2, 2, 2)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_odd_shapes_padded(self, use_pallas):
        """Non-multiple-of-block extents on every axis."""
        x4 = _rand(5, 13, 11, 9)
        ca, cb, cc = _rand(9, 10), _rand(11, 7), _rand(13, 12)
        y, _ = ops.fused3_gemt(x4, ca, cb, cc, bu=8, bka=8, bnb=8, bnc=8,
                               bna=8, use_pallas=use_pallas)
        assert y.shape == (5, 10, 7, 12)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(_ref4(x4, ca, cb, cc)),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_bf16(self, use_pallas):
        x4 = _rand(8, 16, 16, 16, dtype=jnp.bfloat16)
        cs = [_rand(16, 16, dtype=jnp.bfloat16) for _ in range(3)]
        y, _ = ops.fused3_gemt(x4, *cs, bu=8, bka=16, bnb=16, bnc=16,
                               bna=16, use_pallas=use_pallas)
        ref = _ref4(*(t.astype(jnp.float32) for t in (x4, *cs)))
        # three chained bf16 roundings over a 16^3 contraction: scale the
        # tolerance to the result's magnitude
        scale = float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ref), rtol=5e-2,
                                   atol=5e-2 * scale)

    def test_complex_routes_to_reference(self):
        """DFT coefficients: the real-valued kernel is bypassed either way."""
        x4 = _rand(4, 16, 16, 16).astype(jnp.complex64)
        c = coefficient_matrix("dft", 16)
        y, _ = ops.fused3_gemt(x4, c, c, c, bu=8, bka=8, bnb=8, bnc=8,
                               bna=8, use_pallas=True)  # forced: still ref
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(_ref4(x4, c, c, c)),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_sparse_all_three_streams_skip(self, use_pallas):
        """Zero blocks of C_a and zero slabs of C_b / C_c are skipped, and
        skipping is exact: the sparse result bit-matches the dense product
        of the same matrices (adding 0 is exact in IEEE arithmetic)."""
        keep_a = np.array([[1, 0], [0, 1]]).astype(bool)
        ca = _block_sparse(32, 32, keep_a, 16)
        cb0 = np.zeros((32, 16), np.float32)
        cb0[:16] = RNG.normal(size=(16, 16))  # upper slab live, lower zero
        cc0 = np.zeros((32, 16), np.float32)
        cc0[16:] = RNG.normal(size=(16, 16))  # lower slab live, upper zero
        cb, cc = jnp.asarray(cb0), jnp.asarray(cc0)
        x4 = _rand(8, 32, 32, 32)
        y, info = ops.fused3_gemt(x4, ca, cb, cc, bu=8, bka=16, bnb=16,
                                  bnc=16, bna=16, use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(_ref4(x4, ca, cb, cc)),
                                   rtol=2e-4, atol=2e-4)
        assert info["blocks_live_a"] == 2 and info["blocks_dense_a"] == 4
        assert info["slabs_live_b"] == 1 and info["slabs_dense_b"] == 2
        assert info["slabs_live_c"] == 1 and info["slabs_dense_c"] == 2
        assert info["fetch_savings"] == pytest.approx(1 - 2 / 16)

    def test_pallas_matches_reference_accounting_and_values(self):
        """Accounting is backend-independent (bit-identical info dicts both
        paths), and the interpret-mode kernel agrees with kernels/ref.py to
        f32 reduction-order resolution over the 32³ contraction."""
        ca = _block_sparse(32, 32, np.array([[1, 0], [1, 1]]).astype(bool),
                           16)
        cb, cc = _rand(32, 16), _rand(32, 16)
        x4 = _rand(8, 32, 32, 32)
        y_ref, i_ref = ops.fused3_gemt(x4, ca, cb, cc, bu=8, bka=16, bnb=16,
                                       bnc=16, bna=16, use_pallas=False)
        y_pal, i_pal = ops.fused3_gemt(x4, ca, cb, cc, bu=8, bka=16, bnb=16,
                                       bnc=16, bna=16, use_pallas=True)
        assert i_ref == i_pal
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_shape_mismatch_raises(self):
        x4 = _rand(4, 8, 8, 8)
        with pytest.raises(ValueError, match="incompatible"):
            ops.fused3_gemt(x4, _rand(9, 8), _rand(8, 8), _rand(8, 8))


class TestFused3Engine:
    """gemt3_planned with triple fusion vs the einsum oracle."""

    @pytest.mark.parametrize("dims,ranks", [
        ((16, 16, 16), (16, 16, 16)),   # cube
        ((24, 20, 16), (8, 10, 12)),    # rectangular compressive
        ((13, 17, 9), (9, 10, 11)),     # odd non-multiple-of-block
    ])
    def test_forced_triple_matches_oracle(self, dims, ranks):
        x, cs = _problem(dims, ranks, seed=1)
        y, info = gemt3_planned(x, *cs, fuse="triple", with_info=True)
        assert info["fused"] is not None
        assert len(info["fused"]["modes"]) == 3
        assert info["backends_executed"] == (
            "fused" + str(info["fused"]["modes"]),)
        np.testing.assert_allclose(np.asarray(y), np.asarray(gemt3(x, *cs)),
                                   rtol=1e-4, atol=1e-4)

    def test_batched_matches_vmap(self):
        x, cs = _problem((4, 16, 12, 16), (8, 10, 12), seed=2)
        y, info = gemt3_planned(x, *cs, fuse="triple", with_info=True)
        assert info["fused"] is not None
        ref = jax.vmap(lambda t: gemt3(t, *cs))(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_engine(self):
        x, cs = _problem((8, 16, 16, 16), (16, 16, 16), seed=3,
                         dtype=jnp.bfloat16)
        y = gemt3_planned(x, *cs, fuse="triple")
        ref = jax.vmap(lambda t: gemt3(t, *(c.astype(jnp.float32)
                                            for c in cs)))(
            x.astype(jnp.float32))
        scale = float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ref),
                                   rtol=5e-2, atol=5e-2 * scale)

    def test_complex_declines_but_matches(self):
        """DFT: triple fusion declines (kernel is real-valued), result
        unchanged."""
        x = _rand(16, 16, 16)
        y, info = dxt3d(x, "dft", engine=True, fuse=True, with_info=True)
        assert info["fused"] is None
        np.testing.assert_allclose(np.asarray(y), np.asarray(dxt3d(x, "dft")),
                                   rtol=1e-4, atol=1e-4)

    def test_sparse_esop_triple_fusion(self):
        """Block-sparse coefficients compose with triple fusion: the ESOP
        schedule skips dead work on whichever stream the planner assigns
        the sparse matrix to, and skipping is exact (zero blocks contribute
        exactly zero, so the fused result matches the staged dense one)."""
        # half of C3's 16-row slabs are entirely zero, so slab-level
        # skipping engages even if C3 lands on the b/c slab streams
        keep = np.array([[1, 0, 0, 1], [0, 0, 0, 0],
                         [0, 0, 0, 0], [1, 0, 0, 1]]).astype(bool)
        c3 = _block_sparse(64, 64, keep, 16)
        c1, c2 = _rand(16, 16), _rand(16, 16)
        x = _rand(8, 16, 16, 64)
        # 16-wide stage blocks so the zero pattern is visible to the planner
        # (the default pow2 clamp would grid this C as one 64x64 block)
        y, info = gemt3_planned(x, c1, c2, c3, fuse="triple",
                                block_sizes=(8, 16, 16), with_info=True)
        f = info["fused"]
        assert f is not None and len(f["modes"]) == 3
        assert info["fetch_savings"] > 0  # dead blocks/slabs never fetched
        assert f["blocks_live"] < f["blocks_dense"]
        y_dense = gemt3_planned(x, c1, c2, c3, fuse=False)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_dense),
                                   rtol=5e-3, atol=5e-4)
        # and the interpret-mode Pallas kernel agrees with the reference path
        y_pal = gemt3_planned(x, c1, c2, c3, fuse="triple",
                              block_sizes=(8, 16, 16), use_pallas=True)
        np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y),
                                   rtol=2e-4, atol=2e-4)

    def test_sparse_compressive_prefers_skipping_stream(self):
        """A strongly block-sparse compressive matrix ends up on a stream
        where its zero blocks are modeled as skipped (nonzero
        zero_block_frac on its assigned slot)."""
        keep = np.array([[1], [0], [0], [1]]).astype(bool)  # 50% zero slabs
        c3 = _block_sparse(256, 64, keep, 64)
        c1, c2 = _rand(64, 64), _rand(48, 48)
        plan = build_plan((8, 64, 48, 256), jnp.float32, c1, c2, c3,
                          fuse="triple", block_sizes=(128, 64, 64))
        assert plan.fused3 is not None
        ft = plan.fused3
        slot = {ft.mode_a: ft.zero_block_frac_a,
                ft.mode_b: ft.zero_block_frac_b,
                ft.mode_c: ft.zero_block_frac_c}
        assert slot[3] == pytest.approx(0.5)  # C3's zeros stay skippable

    def test_affine_out_applies_after_fusion(self):
        x, cs = _problem((8, 16, 12, 16), (8, 10, 12), seed=4)
        out = _rand(8, 8, 10, 12)
        y = gemt3_planned(x, *cs, out=out, fuse="triple")
        ref = jax.vmap(lambda t, o: gemt3(t, *cs, out=o))(x, out)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_interpret_pallas_through_engine(self):
        x, cs = _problem((8, 16, 16, 16), (16, 16, 16), seed=5)
        y, info = gemt3_planned(x, *cs, fuse="triple", use_pallas=True,
                                with_info=True)
        assert info["fused"] is not None
        ref = jax.vmap(lambda t: gemt3(t, *cs))(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


class TestTripleDecision:
    """Plan-level: triple → pair → staged degradation on the modeled
    boundaries."""

    def _serving(self, batch=8, n=32):
        c = coefficient_matrix("dct", n)
        return (batch, n, n, n), (c, c, c)

    def test_auto_prefers_triple_on_serving_shape(self):
        shape, cs = self._serving()
        plan = build_plan(shape, jnp.float32, *cs)
        assert plan.fused3 is not None and plan.fused is None
        pair = build_plan(shape, jnp.float32, *cs, fuse="pair")
        assert plan.hbm_bytes_moved < pair.hbm_bytes_moved
        assert plan.hbm_bytes_moved < plan.hbm_bytes_staged
        assert plan.fused3.hbm_savings > 2.5

    def test_degradation_triple_pair_staged(self):
        """Shrinking the VMEM budget walks the schedule down the ladder:
        triple at the default budget, pair when the triple's accumulator
        no longer fits, staged when nothing does."""
        shape, cs = self._serving()
        full = build_plan(shape, jnp.float32, *cs)
        assert full.fused3 is not None  # triple fits the default budget
        # below the triple's minimal footprint but above the pair's
        t_floor = fused3_vmem_bytes(8, 8, 8, 8, 8, full.fused3.kbp,
                                    full.fused3.kcp, 4)
        mid = build_plan(shape, jnp.float32, *cs, fuse=True,
                         vmem_budget=t_floor - 1)
        assert mid.fused3 is None and mid.fused is not None
        # below the pair's minimal footprint: fully staged
        p_floor = fused_vmem_bytes(8, 8, 8, 8, mid.fused.kbp, 4)
        low = build_plan(shape, jnp.float32, *cs, fuse=True,
                         vmem_budget=min(t_floor, p_floor) - 1)
        assert low.fused3 is None and low.fused is None
        # the modeled bytes are monotone along the ladder
        assert (full.hbm_bytes_moved < mid.hbm_bytes_moved
                <= low.hbm_bytes_moved == low.hbm_bytes_staged)

    def test_auto_degrades_to_pair_when_triple_models_more_bytes(self):
        """A budget-starved triple (bka shrunk → X re-streamed many times)
        loses to the pair on the byte model even though it still *fits* —
        auto mode must pick the pair then."""
        shape, cs = self._serving(batch=4, n=64)
        t_budget = None
        for shift in range(18, 24):  # find a budget where triple fits ...
            budget = 1 << shift
            p = build_plan(shape, jnp.float32, *cs, fuse="triple",
                           vmem_budget=budget)
            if p.fused3 is None:
                continue
            auto = build_plan(shape, jnp.float32, *cs, vmem_budget=budget)
            pair = build_plan(shape, jnp.float32, *cs, fuse="pair",
                              vmem_budget=budget)
            if (pair.fused is not None
                    and pair.hbm_bytes_moved < p.hbm_bytes_moved):
                # ... but models more bytes than the pair: auto takes pair
                assert auto.fused3 is None and auto.fused is not None
                t_budget = budget
                break
        assert t_budget is not None, "no boundary budget found"

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtype_sweep_boundaries(self, dtype):
        """The degradation ladder exists for every kernel dtype; complex64
        never fuses at any budget."""
        shape, cs = self._serving(batch=8, n=16)
        cs = tuple(c.astype(dtype) for c in cs)
        assert build_plan(shape, dtype, *cs,
                          fuse="triple").fused3 is not None
        assert build_plan(shape, dtype, *cs, fuse="triple",
                          vmem_budget=1024).fused3 is None

    def test_complex64_never_fuses(self):
        c = coefficient_matrix("dft", 16)
        for budget in (1 << 20, 1 << 30):
            p = build_plan((8, 16, 16, 16), jnp.complex64, c, c, c,
                           fuse=True, vmem_budget=budget)
            assert p.fused3 is None and p.fused is None

    def test_fuse_false_and_pair_pin_depth(self):
        shape, cs = self._serving()
        assert build_plan(shape, jnp.float32, *cs, fuse=False).fused3 is None
        p = build_plan(shape, jnp.float32, *cs, fuse="pair")
        assert p.fused3 is None and p.fused is not None
        with pytest.raises(ValueError, match="fuse must be one of"):
            build_plan(shape, jnp.float32, *cs, fuse="both")

    def test_key_distinguishes_fuse_modes(self):
        shape, cs = self._serving()
        keys = {build_plan(shape, jnp.float32, *cs, fuse=f).key
                for f in (None, False, "pair", "triple")}
        assert len(keys) == 4

    def test_vmem_model_boundary_is_exact(self):
        """Triple fusion flips exactly where the modeled footprint crosses."""
        shape, cs = self._serving()
        ft = build_plan(shape, jnp.float32, *cs, fuse="triple").fused3
        assert build_plan(shape, jnp.float32, *cs, fuse="triple",
                          vmem_budget=ft.vmem_bytes).fused3 is not None
        floor = fused3_vmem_bytes(8, 8, 8, 8, 8, ft.kbp, ft.kcp, 4)
        assert build_plan(shape, jnp.float32, *cs, fuse="triple",
                          vmem_budget=floor - 1).fused3 is None

    def test_fused3_tile_sizes_fit_budget(self):
        for budget in (1 << 19, 1 << 21, 1 << 23):
            tiles = fused3_tile_sizes(8, 64, 64, 64, 64, 64, 64, 4, budget)
            if tiles is not None:
                assert fused3_vmem_bytes(*tiles, 4) <= budget

    def test_unbatched_u_padding_is_modeled(self):
        """batch=1 pads U 1→8 in the kernel; the byte model carries the ×8
        and forcing still computes correctly."""
        x, cs = _problem((16, 16, 16), (16, 16, 16), seed=7)
        y, info = gemt3_planned(x, *cs, fuse="triple", with_info=True)
        assert info["fused"] is not None
        np.testing.assert_allclose(np.asarray(y), np.asarray(gemt3(x, *cs)),
                                   rtol=1e-4, atol=1e-4)


class TestFused3Autotune:
    def test_autotune_fused3_caches_and_matches(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "a.json"))
        x, cs = _problem((8, 16, 16, 16), (16, 16, 16), seed=8)
        y = gemt3_planned(x, *cs, fuse="triple", autotune=True,
                          autotune_cache=cache)
        ref = jax.vmap(lambda t: gemt3(t, *cs))(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        assert any(k.startswith("fused3:") for k in cache._entries)

    def test_autotune_fused3_respects_vmem_budget(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "a.json"))
        ca, cb, cc = _rand(32, 32), _rand(32, 32), _rand(32, 32)
        budget = fused3_vmem_bytes(8, 16, 16, 16, 16, 32, 32, 4)
        bu, bka, bnb, bnc = autotune_fused3(
            ca, cb, cc, rows=16, dtype=jnp.float32, start=(8, 16, 16, 16),
            bna=16, kbp=32, kcp=32, cache=cache, use_pallas=True,
            max_steps=1, reps=1, vmem_budget=budget)
        assert fused3_vmem_bytes(bu, bka, bnb, bnc, 16, 32, 32, 4) <= budget

    def test_budget_is_part_of_the_cache_key(self):
        """Regression (PR 4 satellite): the plan cache keyed ``vb=`` but the
        autotune cache did not, so tiles tuned under a roomy budget could
        replay under a stricter one and exceed it."""
        a = make_fused_key(64, 32, 32, 32, 32, jnp.float32, "s",
                           vmem_budget=1 << 23)
        b = make_fused_key(64, 32, 32, 32, 32, jnp.float32, "s",
                           vmem_budget=1 << 20)
        # v1 (unbudgeted), v2 (pre-differentiable timings), v3
        # (pre-adjoint-role tile sharing) and v4 (pre-accum-mode) orphaned
        assert a != b and a.startswith("fused:v5:")
        a3 = make_fused3_key(8, 32, 32, 32, 32, 32, 32, jnp.float32, "s",
                             vmem_budget=1 << 23)
        b3 = make_fused3_key(8, 32, 32, 32, 32, 32, 32, jnp.float32, "s",
                             vmem_budget=1 << 20)
        assert a3 != b3 and a3.startswith("fused3:")

    def test_distinct_budgets_tune_distinct_entries(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "a.json"))
        ca, cb, cc = _rand(32, 32), _rand(32, 32), _rand(32, 32)
        kw = dict(rows=16, dtype=jnp.float32, start=(8, 16, 16, 16),
                  bna=16, kbp=32, kcp=32, cache=cache)
        autotune_fused3(ca, cb, cc, vmem_budget=1 << 23, **kw)
        autotune_fused3(ca, cb, cc, vmem_budget=1 << 22, **kw)
        assert len(cache._entries) == 2


class TestFused3Serve:
    def test_serve_session_reports_triple(self):
        from repro.serve import DxtServeSession
        sess = DxtServeSession(kind="dct")
        b = _rand(4, 16, 16, 16)
        y = sess.transform(b)
        ref = jax.vmap(lambda t: dxt3d(t, "dct"))(b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        assert sess.last_info["fused"] is not None
        assert sess.fused_served == 4 and sess.fused3_served == 4
        assert 0 < sess.hbm_bytes_moved < sess.hbm_bytes_staged
        # pinning the pair keeps the old behaviour reachable
        sess_pair = DxtServeSession(kind="dct", fuse="pair")
        sess_pair.transform(b)
        assert sess_pair.fused_served == 4 and sess_pair.fused3_served == 0
