"""Fused two-stage GEMT: kernel vs the gemt3 oracle across dtypes, odd
shapes, batching and block sparsity; plan-level fusion trigger/decline
boundaries; fused autotune; tier-2 bench smoke."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import coefficient_matrix, dxt3d, gemt3
from repro.engine import (AutotuneCache, autotune_fused, build_plan,
                          fused_tile_sizes, fused_vmem_bytes, gemt3_planned)
from repro.kernels import ops

RNG = np.random.default_rng(17)


def _rand(*shape, dtype=np.float32):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32), dtype=dtype)


def _problem(dims, ranks, seed=0, dtype=np.float32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=dims).astype(np.float32), dtype=dtype)
    cs = tuple(jnp.asarray(rng.normal(size=(n, k)).astype(np.float32),
                           dtype=dtype)
               for n, k in zip(dims[-3:], ranks))
    return x, cs


def _block_sparse(n, k, keep, block):
    """Coefficient matrix with the given boolean block-keep pattern."""
    dense = RNG.normal(size=(n, k)).astype(np.float32)
    return jnp.asarray(np.kron(keep, np.ones((block, block))) * dense)


class TestFusedOp:
    """ops.fused_gemt directly: reference path and interpret-mode Pallas."""

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_square_matches_einsum(self, use_pallas):
        x3, ca, cb = _rand(24, 32, 32), _rand(32, 32), _rand(32, 32)
        y, info = ops.fused_gemt(x3, ca, cb, bu=8, bka=16, bnb=16, bna=16,
                                 use_pallas=use_pallas)
        ref = jnp.einsum("uba,ak,bl->ukl", x3, ca, cb)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        assert info["fetch_savings"] == 0.0  # dense: nothing skipped

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_odd_shapes_padded(self, use_pallas):
        """Non-multiple-of-block extents everywhere."""
        x3, ca, cb = _rand(13, 17, 9), _rand(9, 11), _rand(17, 10)
        y, _ = ops.fused_gemt(x3, ca, cb, bu=8, bka=8, bnb=8, bna=8,
                              use_pallas=use_pallas)
        ref = jnp.einsum("uba,ak,bl->ukl", x3, ca, cb)
        assert y.shape == (13, 11, 10)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_bf16(self, use_pallas):
        x3 = _rand(16, 32, 32, dtype=jnp.bfloat16)
        ca = _rand(32, 16, dtype=jnp.bfloat16)
        cb = _rand(32, 16, dtype=jnp.bfloat16)
        y, _ = ops.fused_gemt(x3, ca, cb, bu=16, bka=16, bnb=16, bna=16,
                              use_pallas=use_pallas)
        ref = jnp.einsum("uba,ak,bl->ukl", x3.astype(jnp.float32),
                         ca.astype(jnp.float32), cb.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ref), rtol=5e-2, atol=5e-1)

    def test_complex_routes_to_reference(self):
        """DFT coefficients: the real-valued kernel is bypassed either way."""
        x3 = _rand(8, 16, 16).astype(jnp.complex64)
        ca = coefficient_matrix("dft", 16)
        cb = coefficient_matrix("dft", 16)
        y, _ = ops.fused_gemt(x3, ca, cb, bu=8, bka=8, bnb=8, bna=8,
                              use_pallas=True)  # forced: still reference
        ref = jnp.einsum("uba,ak,bl->ukl", x3, ca, cb)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_sparse_both_streams_skip(self, use_pallas):
        """Zero blocks of C_a and zero slabs of C_b are skipped exactly."""
        keep_a = np.array([[1, 0], [0, 1]]).astype(bool)
        ca = _block_sparse(32, 32, keep_a, 16)
        cb0 = np.zeros((32, 16), np.float32)
        cb0[:16] = RNG.normal(size=(16, 16))  # lower slab entirely zero
        cb = jnp.asarray(cb0)
        x3 = _rand(16, 32, 32)
        y, info = ops.fused_gemt(x3, ca, cb, bu=16, bka=16, bnb=16, bna=16,
                                 use_pallas=use_pallas)
        ref = jnp.einsum("uba,ak,bl->ukl", x3, ca, cb)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        assert info["blocks_live_a"] == 2 and info["blocks_dense_a"] == 4
        assert info["slabs_live_b"] == 1 and info["slabs_dense_b"] == 2
        assert info["fetch_savings"] == pytest.approx(0.75)

    def test_pallas_info_matches_reference_info(self):
        """Accounting is backend-independent (same dict both paths)."""
        ca = _block_sparse(32, 32, np.array([[1, 0], [1, 1]]).astype(bool), 16)
        cb = _rand(32, 16)
        x3 = _rand(16, 32, 32)
        _, i_ref = ops.fused_gemt(x3, ca, cb, bu=16, bka=16, bnb=16, bna=16,
                                  use_pallas=False)
        _, i_pal = ops.fused_gemt(x3, ca, cb, bu=16, bka=16, bnb=16, bna=16,
                                  use_pallas=True)
        assert i_ref == i_pal


class TestFusedEngine:
    """gemt3_planned with fusion vs the einsum oracle."""

    @pytest.mark.parametrize("dims,ranks", [
        ((16, 16, 16), (16, 16, 16)),   # cube
        ((24, 20, 16), (8, 10, 12)),    # rectangular compressive
        ((13, 17, 9), (9, 10, 11)),     # odd non-multiple-of-block
    ])
    def test_forced_fusion_matches_oracle(self, dims, ranks):
        x, cs = _problem(dims, ranks, seed=1)
        y, info = gemt3_planned(x, *cs, fuse=True, with_info=True)
        assert info["fused"] is not None
        np.testing.assert_allclose(np.asarray(y), np.asarray(gemt3(x, *cs)),
                                   rtol=1e-4, atol=1e-4)

    def test_batched_matches_vmap(self):
        x, cs = _problem((4, 16, 12, 16), (8, 10, 12), seed=2)
        y, info = gemt3_planned(x, *cs, fuse=True, with_info=True)
        assert info["fused"] is not None
        ref = jax.vmap(lambda t: gemt3(t, *cs))(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_bf16_engine(self):
        x, cs = _problem((16, 16, 16), (16, 16, 16), seed=3,
                         dtype=jnp.bfloat16)
        y = gemt3_planned(x, *cs, fuse=True)
        # f32 oracle: the fused path accumulates both stages in f32, the
        # bf16 einsum chain rounds between stages — compare to the truth,
        # scaled to the chained-bf16 rounding error
        ref = gemt3(x.astype(jnp.float32),
                    *(c.astype(jnp.float32) for c in cs))
        scale = float(jnp.max(jnp.abs(ref)))
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ref),
                                   rtol=5e-2, atol=5e-2 * scale)

    def test_complex_declines_but_matches(self):
        """DFT: fusion declines (kernel is real-valued), result unchanged."""
        x = _rand(16, 16, 16)
        y, info = dxt3d(x, "dft", engine=True, fuse=True, with_info=True)
        assert info["fused"] is None
        ref = dxt3d(x, "dft")
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)

    def test_sparse_fused_engine(self):
        """Block-sparse C composes with fusion (ESOP on the a-stream)."""
        keep = np.array([[1, 0, 0, 1]] * 4).astype(bool)
        c3 = _block_sparse(128, 128, keep, 32)
        c1, c2 = _rand(16, 16), _rand(16, 16)
        x = _rand(16, 16, 128)
        y, info = gemt3_planned(x, c1, c2, c3, fuse=True, with_info=True)
        assert info["fused"] is not None
        # 128-length contractions reassociated between schedules: ~1e-3 rel
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(gemt3(x, c1, c2, c3)),
                                   rtol=5e-3, atol=5e-4)

    def test_affine_out_applies_after_fusion(self):
        x, cs = _problem((16, 12, 16), (8, 10, 12), seed=4)
        out = _rand(8, 10, 12)
        y = gemt3_planned(x, *cs, out=out, fuse=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(gemt3(x, *cs, out=out)),
                                   rtol=1e-4, atol=1e-4)

    def test_interpret_pallas_through_engine(self):
        """The fused Pallas kernel (interpret off-TPU) inside the engine."""
        x, cs = _problem((16, 16, 16), (16, 16, 16), seed=5)
        y, info = gemt3_planned(x, *cs, fuse=True, use_pallas=True,
                                with_info=True)
        assert info["fused"] is not None
        np.testing.assert_allclose(np.asarray(y), np.asarray(gemt3(x, *cs)),
                                   rtol=2e-4, atol=2e-4)


class TestFusionDecision:
    """Plan-level: fusion triggers/declines on the modeled boundaries."""

    def _serving(self, batch=8, n=32):
        c = coefficient_matrix("dct", n)
        return (batch, n, n, n), (c, c, c)

    def test_triggers_on_serving_shape_with_savings(self):
        # fuse="pair" pins the pair depth: since the whole-transform
        # megakernel landed, auto mode prefers the triple on these shapes
        # (tests/test_fused3_gemt.py covers that boundary).
        shape, cs = self._serving()
        plan = build_plan(shape, jnp.float32, *cs, fuse="pair")
        assert plan.fused is not None
        assert plan.fused.hbm_savings > 1.5
        assert plan.hbm_bytes_moved < plan.hbm_bytes_staged
        # the fused pair covers consecutive stages of the chosen order
        assert plan.fused.first in (0, 1)
        pair = {plan.order[plan.fused.first], plan.order[plan.fused.first + 1]}
        assert pair == {plan.fused.mode_a, plan.fused.mode_b}

    def test_fuse_false_pins_staged(self):
        shape, cs = self._serving()
        plan = build_plan(shape, jnp.float32, *cs, fuse=False)
        assert plan.fused is None
        assert plan.hbm_bytes_moved == plan.hbm_bytes_staged

    def test_declines_when_tiles_cannot_fit_vmem(self):
        shape, cs = self._serving()
        assert build_plan(shape, jnp.float32, *cs, fuse="pair",
                          vmem_budget=1024).fused is None
        # the boundary is monotone: a roomy budget fuses again
        assert build_plan(shape, jnp.float32, *cs, fuse="pair",
                          vmem_budget=64 << 20).fused is not None

    def test_vmem_model_boundary(self):
        """Fusion flips exactly where the modeled footprint crosses."""
        shape, cs = self._serving()
        plan = build_plan(shape, jnp.float32, *cs, fuse="pair")
        need = plan.fused.vmem_bytes
        assert build_plan(shape, jnp.float32, *cs, fuse="pair",
                          vmem_budget=need).fused is not None
        # the minimal-footprint tiling (all dims at 8) is the true floor
        floor = fused_vmem_bytes(8, 8, 8, 8, plan.fused.kbp, 4)
        assert build_plan(shape, jnp.float32, *cs, fuse="pair",
                          vmem_budget=floor - 1).fused is None

    def test_declines_below_kernel_dims(self):
        """Sub-MIN_KERNEL_DIM extents fall back to staged (einsum) stages."""
        x, cs = _problem((4, 4, 4), (4, 4, 4))
        plan = build_plan(x.shape, x.dtype, *cs, fuse=True)
        assert plan.fused is None

    def test_declines_for_complex(self):
        c = coefficient_matrix("dft", 16)
        plan = build_plan((16, 16, 16), jnp.complex64, c, c, c, fuse=True)
        assert plan.fused is None

    def test_pair_choice_prefers_larger_intermediate(self):
        """Rectangular Tucker: the fused pair is the two compressive modes."""
        dims, ranks = (64, 48, 32), (8, 16, 32)
        x, cs = _problem(dims, ranks, seed=6)
        plan = build_plan(x.shape, x.dtype, *cs)
        assert plan.fused is not None
        # compressive modes 1 and 2 are contracted first and fused
        assert {plan.fused.mode_a, plan.fused.mode_b} == {1, 2}

    def test_sparse_assignment_lands_on_a_stream(self):
        """A compressive sparse C streams as C_a, where 2D skipping works.

        (When K_a is large the model may legitimately prefer the dense
        matrix on the a-stream — X refetches per ka-block outweigh the
        skipping — so this pins the compressive case where ESOP-on-a is
        the clear bytes winner.)
        """
        keep = np.array([[1], [0], [0], [1]]).astype(bool)  # 50% zero blocks
        c3 = _block_sparse(256, 64, keep, 64)
        c1, c2 = jnp.asarray(np.eye(64, dtype=np.float32)), _rand(48, 48)
        plan = build_plan((64, 48, 256), jnp.float32, c1, c2, c3, fuse="pair",
                          block_sizes=(128, 64, 64))
        assert plan.fused is not None
        assert plan.fused.mode_a == 3
        assert plan.fused.zero_block_frac_a == pytest.approx(0.5)
        assert plan.fused.zero_block_frac_b == 0.0

    def test_key_distinguishes_fusion_options(self):
        shape, cs = self._serving()
        k0 = build_plan(shape, jnp.float32, *cs).key
        k1 = build_plan(shape, jnp.float32, *cs, fuse=False).key
        k2 = build_plan(shape, jnp.float32, *cs, vmem_budget=1 << 20).key
        assert len({k0, k1, k2}) == 3

    def test_fused_tile_sizes_fit_budget(self):
        for budget in (1 << 18, 1 << 20, 1 << 23):
            tiles = fused_tile_sizes(256, 64, 64, 64, 64, 4, budget)
            if tiles is not None:
                bu, bka, bnb, bna, kbp = tiles
                assert fused_vmem_bytes(bu, bka, bnb, bna, kbp, 4) <= budget


class TestFusedAutotune:
    def test_autotune_fused_caches_and_matches(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "a.json"))
        x, cs = _problem((16, 16, 16), (16, 16, 16), seed=8)
        y = gemt3_planned(x, *cs, fuse="pair", autotune=True,
                          autotune_cache=cache)
        np.testing.assert_allclose(np.asarray(y), np.asarray(gemt3(x, *cs)),
                                   rtol=1e-4, atol=1e-4)
        assert any(k.startswith("fused:") for k in cache._entries)

    def test_autotune_fused_respects_vmem_budget(self, tmp_path):
        cache = AutotuneCache(str(tmp_path / "a.json"))
        ca, cb = _rand(32, 32), _rand(32, 32)
        budget = fused_vmem_bytes(16, 16, 16, 16, 32, 4)
        bu, bka, bnb = autotune_fused(
            ca, cb, rows=64, dtype=jnp.float32, start=(16, 16, 16),
            bna=16, kbp=32, cache=cache, use_pallas=True, max_steps=1,
            reps=1, vmem_budget=budget)
        assert fused_vmem_bytes(bu, bka, bnb, 16, 32, 4) <= budget


class TestFusedServe:
    def test_serve_session_reports_fusion(self):
        from repro.serve import DxtServeSession
        sess = DxtServeSession(kind="dct")
        b = _rand(4, 16, 16, 16)
        y = sess.transform(b)
        ref = jax.vmap(lambda t: dxt3d(t, "dct"))(b)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        assert sess.last_info["fused"] is not None
        assert sess.fused_served == 4
        assert 0 < sess.hbm_bytes_moved < sess.hbm_bytes_staged
        # staged sessions stay available and report zero fused traffic
        sess_staged = DxtServeSession(kind="dct", fuse=False)
        sess_staged.transform(b)
        assert sess_staged.fused_served == 0
        assert sess_staged.hbm_bytes_moved == sess_staged.hbm_bytes_staged


@pytest.mark.bench_smoke
def test_bench_smoke_fused_vs_staged():
    """Tier-2 smoke: one tiny fused-vs-staged comparison, exercised in the
    default run (select just this with ``pytest -m bench_smoke``)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 16, 16, 16)).astype(np.float32))
    c = coefficient_matrix("dct", 16)
    y_staged, i_staged = gemt3_planned(x, c, c, c, fuse=False, with_info=True)
    y_fused, i_fused = gemt3_planned(x, c, c, c, with_info=True)
    assert i_staged["fused"] is None and i_fused["fused"] is not None
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_staged),
                               rtol=1e-4, atol=1e-4)
    assert i_fused["hbm_bytes_moved"] < i_staged["hbm_bytes_moved"]
    assert i_fused["fused"]["hbm_savings"] > 1.0
