"""Core TriADA GEMT/DXT correctness: Eq.(1) oracle, all parenthesizations,
outer-product equivalence, transform family round trips, Parseval, Tucker."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import (PAREN_ORDERS, coefficient_matrix, dxt3d, gemt3,
                        gemt3_outer, hosvd, inverse_coefficient_matrix, macs,
                        mode_product, time_steps, tucker_compress,
                        tucker_expand, tucker_roundtrip_error)

RNG = np.random.default_rng(0)


def _rand(*shape):
    return jnp.asarray(RNG.normal(size=shape).astype(np.float32))


def _direct(x, c1, c2, c3):
    """Element-wise 6D-index-space oracle of Eq. (1)."""
    return jnp.einsum("abc,ax,by,cz->xyz", x, c1, c2, c3)


class TestGemt:
    def test_all_orders_match_direct(self):
        x = _rand(5, 6, 7)
        cs = [coefficient_matrix("dct", n) for n in x.shape]
        ref = _direct(x, *cs)
        for order in PAREN_ORDERS:
            np.testing.assert_allclose(gemt3(x, *cs, order=order), ref,
                                       rtol=3e-5, atol=3e-5)

    def test_outer_equals_inner(self):
        x = _rand(4, 5, 6)
        cs = [coefficient_matrix("dht", n) for n in x.shape]
        np.testing.assert_allclose(gemt3_outer(x, *cs), gemt3(x, *cs),
                                   rtol=3e-5, atol=3e-5)

    def test_affine_accumulate(self):
        """Eq. (1) is affine: += initialization."""
        x = _rand(4, 4, 4)
        out = _rand(4, 4, 4)
        cs = [coefficient_matrix("dct", 4)] * 3
        np.testing.assert_allclose(
            gemt3(x, *cs, out=out), _direct(x, *cs) + out, rtol=3e-5, atol=3e-5)

    def test_rectangular_gemt(self):
        """Non-square C: tensor expansion & compression (paper §2.3)."""
        x = _rand(4, 5, 6)
        c1, c2, c3 = _rand(4, 8), _rand(5, 2), _rand(6, 3)
        y = gemt3(x, c1, c2, c3)
        assert y.shape == (8, 2, 3)
        ref = jnp.einsum("abc,ax,by,cz->xyz", x, c1, c2, c3)
        np.testing.assert_allclose(y, ref, rtol=1e-4, atol=1e-4)

    def test_mode_product_validation(self):
        x = _rand(3, 4, 5)
        with pytest.raises(ValueError):
            mode_product(x, _rand(4, 4), 1)  # wrong extent
        with pytest.raises(ValueError):
            mode_product(x, _rand(3, 3), 4)  # bad mode

    def test_complexity_model(self):
        assert macs(4, 5, 6) == 4 * 5 * 6 * 15
        assert time_steps(4, 5, 6) == 15


class TestTransforms:
    @pytest.mark.parametrize("kind", ["dct", "dht", "dft"])
    def test_roundtrip(self, kind):
        x = _rand(5, 6, 7)
        xr = dxt3d(dxt3d(x, kind), kind, inverse=True)
        np.testing.assert_allclose(
            xr.real if jnp.iscomplexobj(xr) else xr, x, rtol=2e-4, atol=2e-4)

    def test_dwht_roundtrip_pow2(self):
        x = _rand(4, 8, 2)
        np.testing.assert_allclose(dxt3d(dxt3d(x, "dwht"), "dwht", inverse=True),
                                   x, rtol=2e-4, atol=2e-4)
        with pytest.raises(ValueError):
            coefficient_matrix("dwht", 6)

    def test_dft_matches_fftn(self):
        x = _rand(4, 6, 5)  # non-square, non-pow2: no FFT-style size limits
        np.testing.assert_allclose(np.asarray(dxt3d(x, "dft")),
                                   np.fft.fftn(np.asarray(x), norm="ortho"),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("kind", ["dct", "dht", "dwht"])
    def test_orthonormality(self, kind):
        n = 8
        c = np.asarray(coefficient_matrix(kind, n))
        np.testing.assert_allclose(c.T @ c, np.eye(n), atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 9), st.integers(2, 9), st.integers(2, 9),
           st.sampled_from(["dct", "dht"]))
    def test_parseval_property(self, n1, n2, n3, kind):
        """Orthogonal transforms are isometries: ||DXT(x)|| == ||x||."""
        rng = np.random.default_rng(n1 * 100 + n2 * 10 + n3)
        x = jnp.asarray(rng.normal(size=(n1, n2, n3)).astype(np.float32))
        y = dxt3d(x, kind)
        np.testing.assert_allclose(float(jnp.linalg.norm(y.ravel())),
                                   float(jnp.linalg.norm(x.ravel())),
                                   rtol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 7), st.integers(2, 7), st.integers(2, 7))
    def test_linearity_property(self, n1, n2, n3):
        rng = np.random.default_rng(n1 + n2 * 7 + n3 * 49)
        x = jnp.asarray(rng.normal(size=(n1, n2, n3)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n1, n2, n3)).astype(np.float32))
        a = 2.5
        np.testing.assert_allclose(dxt3d(a * x + y, "dct"),
                                   a * dxt3d(x, "dct") + dxt3d(y, "dct"),
                                   rtol=2e-3, atol=2e-4)


class TestTucker:
    def test_full_rank_roundtrip(self):
        x = _rand(5, 6, 7)
        err = tucker_roundtrip_error(x, (5, 6, 7))
        assert err["rel_fro_err"] < 1e-5

    def test_low_rank_compresses_lowrank_tensor(self):
        """A genuinely rank-(2,2,2) tensor reconstructs exactly."""
        g = _rand(2, 2, 2)
        us = (_rand(8, 2), _rand(9, 2), _rand(10, 2))
        x = gemt3(g, us[0].T, us[1].T, us[2].T)
        factors = hosvd(x, (2, 2, 2))
        xr = tucker_expand(tucker_compress(x, factors), factors)
        np.testing.assert_allclose(xr, x, rtol=1e-3, atol=1e-3)
