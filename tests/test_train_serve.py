"""Training/serving integration: loss decreases, optimizer behaviour,
generation loop, data determinism."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.configs import load_config
from repro.data import TokenSource, make_source
from repro.models import ShardCtx
from repro.optim import OptConfig, adamw_update, global_norm, init_opt_state, schedule
from repro.serve import ServeSession, SlotManager
from repro.train import build_train_step, cross_entropy, init_train_state

CTX = ShardCtx()


class TestOptim:
    def test_adamw_converges_quadratic(self):
        ocfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                         total_steps=200)
        params = {"w": jnp.asarray([3.0, -2.0])}
        state = init_opt_state(params, ocfg)
        for _ in range(150):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state, m = adamw_update(params, grads, state, ocfg)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_schedule_warmup_cosine(self):
        ocfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                         min_lr_frac=0.1)
        assert float(schedule(jnp.int32(0), ocfg)) == pytest.approx(0.1)
        assert float(schedule(jnp.int32(9), ocfg)) == pytest.approx(1.0)
        assert float(schedule(jnp.int32(99), ocfg)) == pytest.approx(0.1, abs=0.01)

    def test_grad_clipping_metric(self):
        ocfg = OptConfig(clip_norm=1e-6)
        params = {"w": jnp.ones((4,))}
        state = init_opt_state(params, ocfg)
        new_params, _, m = adamw_update(params, {"w": jnp.ones((4,)) * 100},
                                        state, ocfg)
        assert float(m["grad_norm"]) == pytest.approx(200.0)
        # clipped to ~0 step (plus weight decay)
        assert float(jnp.max(jnp.abs(new_params["w"] - params["w"]))) < 1e-3


class TestCrossEntropy:
    def test_matches_naive(self):
        rng = np.random.default_rng(0)
        logits = jnp.asarray(rng.normal(size=(2, 8, 16)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, 16, size=(2, 8)).astype(np.int32))
        naive = -jnp.mean(jnp.take_along_axis(
            jax.nn.log_softmax(logits, -1), labels[..., None], -1))
        np.testing.assert_allclose(float(cross_entropy(logits, labels, 16)),
                                   float(naive), rtol=1e-5)


class TestTrainLoop:
    def test_loss_decreases_qwen_smoke(self):
        cfg = load_config("qwen1_5_0_5b", smoke=True)
        ocfg = OptConfig(lr=3e-3, warmup_steps=2, total_steps=30,
                         weight_decay=0.01)
        state = init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
        step = jax.jit(build_train_step(cfg, CTX, ocfg), donate_argnums=(0,))
        src = TokenSource(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=4, seed=0)
        losses = []
        for i in range(25):
            batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
            state, metrics = step(state, batch)  # same batch: must overfit
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]

    def test_microbatch_equivalence(self):
        """grad-accum over 2 microbatches ≈ full batch (same data)."""
        cfg = load_config("qwen1_5_0_5b", smoke=True)
        import dataclasses
        cfg = dataclasses.replace(cfg, param_dtype=jnp.float32,
                                  act_dtype=jnp.float32, remat="none")
        ocfg = OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
        s1 = init_train_state(jax.random.PRNGKey(0), cfg, ocfg)
        s2 = jax.tree.map(lambda x: x, s1)
        src = TokenSource(vocab_size=cfg.vocab_size, seq_len=16,
                          global_batch=4, seed=0)
        batch = {k: jnp.asarray(v) for k, v in src.batch(0).items()}
        f1 = jax.jit(build_train_step(cfg, CTX, ocfg, microbatch=1))
        f2 = jax.jit(build_train_step(cfg, CTX, ocfg, microbatch=2))
        s1, m1 = f1(s1, batch)
        s2, m2 = f2(s2, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         s1["params"], s2["params"])
        assert max(jax.tree.leaves(d)) < 1e-4


class TestServe:
    def test_generate_greedy_deterministic(self):
        cfg = load_config("qwen1_5_0_5b", smoke=True)
        from repro.models import init_model
        params = init_model(jax.random.PRNGKey(0), cfg)
        sess = ServeSession(cfg=cfg, params=params)
        rng = np.random.default_rng(0)
        prompts = rng.integers(0, cfg.vocab_size, size=(2, 8)).astype(np.int32)
        out1 = sess.generate(prompts, max_new=6)
        out2 = sess.generate(prompts, max_new=6)
        assert out1.shape == (2, 6)
        np.testing.assert_array_equal(out1, out2)
        assert out1.max() < cfg.vocab_size  # padded vocab never sampled

    def test_slot_manager(self):
        sm = SlotManager(n_slots=2, max_len=16)
        a, b = sm.admit("r1"), sm.admit("r2")
        assert sm.admit("r3") is None and sm.utilization == 1.0
        sm.step(a)
        sm.finish(a)
        assert sm.admit("r3") is not None


class TestData:
    def test_determinism_pure_function_of_step(self):
        s1 = TokenSource(vocab_size=100, seq_len=8, global_batch=2, seed=5)
        s2 = TokenSource(vocab_size=100, seq_len=8, global_batch=2, seed=5)
        for step in (0, 7, 123):
            b1, b2 = s1.batch(step), s2.batch(step)
            np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        assert not np.array_equal(s1.batch(0)["tokens"], s1.batch(1)["tokens"])

    def test_labels_are_shifted_tokens(self):
        src = TokenSource(vocab_size=50, seq_len=8, global_batch=1, seed=0)
        b = src.batch(3)
        assert b["tokens"].shape == (1, 8) and b["labels"].shape == (1, 8)

    def test_modality_sources(self):
        cfg = load_config("musicgen_large", smoke=True)
        import dataclasses as dc
        from repro.configs import SHAPES
        shape = dc.replace(SHAPES["train_4k"], seq_len=8, global_batch=2)
        b = make_source(cfg, shape).batch(0)
        assert b["tokens"].shape == (2, 8, 4)
        cfg2 = load_config("qwen2_vl_72b", smoke=True)
        b2 = make_source(cfg2, shape).batch(0)
        assert b2["embeddings"].shape == (2, 8, cfg2.d_model)
