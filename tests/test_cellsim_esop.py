"""TriADA cell-grid simulator + ESOP: device-model validation of the
paper's time-step/MAC/energy claims and the sparsity method."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypothesis_compat import given, settings, st

from repro.core import (EsopStats, block_nonzero_mask, coefficient_matrix,
                        energy_joules, esop_gemt3, gemt3, macs, prune,
                        simulate_dxt3, sparsity, time_steps)

RNG = np.random.default_rng(7)


def _problem(n1, n2, n3, kind="dct"):
    x = RNG.normal(size=(n1, n2, n3)).astype(np.float32)
    cs = [np.asarray(coefficient_matrix(kind, n)) for n in (n1, n2, n3)]
    return x, cs


class TestCellSim:
    def test_dense_matches_gemt3_and_counts(self):
        x, cs = _problem(5, 6, 7)
        out, stats = simulate_dxt3(x, *cs, esop=False)
        ref = gemt3(jnp.asarray(x), *map(jnp.asarray, cs))
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-4)
        # Paper §5.4: linear time-steps, hypercubic MACs, 100% efficiency.
        assert stats.steps_done == time_steps(5, 6, 7)
        assert stats.macs_done == macs(5, 6, 7)

    @pytest.mark.parametrize("order", [(3, 1, 2), (1, 2, 3), (2, 3, 1)])
    def test_stage_orders(self, order):
        x, cs = _problem(4, 5, 6)
        out, _ = simulate_dxt3(x, *cs, order=order, esop=False)
        ref = gemt3(jnp.asarray(x), *map(jnp.asarray, cs), order=order)
        np.testing.assert_allclose(out, ref, rtol=1e-3, atol=1e-4)

    def test_esop_bit_identical_and_counts_match_analytic(self):
        x, cs = _problem(6, 5, 4)
        x *= RNG.random(x.shape) > 0.7  # ~70% sparse data
        cs[2] = cs[2] * (RNG.random(cs[2].shape) > 0.4)
        out_sim, st_sim = simulate_dxt3(x, *cs, esop=True)
        out_ana, st_ana = esop_gemt3(jnp.asarray(x), *map(jnp.asarray, cs))
        np.testing.assert_allclose(out_sim, out_ana, rtol=1e-3, atol=1e-4)
        assert st_sim.macs_done == st_ana.macs_done
        assert st_sim.steps_done == st_ana.steps_done
        assert st_sim.coeff_sends_done == st_ana.coeff_sends_done
        assert st_sim.data_sends_done == st_ana.data_sends_done
        assert st_ana.macs_done < st_ana.macs_dense  # actually skipped work

    def test_all_zero_vector_skips_time_step(self):
        x, cs = _problem(4, 4, 4)
        cs = [np.array(c) for c in cs]
        cs[2][2, :] = 0.0  # one all-zero streamed coefficient row
        _, stats = simulate_dxt3(x, *cs, esop=True)
        assert stats.steps_done == time_steps(4, 4, 4) - 1

    def test_esop_dense_data_no_skips(self):
        x, cs = _problem(3, 3, 3)
        x += 10.0  # strictly nonzero
        _, stats = simulate_dxt3(x, *cs, esop=True)
        # DCT row 0 is constant nonzero; other rows have no exact zeros.
        assert stats.steps_done == stats.steps_dense


class TestEsop:
    @settings(max_examples=10, deadline=None)
    @given(st.floats(0.0, 0.95))
    def test_energy_savings_track_sparsity(self, p):
        rng = np.random.default_rng(int(p * 1000))
        x = rng.normal(size=(6, 6, 6)).astype(np.float32)
        x *= rng.random(x.shape) >= p
        cs = [np.asarray(coefficient_matrix("dht", 6))] * 3
        _, stats = esop_gemt3(jnp.asarray(x), *map(jnp.asarray, cs))
        e = energy_joules(stats)
        assert 0.0 <= e["saving"] <= 1.0
        if p > 0.5:
            assert e["saving"] > 0.1  # visibly saves on sparse data

    def test_prune_and_sparsity(self):
        x = jnp.asarray([[0.001, 1.0], [-0.002, -2.0]])
        xp = prune(x, 0.01)
        assert sparsity(xp) == 0.5
        np.testing.assert_array_equal(np.asarray(xp),
                                      [[0.0, 1.0], [0.0, -2.0]])

    def test_block_mask(self):
        a = jnp.zeros((4, 6)).at[0, 0].set(1.0).at[3, 5].set(2.0)
        m = block_nonzero_mask(a, (2, 3))
        np.testing.assert_array_equal(np.asarray(m),
                                      [[True, False], [False, True]])
        with pytest.raises(ValueError):
            block_nonzero_mask(a, (3, 3))

    def test_stats_addition(self):
        s = EsopStats(10, 5, 3, 2, 4, 2, 6, 3)
        t = s + s
        assert t.macs_dense == 20 and t.macs_done == 10
        assert t.mac_savings == 0.5
